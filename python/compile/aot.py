"""AOT lowering: JAX module forwards → HLO text artifacts for Rust/PJRT.

Interchange format is HLO *text*, not `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Usage (from `make artifacts`):
    cd python && python -m compile.aot --out-dir ../artifacts

Emits one `<module>.hlo.txt` per profiled module plus `manifest.json`
describing entry shapes so the Rust runtime can build input literals
without re-deriving them.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import FEATURE_DIM, PREDICT_BATCH, SimDims


def to_hlo_text(lowered) -> str:
    """Lowered jax fn → XLA HLO text (return_tuple=True; unwrap with
    to_tuple1 on the Rust side for single-output fns)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def module_entries(dims: SimDims):
    """(name, fn, input_shapes) for every AOT-exported executable."""
    x_shape = (dims.batch, dims.seq, dims.d_model)
    shapes = model.param_shapes(dims)

    def entry(name, fn, first_input):
        ins = [first_input] + list(shapes[name])
        return name, fn, ins

    return [
        entry(
            "self_attention",
            functools.partial(model.self_attention, dims=dims),
            x_shape,
        ),
        entry("mlp", functools.partial(model.mlp, dims=dims), x_shape),
        entry("rmsnorm", functools.partial(model.norm, dims=dims), x_shape),
        entry(
            "logits_head",
            functools.partial(model.logits_head, dims=dims),
            x_shape,
        ),
        entry("block", functools.partial(model.block, dims=dims), x_shape),
        entry(
            "ridge_predict",
            model.ridge_predict,
            (PREDICT_BATCH, FEATURE_DIM),
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    dims = SimDims()
    manifest: dict = {
        "sim_dims": {
            "batch": dims.batch,
            "seq": dims.seq,
            "d_model": dims.d_model,
            "n_heads": dims.n_heads,
            "n_kv_heads": dims.n_kv_heads,
            "d_ff": dims.d_ff,
            "vocab": dims.vocab,
        },
        "feature_dim": FEATURE_DIM,
        "predict_batch": PREDICT_BATCH,
        "modules": {},
    }

    for name, fn, in_shapes in module_entries(dims):
        specs = [_spec(s) for s in in_shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shape = jax.eval_shape(fn, *specs)
        manifest["modules"][name] = {
            "inputs": [list(s) for s in in_shapes],
            "output": list(out_shape.shape),
            "hlo": f"{name}.hlo.txt",
            "hlo_chars": len(text),
        }
        print(f"aot: {name}: {len(text)} chars -> {path}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"aot: wrote manifest with {len(manifest['modules'])} modules")


if __name__ == "__main__":
    main()
