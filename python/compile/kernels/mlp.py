"""Layer-1 Pallas kernel: fused SwiGLU feed-forward.

One kernel computes ``(silu(x @ Wg) * (x @ Wu)) @ Wd`` per row-block so the
[block_rows, d_ff] gate/up intermediates live only in VMEM — the TPU
analogue of the paper testbed's CUDA epilogue fusion (DESIGN.md
§Hardware-Adaptation). Weights are kept whole per grid cell at sim scale
(d_model=256, d_ff=1024 ⇒ ~3 MiB f32, inside the ~16 MiB VMEM budget);
the d_ff axis would be tiled next for larger shapes.

interpret=True: see attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # [block_rows, D]
    wg = wg_ref[...].astype(jnp.float32)  # [D, F]
    wu = wu_ref[...].astype(jnp.float32)
    wd = wd_ref[...].astype(jnp.float32)  # [F, D]
    g = x @ wg
    u = x @ wu
    h = (g * jnp.reciprocal(1.0 + jnp.exp(-g))) * u  # silu(g) * u, f32 accum
    o_ref[...] = (h @ wd).astype(o_ref.dtype)


def swiglu_mlp(x, w_gate, w_up, w_down, *, block_rows: int = 64):
    """Fused SwiGLU MLP. x: [N, D]; w_gate/w_up: [D, F]; w_down: [F, D]."""
    n, d = x.shape
    f = w_gate.shape[1]
    assert w_gate.shape == (d, f) and w_up.shape == (d, f) and w_down.shape == (f, d)
    block_rows = min(block_rows, n)
    assert n % block_rows == 0

    grid = (n // block_rows,)
    out = pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d, f), lambda r: (0, 0)),
            pl.BlockSpec((d, f), lambda r: (0, 0)),
            pl.BlockSpec((f, d), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, w_gate, w_up, w_down)
    return out


def vmem_footprint_bytes(
    *, block_rows: int, d_model: int, d_ff: int, dtype_bytes: int = 4
) -> int:
    """Per-cell VMEM residency estimate (DESIGN.md §Perf)."""
    x_tile = block_rows * d_model * dtype_bytes
    weights = (2 * d_model * d_ff + d_ff * d_model) * dtype_bytes
    inter = 2 * block_rows * d_ff * 4  # f32 gate/up intermediates
    out = block_rows * d_model * dtype_bytes
    return x_tile + weights + inter + out
