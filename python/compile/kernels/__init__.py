"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

Kernels: flash-style tiled attention, fused SwiGLU MLP, RMSNorm.
`ref.py` holds the pure-jnp oracles used by the pytest suite.
"""

from .attention import flash_attention
from .mlp import swiglu_mlp
from .rmsnorm import rmsnorm

__all__ = ["flash_attention", "swiglu_mlp", "rmsnorm"]
