"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain `jax.numpy` ops (no Pallas, no tiling, no online softmax).
`python/tests/` asserts `assert_allclose(kernel(...), ref(...))` across a
hypothesis-driven sweep of shapes/dtypes — this is the core correctness
signal for Layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """Reference multi-head attention.

    q: [B, H, S, Dh]; k, v: [B, H, S, Dh] (KV heads already expanded for
    grouped-query attention). Returns [B, H, S, Dh].
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    if causal:
        seq = q.shape[2]
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def swiglu_mlp(x, w_gate, w_up, w_down):
    """Reference SwiGLU feed-forward: (silu(x Wg) * (x Wu)) Wd.

    x: [N, D]; w_gate/w_up: [D, F]; w_down: [F, D].
    """
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    h = (g * jnp.reciprocal(1.0 + jnp.exp(-g))) * u  # silu(g) * u
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def rmsnorm(x, gain, *, eps: float = 1e-6):
    """Reference RMSNorm over the last axis. x: [N, D], gain: [D]."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jnp.reciprocal(jnp.sqrt(ms + eps)) * gain.astype(jnp.float32)).astype(
        x.dtype
    )


def expand_kv(k, *, n_heads: int):
    """Expand grouped KV heads [B, Hkv, S, D] -> [B, H, S, D] by repetition."""
    n_kv = k.shape[1]
    assert n_heads % n_kv == 0
    return jnp.repeat(k, n_heads // n_kv, axis=1)
