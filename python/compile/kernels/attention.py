"""Layer-1 Pallas kernel: tiled (flash-style) causal attention.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
testbed runs CUDA attention kernels that stream K/V through SMEM per
threadblock. On TPU the analogous structure is a grid over
(batch*heads, q-blocks) where each grid cell holds a Q tile resident in
VMEM and streams K/V tiles HBM→VMEM, maintaining an online-softmax
accumulator so the S×S score matrix is never materialized.

Executed with ``interpret=True`` — the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the AOT artifact runs
anywhere (including the Rust PJRT client).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Mask fill value. A large-negative finite value (not -inf) so that a
# fully-masked score row produces exp(s - m) == 0 rather than NaN.
_MASK_VALUE = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale, block_k, seq_len, causal):
    """One grid cell: one (batch*head, q-block) tile.

    q_ref: [1, block_q, Dh] VMEM tile; k_ref/v_ref: [1, S, Dh] (streamed in
    block_k chunks below); o_ref: [1, block_q, Dh].
    """
    q = q_ref[0].astype(jnp.float32)  # [bq, Dh]
    block_q, head_dim = q.shape
    q_block = pl.program_id(1)

    m0 = jnp.full((block_q,), _MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim), jnp.float32)

    num_k_blocks = seq_len // block_k

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        s = (q @ k.T) * sm_scale  # [bq, bk]
        if causal:
            q_ids = q_block * block_q + jax.lax.iota(jnp.int32, block_q)
            k_ids = i * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(q_ids[:, None] >= k_ids[None, :], s, _MASK_VALUE)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 32,
    block_k: int = 32,
):
    """Tiled attention. q/k/v: [B, H, S, Dh] (KV already head-expanded).

    Requires S % block_q == 0 and S % block_k == 0 (the sweep tests cover
    several block sizes; `model.py` picks blocks that divide the AOT
    shapes). Accumulation is always f32 regardless of input dtype.
    """
    batch, heads, seq, head_dim = q.shape
    assert k.shape == q.shape and v.shape == q.shape, "expand KV heads first"
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    assert seq % block_q == 0 and seq % block_k == 0
    if sm_scale is None:
        sm_scale = 1.0 / (head_dim**0.5)

    qf = q.reshape(batch * heads, seq, head_dim)
    kf = k.reshape(batch * heads, seq, head_dim)
    vf = v.reshape(batch * heads, seq, head_dim)

    grid = (batch * heads, seq // block_q)
    kernel = functools.partial(
        _attn_kernel,
        sm_scale=sm_scale,
        block_k=block_k,
        seq_len=seq,
        causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, head_dim), lambda bh, qb: (bh, qb, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda bh, qb: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), lambda bh, qb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(batch, heads, seq, head_dim)


def vmem_footprint_bytes(
    *, block_q: int, block_k: int, seq: int, head_dim: int, dtype_bytes: int = 4
) -> int:
    """Estimated per-cell VMEM residency of the kernel (DESIGN.md §Perf).

    Q tile + one K tile + one V tile + f32 accumulator/stats + output tile.
    The full K/V rows are *streamed*, so only one block_k tile of each is
    live at a time.
    """
    q_tile = block_q * head_dim * dtype_bytes
    kv_tiles = 2 * block_k * head_dim * dtype_bytes
    acc = block_q * head_dim * 4 + 2 * block_q * 4
    out = block_q * head_dim * dtype_bytes
    return q_tile + kv_tiles + acc + out
