"""Layer-1 Pallas kernel: RMSNorm over the last axis, row-block tiled.

interpret=True: see attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # [block_rows, D]
    g = g_ref[...].astype(jnp.float32)  # [D]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


def rmsnorm(x, gain, *, eps: float = 1e-6, block_rows: int = 64):
    """RMSNorm. x: [N, D]; gain: [D]."""
    n, d = x.shape
    assert gain.shape == (d,)
    block_rows = min(block_rows, n)
    assert n % block_rows == 0

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, gain)
    return out
