"""Layer-2: JAX compute graphs for the profiled LLM modules.

These functions are the *functional* forward passes of the model-tree leaf
modules PIE-P profiles (Self-Attention, MLP, RMSNorm, LLMEmbedding/logits)
plus the composed transformer block, all calling the Layer-1 Pallas
kernels. `aot.py` lowers each one once to HLO text; the Rust coordinator
executes the artifacts via PJRT on the request path (Python never runs at
inference time).

The AOT shapes are the reduced "sim scale" dimensions (SimDims): energy in
the reproduction substrate depends on the *architecture descriptors* (see
rust/src/models/), while these executables prove the three-layer stack
composes and supply real activations whose tensor shapes drive the
simulator's communication volumes.

All module functions take positional array arguments only (x, then flat
params) so the Rust side can feed PJRT literals in a documented order —
see `aot.py`'s manifest.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import flash_attention, rmsnorm as rmsnorm_kernel, swiglu_mlp
from .kernels.ref import expand_kv


@dataclass(frozen=True)
class SimDims:
    """Reduced dimensions used for the AOT artifacts."""

    batch: int = 2
    seq: int = 64
    d_model: int = 256
    n_heads: int = 8
    n_kv_heads: int = 4  # grouped-query, mirroring Mistral/Llama-70B style
    d_ff: int = 1024
    vocab: int = 2048

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Feature-vector width shared with rust/src/features/ (padded). Keep in
# sync with `piep::features::FEATURE_DIM`.
FEATURE_DIM = 48
# Row count of the batched ridge-predict executable; Rust pads partial
# batches with zero rows.
PREDICT_BATCH = 256


def self_attention(x, wq, wk, wv, wo, *, dims: SimDims):
    """Self-attention module: QKV projection + tiled attention + out-proj.

    x: [B, S, D]; wq: [D, H*Dh]; wk/wv: [D, Hkv*Dh]; wo: [H*Dh, D].
    """
    b, s, d = x.shape
    h, hk, dh = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = (x @ wq).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, hk, dh).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, hk, dh).transpose(0, 2, 1, 3)
    k = expand_kv(k, n_heads=h)
    v = expand_kv(v, n_heads=h)
    o = flash_attention(q, k, v, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return o @ wo


def mlp(x, w_gate, w_up, w_down, *, dims: SimDims):
    """SwiGLU MLP module. x: [B, S, D]."""
    b, s, d = x.shape
    out = swiglu_mlp(x.reshape(b * s, d), w_gate, w_up, w_down)
    return out.reshape(b, s, d)


def norm(x, gain, *, dims: SimDims):
    """RMSNorm module. x: [B, S, D]; gain: [D]."""
    b, s, d = x.shape
    return rmsnorm_kernel(x.reshape(b * s, d), gain).reshape(b, s, d)


def logits_head(x, w_embed_t, *, dims: SimDims):
    """LLMEmbedding (tied) output head: last-token logits. x: [B, S, D]."""
    return x[:, -1, :] @ w_embed_t  # [B, V]


def block(x, g1, wq, wk, wv, wo, g2, w_gate, w_up, w_down, *, dims: SimDims):
    """Pre-norm transformer block: x + Attn(RMS(x)); x + MLP(RMS(x))."""
    h = x + self_attention(norm(x, g1, dims=dims), wq, wk, wv, wo, dims=dims)
    return h + mlp(norm(h, g2, dims=dims), w_gate, w_up, w_down, dims=dims)


def ridge_predict(features, weights, bias):
    """Batched leaf-regressor inference used on the Rust prediction path.

    features: [PREDICT_BATCH, FEATURE_DIM]; weights: [FEATURE_DIM]; bias: [1].
    Returns [PREDICT_BATCH] predicted energies (Joules).
    """
    return features @ weights + bias[0]


def param_shapes(dims: SimDims) -> dict[str, list[tuple[int, ...]]]:
    """Positional parameter shapes per module (after x), used by aot.py's
    manifest and mirrored by the Rust runtime when building literals."""
    d, h, hk, dh, f = (
        dims.d_model,
        dims.n_heads,
        dims.n_kv_heads,
        dims.head_dim,
        dims.d_ff,
    )
    attn = [(d, h * dh), (d, hk * dh), (d, hk * dh), (h * dh, d)]
    mlp_p = [(d, f), (d, f), (f, d)]
    return {
        "self_attention": attn,
        "mlp": mlp_p,
        "rmsnorm": [(d,)],
        "logits_head": [(d, dims.vocab)],
        "block": [(d,)] + attn + [(d,)] + mlp_p,
        "ridge_predict": [(FEATURE_DIM,), (1,)],
    }
