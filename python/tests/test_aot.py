"""AOT path: every module lowers to parseable HLO text with stable entry
signatures, and the HLO text format is the one the Rust loader expects."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.model import SimDims


@pytest.fixture(scope="module")
def dims():
    return SimDims()


@pytest.fixture(scope="module")
def entries(dims):
    return aot.module_entries(dims)


def test_all_expected_modules_present(entries):
    names = [n for n, _, _ in entries]
    assert names == [
        "self_attention",
        "mlp",
        "rmsnorm",
        "logits_head",
        "block",
        "ridge_predict",
    ]


@pytest.mark.parametrize("idx", range(6))
def test_module_lowers_to_hlo_text(entries, idx):
    name, fn, in_shapes = entries[idx]
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in in_shapes]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    # HLO text sanity: module header, an ENTRY computation, a ROOT op.
    assert text.startswith("HloModule"), name
    assert "ENTRY" in text and "ROOT" in text, name
    # return_tuple=True ⇒ root is a tuple (Rust side unwraps to_tuple1).
    root_lines = [ln for ln in text.splitlines() if "ROOT" in ln]
    assert any("tuple" in ln or "(" in ln for ln in root_lines), name


def test_hlo_numerics_roundtrip_via_xla_client(entries, dims):
    """Compile the emitted HLO text with the local CPU client and check the
    numbers against the jax function — the same round-trip Rust performs."""
    from jax._src.lib import xla_client as xc

    name, fn, in_shapes = entries[2]  # rmsnorm: cheap
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in in_shapes]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))

    keys = jax.random.split(jax.random.PRNGKey(0), len(in_shapes))
    args = [np.asarray(jax.random.normal(k, s), np.float32) for k, s in zip(keys, in_shapes)]
    want = np.asarray(fn(*[jnp.asarray(a) for a in args]))

    # The text itself is validated structurally above; execute the same
    # lowered computation through the raw xla_client (the Rust `xla` crate
    # drives the equivalent C API) and compare numerics. The client API
    # renamed compile() -> compile_and_load() across jaxlib releases; take
    # whichever this jaxlib carries.
    client = xc.make_cpu_client()
    mlir_mod = jax.jit(fn).lower(*specs).compiler_ir("stablehlo")
    if hasattr(client, "compile_and_load"):
        devices = xc.DeviceList(tuple(client.local_devices()[:1]))
        exe = client.compile_and_load(str(mlir_mod), devices)
    else:
        exe = client.compile(str(mlir_mod))
    out = exe.execute_sharded(
        [client.buffer_from_pyval(a) for a in args]
    ).disassemble_into_single_device_arrays()
    np.testing.assert_allclose(np.asarray(out[0][0]), want, rtol=1e-5, atol=1e-5)


def test_manifest_dims_match_feature_contract(dims):
    # The Rust feature pipeline pads to FEATURE_DIM and batches PREDICT_BATCH
    # rows; these constants are part of the artifact ABI.
    assert model.FEATURE_DIM == 48
    assert model.PREDICT_BATCH == 256
    assert dims.d_model % dims.n_heads == 0
    assert dims.n_heads % dims.n_kv_heads == 0
