"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/block sizes; every property asserts
allclose against `kernels.ref`. This is the build-time gate for the AOT
artifacts — if these fail, `make artifacts` must not be trusted.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from compile.kernels import attention, mlp, ref

jax.config.update("jax_enable_x64", False)

# Interpret-mode Pallas is slow; keep example counts modest but meaningful.
SETTINGS = settings(max_examples=12, deadline=None)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------- attention
@given(
    batch=st.sampled_from([1, 2]),
    heads=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([32, 64, 128]),
    head_dim=st.sampled_from([16, 32, 64]),
    block_q=st.sampled_from([16, 32]),
    block_k=st.sampled_from([16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
@SETTINGS
def test_attention_matches_ref(batch, heads, seq, head_dim, block_q, block_k, causal, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(keys[0], (batch, heads, seq, head_dim))
    k = _rand(keys[1], (batch, heads, seq, head_dim))
    v = _rand(keys[2], (batch, heads, seq, head_dim))
    got = attention.flash_attention(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_non_causal_uniform_values():
    # With identical V rows, non-causal attention output == that row exactly.
    b, h, s, d = 1, 2, 32, 16
    q = _rand(jax.random.PRNGKey(0), (b, h, s, d))
    k = _rand(jax.random.PRNGKey(1), (b, h, s, d))
    v = jnp.broadcast_to(jnp.arange(d, dtype=jnp.float32), (b, h, s, d))
    got = attention.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, v, rtol=1e-5, atol=1e-5)


def test_attention_causal_first_row_is_v0():
    # Causal: position 0 can only attend to itself.
    b, h, s, d = 1, 1, 64, 32
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (_rand(kk, (b, h, s, d)) for kk in keys)
    got = attention.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got[:, :, 0, :], v[:, :, 0, :], rtol=1e-5, atol=1e-5)


def test_attention_scale_override():
    b, h, s, d = 1, 1, 32, 16
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (_rand(kk, (b, h, s, d)) for kk in keys)
    got = attention.flash_attention(q, k, v, causal=True, sm_scale=0.5)
    want = ref.attention(q, k, v, causal=True, sm_scale=0.5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_large_magnitude_stability():
    # Online softmax must survive large score magnitudes without overflow.
    b, h, s, d = 1, 1, 64, 32
    keys = jax.random.split(jax.random.PRNGKey(11), 3)
    q = _rand(keys[0], (b, h, s, d), scale=30.0)
    k = _rand(keys[1], (b, h, s, d), scale=30.0)
    v = _rand(keys[2], (b, h, s, d))
    got = attention.flash_attention(q, k, v, causal=True)
    want = ref.attention(q, k, v, causal=True)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attention_rejects_unexpanded_kv():
    q = jnp.zeros((1, 4, 32, 16))
    k = jnp.zeros((1, 2, 32, 16))
    with pytest.raises(AssertionError):
        attention.flash_attention(q, k, k)


def test_attention_vmem_footprint_budget():
    # DESIGN.md §Perf: per-cell VMEM residency ≤ 2 MiB at profile shapes.
    bytes_ = attention.vmem_footprint_bytes(block_q=32, block_k=32, seq=64, head_dim=64)
    assert bytes_ <= 2 * 1024 * 1024


# ---------------------------------------------------------------- swiglu mlp
@given(
    rows=st.sampled_from([32, 64, 128]),
    d_model=st.sampled_from([32, 64, 128]),
    d_ff=st.sampled_from([64, 128, 256]),
    block_rows=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@SETTINGS
def test_swiglu_matches_ref(rows, d_model, d_ff, block_rows, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(keys[0], (rows, d_model))
    wg = _rand(keys[1], (d_model, d_ff), scale=0.1)
    wu = _rand(keys[2], (d_model, d_ff), scale=0.1)
    wd = _rand(keys[3], (d_ff, d_model), scale=0.1)
    got = mlp.swiglu_mlp(x, wg, wu, wd, block_rows=block_rows)
    want = ref.swiglu_mlp(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_swiglu_zero_input_is_zero():
    x = jnp.zeros((32, 64))
    w = jnp.ones((64, 128)) * 0.1
    wd = jnp.ones((128, 64)) * 0.1
    got = mlp.swiglu_mlp(x, w, w, wd)
    np.testing.assert_allclose(got, jnp.zeros_like(x), atol=1e-7)


def test_swiglu_block_rows_larger_than_n_clamps():
    x = _rand(jax.random.PRNGKey(0), (16, 32))
    w = _rand(jax.random.PRNGKey(1), (32, 64), scale=0.1)
    wd = _rand(jax.random.PRNGKey(2), (64, 32), scale=0.1)
    got = mlp.swiglu_mlp(x, w, w, wd, block_rows=512)
    want = ref.swiglu_mlp(x, w, w, wd)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ rmsnorm
@given(
    rows=st.sampled_from([16, 64, 128]),
    d_model=st.sampled_from([32, 128, 256]),
    block_rows=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@SETTINGS
def test_rmsnorm_matches_ref(rows, d_model, block_rows, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = _rand(keys[0], (rows, d_model), scale=3.0)
    g = _rand(keys[1], (d_model,))
    got = rmsnorm_kernel(x, g, block_rows=block_rows)
    want = ref.rmsnorm(x, g)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_rmsnorm_unit_gain_unit_rows():
    # Rows with RMS 1 and unit gain pass through unchanged.
    d = 64
    x = jnp.ones((16, d))
    got = rmsnorm_kernel(x, jnp.ones((d,)))
    np.testing.assert_allclose(got, x, rtol=1e-5)


def test_rmsnorm_output_rms_is_gain_rms():
    # After normalization with gain g, each row's per-dim values are g * x_hat
    # where rms(x_hat) == 1.
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    x = _rand(keys[0], (32, 128), scale=10.0)
    got = rmsnorm_kernel(x, jnp.ones((128,)))
    rms = np.sqrt(np.mean(np.asarray(got) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(32), rtol=1e-3)
