"""L2 correctness: composed module forwards vs pure-jnp block, shapes, GQA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.model import SimDims


def _params(key, shapes):
    keys = jax.random.split(key, len(shapes))
    return [jax.random.normal(k, s) * 0.05 for k, s in zip(keys, shapes)]


@pytest.fixture(scope="module")
def dims():
    return SimDims()


@pytest.fixture(scope="module")
def x(dims):
    return jax.random.normal(jax.random.PRNGKey(0), (dims.batch, dims.seq, dims.d_model))


def _ref_self_attention(x, wq, wk, wv, wo, dims):
    b, s, _ = x.shape
    h, hk, dh = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = (x @ wq).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, hk, dh).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, hk, dh).transpose(0, 2, 1, 3)
    k = ref.expand_kv(k, n_heads=h)
    v = ref.expand_kv(v, n_heads=h)
    o = ref.attention(q, k, v, causal=True)
    return o.transpose(0, 2, 1, 3).reshape(b, s, h * dh) @ wo


def test_self_attention_module(dims, x):
    shapes = model.param_shapes(dims)["self_attention"]
    p = _params(jax.random.PRNGKey(1), shapes)
    got = model.self_attention(x, *p, dims=dims)
    want = _ref_self_attention(x, *p, dims=dims)
    assert got.shape == x.shape
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_mlp_module(dims, x):
    shapes = model.param_shapes(dims)["mlp"]
    p = _params(jax.random.PRNGKey(2), shapes)
    got = model.mlp(x, *p, dims=dims)
    b, s, d = x.shape
    want = ref.swiglu_mlp(x.reshape(b * s, d), *p).reshape(b, s, d)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_norm_module(dims, x):
    (gshape,) = model.param_shapes(dims)["rmsnorm"]
    g = jax.random.normal(jax.random.PRNGKey(3), gshape)
    got = model.norm(x, g, dims=dims)
    b, s, d = x.shape
    want = ref.rmsnorm(x.reshape(b * s, d), g).reshape(b, s, d)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_logits_head_shape(dims, x):
    (wshape,) = model.param_shapes(dims)["logits_head"]
    w = jax.random.normal(jax.random.PRNGKey(4), wshape) * 0.05
    out = model.logits_head(x, w, dims=dims)
    assert out.shape == (dims.batch, dims.vocab)
    np.testing.assert_allclose(out, x[:, -1, :] @ w, rtol=1e-5, atol=1e-6)


def test_block_composition(dims, x):
    """Full pre-norm block vs a pure-jnp recomposition of the oracles."""
    shapes = model.param_shapes(dims)["block"]
    p = _params(jax.random.PRNGKey(5), shapes)
    g1, wq, wk, wv, wo, g2, wg, wu, wd = p
    got = model.block(x, *p, dims=dims)

    b, s, d = x.shape
    xn = ref.rmsnorm(x.reshape(b * s, d), g1).reshape(b, s, d)
    h = x + _ref_self_attention(xn, wq, wk, wv, wo, dims)
    hn = ref.rmsnorm(h.reshape(b * s, d), g2).reshape(b, s, d)
    want = h + ref.swiglu_mlp(hn.reshape(b * s, d), wg, wu, wd).reshape(b, s, d)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-5)


def test_block_residual_identity_at_zero_params(dims, x):
    """Zero weights + zero gains ⇒ the block is the identity (residuals only)."""
    shapes = model.param_shapes(dims)["block"]
    p = [jnp.zeros(s) for s in shapes]
    got = model.block(x, *p, dims=dims)
    np.testing.assert_allclose(got, x, atol=1e-6)


def test_ridge_predict(dims):
    feats = jax.random.normal(jax.random.PRNGKey(6), (model.PREDICT_BATCH, model.FEATURE_DIM))
    w = jax.random.normal(jax.random.PRNGKey(7), (model.FEATURE_DIM,))
    b = jnp.array([1.5])
    out = model.ridge_predict(feats, w, b)
    assert out.shape == (model.PREDICT_BATCH,)
    np.testing.assert_allclose(out, feats @ w + 1.5, rtol=1e-5, atol=1e-5)


def test_param_shapes_cover_all_modules(dims):
    shapes = model.param_shapes(dims)
    assert set(shapes) == {
        "self_attention",
        "mlp",
        "rmsnorm",
        "logits_head",
        "block",
        "ridge_predict",
    }
    # block params = norm + attn + norm + mlp
    assert len(shapes["block"]) == 1 + 4 + 1 + 3
