//! Minimal property-testing harness (no proptest on this offline image).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` against `cases` generated
//! inputs; on failure it performs a bounded shrink search (halving numeric
//! fields via the `Shrink` impl) and panics with the minimal failing case.

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate smaller values, roughly ordered most-aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.abs() > 1e-9 {
            out.push(self / 2.0);
            out.push(0.0);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink, D: Shrink> Shrink for (A, B, C, D) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone(), self.3.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone(), self.3.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c, self.3.clone())),
        );
        out.extend(
            self.3
                .shrink()
                .into_iter()
                .map(|d| (self.0.clone(), self.1.clone(), self.2.clone(), d)),
        );
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

impl Shrink for Vec<f64> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.len() > 1 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
        }
        out
    }
}

/// Run `prop` on `cases` random inputs from `gen`; shrink on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Bounded greedy shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = 200usize;
            'outer: while budget > 0 {
                for cand in best.shrink() {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input (shrunk): {best:?}\n  reason: {best_msg}"
            );
        }
    }
}

/// Assertion helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |r| r.below(100),
            |_| {
                Ok(())
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        forall(
            2,
            100,
            |r| 10 + r.below(1000),
            |&x| ensure(x < 5, format!("x={x} not < 5")),
        );
    }

    #[test]
    fn shrink_reduces_usize() {
        let s = 100usize.shrink();
        assert!(s.contains(&50));
        assert!(s.contains(&99));
        assert!(0usize.shrink().is_empty());
    }

    #[test]
    fn tuple_shrink_covers_both_fields() {
        let t = (4usize, 2.0f64);
        let s = t.shrink();
        assert!(s.iter().any(|(a, _)| *a < 4));
        assert!(s.iter().any(|(_, b)| *b < 2.0));
    }
}
