//! Deterministic PRNG + distributions.
//!
//! The offline image has no `rand` crate, so we carry a small, well-known
//! generator: SplitMix64 for seeding and xoshiro256** for the stream.
//! Everything in the simulator that is "non-deterministic" on real hardware
//! (rank skew, stragglers, thermal drift, meter noise) is driven from seeded
//! instances of this PRNG so experiments are exactly reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box–Muller pair.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream for a named sub-component.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal parameterized by the *target* mean and coefficient of
    /// variation (cv = std/mean) of the resulting distribution — the
    /// natural way to express "compute time jitters by ~8%".
    pub fn lognormal_mean_cv(&mut self, mean: f64, cv: f64) -> f64 {
        if cv <= 0.0 {
            return mean;
        }
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = mean.ln() - 0.5 * sigma2;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    /// Unit-mean lognormal factor with precomputed sigma:
    /// `exp(σ·N − σ²/2)`. Hot-path variant of `lognormal_mean_cv` that
    /// skips the per-call `ln(1+cv²)` (see `SkewModel`).
    #[inline]
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal() - 0.5 * sigma * sigma).exp()
    }

    /// Bernoulli.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Exponential with given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.uniform()).max(1e-300).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random f32 vector in [-scale, scale] (used to seed PJRT literals).
    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n)
            .map(|_| (self.range(-1.0, 1.0) as f32) * scale)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_hits_target_mean_and_cv() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal_mean_cv(5.0, 0.2)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let cv = var.sqrt() / mean;
        assert!((mean - 5.0).abs() / 5.0 < 0.01, "mean={mean}");
        assert!((cv - 0.2).abs() < 0.01, "cv={cv}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
