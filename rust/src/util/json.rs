//! Minimal JSON reader/writer (no serde on this offline image).
//!
//! The parser covers the subset we exchange with the Python AOT step
//! (`artifacts/manifest.json`) and our own report files: objects, arrays,
//! strings with standard escapes, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}
pub fn arr(xs: Vec<Json>) -> Json {
    Json::Arr(xs)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                c => {
                    // Copy the full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let chunk = std::str::from_utf8(&self.b[self.i..self.i + len])
                        .map_err(|_| "bad utf8")?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
        Err("unterminated string".into())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{"sim_dims": {"batch": 2, "seq": 64}, "modules": {"mlp": {"inputs": [[2,64,256],[256,1024]], "hlo": "mlp.hlo.txt"}}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("sim_dims").unwrap().get("batch").unwrap().as_usize(), Some(2));
        let inputs = j
            .get("modules")
            .unwrap()
            .get("mlp")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(inputs[0].as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let j = obj(vec![
            ("a", num(1.5)),
            ("b", arr(vec![Json::Bool(true), Json::Null])),
            ("c", s("x\"y\n")),
        ]);
        let txt = j.render();
        assert_eq!(Json::parse(&txt).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn parses_negative_and_exponent_numbers() {
        let j = Json::parse("[-1.5e3, 0.25]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[1].as_f64(), Some(0.25));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"a\\u0041b\"").unwrap();
        assert_eq!(j.as_str(), Some("aAb"));
    }
}
