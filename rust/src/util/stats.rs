//! Small statistics toolkit: aggregates, MAPE, Spearman rank correlation,
//! linear algebra helpers used by the regressors.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// The four cross-GPU aggregates PIE-P uses (mean, std, min, max).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Aggregates {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Aggregates {
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        Aggregates {
            mean: mean(xs),
            std: std_dev(xs),
            min: min(xs),
            max: max(xs),
        }
    }
}

/// Mean absolute percentage error over (prediction, truth) pairs.
/// Pairs with |truth| < 1e-12 are skipped (undefined percentage).
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&p, &t) in pred.iter().zip(truth) {
        if t.abs() > 1e-12 {
            acc += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * acc / n as f64
    }
}

/// Standard error of the per-sample absolute percentage errors (the paper's
/// Figure-2 error bars).
pub fn mape_std_err(pred: &[f64], truth: &[f64]) -> f64 {
    let apes: Vec<f64> = pred
        .iter()
        .zip(truth)
        .filter(|(_, &t)| t.abs() > 1e-12)
        .map(|(&p, &t)| 100.0 * ((p - t) / t).abs())
        .collect();
    if apes.len() < 2 {
        return 0.0;
    }
    std_dev(&apes) / (apes.len() as f64).sqrt()
}

/// Ranks with average ties (1-based), as used by Spearman.
fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg_rank;
        }
        i = j + 1;
    }
    out
}

/// Pearson correlation.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Spearman rank correlation (Pearson over average ranks; tie-safe).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Solve the symmetric positive-definite system `A x = b` in place via
/// Cholesky. `a` is row-major n×n. Panics if not SPD (callers add a ridge).
pub fn cholesky_solve(a: &mut [f64], b: &mut [f64], n: usize) {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    // Decompose A = L L^T (lower triangle stored in `a`).
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        assert!(d > 0.0, "matrix not positive definite (d={d} at {j})");
        let ljj = d.sqrt();
        a[j * n + j] = ljj;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / ljj;
        }
    }
    // Forward solve L y = b.
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= a[i * n + k] * b[k];
        }
        b[i] = s / a[i * n + i];
    }
    // Back solve L^T x = y.
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= a[k * n + i] * b[k];
        }
        b[i] = s / a[i * n + i];
    }
}

/// Percentile (linear interpolation), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (p / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_basic() {
        let a = Aggregates::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.mean, 2.5);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 4.0);
        assert!((a.std - 1.118).abs() < 1e-3);
    }

    #[test]
    fn mape_exact_prediction_is_zero() {
        assert_eq!(mape(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn mape_known_value() {
        // |110-100|/100 = 10%, |90-100|/100 = 10% -> 10%
        assert!((mape(&[110.0, 90.0], &[100.0, 100.0]) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn mape_skips_zero_truth() {
        assert!((mape(&[5.0, 110.0], &[0.0, 100.0]) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [10.0, 100.0, 1000.0, 1e4, 1e5];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_reverse_is_minus_one() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman(&xs, &ys) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let xs = [1.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 1.0, 2.0, 3.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cholesky_solves_spd_system() {
        // A = [[4,2],[2,3]], b = [10, 9] -> x = [1.5, 2]
        let mut a = vec![4.0, 2.0, 2.0, 3.0];
        let mut b = vec![10.0, 9.0];
        cholesky_solve(&mut a, &mut b, 2);
        assert!((b[0] - 1.5).abs() < 1e-12);
        assert!((b[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_median() {
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), 2.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 100.0), 4.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.0), 1.0);
    }
}
