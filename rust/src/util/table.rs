//! ASCII table / CSV rendering for the report layer. Every paper table and
//! figure is emitted both as an aligned console table and as a CSV row set
//! under `reports/`.

use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render an aligned console table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let _ = writeln!(out, "{sep}");
        let hdr: String = self
            .headers
            .iter()
            .zip(&widths)
            .map(|(h, w)| format!("| {h:<w$} "))
            .collect();
        let _ = writeln!(out, "{hdr}|");
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let line: String = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("| {c:<w$} "))
                .collect();
            let _ = writeln!(out, "{line}|");
        }
        let _ = writeln!(out, "{sep}");
        out
    }

    /// CSV (RFC-4180-ish: quotes cells containing separators).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV under `dir/<slug>.csv` (creating dir) and return the path.
    pub fn save_csv(&self, dir: &str, slug: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{slug}.csv");
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a float with `d` decimals (helper for table cells).
pub fn fnum(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

/// Format a percentage cell.
pub fn pct(x: f64) -> String {
    format!("{x:.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| a "));
        assert!(s.contains("| bbbb "));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["h1", "h2"]);
        t.row(vec!["a,b".into(), "c\"d".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"c\"\"d\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["h1", "h2"]);
        t.row(vec!["only-one".into()]);
    }
}
