//! Minimal data-parallel map over scoped threads.
//!
//! The offline image has no `rayon`, so this carries the subset the repo
//! needs: an order-preserving `par_map` with an atomic work index (dynamic
//! load balancing, same scheduling shape as rayon's work-stealing for
//! embarrassingly parallel loops). It powers both the profiling campaigns
//! (`profiler::Campaign::profile`) and the scenario sweep engine
//! (`eval::sweep`). `threads == 0` means one worker per available core;
//! `threads == 1` degrades to a plain serial map (no thread spawn), which
//! is what the sweep engine's `--serial` baseline uses.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a thread-count knob: 0 ⇒ available parallelism.
pub fn effective_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
}

/// Apply `f` to every item, in parallel, preserving input order in the
/// output. Worker threads pull items off a shared atomic index, so uneven
/// per-item cost (e.g. Llama-70B vs Vicuna-7B simulations) load-balances.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = effective_threads(threads).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..items.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker completed every item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn preserves_order_and_covers_all_items() {
        let items: Vec<usize> = (0..257).collect();
        let out = par_map(&items, 0, |&x| x * 2);
        assert_eq!(out.len(), items.len());
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 2 * i);
        }
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..100).collect();
        let f = |&x: &u64| x.wrapping_mul(0x9E3779B97F4A7C15) ^ (x << 7);
        assert_eq!(par_map(&items, 1, f), par_map(&items, 4, f));
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let calls = AtomicUsize::new(0);
        let items: Vec<usize> = (0..64).collect();
        let _ = par_map(&items, 3, |_| calls.fetch_add(1, Ordering::Relaxed));
        assert_eq!(calls.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<usize> = par_map(&[] as &[usize], 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn effective_threads_resolves_zero() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }
}
