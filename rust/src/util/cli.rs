//! Tiny argv parser: `piep <command> [--flag value] [--switch]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value or --key value or --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.flags.contains_key(switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn command_flags_switches() {
        let a = parse("figure2 --passes 5 --out reports --verbose");
        assert_eq!(a.command.as_deref(), Some("figure2"));
        assert_eq!(a.get_usize("passes", 0), 5);
        assert_eq!(a.get("out"), Some("reports"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("train --seed=42 --model=Vicuna-7B");
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get("model"), Some("Vicuna-7B"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn positional_args() {
        let a = parse("reproduce table3 table4");
        assert_eq!(a.positional, vec!["table3", "table4"]);
    }
}
