//! Self-contained utility layer (the offline image has no access to the
//! usual crates — see Cargo.toml).

pub mod cli;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
