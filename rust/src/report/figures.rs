//! Figure harnesses (Figures 2–8 of the paper).

use crate::config::Parallelism;
use crate::eval;
use crate::models::{self, Family};
use crate::predict::codecarbon::CodeCarbon;
use crate::predict::wilkins::Wilkins;
use crate::predict::{PieP, PiepOptions};
use crate::simulator::timeline::ModuleKind;
use crate::simulator::RunRecord;
use crate::util::stats::{self, mape};
use crate::util::table::{fnum, pct, Table};

use super::{family_fit, ReportCtx};

/// MAPE of a predictor closure over a filtered slice of test runs.
fn cell_mape<F: Fn(&RunRecord) -> f64>(test: &[&RunRecord], pred: F) -> f64 {
    let p: Vec<f64> = test.iter().map(|r| pred(r)).collect();
    let t: Vec<f64> = test.iter().map(|r| r.meter_total_j).collect();
    mape(&p, &t)
}

/// Figure 2: model-level MAPE across families/variants/GPU counts under
/// tensor parallelism — PIE-P vs IrEne vs CodeCarbon vs Wilkins.
pub fn figure2(ctx: &mut ReportCtx) -> Table {
    let split_seed = ctx.split_seed;
    let cc = CodeCarbon::new(ctx.campaign.hw.cpu_max_w);
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Figure 2 — MAPE under tensor parallelism (PIE-P vs baselines)",
        &["Family", "Variant", "GPUs", "PIE-P", "±se", "CodeCarbon", "IrEne", "Wilkins"],
    );
    let mut avgs: Vec<(f64, f64, f64, f64)> = Vec::new();
    for family in Family::ALL {
        let fit = family_fit(ds, family, split_seed);
        let wilkins = Wilkins::fit(&fit.train);
        for variant in models::family_variants(family) {
            for gpus in crate::workload::GPU_COUNTS {
                let cell: Vec<&RunRecord> = fit
                    .test
                    .iter()
                    .copied()
                    .filter(|r| r.config.model == variant.name && r.config.gpus == gpus)
                    .collect();
                if cell.is_empty() {
                    continue;
                }
                let piep_pred: Vec<f64> = cell
                    .iter()
                    .map(|r| fit.piep.predict_total(r, &ds.sync_db))
                    .collect();
                let truth: Vec<f64> = cell.iter().map(|r| r.meter_total_j).collect();
                let (pm, pse) = (mape(&piep_pred, &truth), stats::mape_std_err(&piep_pred, &truth));
                let ccm = cell_mape(&cell, |r| cc.estimate(r));
                let irm = cell_mape(&cell, |r| fit.irene.predict_total(r, &ds.sync_db));
                let wim = cell_mape(&cell, |r| wilkins.predict(r));
                avgs.push((pm, ccm, irm, wim));
                t.row(vec![
                    family.name().into(),
                    variant.name.into(),
                    gpus.to_string(),
                    pct(pm),
                    fnum(pse, 1),
                    pct(ccm),
                    pct(irm),
                    pct(wim),
                ]);
            }
        }
    }
    let n = avgs.len() as f64;
    let mean_of = |f: fn(&(f64, f64, f64, f64)) -> f64| avgs.iter().map(f).sum::<f64>() / n;
    t.row(vec![
        "AVERAGE".into(),
        "-".into(),
        "-".into(),
        pct(mean_of(|a| a.0)),
        "-".into(),
        pct(mean_of(|a| a.1)),
        pct(mean_of(|a| a.2)),
        pct(mean_of(|a| a.3)),
    ]);
    ctx.emit(&t, "figure2");
    t
}

/// Figure 3: predicted trade-off between inference time per token and
/// energy per token for Vicuna under TP (highest batch per size).
pub fn figure3(ctx: &mut ReportCtx) -> Table {
    let split_seed = ctx.split_seed;
    let ds = ctx.tp_dataset();
    let fit = family_fit(ds, Family::Vicuna, split_seed);
    let mut t = Table::new(
        "Figure 3 — Vicuna TP: time/token vs PIE-P-predicted energy/token",
        &["Variant", "GPUs", "ms/token", "pred J/token", "true J/token"],
    );
    for variant in models::family_variants(Family::Vicuna) {
        for gpus in crate::workload::GPU_COUNTS {
            let cell: Vec<&RunRecord> = ds
                .runs
                .iter()
                .filter(|r| {
                    r.config.model == variant.name
                        && r.config.gpus == gpus
                        && r.config.batch == 64
                        && r.config.seq_out == 512
                })
                .collect();
            if cell.is_empty() {
                continue;
            }
            let ms: Vec<f64> = cell.iter().map(|r| r.time_per_token_s() * 1e3).collect();
            let pred: Vec<f64> = cell
                .iter()
                .map(|r| fit.piep.predict_total(r, &ds.sync_db) / r.tokens_out as f64)
                .collect();
            let truth: Vec<f64> = cell.iter().map(|r| r.energy_per_token_j()).collect();
            t.row(vec![
                variant.name.into(),
                gpus.to_string(),
                fnum(stats::mean(&ms), 2),
                fnum(stats::mean(&pred), 3),
                fnum(stats::mean(&truth), 3),
            ]);
        }
    }
    ctx.emit(&t, "figure3");
    t
}

/// Figure 4: MAPE for Vicuna under pipeline and data parallelism.
pub fn figure4(ctx: &mut ReportCtx) -> Table {
    let split_seed = ctx.split_seed;
    let cc = CodeCarbon::new(ctx.campaign.hw.cpu_max_w);
    let mut t = Table::new(
        "Figure 4 — Vicuna MAPE under pipeline / data parallelism",
        &["Parallelism", "Variant", "GPUs", "PIE-P", "CodeCarbon", "IrEne"],
    );
    let mut summary: Vec<(Parallelism, f64, f64, f64)> = Vec::new();
    for parallelism in [Parallelism::Pipeline, Parallelism::Data] {
        let ds = ctx.vicuna_dataset(parallelism);
        let fit = family_fit(ds, Family::Vicuna, split_seed);
        for variant in models::family_variants(Family::Vicuna) {
            for gpus in crate::workload::GPU_COUNTS {
                let cell: Vec<&RunRecord> = fit
                    .test
                    .iter()
                    .copied()
                    .filter(|r| r.config.model == variant.name && r.config.gpus == gpus)
                    .collect();
                if cell.is_empty() {
                    continue;
                }
                let pm = cell_mape(&cell, |r| fit.piep.predict_total(r, &ds.sync_db));
                let ccm = cell_mape(&cell, |r| cc.estimate(r));
                let irm = cell_mape(&cell, |r| fit.irene.predict_total(r, &ds.sync_db));
                summary.push((parallelism, pm, ccm, irm));
                t.row(vec![
                    parallelism.name().into(),
                    variant.name.into(),
                    gpus.to_string(),
                    pct(pm),
                    pct(ccm),
                    pct(irm),
                ]);
            }
        }
    }
    for parallelism in [Parallelism::Pipeline, Parallelism::Data] {
        let rows: Vec<&(Parallelism, f64, f64, f64)> =
            summary.iter().filter(|s| s.0 == parallelism).collect();
        let n = rows.len().max(1) as f64;
        t.row(vec![
            format!("AVG {}", parallelism.name()),
            "-".into(),
            "-".into(),
            pct(rows.iter().map(|s| s.1).sum::<f64>() / n),
            pct(rows.iter().map(|s| s.2).sum::<f64>() / n),
            pct(rows.iter().map(|s| s.3).sum::<f64>() / n),
        ]);
    }
    ctx.emit(&t, "figure4");
    t
}

/// Figure 5: energy breakdown — total Wh per run with the AllReduce
/// (communication) share, per family × GPU count (batch 64, the paper's
/// batched-inference setting).
pub fn figure5(ctx: &mut ReportCtx) -> Table {
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Figure 5 — energy breakdown: AllReduce share of total (TP, batch 64)",
        &["Family", "Variant", "GPUs", "Total Wh", "AllReduce Wh", "Share"],
    );
    for family in Family::ALL {
        for variant in models::family_variants(family) {
            for gpus in crate::workload::GPU_COUNTS {
                let cell: Vec<&RunRecord> = ds
                    .runs
                    .iter()
                    .filter(|r| {
                        r.config.model == variant.name
                            && r.config.gpus == gpus
                            && r.config.batch == 64
                            && r.config.seq_out == 512
                    })
                    .collect();
                if cell.is_empty() {
                    continue;
                }
                let total: f64 =
                    stats::mean(&cell.iter().map(|r| r.true_total_j / 3600.0).collect::<Vec<_>>());
                let ar: f64 = stats::mean(
                    &cell
                        .iter()
                        .map(|r| {
                            (r.module_energy_j
                                .get(&ModuleKind::AllReduce)
                                .copied()
                                .unwrap_or(0.0)
                                + r.module_energy_j
                                    .get(&ModuleKind::AllGather)
                                    .copied()
                                    .unwrap_or(0.0))
                                / 3600.0
                        })
                        .collect::<Vec<_>>(),
                );
                t.row(vec![
                    family.name().into(),
                    variant.name.into(),
                    gpus.to_string(),
                    fnum(total, 2),
                    fnum(ar, 2),
                    pct(100.0 * ar / total),
                ]);
            }
        }
    }
    ctx.emit(&t, "figure5");
    t
}

/// Figure 6: ablation — PIE-P vs PIE-P without the waiting phase, per
/// variant/GPU count under TP.
pub fn figure6(ctx: &mut ReportCtx) -> Table {
    let split_seed = ctx.split_seed;
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Figure 6 — ablation: PIE-P vs PIE-P w/o waiting (TP)",
        &["Family", "Variant", "GPUs", "PIE-P", "w/o waiting"],
    );
    let mut accs = (Vec::new(), Vec::new());
    for family in Family::ALL {
        let fit = family_fit(ds, family, split_seed);
        let ablated = PieP::fit(&fit.train, &ds.sync_db, PiepOptions::without_waiting());
        for variant in models::family_variants(family) {
            for gpus in crate::workload::GPU_COUNTS {
                let cell: Vec<&RunRecord> = fit
                    .test
                    .iter()
                    .copied()
                    .filter(|r| r.config.model == variant.name && r.config.gpus == gpus)
                    .collect();
                if cell.is_empty() {
                    continue;
                }
                let pm = cell_mape(&cell, |r| fit.piep.predict_total(r, &ds.sync_db));
                let am = cell_mape(&cell, |r| ablated.predict_total(r, &ds.sync_db));
                accs.0.push(pm);
                accs.1.push(am);
                t.row(vec![
                    family.name().into(),
                    variant.name.into(),
                    gpus.to_string(),
                    pct(pm),
                    pct(am),
                ]);
            }
        }
    }
    t.row(vec![
        "AVERAGE".into(),
        "-".into(),
        "-".into(),
        pct(stats::mean(&accs.0)),
        pct(stats::mean(&accs.1)),
    ]);
    ctx.emit(&t, "figure6");
    t
}

/// Figure 7: Spearman rank correlation of each runtime feature with total
/// energy, per Vicuna size (the paper's heatmap, rendered as a table).
pub fn figure7(ctx: &mut ReportCtx) -> Table {
    let ds = ctx.tp_dataset();
    let variants = models::family_variants(Family::Vicuna);
    let headers: Vec<String> = std::iter::once("Feature".to_string())
        .chain(variants.iter().map(|v| v.name.to_string()))
        .collect();
    let mut t = Table::new(
        "Figure 7 — Spearman ρ of runtime features vs total energy (Vicuna)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut per_variant: Vec<Vec<(&'static str, f64)>> = Vec::new();
    for v in &variants {
        let runs: Vec<RunRecord> = ds
            .runs
            .iter()
            .filter(|r| r.config.model == v.name)
            .cloned()
            .collect();
        per_variant.push(eval::feature_correlations(&runs));
    }
    // Keep the paper-salient subset in its order.
    let salient = [
        "nvml_energy_wh",
        "exec_time_s",
        "batch_size",
        "memory_gb",
        "gpu_util_mean",
        "gpu_mem_util_mean",
        "cpu_util",
        "seq_len",
        "num_gpus",
        "gpu_clock_mean",
    ];
    for name in salient {
        let mut row = vec![name.to_string()];
        for cors in &per_variant {
            let rho = cors.iter().find(|(n, _)| *n == name).map(|(_, r)| *r).unwrap_or(0.0);
            row.push(fnum(rho, 3));
        }
        t.row(row);
    }
    ctx.emit(&t, "figure7");
    t
}

/// Figure 8: the Figure-3 trade-off with *ground-truth* energy.
pub fn figure8(ctx: &mut ReportCtx) -> Table {
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Figure 8 — Vicuna TP: time/token vs ground-truth energy/token",
        &["Variant", "GPUs", "ms/token", "true J/token"],
    );
    for variant in models::family_variants(Family::Vicuna) {
        for gpus in crate::workload::GPU_COUNTS {
            let cell: Vec<&RunRecord> = ds
                .runs
                .iter()
                .filter(|r| {
                    r.config.model == variant.name
                        && r.config.gpus == gpus
                        && r.config.batch == 64
                        && r.config.seq_out == 512
                })
                .collect();
            if cell.is_empty() {
                continue;
            }
            t.row(vec![
                variant.name.into(),
                gpus.to_string(),
                fnum(
                    stats::mean(&cell.iter().map(|r| r.time_per_token_s() * 1e3).collect::<Vec<_>>()),
                    2,
                ),
                fnum(
                    stats::mean(&cell.iter().map(|r| r.energy_per_token_j()).collect::<Vec<_>>()),
                    3,
                ),
            ]);
        }
    }
    ctx.emit(&t, "figure8");
    t
}
