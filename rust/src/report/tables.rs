//! Table harnesses (Tables 2–9 of the paper).

use crate::eval;
use crate::models::{self, Family, ModuleFlops};
use crate::predict::nvml_proxy::NvmlProxy;
use crate::predict::{PieP, PiepOptions};
use crate::simulator::timeline::ModuleKind;
use crate::simulator::RunRecord;
use crate::util::stats::{self, mape};
use crate::util::table::{fnum, pct, Table};

use super::{family_fit, ReportCtx};

/// Module-level MAPE of a fitted model over test runs, for one module kind.
fn module_mape(
    model: &PieP,
    sync_db: &crate::features::SyncDb,
    test: &[&RunRecord],
    kind: ModuleKind,
) -> Option<f64> {
    let mut pred = Vec::new();
    let mut truth = Vec::new();
    for r in test {
        if let (Some(p), Some(&t)) = (
            model.predict_module(r, kind, sync_db),
            r.module_energy_j.get(&kind),
        ) {
            pred.push(p);
            truth.push(t);
        }
    }
    (!pred.is_empty()).then(|| mape(&pred, &truth))
}

/// Leaf-level MAPE for one phase-resolved comm leaf (sync-wait/transfer),
/// scored against exactly the energy target the leaf regressor trained on.
fn part_mape(
    model: &PieP,
    sync_db: &crate::features::SyncDb,
    test: &[&RunRecord],
    leaf: crate::tree::Leaf,
) -> Option<f64> {
    let mut pred = Vec::new();
    let mut truth = Vec::new();
    for r in test {
        if let (Some(p), Some(t)) = (
            model.predict_part(r, leaf, sync_db),
            crate::predict::piep::leaf_target(r, leaf),
        ) {
            if t > 0.0 {
                pred.push(p);
                truth.push(t);
            }
        }
    }
    (!pred.is_empty()).then(|| mape(&pred, &truth))
}

/// Table 2: transformer-module-level prediction error per family, with the
/// FLOPs/block and block-complexity columns.
pub fn table2(ctx: &mut ReportCtx) -> Table {
    let split_seed = ctx.split_seed;
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Table 2 — module-level MAPE vs block complexity",
        &["Family", "Module MAPE", "GFLOPs/Block", "Modules/Block"],
    );
    for family in Family::ALL {
        let fit = family_fit(ds, family, split_seed);
        // Mean over the transformer-block modules (Self-Attn, MLP, Norm).
        let kinds = [ModuleKind::SelfAttention, ModuleKind::Mlp, ModuleKind::Norm];
        let mapes: Vec<f64> = kinds
            .iter()
            .filter_map(|&k| module_mape(&fit.piep, &ds.sync_db, &fit.test, k))
            .collect();
        let smallest = &models::family_variants(family)[0];
        let desc = match family {
            Family::Vicuna => "Standard Self-Attn., MLP",
            Family::Mistral => "Grouped-Query Attn., SwiGLU",
            Family::Llama => "Rotary Embeddings, RMSNorm",
            Family::Qwen => "Multi-Query Attn., Rotary",
        };
        t.row(vec![
            family.name().into(),
            pct(stats::mean(&mapes)),
            fnum(ModuleFlops::table2_gflops_per_block(smallest), 0),
            desc.into(),
        ]);
    }
    ctx.emit(&t, "table2");
    t
}

/// Table 3: leave-one-out generalization — exclude one model size (or one
/// batch size) from training, test on it.
pub fn table3(ctx: &mut ReportCtx) -> Table {
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Table 3 — leave-one-out prediction (variant / batch size held out)",
        &["Family", "Held out", "MAPE", "n"],
    );
    for family in Family::ALL {
        let fam: Vec<RunRecord> = ds
            .runs
            .iter()
            .filter(|r| r.spec.family == family)
            .cloned()
            .collect();
        for variant in models::family_variants(family) {
            let (m, _, n) =
                eval::leave_out_mape(&fam, &ds.sync_db, PiepOptions::default(), |r| {
                    r.config.model == variant.name
                });
            t.row(vec![
                family.name().into(),
                variant.name.into(),
                pct(m),
                n.to_string(),
            ]);
        }
        for batch in [16usize, 32] {
            let (m, _, n) =
                eval::leave_out_mape(&fam, &ds.sync_db, PiepOptions::default(), |r| {
                    r.config.batch == batch
                });
            t.row(vec![
                family.name().into(),
                format!("BS-{batch}"),
                pct(m),
                n.to_string(),
            ]);
        }
    }
    ctx.emit(&t, "table3");
    t
}

/// Table 4: cross-architecture generalization — exclude an entire family.
pub fn table4(ctx: &mut ReportCtx) -> Table {
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Table 4 — cross-architecture generalization (family held out)",
        &["Excluded family", "PIE-P", "IrEne"],
    );
    for family in Family::ALL {
        let (pm, _, _) =
            eval::leave_out_mape(&ds.runs, &ds.sync_db, PiepOptions::default(), |r| {
                r.spec.family == family
            });
        let (im, _, _) = eval::leave_out_mape(&ds.runs, &ds.sync_db, PiepOptions::irene(), |r| {
            r.spec.family == family
        });
        t.row(vec![family.name().into(), pct(pm), pct(im)]);
    }
    ctx.emit(&t, "table4");
    t
}

/// Table 5: module-level MAPE per module kind, 2 vs 4 GPUs (Vicuna).
pub fn table5(ctx: &mut ReportCtx) -> Table {
    let split_seed = ctx.split_seed;
    let ds = ctx.tp_dataset();
    let fit = family_fit(ds, Family::Vicuna, split_seed);
    let mut t = Table::new(
        "Table 5 — module-level MAPE, Vicuna (PIE-P)",
        &["Module", "2 GPUs", "4 GPUs"],
    );
    for kind in [
        ModuleKind::SelfAttention,
        ModuleKind::Mlp,
        ModuleKind::AllReduce,
        ModuleKind::Norm,
        ModuleKind::Embedding,
    ] {
        let cell = |gpus: usize| -> String {
            let test: Vec<&RunRecord> = fit
                .test
                .iter()
                .copied()
                .filter(|r| r.config.gpus == gpus)
                .collect();
            module_mape(&fit.piep, &ds.sync_db, &test, kind)
                .map(pct)
                .unwrap_or_else(|| "-".into())
        };
        t.row(vec![kind.name().into(), cell(2), cell(4)]);
    }
    // Phase-resolved AllReduce decomposition: the sync-wait and transfer
    // leaves are regressed (and scored) separately against the engine's
    // isolated phase energies.
    for leaf in [
        crate::tree::Leaf::sync(ModuleKind::AllReduce),
        crate::tree::Leaf::transfer(ModuleKind::AllReduce),
    ] {
        let cell = |gpus: usize| -> String {
            let test: Vec<&RunRecord> = fit
                .test
                .iter()
                .copied()
                .filter(|r| r.config.gpus == gpus)
                .collect();
            part_mape(&fit.piep, &ds.sync_db, &test, leaf)
                .map(pct)
                .unwrap_or_else(|| "-".into())
        };
        t.row(vec![leaf.name(), cell(2), cell(4)]);
    }
    ctx.emit(&t, "table5");
    t
}

/// Table 6: NVML-as-proxy in-sample error per model (global regression, as
/// a deployment would have: one mapping from NVML energy to wall energy).
pub fn table6(ctx: &mut ReportCtx) -> Table {
    let split_seed = ctx.split_seed;
    let ds = ctx.tp_dataset();
    let (tr_i, te_i) = eval::split_train_test(&ds.runs, 0.7, split_seed);
    let train: Vec<RunRecord> = tr_i.iter().map(|&i| ds.runs[i].clone()).collect();
    let proxy = NvmlProxy::fit(&train);
    let mut t = Table::new(
        "Table 6 — NVML-reported GPU energy as a proxy for total energy",
        &["Model", "MAPE"],
    );
    for variant in models::zoo() {
        let test: Vec<&RunRecord> = te_i
            .iter()
            .map(|&i| &ds.runs[i])
            .filter(|r| r.config.model == variant.name)
            .collect();
        if test.is_empty() {
            continue;
        }
        let pred: Vec<f64> = test.iter().map(|r| proxy.predict(r)).collect();
        let truth: Vec<f64> = test.iter().map(|r| r.meter_total_j).collect();
        t.row(vec![variant.name.into(), pct(mape(&pred, &truth))]);
    }
    ctx.emit(&t, "table6");
    t
}

/// Table 7: NVML proxy leave-one-out generalization.
pub fn table7(ctx: &mut ReportCtx) -> Table {
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Table 7 — NVML proxy leave-one-out generalization",
        &["Model", "MAPE"],
    );
    for variant in models::zoo() {
        let train: Vec<RunRecord> = ds
            .runs
            .iter()
            .filter(|r| r.spec.family == variant.family && r.config.model != variant.name)
            .cloned()
            .collect();
        let test: Vec<&RunRecord> = ds
            .runs
            .iter()
            .filter(|r| r.config.model == variant.name)
            .collect();
        if train.is_empty() || test.is_empty() {
            continue;
        }
        let proxy = NvmlProxy::fit(&train);
        let pred: Vec<f64> = test.iter().map(|r| proxy.predict(r)).collect();
        let truth: Vec<f64> = test.iter().map(|r| r.meter_total_j).collect();
        t.row(vec![variant.name.into(), pct(mape(&pred, &truth))]);
    }
    ctx.emit(&t, "table7");
    t
}

/// Table 8: cross-architecture generalization with and without waiting.
pub fn table8(ctx: &mut ReportCtx) -> Table {
    let ds = ctx.tp_dataset();
    let mut t = Table::new(
        "Table 8 — cross-architecture generalization: PIE-P vs w/o waiting",
        &["Excluded family", "PIE-P", "PIE-P w/o waiting"],
    );
    for family in Family::ALL {
        let (pm, _, _) =
            eval::leave_out_mape(&ds.runs, &ds.sync_db, PiepOptions::default(), |r| {
                r.spec.family == family
            });
        let (am, _, _) =
            eval::leave_out_mape(&ds.runs, &ds.sync_db, PiepOptions::without_waiting(), |r| {
                r.spec.family == family
            });
        t.row(vec![family.name().into(), pct(pm), pct(am)]);
    }
    ctx.emit(&t, "table8");
    t
}

/// Table 9: role of model-structure features (leave-one-variant-out on
/// Vicuna, with vs without the structural feature group).
pub fn table9(ctx: &mut ReportCtx) -> Table {
    let ds = ctx.tp_dataset();
    let vicuna: Vec<RunRecord> = ds
        .runs
        .iter()
        .filter(|r| r.spec.family == Family::Vicuna)
        .cloned()
        .collect();
    let mut t = Table::new(
        "Table 9 — ablation: model-structure features (Vicuna LOO)",
        &["Variant", "With features", "Without features"],
    );
    for variant in models::family_variants(Family::Vicuna) {
        let (with, _, _) =
            eval::leave_out_mape(&vicuna, &ds.sync_db, PiepOptions::default(), |r| {
                r.config.model == variant.name
            });
        let (without, _, _) = eval::leave_out_mape(
            &vicuna,
            &ds.sync_db,
            PiepOptions::without_struct_features(),
            |r| r.config.model == variant.name,
        );
        t.row(vec![variant.name.into(), pct(with), pct(without)]);
    }
    ctx.emit(&t, "table9");
    t
}
