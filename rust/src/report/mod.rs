//! Experiment harnesses: one function per paper table/figure (DESIGN.md §6).
//!
//! Every harness runs its profiling campaign (cached per parallelism),
//! applies the paper's training/evaluation protocol, prints an aligned
//! console table, and saves a CSV under the report directory. Numbers are
//! expected to match the paper in *shape* (ordering, ratios, trends), not
//! absolute values — the substrate is a simulator, not the authors'
//! testbed (see EXPERIMENTS.md for the side-by-side).

mod extensions;
mod figures;
mod tables;

pub use extensions::*;
pub use figures::*;
pub use tables::*;

use crate::config::Parallelism;
use crate::models::Family;
use crate::predict::{PieP, PiepOptions};
use crate::profiler::{Campaign, Dataset};
use crate::util::table::Table;
use crate::workload;

/// Shared context: campaign parameters + dataset caches + output sink.
pub struct ReportCtx {
    pub campaign: Campaign,
    pub out_dir: String,
    pub split_seed: u64,
    tp: Option<Dataset>,
    pp: Option<Dataset>,
    dp: Option<Dataset>,
}

impl ReportCtx {
    pub fn new(out_dir: &str, campaign: Campaign) -> Self {
        ReportCtx {
            campaign,
            out_dir: out_dir.to_string(),
            split_seed: 17,
            tp: None,
            pp: None,
            dp: None,
        }
    }

    /// The full tensor-parallel dataset (all families), profiled once.
    pub fn tp_dataset(&mut self) -> &Dataset {
        if self.tp.is_none() {
            let grid = workload::paper_grid_tp(&self.campaign.hw);
            eprintln!(
                "[profile] tensor-parallel campaign: {} configs × {} passes",
                grid.len(),
                self.campaign.passes
            );
            self.tp = Some(self.campaign.profile(&grid));
        }
        self.tp.as_ref().unwrap()
    }

    /// Vicuna pipeline-/data-parallel datasets (Figure 4).
    pub fn vicuna_dataset(&mut self, parallelism: Parallelism) -> &Dataset {
        let slot = match parallelism {
            Parallelism::Pipeline => &mut self.pp,
            Parallelism::Data => &mut self.dp,
            _ => panic!("use tp_dataset (TP) or eval::sweep (hybrids)"),
        };
        if slot.is_none() {
            let grid = workload::vicuna_grid(parallelism, &self.campaign.hw);
            eprintln!(
                "[profile] vicuna {} campaign: {} configs × {} passes",
                parallelism.name(),
                grid.len(),
                self.campaign.passes
            );
            *slot = Some(self.campaign.profile(&grid));
        }
        slot.as_ref().unwrap()
    }

    /// Print the table and persist its CSV.
    pub fn emit(&self, t: &Table, slug: &str) {
        print!("{}", t.render());
        match t.save_csv(&self.out_dir, slug) {
            Ok(path) => println!("  -> {path}\n"),
            Err(e) => eprintln!("  !! could not save {slug}.csv: {e}"),
        }
    }
}

/// Per-family 70/30 split + fitted PIE-P-family models, shared by several
/// experiments (the Figure-2 protocol: "train a regressor on 70% of
/// module-level predictions aggregated across all variants").
pub struct FamilyFit<'a> {
    pub family: Family,
    pub train: Vec<crate::simulator::RunRecord>,
    pub test: Vec<&'a crate::simulator::RunRecord>,
    pub piep: PieP,
    pub irene: PieP,
}

pub fn family_fit<'a>(ds: &'a Dataset, family: Family, split_seed: u64) -> FamilyFit<'a> {
    let fam_runs: Vec<&crate::simulator::RunRecord> = ds
        .runs
        .iter()
        .filter(|r| r.spec.family == family)
        .collect();
    let owned: Vec<crate::simulator::RunRecord> = fam_runs.iter().map(|r| (*r).clone()).collect();
    let (tr_i, te_i) = crate::eval::split_train_test(&owned, 0.7, split_seed);
    let train: Vec<crate::simulator::RunRecord> =
        tr_i.iter().map(|&i| owned[i].clone()).collect();
    let test: Vec<&crate::simulator::RunRecord> = te_i.iter().map(|&i| fam_runs[i]).collect();
    let piep = PieP::fit(&train, &ds.sync_db, PiepOptions::default());
    let irene = PieP::fit(&train, &ds.sync_db, PiepOptions::irene());
    FamilyFit {
        family,
        train,
        test,
        piep,
        irene,
    }
}
