//! Extension studies beyond the paper's evaluation section:
//!
//! * `crosshw`   — the paper's stated limitation ("PIE-P is
//!   hardware-dependent", Section 6): train on the A6000 testbed, test on
//!   an H100-class testbed (and the reverse) with and without retraining.
//! * `sensitivity` — design-choice ablations DESIGN.md calls out: how many
//!   repeated passes and how many sampled decode steps does the profiler
//!   need before PIE-P's accuracy saturates; how slow can the wall meter
//!   be before ground truth degrades.
//! * `ablate_ring` — collective-algorithm ablation: standard ring vs
//!   interleaved bidirectional ring (IBing, cited by the paper) — where
//!   the crossover in AllReduce time/energy falls.

use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
use crate::eval;
use crate::models::Family;
use crate::predict::{PieP, PiepOptions};
use crate::profiler::Campaign;
use crate::simulator::collective;
use crate::util::stats::{self};
use crate::util::table::{fnum, pct, Table};

use super::ReportCtx;

/// Cross-hardware generalization: fit on one testbed, predict on another.
pub fn crosshw(ctx: &mut ReportCtx) -> Table {
    let mut t = Table::new(
        "Extension — cross-hardware generalization (Vicuna, TP)",
        &["Train on", "Test on", "MAPE", "Retrained MAPE"],
    );
    let beds: [(&str, HwSpec); 2] = [
        ("A6000", HwSpec::a6000_testbed()),
        ("H100", HwSpec::h100_testbed()),
    ];
    // Profile both testbeds once.
    let mut datasets = Vec::new();
    for (name, hw) in &beds {
        let campaign = Campaign {
            hw: hw.clone(),
            ..ctx.campaign.clone()
        };
        let grid = crate::workload::family_grid_tp(Family::Vicuna, hw);
        eprintln!("[profile] {name} cross-hw campaign: {} configs", grid.len());
        datasets.push(campaign.profile(&grid));
    }
    for (i, (train_name, _)) in beds.iter().enumerate() {
        for (j, (test_name, _)) in beds.iter().enumerate() {
            if i == j {
                continue;
            }
            let model = PieP::fit(&datasets[i].runs, &datasets[i].sync_db, PiepOptions::default());
            let test: Vec<&crate::simulator::RunRecord> = datasets[j].runs.iter().collect();
            // Foreign-hardware prediction still uses the *target* machine's
            // offline sync DB (a cheap microbenchmark, per Section 4).
            let (m, _) = eval::score_total(&model, &datasets[j].sync_db, &test);
            // Reference: retrain natively (3-fold CV on the target bed).
            let (native, _) = eval::cv_mape(
                &datasets[j].runs,
                &datasets[j].sync_db,
                PiepOptions::default(),
                3,
                11,
            );
            t.row(vec![
                train_name.to_string(),
                test_name.to_string(),
                pct(m),
                pct(native),
            ]);
        }
    }
    ctx.emit(&t, "ext_crosshw");
    t
}

/// Profiler sampling sufficiency: PIE-P MAPE vs passes and decode steps.
pub fn sensitivity(ctx: &mut ReportCtx) -> Table {
    let mut t = Table::new(
        "Extension — profiler sampling sensitivity (Vicuna, TP)",
        &["Axis", "Value", "PIE-P MAPE", "Campaign runs"],
    );
    let hw = ctx.campaign.hw.clone();
    let grid = crate::workload::family_grid_tp(Family::Vicuna, &hw);

    let eval_with = |passes: usize, steps: usize| -> (f64, usize) {
        let campaign = Campaign {
            hw: hw.clone(),
            passes,
            knobs: SimKnobs {
                sim_decode_steps: steps,
                ..ctx.campaign.knobs.clone()
            },
            ..ctx.campaign.clone()
        };
        let ds = campaign.profile(&grid);
        let (m, _) = eval::cv_mape(&ds.runs, &ds.sync_db, PiepOptions::default(), 3, 13);
        (m, ds.runs.len())
    };

    for passes in [2usize, 5, 10] {
        let (m, n) = eval_with(passes, 16);
        t.row(vec!["passes".into(), passes.to_string(), pct(m), n.to_string()]);
    }
    for steps in [4usize, 8, 16, 32] {
        let (m, n) = eval_with(5, steps);
        t.row(vec!["decode steps".into(), steps.to_string(), pct(m), n.to_string()]);
    }
    // Meter sampling interval: ground-truth degradation.
    for interval in [0.2f64, 1.0, 5.0] {
        let mut hw2 = hw.clone();
        hw2.meter_interval_s = interval;
        let campaign = Campaign {
            hw: hw2,
            ..ctx.campaign.clone()
        };
        let cfg = RunConfig::new("Vicuna-13B", Parallelism::Tensor, 4, 32);
        let ds = campaign.profile(&[cfg]);
        let errs: Vec<f64> = ds
            .runs
            .iter()
            .map(|r| 100.0 * (r.meter_total_j - r.true_total_j).abs() / r.true_total_j)
            .collect();
        t.row(vec![
            "meter interval (s)".into(),
            format!("{interval}"),
            pct(stats::mean(&errs)),
            ds.runs.len().to_string(),
        ]);
    }
    ctx.emit(&t, "ext_sensitivity");
    t
}

/// Ring vs interleaved bidirectional ring: AllReduce time across payloads.
pub fn ablate_ring(ctx: &mut ReportCtx) -> Table {
    let hw = ctx.campaign.hw.clone();
    let mut t = Table::new(
        "Extension — AllReduce algorithm ablation (4 GPUs)",
        &["Payload", "Ring µs", "Bidirectional µs", "Winner"],
    );
    for payload in [16e3, 64e3, 256e3, 1e6, 4e6, 16e6, 64e6] {
        let ring = collective::allreduce(&hw, 4, payload).transfer_s * 1e6;
        let bi = collective::allreduce_bidirectional(&hw, 4, payload).transfer_s * 1e6;
        t.row(vec![
            if payload >= 1e6 {
                format!("{:.0} MB", payload / 1e6)
            } else {
                format!("{:.0} KB", payload / 1e3)
            },
            fnum(ring, 1),
            fnum(bi, 1),
            if bi < ring { "bidirectional" } else { "ring" }.into(),
        ]);
    }
    ctx.emit(&t, "ext_ring");
    t
}

/// Per-parallelism energy-efficiency comparison at fixed work — an
/// operator-facing summary the paper motivates but does not tabulate.
pub fn parallelism_matrix(ctx: &mut ReportCtx) -> Table {
    let hw = ctx.campaign.hw.clone();
    let knobs = ctx.campaign.knobs.clone();
    let mut t = Table::new(
        "Extension — parallelism strategy matrix (Vicuna-13B, batch 32)",
        &["Strategy", "GPUs", "ms/token", "J/token", "Comm share"],
    );
    for par in [Parallelism::Tensor, Parallelism::Pipeline, Parallelism::Data] {
        for gpus in [2usize, 4] {
            let spec = crate::models::by_name("Vicuna-13B").unwrap();
            if !crate::workload::runnable(&spec, par, gpus, &hw) {
                continue;
            }
            let runs: Vec<_> = (0..4u64)
                .map(|s| {
                    let cfg = RunConfig::new("Vicuna-13B", par, gpus, 32).with_seed(s);
                    crate::simulator::simulate_run(&cfg, &hw, &knobs)
                })
                .collect();
            let ms = stats::mean(&runs.iter().map(|r| r.time_per_token_s() * 1e3).collect::<Vec<_>>());
            let jt = stats::mean(&runs.iter().map(|r| r.energy_per_token_j()).collect::<Vec<_>>());
            let share = stats::mean(
                &runs
                    .iter()
                    .map(|r| 100.0 * r.comm_energy_j() / r.true_total_j)
                    .collect::<Vec<_>>(),
            );
            t.row(vec![
                par.name().into(),
                gpus.to_string(),
                fnum(ms, 2),
                fnum(jt, 3),
                pct(share),
            ]);
        }
    }
    ctx.emit(&t, "ext_parallelism_matrix");
    t
}

/// Expert-parallelism study (DESIGN.md §16): full-mesh MoE all-to-all vs
/// the paper's pure strategies at fixed work — decode latency, J/token,
/// the all-to-all energy itself, and the communication share. The ep rows
/// carry nonzero AllToAll energy; the paper strategies never do.
pub fn expert_study(ctx: &mut ReportCtx) -> Table {
    use crate::simulator::timeline::ModuleKind;
    let hw = ctx.campaign.hw.clone();
    let knobs = ctx.campaign.knobs.clone();
    let mut t = Table::new(
        "Extension — expert parallelism (MoE all-to-all) vs paper strategies (Vicuna-7B, batch 32)",
        &["Strategy", "GPUs", "ms/token", "J/token", "A2A J", "Comm share"],
    );
    for gpus in [2usize, 4] {
        for par in [Parallelism::Tensor, Parallelism::Data, Parallelism::expert(gpus)] {
            let spec = crate::models::by_name("Vicuna-7B").unwrap();
            if !crate::workload::runnable(&spec, par, gpus, &hw) {
                continue;
            }
            let runs: Vec<_> = (0..4u64)
                .map(|s| {
                    let cfg = RunConfig::new("Vicuna-7B", par, gpus, 32).with_seed(s);
                    crate::simulator::simulate_run(&cfg, &hw, &knobs)
                })
                .collect();
            let ms = stats::mean(&runs.iter().map(|r| r.time_per_token_s() * 1e3).collect::<Vec<_>>());
            let jt = stats::mean(&runs.iter().map(|r| r.energy_per_token_j()).collect::<Vec<_>>());
            let a2a = stats::mean(
                &runs
                    .iter()
                    .map(|r| r.module_energy_j.get(&ModuleKind::AllToAll).copied().unwrap_or(0.0))
                    .collect::<Vec<_>>(),
            );
            let share = stats::mean(
                &runs
                    .iter()
                    .map(|r| 100.0 * r.comm_energy_j() / r.true_total_j)
                    .collect::<Vec<_>>(),
            );
            t.row(vec![
                par.label(),
                gpus.to_string(),
                fnum(ms, 2),
                fnum(jt, 3),
                fnum(a2a, 1),
                pct(share),
            ]);
        }
    }
    ctx.emit(&t, "ext_expert");
    t
}

/// Topology/tuner study (DESIGN.md §11): run the energy-aware strategy
/// autotuner on the flat single-node testbed and on a 2-node NVLink +
/// InfiniBand fleet, and tabulate each fleet's Pareto front — showing how
/// the node boundary reshapes the energy-optimal deployment.
pub fn tune_study(ctx: &mut ReportCtx) -> Table {
    use crate::cluster::LinkTier;
    use crate::eval::tune::{run_tune, TuneOptions};

    let mut t = Table::new(
        "Extension — energy-aware autotuner across fleets (Vicuna-7B)",
        &["Fleet", "Strategy", "GPUs", "Batch", "J/token", "ms/token", "Pareto", "Argmin"],
    );
    let fleets: [(&str, HwSpec); 2] = [
        ("flat-4gpu", ctx.campaign.hw.clone()),
        ("2node-nvl-ib", HwSpec::cluster_testbed(2, 2, LinkTier::NvLink, LinkTier::InfiniBand, &[])),
    ];
    for (label, hw) in fleets {
        let opts = TuneOptions {
            hw,
            knobs: ctx.campaign.knobs.clone(),
            passes: ctx.campaign.passes.clamp(1, 3),
            base_seed: ctx.campaign.base_seed,
            threads: ctx.campaign.threads,
            gpu_counts: vec![2, 4],
            batches: vec![8, 32],
            ..TuneOptions::default()
        };
        let res = run_tune(&opts);
        let argmin_key = res.argmin_j_token.as_ref().map(|c| c.key.clone());
        let front: std::collections::BTreeSet<String> = res.pareto.iter().map(|c| c.key.clone()).collect();
        for c in &res.candidates {
            t.row(vec![
                label.to_string(),
                c.parallelism.label(),
                c.gpus.to_string(),
                c.batch.to_string(),
                fnum(c.j_per_token, 3),
                fnum(c.ms_per_token, 2),
                if front.contains(&c.key) { "*" } else { "" }.into(),
                if argmin_key.as_deref() == Some(c.key.as_str()) { "<-" } else { "" }.into(),
            ]);
        }
    }
    ctx.emit(&t, "ext_tune");
    t
}

/// Serving table (DESIGN.md §10): policy × strategy × trace family →
/// per-request energy (p50/p99), energy per generated token, continuous-
/// batching occupancy, and the sync-wait share of communication energy —
/// the trace-driven serving analogue of the sweep summary.
pub fn serving(ctx: &mut ReportCtx) -> Table {
    use crate::eval::serving::{run_serving, serving_scenarios, ServingOptions};

    let scenarios = serving_scenarios(&ctx.campaign.hw);
    let opts = ServingOptions {
        hw: ctx.campaign.hw.clone(),
        knobs: ctx.campaign.knobs.clone(),
        requests: (4 * ctx.campaign.passes).max(8),
        seed: ctx.campaign.base_seed,
        threads: ctx.campaign.threads,
        ..ServingOptions::default()
    };
    eprintln!(
        "[serve] {} scenarios × {} requests (trace × policy × strategy)",
        scenarios.len(),
        opts.requests
    );
    let outcomes = run_serving(&scenarios, &opts);
    let mut t = Table::new(
        "Serving — per-request energy by trace × policy × strategy",
        &["Scenario", "Reqs", "Steps", "J/req p50", "J/req p99", "J/token", "Occup", "Sync%", "Wall s"],
    );
    for o in &outcomes {
        t.row(vec![
            o.label.clone(),
            format!("{}{}", o.requests, if o.rejected > 0 { "*" } else { "" }),
            o.steps.to_string(),
            fnum(o.j_per_request_p50, 1),
            fnum(o.j_per_request_p99, 1),
            fnum(o.j_per_token, 2),
            pct(100.0 * o.occupancy),
            pct(100.0 * o.sync_share),
            fnum(o.makespan_s, 1),
        ]);
    }
    ctx.emit(&t, "ext_serving");
    t
}

/// Fleet table (DESIGN.md §13): cluster J/token and tail latency as the
/// replica count and router policy vary over one shared diurnal trace —
/// the multi-replica analogue of the serving table, with every replica a
/// full 2-node NVLink+IB mesh.
pub fn fleet(ctx: &mut ReportCtx) -> Table {
    use crate::cluster::LinkTier;
    use crate::config::TestbedSpec;
    use crate::eval::fleet::{run_fleet_eval, FleetOptions};

    let opts = FleetOptions {
        testbed: TestbedSpec::Cluster {
            nodes: 2,
            gpus_per_node: 2,
            intra: LinkTier::NvLink,
            inter: LinkTier::InfiniBand,
            fleet: Vec::new(),
        },
        requests: (4 * ctx.campaign.passes).max(8),
        knobs: ctx.campaign.knobs.clone(),
        seed: ctx.campaign.base_seed,
        threads: ctx.campaign.threads,
        ..FleetOptions::default()
    };
    eprintln!(
        "[fleet] replicas {:?} × {} policies over one {}-request trace",
        opts.replica_counts,
        opts.policies.len(),
        opts.requests
    );
    let res = run_fleet_eval(&opts);
    let argmin_label = res.argmin.as_ref().map(|c| c.label.clone());
    let mut t = Table::new(
        "Fleet — cluster J/token and latency vs replicas × router",
        &["Replicas", "Router", "J/token", "p50 s", "p99 s", "Cluster J", "Served", "Argmin"],
    );
    for c in &res.cells {
        t.row(vec![
            c.replicas.to_string(),
            c.policy.name().into(),
            fnum(c.j_per_token, 3),
            fnum(c.p50_latency_s, 2),
            fnum(c.p99_latency_s, 2),
            fnum(c.cluster_energy_j, 1),
            format!("{}/{}", c.served, c.served + c.rejected),
            if argmin_label.as_deref() == Some(c.label.as_str()) { "<-" } else { "" }.into(),
        ]);
    }
    ctx.emit(&t, "ext_fleet");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_ctx(dir: &str) -> ReportCtx {
        ReportCtx::new(
            dir,
            Campaign {
                passes: 2,
                knobs: SimKnobs {
                    sim_decode_steps: 4,
                    ..SimKnobs::default()
                },
                ..Campaign::default()
            },
        )
    }

    #[test]
    fn ring_ablation_has_crossover() {
        let mut ctx = quick_ctx("target/test-reports");
        let t = ablate_ring(&mut ctx);
        let winners: Vec<&str> = t.rows.iter().map(|r| r[3].as_str()).collect();
        assert!(winners.contains(&"ring"));
        assert!(winners.contains(&"bidirectional"));
        // Ring wins small payloads, bidirectional wins large: monotone flip.
        assert_eq!(winners.first(), Some(&"ring"));
        assert_eq!(winners.last(), Some(&"bidirectional"));
    }

    #[test]
    fn parallelism_matrix_covers_strategies() {
        let mut ctx = quick_ctx("target/test-reports");
        let t = parallelism_matrix(&mut ctx);
        assert!(t.rows.len() >= 5);
        for strat in ["tensor", "pipeline", "data"] {
            assert!(t.rows.iter().any(|r| r[0] == strat), "{strat}");
        }
    }

    #[test]
    fn serving_table_covers_the_scenario_grid() {
        let mut ctx = quick_ctx("target/test-reports");
        let t = serving(&mut ctx);
        // 4 strategies × 3 trace kinds × 2 policies on the default testbed.
        assert_eq!(t.rows.len(), 24);
        for label in ["poisson/fcfs/tensor", "diurnal/spf/tp2xpp"] {
            assert!(t.rows.iter().any(|r| r[0] == label), "{label}");
        }
        for row in &t.rows {
            let p50: f64 = row[3].parse().unwrap();
            let p99: f64 = row[4].parse().unwrap();
            assert!(p50 > 0.0 && p99 >= p50, "{}: p50 {p50} p99 {p99}", row[0]);
        }
    }

    #[test]
    fn expert_study_rows_carry_alltoall_energy_only_for_ep() {
        let mut ctx = quick_ctx("target/test-reports");
        let t = expert_study(&mut ctx);
        for label in ["ep2", "ep4", "tp", "dp"] {
            assert!(t.rows.iter().any(|r| r[0] == label), "{label} missing");
        }
        for row in &t.rows {
            let a2a: f64 = row[4].parse().unwrap();
            if row[0].starts_with("ep") {
                assert!(a2a > 0.0, "{}: expert rows burn all-to-all energy", row[0]);
            } else {
                assert_eq!(a2a, 0.0, "{}: paper strategies have no all-to-all", row[0]);
            }
        }
    }

    #[test]
    fn tune_study_scores_both_fleets() {
        let mut ctx = quick_ctx("target/test-reports");
        let t = tune_study(&mut ctx);
        for fleet in ["flat-4gpu", "2node-nvl-ib"] {
            assert!(t.rows.iter().any(|r| r[0] == fleet), "{fleet} missing");
            // Each fleet has exactly one argmin marker and ≥1 Pareto member.
            let argmins = t.rows.iter().filter(|r| r[0] == fleet && r[7] == "<-").count();
            assert_eq!(argmins, 1, "{fleet}");
            assert!(t.rows.iter().any(|r| r[0] == fleet && r[6] == "*"), "{fleet}");
        }
    }

    #[test]
    fn fleet_table_covers_the_replica_router_grid() {
        let mut ctx = quick_ctx("target/test-reports");
        let t = fleet(&mut ctx);
        // 2 replica counts × 4 router policies, exactly one argmin marker.
        assert_eq!(t.rows.len(), 8);
        assert_eq!(t.rows.iter().filter(|r| r[7] == "<-").count(), 1);
        for policy in ["rr", "jsq", "energy", "session"] {
            assert!(t.rows.iter().any(|r| r[1] == policy), "{policy}");
        }
        for row in &t.rows {
            let p50: f64 = row[3].parse().unwrap();
            let p99: f64 = row[4].parse().unwrap();
            assert!(p50 > 0.0 && p99 >= p50, "{}: p50 {p50} p99 {p99}", row[1]);
        }
    }

    #[test]
    fn crosshw_demonstrates_hardware_dependence() {
        // Section 6 of the paper: "PIE-P is hardware-dependent ...
        // hardware-agnostic energy prediction is a challenging task". The
        // extension study must reproduce that: transferring a fitted model
        // across testbeds is drastically worse than retraining natively.
        let mut ctx = quick_ctx("target/test-reports");
        let t = crosshw(&mut ctx);
        assert_eq!(t.rows.len(), 2); // A6000→H100 and H100→A6000
        for row in &t.rows {
            let cross: f64 = row[2].trim_end_matches('%').parse().unwrap();
            let native: f64 = row[3].trim_end_matches('%').parse().unwrap();
            assert!(cross.is_finite() && native.is_finite());
            assert!(
                cross > 2.0 * native,
                "cross-hw {cross}% must dwarf native {native}%"
            );
        }
    }
}
