//! Host-side procfs/psutil-style counters: CPU utilization, CPU memory,
//! clock speeds. Derived from the simulator's host-activity level with
//! reading jitter, these populate the CPU rows of the Table-1 feature set.

use crate::config::HwSpec;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct ProcfsReading {
    /// CPU utilization, percent of all cores.
    pub cpu_util_pct: f64,
    /// CPU (host) memory utilization, percent.
    pub cpu_mem_util_pct: f64,
    /// Effective CPU clock, GHz (governor scales with load).
    pub cpu_clock_ghz: f64,
    /// Memory clock, GHz.
    pub cpu_mem_clock_ghz: f64,
}

pub fn measure(
    hw: &HwSpec,
    host_activity: f64,
    batch: usize,
    model_bytes: f64,
    rng: &mut Rng,
) -> ProcfsReading {
    let cpu_util_pct = (100.0 * host_activity * rng.lognormal_mean_cv(1.0, 0.03)).clamp(0.0, 100.0);
    // Host RAM: weights staged at load + serving buffers per request.
    let host_ram_bytes = 256.0 * (1u64 << 30) as f64;
    let used = 0.08 * host_ram_bytes + model_bytes * 0.15 + batch as f64 * 64e6;
    let cpu_mem_util_pct = (100.0 * used / host_ram_bytes).clamp(0.0, 100.0)
        * rng.lognormal_mean_cv(1.0, 0.02);
    // Governor: clocks rise with activity.
    let cpu_clock_ghz = hw.cpu_clock_ghz * (0.85 + 0.25 * host_activity)
        * rng.lognormal_mean_cv(1.0, 0.01);
    ProcfsReading {
        cpu_util_pct,
        cpu_mem_util_pct,
        cpu_clock_ghz,
        cpu_mem_clock_ghz: hw.cpu_mem_clock_ghz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_in_range() {
        let hw = HwSpec::default();
        let mut rng = Rng::new(1);
        for act in [0.0, 0.3, 0.8, 1.0] {
            let r = measure(&hw, act, 32, 14e9, &mut rng);
            assert!((0.0..=100.0).contains(&r.cpu_util_pct));
            assert!((0.0..=100.0).contains(&r.cpu_mem_util_pct));
            assert!(r.cpu_clock_ghz > 0.0);
        }
    }

    #[test]
    fn higher_activity_higher_util_and_clock() {
        let hw = HwSpec::default();
        let mut rng = Rng::new(2);
        let lo = measure(&hw, 0.1, 8, 14e9, &mut rng);
        let hi = measure(&hw, 0.9, 8, 14e9, &mut rng);
        assert!(hi.cpu_util_pct > lo.cpu_util_pct);
        assert!(hi.cpu_clock_ghz > lo.cpu_clock_ghz);
    }

    #[test]
    fn bigger_models_more_host_memory() {
        let hw = HwSpec::default();
        let mut rng = Rng::new(3);
        let small = measure(&hw, 0.5, 8, 14e9, &mut rng).cpu_mem_util_pct;
        let big = measure(&hw, 0.5, 8, 140e9, &mut rng).cpu_mem_util_pct;
        assert!(big > small);
    }
}
