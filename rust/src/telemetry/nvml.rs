//! NVML (nvidia-smi) board-power telemetry simulation.
//!
//! NVML reports *GPU board power only*: host CPU, DRAM and PSU conversion
//! losses are invisible, which is why the literature treats NVML-derived
//! energy as a lower bound (Section 2). On top of the scope gap we model
//! the documented Ampere reading bias and polling-rate noise.

use crate::config::{HwSpec, SimKnobs};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct NvmlReading {
    /// Per-GPU measured board energy, J.
    pub gpu_energy_j: Vec<f64>,
    /// Sum over GPUs, J.
    pub total_j: f64,
    /// Per-GPU mean board power, W.
    pub mean_power_w: Vec<f64>,
}

/// Simulate NVML energy readings for a run.
///
/// * `true_gpu_energy_j` — exact per-GPU board energies.
/// * `per_gpu_cv` — power-signal variability (aliasing term).
/// * `comm_energy_frac` — fraction of GPU energy spent in brief
///   synchronization/transfer states; NVML's slow telemetry misses
///   `nvml_transient_miss` of it (Section 5.1's "misses the fine-grained
///   multi-GPU sync/transfer events").
pub fn measure(
    hw: &HwSpec,
    knobs: &SimKnobs,
    true_gpu_energy_j: &[f64],
    wall_s: f64,
    per_gpu_cv: f64,
    comm_energy_frac: f64,
    rng: &mut Rng,
) -> NvmlReading {
    let samples = ((wall_s / hw.nvml_interval_s).floor() as usize).max(1);
    let rel_std = (knobs.nvml_noise.powi(2) + per_gpu_cv.powi(2) / samples as f64).sqrt();
    // Run-level bias jitter (driver / sampling-phase effects) decorrelates
    // the NVML channel from true GPU energy — shared across the run's GPUs.
    let run_bias = knobs.nvml_bias
        * (1.0 - knobs.nvml_transient_miss * comm_energy_frac.clamp(0.0, 1.0))
        * rng.lognormal_mean_cv(1.0, knobs.nvml_bias_cv);
    let gpu_energy_j: Vec<f64> = true_gpu_energy_j
        .iter()
        .map(|&e| (e * run_bias * (1.0 + rng.normal_ms(0.0, rel_std))).max(0.0))
        .collect();
    let total_j = gpu_energy_j.iter().sum();
    let mean_power_w = gpu_energy_j
        .iter()
        .map(|&e| e / wall_s.max(1e-9))
        .collect();
    NvmlReading {
        gpu_energy_j,
        total_j,
        mean_power_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvml_underestimates_by_bias() {
        let hw = HwSpec::default();
        let knobs = SimKnobs::default();
        let mut rng = Rng::new(3);
        let truth = vec![1000.0, 1000.0];
        let mut totals = Vec::new();
        for _ in 0..300 {
            totals.push(measure(&hw, &knobs, &truth, 30.0, 0.3, 0.0, &mut rng).total_j);
        }
        let mean = crate::util::stats::mean(&totals);
        // Bias 0.94 ⇒ mean ≈ 1880.
        assert!((mean / 2000.0 - knobs.nvml_bias).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn per_gpu_vector_shape() {
        let hw = HwSpec::default();
        let knobs = SimKnobs::default();
        let mut rng = Rng::new(4);
        let r = measure(&hw, &knobs, &[10.0, 20.0, 30.0, 40.0], 5.0, 0.2, 0.0, &mut rng);
        assert_eq!(r.gpu_energy_j.len(), 4);
        assert!(r.gpu_energy_j[3] > r.gpu_energy_j[0]);
        assert!((r.total_j - r.gpu_energy_j.iter().sum::<f64>()).abs() < 1e-9);
    }
}
