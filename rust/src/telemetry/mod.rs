//! Instrument simulations: the three ways the paper observes energy.
//!
//! * `meter` — the external Watts Up Pro wall meter: sees *everything*
//!   (GPUs + CPU + DRAM + PSU losses) but samples slowly (1 Hz) and with
//!   reading noise. This is the ground-truth instrument for training.
//! * `nvml` — NVIDIA NVML board power: GPU-only (systematically misses
//!   host/PSU energy), polls at ~10 Hz, small reading bias. The paper's
//!   Appendices G/H show why it is a poor proxy; our CodeCarbon and
//!   NVML-proxy baselines consume this channel.
//! * `procfs` — Linux procfs-style CPU/memory utilization counters.

pub mod meter;
pub mod nvml;
pub mod procfs;

pub use meter::MeterReading;
pub use nvml::NvmlReading;
pub use procfs::ProcfsReading;
