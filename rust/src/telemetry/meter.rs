//! External wall-power meter simulation (Watts Up Pro class).
//!
//! The meter integrates true system power but at a coarse sampling
//! interval, so fast power transitions alias. We model the measured total
//! as `true × (1 + ε)` with ε combining per-sample reading noise and the
//! aliasing error implied by the power signal's coefficient of variation
//! and the number of samples taken over the run.

use crate::config::{HwSpec, SimKnobs};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct MeterReading {
    /// Measured total system energy over the run, J.
    pub energy_j: f64,
    /// Number of samples the meter took.
    pub samples: usize,
    /// Mean measured wall power, W.
    pub mean_power_w: f64,
}

/// Simulate a wall-meter measurement of a run.
///
/// * `true_energy_j` — exact wall-side energy of the run.
/// * `wall_s` — run duration.
/// * `power_cv` — coefficient of variation of the instantaneous power
///   signal (from `Timeline::power_mean_cv`).
pub fn measure(
    hw: &HwSpec,
    knobs: &SimKnobs,
    true_energy_j: f64,
    wall_s: f64,
    power_cv: f64,
    rng: &mut Rng,
) -> MeterReading {
    let samples = ((wall_s / hw.meter_interval_s).floor() as usize).max(1);
    // Reading noise shrinks with averaging; aliasing error shrinks with
    // sample count relative to signal variability.
    let rel_std = (knobs.meter_noise.powi(2) + power_cv.powi(2) / samples as f64).sqrt();
    let energy_j = true_energy_j * (1.0 + rng.normal_ms(0.0, rel_std));
    MeterReading {
        energy_j: energy_j.max(0.0),
        samples,
        mean_power_w: energy_j / wall_s.max(1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_close_to_truth_for_long_runs() {
        let hw = HwSpec::default();
        let knobs = SimKnobs::default();
        let mut rng = Rng::new(1);
        let mut errs = Vec::new();
        for _ in 0..200 {
            let r = measure(&hw, &knobs, 10_000.0, 60.0, 0.3, &mut rng);
            errs.push((r.energy_j - 10_000.0).abs() / 10_000.0);
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean_err < 0.05, "mean_err={mean_err}");
    }

    #[test]
    fn short_runs_noisier() {
        let hw = HwSpec::default();
        let knobs = SimKnobs::default();
        let spread = |wall: f64| {
            let mut rng = Rng::new(7);
            let xs: Vec<f64> = (0..500)
                .map(|_| measure(&hw, &knobs, 1000.0, wall, 0.4, &mut rng).energy_j)
                .collect();
            crate::util::stats::std_dev(&xs)
        };
        assert!(spread(2.0) > spread(120.0));
    }

    #[test]
    fn sample_count_floor() {
        let hw = HwSpec::default();
        let knobs = SimKnobs::default();
        let mut rng = Rng::new(2);
        let r = measure(&hw, &knobs, 100.0, 0.2, 0.1, &mut rng);
        assert_eq!(r.samples, 1);
        assert!(r.energy_j > 0.0);
    }
}
