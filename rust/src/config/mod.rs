//! Run/experiment configuration.
//!
//! `HwSpec` describes the simulated testbed (the paper's 4× RTX A6000 +
//! AMD EPYC 7543P server with an inline wall meter); `SimKnobs` holds the
//! calibration constants of the energy/time substrate. Both are plain
//! structs with documented defaults rather than an external config file
//! format (the offline image has no serde/toml) — the CLI exposes the
//! fields that experiments sweep.

pub mod hw;

pub use hw::{HwSpec, SimKnobs};

/// Parallelism strategy (Section 3 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Parallelism {
    Tensor,
    Pipeline,
    Data,
}

impl Parallelism {
    pub const ALL: [Parallelism; 3] =
        [Parallelism::Tensor, Parallelism::Pipeline, Parallelism::Data];

    pub fn name(&self) -> &'static str {
        match self {
            Parallelism::Tensor => "tensor",
            Parallelism::Pipeline => "pipeline",
            Parallelism::Data => "data",
        }
    }

    pub fn parse(s: &str) -> Option<Parallelism> {
        match s.to_ascii_lowercase().as_str() {
            "tensor" | "tp" => Some(Parallelism::Tensor),
            "pipeline" | "pp" => Some(Parallelism::Pipeline),
            "data" | "dp" => Some(Parallelism::Data),
            _ => None,
        }
    }
}

/// One profiled inference run: the unit of both measurement and prediction.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model variant display name (key into `models::zoo()`).
    pub model: String,
    pub parallelism: Parallelism,
    /// Number of GPUs (TP degree / pipeline stages / replicas).
    pub gpus: usize,
    /// Request batch size.
    pub batch: usize,
    /// Prompt length (tokens).
    pub seq_in: usize,
    /// Generated length (tokens).
    pub seq_out: usize,
    /// Substrate seed; repeated passes vary this.
    pub seed: u64,
}

impl RunConfig {
    pub fn new(model: &str, parallelism: Parallelism, gpus: usize, batch: usize) -> Self {
        RunConfig {
            model: model.to_string(),
            parallelism,
            gpus,
            batch,
            seq_in: 128,
            seq_out: 512,
            seed: 0,
        }
    }

    pub fn with_seq_out(mut self, seq_out: usize) -> Self {
        self.seq_out = seq_out;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Stable identifier for grouping repeated passes of a configuration.
    pub fn key(&self) -> String {
        format!(
            "{}/{}/g{}/b{}/s{}",
            self.model,
            self.parallelism.name(),
            self.gpus,
            self.batch,
            self.seq_out
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_parse() {
        assert_eq!(Parallelism::parse("tp"), Some(Parallelism::Tensor));
        assert_eq!(Parallelism::parse("Pipeline"), Some(Parallelism::Pipeline));
        assert_eq!(Parallelism::parse("dp"), Some(Parallelism::Data));
        assert_eq!(Parallelism::parse("zz"), None);
    }

    #[test]
    fn run_key_distinguishes_configs() {
        let a = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8);
        let b = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8);
        assert_ne!(a.key(), b.key());
        // Seed does not change the key (passes group together).
        assert_eq!(a.key(), a.clone().with_seed(9).key());
    }
}
