//! Run/experiment configuration.
//!
//! `HwSpec` describes the simulated testbed (the paper's 4× RTX A6000 +
//! AMD EPYC 7543P server with an inline wall meter); `SimKnobs` holds the
//! calibration constants of the energy/time substrate. Both are plain
//! structs with documented defaults rather than an external config file
//! format (the offline image has no serde/toml) — the CLI exposes the
//! fields that experiments sweep.

pub mod hw;

pub use hw::{HwSpec, SimKnobs, TestbedSpec};

/// One of the three base parallelization strategies (Section 3 of the
/// paper). `Parallelism` composes these into pure or hybrid deployments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Strategy {
    Tensor,
    Pipeline,
    Data,
}

impl Strategy {
    pub const ALL: [Strategy; 3] = [Strategy::Tensor, Strategy::Pipeline, Strategy::Data];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Tensor => "tensor",
            Strategy::Pipeline => "pipeline",
            Strategy::Data => "data",
        }
    }

    /// Two-letter shorthand used in hybrid labels ("tp2xpp").
    pub fn short(&self) -> &'static str {
        match self {
            Strategy::Tensor => "tp",
            Strategy::Pipeline => "pp",
            Strategy::Data => "dp",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "tensor" | "tp" => Some(Strategy::Tensor),
            "pipeline" | "pp" => Some(Strategy::Pipeline),
            "data" | "dp" => Some(Strategy::Data),
            _ => None,
        }
    }
}

/// Parallelism strategy of a run: one of the paper's three pure strategies,
/// or a pairwise hybrid over a 2-D rank mesh.
///
/// A hybrid splits the `gpus` ranks into contiguous groups of
/// `inner_degree`; the `inner` strategy runs within each group and the
/// `outer` strategy runs across the groups (e.g. `tp2xpp` on 4 GPUs is two
/// pipeline stages of two tensor-parallel ranks each). Canonical nesting
/// order is Tensor < Pipeline < Data — TP innermost (it needs the highest
/// link bandwidth), DP outermost — matching production deployments; the
/// `hybrid` constructor enforces it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Parallelism {
    Tensor,
    Pipeline,
    Data,
    Hybrid {
        inner: Strategy,
        outer: Strategy,
        /// Ranks per inner group (the outer degree is `gpus / inner_degree`).
        inner_degree: usize,
    },
    /// Expert parallelism (MoE): attention replicated on every rank, MLP
    /// experts sharded across all `degree` ranks, with per-layer all-to-all
    /// dispatch/combine collectives routing each token's top-k expert
    /// activations. Labels serialize as `"ep<degree>"` (e.g. `"ep4"`).
    Expert {
        /// Expert-parallel degree (the whole mesh: `degree == gpus`).
        degree: usize,
        /// Experts each token routes to (payload multiplier on dispatch).
        top_k: usize,
        /// Per-expert capacity factor, percent (125 = 1.25× even share);
        /// headroom buffered for routing imbalance.
        capacity_pct: usize,
    },
}

impl Parallelism {
    /// The three pure strategies (the paper's evaluation set).
    pub const ALL: [Parallelism; 3] =
        [Parallelism::Tensor, Parallelism::Pipeline, Parallelism::Data];

    /// The three canonical pairwise hybrid combinations as (inner, outer).
    pub const HYBRID_COMBOS: [(Strategy, Strategy); 3] = [
        (Strategy::Tensor, Strategy::Pipeline),
        (Strategy::Tensor, Strategy::Data),
        (Strategy::Pipeline, Strategy::Data),
    ];

    /// Construct a validated hybrid: the pair must be in canonical order
    /// (Tensor < Pipeline < Data), distinct, and `inner_degree >= 2`
    /// (degree 1 degenerates to the pure outer strategy).
    pub fn hybrid(inner: Strategy, outer: Strategy, inner_degree: usize) -> Option<Parallelism> {
        if inner >= outer || inner_degree < 2 {
            return None;
        }
        Some(Parallelism::Hybrid {
            inner,
            outer,
            inner_degree,
        })
    }

    /// Construct an expert-parallel deployment with the canonical MoE
    /// routing defaults (top-2 routing, 1.25× capacity factor) — the shape
    /// `parse("ep<degree>")` yields.
    pub fn expert(degree: usize) -> Parallelism {
        Parallelism::Expert {
            degree,
            top_k: 2,
            capacity_pct: 125,
        }
    }

    pub fn is_hybrid(&self) -> bool {
        matches!(self, Parallelism::Hybrid { .. })
    }

    /// Tensor-parallel degree within the composition (1 when absent).
    pub fn tensor_degree(&self, gpus: usize) -> usize {
        match *self {
            Parallelism::Tensor => gpus,
            Parallelism::Hybrid {
                inner: Strategy::Tensor,
                inner_degree,
                ..
            } => inner_degree,
            _ => 1,
        }
    }

    /// Pipeline-stage count within the composition (1 when absent).
    pub fn pipeline_degree(&self, gpus: usize) -> usize {
        match *self {
            Parallelism::Pipeline => gpus,
            Parallelism::Hybrid {
                inner: Strategy::Pipeline,
                inner_degree,
                ..
            } => inner_degree,
            Parallelism::Hybrid {
                outer: Strategy::Pipeline,
                inner_degree,
                ..
            } => gpus / inner_degree.max(1),
            _ => 1,
        }
    }

    /// Data-parallel replica count within the composition (1 when absent).
    /// Data can only sit on the outer axis under the canonical ordering.
    pub fn data_degree(&self, gpus: usize) -> usize {
        match *self {
            Parallelism::Data => gpus,
            Parallelism::Hybrid {
                outer: Strategy::Data,
                inner_degree,
                ..
            } => gpus / inner_degree.max(1),
            _ => 1,
        }
    }

    /// Expert-parallel degree within the composition (1 when absent).
    /// Expert parallelism takes the whole mesh (no hybrid nesting yet).
    pub fn expert_degree(&self, gpus: usize) -> usize {
        match *self {
            Parallelism::Expert { .. } => gpus,
            _ => 1,
        }
    }

    /// Display/grouping name. Hybrid names omit the inner degree (use
    /// `label` for the unambiguous serialized form).
    pub fn name(&self) -> &'static str {
        match self {
            Parallelism::Tensor => "tensor",
            Parallelism::Pipeline => "pipeline",
            Parallelism::Data => "data",
            Parallelism::Hybrid { inner, outer, .. } => match (inner, outer) {
                (Strategy::Tensor, Strategy::Pipeline) => "tensor+pipeline",
                (Strategy::Tensor, Strategy::Data) => "tensor+data",
                (Strategy::Pipeline, Strategy::Data) => "pipeline+data",
                _ => "hybrid",
            },
            Parallelism::Expert { .. } => "expert",
        }
    }

    /// Unambiguous label, stable under `parse` roundtrips: pure strategies
    /// keep their names; hybrids serialize as `"<inner><degree>x<outer>"`
    /// (e.g. `"tp2xpp"`); expert parallelism as `"ep<degree>"` (e.g.
    /// `"ep4"`).
    pub fn label(&self) -> String {
        match *self {
            Parallelism::Hybrid {
                inner,
                outer,
                inner_degree,
            } => format!("{}{}x{}", inner.short(), inner_degree, outer.short()),
            Parallelism::Expert { degree, .. } => format!("ep{degree}"),
            _ => self.name().to_string(),
        }
    }

    pub fn parse(s: &str) -> Option<Parallelism> {
        let t = s.to_ascii_lowercase();
        match t.as_str() {
            "tensor" | "tp" => return Some(Parallelism::Tensor),
            "pipeline" | "pp" => return Some(Parallelism::Pipeline),
            "data" | "dp" => return Some(Parallelism::Data),
            _ => {}
        }
        // Expert labels: "ep<degree>", e.g. "ep4" — checked before the
        // hybrid path ("ep…" never contains an 'x' strategy pair).
        if let Some(d) = t.strip_prefix("ep") {
            let degree: usize = d.parse().ok()?;
            if degree < 2 {
                return None;
            }
            return Some(Parallelism::expert(degree));
        }
        // Hybrid labels: "<inner><degree>x<outer>", e.g. "tp2xpp".
        let (lhs, rhs) = t.split_once('x')?;
        let outer = Strategy::parse(rhs)?;
        let digits_at = lhs.find(|c: char| c.is_ascii_digit())?;
        let inner = Strategy::parse(&lhs[..digits_at])?;
        let inner_degree: usize = lhs[digits_at..].parse().ok()?;
        Parallelism::hybrid(inner, outer, inner_degree)
    }
}

/// One profiled inference run: the unit of both measurement and prediction.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model variant display name (key into `models::zoo()`).
    pub model: String,
    pub parallelism: Parallelism,
    /// Number of GPUs (TP degree / pipeline stages / replicas).
    pub gpus: usize,
    /// Request batch size.
    pub batch: usize,
    /// Prompt length (tokens).
    pub seq_in: usize,
    /// Generated length (tokens).
    pub seq_out: usize,
    /// Substrate seed; repeated passes vary this.
    pub seed: u64,
}

impl RunConfig {
    /// Builder over the same defaults as [`RunConfig::new`]
    /// (`seq_in 128`, `seq_out 512`, `seed 0`).
    pub fn builder(model: &str) -> RunConfigBuilder {
        RunConfigBuilder {
            cfg: RunConfig::new(model, Parallelism::Tensor, HwSpec::default().num_gpus, 1),
        }
    }

    pub fn new(model: &str, parallelism: Parallelism, gpus: usize, batch: usize) -> Self {
        RunConfig {
            model: model.to_string(),
            parallelism,
            gpus,
            batch,
            seq_in: 128,
            seq_out: 512,
            seed: 0,
        }
    }

    pub fn with_seq_out(mut self, seq_out: usize) -> Self {
        self.seq_out = seq_out;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Stable identifier for grouping repeated passes of a configuration.
    /// Uses `Parallelism::label` so hybrid inner degrees stay distinct.
    pub fn key(&self) -> String {
        format!(
            "{}/{}/g{}/b{}/s{}",
            self.model,
            self.parallelism.label(),
            self.gpus,
            self.batch,
            self.seq_out
        )
    }
}

/// Chainable construction of a [`RunConfig`] (`RunConfig::builder`):
/// every field has the documented default, so callers state only what
/// their run varies.
#[derive(Debug, Clone)]
pub struct RunConfigBuilder {
    cfg: RunConfig,
}

impl RunConfigBuilder {
    pub fn parallelism(mut self, parallelism: Parallelism) -> Self {
        self.cfg.parallelism = parallelism;
        self
    }

    pub fn gpus(mut self, gpus: usize) -> Self {
        self.cfg.gpus = gpus;
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.cfg.batch = batch;
        self
    }

    pub fn seq_in(mut self, seq_in: usize) -> Self {
        self.cfg.seq_in = seq_in;
        self
    }

    pub fn seq_out(mut self, seq_out: usize) -> Self {
        self.cfg.seq_out = seq_out;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn build(self) -> RunConfig {
        self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_matches_literal_construction() {
        let built = RunConfig::builder("Vicuna-7B")
            .parallelism(Parallelism::Pipeline)
            .gpus(2)
            .batch(8)
            .seq_out(64)
            .seed(9)
            .build();
        let literal = RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 2, 8)
            .with_seq_out(64)
            .with_seed(9);
        assert_eq!(built.key(), literal.key());
        assert_eq!(built.seq_in, literal.seq_in);
        assert_eq!(built.seed, literal.seed);
        // Defaults mirror `new`.
        let d = RunConfig::builder("Vicuna-7B").build();
        assert_eq!(d.gpus, HwSpec::default().num_gpus);
        assert_eq!((d.seq_in, d.seq_out, d.seed), (128, 512, 0));
    }

    #[test]
    fn parallelism_parse() {
        assert_eq!(Parallelism::parse("tp"), Some(Parallelism::Tensor));
        assert_eq!(Parallelism::parse("Pipeline"), Some(Parallelism::Pipeline));
        assert_eq!(Parallelism::parse("dp"), Some(Parallelism::Data));
        assert_eq!(Parallelism::parse("zz"), None);
    }

    #[test]
    fn hybrid_constructor_enforces_canonical_order() {
        assert!(Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).is_some());
        assert!(Parallelism::hybrid(Strategy::Tensor, Strategy::Data, 2).is_some());
        assert!(Parallelism::hybrid(Strategy::Pipeline, Strategy::Data, 2).is_some());
        // Reversed order, same-strategy pairs, and degenerate degrees are rejected.
        assert!(Parallelism::hybrid(Strategy::Pipeline, Strategy::Tensor, 2).is_none());
        assert!(Parallelism::hybrid(Strategy::Data, Strategy::Tensor, 2).is_none());
        assert!(Parallelism::hybrid(Strategy::Tensor, Strategy::Tensor, 2).is_none());
        assert!(Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 1).is_none());
    }

    #[test]
    fn hybrid_label_parse_roundtrip() {
        for (inner, outer) in Parallelism::HYBRID_COMBOS {
            for degree in [2usize, 4] {
                let p = Parallelism::hybrid(inner, outer, degree).unwrap();
                assert_eq!(Parallelism::parse(&p.label()), Some(p), "{}", p.label());
            }
        }
        assert_eq!(
            Parallelism::parse("tp2xpp"),
            Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2)
        );
        assert_eq!(Parallelism::parse("tpxpp"), None); // degree is mandatory
        assert_eq!(Parallelism::parse("dp2xtp"), None); // non-canonical order
    }

    #[test]
    fn expert_label_parse_roundtrip() {
        for degree in [2usize, 4, 8] {
            let p = Parallelism::expert(degree);
            assert_eq!(p.label(), format!("ep{degree}"));
            assert_eq!(Parallelism::parse(&p.label()), Some(p), "{}", p.label());
        }
        // Defaults are the canonical MoE routing shape.
        assert_eq!(
            Parallelism::parse("ep4"),
            Some(Parallelism::Expert {
                degree: 4,
                top_k: 2,
                capacity_pct: 125
            })
        );
        assert_eq!(Parallelism::parse("ep"), None); // degree is mandatory
        assert_eq!(Parallelism::parse("ep1"), None); // degenerate degree
        assert_eq!(Parallelism::parse("ep2x"), None); // trailing garbage
        assert_eq!(Parallelism::expert(4).name(), "expert");
    }

    #[test]
    fn expert_degree_takes_the_whole_mesh() {
        let p = Parallelism::expert(4);
        assert_eq!(p.expert_degree(4), 4);
        assert_eq!(p.tensor_degree(4), 1);
        assert_eq!(p.pipeline_degree(4), 1);
        assert_eq!(p.data_degree(4), 1);
        assert!(!p.is_hybrid());
        // Non-expert strategies have expert degree 1.
        assert_eq!(Parallelism::Tensor.expert_degree(4), 1);
    }

    #[test]
    fn hybrid_degrees_decompose_the_mesh() {
        let p = Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap();
        assert_eq!(p.tensor_degree(4), 2);
        assert_eq!(p.pipeline_degree(4), 2);
        assert_eq!(p.data_degree(4), 1);
        let p = Parallelism::hybrid(Strategy::Pipeline, Strategy::Data, 2).unwrap();
        assert_eq!(p.tensor_degree(8), 1);
        assert_eq!(p.pipeline_degree(8), 2);
        assert_eq!(p.data_degree(8), 4);
        // Pure strategies take the whole mesh on their own axis.
        assert_eq!(Parallelism::Tensor.tensor_degree(4), 4);
        assert_eq!(Parallelism::Pipeline.pipeline_degree(4), 4);
        assert_eq!(Parallelism::Data.data_degree(4), 4);
        assert_eq!(Parallelism::Data.tensor_degree(4), 1);
    }

    #[test]
    fn hybrid_keys_distinguish_inner_degree() {
        let a = RunConfig::new(
            "Vicuna-7B",
            Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap(),
            8,
            8,
        );
        let b = RunConfig::new(
            "Vicuna-7B",
            Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 4).unwrap(),
            8,
            8,
        );
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn run_key_distinguishes_configs() {
        let a = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8);
        let b = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8);
        assert_ne!(a.key(), b.key());
        // Seed does not change the key (passes group together).
        assert_eq!(a.key(), a.clone().with_seed(9).key());
    }
}
