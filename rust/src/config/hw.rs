//! Simulated testbed description + substrate calibration knobs.
//!
//! Defaults describe the paper's server: AMD EPYC Milan 7543P (32 cores),
//! 4× NVIDIA RTX A6000 (48 GB GDDR6, PCIe 4.0), wall power measured by a
//! Watts Up Pro. Power/time constants come from public spec sheets and the
//! usual measured-behavior literature (NCCL busy-wait draw, PCIe effective
//! bandwidth, PSU conversion losses); DESIGN.md §7 documents the model.

use crate::cluster::{GpuSpec, LinkSpec, LinkTier, Topology};

/// Static hardware description.
#[derive(Debug, Clone)]
pub struct HwSpec {
    /// GPUs installed.
    pub num_gpus: usize,
    /// Per-GPU VRAM bytes (A6000: 48 GB).
    pub vram_bytes: f64,
    /// Peak dense FP16 throughput per GPU, FLOP/s (A6000 ≈ 77.4 TFLOPS
    /// tensor, ~45% achievable in decode kernels).
    pub gpu_peak_flops: f64,
    /// Achievable fraction of peak in LLM kernels.
    pub gpu_mfu: f64,
    /// HBM/GDDR6 bandwidth per GPU, bytes/s (A6000: 768 GB/s).
    pub gpu_mem_bw: f64,
    /// Achievable fraction of memory bandwidth.
    pub gpu_mem_eff: f64,
    /// GPU idle board power, W.
    pub gpu_idle_w: f64,
    /// GPU board power limit, W (A6000: 300).
    pub gpu_tdp_w: f64,
    /// Board power while a collective busy-waits (NCCL spins SMs).
    pub gpu_wait_w: f64,
    /// Board power while driving the interconnect.
    pub gpu_comm_w: f64,
    /// Inter-GPU link bandwidth, bytes/s (PCIe 4.0 x16 ≈ 25 GB/s effective
    /// ≈ 17 GB/s with NCCL protocol overhead).
    pub link_bw: f64,
    /// Per-ring-step latency, s (kernel launch + DMA setup).
    pub link_step_latency: f64,
    /// Fixed per-collective-call latency, s.
    pub coll_base_latency: f64,
    /// CPU package idle power, W (EPYC 7543P idles high on servers).
    pub cpu_idle_w: f64,
    /// CPU package max power, W (TDP 225).
    pub cpu_max_w: f64,
    /// DRAM + fans + board baseline, W.
    pub dram_base_w: f64,
    /// DRAM active adder, W.
    pub dram_active_w: f64,
    /// PSU fixed overhead, W.
    pub psu_base_w: f64,
    /// PSU proportional conversion loss (fraction of subtotal).
    pub psu_loss_frac: f64,
    /// GPU base/boost clock, GHz (telemetry feature).
    pub gpu_clock_ghz: f64,
    /// GPU memory clock, GHz.
    pub gpu_mem_clock_ghz: f64,
    /// CPU clock, GHz.
    pub cpu_clock_ghz: f64,
    /// CPU memory clock, GHz.
    pub cpu_mem_clock_ghz: f64,
    /// Wall-meter sampling interval, s (Watts Up Pro: 1 Hz).
    pub meter_interval_s: f64,
    /// NVML polling interval, s (the paper's profilers poll ~10 Hz).
    pub nvml_interval_s: f64,
    /// Cluster topology: node boundaries, link tiers, heterogeneous fleet.
    /// `None` is the legacy flat view — a single node whose only link tier
    /// is derived from the `link_*`/`coll_*` fields above — and is
    /// bit-identical to the pre-topology code path.
    pub topology: Option<Topology>,
}

impl Default for HwSpec {
    fn default() -> Self {
        HwSpec {
            num_gpus: 4,
            vram_bytes: 48.0 * (1u64 << 30) as f64,
            gpu_peak_flops: 77.4e12,
            gpu_mfu: 0.45,
            gpu_mem_bw: 768.0e9,
            gpu_mem_eff: 0.75,
            gpu_idle_w: 22.0,
            gpu_tdp_w: 300.0,
            gpu_wait_w: 95.0,
            gpu_comm_w: 120.0,
            link_bw: 12.0e9,
            link_step_latency: 5.0e-6,
            coll_base_latency: 14.0e-6,
            cpu_idle_w: 85.0,
            cpu_max_w: 225.0,
            dram_base_w: 28.0,
            dram_active_w: 22.0,
            psu_base_w: 30.0,
            psu_loss_frac: 0.10,
            gpu_clock_ghz: 1.80,
            gpu_mem_clock_ghz: 2.00,
            cpu_clock_ghz: 2.80,
            cpu_mem_clock_ghz: 1.60,
            meter_interval_s: 1.0,
            nvml_interval_s: 0.1,
            topology: None,
        }
    }
}

impl HwSpec {
    /// The paper's testbed: 4x RTX A6000 over PCIe 4.0 + EPYC 7543P.
    pub fn a6000_testbed() -> Self {
        Self::default()
    }

    /// Chainable: set the installed GPU count.
    pub fn with_gpus(mut self, num_gpus: usize) -> Self {
        self.num_gpus = num_gpus;
        self
    }

    /// Chainable: install a cluster topology (node boundaries, link
    /// tiers, optional heterogeneous fleet) over this testbed's per-GPU
    /// constants.
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = Some(topology);
        self
    }

    /// The legacy flat link as a `LinkSpec` (wire energy stays folded into
    /// `gpu_comm_w`, so `energy_per_byte` is zero — this is what keeps the
    /// tiered cost formulas bit-identical to the flat ones).
    pub fn flat_link(&self) -> LinkSpec {
        LinkSpec {
            bw: self.link_bw,
            step_latency: self.link_step_latency,
            base_latency: self.coll_base_latency,
            energy_per_byte: 0.0,
        }
    }

    /// Effective topology: the configured cluster topology, or the flat
    /// single-node single-tier view derived from the legacy link fields.
    pub fn topo(&self) -> Topology {
        self.topology.clone().unwrap_or_else(|| Topology::single_node(self.flat_link()))
    }

    /// A multi-node fleet: `nodes × gpus_per_node` ranks with the given
    /// intra/inter tiers and an optional heterogeneous per-rank fleet
    /// (cycled across ranks when shorter than the mesh). Base per-GPU
    /// constants stay at the A6000 testbed values; per-rank `GpuSpec`s
    /// override compute throughput and idle/peak power.
    pub fn cluster_testbed(
        nodes: usize,
        gpus_per_node: usize,
        intra: LinkTier,
        inter: LinkTier,
        fleet: &[GpuSpec],
    ) -> Self {
        let num = nodes.max(1) * gpus_per_node.max(1);
        let ranks: Vec<GpuSpec> = if fleet.is_empty() {
            Vec::new()
        } else {
            (0..num).map(|r| fleet[r % fleet.len()]).collect()
        };
        HwSpec {
            num_gpus: num,
            topology: Some(Topology::multi_node(gpus_per_node.max(1), intra, inter).with_fleet(ranks)),
            ..HwSpec::default()
        }
    }

    /// An alternative testbed for the cross-hardware extension study
    /// (the paper's stated limitation -- "PIE-P is hardware-dependent"):
    /// 4x H100-PCIe-class GPUs (faster HBM and compute, higher idle/TDP,
    /// wider links) on a newer host. Used by `piep crosshw`.
    pub fn h100_testbed() -> Self {
        HwSpec {
            num_gpus: 4,
            vram_bytes: 80.0 * (1u64 << 30) as f64,
            gpu_peak_flops: 756.0e12,
            gpu_mfu: 0.40,
            gpu_mem_bw: 2000.0e9,
            gpu_mem_eff: 0.70,
            gpu_idle_w: 60.0,
            gpu_tdp_w: 350.0,
            gpu_wait_w: 130.0,
            gpu_comm_w: 160.0,
            link_bw: 40.0e9,
            link_step_latency: 3.0e-6,
            coll_base_latency: 10.0e-6,
            cpu_idle_w: 95.0,
            cpu_max_w: 280.0,
            dram_base_w: 35.0,
            dram_active_w: 28.0,
            psu_base_w: 35.0,
            psu_loss_frac: 0.09,
            gpu_clock_ghz: 1.98,
            gpu_mem_clock_ghz: 2.62,
            cpu_clock_ghz: 3.1,
            cpu_mem_clock_ghz: 2.4,
            meter_interval_s: 1.0,
            nvml_interval_s: 0.1,
            topology: None,
        }
    }
}

/// Declarative testbed description — the one vocabulary every CLI
/// subcommand (`cli::topo`) and builder-API caller uses to say *where* a
/// simulation runs. `hw()` resolves it to a concrete [`HwSpec`]: the flat
/// form is bit-identical to the legacy pre-topology path, the cluster form
/// is exactly [`HwSpec::cluster_testbed`].
#[derive(Debug, Clone, PartialEq)]
pub enum TestbedSpec {
    /// The paper's flat single-node box with `gpus` installed GPUs.
    Flat { gpus: usize },
    /// A multi-node fleet: `nodes × gpus_per_node` ranks, intra/inter
    /// link tiers, optional heterogeneous per-rank fleet (cycled).
    Cluster {
        nodes: usize,
        gpus_per_node: usize,
        intra: LinkTier,
        inter: LinkTier,
        fleet: Vec<GpuSpec>,
    },
}

impl Default for TestbedSpec {
    fn default() -> Self {
        TestbedSpec::Flat {
            gpus: HwSpec::default().num_gpus,
        }
    }
}

impl TestbedSpec {
    /// Total ranks in the mesh.
    pub fn gpus(&self) -> usize {
        match self {
            TestbedSpec::Flat { gpus } => (*gpus).max(1),
            TestbedSpec::Cluster {
                nodes, gpus_per_node, ..
            } => nodes.max(1) * gpus_per_node.max(1),
        }
    }

    /// Resolve to a concrete hardware description.
    pub fn hw(&self) -> HwSpec {
        match self {
            TestbedSpec::Flat { gpus } => HwSpec {
                num_gpus: (*gpus).max(1),
                ..HwSpec::default()
            },
            TestbedSpec::Cluster {
                nodes,
                gpus_per_node,
                intra,
                inter,
                fleet,
            } => HwSpec::cluster_testbed(*nodes, *gpus_per_node, *intra, *inter, fleet),
        }
    }

    /// Stable human-readable key (mesh-cache keys, table rows).
    pub fn label(&self) -> String {
        match self {
            TestbedSpec::Flat { gpus } => format!("flat{}", gpus.max(1)),
            TestbedSpec::Cluster {
                nodes,
                gpus_per_node,
                intra,
                inter,
                fleet,
            } => {
                let mut s = format!("{}x{}:{}/{}", nodes.max(1), gpus_per_node.max(1), intra.name(), inter.name());
                if !fleet.is_empty() {
                    s.push(':');
                    s.push_str(&fleet.iter().map(|g| g.name).collect::<Vec<_>>().join(","));
                }
                s
            }
        }
    }
}

/// Stochastic-substrate calibration knobs (the "non-determinism" the paper
/// measures: rank skew, stragglers, thermal drift, host interference).
#[derive(Debug, Clone)]
pub struct SimKnobs {
    /// Coefficient of variation of per-module compute time across ranks
    /// and steps (caching effects, memory access, hardware scheduling).
    pub compute_cv: f64,
    /// Persistent per-rank speed bias cv (silicon lottery / slot cooling):
    /// the same GPU lags all run long — the main source of the
    /// synchronization waiting the paper samples.
    pub rank_bias_cv: f64,
    /// Mean of the exponential per-rank launch desynchronization at each
    /// collective (host kernel-launch skew, memory-allocator stalls, NCCL
    /// channel setup). On PCIe testbeds this — not the wire time — is the
    /// dominant AllReduce cost, and it is what synchronization sampling
    /// measures. Seconds.
    pub sync_jitter_s: f64,
    /// Run-to-run lognormal cv of the launch-desync scale: communication
    /// variance persists within a run but differs across runs (driver
    /// state, NCCL channel placement) — the paper's "higher variance ...
    /// due to the inherent non-determinism in communication".
    pub sync_jitter_cv: f64,
    /// Per-run lognormal cv of the MoE top-k routing imbalance: expert
    /// parallelism draws one persistent hot-expert load multiplier per rank
    /// (clamped ≥ 1 — hot experts only slow down), which stretches expert
    /// MLP compute and widens the straggler rendezvous at the all-to-all
    /// dispatch/combine barriers. Only drawn by plans that carry all-to-all
    /// collectives (`Plan::draws_route_bias`); every other strategy's seed
    /// stream is untouched.
    pub route_imbalance_cv: f64,
    /// Probability that a (rank, step) compute phase is a straggler.
    pub straggler_p: f64,
    /// Straggler slowdown multiplier range (uniform).
    pub straggler_scale: (f64, f64),
    /// Run-level thermal/power drift: multiplier on all GPU power draw,
    /// lognormal cv.
    pub thermal_cv: f64,
    /// Run-level cv of the busy-wait power draw: the NCCL spin/yield mix
    /// (and hence the power burned while waiting) varies run to run, which
    /// decouples communication energy from communication time — the reason
    /// the paper's AllReduce module error exceeds the compute modules'
    /// (Table 5).
    pub wait_power_cv: f64,
    /// Probability per run of background host interference.
    pub interference_p: f64,
    /// Host interference adds this fraction of extra CPU activity.
    pub interference_frac: (f64, f64),
    /// Relative std of wall-meter reading error per sample.
    pub meter_noise: f64,
    /// Relative std of NVML power reading error per sample.
    pub nvml_noise: f64,
    /// NVML reading bias (board power telemetry reads low on Ampere).
    pub nvml_bias: f64,
    /// Run-to-run jitter of the NVML bias (driver/sampling-phase effects) —
    /// decorrelates the NVML feature from true GPU energy.
    pub nvml_bias_cv: f64,
    /// Fraction of energy in brief synchronization/transfer states that
    /// NVML's slow power telemetry fails to register (the "misses the
    /// fine-grained multi-GPU sync/transfer events" effect, Section 5.1).
    pub nvml_transient_miss: f64,
    /// Probability that background host work (other tenants, system
    /// daemons) draws extra wall power during a run. Invisible to the
    /// Table-1 features; the wall meter sees it. This is the substrate's
    /// irreducible-error channel.
    pub background_p: f64,
    /// Mean of the exponential background power draw, W.
    pub background_mean_w: f64,
    /// Decode steps simulated explicitly per run (remaining steps are
    /// extrapolated with CLT-scaled variance; the paper's profiler samples
    /// the same way).
    pub sim_decode_steps: usize,
    /// Worker threads for the event engine's per-rank phase
    /// materialization (`simulator::engine`): 1 ⇒ serial (the default —
    /// campaigns already parallelize across runs), 0 ⇒ available cores.
    /// Serial and parallel execution are bit-identical.
    pub engine_threads: usize,
    /// Run the interpreted reference path (`Vec<Op>` plan + op-enum
    /// engine walk) instead of the compiled structure-of-arrays
    /// `plan::ExecPlan` (DESIGN.md §12). The two are bit-identical
    /// (property-tested); the reference mode exists to pin that contract
    /// and for debugging the compiled layer.
    pub reference_engine: bool,
    /// Resolve all shape candidates of one mesh structure in a single
    /// engine walk (`simulator::engine::execute_batch`, DESIGN.md §14)
    /// wherever a caller holds several at once (sweep campaigns, tune
    /// grids, fleet replica steps). Pure wall-time optimization — every
    /// candidate's draws stay bit-identical to the serial path
    /// (property-tested); off ⇒ each candidate runs its own walk (the
    /// pinned reference, also the `--no-batch` escape hatch).
    pub batch_execution: bool,
    /// Serve shape rebinds from the structure's compiled shape-affine
    /// scalar program (`plan::affine`, DESIGN.md §17) when one was
    /// captured and verified at structure-compile time. Pure wall-time
    /// optimization — accepted programs are bit-identical to the
    /// `ShapeBinding` replay (probe-verified at compile, property-tested),
    /// and rejected structures fall back to the replay regardless of this
    /// knob; off ⇒ every rebind replays the lowering (the pinned
    /// reference, also the `--no-affine` escape hatch).
    pub affine_rebind: bool,
    /// Capture an execution trace alongside every materialized timeline:
    /// the engine records, per phase, the index of the plan op that
    /// produced it (`trace::Trace`), which the observability layer
    /// (`piep critpath`, the Perfetto exporter) joins back against the
    /// `ExecPlan` for op-level span events. Off by default — when off the
    /// engine allocates and records nothing, and every table is
    /// byte-identical to the untraced path (the trace is derived data;
    /// no simulation draw depends on it).
    pub trace: bool,
}

impl Default for SimKnobs {
    fn default() -> Self {
        SimKnobs {
            compute_cv: 0.10,
            rank_bias_cv: 0.08,
            sync_jitter_s: 40.0e-6,
            sync_jitter_cv: 0.35,
            route_imbalance_cv: 0.30,
            straggler_p: 0.006,
            straggler_scale: (1.4, 2.8),
            thermal_cv: 0.14,
            wait_power_cv: 0.25,
            interference_p: 0.60,
            interference_frac: (0.10, 0.90),
            meter_noise: 0.02,
            nvml_noise: 0.03,
            nvml_bias: 0.94,
            nvml_bias_cv: 0.09,
            nvml_transient_miss: 0.8,
            background_p: 0.70,
            background_mean_w: 155.0,
            sim_decode_steps: 24,
            engine_threads: 1,
            reference_engine: false,
            batch_execution: true,
            affine_rebind: true,
            trace: false,
        }
    }
}

impl SimKnobs {
    /// Set the explicitly simulated decode steps (the cost knob every
    /// driver tunes; the rest of the stochastic substrate rarely moves).
    pub fn with_decode_steps(mut self, steps: usize) -> SimKnobs {
        self.sim_decode_steps = steps;
        self
    }

    /// Set the per-rank event-engine worker threads (1 = serial).
    pub fn with_engine_threads(mut self, threads: usize) -> SimKnobs {
        self.engine_threads = threads;
        self
    }

    /// Enable/disable batched multi-candidate execution (`--no-batch`).
    pub fn with_batch_execution(mut self, on: bool) -> SimKnobs {
        self.batch_execution = on;
        self
    }

    /// Enable/disable affine rebind evaluation (`--no-affine`).
    pub fn with_affine_rebind(mut self, on: bool) -> SimKnobs {
        self.affine_rebind = on;
        self
    }

    /// Enable/disable execution-trace capture (`trace::Trace` per run).
    pub fn with_trace(mut self, on: bool) -> SimKnobs {
        self.trace = on;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_physical() {
        let hw = HwSpec::default();
        assert!(hw.gpu_idle_w < hw.gpu_wait_w);
        assert!(hw.gpu_wait_w < hw.gpu_tdp_w);
        assert!(hw.gpu_comm_w < hw.gpu_tdp_w);
        assert!(hw.cpu_idle_w < hw.cpu_max_w);
        assert!(hw.link_bw < hw.gpu_mem_bw);
        assert!(hw.psu_loss_frac > 0.0 && hw.psu_loss_frac < 0.2);
    }

    #[test]
    fn flat_topology_mirrors_legacy_link_fields() {
        let hw = HwSpec::default();
        let topo = hw.topo();
        assert_eq!(topo.intra, hw.flat_link());
        assert_eq!(topo.inter, hw.flat_link());
        assert!(!topo.spans(0, hw.num_gpus));
        assert!(topo.homogeneous());
        assert_eq!(hw.flat_link().energy_per_byte, 0.0);
    }

    #[test]
    fn cluster_testbed_builds_the_mesh() {
        let fleet = [GpuSpec::a6000(), GpuSpec::h100()];
        let hw = HwSpec::cluster_testbed(2, 2, LinkTier::NvLink, LinkTier::InfiniBand, &fleet);
        assert_eq!(hw.num_gpus, 4);
        let topo = hw.topo();
        assert!(topo.spans(0, 4));
        assert_eq!(topo.nodes_spanned(0, 4), 2);
        // Fleet cycles across ranks.
        assert_eq!(topo.gpu(0).unwrap().name, "a6000");
        assert_eq!(topo.gpu(1).unwrap().name, "h100");
        assert_eq!(topo.gpu(3).unwrap().name, "h100");
        assert!(!topo.homogeneous());
    }

    #[test]
    fn testbed_spec_resolves_and_labels() {
        let flat = TestbedSpec::default();
        assert_eq!(flat.gpus(), 4);
        assert_eq!(flat.label(), "flat4");
        assert!(flat.hw().topology.is_none());
        let cluster = TestbedSpec::Cluster {
            nodes: 2,
            gpus_per_node: 2,
            intra: LinkTier::NvLink,
            inter: LinkTier::InfiniBand,
            fleet: vec![GpuSpec::a6000(), GpuSpec::h100()],
        };
        assert_eq!(cluster.gpus(), 4);
        assert_eq!(cluster.label(), "2x2:nvlink/infiniband:a6000,h100");
        let hw = cluster.hw();
        assert_eq!(hw.num_gpus, 4);
        assert!(hw.topo().spans(0, 4));
        // Chainable testbed builders.
        let hw2 = HwSpec::a6000_testbed()
            .with_gpus(8)
            .with_topology(Topology::multi_node(4, LinkTier::NvLink, LinkTier::InfiniBand));
        assert_eq!(hw2.num_gpus, 8);
        assert!(hw2.topology.is_some());
    }

    #[test]
    fn knob_defaults_sane() {
        let k = SimKnobs::default();
        assert!(k.compute_cv > 0.0 && k.compute_cv < 0.5);
        assert!(k.route_imbalance_cv > 0.0 && k.route_imbalance_cv < 1.0);
        assert!(k.straggler_scale.0 > 1.0);
        assert!(k.straggler_scale.1 > k.straggler_scale.0);
        assert!(k.sim_decode_steps >= 8);
    }
}
