//! Step lowering: scheduled serving steps → Plan IR.
//!
//! The batcher emits a sequence of heterogeneous *steps* — a batched
//! prefill over newly admitted prompts, or one decode iteration for the
//! resident batch at its current KV context. Each step shape lowers
//! through the **existing** parallelism lowerers (`parallelism::lower`)
//! unchanged: a step-shaped `RunConfig` (`seq_out = 1`, one simulated
//! decode step) produces a full mini-plan whose step-0 ops are exactly the
//! prefill pass over `tokens` prompt tokens and whose step-1 ops are
//! exactly one decode iteration at KV context `tokens` — the sub-plan the
//! step needs is sliced out by the op `step` tag. Sends and receives never
//! cross a step tag in any lowerer (pipeline boundary edges live inside
//! one pass), so sliced sub-plans keep every edge matched; edge ids are
//! left untouched (unconsumed slots are simply never received).
//!
//! Both step kinds of one (batch, tokens) shape share a single lowering
//! via the run-level `plan::PlanCache`; the sliced sub-plans are cached
//! again per shape, so a long trace replays thousands of steps from a
//! handful of lowered plans. Contexts are bucketed by the caller
//! (`ServeConfig::ctx_bucket`) to keep that handful small. The engine's
//! sync/transfer isolation then applies to every serving step unchanged.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
use crate::plan::{Plan, PlanCache};

/// Phase of a scheduled step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepKind {
    /// Batched prompt prefill for newly admitted requests.
    Prefill,
    /// One decode iteration for the resident batch.
    Decode,
}

impl StepKind {
    pub fn name(&self) -> &'static str {
        match self {
            StepKind::Prefill => "prefill",
            StepKind::Decode => "decode",
        }
    }
}

/// Shape of one serving step: everything lowering depends on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StepShape {
    pub kind: StepKind,
    /// Sequences in the iteration batch.
    pub batch: usize,
    /// Prompt length (prefill) or KV context (decode), bucketed tokens.
    pub tokens: usize,
}

/// Round a token count up to the bucket grid (minimum one bucket).
pub fn bucket_tokens(tokens: usize, bucket: usize) -> usize {
    let b = bucket.max(1);
    tokens.div_ceil(b) * b
}

/// Slice the ops of a lowered mini-plan down to one step kind.
fn slice(plan: &Plan, kind: StepKind) -> Plan {
    let ops = plan
        .ops
        .iter()
        .filter(|op| match kind {
            StepKind::Prefill => op.step() == 0,
            StepKind::Decode => op.step() > 0,
        })
        .cloned()
        .collect();
    Plan {
        num_ranks: plan.num_ranks,
        ops,
        // Edge ids are global to the mini-plan; keeping the count valid is
        // all the engine needs (unreferenced edges are never received).
        num_edges: plan.num_edges,
        draws_sync_jitter: plan.draws_sync_jitter,
        sim_steps: 1,
        comm_bytes_per_step: plan.comm_bytes_per_step,
    }
}

/// Shape-keyed step-plan cache over the shared run-level `PlanCache`.
#[derive(Debug)]
pub struct StepLowerer {
    model: String,
    parallelism: Parallelism,
    gpus: usize,
    hw: HwSpec,
    /// Step knobs: exactly one simulated decode step.
    knobs: SimKnobs,
    runs: PlanCache,
    steps: Mutex<HashMap<StepShape, Arc<Plan>>>,
}

impl StepLowerer {
    pub fn new(model: &str, parallelism: Parallelism, gpus: usize, hw: HwSpec, knobs: &SimKnobs) -> StepLowerer {
        StepLowerer {
            model: model.to_string(),
            parallelism,
            gpus,
            hw,
            knobs: SimKnobs {
                sim_decode_steps: 1,
                ..knobs.clone()
            },
            runs: PlanCache::new(),
            steps: Mutex::new(HashMap::new()),
        }
    }

    /// The step knobs every step simulation must execute under.
    pub fn knobs(&self) -> &SimKnobs {
        &self.knobs
    }

    /// Step-shaped run configuration: `seq_in` carries the shape's token
    /// count (prompt length or KV context) and `seq_out = 1` pins the
    /// mini-plan to a single decode iteration at exactly that context.
    pub fn step_config(&self, shape: &StepShape, seed: u64) -> RunConfig {
        RunConfig {
            model: self.model.clone(),
            parallelism: self.parallelism,
            gpus: self.gpus,
            batch: shape.batch,
            seq_in: shape.tokens,
            seq_out: 1,
            seed,
        }
    }

    /// The sliced sub-plan for a step shape (lowering on first use; both
    /// kinds of one (batch, tokens) shape share a single lowering).
    pub fn step_plan(&self, shape: &StepShape) -> Arc<Plan> {
        if let Some(p) = self.steps.lock().unwrap().get(shape) {
            return Arc::clone(p);
        }
        let cfg = self.step_config(shape, 0);
        let full = self.runs.get_or_lower(&cfg, &self.hw, &self.knobs);
        let sub = Arc::new(slice(&full, shape.kind));
        self.steps.lock().unwrap().entry(shape.clone()).or_insert(sub).clone()
    }

    /// (lowered mini-plans, run-cache hits, sliced step plans).
    pub fn stats(&self) -> (usize, usize, usize) {
        let (plans, hits) = self.runs.stats();
        (plans, hits, self.steps.lock().unwrap().len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Strategy;
    use crate::plan::Op;

    fn lowerer(par: Parallelism, gpus: usize) -> StepLowerer {
        StepLowerer::new("Vicuna-7B", par, gpus, HwSpec::default(), &SimKnobs::default())
    }

    fn shapes() -> [StepShape; 2] {
        [
            StepShape {
                kind: StepKind::Prefill,
                batch: 4,
                tokens: 128,
            },
            StepShape {
                kind: StepKind::Decode,
                batch: 4,
                tokens: 128,
            },
        ]
    }

    fn all_pars() -> Vec<Parallelism> {
        vec![
            Parallelism::Tensor,
            Parallelism::Pipeline,
            Parallelism::Data,
            Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap(),
            Parallelism::hybrid(Strategy::Tensor, Strategy::Data, 2).unwrap(),
            Parallelism::hybrid(Strategy::Pipeline, Strategy::Data, 2).unwrap(),
        ]
    }

    #[test]
    fn bucketing_rounds_up_on_the_grid() {
        assert_eq!(bucket_tokens(1, 64), 64);
        assert_eq!(bucket_tokens(64, 64), 64);
        assert_eq!(bucket_tokens(65, 64), 128);
        assert_eq!(bucket_tokens(7, 0), 7); // degenerate bucket -> identity
    }

    #[test]
    fn sliced_subplans_partition_the_mini_plan() {
        for par in all_pars() {
            let lw = lowerer(par, 4);
            let [pre, dec] = shapes();
            let full = {
                let cfg = lw.step_config(&pre, 0);
                crate::parallelism::lower(&crate::models::by_name("Vicuna-7B").unwrap(), &lw.hw, &lw.knobs, &cfg)
            };
            let p = lw.step_plan(&pre);
            let d = lw.step_plan(&dec);
            assert_eq!(p.ops.len() + d.ops.len(), full.ops.len(), "{par:?} partition");
            assert!(p.ops.iter().all(|op| op.step() == 0), "{par:?} prefill tags");
            assert!(d.ops.iter().all(|op| op.step() > 0), "{par:?} decode tags");
            assert!(!p.ops.is_empty() && !d.ops.is_empty(), "{par:?} non-empty");
        }
    }

    #[test]
    fn sliced_subplans_keep_edges_matched() {
        for par in all_pars() {
            let lw = lowerer(par, 4);
            for shape in shapes() {
                let plan = lw.step_plan(&shape);
                let mut sent = vec![false; plan.num_edges as usize];
                for op in &plan.ops {
                    match op {
                        Op::Send { edge, .. } => sent[*edge as usize] = true,
                        Op::Recv { edge, .. } => {
                            assert!(sent[*edge as usize], "{par:?} {shape:?}: recv of unsliced edge {edge}");
                        }
                        _ => {}
                    }
                }
            }
        }
    }

    #[test]
    fn step_plans_execute_through_the_engine() {
        use crate::simulator::simulate_run_planned;
        for par in all_pars() {
            let lw = lowerer(par, 4);
            for shape in shapes() {
                let plan = lw.step_plan(&shape);
                let cfg = lw.step_config(&shape, 9);
                let r = simulate_run_planned(&cfg, &lw.hw, lw.knobs(), &plan);
                assert!(r.true_total_j > 0.0 && r.wall_s > 0.0, "{par:?} {shape:?}");
                match shape.kind {
                    // A prefill step is all prefill: no decode tail.
                    StepKind::Prefill => assert_eq!(r.decode_s, 0.0, "{par:?}"),
                    // A decode step has no prefill prologue.
                    StepKind::Decode => assert_eq!(r.prefill_s, 0.0, "{par:?}"),
                }
            }
        }
    }

    #[test]
    fn both_kinds_share_one_lowering() {
        let lw = lowerer(Parallelism::Tensor, 4);
        let [pre, dec] = shapes();
        let _ = lw.step_plan(&pre);
        let _ = lw.step_plan(&dec);
        let _ = lw.step_plan(&pre);
        let (plans, hits, steps) = lw.stats();
        assert_eq!(plans, 1, "one mini-plan lowering serves both kinds");
        assert_eq!(hits, 1, "the second kind hits the run cache");
        assert_eq!(steps, 2);
    }

    #[test]
    fn decode_context_is_exact() {
        // seq_out = 1 makes the lowered decode iteration's representative
        // KV context exactly seq_in: frac = 0.5, (0.5 * 1) as usize = 0.
        let lw = lowerer(Parallelism::Tensor, 2);
        let a = lw.step_plan(&StepShape {
            kind: StepKind::Decode,
            batch: 8,
            tokens: 256,
        });
        let b = lw.step_plan(&StepShape {
            kind: StepKind::Decode,
            batch: 8,
            tokens: 512,
        });
        // Longer context -> strictly more attention time in the plan.
        let attn_time = |p: &Plan| -> f64 {
            let mut t = 0.0;
            for op in &p.ops {
                if let Op::Compute { module, nominal_s, .. } = op {
                    if *module == crate::simulator::timeline::ModuleKind::SelfAttention {
                        t += *nominal_s;
                    }
                }
            }
            t
        };
        assert!(attn_time(&b) > attn_time(&a));
    }
}
