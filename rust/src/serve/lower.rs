//! Step lowering: scheduled serving steps → compiled sub-plans.
//!
//! The batcher emits a sequence of heterogeneous *steps* — a batched
//! prefill over newly admitted prompts, or one decode iteration for the
//! resident batch at its current KV context. Each step shape lowers
//! through the **existing** parallelism lowerers unchanged: a step-shaped
//! `RunConfig` (`seq_out = 1`, one simulated decode step) produces a full
//! compiled mini-plan whose step-0 ops are exactly the prefill pass over
//! `tokens` prompt tokens and whose step-1 ops are exactly one decode
//! iteration at KV context `tokens` — the sub-plan the step needs is
//! sliced out of the structure arrays by op `step` tag
//! (`ExecPlan::slice_steps`). Sends and receives never cross a step tag
//! in any lowerer (pipeline boundary edges live inside one pass), so
//! sliced sub-plans keep every edge matched; edge ids are left untouched
//! (unconsumed slots are simply never received).
//!
//! Lowering rides the shared two-level `plan::PlanCache`: both step kinds
//! of one (batch, tokens) shape share a single lowering via the shape
//! level, and — the serving win of the compiled layer — decode steps at
//! *different* bucketed contexts share one mesh **structure** and rebind
//! only the scalar table, so a long trace replays thousands of steps from
//! a handful of structure lowerings plus cheap array fills. Contexts are
//! bucketed by the caller (`ServeConfig::ctx_bucket`) to bound even the
//! rebind count. The engine's sync/transfer isolation then applies to
//! every serving step unchanged.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
use crate::plan::{CacheStats, ExecPlan, PlanCache};

/// Phase of a scheduled step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepKind {
    /// Batched prompt prefill for newly admitted requests.
    Prefill,
    /// One decode iteration for the resident batch.
    Decode,
}

impl StepKind {
    pub fn name(&self) -> &'static str {
        match self {
            StepKind::Prefill => "prefill",
            StepKind::Decode => "decode",
        }
    }
}

/// Shape of one serving step: everything lowering depends on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StepShape {
    pub kind: StepKind,
    /// Sequences in the iteration batch.
    pub batch: usize,
    /// Prompt length (prefill) or KV context (decode), bucketed tokens.
    pub tokens: usize,
}

/// Round a token count up to the bucket grid (minimum one bucket).
pub fn bucket_tokens(tokens: usize, bucket: usize) -> usize {
    let b = bucket.max(1);
    tokens.div_ceil(b) * b
}

/// Slice a compiled mini-plan down to one step kind by op `step` tag.
fn slice(plan: &ExecPlan, kind: StepKind) -> ExecPlan {
    match kind {
        StepKind::Prefill => plan.slice_steps(|s| s == 0),
        StepKind::Decode => plan.slice_steps(|s| s > 0),
    }
}

/// Shape-keyed step-plan cache over the shared two-level run `PlanCache`.
#[derive(Debug)]
pub struct StepLowerer {
    model: String,
    parallelism: Parallelism,
    gpus: usize,
    hw: HwSpec,
    /// Step knobs: exactly one simulated decode step.
    knobs: SimKnobs,
    runs: PlanCache,
    steps: Mutex<HashMap<StepShape, ExecPlan>>,
}

impl StepLowerer {
    pub fn new(model: &str, parallelism: Parallelism, gpus: usize, hw: HwSpec, knobs: &SimKnobs) -> StepLowerer {
        StepLowerer {
            model: model.to_string(),
            parallelism,
            gpus,
            hw,
            knobs: SimKnobs {
                sim_decode_steps: 1,
                ..knobs.clone()
            },
            runs: PlanCache::new(),
            steps: Mutex::new(HashMap::new()),
        }
    }

    /// The step knobs every step simulation must execute under.
    pub fn knobs(&self) -> &SimKnobs {
        &self.knobs
    }

    /// Step-shaped run configuration: `seq_in` carries the shape's token
    /// count (prompt length or KV context) and `seq_out = 1` pins the
    /// mini-plan to a single decode iteration at exactly that context.
    pub fn step_config(&self, shape: &StepShape, seed: u64) -> RunConfig {
        RunConfig {
            model: self.model.clone(),
            parallelism: self.parallelism,
            gpus: self.gpus,
            batch: shape.batch,
            seq_in: shape.tokens,
            seq_out: 1,
            seed,
        }
    }

    /// The sliced sub-plan for a step shape. First use of a shape lowers
    /// (or rebinds — shapes differing only in bucketed context share one
    /// structure) through the run cache, then slices; both kinds of one
    /// (batch, tokens) shape share a single lowering.
    pub fn step_plan(&self, shape: &StepShape) -> ExecPlan {
        if let Some(p) = self.steps.lock().unwrap().get(shape) {
            return p.clone();
        }
        let cfg = self.step_config(shape, 0);
        let full = self.runs.get_or_lower(&cfg, &self.hw, &self.knobs);
        let sub = slice(&full, shape.kind);
        self.steps.lock().unwrap().entry(shape.clone()).or_insert(sub).clone()
    }

    /// (run-cache counters, sliced step plans).
    pub fn stats(&self) -> (CacheStats, usize) {
        (self.runs.stats(), self.steps.lock().unwrap().len())
    }

    /// Record one batched step walk resolving `lanes` sessions' steps
    /// (fleet speculative batching; surfaces in `stats`).
    pub fn note_batch(&self, lanes: usize) {
        self.runs.note_batch(lanes);
    }

    /// Record one step executed outside a batch.
    pub fn note_serial_fallback(&self) {
        self.runs.note_serial_fallback();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Strategy;
    use crate::plan::exec::OpKind;
    use crate::simulator::timeline::ModuleKind;

    fn lowerer(par: Parallelism, gpus: usize) -> StepLowerer {
        StepLowerer::new("Vicuna-7B", par, gpus, HwSpec::default(), &SimKnobs::default())
    }

    fn shapes() -> [StepShape; 2] {
        [
            StepShape {
                kind: StepKind::Prefill,
                batch: 4,
                tokens: 128,
            },
            StepShape {
                kind: StepKind::Decode,
                batch: 4,
                tokens: 128,
            },
        ]
    }

    fn all_pars() -> Vec<Parallelism> {
        vec![
            Parallelism::Tensor,
            Parallelism::Pipeline,
            Parallelism::Data,
            Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap(),
            Parallelism::hybrid(Strategy::Tensor, Strategy::Data, 2).unwrap(),
            Parallelism::hybrid(Strategy::Pipeline, Strategy::Data, 2).unwrap(),
        ]
    }

    #[test]
    fn bucketing_rounds_up_on_the_grid() {
        assert_eq!(bucket_tokens(1, 64), 64);
        assert_eq!(bucket_tokens(64, 64), 64);
        assert_eq!(bucket_tokens(65, 64), 128);
        assert_eq!(bucket_tokens(7, 0), 7); // degenerate bucket -> identity
    }

    #[test]
    fn sliced_subplans_partition_the_mini_plan() {
        for par in all_pars() {
            let lw = lowerer(par, 4);
            let [pre, dec] = shapes();
            let full = {
                let cfg = lw.step_config(&pre, 0);
                let spec = crate::models::by_name("Vicuna-7B").unwrap();
                crate::parallelism::compile(&spec, &lw.hw, &lw.knobs, &cfg)
            };
            let p = lw.step_plan(&pre);
            let d = lw.step_plan(&dec);
            assert_eq!(p.len() + d.len(), full.len(), "{par:?} partition");
            assert!(p.structure.step.iter().all(|&s| s == 0), "{par:?} prefill tags");
            assert!(d.structure.step.iter().all(|&s| s > 0), "{par:?} decode tags");
            assert!(!p.is_empty() && !d.is_empty(), "{par:?} non-empty");
        }
    }

    #[test]
    fn sliced_subplans_keep_edges_matched() {
        for par in all_pars() {
            let lw = lowerer(par, 4);
            for shape in shapes() {
                let plan = lw.step_plan(&shape);
                let s = &plan.structure;
                let mut sent = vec![false; s.num_edges as usize];
                for i in 0..s.len() {
                    match s.kind[i] {
                        OpKind::Send => sent[s.edge[i] as usize] = true,
                        OpKind::Recv => {
                            assert!(
                                sent[s.edge[i] as usize],
                                "{par:?} {shape:?}: recv of unsliced edge {}",
                                s.edge[i]
                            );
                        }
                        _ => {}
                    }
                }
            }
        }
    }

    #[test]
    fn step_plans_execute_through_the_engine() {
        use crate::simulator::simulate_run_planned;
        for par in all_pars() {
            let lw = lowerer(par, 4);
            for shape in shapes() {
                let plan = lw.step_plan(&shape);
                let cfg = lw.step_config(&shape, 9);
                let r = simulate_run_planned(&cfg, &lw.hw, lw.knobs(), &plan);
                assert!(r.true_total_j > 0.0 && r.wall_s > 0.0, "{par:?} {shape:?}");
                match shape.kind {
                    // A prefill step is all prefill: no decode tail.
                    StepKind::Prefill => assert_eq!(r.decode_s, 0.0, "{par:?}"),
                    // A decode step has no prefill prologue.
                    StepKind::Decode => assert_eq!(r.prefill_s, 0.0, "{par:?}"),
                }
            }
        }
    }

    #[test]
    fn both_kinds_share_one_lowering() {
        let lw = lowerer(Parallelism::Tensor, 4);
        let [pre, dec] = shapes();
        let _ = lw.step_plan(&pre);
        let _ = lw.step_plan(&dec);
        let _ = lw.step_plan(&pre);
        let (cache, steps) = lw.stats();
        assert_eq!(cache.structure_lowerings, 1, "one mini-plan lowering serves both kinds");
        assert_eq!(cache.shape_hits, 1, "the second kind hits the shape level");
        assert_eq!(steps, 2);
    }

    #[test]
    fn contexts_share_one_structure_via_rebinding() {
        // Decode steps at different bucketed KV contexts are different
        // shapes of the *same* mesh: the run cache serves them with one
        // structure lowering plus scalar rebinds.
        let lw = lowerer(Parallelism::Tensor, 4);
        let plans: Vec<ExecPlan> = [128usize, 256, 384, 512]
            .iter()
            .map(|&tokens| {
                lw.step_plan(&StepShape {
                    kind: StepKind::Decode,
                    batch: 8,
                    tokens,
                })
            })
            .collect();
        let (cache, steps) = lw.stats();
        assert_eq!(cache.structure_lowerings, 1, "one structure for every context");
        assert_eq!(cache.rebinds, 3, "further contexts are scalar rebinds");
        assert_eq!(
            cache.affine_rebinds + cache.replay_fallbacks,
            cache.rebinds,
            "every rebind is either an affine evaluation or a lowerer replay"
        );
        assert_eq!(steps, 4);
        // Longer context -> strictly more attention time in the slice.
        let attn = |p: &ExecPlan| -> f64 {
            let s = &p.structure;
            (0..s.len())
                .filter(|&i| s.kind[i] == OpKind::Compute && s.module[i] == ModuleKind::SelfAttention)
                .map(|i| p.scalars.dur_s[i])
                .sum()
        };
        for w in plans.windows(2) {
            assert!(attn(&w[1]) > attn(&w[0]));
        }
    }
}
