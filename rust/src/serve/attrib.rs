//! Per-request energy attribution.
//!
//! Every serving step is simulated as one engine execution whose
//! `RunRecord` carries the step's exact wall energy (`true_total_j`) and
//! its phase-resolved sync/transfer split. The attribution rule splits
//! each step's energy across the requests resident in that step
//! proportional to their *token work*:
//!
//! * prefill step — each admitted request weighs its prompt length (the
//!   tokens it contributes to the batched prefill);
//! * decode step — each resident request weighs its current KV context
//!   (prompt + tokens generated so far, the KV rows its attention touches)
//!   plus the one token it generates.
//!
//! The split is a plain proportional division, so the **conservation
//! invariant** holds by construction: the per-request energies of a step
//! sum to the step's wall energy to floating-point rounding, and over a
//! whole trace Σ per-request J == Σ per-step J within 1e-9 relative
//! (property-tested across every strategy, hybrids included, and both
//! scheduling policies).

/// Everything recorded about one served request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: u32,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Trace arrival time, s.
    pub arrival_s: f64,
    /// Admission into the resident batch, s.
    pub admit_s: f64,
    /// End of the prefill step that produced the first output token, s.
    pub first_token_s: f64,
    /// Completion (or rejection) time, s.
    pub finish_s: f64,
    /// Attributed wall energy, J.
    pub energy_j: f64,
    /// Attributed share of synchronization-wait energy, J.
    pub sync_energy_j: f64,
    /// Decode iterations the request participated in.
    pub decode_steps: usize,
    /// True when the request could never fit the serving budgets and was
    /// dropped unserved (zero energy).
    pub rejected: bool,
}

impl RequestRecord {
    /// Attributed energy per generated token, J.
    pub fn energy_per_token_j(&self) -> f64 {
        self.energy_j / self.output_tokens.max(1) as f64
    }

    /// Queueing delay before admission, s.
    pub fn queue_delay_s(&self) -> f64 {
        self.admit_s - self.arrival_s
    }

    /// End-to-end latency, s.
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Split `energy_j` across participants proportional to `weights`.
/// Degenerate all-zero weights fall back to an equal split so a step's
/// energy is never dropped.
pub fn split_energy(energy_j: f64, weights: &[f64]) -> Vec<f64> {
    debug_assert!(!weights.is_empty(), "attribution over an empty step");
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        let n = weights.len().max(1) as f64;
        return weights.iter().map(|_| energy_j / n).collect();
    }
    weights.iter().map(|w| energy_j * (w / total)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_proportional_and_conserves() {
        let parts = split_energy(100.0, &[1.0, 3.0]);
        assert_eq!(parts.len(), 2);
        assert!((parts[0] - 25.0).abs() < 1e-12);
        assert!((parts[1] - 75.0).abs() < 1e-12);
        let total: f64 = parts.iter().sum();
        assert!((total - 100.0).abs() / 100.0 < 1e-12);
    }

    #[test]
    fn split_conserves_under_many_irrational_weights() {
        let weights: Vec<f64> = (1..200).map(|i| (i as f64).sqrt() * 0.377).collect();
        let e = 12345.6789;
        let total: f64 = split_energy(e, &weights).iter().sum();
        assert!((total - e).abs() / e < 1e-12, "total {total}");
    }

    #[test]
    fn zero_weights_fall_back_to_equal_split() {
        let parts = split_energy(9.0, &[0.0, 0.0, 0.0]);
        for p in &parts {
            assert!((p - 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn record_derived_metrics() {
        let r = RequestRecord {
            id: 1,
            prompt_tokens: 64,
            output_tokens: 8,
            arrival_s: 1.0,
            admit_s: 1.5,
            first_token_s: 2.0,
            finish_s: 4.0,
            energy_j: 80.0,
            sync_energy_j: 8.0,
            decode_steps: 7,
            rejected: false,
        };
        assert!((r.energy_per_token_j() - 10.0).abs() < 1e-12);
        assert!((r.queue_delay_s() - 0.5).abs() < 1e-12);
        assert!((r.latency_s() - 3.0).abs() < 1e-12);
    }
}
