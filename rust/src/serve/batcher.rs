//! Iteration-level continuous-batching scheduler.
//!
//! Requests are admitted only at decode-step boundaries (the vLLM-style
//! iteration-level scheduling the serving literature assumes): between
//! steps the batcher pulls queued requests into the resident batch, and
//! each admission reserves the request's full KV footprint (prompt +
//! output tokens) for its lifetime — conservative admission, so a request
//! never has to be preempted for KV space mid-decode. Three budgets gate
//! admission: the resident-sequence cap, the reserved-token cap, and the
//! mesh-wide KV-cache VRAM budget derived from `config::HwSpec` and the
//! shared weight-memory model (`workload::weights_per_gpu_bytes`).
//!
//! Two policies: strict FCFS (head-of-line blocks — arrival order is
//! served exactly) and shortest-prompt-first (pending requests reordered
//! by prompt length; misfits are skipped, trading fairness for occupancy).

use crate::config::{HwSpec, Parallelism};
use crate::models::ModelSpec;
use crate::workload;

use super::trace::Request;

/// Admission-ordering policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First-come-first-served; the queue head blocks admission.
    Fcfs,
    /// Shortest prompt first; misfitting requests are skipped over.
    ShortestPromptFirst,
}

impl Policy {
    pub const ALL: [Policy; 2] = [Policy::Fcfs, Policy::ShortestPromptFirst];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::ShortestPromptFirst => "spf",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Some(Policy::Fcfs),
            "spf" | "shortest-prompt-first" => Some(Policy::ShortestPromptFirst),
            _ => None,
        }
    }
}

// The KV-per-token size is the same formula the simulator's memory model
// uses — one shared definition in `workload`.
pub use crate::workload::kv_bytes_per_token;

/// Mesh-wide KV-cache VRAM budget: per-GPU headroom left over the resident
/// weights (with the same 5% runtime-state margin `workload::runnable`
/// applies) summed over the mesh. Zero when the model itself does not fit.
pub fn kv_budget_bytes(spec: &ModelSpec, parallelism: Parallelism, gpus: usize, hw: &HwSpec) -> f64 {
    let weights = workload::weights_per_gpu_bytes(spec, parallelism, gpus);
    (hw.vram_bytes - 1.05 * weights).max(0.0) * gpus as f64
}

/// Batcher limits.
#[derive(Debug, Clone)]
pub struct BatcherCfg {
    pub policy: Policy,
    /// Max resident sequences per iteration batch.
    pub max_batch_requests: usize,
    /// Max reserved tokens (prompt + output) across resident sequences.
    pub max_batch_tokens: usize,
    /// Mesh-wide KV-cache byte budget (`kv_budget_bytes`).
    pub kv_budget_bytes: f64,
}

/// Continuous batcher state: the pending queue plus the resident batch's
/// reservation counters.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherCfg,
    kv_per_token: f64,
    /// Arrived, not yet admitted (FCFS: arrival order; SPF: resorted on
    /// every admission pass).
    pending: Vec<Request>,
    resident_requests: usize,
    resident_tokens: usize,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg, kv_per_token: f64) -> Batcher {
        assert!(cfg.max_batch_requests > 0 && cfg.max_batch_tokens > 0, "degenerate batcher limits");
        Batcher {
            cfg,
            kv_per_token,
            pending: Vec::new(),
            resident_requests: 0,
            resident_tokens: 0,
        }
    }

    /// Queue an arrived request (callers enqueue in arrival order).
    pub fn enqueue(&mut self, r: Request) {
        self.pending.push(r);
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    pub fn resident_requests(&self) -> usize {
        self.resident_requests
    }

    /// Reserved tokens across the resident batch.
    pub fn resident_tokens(&self) -> usize {
        self.resident_tokens
    }

    /// Reserved KV bytes across the resident batch.
    pub fn resident_kv_bytes(&self) -> f64 {
        self.resident_tokens as f64 * self.kv_per_token
    }

    fn fits(&self, r: &Request) -> bool {
        let tokens = self.resident_tokens + r.reserved_tokens();
        self.resident_requests < self.cfg.max_batch_requests
            && tokens <= self.cfg.max_batch_tokens
            && tokens as f64 * self.kv_per_token <= self.cfg.kv_budget_bytes
    }

    /// Admit queued requests under the policy and budgets; called at every
    /// decode-step boundary. Returns the newly admitted requests (their
    /// reservations are taken immediately).
    pub fn admit(&mut self) -> Vec<Request> {
        if self.cfg.policy == Policy::ShortestPromptFirst {
            self.pending.sort_by(|a, b| {
                a.prompt_tokens
                    .cmp(&b.prompt_tokens)
                    .then(a.arrival_s.partial_cmp(&b.arrival_s).expect("finite arrivals"))
                    .then(a.id.cmp(&b.id))
            });
        }
        let mut admitted = Vec::new();
        let mut i = 0;
        while i < self.pending.len() {
            if self.fits(&self.pending[i]) {
                let r = self.pending.remove(i);
                self.resident_requests += 1;
                self.resident_tokens += r.reserved_tokens();
                admitted.push(r);
            } else if self.cfg.policy == Policy::Fcfs {
                break; // strict FCFS: the head blocks
            } else {
                i += 1; // SPF: skip misfits
            }
        }
        admitted
    }

    /// Release a finished request's reservation.
    pub fn release(&mut self, r: &Request) {
        debug_assert!(self.resident_requests > 0 && self.resident_tokens >= r.reserved_tokens());
        self.resident_requests -= 1;
        self.resident_tokens -= r.reserved_tokens();
    }

    /// Drop the policy-first pending request (driver fallback when nothing
    /// is resident and nothing can ever be admitted). Returns it.
    pub fn reject_head(&mut self) -> Option<Request> {
        if self.pending.is_empty() {
            return None;
        }
        Some(self.pending.remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn req(id: u32, arrival: f64, prompt: usize, output: usize) -> Request {
        Request {
            id,
            arrival_s: arrival,
            prompt_tokens: prompt,
            output_tokens: output,
            session: None,
        }
    }

    fn batcher(policy: Policy, max_requests: usize, max_tokens: usize) -> Batcher {
        Batcher::new(
            BatcherCfg {
                policy,
                max_batch_requests: max_requests,
                max_batch_tokens: max_tokens,
                kv_budget_bytes: f64::INFINITY,
            },
            1.0,
        )
    }

    #[test]
    fn fcfs_serves_arrival_order() {
        let mut b = batcher(Policy::Fcfs, 8, 100);
        b.enqueue(req(0, 0.0, 50, 10)); // reserves 60
        b.enqueue(req(1, 0.1, 20, 10)); // reserves 30
        b.enqueue(req(2, 0.2, 20, 10)); // would overflow the 100-token cap
        let a = b.admit();
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 1);
        // Space released -> the blocked head admits at the next boundary.
        b.release(&a[0]);
        let a2 = b.admit();
        assert_eq!(a2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn fcfs_head_blocks_until_rejected() {
        let mut b = batcher(Policy::Fcfs, 8, 100);
        b.enqueue(req(0, 0.0, 120, 5)); // reserves 125: can never fit
        b.enqueue(req(1, 0.1, 2, 2)); // fits, but sits behind the head
        assert!(b.admit().is_empty(), "strict FCFS: the oversized head blocks");
        let dropped = b.reject_head().unwrap();
        assert_eq!(dropped.id, 0);
        assert_eq!(b.admit().iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn spf_reorders_by_prompt_and_skips_misfits() {
        let mut b = batcher(Policy::ShortestPromptFirst, 8, 100);
        b.enqueue(req(0, 0.0, 80, 10)); // 90 tokens
        b.enqueue(req(1, 0.1, 10, 5)); // 15 tokens
        b.enqueue(req(2, 0.2, 30, 5)); // 35 tokens
        let a = b.admit();
        // Shortest first: 1 (15) then 2 (35); 0 no longer fits (90 > 50 left).
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.resident_tokens(), 50);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn request_cap_limits_admission() {
        let mut b = batcher(Policy::Fcfs, 2, 1_000_000);
        for i in 0..5 {
            b.enqueue(req(i, i as f64, 8, 4));
        }
        assert_eq!(b.admit().len(), 2);
        assert_eq!(b.resident_requests(), 2);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn kv_budget_gates_admission() {
        let mut b = Batcher::new(
            BatcherCfg {
                policy: Policy::Fcfs,
                max_batch_requests: 8,
                max_batch_tokens: 1_000_000,
                kv_budget_bytes: 100.0,
            },
            2.0, // 2 bytes per token -> 50-token budget
        );
        b.enqueue(req(0, 0.0, 30, 10)); // 40 tokens = 80 bytes
        b.enqueue(req(1, 0.1, 10, 10)); // would exceed 100 bytes
        assert_eq!(b.admit().len(), 1);
        assert!(b.resident_kv_bytes() <= 100.0);
        b.release(&req(0, 0.0, 30, 10));
        assert_eq!(b.admit().len(), 1);
    }

    #[test]
    fn kv_model_matches_testbed_scale() {
        let spec = models::by_name("Vicuna-7B").unwrap();
        let hw = crate::config::HwSpec::default();
        // fp16 7B: 2 * 32 kv heads * 128 head dim * 2 B * 32 layers = 1 MiB/token.
        let per_tok = kv_bytes_per_token(&spec);
        assert_eq!(per_tok, (2 * 32 * 128 * 2 * 32) as f64);
        // TP-4 leaves most of the 4x48 GB mesh to KV.
        let budget = kv_budget_bytes(&spec, Parallelism::Tensor, 4, &hw);
        assert!(budget > 100.0e9, "budget {budget}");
        // DP replicates weights: less KV headroom than TP.
        assert!(kv_budget_bytes(&spec, Parallelism::Data, 4, &hw) < budget);
        // A model that does not fit has zero budget.
        let llama = models::by_name("Llama-70B").unwrap();
        assert_eq!(kv_budget_bytes(&llama, Parallelism::Data, 2, &hw), 0.0);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("shortest-prompt-first"), Some(Policy::ShortestPromptFirst));
        assert_eq!(Policy::parse("lifo"), None);
    }
}
