//! Request traces for the serving simulator.
//!
//! A trace is an ordered list of inference requests — arrival timestamp,
//! prompt length, output length — replayed by `serve::serve` through the
//! continuous batcher. Traces load from a newline-delimited JSON format
//! (one object per line: `{"id": 0, "arrival_s": 0.41, "prompt_tokens":
//! 128, "output_tokens": 64}`; `id` is optional, defaults to the
//! parsed-request index, and must be unique) or come from the seeded
//! synthetic generators: homogeneous
//! Poisson arrivals, bursty ON/OFF traffic, and a sinusoidal diurnal ramp
//! — the request-mix regimes TokenPowerBench identifies as the dominant
//! drivers of real serving energy. Generation is fully deterministic
//! under a fixed seed (everything draws from `util::rng`).

use crate::util::json::{num, obj, Json};
use crate::util::rng::Rng;

/// One inference request of a serving trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u32,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length, tokens.
    pub prompt_tokens: usize,
    /// Requested generation length, tokens.
    pub output_tokens: usize,
    /// Conversation/session id, if the trace carries one. The fleet
    /// router's session-affinity policy keys on this; `None` requests
    /// fall back to hashing the request id.
    pub session: Option<u32>,
}

impl Request {
    /// Tokens of KV cache the request holds at completion (its
    /// reservation under conservative admission).
    pub fn reserved_tokens(&self) -> usize {
        self.prompt_tokens + self.output_tokens
    }
}

/// An arrival-ordered request trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Requests sorted by (arrival, id).
    pub requests: Vec<Request>,
}

impl Trace {
    /// Build a trace, sorting requests into arrival order.
    pub fn new(mut requests: Vec<Request>) -> Trace {
        requests.sort_by(|a, b| {
            a.arrival_s
                .partial_cmp(&b.arrival_s)
                .expect("finite arrival times")
                .then(a.id.cmp(&b.id))
        });
        Trace { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Total requested output tokens across the trace.
    pub fn output_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.output_tokens).sum()
    }

    /// Parse the JSONL trace format. Blank lines and `#` comments are
    /// skipped; requests with zero-length prompts or outputs, malformed
    /// ids, or duplicate ids are rejected (the per-request records and the
    /// `piep-serve-v3` store join on id).
    pub fn parse_jsonl(src: &str) -> Result<Trace, String> {
        let mut out: Vec<Request> = Vec::new();
        let mut seen_ids = std::collections::BTreeSet::new();
        for (i, line) in src.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let j = Json::parse(line).map_err(|e| format!("trace line {}: {e}", i + 1))?;
            let field = |k: &str| {
                j.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("trace line {}: missing numeric `{k}`", i + 1))
            };
            let arrival_s = field("arrival_s")?;
            let prompt_tokens = field("prompt_tokens")? as usize;
            let output_tokens = field("output_tokens")? as usize;
            if !(arrival_s.is_finite() && arrival_s >= 0.0) {
                return Err(format!("trace line {}: bad arrival_s", i + 1));
            }
            if prompt_tokens == 0 || output_tokens == 0 {
                return Err(format!("trace line {}: zero-length request", i + 1));
            }
            let id = match j.get("id").and_then(Json::as_f64) {
                Some(x) if x >= 0.0 && x <= u32::MAX as f64 && x.fract() == 0.0 => x as u32,
                Some(_) => return Err(format!("trace line {}: id must be a u32", i + 1)),
                // Default: the parsed-request index.
                None => out.len() as u32,
            };
            if !seen_ids.insert(id) {
                return Err(format!("trace line {}: duplicate request id {id}", i + 1));
            }
            let session = match j.get("session").and_then(Json::as_f64) {
                Some(x) if x >= 0.0 && x <= u32::MAX as f64 && x.fract() == 0.0 => Some(x as u32),
                Some(_) => return Err(format!("trace line {}: session must be a u32", i + 1)),
                None => None,
            };
            out.push(Request {
                id,
                arrival_s,
                prompt_tokens,
                output_tokens,
                session,
            });
        }
        if out.is_empty() {
            return Err("trace has no requests".into());
        }
        Ok(Trace::new(out))
    }

    /// Load a JSONL trace file.
    pub fn load_jsonl(path: &str) -> Result<Trace, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse_jsonl(&src)
    }

    /// Render the trace back to its JSONL form.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.requests {
            let mut fields = vec![
                ("id", num(r.id as f64)),
                ("arrival_s", num(r.arrival_s)),
                ("prompt_tokens", num(r.prompt_tokens as f64)),
                ("output_tokens", num(r.output_tokens as f64)),
            ];
            if let Some(s) = r.session {
                fields.push(("session", num(s as f64)));
            }
            let j = obj(fields);
            out.push_str(&j.render());
            out.push('\n');
        }
        out
    }

    /// Write the trace as a JSONL file (`load_jsonl`'s inverse).
    pub fn save_jsonl(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_jsonl()).map_err(|e| format!("{path}: {e}"))
    }
}

/// Arrival-process family of a synthetic trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Homogeneous Poisson arrivals at `rate_rps`.
    Poisson,
    /// ON/OFF bursts: Poisson at `burst_factor × rate_rps` inside ON
    /// windows, silence in the OFF gaps.
    Bursty,
    /// Sinusoidal diurnal ramp of the Poisson rate around `rate_rps`.
    Diurnal,
}

impl ArrivalKind {
    pub const ALL: [ArrivalKind; 3] = [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal];

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Diurnal => "diurnal",
        }
    }

    pub fn parse(s: &str) -> Option<ArrivalKind> {
        match s.to_ascii_lowercase().as_str() {
            "poisson" => Some(ArrivalKind::Poisson),
            "bursty" | "onoff" => Some(ArrivalKind::Bursty),
            "diurnal" | "ramp" => Some(ArrivalKind::Diurnal),
            _ => None,
        }
    }
}

/// Synthetic-trace description: arrival process plus lognormal
/// prompt/output length distributions (clamped to the given ranges).
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub kind: ArrivalKind,
    pub requests: usize,
    /// Mean arrival rate, requests/s.
    pub rate_rps: f64,
    /// Target mean / cv of the prompt-length distribution, tokens.
    pub prompt_mean: f64,
    pub prompt_cv: f64,
    /// Clamp range for prompt lengths.
    pub prompt_range: (usize, usize),
    /// Target mean / cv of the output-length distribution, tokens.
    pub output_mean: f64,
    pub output_cv: f64,
    /// Clamp range for output lengths.
    pub output_range: (usize, usize),
    /// Bursty: ON-window rate multiplier and window durations, s.
    pub burst_factor: f64,
    pub on_s: f64,
    pub off_s: f64,
    /// Diurnal: relative rate amplitude in [0, 1) and period, s.
    pub diurnal_amplitude: f64,
    pub period_s: f64,
    /// Number of conversation sessions to spread requests over; 0 (the
    /// default) leaves `Request::session` unset and keeps the RNG stream
    /// bit-identical to pre-session traces.
    pub sessions: usize,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            kind: ArrivalKind::Poisson,
            requests: 32,
            rate_rps: 2.0,
            prompt_mean: 128.0,
            prompt_cv: 0.6,
            prompt_range: (8, 1024),
            output_mean: 8.0,
            output_cv: 0.5,
            output_range: (2, 64),
            burst_factor: 4.0,
            on_s: 4.0,
            off_s: 8.0,
            diurnal_amplitude: 0.8,
            period_s: 60.0,
            sessions: 0,
        }
    }
}

/// Generate a synthetic trace. Deterministic: the same (spec, seed) always
/// produces the same requests.
pub fn synthesize(spec: &SynthSpec, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0x7ACE_5EED);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.requests);
    for i in 0..spec.requests {
        t = match spec.kind {
            ArrivalKind::Poisson => t + rng.exponential(1.0 / spec.rate_rps),
            ArrivalKind::Bursty => {
                // Draw the next ON-rate arrival, then skip any OFF window
                // it lands in (arrivals only happen inside ON windows).
                let cycle = spec.on_s + spec.off_s;
                let mut next = t + rng.exponential(1.0 / (spec.rate_rps * spec.burst_factor));
                if next % cycle >= spec.on_s {
                    // Jump to the start of the next ON window.
                    next = ((next / cycle).floor() + 1.0) * cycle;
                }
                next
            }
            ArrivalKind::Diurnal => {
                // Rate modulated by the phase at the previous arrival
                // (piecewise-constant thinning-free approximation).
                let phase = std::f64::consts::TAU * (t / spec.period_s);
                let amp = spec.diurnal_amplitude.clamp(0.0, 0.95);
                let rate = spec.rate_rps * (1.0 + amp * phase.sin()).max(0.05);
                t + rng.exponential(1.0 / rate)
            }
        };
        let draw_len = |rng: &mut Rng, mean: f64, cv: f64, range: (usize, usize)| -> usize {
            let x = rng.lognormal_mean_cv(mean, cv).round() as usize;
            x.clamp(range.0.max(1), range.1.max(1))
        };
        let prompt_tokens = draw_len(&mut rng, spec.prompt_mean, spec.prompt_cv, spec.prompt_range);
        let output_tokens = draw_len(&mut rng, spec.output_mean, spec.output_cv, spec.output_range);
        // Session draw comes last, and only when requested: traces with
        // `sessions == 0` consume exactly the pre-session RNG stream.
        let session = if spec.sessions > 0 {
            Some(rng.below(spec.sessions) as u32)
        } else {
            None
        };
        out.push(Request {
            id: i as u32,
            arrival_s: t,
            prompt_tokens,
            output_tokens,
            session,
        });
    }
    Trace::new(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_roundtrip_preserves_requests() {
        let spec = SynthSpec {
            requests: 12,
            ..SynthSpec::default()
        };
        let trace = synthesize(&spec, 7);
        let text = trace.to_jsonl();
        let back = Trace::parse_jsonl(&text).unwrap();
        assert_eq!(trace.requests, back.requests);
    }

    #[test]
    fn parse_skips_comments_and_defaults_ids() {
        let src = "# demo trace\n\n{\"arrival_s\": 0.5, \"prompt_tokens\": 16, \"output_tokens\": 4}\n\
                   {\"arrival_s\": 0.1, \"prompt_tokens\": 8, \"output_tokens\": 2}\n";
        let t = Trace::parse_jsonl(src).unwrap();
        assert_eq!(t.len(), 2);
        // Sorted into arrival order; ids default to line order.
        assert_eq!(t.requests[0].arrival_s, 0.1);
        assert_eq!(t.requests[0].id, 1);
        assert_eq!(t.requests[1].id, 0);
    }

    #[test]
    fn parse_rejects_bad_lines() {
        assert!(Trace::parse_jsonl("").is_err());
        assert!(Trace::parse_jsonl("{\"arrival_s\": 1.0}").is_err());
        assert!(Trace::parse_jsonl("{\"arrival_s\": 1.0, \"prompt_tokens\": 0, \"output_tokens\": 4}").is_err());
        assert!(Trace::parse_jsonl("{\"arrival_s\": -1.0, \"prompt_tokens\": 4, \"output_tokens\": 4}").is_err());
    }

    #[test]
    fn parse_rejects_duplicate_and_malformed_ids() {
        // An explicit id colliding with a later default (= parsed index).
        let dup = "{\"id\": 1, \"arrival_s\": 0.1, \"prompt_tokens\": 8, \"output_tokens\": 2}\n\
                   {\"arrival_s\": 0.2, \"prompt_tokens\": 8, \"output_tokens\": 2}\n";
        assert!(Trace::parse_jsonl(dup).unwrap_err().contains("duplicate"));
        for bad in ["-1", "1.5", "5000000000"] {
            let src = format!("{{\"id\": {bad}, \"arrival_s\": 0.1, \"prompt_tokens\": 8, \"output_tokens\": 2}}");
            assert!(Trace::parse_jsonl(&src).unwrap_err().contains("u32"), "{bad}");
        }
    }

    #[test]
    fn synthesis_is_deterministic_and_seed_sensitive() {
        let spec = SynthSpec {
            requests: 20,
            ..SynthSpec::default()
        };
        let a = synthesize(&spec, 3);
        let b = synthesize(&spec, 3);
        let c = synthesize(&spec, 4);
        assert_eq!(a.requests, b.requests);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn arrivals_are_ordered_and_lengths_in_range() {
        for kind in ArrivalKind::ALL {
            let spec = SynthSpec {
                kind,
                requests: 40,
                ..SynthSpec::default()
            };
            let t = synthesize(&spec, 11);
            assert_eq!(t.len(), 40);
            for w in t.requests.windows(2) {
                assert!(w[0].arrival_s <= w[1].arrival_s, "{kind:?} ordered");
            }
            for r in &t.requests {
                assert!((spec.prompt_range.0..=spec.prompt_range.1).contains(&r.prompt_tokens));
                assert!((spec.output_range.0..=spec.output_range.1).contains(&r.output_tokens));
                assert!(r.arrival_s >= 0.0);
            }
        }
    }

    #[test]
    fn bursty_arrivals_cluster_in_on_windows() {
        let spec = SynthSpec {
            kind: ArrivalKind::Bursty,
            requests: 60,
            ..SynthSpec::default()
        };
        let t = synthesize(&spec, 5);
        let cycle = spec.on_s + spec.off_s;
        for r in &t.requests {
            // In an ON window, up to fp tolerance at the window boundary.
            let pos = r.arrival_s % cycle;
            let in_on = pos < spec.on_s + 1e-6 || cycle - pos < 1e-6;
            assert!(in_on, "arrival at cycle offset {pos:.6}s falls in an OFF window");
        }
    }

    #[test]
    fn sessions_are_optional_and_rng_stream_compatible() {
        let base = SynthSpec {
            requests: 16,
            ..SynthSpec::default()
        };
        let plain = synthesize(&base, 9);
        assert!(plain.requests.iter().all(|r| r.session.is_none()));
        let with = synthesize(
            &SynthSpec {
                sessions: 3,
                ..base.clone()
            },
            9,
        );
        // Session draws happen after the length draws, so arrival times
        // and lengths match the session-free trace bit-for-bit.
        for (a, b) in plain.requests.iter().zip(&with.requests) {
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            assert!(matches!(b.session, Some(s) if (s as usize) < 3));
        }
        // Session ids survive the JSONL roundtrip.
        let back = Trace::parse_jsonl(&with.to_jsonl()).unwrap();
        assert_eq!(with.requests, back.requests);
        // Malformed session ids are rejected.
        let bad = "{\"arrival_s\": 0.1, \"prompt_tokens\": 8, \"output_tokens\": 2, \"session\": 1.5}";
        assert!(Trace::parse_jsonl(bad).unwrap_err().contains("session"));
    }

    #[test]
    fn arrival_kind_parse_roundtrip() {
        for k in ArrivalKind::ALL {
            assert_eq!(ArrivalKind::parse(k.name()), Some(k));
        }
        assert_eq!(ArrivalKind::parse("uniform"), None);
    }
}
