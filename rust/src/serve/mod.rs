//! Trace-driven serving simulator: the repo's fourth pillar
//! (workload → plan → engine → **serve**), DESIGN.md §10.
//!
//! `serve` replays a request trace (`trace`) through an iteration-level
//! continuous batcher (`batcher`): at every decode-step boundary queued
//! requests are admitted under the resident-sequence, reserved-token, and
//! KV-cache VRAM budgets, newly admitted prompts run one batched prefill
//! step, and the resident batch then decodes one token per iteration.
//! Every scheduled step lowers through the existing parallelism lowerers
//! into the shared Plan IR (`lower`) and executes on the per-rank
//! discrete-event engine, so the sync/transfer energy isolation, the
//! stochastic skew substrate, and the instrument models all apply to
//! serving steps unchanged. Each step's wall energy is attributed across
//! its resident requests proportional to token work (`attrib`), with exact
//! conservation: Σ per-request J == Σ per-step J (rel 1e-9).
//!
//! Everything is deterministic under `ServeConfig::base_seed`: the same
//! trace and seed reproduce bit-identical per-request records.

pub mod attrib;
pub mod batcher;
pub mod lower;
pub mod trace;

pub use attrib::{split_energy, RequestRecord};
pub use batcher::{kv_budget_bytes, kv_bytes_per_token, Batcher, BatcherCfg, Policy};
pub use lower::{bucket_tokens, StepKind, StepLowerer, StepShape};
pub use trace::{synthesize, ArrivalKind, Request, SynthSpec, Trace};

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::config::{HwSpec, Parallelism, SimKnobs};
use crate::models;
use crate::simulator::{simulate_run_batch, simulate_run_planned, RunRecord};
use crate::util::stats::percentile;
use crate::workload;

/// Serving deployment + scheduling configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: String,
    pub parallelism: Parallelism,
    pub gpus: usize,
    pub policy: Policy,
    /// Max resident sequences per iteration batch.
    pub max_batch_requests: usize,
    /// Max reserved tokens (prompt + output) across resident sequences.
    pub max_batch_tokens: usize,
    /// Context bucket for step-plan reuse, tokens.
    pub ctx_bucket: usize,
    pub base_seed: u64,
}

impl ServeConfig {
    pub fn new(model: &str, parallelism: Parallelism, gpus: usize) -> ServeConfig {
        ServeConfig {
            model: model.to_string(),
            parallelism,
            gpus,
            policy: Policy::Fcfs,
            max_batch_requests: 32,
            max_batch_tokens: 65536,
            ctx_bucket: 64,
            base_seed: 0x5EB5E,
        }
    }

    /// Chainable: set the admission policy.
    pub fn with_policy(mut self, policy: Policy) -> ServeConfig {
        self.policy = policy;
        self
    }

    /// Chainable: cap resident sequences per iteration batch.
    pub fn with_max_batch_requests(mut self, n: usize) -> ServeConfig {
        self.max_batch_requests = n;
        self
    }

    /// Chainable: cap reserved tokens across resident sequences.
    pub fn with_max_batch_tokens(mut self, n: usize) -> ServeConfig {
        self.max_batch_tokens = n;
        self
    }

    /// Chainable: set the context bucket for step-plan reuse.
    pub fn with_ctx_bucket(mut self, tokens: usize) -> ServeConfig {
        self.ctx_bucket = tokens;
        self
    }

    /// Chainable: set the deployment's base seed.
    pub fn with_base_seed(mut self, seed: u64) -> ServeConfig {
        self.base_seed = seed;
        self
    }
}

/// One executed serving step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub kind: StepKind,
    /// Step start on the serving clock, s.
    pub t0_s: f64,
    pub dur_s: f64,
    /// Sequences in the iteration batch.
    pub batch: usize,
    /// Bucketed step tokens (prompt length / KV context).
    pub tokens: usize,
    /// Step wall energy (PSU-referenced), J.
    pub energy_j: f64,
    /// Synchronization-wait share of the step's comm energy, J.
    pub sync_j: f64,
    /// Network-transfer share, J.
    pub transfer_j: f64,
    /// Mean fraction of the step the ranks spent running kernels
    /// (`Timeline::occupancy_split` busy component).
    pub busy_frac: f64,
    /// Mean fraction spent blocked at synchronization points. The
    /// remainder (1 − busy − wait) is idle.
    pub wait_frac: f64,
    /// Binding resource of the step's critical path
    /// (`trace::critpath::BoundBy` name).
    pub bound_by: String,
}

/// Outcome of replaying one trace.
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// Per-request records, sorted by request id.
    pub requests: Vec<RequestRecord>,
    pub steps: Vec<StepRecord>,
    /// Serving-clock makespan, s.
    pub makespan_s: f64,
    /// Σ step energy, J (== Σ per-request energy, rel 1e-9).
    pub total_energy_j: f64,
    /// Mean resident sequences per decode step / `max_batch_requests`.
    pub occupancy: f64,
    /// Step-duration-weighted mean GPU busy fraction (kernels only —
    /// sync-wait time is reported separately in `wait_frac`, not folded
    /// into busy).
    pub busy_frac: f64,
    /// Step-duration-weighted mean sync-wait fraction; the remainder
    /// (1 − busy − wait) is idle.
    pub wait_frac: f64,
    /// Steps per critical-path binding resource
    /// (`trace::critpath::BoundBy` name → step count).
    pub bound_hist: std::collections::BTreeMap<String, usize>,
    /// Sync-wait share of communication energy across all steps.
    pub sync_share: f64,
    /// Peak reserved KV bytes observed.
    pub peak_kv_bytes: f64,
    /// The budget admission was gated on.
    pub kv_budget_bytes: f64,
}

impl ServeResult {
    /// Served (non-rejected) request records.
    pub fn served(&self) -> impl Iterator<Item = &RequestRecord> {
        self.requests.iter().filter(|r| !r.rejected)
    }

    /// Percentile of attributed per-request energy over served requests.
    pub fn energy_percentile_j(&self, p: f64) -> f64 {
        let xs: Vec<f64> = self.served().map(|r| r.energy_j).collect();
        percentile(&xs, p)
    }

    /// Mean energy per generated token over served requests, J.
    pub fn energy_per_token_j(&self) -> f64 {
        let tokens: usize = self.served().map(|r| r.output_tokens).sum();
        let energy: f64 = self.served().map(|r| r.energy_j).sum();
        energy / tokens.max(1) as f64
    }
}

/// In-flight request state.
#[derive(Debug)]
struct Active {
    req: Request,
    admit_s: f64,
    first_token_s: f64,
    generated: usize,
    energy_j: f64,
    sync_j: f64,
    decode_steps: usize,
}

/// One replica's serving loop, exposed one scheduling round at a time.
///
/// `serve` is now a thin wrapper — enqueue the whole trace, [`Session::drain`],
/// [`Session::finish`] — and stays bit-identical to the original closed
/// loop. The incremental surface exists for callers that interleave many
/// replicas (the fleet simulator): each replica advances its own serving
/// clock independently via [`Session::advance_to`] while new requests are
/// routed in between rounds, and same-mesh replicas can share one
/// `Arc<StepLowerer>` so plan structures lower once per mesh topology.
#[derive(Debug)]
pub struct Session {
    cfg: ServeConfig,
    hw: HwSpec,
    lowerer: Arc<StepLowerer>,
    batcher: Batcher,
    /// Routed, not yet pulled into the batcher (nondecreasing arrival).
    arrivals: VecDeque<Request>,
    active: Vec<Active>,
    records: Vec<RequestRecord>,
    steps: Vec<StepRecord>,
    clock: f64,
    step_idx: u64,
    peak_kv: f64,
    occupancy_sum: f64,
    kv_budget: f64,
    total_step_j: f64,
    generated_tokens: usize,
    /// Speculatively executed record for the predicted next step
    /// (`predict_step` / `prefetch_shared_steps`); consumed by `sim_step`
    /// when the (shape, index) still match.
    prepared: Option<(StepShape, u64, RunRecord)>,
}

impl Session {
    /// Open a session with its own step lowerer. Panics if the model does
    /// not fit the deployment (same gate as the workload grids).
    pub fn new(cfg: &ServeConfig, hw: &HwSpec, knobs: &SimKnobs) -> Session {
        let lowerer = Arc::new(StepLowerer::new(&cfg.model, cfg.parallelism, cfg.gpus, hw.clone(), knobs));
        Session::with_lowerer(cfg, hw, lowerer)
    }

    /// Open a session over a shared, pre-built step lowerer. The lowerer
    /// must have been built for the same model / parallelism / GPU count
    /// as `cfg` on the same `hw` (the fleet keys its lowerer map on
    /// exactly that tuple).
    pub fn with_lowerer(cfg: &ServeConfig, hw: &HwSpec, lowerer: Arc<StepLowerer>) -> Session {
        let spec = models::by_name(&cfg.model).unwrap_or_else(|| panic!("unknown model {}", cfg.model));
        assert!(
            workload::runnable(&spec, cfg.parallelism, cfg.gpus, hw),
            "{} does not fit {} on {} GPUs",
            cfg.model,
            cfg.parallelism.label(),
            cfg.gpus
        );
        let kv_per_token = kv_bytes_per_token(&spec);
        let budget = kv_budget_bytes(&spec, cfg.parallelism, cfg.gpus, hw);
        let batcher = Batcher::new(
            BatcherCfg {
                policy: cfg.policy,
                max_batch_requests: cfg.max_batch_requests,
                max_batch_tokens: cfg.max_batch_tokens,
                kv_budget_bytes: budget,
            },
            kv_per_token,
        );
        Session {
            cfg: cfg.clone(),
            hw: hw.clone(),
            lowerer,
            batcher,
            arrivals: VecDeque::new(),
            active: Vec::new(),
            records: Vec::new(),
            steps: Vec::new(),
            clock: 0.0,
            step_idx: 0,
            peak_kv: 0.0,
            occupancy_sum: 0.0,
            kv_budget: budget,
            total_step_j: 0.0,
            generated_tokens: 0,
            prepared: None,
        }
    }

    /// Hand the session a routed request. Requests must arrive in
    /// nondecreasing `arrival_s` order (traces and routers both do).
    pub fn enqueue(&mut self, req: Request) {
        debug_assert!(
            self.arrivals.back().map(|b| b.arrival_s <= req.arrival_s).unwrap_or(true),
            "requests must be enqueued in arrival order"
        );
        self.arrivals.push_back(req);
    }

    /// Serving-clock time, s.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Requests routed here and not yet finished (queued + resident).
    pub fn in_flight(&self) -> usize {
        self.arrivals.len() + self.batcher.pending() + self.active.len()
    }

    /// Nothing queued, pending, or resident.
    pub fn is_idle(&self) -> bool {
        self.arrivals.is_empty() && self.batcher.pending() == 0 && self.active.is_empty()
    }

    /// Σ step energy so far, J (wall energy of every executed step).
    pub fn energy_so_far_j(&self) -> f64 {
        self.total_step_j
    }

    /// Observed energy per generated token so far, J — the signal the
    /// fleet's energy-aware router balances on. Zero until the first step.
    pub fn j_per_token_so_far(&self) -> f64 {
        self.total_step_j / self.generated_tokens.max(1) as f64
    }

    /// The shared step lowerer (for cache-stats aggregation).
    pub fn lowerer(&self) -> &Arc<StepLowerer> {
        &self.lowerer
    }

    /// Jump an idle session's clock forward (cold-start readiness: a
    /// freshly started replica cannot schedule before `t`).
    pub fn skip_to(&mut self, t: f64) {
        debug_assert!(self.active.is_empty() && self.batcher.pending() == 0, "skip_to on a busy session");
        self.clock = self.clock.max(t);
    }

    /// Shape of the decode iteration the resident batch would run next.
    fn decode_shape(&self) -> StepShape {
        let contexts: Vec<f64> = self
            .active
            .iter()
            .map(|a| (a.req.prompt_tokens + a.generated) as f64)
            .collect();
        let mean_ctx = (contexts.iter().sum::<f64>() / contexts.len() as f64).ceil() as usize;
        StepShape {
            kind: StepKind::Decode,
            batch: self.active.len(),
            tokens: bucket_tokens(mean_ctx.max(1), self.cfg.ctx_bucket),
        }
    }

    /// The exact (shape, step index) of the next engine step this session
    /// would execute, when that is predictable without running the
    /// scheduler: a resident decode iteration with nothing pending and no
    /// arrival due at the current clock. The fleet layer uses this to
    /// co-schedule coinciding replica steps as one batched engine walk
    /// (`prefetch_shared_steps`).
    pub fn predict_step(&self) -> Option<(StepShape, u64)> {
        let arrival_due = self
            .arrivals
            .front()
            .map(|r| r.arrival_s <= self.clock)
            .unwrap_or(false);
        if self.active.is_empty() || self.batcher.pending() != 0 || arrival_due {
            return None;
        }
        Some((self.decode_shape(), self.step_idx))
    }

    fn sim_step(&mut self, shape: &StepShape, idx: u64) -> RunRecord {
        // A stashed speculative record is bit-identical to the serial
        // simulation below (batched lanes keep their own seed streams), so
        // consuming it changes nothing but wall time.
        if let Some((s, i, rec)) = self.prepared.take() {
            if s == *shape && i == idx {
                return rec;
            }
        }
        self.lowerer.note_serial_fallback();
        let plan = self.lowerer.step_plan(shape);
        let scfg = self.lowerer.step_config(shape, self.cfg.base_seed ^ (idx + 1));
        simulate_run_planned(&scfg, &self.hw, self.lowerer.knobs(), &plan)
    }

    /// Move finished requests out of the resident batch.
    fn retire(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated >= self.active[i].req.output_tokens {
                let a = self.active.swap_remove(i);
                self.batcher.release(&a.req);
                self.records.push(RequestRecord {
                    id: a.req.id,
                    prompt_tokens: a.req.prompt_tokens,
                    output_tokens: a.req.output_tokens,
                    arrival_s: a.req.arrival_s,
                    admit_s: a.admit_s,
                    first_token_s: a.first_token_s,
                    finish_s: self.clock,
                    energy_j: a.energy_j,
                    sync_energy_j: a.sync_j,
                    decode_steps: a.decode_steps,
                    rejected: false,
                });
            } else {
                i += 1;
            }
        }
    }

    /// One scheduling round: pull due arrivals, then either jump an idle
    /// clock to the next arrival or run one admission + prefill + decode
    /// boundary. Returns `false` once the session is fully drained.
    pub fn round(&mut self) -> bool {
        // Pull arrivals up to the serving clock into the queue.
        while self.arrivals.front().map(|r| r.arrival_s <= self.clock).unwrap_or(false) {
            let r = self.arrivals.pop_front().expect("checked front");
            self.batcher.enqueue(r);
        }
        if self.active.is_empty() && self.batcher.pending() == 0 {
            match self.arrivals.front() {
                // Idle: jump to the next arrival.
                Some(r) => {
                    self.clock = r.arrival_s;
                    return true;
                }
                None => return false,
            }
        }

        // ---- Admission at the decode boundary. ----
        let admitted = self.batcher.admit();
        if self.active.is_empty() && admitted.is_empty() {
            // Nothing resident and nothing admissible: the policy-first
            // pending request can never fit the budgets — drop it unserved
            // rather than livelock.
            if let Some(r) = self.batcher.reject_head() {
                self.records.push(RequestRecord {
                    id: r.id,
                    prompt_tokens: r.prompt_tokens,
                    output_tokens: r.output_tokens,
                    arrival_s: r.arrival_s,
                    admit_s: self.clock,
                    first_token_s: self.clock,
                    finish_s: self.clock,
                    energy_j: 0.0,
                    sync_energy_j: 0.0,
                    decode_steps: 0,
                    rejected: true,
                });
            }
            return true;
        }
        self.peak_kv = self.peak_kv.max(self.batcher.resident_kv_bytes());

        // ---- Batched prefill over the admitted prompts. Resident decode
        // stalls for its duration (iteration-level scheduling); the step's
        // energy is attributed to the admitted requests it prefills. ----
        if !admitted.is_empty() {
            let admit_s = self.clock;
            let total_prompt: usize = admitted.iter().map(|r| r.prompt_tokens).sum();
            let mean_prompt = total_prompt.div_ceil(admitted.len());
            let shape = StepShape {
                kind: StepKind::Prefill,
                batch: admitted.len(),
                tokens: bucket_tokens(mean_prompt, self.cfg.ctx_bucket),
            };
            let r = self.sim_step(&shape, self.step_idx);
            self.step_idx += 1;
            let weights: Vec<f64> = admitted.iter().map(|q| q.prompt_tokens as f64).collect();
            let shares = split_energy(r.true_total_j, &weights);
            let sync_shares = split_energy(r.sync_wait_j(), &weights);
            self.steps.push(StepRecord {
                kind: StepKind::Prefill,
                t0_s: self.clock,
                dur_s: r.wall_s,
                batch: admitted.len(),
                tokens: shape.tokens,
                energy_j: r.true_total_j,
                sync_j: r.sync_wait_j(),
                transfer_j: r.comm_transfer_j(),
                busy_frac: crate::util::stats::mean(&r.gpu_util),
                wait_frac: r.wait_frac,
                bound_by: r.bound_by.clone(),
            });
            self.clock += r.wall_s;
            self.total_step_j += r.true_total_j;
            self.generated_tokens += admitted.len();
            // Prefill yields each admitted request's first output token.
            for ((q, e), s) in admitted.into_iter().zip(shares).zip(sync_shares) {
                self.active.push(Active {
                    req: q,
                    admit_s,
                    first_token_s: self.clock,
                    generated: 1,
                    energy_j: e,
                    sync_j: s,
                    decode_steps: 0,
                });
            }
            self.retire();
            if self.active.is_empty() {
                return true; // every admitted request wanted a single token
            }
        }

        // ---- One decode iteration for the resident batch. ----
        let contexts: Vec<f64> = self.active.iter().map(|a| (a.req.prompt_tokens + a.generated) as f64).collect();
        let shape = self.decode_shape();
        let r = self.sim_step(&shape, self.step_idx);
        self.step_idx += 1;
        // Token work per request: KV context touched + the generated token.
        let weights: Vec<f64> = contexts.iter().map(|c| c + 1.0).collect();
        let shares = split_energy(r.true_total_j, &weights);
        let sync_shares = split_energy(r.sync_wait_j(), &weights);
        self.steps.push(StepRecord {
            kind: StepKind::Decode,
            t0_s: self.clock,
            dur_s: r.wall_s,
            batch: self.active.len(),
            tokens: shape.tokens,
            energy_j: r.true_total_j,
            sync_j: r.sync_wait_j(),
            transfer_j: r.comm_transfer_j(),
            busy_frac: crate::util::stats::mean(&r.gpu_util),
            wait_frac: r.wait_frac,
            bound_by: r.bound_by.clone(),
        });
        self.clock += r.wall_s;
        self.total_step_j += r.true_total_j;
        self.generated_tokens += self.active.len();
        self.occupancy_sum += self.active.len() as f64;
        for (a, (e, s)) in self.active.iter_mut().zip(shares.into_iter().zip(sync_shares)) {
            a.energy_j += e;
            a.sync_j += s;
            a.generated += 1;
            a.decode_steps += 1;
        }
        self.retire();
        true
    }

    /// Run rounds until the next step would start at or after `t` (a step
    /// in progress finishes — the serving clock only stops at decode
    /// boundaries) or the session drains.
    pub fn advance_to(&mut self, t: f64) {
        while self.clock < t && self.round() {}
    }

    /// Run every remaining round.
    pub fn drain(&mut self) {
        while self.round() {}
    }

    /// Close the session and assemble the replica's `ServeResult`.
    pub fn finish(mut self) -> ServeResult {
        self.records.sort_by_key(|r| r.id);
        let total_energy_j: f64 = self.steps.iter().map(|s| s.energy_j).sum();
        let decode_steps = self.steps.iter().filter(|s| s.kind == StepKind::Decode).count();
        let occupancy = if decode_steps > 0 {
            self.occupancy_sum / decode_steps as f64 / self.cfg.max_batch_requests as f64
        } else {
            0.0
        };
        let sync_j: f64 = self.steps.iter().map(|s| s.sync_j).sum();
        let comm_j: f64 = self.steps.iter().map(|s| s.sync_j + s.transfer_j).sum();
        // Step-duration-weighted occupancy split + binding-resource counts.
        let step_time: f64 = self.steps.iter().map(|s| s.dur_s).sum();
        let (mut busy_frac, mut wait_frac) = (0.0f64, 0.0f64);
        let mut bound_hist: std::collections::BTreeMap<String, usize> = Default::default();
        for st in &self.steps {
            busy_frac += st.busy_frac * st.dur_s;
            wait_frac += st.wait_frac * st.dur_s;
            *bound_hist.entry(st.bound_by.clone()).or_insert(0) += 1;
        }
        if step_time > 0.0 {
            busy_frac /= step_time;
            wait_frac /= step_time;
        }
        ServeResult {
            requests: self.records,
            steps: self.steps,
            makespan_s: self.clock,
            total_energy_j,
            occupancy,
            busy_frac,
            wait_frac,
            bound_hist,
            sync_share: if comm_j > 0.0 { sync_j / comm_j } else { 0.0 },
            peak_kv_bytes: self.peak_kv,
            kv_budget_bytes: self.kv_budget,
        }
    }
}

/// Speculatively execute the predicted next steps of every session still
/// behind `horizon_s`, batching the ones that coincide — same lowerer
/// (mesh) and same step shape — into one engine walk per group
/// (DESIGN.md §14). Each lane keeps its own session's seed stream, so the
/// stashed records the sessions later consume are bit-identical to the
/// serial path; groups of one are left for `sim_step`. Batches are
/// counted on the group's shared lowerer (`StepLowerer::stats`).
pub fn prefetch_shared_steps(sessions: &mut [Session], horizon_s: f64) {
    let mut groups: HashMap<(usize, StepShape), Vec<(usize, u64)>> = HashMap::new();
    for (i, s) in sessions.iter().enumerate() {
        if s.clock < horizon_s && s.prepared.is_none() {
            if let Some((shape, idx)) = s.predict_step() {
                groups
                    .entry((Arc::as_ptr(&s.lowerer) as usize, shape))
                    .or_default()
                    .push((i, idx));
            }
        }
    }
    for ((_, shape), members) in groups {
        if members.len() < 2 {
            continue;
        }
        let mut cfgs = Vec::with_capacity(members.len());
        let mut plans = Vec::with_capacity(members.len());
        for &(i, idx) in &members {
            let s = &sessions[i];
            cfgs.push(s.lowerer.step_config(&shape, s.cfg.base_seed ^ (idx + 1)));
            plans.push(s.lowerer.step_plan(&shape));
        }
        let leader = &sessions[members[0].0];
        let hw = leader.hw.clone();
        let knobs = leader.lowerer.knobs().clone();
        leader.lowerer.note_batch(members.len());
        let records = simulate_run_batch(&cfgs, &hw, &knobs, &plans);
        for ((i, idx), rec) in members.into_iter().zip(records) {
            sessions[i].prepared = Some((shape.clone(), idx, rec));
        }
    }
}

/// Replay `trace` under the serving configuration. Panics if the model
/// does not fit the deployment (same gate as the workload grids).
pub fn serve(trace: &Trace, cfg: &ServeConfig, hw: &HwSpec, knobs: &SimKnobs) -> ServeResult {
    let mut session = Session::new(cfg, hw, knobs);
    for r in &trace.requests {
        session.enqueue(r.clone());
    }
    session.drain();
    session.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Strategy;

    fn tiny_trace(seed: u64) -> Trace {
        synthesize(
            &SynthSpec {
                requests: 6,
                rate_rps: 4.0,
                prompt_mean: 32.0,
                prompt_range: (8, 64),
                output_mean: 4.0,
                output_range: (2, 8),
                ..SynthSpec::default()
            },
            seed,
        )
    }

    fn tiny_cfg(par: Parallelism, gpus: usize) -> ServeConfig {
        ServeConfig {
            max_batch_requests: 4,
            ..ServeConfig::new("Vicuna-7B", par, gpus)
        }
    }

    #[test]
    fn per_request_energy_conserves_batch_energy() {
        let trace = tiny_trace(1);
        let res = serve(&trace, &tiny_cfg(Parallelism::Tensor, 2), &HwSpec::default(), &SimKnobs::default());
        assert_eq!(res.requests.len(), trace.len());
        let req_j: f64 = res.requests.iter().map(|r| r.energy_j).sum();
        let rel = (req_j - res.total_energy_j).abs() / res.total_energy_j;
        assert!(rel < 1e-9, "Σreq {req_j} vs Σstep {} (rel {rel})", res.total_energy_j);
        assert!(res.total_energy_j > 0.0);
    }

    #[test]
    fn serving_is_deterministic_under_a_seed() {
        let trace = tiny_trace(2);
        let cfg = tiny_cfg(Parallelism::Tensor, 2);
        let a = serve(&trace, &cfg, &HwSpec::default(), &SimKnobs::default());
        let b = serve(&trace, &cfg, &HwSpec::default(), &SimKnobs::default());
        assert_eq!(a.requests, b.requests, "bit-identical per-request records");
        assert_eq!(a.total_energy_j, b.total_energy_j);
        assert_eq!(a.makespan_s, b.makespan_s);
        // A different seed changes the energies (stochastic substrate).
        let c = serve(&trace, &ServeConfig { base_seed: 99, ..cfg }, &HwSpec::default(), &SimKnobs::default());
        assert_ne!(a.total_energy_j, c.total_energy_j);
    }

    #[test]
    fn request_timestamps_are_ordered_and_budgets_hold() {
        let trace = tiny_trace(3);
        let res = serve(&trace, &tiny_cfg(Parallelism::Tensor, 2), &HwSpec::default(), &SimKnobs::default());
        for r in res.served() {
            assert!(r.arrival_s <= r.admit_s, "{}", r.id);
            assert!(r.admit_s < r.first_token_s, "{}", r.id);
            assert!(r.first_token_s <= r.finish_s, "{}", r.id);
            assert!(r.energy_j > 0.0);
            assert_eq!(r.decode_steps, r.output_tokens - 1, "{}", r.id);
        }
        assert!(res.peak_kv_bytes <= res.kv_budget_bytes);
        assert!(res.occupancy > 0.0 && res.occupancy <= 1.0);
        assert!(res.sync_share > 0.0 && res.sync_share < 1.0);
        assert!(res.makespan_s > 0.0);
        // Occupancy split: busy and wait are both real on a TP deployment
        // and leave room for idle (they never exceed the step).
        assert!(res.busy_frac > 0.0 && res.busy_frac <= 1.0);
        assert!(res.wait_frac > 0.0, "TP collectives must show wait time");
        assert!(res.busy_frac + res.wait_frac <= 1.0 + 1e-9);
        // Every step lands in the binding-resource histogram.
        let counted: usize = res.bound_hist.values().sum();
        assert_eq!(counted, res.steps.len());
        for b in res.bound_hist.keys() {
            assert!(crate::trace::critpath::BoundBy::parse(b).is_some(), "{b}");
        }
    }

    #[test]
    fn oversized_request_is_rejected_not_livelocked() {
        let mut reqs = tiny_trace(4).requests;
        reqs.push(Request {
            id: 99,
            arrival_s: 0.0,
            prompt_tokens: 1 << 20, // can never fit max_batch_tokens
            output_tokens: 4,
            session: None,
        });
        let trace = Trace::new(reqs);
        let res = serve(&trace, &tiny_cfg(Parallelism::Tensor, 2), &HwSpec::default(), &SimKnobs::default());
        let rejected: Vec<u32> = res.requests.iter().filter(|r| r.rejected).map(|r| r.id).collect();
        assert_eq!(rejected, vec![99]);
        assert_eq!(res.served().count(), trace.len() - 1);
        // Rejection carries no energy; conservation still holds.
        let req_j: f64 = res.requests.iter().map(|r| r.energy_j).sum();
        assert!((req_j - res.total_energy_j).abs() / res.total_energy_j < 1e-9);
    }

    #[test]
    fn policies_change_admission_order_under_contention() {
        // Arrivals all at t=0 with contrasting prompt lengths and a
        // one-request batch: FCFS serves by arrival, SPF by prompt length.
        let reqs: Vec<Request> = [(0u32, 60usize), (1, 10), (2, 30)]
            .into_iter()
            .map(|(id, prompt)| Request {
                id,
                arrival_s: 0.0,
                prompt_tokens: prompt,
                output_tokens: 2,
                session: None,
            })
            .collect();
        let trace = Trace::new(reqs);
        let base = ServeConfig {
            max_batch_requests: 1,
            ..ServeConfig::new("Vicuna-7B", Parallelism::Tensor, 2)
        };
        let order = |policy: Policy| -> Vec<u32> {
            let cfg = ServeConfig { policy, ..base.clone() };
            let mut done: Vec<(f64, u32)> = serve(&trace, &cfg, &HwSpec::default(), &SimKnobs::default())
                .requests
                .iter()
                .map(|r| (r.finish_s, r.id))
                .collect();
            done.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            done.into_iter().map(|(_, id)| id).collect()
        };
        assert_eq!(order(Policy::Fcfs), vec![0, 1, 2]);
        assert_eq!(order(Policy::ShortestPromptFirst), vec![1, 2, 0]);
    }

    #[test]
    fn hybrid_mesh_serves_with_comm_isolation() {
        let par = Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap();
        let trace = tiny_trace(5);
        let res = serve(&trace, &tiny_cfg(par, 4), &HwSpec::default(), &SimKnobs::default());
        let req_j: f64 = res.requests.iter().map(|r| r.energy_j).sum();
        assert!((req_j - res.total_energy_j).abs() / res.total_energy_j < 1e-9);
        // The TP axis jitters collectives; sync energy reaches requests.
        assert!(res.requests.iter().any(|r| r.sync_energy_j > 0.0));
    }
}
