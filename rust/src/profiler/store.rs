//! Dataset and model persistence.
//!
//! The paper's released artifact separates (i) offline profiling from
//! (iii) training/evaluation; this module provides the same workflow:
//! `piep profile --save runs.json` writes a campaign to disk and
//! `piep train --dataset runs.json` / `piep predict --model-file m.json`
//! consume it without re-simulating. Everything serializes through the
//! in-repo JSON layer (no serde on the offline image).

use std::collections::BTreeMap;

use crate::config::{Parallelism, RunConfig};
use crate::features::SyncDb;
use crate::models;
use crate::predict::{Combiner, PieP, PiepOptions, Ridge};
use crate::simulator::timeline::ModuleKind;
use crate::simulator::RunRecord;
use crate::tree::{Leaf, LeafPart};
use crate::util::json::{arr, num, obj, s, Json};

fn vecf(xs: &[f64]) -> Json {
    arr(xs.iter().map(|&x| num(x)).collect())
}

fn getf(j: &Json, k: &str) -> Result<f64, String> {
    j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing {k}"))
}

fn getv(j: &Json, k: &str) -> Result<Vec<f64>, String> {
    Ok(j.get(k)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("missing {k}"))?
        .iter()
        .filter_map(Json::as_f64)
        .collect())
}

fn module_key(m: ModuleKind) -> &'static str {
    match m {
        ModuleKind::Embedding => "embedding",
        ModuleKind::Norm => "norm",
        ModuleKind::SelfAttention => "self_attention",
        ModuleKind::Mlp => "mlp",
        ModuleKind::LogitsHead => "logits_head",
        ModuleKind::AllReduce => "allreduce",
        ModuleKind::P2PTransfer => "p2p",
        ModuleKind::AllGather => "allgather",
        ModuleKind::AllToAll => "alltoall",
    }
}

fn module_from_key(k: &str) -> Option<ModuleKind> {
    ModuleKind::ALL.into_iter().find(|m| module_key(*m) == k)
}

fn part_key(p: LeafPart) -> &'static str {
    match p {
        LeafPart::Compute => "compute",
        LeafPart::Sync => "sync",
        LeafPart::Transfer => "transfer",
    }
}

fn part_from_key(k: &str) -> Option<LeafPart> {
    match k {
        "compute" => Some(LeafPart::Compute),
        "sync" => Some(LeafPart::Sync),
        "transfer" => Some(LeafPart::Transfer),
        _ => None,
    }
}

/// Serialize one run record.
pub fn run_to_json(r: &RunRecord) -> Json {
    let modules: Vec<Json> = r
        .module_energy_j
        .iter()
        .map(|(k, &e)| {
            obj(vec![
                ("kind", s(module_key(*k))),
                ("energy_j", num(e)),
                ("time_s", num(r.module_time_s.get(k).copied().unwrap_or(0.0))),
            ])
        })
        .collect();
    obj(vec![
        ("model", s(&r.config.model)),
        ("parallelism", s(&r.config.parallelism.label())),
        ("gpus", num(r.config.gpus as f64)),
        ("batch", num(r.config.batch as f64)),
        ("seq_in", num(r.config.seq_in as f64)),
        ("seq_out", num(r.config.seq_out as f64)),
        ("seed", num(r.config.seed as f64)),
        ("wall_s", num(r.wall_s)),
        ("prefill_s", num(r.prefill_s)),
        ("decode_s", num(r.decode_s)),
        ("tokens_out", num(r.tokens_out as f64)),
        ("true_total_j", num(r.true_total_j)),
        ("gpu_energy_j", num(r.gpu_energy_j)),
        ("host_energy_j", num(r.host_energy_j)),
        ("meter_total_j", num(r.meter_total_j)),
        ("nvml_gpu_j", vecf(&r.nvml_gpu_j)),
        ("nvml_total_j", num(r.nvml_total_j)),
        ("modules", Json::Arr(modules)),
        (
            "comm_splits",
            Json::Arr(
                r.comm_split_j
                    .iter()
                    .map(|(k, &(w, x))| {
                        obj(vec![
                            ("kind", s(module_key(*k))),
                            ("wait_j", num(w)),
                            ("xfer_j", num(x)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("unattributed_j", num(r.unattributed_j)),
        ("gpu_util", vecf(&r.gpu_util)),
        ("wait_frac", num(r.wait_frac)),
        ("gpu_mem_util", vecf(&r.gpu_mem_util)),
        ("gpu_clock", vecf(&r.gpu_clock_ghz)),
        ("gpu_mem_clock", vecf(&r.gpu_mem_clock_ghz)),
        ("cpu_util_pct", num(r.cpu_util_pct)),
        ("cpu_mem_util_pct", num(r.cpu_mem_util_pct)),
        ("cpu_clock", num(r.cpu_clock_ghz)),
        ("cpu_mem_clock", num(r.cpu_mem_clock_ghz)),
        ("mem_bytes", num(r.mem_bytes)),
        ("wait_samples", vecf(&r.wait_samples)),
        ("comm_bytes_per_step", num(r.comm_bytes_per_step)),
        ("host_activity", num(r.host_activity)),
        ("nodes", num(r.nodes as f64)),
        ("tier_bw_ratio", num(r.tier_bw_ratio)),
        ("crit_share_j", num(r.crit_share_j)),
        ("bound_by", s(&r.bound_by)),
    ])
}

/// Deserialize one run record.
pub fn run_from_json(j: &Json) -> Result<RunRecord, String> {
    let model = j.get("model").and_then(Json::as_str).ok_or("model")?.to_string();
    let spec = models::by_name(&model).ok_or_else(|| format!("unknown model {model}"))?;
    let parallelism = Parallelism::parse(j.get("parallelism").and_then(Json::as_str).ok_or("parallelism")?)
        .ok_or("bad parallelism")?;
    let config = RunConfig {
        model,
        parallelism,
        gpus: getf(j, "gpus")? as usize,
        batch: getf(j, "batch")? as usize,
        seq_in: getf(j, "seq_in")? as usize,
        seq_out: getf(j, "seq_out")? as usize,
        seed: getf(j, "seed")? as u64,
    };
    let mut module_energy_j = BTreeMap::new();
    let mut module_time_s = BTreeMap::new();
    for m in j.get("modules").and_then(Json::as_arr).ok_or("modules")? {
        let kind = module_from_key(m.get("kind").and_then(Json::as_str).ok_or("kind")?)
            .ok_or("bad module kind")?;
        module_energy_j.insert(kind, getf(m, "energy_j")?);
        module_time_s.insert(kind, getf(m, "time_s")?);
    }
    let mut comm_split_j = BTreeMap::new();
    for cs in j.get("comm_splits").and_then(Json::as_arr).ok_or("comm_splits")? {
        let kind = module_from_key(cs.get("kind").and_then(Json::as_str).ok_or("kind")?)
            .ok_or("bad comm kind")?;
        comm_split_j.insert(kind, (getf(cs, "wait_j")?, getf(cs, "xfer_j")?));
    }
    let wait_samples = getv(j, "wait_samples")?;
    let (wm, ws, wx) = (
        crate::util::stats::mean(&wait_samples),
        crate::util::stats::std_dev(&wait_samples),
        if wait_samples.is_empty() { 0.0 } else { crate::util::stats::max(&wait_samples) },
    );
    Ok(RunRecord {
        config,
        spec,
        wall_s: getf(j, "wall_s")?,
        prefill_s: getf(j, "prefill_s")?,
        decode_s: getf(j, "decode_s")?,
        tokens_out: getf(j, "tokens_out")? as usize,
        true_total_j: getf(j, "true_total_j")?,
        gpu_energy_j: getf(j, "gpu_energy_j")?,
        host_energy_j: getf(j, "host_energy_j")?,
        module_energy_j,
        module_time_s,
        comm_split_j,
        unattributed_j: getf(j, "unattributed_j")?,
        meter_total_j: getf(j, "meter_total_j")?,
        nvml_gpu_j: getv(j, "nvml_gpu_j")?,
        nvml_total_j: getf(j, "nvml_total_j")?,
        gpu_util: getv(j, "gpu_util")?,
        // v3: occupancy wait share (pre-v3 records folded wait into idle).
        wait_frac: j.get("wait_frac").and_then(Json::as_f64).unwrap_or(0.0),
        gpu_mem_util: getv(j, "gpu_mem_util")?,
        gpu_clock_ghz: getv(j, "gpu_clock")?,
        gpu_mem_clock_ghz: getv(j, "gpu_mem_clock")?,
        cpu_util_pct: getf(j, "cpu_util_pct")?,
        cpu_mem_util_pct: getf(j, "cpu_mem_util_pct")?,
        cpu_clock_ghz: getf(j, "cpu_clock")?,
        cpu_mem_clock_ghz: getf(j, "cpu_mem_clock")?,
        mem_bytes: getf(j, "mem_bytes")?,
        wait_samples,
        wait_mean_s: wm,
        wait_std_s: ws,
        wait_max_s: wx,
        comm_bytes_per_step: getf(j, "comm_bytes_per_step")?,
        host_activity: getf(j, "host_activity")?,
        // Topology descriptors: absent in pre-topology datasets, which were
        // all single-node single-tier.
        nodes: j.get("nodes").and_then(Json::as_f64).unwrap_or(1.0) as usize,
        tier_bw_ratio: j.get("tier_bw_ratio").and_then(Json::as_f64).unwrap_or(1.0),
        // Critical-path attribution: absent in pre-v3 datasets (no
        // critpath pass had run); zero share marks "unknown".
        crit_share_j: j.get("crit_share_j").and_then(Json::as_f64).unwrap_or(0.0),
        bound_by: j
            .get("bound_by")
            .and_then(Json::as_str)
            .unwrap_or("compute")
            .to_string(),
    })
}

/// Save a profiled dataset (runs; the sync DB is rebuilt on load).
pub fn save_dataset(runs: &[RunRecord], path: &str) -> std::io::Result<()> {
    let j = obj(vec![
        // v4: expert-parallel runs with "alltoall" module rows (v3 added
        // critical-path attribution, v2 phase-resolved comm splits +
        // unattributed residual).
        ("format", s("piep-dataset-v4")),
        ("runs", Json::Arr(runs.iter().map(run_to_json).collect())),
    ]);
    std::fs::write(path, j.render())
}

/// Load a dataset saved by `save_dataset`.
pub fn load_dataset(path: &str) -> Result<super::Dataset, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let j = Json::parse(&text)?;
    // Older lineages load with their missing fields defaulted: v2 files
    // predate critical-path attribution, v3 files simply contain no
    // expert-parallel runs.
    if !matches!(
        j.get("format").and_then(Json::as_str),
        Some("piep-dataset-v2") | Some("piep-dataset-v3") | Some("piep-dataset-v4")
    ) {
        return Err("not a piep dataset file (expected piep-dataset-v2/v3/v4)".into());
    }
    let runs: Result<Vec<RunRecord>, String> = j
        .get("runs")
        .and_then(Json::as_arr)
        .ok_or("runs")?
        .iter()
        .map(run_from_json)
        .collect();
    let runs = runs?;
    let sync_db = SyncDb::build(&runs);
    Ok(super::Dataset {
        runs,
        sync_db,
        cache: Default::default(),
    })
}

/// Serialize one served-request record (serving store, schema v3).
pub fn serve_record_to_json(r: &crate::serve::RequestRecord) -> Json {
    obj(vec![
        ("id", num(r.id as f64)),
        ("prompt_tokens", num(r.prompt_tokens as f64)),
        ("output_tokens", num(r.output_tokens as f64)),
        ("arrival_s", num(r.arrival_s)),
        ("admit_s", num(r.admit_s)),
        ("first_token_s", num(r.first_token_s)),
        ("finish_s", num(r.finish_s)),
        ("energy_j", num(r.energy_j)),
        ("sync_energy_j", num(r.sync_energy_j)),
        ("decode_steps", num(r.decode_steps as f64)),
        ("rejected", Json::Bool(r.rejected)),
    ])
}

/// Deserialize one served-request record.
pub fn serve_record_from_json(j: &Json) -> Result<crate::serve::RequestRecord, String> {
    Ok(crate::serve::RequestRecord {
        id: getf(j, "id")? as u32,
        prompt_tokens: getf(j, "prompt_tokens")? as usize,
        output_tokens: getf(j, "output_tokens")? as usize,
        arrival_s: getf(j, "arrival_s")?,
        admit_s: getf(j, "admit_s")?,
        first_token_s: getf(j, "first_token_s")?,
        finish_s: getf(j, "finish_s")?,
        energy_j: getf(j, "energy_j")?,
        sync_energy_j: getf(j, "sync_energy_j")?,
        decode_steps: getf(j, "decode_steps")? as usize,
        rejected: matches!(j.get("rejected"), Some(Json::Bool(true))),
    })
}

/// Save per-request serving records (the serving layer's dataset: v3 of
/// the store lineage — v1 runs, v2 phase-resolved splits, v3 per-request
/// serving attribution).
pub fn save_serve_records(records: &[crate::serve::RequestRecord], path: &str) -> std::io::Result<()> {
    let j = obj(vec![
        ("format", s("piep-serve-v3")),
        ("requests", Json::Arr(records.iter().map(serve_record_to_json).collect())),
    ]);
    std::fs::write(path, j.render())
}

/// Load records saved by `save_serve_records`.
pub fn load_serve_records(path: &str) -> Result<Vec<crate::serve::RequestRecord>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let j = Json::parse(&text)?;
    if j.get("format").and_then(Json::as_str) != Some("piep-serve-v3") {
        return Err("not a piep serving file (expected piep-serve-v3)".into());
    }
    j.get("requests")
        .and_then(Json::as_arr)
        .ok_or("requests")?
        .iter()
        .map(serve_record_from_json)
        .collect()
}

/// Save routed per-request fleet records (v4 of the store lineage: the
/// serving record plus the replica that served each request).
pub fn save_fleet_records(records: &[crate::fleet::FleetRequest], path: &str) -> std::io::Result<()> {
    let reqs: Vec<Json> = records
        .iter()
        .map(|fr| {
            let mut fields = match serve_record_to_json(&fr.record) {
                Json::Obj(fields) => fields,
                _ => unreachable!("serve records serialize to objects"),
            };
            fields.insert("replica".into(), num(fr.replica as f64));
            Json::Obj(fields)
        })
        .collect();
    let j = obj(vec![
        ("format", s("piep-fleet-v4")),
        ("requests", Json::Arr(reqs)),
    ]);
    std::fs::write(path, j.render())
}

/// Load records saved by `save_fleet_records`.
pub fn load_fleet_records(path: &str) -> Result<Vec<crate::fleet::FleetRequest>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let j = Json::parse(&text)?;
    if j.get("format").and_then(Json::as_str) != Some("piep-fleet-v4") {
        return Err("not a piep fleet file (expected piep-fleet-v4)".into());
    }
    j.get("requests")
        .and_then(Json::as_arr)
        .ok_or("requests")?
        .iter()
        .map(|r| {
            Ok(crate::fleet::FleetRequest {
                replica: getf(r, "replica")? as usize,
                record: serve_record_from_json(r)?,
            })
        })
        .collect()
}

fn ridge_to_json(r: &Ridge) -> Json {
    obj(vec![
        ("w", vecf(&r.w)),
        ("b", num(r.b)),
        ("x_mean", vecf(&r.x_mean)),
        ("x_std", vecf(&r.x_std)),
        ("log_target", Json::Bool(r.log_target)),
        ("lambda", num(r.lambda)),
    ])
}

fn ridge_from_json(j: &Json) -> Result<Ridge, String> {
    Ok(Ridge {
        w: getv(j, "w")?,
        b: getf(j, "b")?,
        x_mean: getv(j, "x_mean")?,
        x_std: getv(j, "x_std")?,
        log_target: matches!(j.get("log_target"), Some(Json::Bool(true))),
        lambda: getf(j, "lambda")?,
    })
}

/// Save a fitted PIE-P model.
pub fn save_model(m: &PieP, path: &str) -> std::io::Result<()> {
    let leaves: Vec<Json> = m
        .leaf
        .iter()
        .map(|(l, r)| {
            obj(vec![
                ("kind", s(module_key(l.kind))),
                ("part", s(part_key(l.part))),
                ("ridge", ridge_to_json(r)),
            ])
        })
        .collect();
    let j = obj(vec![
        // v2: leaves keyed by (module kind, execution part).
        ("format", s("piep-model-v2")),
        ("include_comm", Json::Bool(m.opts.include_comm)),
        ("use_wait", Json::Bool(m.opts.use_wait)),
        ("use_struct", Json::Bool(m.opts.use_struct)),
        ("tau", num(m.combiner.tau)),
        ("leaves", Json::Arr(leaves)),
        (
            "combiner",
            obj(vec![
                ("w", vecf(&m.combiner.w)),
                ("b", num(m.combiner.b)),
                ("x_mean", vecf(&m.combiner.x_mean)),
                ("x_std", vecf(&m.combiner.x_std)),
            ]),
        ),
    ]);
    std::fs::write(path, j.render())
}

/// Load a fitted PIE-P model.
pub fn load_model(path: &str) -> Result<PieP, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let j = Json::parse(&text)?;
    if j.get("format").and_then(Json::as_str) != Some("piep-model-v2") {
        return Err("not a piep model file (expected piep-model-v2)".into());
    }
    let mut leaf = BTreeMap::new();
    for l in j.get("leaves").and_then(Json::as_arr).ok_or("leaves")? {
        let kind = module_from_key(l.get("kind").and_then(Json::as_str).ok_or("kind")?)
            .ok_or("bad kind")?;
        let part = part_from_key(l.get("part").and_then(Json::as_str).ok_or("part")?)
            .ok_or("bad part")?;
        leaf.insert(Leaf { kind, part }, ridge_from_json(l.get("ridge").ok_or("ridge")?)?);
    }
    let cj = j.get("combiner").ok_or("combiner")?;
    let combiner = Combiner {
        w: getv(cj, "w")?,
        b: getf(cj, "b")?,
        tau: getf(&j, "tau")?,
        x_mean: getv(cj, "x_mean")?,
        x_std: getv(cj, "x_std")?,
    };
    let opts = PiepOptions {
        include_comm: matches!(j.get("include_comm"), Some(Json::Bool(true))),
        use_wait: matches!(j.get("use_wait"), Some(Json::Bool(true))),
        use_struct: matches!(j.get("use_struct"), Some(Json::Bool(true))),
        ..PiepOptions::default()
    };
    Ok(PieP {
        opts,
        leaf,
        combiner,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwSpec, SimKnobs};
    use crate::predict::PiepOptions;
    use crate::profiler::Campaign;

    fn tiny_dataset() -> crate::profiler::Dataset {
        let c = Campaign {
            passes: 3,
            knobs: SimKnobs {
                sim_decode_steps: 4,
                ..SimKnobs::default()
            },
            ..Campaign::default()
        };
        let hybrid = Parallelism::hybrid(
            crate::config::Strategy::Tensor,
            crate::config::Strategy::Pipeline,
            2,
        )
        .unwrap();
        c.profile(&[
            RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8),
            RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 16),
            // Hybrid config: exercises the label()/parse() roundtrip.
            RunConfig::new("Vicuna-7B", hybrid, 4, 8),
            // Expert config: exercises the "ep" label roundtrip and the
            // "alltoall" module rows (schema v4).
            RunConfig::new("Vicuna-7B", Parallelism::expert(2), 2, 8),
        ])
    }

    #[test]
    fn dataset_roundtrip_preserves_everything_relevant() {
        let ds = tiny_dataset();
        let path = "target/test-store-dataset.json";
        save_dataset(&ds.runs, path).unwrap();
        let loaded = load_dataset(path).unwrap();
        assert_eq!(loaded.runs.len(), ds.runs.len());
        for (a, b) in ds.runs.iter().zip(&loaded.runs) {
            assert_eq!(a.config.key(), b.config.key());
            assert!((a.meter_total_j - b.meter_total_j).abs() < 1e-9);
            assert!((a.true_total_j - b.true_total_j).abs() < 1e-9);
            assert_eq!(a.module_energy_j.len(), b.module_energy_j.len());
            assert_eq!(a.comm_split_j, b.comm_split_j);
            assert!((a.unattributed_j - b.unattributed_j).abs() < 1e-9);
            assert_eq!(a.wait_samples.len(), b.wait_samples.len());
            assert_eq!(a.gpu_util, b.gpu_util);
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.tier_bw_ratio, b.tier_bw_ratio);
            assert!((a.crit_share_j - b.crit_share_j).abs() < 1e-9);
            assert_eq!(a.bound_by, b.bound_by);
            assert!((a.wait_frac - b.wait_frac).abs() < 1e-12);
        }
        // Sync DB rebuilt identically.
        assert_eq!(loaded.sync_db.groups(), ds.sync_db.groups());
    }

    #[test]
    fn model_roundtrip_predicts_identically() {
        let ds = tiny_dataset();
        let m = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        let path = "target/test-store-model.json";
        save_model(&m, path).unwrap();
        let loaded = load_model(path).unwrap();
        for r in &ds.runs {
            let a = m.predict_total(r, &ds.sync_db);
            let b = loaded.predict_total(r, &ds.sync_db);
            assert!((a - b).abs() / a.abs().max(1e-9) < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn load_rejects_wrong_format() {
        let path = "target/test-store-bad.json";
        std::fs::write(path, "{\"format\":\"nope\"}").unwrap();
        assert!(load_dataset(path).is_err());
        assert!(load_model(path).is_err());
        assert!(load_serve_records(path).is_err());
    }

    #[test]
    fn serve_records_roundtrip_exactly() {
        use crate::serve::{serve, synthesize, ServeConfig, SynthSpec};
        let trace = synthesize(
            &SynthSpec {
                requests: 4,
                prompt_range: (8, 32),
                output_range: (2, 4),
                ..SynthSpec::default()
            },
            5,
        );
        let cfg = ServeConfig::new("Vicuna-7B", Parallelism::Tensor, 2);
        let res = serve(&trace, &cfg, &HwSpec::default(), &SimKnobs::default());
        let path = "target/test-store-serve.json";
        save_serve_records(&res.requests, path).unwrap();
        let loaded = load_serve_records(path).unwrap();
        // Schema v3 roundtrips the per-request records bit-for-bit.
        assert_eq!(res.requests, loaded);
    }

    #[test]
    fn fleet_records_roundtrip_with_replica_attribution() {
        use crate::config::TestbedSpec;
        use crate::fleet::{simulate_fleet, FleetConfig, ReplicaSpec};
        use crate::serve::{synthesize, ServeConfig, SynthSpec};
        let trace = synthesize(
            &SynthSpec {
                requests: 4,
                prompt_range: (8, 32),
                output_range: (2, 4),
                ..SynthSpec::default()
            },
            5,
        );
        let spec = ReplicaSpec::new(
            ServeConfig::new("Vicuna-7B", Parallelism::Tensor, 2),
            TestbedSpec::Flat { gpus: 2 },
        );
        let res = simulate_fleet(&trace, &FleetConfig::new(vec![spec; 2]));
        let path = "target/test-store-fleet.json";
        save_fleet_records(&res.requests, path).unwrap();
        let loaded = load_fleet_records(path).unwrap();
        // Schema v4 roundtrips the routed records bit-for-bit.
        assert_eq!(res.requests, loaded);
        assert!(load_serve_records(path).is_err(), "v4 is not a v3 file");
    }

    #[test]
    fn v2_datasets_load_with_defaulted_crit_fields() {
        let ds = tiny_dataset();
        let path = "target/test-store-dataset-v2.json";
        save_dataset(&ds.runs, path).unwrap();
        // Rewrite to the v2 lineage: old header, no crit fields.
        let mut j = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        if let Json::Obj(fields) = &mut j {
            fields.insert("format".into(), s("piep-dataset-v2"));
            if let Some(Json::Arr(runs)) = fields.get_mut("runs") {
                for r in runs {
                    if let Json::Obj(rf) = r {
                        rf.remove("crit_share_j");
                        rf.remove("bound_by");
                        rf.remove("wait_frac");
                    }
                }
            }
        }
        std::fs::write(path, j.render()).unwrap();
        let loaded = load_dataset(path).unwrap();
        assert_eq!(loaded.runs.len(), ds.runs.len());
        for r in &loaded.runs {
            assert_eq!(r.crit_share_j, 0.0, "absent ⇒ unknown");
            assert_eq!(r.bound_by, "compute");
            assert_eq!(r.wait_frac, 0.0);
        }
    }

    #[test]
    fn module_keys_roundtrip() {
        for m in ModuleKind::ALL {
            assert_eq!(module_from_key(module_key(m)), Some(m));
        }
    }

    #[test]
    fn v3_headers_still_load_and_v4_carries_alltoall_rows() {
        let ds = tiny_dataset();
        let path = "target/test-store-dataset-v3.json";
        save_dataset(&ds.runs, path).unwrap();
        // The v4 file carries "alltoall" module rows for the expert run.
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("piep-dataset-v4"));
        assert!(text.contains("\"alltoall\""));
        // A v3 header (pre-expert dataset) is still accepted.
        std::fs::write(path, text.replace("piep-dataset-v4", "piep-dataset-v3")).unwrap();
        let loaded = load_dataset(path).unwrap();
        assert_eq!(loaded.runs.len(), ds.runs.len());
        let ep = loaded
            .runs
            .iter()
            .find(|r| r.config.parallelism == Parallelism::expert(2))
            .expect("expert run survives the roundtrip");
        assert!(ep.module_energy_j.contains_key(&ModuleKind::AllToAll));
    }
}
