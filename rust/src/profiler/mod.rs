//! Offline profiling campaigns (the paper's "Fine-grained Measurement").
//!
//! A campaign runs repeated, controlled passes over a configuration grid,
//! capturing for every run the wall-meter total, NVML channels, runtime
//! utilization, module-level energy attribution, and the raw wait-time
//! samples that feed synchronization sampling. All passes are seeded, so a
//! campaign is exactly reproducible; passes of one config differ only by
//! seed (the paper's repeated-runs distribution capture).
//!
//! Campaigns fan out over the shared `util::par` thread pool (the image
//! has no tokio/rayon); the simulator is CPU-bound and embarrassingly
//! parallel across runs. Lowered plans are cached across the repeated
//! passes of each configuration (`plan::PlanCache`) — lowering is
//! seed-free, so only the stochastic event-engine execution repeats.

pub mod store;

use std::collections::BTreeMap;

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::features::SyncDb;
use crate::parallelism;
use crate::plan::{CacheStats, ExecPlan, PlanCache};
use crate::simulator::{
    simulate_run_batch, simulate_run_planned, simulate_run_reference, RunRecord,
};
use crate::util::par;

/// A profiling campaign description.
#[derive(Debug, Clone)]
pub struct Campaign {
    pub hw: HwSpec,
    pub knobs: SimKnobs,
    /// Repeated passes per configuration (distribution capture).
    pub passes: usize,
    pub base_seed: u64,
    /// Worker threads (0 ⇒ available_parallelism).
    pub threads: usize,
}

impl Default for Campaign {
    fn default() -> Self {
        Campaign {
            hw: HwSpec::default(),
            knobs: SimKnobs::default(),
            passes: 6,
            base_seed: 0x91E9 << 8, // "PIEP"
            threads: 0,
        }
    }
}

/// Profiled dataset: records plus the offline sync-sampling database and
/// the plan-cache counters of the campaign that produced it.
#[derive(Debug)]
pub struct Dataset {
    pub runs: Vec<RunRecord>,
    pub sync_db: SyncDb,
    /// Two-level plan-cache counters: configs sharing a mesh topology
    /// lower once and rebind shapes; repeated passes hit the shape level.
    pub cache: CacheStats,
}

impl Campaign {
    /// Builder-style constructor: `Campaign::new()` is the default
    /// campaign; chain `with_*` to shape it.
    pub fn new() -> Campaign {
        Campaign::default()
    }

    /// Replace the testbed hardware.
    pub fn with_hw(mut self, hw: HwSpec) -> Campaign {
        self.hw = hw;
        self
    }

    /// Replace the simulator knobs.
    pub fn with_knobs(mut self, knobs: SimKnobs) -> Campaign {
        self.knobs = knobs;
        self
    }

    /// Set the repeated passes per configuration.
    pub fn with_passes(mut self, passes: usize) -> Campaign {
        self.passes = passes;
        self
    }

    /// Set the campaign base seed.
    pub fn with_base_seed(mut self, base_seed: u64) -> Campaign {
        self.base_seed = base_seed;
        self
    }

    /// Set the worker-thread count (0 ⇒ available parallelism).
    pub fn with_threads(mut self, threads: usize) -> Campaign {
        self.threads = threads;
        self
    }

    /// Expand configs × passes and simulate them all. Every pass of one
    /// configuration executes the same cached compiled plan (lowering
    /// never sees the seed), and configurations sharing a mesh topology
    /// share one structure lowering (`plan::PlanCache`). With
    /// `SimKnobs::batch_execution` (the default) all candidates of one
    /// mesh resolve in a single batched engine walk (DESIGN.md §14);
    /// records are bit-identical either way. With
    /// `SimKnobs::reference_engine` set, every run instead lowers and
    /// executes on the interpreted reference path (bit-identical).
    pub fn profile(&self, configs: &[RunConfig]) -> Dataset {
        let mut jobs: Vec<RunConfig> = Vec::with_capacity(configs.len() * self.passes);
        for cfg in configs {
            for pass in 0..self.passes {
                jobs.push(cfg.clone().with_seed(self.base_seed ^ (pass as u64 + 1)));
            }
        }

        let cache = PlanCache::new();
        let runs = if self.knobs.batch_execution && !self.knobs.reference_engine {
            // Group jobs by mesh identity and resolve each group — all
            // shape candidates × passes of one structure — in a single
            // batched engine walk; fan the groups out over the pool. Each
            // lane's seed stream is its own, so the scatter-back below
            // reproduces the serial per-job records bit for bit.
            let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
            for (i, cfg) in jobs.iter().enumerate() {
                groups
                    .entry(parallelism::structure_key(&self.knobs, cfg))
                    .or_default()
                    .push(i);
            }
            let groups: Vec<Vec<usize>> = groups.into_values().collect();
            let per_group = par::par_map(&groups, self.threads, |idxs| {
                let cfgs: Vec<RunConfig> = idxs.iter().map(|&i| jobs[i].clone()).collect();
                let plans: Vec<ExecPlan> = cfgs
                    .iter()
                    .map(|cfg| cache.get_or_lower(cfg, &self.hw, &self.knobs))
                    .collect();
                cache.note_batch(cfgs.len());
                simulate_run_batch(&cfgs, &self.hw, &self.knobs, &plans)
            });
            let mut slots: Vec<Option<RunRecord>> = jobs.iter().map(|_| None).collect();
            for (idxs, recs) in groups.iter().zip(per_group) {
                for (&i, rec) in idxs.iter().zip(recs) {
                    slots[i] = Some(rec);
                }
            }
            slots
                .into_iter()
                .map(|s| s.expect("every job scatters back into its slot"))
                .collect()
        } else {
            par::par_map(&jobs, self.threads, |cfg| {
                cache.note_serial_fallback();
                if self.knobs.reference_engine {
                    simulate_run_reference(cfg, &self.hw, &self.knobs)
                } else {
                    let plan = cache.get_or_lower(cfg, &self.hw, &self.knobs);
                    simulate_run_planned(cfg, &self.hw, &self.knobs, &plan)
                }
            })
        };
        let sync_db = SyncDb::build(&runs);
        Dataset {
            runs,
            sync_db,
            cache: cache.stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;

    #[test]
    fn campaign_runs_passes_per_config() {
        let c = Campaign {
            passes: 3,
            knobs: SimKnobs {
                sim_decode_steps: 4,
                ..SimKnobs::default()
            },
            ..Campaign::default()
        };
        let cfgs = vec![
            RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8),
            RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8),
        ];
        let ds = c.profile(&cfgs);
        assert_eq!(ds.runs.len(), 6);
        assert!(ds.sync_db.groups() >= 2);
    }

    #[test]
    fn campaign_is_deterministic() {
        let c = Campaign {
            passes: 2,
            threads: 3,
            knobs: SimKnobs {
                sim_decode_steps: 4,
                ..SimKnobs::default()
            },
            ..Campaign::default()
        };
        let cfgs = vec![RunConfig::new("Mistral-8B", Parallelism::Tensor, 2, 16)];
        let a = c.profile(&cfgs);
        let b = c.profile(&cfgs);
        for (x, y) in a.runs.iter().zip(&b.runs) {
            assert_eq!(x.true_total_j, y.true_total_j);
            assert_eq!(x.meter_total_j, y.meter_total_j);
        }
    }

    #[test]
    fn cached_plans_match_uncached_simulation() {
        let c = Campaign {
            passes: 3,
            knobs: SimKnobs {
                sim_decode_steps: 4,
                ..SimKnobs::default()
            },
            ..Campaign::default()
        };
        let cfgs = vec![RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8)];
        let ds = c.profile(&cfgs);
        for (pass, r) in ds.runs.iter().enumerate() {
            let cfg = cfgs[0].clone().with_seed(c.base_seed ^ (pass as u64 + 1));
            let direct = crate::simulator::simulate_run(&cfg, &c.hw, &c.knobs);
            assert_eq!(r.true_total_j, direct.true_total_j);
            assert_eq!(r.wait_samples, direct.wait_samples);
        }
    }

    #[test]
    fn batched_campaign_matches_serial_campaign_bit_for_bit() {
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        // Two shapes of one tensor mesh plus a pipeline mesh: two batch
        // groups, one of width 4 (2 shapes × 2 passes) and one of width 2.
        let cfgs = vec![
            RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8),
            RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 32),
            RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 4, 8),
        ];
        let on = Campaign {
            passes: 2,
            knobs: knobs.clone(),
            ..Campaign::default()
        }
        .profile(&cfgs);
        let off = Campaign {
            passes: 2,
            knobs: knobs.with_batch_execution(false),
            ..Campaign::default()
        }
        .profile(&cfgs);
        assert_eq!(on.runs.len(), off.runs.len());
        for (a, b) in on.runs.iter().zip(&off.runs) {
            assert_eq!(a.true_total_j, b.true_total_j);
            assert_eq!(a.meter_total_j, b.meter_total_j);
            assert_eq!(a.nvml_total_j, b.nvml_total_j);
            assert_eq!(a.wait_samples, b.wait_samples);
            assert_eq!(a.wall_s, b.wall_s);
        }
        assert_eq!(on.cache.batches, 2, "one batched walk per mesh");
        assert_eq!(on.cache.batched_lanes, 6);
        assert_eq!(on.cache.serial_fallbacks, 0);
        assert_eq!(off.cache.batches, 0);
        assert_eq!(off.cache.serial_fallbacks, 6);
    }

    #[test]
    fn passes_differ_from_each_other() {
        let c = Campaign {
            passes: 2,
            knobs: SimKnobs {
                sim_decode_steps: 4,
                ..SimKnobs::default()
            },
            ..Campaign::default()
        };
        let ds = c.profile(&[RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8)]);
        assert_ne!(ds.runs[0].true_total_j, ds.runs[1].true_total_j);
    }
}
