//! The shared **Plan IR**: the single lowering target of every parallelism
//! planner (DESIGN.md §9).
//!
//! A `Plan` is a DAG of per-rank compute ops and inter-rank communication
//! edges over the 2-D rank mesh, flattened into a topologically ordered op
//! list (every op appears after everything it depends on). Four op kinds
//! cover all of the paper's strategies:
//!
//! * `Compute` — a module runs on every rank of a range; the plan carries
//!   the *nominal* roofline timing, the engine samples per-rank skew.
//! * `Collective` — a rendezvous over a rank range (ring AllReduce,
//!   AllGather collation, or — with zero transfer time — a pure barrier):
//!   the straggler determines the start, then all ranks transfer in
//!   lockstep.
//! * `Send` / `Recv` — a point-to-point edge between pipeline stages: the
//!   edge becomes ready when the slowest sender finishes; receivers
//!   busy-wait on it.
//!
//! Plans are **deterministic**: they depend only on the model spec, the
//! hardware, the decode-step knob, and the run configuration — never on
//! the seed. All stochastic behavior (rank skew, stragglers, launch
//! desynchronization) is injected by the event engine at execution time
//! (`simulator::engine`), which is what makes plans cacheable across the
//! repeated passes of a profiling campaign (`plan::cache::PlanCache`).
//!
//! The pointer-heavy `Vec<Op>` form below is the **reference
//! representation** (executed by the interpreted engine path behind
//! `SimKnobs::reference_engine`). The hot paths compile into the
//! structure-of-arrays `exec::ExecPlan` instead — same op sequence, split
//! into a mesh-keyed structure and a shape-scalar table (DESIGN.md §12).
//!
//! # Example: one structure lowering, then scalar rebinds
//!
//! ```
//! use piep::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
//! use piep::plan::PlanCache;
//!
//! let hw = HwSpec::default();
//! let knobs = SimKnobs { sim_decode_steps: 2, ..SimKnobs::default() };
//! let cache = PlanCache::new();
//!
//! // First access lowers the expert-parallel mesh structure...
//! let warm = RunConfig::new("Vicuna-7B", Parallelism::expert(2), 2, 8);
//! let _ = cache.get_or_lower(&warm, &hw, &knobs);
//! // ...a new prompt length is shape-level: served by a scalar rebind.
//! let mut probe = warm.clone();
//! probe.seq_in += 64;
//! let _ = cache.get_or_lower(&probe, &hw, &knobs);
//!
//! let stats = cache.stats();
//! assert_eq!(stats.structure_lowerings, 1);
//! assert_eq!(stats.rebinds, 1);
//! ```

pub mod affine;
pub mod cache;
pub mod exec;

use std::ops::Range;

use crate::simulator::perf::ModuleTiming;
use crate::simulator::timeline::ModuleKind;

pub use affine::{AffineProgram, CommTerm, OpRule, RuleCapture};
pub use cache::{CacheStats, PlanCache};
pub use exec::{ExecBatch, ExecPlan, PlanStructure, ShapeBinding, ShapeScalars, StructureBuilder};

/// How a collective rendezvous records per-rank waiting durations into
/// the run's synchronization samples (the raw material of the paper's
/// synchronization sampling). P2P receives (`Op::Recv`) always record
/// strictly positive waits and carry no knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaitRecord {
    /// Record every participant's wait, including zeros (collectives).
    All,
    /// Record nothing (autoregressive step barriers).
    None,
}

/// Contiguous rank range `[first, first + count)` — every communicator in
/// the canonical 2-D meshes is a contiguous rank group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankRange {
    pub first: u16,
    pub count: u16,
}

impl RankRange {
    pub fn of(r: Range<usize>) -> RankRange {
        RankRange {
            first: r.start as u16,
            count: (r.end - r.start) as u16,
        }
    }

    #[inline]
    pub fn iter(&self) -> Range<usize> {
        self.first as usize..(self.first + self.count) as usize
    }

    #[inline]
    pub fn contains(&self, rank: usize) -> bool {
        (self.first as usize) <= rank && rank < (self.first + self.count) as usize
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.count as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

/// One node of the lowered execution DAG.
#[derive(Debug, Clone)]
pub enum Op {
    /// Skewed module compute on every rank of `ranks`.
    Compute {
        ranks: RankRange,
        module: ModuleKind,
        layer: u16,
        step: u32,
        /// Nominal (unskewed) duration from the roofline perf model, s.
        nominal_s: f64,
        /// Arithmetic utilization for the power model.
        util: f64,
    },
    /// Rendezvous over `ranks`: every participant arrives at its own clock
    /// (plus exponential launch-desync jitter when `jitter` is set), waits
    /// for the straggler, then transfers for `transfer_s` in lockstep.
    /// `transfer_s == 0` is a pure synchronization barrier.
    Collective {
        ranks: RankRange,
        module: ModuleKind,
        layer: u16,
        step: u32,
        transfer_s: f64,
        /// Extra transfer-phase board power from the link tier's wire
        /// energy, W (0 on the legacy flat link — see
        /// `cluster::LinkSpec::energy_per_byte`).
        wire_w: f64,
        jitter: bool,
        record: WaitRecord,
    },
    /// P2P edge producer: each rank of `ranks` drives the link for
    /// `transfer_s`; edge `edge` becomes ready at the slowest sender's
    /// completion.
    Send {
        ranks: RankRange,
        layer: u16,
        step: u32,
        transfer_s: f64,
        /// Extra transfer-phase board power from the link tier's wire
        /// energy, W (0 on the legacy flat link).
        wire_w: f64,
        edge: u32,
    },
    /// P2P edge consumer: each rank of `ranks` busy-waits until edge
    /// `edge` is ready (positive waits are recorded as sync samples).
    Recv {
        ranks: RankRange,
        layer: u16,
        step: u32,
        edge: u32,
    },
}

impl Op {
    /// Ranks whose clocks this op advances.
    pub fn ranks(&self) -> RankRange {
        match self {
            Op::Compute { ranks, .. }
            | Op::Collective { ranks, .. }
            | Op::Send { ranks, .. }
            | Op::Recv { ranks, .. } => *ranks,
        }
    }

    /// Decode step tag (0 = prefill).
    pub fn step(&self) -> u32 {
        match self {
            Op::Compute { step, .. }
            | Op::Collective { step, .. }
            | Op::Send { step, .. }
            | Op::Recv { step, .. } => *step,
        }
    }

    /// Is this a synchronization point (rendezvous or P2P edge)?
    pub fn is_sync(&self) -> bool {
        !matches!(self, Op::Compute { .. })
    }
}

/// A lowered run: the op DAG plus the profiler-visible descriptors the
/// planners used to compute inline.
#[derive(Debug, Clone)]
pub struct Plan {
    pub num_ranks: usize,
    /// Topologically ordered op list (dependencies always point backwards).
    pub ops: Vec<Op>,
    /// Number of P2P edges referenced by `Send`/`Recv` ops.
    pub num_edges: u32,
    /// Whether this strategy draws the per-run launch-desync scale (the
    /// tensor and hybrid planners sample it once per run even when no
    /// collective ends up jittered, preserving the seed stream).
    pub draws_sync_jitter: bool,
    /// Whether this plan draws the per-rank MoE routing-imbalance
    /// multipliers (`SkewModel::draw_route_bias`). Derived at `finish`
    /// time from the presence of all-to-all collectives, so only the
    /// expert-parallel strategy consumes the extra draws — every other
    /// strategy's seed stream stays byte-identical.
    pub draws_route_bias: bool,
    /// Decode steps simulated explicitly (before extrapolation).
    pub sim_steps: usize,
    /// Collective/P2P payload bytes moved per simulated decode step.
    pub comm_bytes_per_step: f64,
}

impl Plan {
    /// Number of ops per kind: (compute, collective, send, recv) — used by
    /// diagnostics and the end-to-end example.
    pub fn op_census(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for op in &self.ops {
            match op {
                Op::Compute { .. } => c.0 += 1,
                Op::Collective { .. } => c.1 += 1,
                Op::Send { .. } => c.2 += 1,
                Op::Recv { .. } => c.3 += 1,
            }
        }
        c
    }
}

/// Lowering sink: the strategy lowerers are generic over this trait, so
/// one lowering pass can feed either the interpreted op list
/// (`PlanBuilder`, the reference representation), a compiled
/// structure-of-arrays plan (`exec::StructureBuilder`), or a scalar-table
/// rebind against a cached structure (`exec::ShapeBinding`).
///
/// Contract: for a fixed structure identity (`parallelism::structure_key`)
/// the lowerers issue the *same call sequence* with the same structural
/// arguments (rank ranges, modules, layers, steps, edge pairing, jitter
/// and record flags); only the scalar arguments — timings, transfer
/// durations, wire powers — may differ between shapes. `ShapeBinding`
/// enforces this with debug assertions.
pub trait PlanSink {
    /// Skewed compute of `timing` on every rank of `ranks`.
    fn compute(
        &mut self,
        ranks: Range<usize>,
        timing: ModuleTiming,
        module: ModuleKind,
        layer: u16,
        step: u32,
    );

    /// Rendezvous collective with an explicit link-tier wire power (the
    /// topology-aware lowering path; `wire_w == 0` reproduces `collective`,
    /// `transfer_s == 0` is a pure barrier).
    #[allow(clippy::too_many_arguments)]
    fn collective_tiered(
        &mut self,
        ranks: Range<usize>,
        module: ModuleKind,
        layer: u16,
        step: u32,
        transfer_s: f64,
        wire_w: f64,
        jitter: bool,
        record: WaitRecord,
    );

    /// P2P send with an explicit link-tier wire power; returns the edge id
    /// for the matching `recv`.
    fn send_tiered(
        &mut self,
        ranks: Range<usize>,
        layer: u16,
        step: u32,
        transfer_s: f64,
        wire_w: f64,
    ) -> u32;

    /// P2P receive on `ranks` of a previously emitted edge.
    fn recv(&mut self, ranks: Range<usize>, layer: u16, step: u32, edge: u32);

    /// Rendezvous collective (or, with `transfer_s == 0`, a barrier) over
    /// the legacy flat link (no wire-power term).
    #[allow(clippy::too_many_arguments)]
    fn collective(
        &mut self,
        ranks: Range<usize>,
        module: ModuleKind,
        layer: u16,
        step: u32,
        transfer_s: f64,
        jitter: bool,
        record: WaitRecord,
    ) {
        self.collective_tiered(ranks, module, layer, step, transfer_s, 0.0, jitter, record);
    }

    /// P2P send from `ranks` over the legacy flat link; returns the edge id
    /// for the matching `recv`.
    fn send(&mut self, ranks: Range<usize>, layer: u16, step: u32, transfer_s: f64) -> u32 {
        self.send_tiered(ranks, layer, step, transfer_s, 0.0)
    }

    /// Announce the shape-affine rule behind the *next* op emission
    /// (DESIGN.md §17). Lowerers call this immediately before the
    /// `compute` / collective / send the rule describes; sinks that do not
    /// compile affine programs ignore it, so plain structure compiles and
    /// `ShapeBinding` replays pay nothing.
    fn rule(&mut self, _rule: affine::OpRule) {}

    /// Announce one additive term of the `comm_bytes_per_step`
    /// accumulation, at the accumulation site (preserving fold order).
    /// Default no-op, like [`PlanSink::rule`].
    fn comm_term(&mut self, _term: affine::CommTerm) {}
}

/// Incremental builder used by the strategy lowerers (the reference
/// `Vec<Op>` representation; hot paths build `exec::ExecPlan` instead).
#[derive(Debug)]
pub struct PlanBuilder {
    num_ranks: usize,
    ops: Vec<Op>,
    num_edges: u32,
}

impl PlanBuilder {
    pub fn new(num_ranks: usize) -> PlanBuilder {
        PlanBuilder {
            num_ranks,
            ops: Vec::new(),
            num_edges: 0,
        }
    }

    pub fn finish(
        self,
        sim_steps: usize,
        comm_bytes_per_step: f64,
        draws_sync_jitter: bool,
    ) -> Plan {
        let draws_route_bias = self.ops.iter().any(|op| {
            matches!(
                op,
                Op::Collective {
                    module: ModuleKind::AllToAll,
                    ..
                }
            )
        });
        Plan {
            num_ranks: self.num_ranks,
            ops: self.ops,
            num_edges: self.num_edges,
            draws_sync_jitter,
            draws_route_bias,
            sim_steps,
            comm_bytes_per_step,
        }
    }
}

impl PlanSink for PlanBuilder {
    fn compute(
        &mut self,
        ranks: Range<usize>,
        timing: ModuleTiming,
        module: ModuleKind,
        layer: u16,
        step: u32,
    ) {
        self.ops.push(Op::Compute {
            ranks: RankRange::of(ranks),
            module,
            layer,
            step,
            nominal_s: timing.dur_s,
            util: timing.util,
        });
    }

    fn collective_tiered(
        &mut self,
        ranks: Range<usize>,
        module: ModuleKind,
        layer: u16,
        step: u32,
        transfer_s: f64,
        wire_w: f64,
        jitter: bool,
        record: WaitRecord,
    ) {
        self.ops.push(Op::Collective {
            ranks: RankRange::of(ranks),
            module,
            layer,
            step,
            transfer_s,
            wire_w,
            jitter,
            record,
        });
    }

    fn send_tiered(&mut self, ranks: Range<usize>, layer: u16, step: u32, transfer_s: f64, wire_w: f64) -> u32 {
        let edge = self.num_edges;
        self.num_edges += 1;
        self.ops.push(Op::Send {
            ranks: RankRange::of(ranks),
            layer,
            step,
            transfer_s,
            wire_w,
            edge,
        });
        edge
    }

    fn recv(&mut self, ranks: Range<usize>, layer: u16, step: u32, edge: u32) {
        debug_assert!(edge < self.num_edges, "recv of unsent edge {edge}");
        self.ops.push(Op::Recv {
            ranks: RankRange::of(ranks),
            layer,
            step,
            edge,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing() -> ModuleTiming {
        ModuleTiming {
            dur_s: 1e-3,
            util: 0.7,
        }
    }

    #[test]
    fn builder_assigns_sequential_edges() {
        let mut b = PlanBuilder::new(2);
        b.compute(0..2, timing(), ModuleKind::Mlp, 0, 0);
        let e0 = b.send(0..1, 8, 0, 1e-4);
        b.recv(1..2, 8, 0, e0);
        let e1 = b.send(0..1, 8, 1, 1e-4);
        b.recv(1..2, 8, 1, e1);
        let plan = b.finish(1, 64.0, false);
        assert_eq!((e0, e1), (0, 1));
        assert_eq!(plan.num_edges, 2);
        assert_eq!(plan.op_census(), (1, 0, 2, 2));
    }

    #[test]
    fn alltoall_collectives_flag_route_bias_draws() {
        let mut b = PlanBuilder::new(4);
        b.compute(0..4, timing(), ModuleKind::SelfAttention, 0, 0);
        b.collective(0..4, ModuleKind::AllToAll, 0, 0, 1e-4, true, WaitRecord::All);
        let plan = b.finish(1, 0.0, true);
        assert!(plan.draws_route_bias);
    }

    #[test]
    fn rank_range_iterates_and_contains() {
        let r = RankRange::of(2..5);
        assert_eq!(r.iter().collect::<Vec<_>>(), vec![2, 3, 4]);
        assert!(r.contains(2) && r.contains(4) && !r.contains(5) && !r.contains(1));
        assert_eq!(r.len(), 3);
        assert!(!r.is_empty());
    }

    #[test]
    fn op_accessors_cover_all_kinds() {
        let mut b = PlanBuilder::new(4);
        b.compute(0..4, timing(), ModuleKind::Norm, 3, 2);
        b.collective(0..4, ModuleKind::AllReduce, 3, 2, 1e-4, true, WaitRecord::All);
        let e = b.send(0..1, 0, 2, 1e-5);
        b.recv(1..2, 0, 2, e);
        let plan = b.finish(1, 0.0, true);
        assert!(plan.draws_sync_jitter);
        assert!(!plan.draws_route_bias, "no all-to-all ops here");
        assert!(!plan.ops[0].is_sync());
        for op in &plan.ops[1..] {
            assert!(op.is_sync());
        }
        assert_eq!(plan.ops[0].step(), 2);
        assert_eq!(plan.ops[1].ranks().len(), 4);
    }
}
