//! Two-level compiled-plan cache: **structures by mesh identity, scalars
//! by shape** (DESIGN.md §12).
//!
//! Plans are deterministic functions of (model, parallelism, gpus, batch,
//! sequence lengths, decode-step knob, hardware) — the seed never enters
//! lowering — and they factor further: configurations sharing a mesh
//! topology (`parallelism::structure_key`) share their entire op
//! *structure* and differ only in the per-op scalar table. The cache
//! exploits both levels:
//!
//! 1. **Shape level** — the full run identity (`RunConfig::key` + seq_in +
//!    decode-step knob) maps to a ready `ExecPlan`. Repeated passes of one
//!    configuration (differing only by seed) hit here.
//! 2. **Structure level** — the mesh identity maps to an
//!    `Arc<PlanStructure>`. A shape miss whose mesh is cached costs one
//!    scalar rebind (`parallelism::rebind`, an array fill) instead of a
//!    full lowering; only a genuinely new mesh pays `parallelism::compile`.
//!
//! A tune grid or serving trace therefore lowers each mesh topology once
//! and rebinds hundreds of shapes — the hit-rate contract asserted by the
//! integration tests. Structure compiles additionally capture and
//! probe-verify a shape-affine scalar program (`plan::affine`, DESIGN.md
//! §17); accepted programs serve later rebinds without replaying the
//! lowerer at all, and rejected ones pin the structure to the replay path
//! (`CacheStats::{affine_rebinds, replay_fallbacks, probe_rejected_ops}`). The cache is shared across `util::par` workers; on a
//! miss the worker lowers outside the lock (a racing duplicate lowering is
//! harmless — plans are deterministic, last insert wins — though it can
//! overcount `CacheStats` by the duplicate; the stats are exact under
//! serial access). One cache instance assumes one `HwSpec` (campaigns hold
//! hardware fixed).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::parallelism;
use crate::plan::affine::{self, AffineProgram};
use crate::plan::exec::{ExecPlan, PlanStructure};

/// Hit/miss counters of the two cache levels.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Full structure lowerings (`parallelism::compile`) — one per mesh
    /// topology the cache has seen.
    pub structure_lowerings: usize,
    /// Structure-level hits served by a scalar rebind
    /// (`parallelism::rebind`) — new shape on a cached mesh.
    pub rebinds: usize,
    /// Shape-level hits — the ready `ExecPlan` was reused as-is (repeated
    /// passes of one configuration).
    pub shape_hits: usize,
    /// Batched engine walks executed (`engine::execute_batch` calls that
    /// resolved ≥1 candidate lane in one pass, DESIGN.md §14).
    pub batches: usize,
    /// Candidate lanes resolved across all batched walks — `batched_lanes
    /// / batches` is the mean batch width.
    pub batched_lanes: usize,
    /// Plan executions performed one-at-a-time on a batch-capable path
    /// (batching disabled via `SimKnobs::batch_execution`, or the
    /// reference engine selected).
    pub serial_fallbacks: usize,
    /// Rebinds served by the structure's shape-affine scalar program
    /// (`plan::affine` — no lowerer replay). Always a subset of `rebinds`:
    /// `affine_rebinds + replay_fallbacks == rebinds`.
    pub affine_rebinds: usize,
    /// Rebinds served by the `ShapeBinding` lowering replay — because the
    /// affine knob is off, the structure's program was rejected at compile
    /// time, or no program was captured.
    pub replay_fallbacks: usize,
    /// Scalar slots (or unannotated ops) on which a captured affine
    /// program disagreed with the replayed lowering during compile-time
    /// probe verification. Any nonzero count rejected that structure's
    /// whole program, pinning its rebinds to the replay path.
    pub probe_rejected_ops: usize,
}

impl CacheStats {
    /// Total cache accesses observed.
    pub fn accesses(&self) -> usize {
        self.structure_lowerings + self.rebinds + self.shape_hits
    }

    /// Fraction of accesses that avoided a full lowering (rebinds and
    /// shape hits over all accesses; 0 when untouched).
    pub fn reuse_rate(&self) -> f64 {
        let total = self.accesses();
        if total == 0 {
            return 0.0;
        }
        (self.rebinds + self.shape_hits) as f64 / total as f64
    }

    /// Mean candidate lanes per batched walk (0 when nothing batched).
    pub fn mean_batch_width(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batched_lanes as f64 / self.batches as f64
    }

    /// Mean batch width formatted for summary lines: `"-"` when no
    /// batched walk ran (printing `0.0` would read as a measured width).
    pub fn mean_batch_width_label(&self) -> String {
        if self.batches == 0 {
            "-".into()
        } else {
            format!("{:.1}", self.mean_batch_width())
        }
    }

    /// Fraction of rebinds served by the affine program (0 when no rebind
    /// has happened).
    pub fn affine_coverage(&self) -> f64 {
        if self.rebinds == 0 {
            return 0.0;
        }
        self.affine_rebinds as f64 / self.rebinds as f64
    }

    /// Affine coverage formatted for summary lines: `"-"` when no rebind
    /// ran at all (printing `0%` would read as a measured fallback rate).
    pub fn affine_coverage_label(&self) -> String {
        if self.rebinds == 0 {
            "-".into()
        } else {
            format!("{:.0}%", 100.0 * self.affine_coverage())
        }
    }
}

/// A cached mesh structure plus its (optional) verified shape-affine
/// scalar program. `affine: None` means rebinds replay the lowering —
/// either the knob was off at compile time, the lowerer left ops
/// unannotated, or probe verification rejected the captured program.
#[derive(Debug, Clone)]
struct CachedStructure {
    structure: Arc<PlanStructure>,
    affine: Option<Arc<AffineProgram>>,
}

/// Thread-safe two-level map from configuration identity to its compiled
/// plan.
#[derive(Debug, Default)]
pub struct PlanCache {
    structures: Mutex<HashMap<String, CachedStructure>>,
    shapes: Mutex<HashMap<String, ExecPlan>>,
    stats: Mutex<CacheStats>,
}

/// Shape identity: everything lowering depends on besides the hardware.
/// `RunConfig::key` covers model/parallelism/gpus/batch/seq_out; seq_in and
/// the decode-step knob complete it.
fn shape_key(cfg: &RunConfig, knobs: &SimKnobs) -> String {
    format!("{}/in{}/steps{}", cfg.key(), cfg.seq_in, knobs.sim_decode_steps)
}

/// Compile-time acceptance check of a captured affine program: evaluate it
/// at the compile shape and at every structure-preserving held-out probe
/// shape (`affine::probe_shapes`), requiring bit-level agreement with the
/// replayed lowering on every scalar. Returns the accepted program, or
/// `None` plus the mismatch count that rejected it. Rejection costs only
/// coverage — the structure's rebinds stay on the (always-correct) replay.
fn verified_program(
    ep: &ExecPlan,
    prog: Result<AffineProgram, usize>,
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
) -> (Option<Arc<AffineProgram>>, usize) {
    let prog = match prog {
        Ok(p) => p,
        Err(unruled) => return (None, unruled.max(1)),
    };
    // Self-check: the program must reproduce the compile shape exactly.
    let self_eval = prog.eval(&ep.structure, spec, hw, knobs, cfg);
    let m = affine::scalars_mismatch(&ep.scalars, &self_eval.scalars);
    if m > 0 {
        return (None, m);
    }
    // Held-out probes. Probes that change the mesh key are skipped: they
    // could not share this structure (or program) in the first place. The
    // prompt-length probes never change the key, so at least two run.
    let key = parallelism::structure_key(knobs, cfg);
    for probe in affine::probe_shapes(cfg) {
        if parallelism::structure_key(knobs, &probe) != key {
            continue;
        }
        let replay = parallelism::rebind(&ep.structure, spec, hw, knobs, &probe);
        let evaluated = prog.eval(&ep.structure, spec, hw, knobs, &probe);
        let m = affine::scalars_mismatch(&replay.scalars, &evaluated.scalars);
        if m > 0 {
            return (None, m);
        }
    }
    (Some(Arc::new(prog)), 0)
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The compiled plan for `cfg`: a shape hit returns the cached
    /// `ExecPlan` (two `Arc` bumps); a shape miss on a cached mesh rebinds
    /// only the scalar table; a new mesh pays one full lowering.
    pub fn get_or_lower(&self, cfg: &RunConfig, hw: &HwSpec, knobs: &SimKnobs) -> ExecPlan {
        let skey = shape_key(cfg, knobs);
        if let Some(ep) = self.shapes.lock().unwrap().get(&skey) {
            self.stats.lock().unwrap().shape_hits += 1;
            return ep.clone();
        }
        let spec = crate::models::by_name(&cfg.model)
            .unwrap_or_else(|| panic!("unknown model {}", cfg.model));
        let mesh_key = parallelism::structure_key(knobs, cfg);
        let cached_structure = self.structures.lock().unwrap().get(&mesh_key).cloned();
        let ep = match cached_structure {
            Some(cs) => {
                let use_affine = knobs.affine_rebind && cs.affine.is_some();
                {
                    let mut st = self.stats.lock().unwrap();
                    st.rebinds += 1;
                    if use_affine {
                        st.affine_rebinds += 1;
                    } else {
                        st.replay_fallbacks += 1;
                    }
                }
                if use_affine {
                    cs.affine
                        .as_ref()
                        .unwrap()
                        .eval(&cs.structure, &spec, hw, knobs, cfg)
                } else {
                    parallelism::rebind(&cs.structure, &spec, hw, knobs, cfg)
                }
            }
            None => {
                let (ep, affine, rejected) = if knobs.affine_rebind {
                    let (ep, prog) = parallelism::compile_affine(&spec, hw, knobs, cfg);
                    let (affine, rejected) = verified_program(&ep, prog, &spec, hw, knobs, cfg);
                    (ep, affine, rejected)
                } else {
                    (parallelism::compile(&spec, hw, knobs, cfg), None, 0)
                };
                {
                    let mut st = self.stats.lock().unwrap();
                    st.structure_lowerings += 1;
                    st.probe_rejected_ops += rejected;
                }
                self.structures
                    .lock()
                    .unwrap()
                    .entry(mesh_key)
                    .or_insert_with(|| CachedStructure {
                        structure: Arc::clone(&ep.structure),
                        affine,
                    });
                ep
            }
        };
        self.shapes.lock().unwrap().entry(skey).or_insert(ep).clone()
    }

    /// Two-level hit/miss counters (exact under serial access; see the
    /// module docs for the racing caveat).
    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap()
    }

    /// Record one batched engine walk resolving `lanes` candidates.
    pub fn note_batch(&self, lanes: usize) {
        let mut st = self.stats.lock().unwrap();
        st.batches += 1;
        st.batched_lanes += lanes;
    }

    /// Record one plan executed serially where a batch was possible.
    pub fn note_serial_fallback(&self) {
        self.stats.lock().unwrap().serial_fallbacks += 1;
    }

    /// (cached mesh structures, cached shape plans).
    pub fn sizes(&self) -> (usize, usize) {
        (
            self.structures.lock().unwrap().len(),
            self.shapes.lock().unwrap().len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;

    fn knobs() -> SimKnobs {
        SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        }
    }

    #[test]
    fn mean_batch_width_guards_the_zero_batch_case() {
        let mut st = CacheStats::default();
        assert_eq!(st.mean_batch_width(), 0.0);
        assert_eq!(st.mean_batch_width_label(), "-", "no batches ⇒ no width");
        st.batches = 2;
        st.batched_lanes = 7;
        assert_eq!(st.mean_batch_width(), 3.5);
        assert_eq!(st.mean_batch_width_label(), "3.5");
    }

    #[test]
    fn passes_share_one_plan() {
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8);
        let a = cache.get_or_lower(&cfg.clone().with_seed(1), &hw, &knobs);
        let b = cache.get_or_lower(&cfg.clone().with_seed(2), &hw, &knobs);
        assert!(
            Arc::ptr_eq(&a.scalars, &b.scalars),
            "seed must not fork the plan"
        );
        let st = cache.stats();
        assert_eq!((st.structure_lowerings, st.rebinds, st.shape_hits), (1, 0, 1));
    }

    #[test]
    fn distinct_meshes_get_distinct_structures() {
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        let a = cache.get_or_lower(
            &RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8),
            &hw,
            &knobs,
        );
        let b = cache.get_or_lower(
            &RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8),
            &hw,
            &knobs,
        );
        assert!(!Arc::ptr_eq(&a.structure, &b.structure));
        assert_eq!(a.num_ranks(), 2);
        assert_eq!(b.num_ranks(), 4);
        assert_eq!(cache.stats().structure_lowerings, 2);
    }

    #[test]
    fn same_mesh_new_shape_rebinds_instead_of_relowering() {
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        // TP structure is batch- and prompt-length-invariant: only the
        // scalar table differs between these three shapes.
        let a = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8), &hw, &knobs);
        let b = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 32), &hw, &knobs);
        let mut long_prompt = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8);
        long_prompt.seq_in = 512;
        let c = cache.get_or_lower(&long_prompt, &hw, &knobs);
        assert!(Arc::ptr_eq(&a.structure, &b.structure), "one structure serves all shapes");
        assert!(Arc::ptr_eq(&a.structure, &c.structure));
        assert!(!Arc::ptr_eq(&a.scalars, &b.scalars), "scalars are per shape");
        let st = cache.stats();
        assert_eq!((st.structure_lowerings, st.rebinds, st.shape_hits), (1, 2, 0));
        assert_eq!(cache.sizes(), (1, 3));
        assert!(st.reuse_rate() > 0.6);
    }

    #[test]
    fn affine_rebinds_split_the_rebind_counter() {
        // Same grid as `same_mesh_new_shape_rebinds_instead_of_relowering`:
        // with the affine knob on (the default) both rebinds must be served
        // by the accepted program, with zero probe rejections.
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8), &hw, &knobs);
        cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 32), &hw, &knobs);
        let mut long_prompt = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8);
        long_prompt.seq_in = 512;
        cache.get_or_lower(&long_prompt, &hw, &knobs);
        let st = cache.stats();
        assert_eq!(st.rebinds, 2);
        assert_eq!(st.affine_rebinds, 2, "stock lowerers must pass probe verification");
        assert_eq!(st.replay_fallbacks, 0);
        assert_eq!(st.probe_rejected_ops, 0);
        assert_eq!(st.affine_rebinds + st.replay_fallbacks, st.rebinds);
    }

    #[test]
    fn no_affine_knob_pins_the_replay_path_bit_identically() {
        let hw = HwSpec::default();
        let on = knobs();
        let off = knobs().with_affine_rebind(false);
        for par in [
            Parallelism::Tensor,
            Parallelism::Pipeline,
            Parallelism::Data,
            Parallelism::expert(4),
        ] {
            let cache_on = PlanCache::new();
            let cache_off = PlanCache::new();
            for (batch, seq_in) in [(8, 128), (8, 256), (16, 128)] {
                let mut cfg = RunConfig::new("Vicuna-7B", par, 4, batch);
                cfg.seq_in = seq_in;
                let a = cache_on.get_or_lower(&cfg, &hw, &on);
                let b = cache_off.get_or_lower(&cfg, &hw, &off);
                assert_eq!(
                    affine::scalars_mismatch(&a.scalars, &b.scalars),
                    0,
                    "{par:?} b{batch} in{seq_in}: affine and replay rebinds must be bit-identical"
                );
            }
            let (son, soff) = (cache_on.stats(), cache_off.stats());
            assert_eq!(son.rebinds, soff.rebinds, "{par:?}: the knob must not change access counts");
            assert_eq!(soff.affine_rebinds, 0, "{par:?}: --no-affine serves every rebind by replay");
            assert_eq!(soff.replay_fallbacks, soff.rebinds);
        }
    }

    #[test]
    fn affine_coverage_label_guards_the_zero_rebind_case() {
        let mut st = CacheStats::default();
        assert_eq!(st.affine_coverage_label(), "-", "no rebinds ⇒ no coverage to report");
        st.rebinds = 4;
        st.affine_rebinds = 3;
        st.replay_fallbacks = 1;
        assert_eq!(st.affine_coverage_label(), "75%");
        assert!((st.affine_coverage() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn pipeline_microbatch_count_is_structural() {
        // batch 2 on 4 stages -> 2 microbatches; batch 8 -> 4. Different op
        // sequences, so distinct structures; batches 8 and 32 share the
        // 4-microbatch structure.
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        let tiny = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 4, 2), &hw, &knobs);
        let a = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 4, 8), &hw, &knobs);
        let b = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 4, 32), &hw, &knobs);
        assert!(!Arc::ptr_eq(&tiny.structure, &a.structure));
        assert!(Arc::ptr_eq(&a.structure, &b.structure));
        assert_eq!(cache.stats().structure_lowerings, 2);
    }
}
