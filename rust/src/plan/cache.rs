//! Two-level compiled-plan cache: **structures by mesh identity, scalars
//! by shape** (DESIGN.md §12).
//!
//! Plans are deterministic functions of (model, parallelism, gpus, batch,
//! sequence lengths, decode-step knob, hardware) — the seed never enters
//! lowering — and they factor further: configurations sharing a mesh
//! topology (`parallelism::structure_key`) share their entire op
//! *structure* and differ only in the per-op scalar table. The cache
//! exploits both levels:
//!
//! 1. **Shape level** — the full run identity (`RunConfig::key` + seq_in +
//!    decode-step knob) maps to a ready `ExecPlan`. Repeated passes of one
//!    configuration (differing only by seed) hit here.
//! 2. **Structure level** — the mesh identity maps to an
//!    `Arc<PlanStructure>`. A shape miss whose mesh is cached costs one
//!    scalar rebind (`parallelism::rebind`, an array fill) instead of a
//!    full lowering; only a genuinely new mesh pays `parallelism::compile`.
//!
//! A tune grid or serving trace therefore lowers each mesh topology once
//! and rebinds hundreds of shapes — the hit-rate contract asserted by the
//! integration tests. The cache is shared across `util::par` workers; on a
//! miss the worker lowers outside the lock (a racing duplicate lowering is
//! harmless — plans are deterministic, last insert wins — though it can
//! overcount `CacheStats` by the duplicate; the stats are exact under
//! serial access). One cache instance assumes one `HwSpec` (campaigns hold
//! hardware fixed).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::parallelism;
use crate::plan::exec::{ExecPlan, PlanStructure};

/// Hit/miss counters of the two cache levels.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Full structure lowerings (`parallelism::compile`) — one per mesh
    /// topology the cache has seen.
    pub structure_lowerings: usize,
    /// Structure-level hits served by a scalar rebind
    /// (`parallelism::rebind`) — new shape on a cached mesh.
    pub rebinds: usize,
    /// Shape-level hits — the ready `ExecPlan` was reused as-is (repeated
    /// passes of one configuration).
    pub shape_hits: usize,
    /// Batched engine walks executed (`engine::execute_batch` calls that
    /// resolved ≥1 candidate lane in one pass, DESIGN.md §14).
    pub batches: usize,
    /// Candidate lanes resolved across all batched walks — `batched_lanes
    /// / batches` is the mean batch width.
    pub batched_lanes: usize,
    /// Plan executions performed one-at-a-time on a batch-capable path
    /// (batching disabled via `SimKnobs::batch_execution`, or the
    /// reference engine selected).
    pub serial_fallbacks: usize,
}

impl CacheStats {
    /// Total cache accesses observed.
    pub fn accesses(&self) -> usize {
        self.structure_lowerings + self.rebinds + self.shape_hits
    }

    /// Fraction of accesses that avoided a full lowering (rebinds and
    /// shape hits over all accesses; 0 when untouched).
    pub fn reuse_rate(&self) -> f64 {
        let total = self.accesses();
        if total == 0 {
            return 0.0;
        }
        (self.rebinds + self.shape_hits) as f64 / total as f64
    }

    /// Mean candidate lanes per batched walk (0 when nothing batched).
    pub fn mean_batch_width(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batched_lanes as f64 / self.batches as f64
    }

    /// Mean batch width formatted for summary lines: `"-"` when no
    /// batched walk ran (printing `0.0` would read as a measured width).
    pub fn mean_batch_width_label(&self) -> String {
        if self.batches == 0 {
            "-".into()
        } else {
            format!("{:.1}", self.mean_batch_width())
        }
    }
}

/// Thread-safe two-level map from configuration identity to its compiled
/// plan.
#[derive(Debug, Default)]
pub struct PlanCache {
    structures: Mutex<HashMap<String, Arc<PlanStructure>>>,
    shapes: Mutex<HashMap<String, ExecPlan>>,
    stats: Mutex<CacheStats>,
}

/// Shape identity: everything lowering depends on besides the hardware.
/// `RunConfig::key` covers model/parallelism/gpus/batch/seq_out; seq_in and
/// the decode-step knob complete it.
fn shape_key(cfg: &RunConfig, knobs: &SimKnobs) -> String {
    format!("{}/in{}/steps{}", cfg.key(), cfg.seq_in, knobs.sim_decode_steps)
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The compiled plan for `cfg`: a shape hit returns the cached
    /// `ExecPlan` (two `Arc` bumps); a shape miss on a cached mesh rebinds
    /// only the scalar table; a new mesh pays one full lowering.
    pub fn get_or_lower(&self, cfg: &RunConfig, hw: &HwSpec, knobs: &SimKnobs) -> ExecPlan {
        let skey = shape_key(cfg, knobs);
        if let Some(ep) = self.shapes.lock().unwrap().get(&skey) {
            self.stats.lock().unwrap().shape_hits += 1;
            return ep.clone();
        }
        let spec = crate::models::by_name(&cfg.model)
            .unwrap_or_else(|| panic!("unknown model {}", cfg.model));
        let mesh_key = parallelism::structure_key(knobs, cfg);
        let cached_structure = self.structures.lock().unwrap().get(&mesh_key).cloned();
        let ep = match cached_structure {
            Some(structure) => {
                self.stats.lock().unwrap().rebinds += 1;
                parallelism::rebind(&structure, &spec, hw, knobs, cfg)
            }
            None => {
                let ep = parallelism::compile(&spec, hw, knobs, cfg);
                self.stats.lock().unwrap().structure_lowerings += 1;
                self.structures
                    .lock()
                    .unwrap()
                    .entry(mesh_key)
                    .or_insert_with(|| Arc::clone(&ep.structure));
                ep
            }
        };
        self.shapes.lock().unwrap().entry(skey).or_insert(ep).clone()
    }

    /// Two-level hit/miss counters (exact under serial access; see the
    /// module docs for the racing caveat).
    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap()
    }

    /// Record one batched engine walk resolving `lanes` candidates.
    pub fn note_batch(&self, lanes: usize) {
        let mut st = self.stats.lock().unwrap();
        st.batches += 1;
        st.batched_lanes += lanes;
    }

    /// Record one plan executed serially where a batch was possible.
    pub fn note_serial_fallback(&self) {
        self.stats.lock().unwrap().serial_fallbacks += 1;
    }

    /// (cached mesh structures, cached shape plans).
    pub fn sizes(&self) -> (usize, usize) {
        (
            self.structures.lock().unwrap().len(),
            self.shapes.lock().unwrap().len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;

    fn knobs() -> SimKnobs {
        SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        }
    }

    #[test]
    fn mean_batch_width_guards_the_zero_batch_case() {
        let mut st = CacheStats::default();
        assert_eq!(st.mean_batch_width(), 0.0);
        assert_eq!(st.mean_batch_width_label(), "-", "no batches ⇒ no width");
        st.batches = 2;
        st.batched_lanes = 7;
        assert_eq!(st.mean_batch_width(), 3.5);
        assert_eq!(st.mean_batch_width_label(), "3.5");
    }

    #[test]
    fn passes_share_one_plan() {
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8);
        let a = cache.get_or_lower(&cfg.clone().with_seed(1), &hw, &knobs);
        let b = cache.get_or_lower(&cfg.clone().with_seed(2), &hw, &knobs);
        assert!(
            Arc::ptr_eq(&a.scalars, &b.scalars),
            "seed must not fork the plan"
        );
        let st = cache.stats();
        assert_eq!((st.structure_lowerings, st.rebinds, st.shape_hits), (1, 0, 1));
    }

    #[test]
    fn distinct_meshes_get_distinct_structures() {
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        let a = cache.get_or_lower(
            &RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8),
            &hw,
            &knobs,
        );
        let b = cache.get_or_lower(
            &RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8),
            &hw,
            &knobs,
        );
        assert!(!Arc::ptr_eq(&a.structure, &b.structure));
        assert_eq!(a.num_ranks(), 2);
        assert_eq!(b.num_ranks(), 4);
        assert_eq!(cache.stats().structure_lowerings, 2);
    }

    #[test]
    fn same_mesh_new_shape_rebinds_instead_of_relowering() {
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        // TP structure is batch- and prompt-length-invariant: only the
        // scalar table differs between these three shapes.
        let a = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8), &hw, &knobs);
        let b = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 32), &hw, &knobs);
        let mut long_prompt = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8);
        long_prompt.seq_in = 512;
        let c = cache.get_or_lower(&long_prompt, &hw, &knobs);
        assert!(Arc::ptr_eq(&a.structure, &b.structure), "one structure serves all shapes");
        assert!(Arc::ptr_eq(&a.structure, &c.structure));
        assert!(!Arc::ptr_eq(&a.scalars, &b.scalars), "scalars are per shape");
        let st = cache.stats();
        assert_eq!((st.structure_lowerings, st.rebinds, st.shape_hits), (1, 2, 0));
        assert_eq!(cache.sizes(), (1, 3));
        assert!(st.reuse_rate() > 0.6);
    }

    #[test]
    fn pipeline_microbatch_count_is_structural() {
        // batch 2 on 4 stages -> 2 microbatches; batch 8 -> 4. Different op
        // sequences, so distinct structures; batches 8 and 32 share the
        // 4-microbatch structure.
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = knobs();
        let tiny = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 4, 2), &hw, &knobs);
        let a = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 4, 8), &hw, &knobs);
        let b = cache.get_or_lower(&RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 4, 32), &hw, &knobs);
        assert!(!Arc::ptr_eq(&tiny.structure, &a.structure));
        assert!(Arc::ptr_eq(&a.structure, &b.structure));
        assert_eq!(cache.stats().structure_lowerings, 2);
    }
}
