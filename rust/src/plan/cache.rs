//! Lowered-plan cache.
//!
//! Plans are deterministic functions of (model, parallelism, gpus, batch,
//! sequence lengths, decode-step knob, hardware) — the seed never enters
//! lowering — so the repeated passes of a profiling campaign and the sweep
//! configs that share a (model, strategy) grid cell can all execute one
//! lowered plan. The cache is shared across the `util::par` workers of a
//! campaign; on a miss the worker lowers outside the lock (a racing
//! duplicate lowering is harmless: plans are deterministic, last insert
//! wins).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::parallelism;
use crate::plan::Plan;

/// Thread-safe map from configuration identity to its lowered plan. One
/// cache instance assumes one `HwSpec` (campaigns hold hardware fixed).
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<String, Arc<Plan>>>,
    hits: Mutex<usize>,
}

/// Everything lowering depends on besides the hardware: `RunConfig::key`
/// covers model/parallelism/gpus/batch/seq_out; seq_in and the decode-step
/// knob complete the identity.
fn cache_key(cfg: &RunConfig, knobs: &SimKnobs) -> String {
    format!("{}/in{}/steps{}", cfg.key(), cfg.seq_in, knobs.sim_decode_steps)
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The lowered plan for `cfg`, reusing a cached one when the identity
    /// matches (passes of one config differ only by seed, which lowering
    /// never sees).
    pub fn get_or_lower(&self, cfg: &RunConfig, hw: &HwSpec, knobs: &SimKnobs) -> Arc<Plan> {
        let key = cache_key(cfg, knobs);
        if let Some(plan) = self.plans.lock().unwrap().get(&key) {
            *self.hits.lock().unwrap() += 1;
            return Arc::clone(plan);
        }
        let spec = crate::models::by_name(&cfg.model)
            .unwrap_or_else(|| panic!("unknown model {}", cfg.model));
        let plan = Arc::new(parallelism::lower(&spec, hw, knobs, cfg));
        self.plans
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(plan)
            .clone()
    }

    /// (cached plans, cache hits) — exposed for tests and diagnostics.
    pub fn stats(&self) -> (usize, usize) {
        (
            self.plans.lock().unwrap().len(),
            *self.hits.lock().unwrap(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;

    #[test]
    fn passes_share_one_plan() {
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8);
        let a = cache.get_or_lower(&cfg.clone().with_seed(1), &hw, &knobs);
        let b = cache.get_or_lower(&cfg.clone().with_seed(2), &hw, &knobs);
        assert!(Arc::ptr_eq(&a, &b), "seed must not fork the plan");
        assert_eq!(cache.stats(), (1, 1));
    }

    #[test]
    fn distinct_configs_get_distinct_plans() {
        let cache = PlanCache::new();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let a = cache.get_or_lower(
            &RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8),
            &hw,
            &knobs,
        );
        let b = cache.get_or_lower(
            &RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8),
            &hw,
            &knobs,
        );
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(a.num_ranks, 2);
        assert_eq!(b.num_ranks, 4);
    }
}
