//! Shape-affine rebind compilation (DESIGN.md §17).
//!
//! A cached `PlanStructure` fixes the op *sequence* of a mesh; a shape
//! rebind re-derives only the per-op scalar table (`ShapeScalars`). Today's
//! replay path (`parallelism::rebind`) does that by re-running the full
//! lowering pass per shape. This module compiles the pass **once** into a
//! symbolic *shape-affine scalar program*: while the structure is lowered,
//! the lowerers announce — via the default-no-op `PlanSink::rule` /
//! `PlanSink::comm_term` hooks — which closed-form rule produced each op's
//! scalars and each `comm_bytes_per_step` accumulation term. Rebinding a
//! new shape then evaluates the captured rules directly (an O(unique-rules)
//! pass over the interned rule set plus an O(ops) scatter), with no lowerer
//! replay.
//!
//! **Bit-identity by construction + verification.** Every rule evaluates
//! the *same* model functions the lowerer calls — `simulator::perf`
//! timings, `simulator::collective` α–β costs, `ModelSpec` payload-byte
//! helpers — with the same integer arguments and the same f64 fold order,
//! so an accepted program is bit-identical to the replay, not approximately
//! equal. The claim is still never trusted: at structure-compile time the
//! cache (`plan::cache`) evaluates the program at the compile shape and at
//! a basis of held-out probe shapes (batch, prompt length, decode-step
//! spread) and compares every scalar bit-for-bit against the replayed
//! lowering. Any mismatch — or any op the lowerer failed to annotate —
//! rejects the whole structure's program, which then falls back to the
//! `ShapeBinding` replay forever (counted in
//! `CacheStats::probe_rejected_ops`). Correctness never depends on the
//! fit; a wrong or missing rule costs coverage, not accuracy.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::Arc;

use crate::cluster::Topology;
use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::parallelism::pipeline::microbatches;
use crate::plan::exec::{ExecPlan, PlanStructure, ShapeScalars, StructureBuilder};
use crate::plan::{PlanSink, WaitRecord};
use crate::simulator::collective::{self, TieredCost};
use crate::simulator::perf::{ModuleTiming, PerfModel};
use crate::simulator::timeline::ModuleKind;

/// Symbolic batch argument of a rule: how the op's token count derives
/// from `RunConfig::batch`. All variants replay the lowerers' integer
/// arithmetic exactly (ceil-divides, GPipe microbatching, MoE top-k).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BatchArg {
    /// `cfg.batch` (tensor-parallel full batch).
    Full,
    /// `ceil(cfg.batch / d)` (data/expert shard, hybrid replica shard).
    CeilDiv(u32),
    /// `pipeline::microbatches(cfg.batch, stages).0` (GPipe microbatch).
    Micro { stages: u32 },
    /// Microbatch of a replica shard: `microbatches(ceil(batch/d), stages).0`
    /// (the PP×DP inner pipeline).
    MicroOfCeilDiv { d: u32, stages: u32 },
    /// `cfg.batch * top_k` (expert-parallel dispatch token count).
    TimesTopK,
}

impl BatchArg {
    fn eval(self, cfg: &RunConfig, top_k: usize) -> usize {
        match self {
            BatchArg::Full => cfg.batch,
            BatchArg::CeilDiv(d) => {
                let d = d as usize;
                (cfg.batch + d - 1) / d
            }
            BatchArg::Micro { stages } => microbatches(cfg.batch, stages as usize).0,
            BatchArg::MicroOfCeilDiv { d, stages } => {
                let d = d as usize;
                microbatches((cfg.batch + d - 1) / d, stages as usize).0
            }
            BatchArg::TimesTopK => cfg.batch * top_k,
        }
    }
}

/// Which roofline perf-model call produced a compute op's timing. The
/// structural arguments (sharding degree, decode-step index) are baked at
/// capture time; the shape arguments (batch, sequence lengths) stay
/// symbolic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ComputeRule {
    /// `perf.embed_decode(spec, b [* cfg.seq_in])` — token embedding
    /// (prefill embeds the whole prompt, decode one token per sequence).
    Embed { batch: BatchArg, times_seq_in: bool },
    NormPrefill { batch: BatchArg },
    AttnPrefill { batch: BatchArg, g: u32 },
    MlpPrefill { batch: BatchArg, g: u32 },
    NormDecode { batch: BatchArg },
    /// `perf.attn_decode(spec, b, context, g)` with the representative KV
    /// context of sampled decode step `si`.
    AttnDecode { batch: BatchArg, si: u32, g: u32 },
    MlpDecode { batch: BatchArg, g: u32 },
    LogitsDecode { batch: BatchArg, g: u32 },
}

/// Which α–β collective cost call priced a communication op.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollKind {
    AllReduceHier { first: u32, n: u32 },
    AllGatherRing { first: u32, n: u32, ring: u32 },
    AllToAllHier { first: u32, n: u32 },
    P2pRange { src: u32, count: u32, dst: u32 },
}

impl CollKind {
    fn eval(self, topo: &Topology, payload: f64) -> TieredCost {
        match self {
            CollKind::AllReduceHier { first, n } => {
                collective::allreduce_hier(topo, first as usize, n as usize, payload)
            }
            CollKind::AllGatherRing { first, n, ring } => {
                collective::allgather_ring(topo, first as usize, n as usize, ring as usize, payload)
            }
            CollKind::AllToAllHier { first, n } => {
                collective::alltoall_hier(topo, first as usize, n as usize, payload)
            }
            CollKind::P2pRange { src, count, dst } => {
                collective::p2p_range(topo, src as usize, count as usize, dst as usize, payload)
            }
        }
    }
}

/// Symbolic payload-byte expression of a communication op. Each variant
/// replays one of the lowerers' payload formulas token-for-token (the
/// `ModelSpec` byte helpers all share the integer product
/// `tokens × hidden × dtype_bytes`, converted to f64 once).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PayloadRule {
    /// `(b [* seq_in] * hidden * dtype_bytes) as f64` — activation bytes
    /// (covers `allreduce_payload_bytes` and `p2p_payload_bytes`).
    Acts { batch: BatchArg, times_seq_in: bool },
    /// `Acts / div as f64` — a 1/div activation shard (TP×PP boundaries).
    ActsShard { batch: BatchArg, times_seq_in: bool, div: u32 },
    /// `spec.allgather_payload_bytes(b)` — terminal logit collation.
    Ag { batch: BatchArg },
    /// `Ag / div as f64` — vocab-parallel logit shard.
    AgShard { batch: BatchArg, div: u32 },
    /// `Acts * top_k as f64 * capacity` — MoE all-to-all dispatch payload.
    ExpertActs { batch: BatchArg, times_seq_in: bool },
}

impl PayloadRule {
    fn eval(self, cx: &EvalCtx) -> f64 {
        let acts = |batch: BatchArg, times_seq_in: bool| -> f64 {
            let b = batch.eval(cx.cfg, cx.top_k);
            let n = if times_seq_in { b * cx.cfg.seq_in } else { b };
            (n * cx.spec.hidden * cx.spec.dtype_bytes) as f64
        };
        match self {
            PayloadRule::Acts { batch, times_seq_in } => acts(batch, times_seq_in),
            PayloadRule::ActsShard { batch, times_seq_in, div } => {
                acts(batch, times_seq_in) / div as f64
            }
            PayloadRule::Ag { batch } => {
                cx.spec.allgather_payload_bytes(batch.eval(cx.cfg, cx.top_k))
            }
            PayloadRule::AgShard { batch, div } => {
                cx.spec.allgather_payload_bytes(batch.eval(cx.cfg, cx.top_k)) / div as f64
            }
            PayloadRule::ExpertActs { batch, times_seq_in } => {
                acts(batch, times_seq_in) * cx.top_k as f64 * cx.capacity
            }
        }
    }
}

/// The closed-form rule behind one op slot's `(dur_s, aux)` scalars.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpRule {
    Compute(ComputeRule),
    /// Rendezvous collective: `dur = cost.transfer_s`, `aux = wire_w`.
    Collective { coll: CollKind, payload: PayloadRule },
    /// Zero-duration synchronization barrier: `(0.0, 0.0)`.
    Barrier,
    /// P2P edge producer: same scalar derivation as `Collective`.
    Send { coll: CollKind, payload: PayloadRule },
    /// P2P edge consumer: `(0.0, 0.0)` (auto-annotated by `RuleCapture`).
    Recv,
}

impl OpRule {
    fn eval(self, cx: &EvalCtx) -> (f64, f64) {
        match self {
            OpRule::Compute(c) => {
                let t = c.eval(cx);
                (t.dur_s, t.util)
            }
            OpRule::Collective { coll, payload } | OpRule::Send { coll, payload } => {
                let t = coll.eval(&cx.topo, payload.eval(cx));
                (t.cost.transfer_s, t.wire_w)
            }
            OpRule::Barrier | OpRule::Recv => (0.0, 0.0),
        }
    }
}

impl ComputeRule {
    fn eval(self, cx: &EvalCtx) -> ModuleTiming {
        let (spec, cfg, perf) = (cx.spec, cx.cfg, &cx.perf);
        match self {
            ComputeRule::Embed { batch, times_seq_in } => {
                let b = batch.eval(cfg, cx.top_k);
                let n = if times_seq_in { b * cfg.seq_in } else { b };
                perf.embed_decode(spec, n)
            }
            ComputeRule::NormPrefill { batch } => {
                perf.norm_prefill(spec, batch.eval(cfg, cx.top_k), cfg.seq_in)
            }
            ComputeRule::AttnPrefill { batch, g } => {
                perf.attn_prefill(spec, batch.eval(cfg, cx.top_k), cfg.seq_in, g as usize)
            }
            ComputeRule::MlpPrefill { batch, g } => {
                perf.mlp_prefill(spec, batch.eval(cfg, cx.top_k), cfg.seq_in, g as usize)
            }
            ComputeRule::NormDecode { batch } => perf.norm_decode(spec, batch.eval(cfg, cx.top_k)),
            ComputeRule::AttnDecode { batch, si, g } => {
                // The lowerers' representative-KV-context formula, verbatim.
                let frac = (si as f64 + 0.5) / cx.sim_steps as f64;
                let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;
                perf.attn_decode(spec, batch.eval(cfg, cx.top_k), context, g as usize)
            }
            ComputeRule::MlpDecode { batch, g } => {
                perf.mlp_decode(spec, batch.eval(cfg, cx.top_k), g as usize)
            }
            ComputeRule::LogitsDecode { batch, g } => {
                perf.logits_decode(spec, batch.eval(cfg, cx.top_k), g as usize)
            }
        }
    }
}

/// One additive term of the `comm_bytes_per_step` accumulation, emitted at
/// the lowerer's accumulation site so the replayed f64 fold order — which
/// bit-level identity depends on — is preserved exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommTerm {
    pub base: CommBase,
    pub scale: CommScale,
}

/// The bytes-moved expression of one accumulation term.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CommBase {
    /// `bytes_moved` of one collective call.
    Coll { coll: CollKind, payload: PayloadRule },
    /// `v + v` for two identical back-to-back calls (the lowerers'
    /// `comm += b1 + b2` sites — summed *before* the accumulate).
    CollPair { coll: CollKind, payload: PayloadRule },
    /// A full pipelined pass's boundary traffic:
    /// `p2p_payload_bytes(micro, 1) * (stages - 1) as f64 * num_micro as f64`
    /// with `(micro, num_micro) = microbatches(b, stages)`.
    Boundary { stages: u32, batch: BatchArg },
}

/// Scaling applied to a term's bytes value before accumulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CommScale {
    One,
    /// `v / sim_steps as f64` (terminal per-run collations).
    OverSteps,
    /// `v * k as f64` (per-shard / per-replica multiplication).
    Times(u32),
}

impl CommTerm {
    fn apply(self, acc: f64, cx: &EvalCtx) -> f64 {
        let v = match self.base {
            CommBase::Coll { coll, payload } => coll.eval(&cx.topo, payload.eval(cx)).cost.bytes_moved,
            CommBase::CollPair { coll, payload } => {
                let b = coll.eval(&cx.topo, payload.eval(cx)).cost.bytes_moved;
                b + b
            }
            CommBase::Boundary { stages, batch } => {
                let stages = stages as usize;
                let (micro, num_micro) = microbatches(batch.eval(cx.cfg, cx.top_k), stages);
                cx.spec.p2p_payload_bytes(micro, 1) * (stages - 1) as f64 * num_micro as f64
            }
        };
        let scaled = match self.scale {
            CommScale::One => v,
            CommScale::OverSteps => v / cx.sim_steps as f64,
            CommScale::Times(k) => v * k as f64,
        };
        acc + scaled
    }
}

/// Everything a rule evaluation reads besides the rule itself: the shape
/// (`cfg`), the model/hardware constants, and the derived step/routing
/// parameters — computed once per rebind, exactly as the lowerers compute
/// them.
struct EvalCtx<'a> {
    spec: &'a ModelSpec,
    cfg: &'a RunConfig,
    perf: PerfModel,
    topo: Topology,
    sim_steps: usize,
    top_k: usize,
    capacity: f64,
}

impl<'a> EvalCtx<'a> {
    fn new(spec: &'a ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &'a RunConfig) -> EvalCtx<'a> {
        let (top_k, capacity_pct) = match cfg.parallelism {
            Parallelism::Expert { top_k, capacity_pct, .. } => (top_k.max(1), capacity_pct.max(100)),
            _ => (2, 125),
        };
        EvalCtx {
            spec,
            cfg,
            perf: PerfModel::new(hw),
            topo: hw.topo(),
            sim_steps: knobs.sim_decode_steps.min(cfg.seq_out).max(1),
            top_k,
            capacity: capacity_pct as f64 / 100.0,
        }
    }
}

/// A compiled shape-affine scalar program: the interned rule set, the
/// per-op rule index, and the ordered `comm_bytes_per_step` terms.
/// Interning is where the speedup lives — a 32-layer decode pass repeats
/// each per-layer rule 32×, so the program evaluates each distinct rule
/// once and scatters the result over the op table.
#[derive(Debug)]
pub struct AffineProgram {
    pub rules: Vec<OpRule>,
    /// Rule index per op slot (`len == structure.len()`).
    pub op_rule: Vec<u32>,
    pub comm: Vec<CommTerm>,
}

impl AffineProgram {
    /// Rebind `structure` to the shape of `cfg` by evaluating the program:
    /// no lowerer call, O(unique rules) model evaluations, O(ops) scatter.
    /// Bit-identical to `parallelism::rebind` on every accepted program
    /// (enforced by the cache's compile-time probe verification).
    pub fn eval(
        &self,
        structure: &Arc<PlanStructure>,
        spec: &ModelSpec,
        hw: &HwSpec,
        knobs: &SimKnobs,
        cfg: &RunConfig,
    ) -> ExecPlan {
        debug_assert_eq!(self.op_rule.len(), structure.len());
        let cx = EvalCtx::new(spec, hw, knobs, cfg);
        let vals: Vec<(f64, f64)> = self.rules.iter().map(|r| r.eval(&cx)).collect();
        let mut dur_s = Vec::with_capacity(self.op_rule.len());
        let mut aux = Vec::with_capacity(self.op_rule.len());
        for &ri in &self.op_rule {
            let (d, a) = vals[ri as usize];
            dur_s.push(d);
            aux.push(a);
        }
        let comm_bytes_per_step = self.comm.iter().fold(0.0, |acc, t| t.apply(acc, &cx));
        ExecPlan {
            structure: Arc::clone(structure),
            scalars: Arc::new(ShapeScalars {
                dur_s,
                aux,
                sim_steps: cx.sim_steps,
                comm_bytes_per_step,
            }),
        }
    }
}

/// Number of scalar slots on which two shape tables disagree at the bit
/// level (0 ⇒ byte-identical). Shape-level metadata mismatches count as
/// whole-table rejections.
pub fn scalars_mismatch(a: &ShapeScalars, b: &ShapeScalars) -> usize {
    if a.sim_steps != b.sim_steps || a.dur_s.len() != b.dur_s.len() || a.aux.len() != b.aux.len() {
        return a.dur_s.len().max(b.dur_s.len()).max(1);
    }
    let mut m = 0;
    for i in 0..a.dur_s.len() {
        if a.dur_s[i].to_bits() != b.dur_s[i].to_bits() || a.aux[i].to_bits() != b.aux[i].to_bits() {
            m += 1;
        }
    }
    if a.comm_bytes_per_step.to_bits() != b.comm_bytes_per_step.to_bits() {
        m += 1;
    }
    m
}

/// The held-out probe basis the cache verifies a captured program against:
/// perturbations of the compile shape along prompt length, batch, and
/// decode-step spread. Probes that would change the mesh structure
/// (`parallelism::structure_key`) are filtered out by the caller; the
/// prompt-length probes never do, so at least two probes always survive.
pub fn probe_shapes(cfg: &RunConfig) -> Vec<RunConfig> {
    let mut probes = Vec::with_capacity(4);
    let mut p = cfg.clone();
    p.seq_in += 64;
    probes.push(p);
    let mut p = cfg.clone();
    p.seq_in += 192;
    probes.push(p);
    let mut p = cfg.clone();
    p.batch *= 2;
    probes.push(p);
    let mut p = cfg.clone();
    p.seq_out += 64;
    probes.push(p);
    probes
}

/// Lowering sink that compiles a structure *and* captures its shape-affine
/// program in one pass: every structural emission is forwarded to an inner
/// `StructureBuilder`, while the immediately preceding `rule()` annotation
/// is interned into the program. Ops the lowerer failed to annotate (or
/// annotated inconsistently) are counted and poison the capture — the
/// structure still compiles, only the program is discarded.
#[derive(Debug)]
pub struct RuleCapture {
    inner: StructureBuilder,
    pending: Option<OpRule>,
    interner: HashMap<OpRule, u32>,
    rules: Vec<OpRule>,
    op_rule: Vec<u32>,
    comm: Vec<CommTerm>,
    unruled: usize,
}

impl RuleCapture {
    pub fn new(num_ranks: usize) -> RuleCapture {
        RuleCapture {
            inner: StructureBuilder::new(num_ranks),
            pending: None,
            interner: HashMap::new(),
            rules: Vec::new(),
            op_rule: Vec::new(),
            comm: Vec::new(),
            unruled: 0,
        }
    }

    fn intern(&mut self, r: OpRule) -> u32 {
        if let Some(&i) = self.interner.get(&r) {
            return i;
        }
        let i = self.rules.len() as u32;
        self.rules.push(r);
        self.interner.insert(r, i);
        i
    }

    /// Consume the pending annotation for the op being emitted; a missing
    /// annotation poisons the capture (sentinel index, never evaluated).
    fn take_rule(&mut self) -> u32 {
        match self.pending.take() {
            Some(r) => self.intern(r),
            None => {
                self.unruled += 1;
                u32::MAX
            }
        }
    }

    /// Finish the compile: the `ExecPlan` is always valid; the program is
    /// `Err(unannotated op count)` when any op lacked a rule.
    pub fn finish(
        mut self,
        sim_steps: usize,
        comm_bytes_per_step: f64,
        draws_sync_jitter: bool,
    ) -> (ExecPlan, Result<AffineProgram, usize>) {
        if self.pending.take().is_some() {
            // A trailing rule() with no op behind it: corrupt capture.
            self.unruled += 1;
        }
        let ep = self.inner.finish(sim_steps, comm_bytes_per_step, draws_sync_jitter);
        let prog = if self.unruled > 0 {
            Err(self.unruled)
        } else {
            Ok(AffineProgram {
                rules: self.rules,
                op_rule: self.op_rule,
                comm: self.comm,
            })
        };
        (ep, prog)
    }
}

impl PlanSink for RuleCapture {
    fn compute(&mut self, ranks: Range<usize>, timing: ModuleTiming, module: ModuleKind, layer: u16, step: u32) {
        let ri = self.take_rule();
        self.op_rule.push(ri);
        self.inner.compute(ranks, timing, module, layer, step);
    }

    fn collective_tiered(
        &mut self,
        ranks: Range<usize>,
        module: ModuleKind,
        layer: u16,
        step: u32,
        transfer_s: f64,
        wire_w: f64,
        jitter: bool,
        record: WaitRecord,
    ) {
        let ri = self.take_rule();
        self.op_rule.push(ri);
        self.inner
            .collective_tiered(ranks, module, layer, step, transfer_s, wire_w, jitter, record);
    }

    fn send_tiered(&mut self, ranks: Range<usize>, layer: u16, step: u32, transfer_s: f64, wire_w: f64) -> u32 {
        let ri = self.take_rule();
        self.op_rule.push(ri);
        self.inner.send_tiered(ranks, layer, step, transfer_s, wire_w)
    }

    fn recv(&mut self, ranks: Range<usize>, layer: u16, step: u32, edge: u32) {
        if self.pending.take().is_some() {
            // Receives derive no scalars; a stray annotation here means the
            // lowerer mis-paired a rule with its op.
            self.unruled += 1;
        }
        let ri = self.intern(OpRule::Recv);
        self.op_rule.push(ri);
        self.inner.recv(ranks, layer, step, edge);
    }

    fn rule(&mut self, rule: OpRule) {
        if self.pending.replace(rule).is_some() {
            // The previous annotation was never consumed by an op.
            self.unruled += 1;
        }
    }

    fn comm_term(&mut self, term: CommTerm) {
        self.comm.push(term);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;
    use crate::parallelism;

    fn knobs() -> SimKnobs {
        SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        }
    }

    fn capture(cfg: &RunConfig, hw: &HwSpec, knobs: &SimKnobs) -> (ExecPlan, AffineProgram) {
        let spec = crate::models::by_name(&cfg.model).unwrap();
        let (ep, prog) = parallelism::compile_affine(&spec, hw, knobs, cfg);
        (ep, prog.expect("every stock lowerer annotates every op"))
    }

    #[test]
    fn capture_covers_all_ops_for_every_strategy() {
        let hw = HwSpec::default();
        let knobs = knobs();
        for par in [
            Parallelism::Tensor,
            Parallelism::Pipeline,
            Parallelism::Data,
            Parallelism::expert(4),
        ] {
            let cfg = RunConfig::new("Vicuna-7B", par, 4, 8);
            let (ep, prog) = capture(&cfg, &hw, &knobs);
            assert_eq!(prog.op_rule.len(), ep.len());
            assert!(
                prog.rules.len() < ep.len() / 4,
                "interning must collapse the per-layer repetition ({} rules / {} ops)",
                prog.rules.len(),
                ep.len()
            );
        }
    }

    #[test]
    fn eval_at_compile_shape_is_bit_identical() {
        let hw = HwSpec::default();
        let knobs = knobs();
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8);
        let spec = crate::models::by_name(&cfg.model).unwrap();
        let (ep, prog) = capture(&cfg, &hw, &knobs);
        let evd = prog.eval(&ep.structure, &spec, &hw, &knobs, &cfg);
        assert_eq!(scalars_mismatch(&ep.scalars, &evd.scalars), 0);
    }

    #[test]
    fn eval_matches_replay_at_probe_shapes() {
        let hw = HwSpec::default();
        let knobs = knobs();
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::expert(2), 2, 8);
        let spec = crate::models::by_name(&cfg.model).unwrap();
        let (ep, prog) = capture(&cfg, &hw, &knobs);
        let key = parallelism::structure_key(&knobs, &cfg);
        let mut probed = 0;
        for p in probe_shapes(&cfg) {
            if parallelism::structure_key(&knobs, &p) != key {
                continue;
            }
            probed += 1;
            let replay = parallelism::rebind(&ep.structure, &spec, &hw, &knobs, &p);
            let affine = prog.eval(&ep.structure, &spec, &hw, &knobs, &p);
            assert_eq!(scalars_mismatch(&replay.scalars, &affine.scalars), 0, "probe {p:?}");
        }
        assert!(probed >= 2, "prompt-length probes never change the mesh key");
    }

    #[test]
    fn unannotated_op_poisons_the_capture_not_the_plan() {
        let mut b = RuleCapture::new(2);
        // No rule() before the op: the structure must still compile.
        b.compute(0..2, ModuleTiming { dur_s: 1e-3, util: 0.7 }, ModuleKind::Mlp, 0, 0);
        let (ep, prog) = b.finish(1, 0.0, false);
        assert_eq!(ep.len(), 1);
        assert_eq!(prog.unwrap_err(), 1);
    }

    #[test]
    fn mismatch_counter_is_bit_exact() {
        let a = ShapeScalars {
            dur_s: vec![1.0, 2.0],
            aux: vec![0.5, 0.5],
            sim_steps: 2,
            comm_bytes_per_step: 64.0,
        };
        let b = ShapeScalars {
            dur_s: vec![1.0, 2.0 + f64::EPSILON],
            aux: vec![0.5, 0.5],
            sim_steps: 2,
            comm_bytes_per_step: 64.0,
        };
        assert_eq!(scalars_mismatch(&a, &a), 0);
        assert_eq!(scalars_mismatch(&a, &b), 1);
    }
}
