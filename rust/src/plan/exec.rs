//! Compiled execution layer: the structure-of-arrays `ExecPlan` and its
//! shape rebinding (DESIGN.md §12).
//!
//! A lowered run factors into two halves with very different lifetimes:
//!
//! * **Structure** ([`PlanStructure`]) — the op sequence over the rank
//!   mesh: kinds, rank ranges, module/layer/step tags, P2P edge ids,
//!   jitter and wait-record flags. It depends only on the configuration's
//!   *mesh topology* (model, strategy, GPU count, microbatch count,
//!   simulated step count) — never on payload sizes, sequence lengths, or
//!   link constants.
//! * **Shape scalars** ([`ShapeScalars`]) — the per-op scalar table:
//!   nominal roofline durations and utilizations for compute ops, transfer
//!   durations and wire powers for communication ops. This is the only
//!   part that differs between sweep/tune candidates or serving steps that
//!   share a mesh.
//!
//! The two lowering sinks here implement that split: [`StructureBuilder`]
//! lowers a configuration into both halves at once (the full lowering of a
//! new mesh), while [`ShapeBinding`] replays the same lowering pass against
//! a cached structure and re-derives *only* the scalar table — an
//! array-fill instead of an op-graph build. `plan::PlanCache` keys
//! structures by `parallelism::structure_key` and shapes by run identity,
//! so a tune grid or serving trace lowers each mesh once.
//!
//! The engine executes the arrays directly
//! (`simulator::engine::execute_compiled`) in the same op order as the
//! interpreted `Plan` walk, so seeded results are bit-identical to the
//! reference path (kept behind `SimKnobs::reference_engine` and
//! property-tested).

use std::ops::Range;
use std::sync::Arc;

use crate::plan::{Op, Plan, PlanSink, RankRange, WaitRecord};
use crate::simulator::perf::ModuleTiming;
use crate::simulator::timeline::ModuleKind;

/// Discriminant of one op slot in the structure arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Compute,
    Collective,
    Send,
    Recv,
}

/// Mesh-topology half of a compiled plan: parallel arrays over the op
/// sequence (one slot per op, in the same topological order as the
/// reference `Plan::ops`). Shared via `Arc` between every shape bound on
/// the same mesh.
#[derive(Debug)]
pub struct PlanStructure {
    pub num_ranks: usize,
    pub kind: Vec<OpKind>,
    pub ranks: Vec<RankRange>,
    pub module: Vec<ModuleKind>,
    pub layer: Vec<u16>,
    pub step: Vec<u32>,
    /// P2P edge id (`Send`/`Recv` slots; `u32::MAX` elsewhere).
    pub edge: Vec<u32>,
    /// Launch-desync jitter flag (`Collective` slots).
    pub jitter: Vec<bool>,
    /// Wait-sample recording policy (`Collective` slots).
    pub record: Vec<WaitRecord>,
    pub num_edges: u32,
    /// Whether this strategy draws the per-run launch-desync scale.
    pub draws_sync_jitter: bool,
    /// Whether this plan draws the per-rank MoE routing-imbalance
    /// multipliers — derived from the presence of all-to-all collectives
    /// at `finish` time, mirroring `Plan::draws_route_bias`.
    pub draws_route_bias: bool,
}

impl PlanStructure {
    #[inline]
    pub fn len(&self) -> usize {
        self.kind.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.kind.is_empty()
    }

    /// Number of ops per kind: (compute, collective, send, recv).
    pub fn op_census(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for k in &self.kind {
            match k {
                OpKind::Compute => c.0 += 1,
                OpKind::Collective => c.1 += 1,
                OpKind::Send => c.2 += 1,
                OpKind::Recv => c.3 += 1,
            }
        }
        c
    }
}

/// Shape half of a compiled plan: the per-op scalar table re-derived for
/// every new (batch, sequence, step) shape on an unchanged mesh — by a
/// `ShapeBinding` lowerer replay, or in O(ops) by an accepted
/// shape-affine program (`plan::affine`, DESIGN.md §17); the two paths
/// produce byte-identical tables.
#[derive(Debug)]
pub struct ShapeScalars {
    /// Per-op duration: nominal compute seconds (`Compute`), transfer
    /// seconds (`Collective`/`Send`), 0 for `Recv`.
    pub dur_s: Vec<f64>,
    /// Per-op auxiliary scalar: arithmetic utilization (`Compute`), extra
    /// transfer-phase wire power in W (`Collective`/`Send`), 0 for `Recv`.
    pub aux: Vec<f64>,
    /// Decode steps simulated explicitly (before extrapolation).
    pub sim_steps: usize,
    /// Collective/P2P payload bytes moved per simulated decode step.
    pub comm_bytes_per_step: f64,
}

/// A compiled, executable plan: shared mesh structure + bound shape
/// scalars. Cloning is two `Arc` bumps.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub structure: Arc<PlanStructure>,
    pub scalars: Arc<ShapeScalars>,
}

impl ExecPlan {
    #[inline]
    pub fn num_ranks(&self) -> usize {
        self.structure.num_ranks
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.structure.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.structure.is_empty()
    }

    /// Number of ops per kind: (compute, collective, send, recv).
    pub fn op_census(&self) -> (usize, usize, usize, usize) {
        self.structure.op_census()
    }

    /// Sub-plan containing exactly the ops whose decode-step tag satisfies
    /// `keep`, in the original order (the serving step slicer). Edge ids
    /// are left untouched — sends and receives never cross a step tag in
    /// any lowerer, so sliced plans keep every consumed edge matched and
    /// unreferenced edge slots are simply never received. The slice is a
    /// one-step plan (`sim_steps = 1`).
    pub fn slice_steps(&self, keep: impl Fn(u32) -> bool) -> ExecPlan {
        let s = &*self.structure;
        let sc = &*self.scalars;
        let idx: Vec<usize> = (0..s.len()).filter(|&i| keep(s.step[i])).collect();
        let structure = PlanStructure {
            num_ranks: s.num_ranks,
            kind: idx.iter().map(|&i| s.kind[i]).collect(),
            ranks: idx.iter().map(|&i| s.ranks[i]).collect(),
            module: idx.iter().map(|&i| s.module[i]).collect(),
            layer: idx.iter().map(|&i| s.layer[i]).collect(),
            step: idx.iter().map(|&i| s.step[i]).collect(),
            edge: idx.iter().map(|&i| s.edge[i]).collect(),
            jitter: idx.iter().map(|&i| s.jitter[i]).collect(),
            record: idx.iter().map(|&i| s.record[i]).collect(),
            num_edges: s.num_edges,
            draws_sync_jitter: s.draws_sync_jitter,
            draws_route_bias: s.draws_route_bias,
        };
        let scalars = ShapeScalars {
            dur_s: idx.iter().map(|&i| sc.dur_s[i]).collect(),
            aux: idx.iter().map(|&i| sc.aux[i]).collect(),
            sim_steps: 1,
            comm_bytes_per_step: sc.comm_bytes_per_step,
        };
        ExecPlan {
            structure: Arc::new(structure),
            scalars: Arc::new(scalars),
        }
    }
}

/// K shape-bindings of one mesh structure, laid out for a single engine
/// walk (DESIGN.md §14). The member plans' scalar columns are interleaved
/// op-major, lane-minor — `dur_s[i * width + k]` is op `i` of lane `k` —
/// so the batched resolve touches one contiguous stripe per op instead of
/// K scattered scalar tables. The lanes keep their original `ExecPlan`s
/// (Arc bumps) for per-lane phase materialization and metadata.
#[derive(Debug, Clone)]
pub struct ExecBatch {
    pub structure: Arc<PlanStructure>,
    /// Interleaved per-op durations, `len = ops × width`.
    pub dur_s: Vec<f64>,
    /// Interleaved per-op auxiliary scalars, `len = ops × width`.
    pub aux: Vec<f64>,
    /// Member plans in lane order; every lane shares `structure`.
    pub lanes: Vec<ExecPlan>,
}

impl ExecBatch {
    /// Assemble a batch from plans bound to one shared structure. Panics
    /// on an empty batch or a lane whose structure is not the same `Arc`
    /// as the first lane's (the `PlanCache` guarantees sharing for equal
    /// `parallelism::structure_key`s).
    pub fn new(lanes: Vec<ExecPlan>) -> ExecBatch {
        assert!(!lanes.is_empty(), "empty execution batch");
        let structure = Arc::clone(&lanes[0].structure);
        let n = structure.len();
        let k = lanes.len();
        let mut dur_s = vec![0.0f64; n * k];
        let mut aux = vec![0.0f64; n * k];
        for (lane, ep) in lanes.iter().enumerate() {
            assert!(
                Arc::ptr_eq(&ep.structure, &structure),
                "lane {lane} is bound to a different mesh structure"
            );
            for i in 0..n {
                dur_s[i * k + lane] = ep.scalars.dur_s[i];
                aux[i * k + lane] = ep.scalars.aux[i];
            }
        }
        ExecBatch {
            structure,
            dur_s,
            aux,
            lanes,
        }
    }

    /// Number of candidate lanes resolved per walk.
    #[inline]
    pub fn width(&self) -> usize {
        self.lanes.len()
    }
}

/// Compile an interpreted reference `Plan` into SoA form. Hot paths lower
/// straight into the arrays via `parallelism::compile`; this conversion
/// serves tests and diagnostics that already hold a `Plan`.
pub fn compile(plan: &Plan) -> ExecPlan {
    let n = plan.ops.len();
    let mut b = StructureBuilder::new(plan.num_ranks);
    b.reserve(n);
    for op in &plan.ops {
        match *op {
            Op::Compute {
                ranks,
                module,
                layer,
                step,
                nominal_s,
                util,
            } => {
                b.push(OpKind::Compute, ranks, module, layer, step, u32::MAX, false, WaitRecord::None, nominal_s, util)
            }
            Op::Collective {
                ranks,
                module,
                layer,
                step,
                transfer_s,
                wire_w,
                jitter,
                record,
            } => b.push(OpKind::Collective, ranks, module, layer, step, u32::MAX, jitter, record, transfer_s, wire_w),
            Op::Send {
                ranks,
                layer,
                step,
                transfer_s,
                wire_w,
                edge,
            } => {
                let module = ModuleKind::P2PTransfer;
                b.push(OpKind::Send, ranks, module, layer, step, edge, false, WaitRecord::None, transfer_s, wire_w);
                b.num_edges = b.num_edges.max(edge + 1);
            }
            Op::Recv { ranks, layer, step, edge } => {
                let module = ModuleKind::P2PTransfer;
                b.push(OpKind::Recv, ranks, module, layer, step, edge, false, WaitRecord::None, 0.0, 0.0)
            }
        }
    }
    b.num_edges = b.num_edges.max(plan.num_edges);
    b.finish(plan.sim_steps, plan.comm_bytes_per_step, plan.draws_sync_jitter)
}

/// Lowering sink that builds a compiled plan directly — the full lowering
/// of a mesh the cache has not seen (structure + scalars in one pass,
/// no `Vec<Op>` intermediary).
#[derive(Debug)]
pub struct StructureBuilder {
    num_ranks: usize,
    kind: Vec<OpKind>,
    ranks: Vec<RankRange>,
    module: Vec<ModuleKind>,
    layer: Vec<u16>,
    step: Vec<u32>,
    edge: Vec<u32>,
    jitter: Vec<bool>,
    record: Vec<WaitRecord>,
    num_edges: u32,
    dur_s: Vec<f64>,
    aux: Vec<f64>,
}

impl StructureBuilder {
    pub fn new(num_ranks: usize) -> StructureBuilder {
        StructureBuilder {
            num_ranks,
            kind: Vec::new(),
            ranks: Vec::new(),
            module: Vec::new(),
            layer: Vec::new(),
            step: Vec::new(),
            edge: Vec::new(),
            jitter: Vec::new(),
            record: Vec::new(),
            num_edges: 0,
            dur_s: Vec::new(),
            aux: Vec::new(),
        }
    }

    fn reserve(&mut self, n: usize) {
        self.kind.reserve(n);
        self.ranks.reserve(n);
        self.module.reserve(n);
        self.layer.reserve(n);
        self.step.reserve(n);
        self.edge.reserve(n);
        self.jitter.reserve(n);
        self.record.reserve(n);
        self.dur_s.reserve(n);
        self.aux.reserve(n);
    }

    #[allow(clippy::too_many_arguments)]
    fn push(
        &mut self,
        kind: OpKind,
        ranks: RankRange,
        module: ModuleKind,
        layer: u16,
        step: u32,
        edge: u32,
        jitter: bool,
        record: WaitRecord,
        dur_s: f64,
        aux: f64,
    ) {
        self.kind.push(kind);
        self.ranks.push(ranks);
        self.module.push(module);
        self.layer.push(layer);
        self.step.push(step);
        self.edge.push(edge);
        self.jitter.push(jitter);
        self.record.push(record);
        self.dur_s.push(dur_s);
        self.aux.push(aux);
    }

    pub fn finish(self, sim_steps: usize, comm_bytes_per_step: f64, draws_sync_jitter: bool) -> ExecPlan {
        let draws_route_bias = self
            .kind
            .iter()
            .zip(&self.module)
            .any(|(k, m)| *k == OpKind::Collective && *m == ModuleKind::AllToAll);
        ExecPlan {
            structure: Arc::new(PlanStructure {
                num_ranks: self.num_ranks,
                kind: self.kind,
                ranks: self.ranks,
                module: self.module,
                layer: self.layer,
                step: self.step,
                edge: self.edge,
                jitter: self.jitter,
                record: self.record,
                num_edges: self.num_edges,
                draws_sync_jitter,
                draws_route_bias,
            }),
            scalars: Arc::new(ShapeScalars {
                dur_s: self.dur_s,
                aux: self.aux,
                sim_steps,
                comm_bytes_per_step,
            }),
        }
    }
}

impl PlanSink for StructureBuilder {
    fn compute(&mut self, ranks: Range<usize>, timing: ModuleTiming, module: ModuleKind, layer: u16, step: u32) {
        self.push(
            OpKind::Compute,
            RankRange::of(ranks),
            module,
            layer,
            step,
            u32::MAX,
            false,
            WaitRecord::None,
            timing.dur_s,
            timing.util,
        );
    }

    fn collective_tiered(
        &mut self,
        ranks: Range<usize>,
        module: ModuleKind,
        layer: u16,
        step: u32,
        transfer_s: f64,
        wire_w: f64,
        jitter: bool,
        record: WaitRecord,
    ) {
        let ranks = RankRange::of(ranks);
        self.push(OpKind::Collective, ranks, module, layer, step, u32::MAX, jitter, record, transfer_s, wire_w);
    }

    fn send_tiered(&mut self, ranks: Range<usize>, layer: u16, step: u32, transfer_s: f64, wire_w: f64) -> u32 {
        let edge = self.num_edges;
        self.num_edges += 1;
        self.push(
            OpKind::Send,
            RankRange::of(ranks),
            ModuleKind::P2PTransfer,
            layer,
            step,
            edge,
            false,
            WaitRecord::None,
            transfer_s,
            wire_w,
        );
        edge
    }

    fn recv(&mut self, ranks: Range<usize>, layer: u16, step: u32, edge: u32) {
        debug_assert!(edge < self.num_edges, "recv of unsent edge {edge}");
        let (ranks, module) = (RankRange::of(ranks), ModuleKind::P2PTransfer);
        self.push(OpKind::Recv, ranks, module, layer, step, edge, false, WaitRecord::None, 0.0, 0.0);
    }
}

/// Lowering sink that *rebinds* a cached structure to a new shape: the
/// lowering pass is replayed, but only the scalar table is written — an
/// array fill at cursor positions, no op-graph allocation. Debug builds
/// assert the replay matches the cached structure op-for-op (the
/// `PlanSink` contract); release builds verify the op and edge counts.
#[derive(Debug)]
pub struct ShapeBinding {
    structure: Arc<PlanStructure>,
    at: usize,
    edges: u32,
    dur_s: Vec<f64>,
    aux: Vec<f64>,
}

impl ShapeBinding {
    pub fn new(structure: Arc<PlanStructure>) -> ShapeBinding {
        let n = structure.len();
        ShapeBinding {
            structure,
            at: 0,
            edges: 0,
            dur_s: Vec::with_capacity(n),
            aux: Vec::with_capacity(n),
        }
    }

    /// Record one op's scalars, debug-asserting the full structural tuple
    /// against the cached slot (the `PlanSink` contract: only scalars may
    /// vary between shapes of one mesh).
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn slot(
        &mut self,
        kind: OpKind,
        ranks: Range<usize>,
        module: ModuleKind,
        layer: u16,
        step: u32,
        dur_s: f64,
        aux: f64,
    ) {
        let i = self.at;
        debug_assert!(i < self.structure.len(), "shape rebind overruns the cached structure at op {i}");
        debug_assert_eq!(self.structure.kind[i], kind, "op {i}: kind drifted from the cached structure");
        debug_assert_eq!(
            self.structure.ranks[i],
            RankRange::of(ranks),
            "op {i}: rank range drifted from the cached structure"
        );
        debug_assert_eq!(self.structure.module[i], module, "op {i}: module drifted from the cached structure");
        debug_assert_eq!(self.structure.layer[i], layer, "op {i}: layer drifted from the cached structure");
        debug_assert_eq!(self.structure.step[i], step, "op {i}: step drifted from the cached structure");
        self.dur_s.push(dur_s);
        self.aux.push(aux);
        self.at += 1;
    }

    pub fn finish(self, sim_steps: usize, comm_bytes_per_step: f64, draws_sync_jitter: bool) -> ExecPlan {
        assert_eq!(
            self.at,
            self.structure.len(),
            "shape rebind emitted a different op count than the cached structure"
        );
        assert_eq!(
            self.edges, self.structure.num_edges,
            "shape rebind emitted a different edge count than the cached structure"
        );
        debug_assert_eq!(draws_sync_jitter, self.structure.draws_sync_jitter);
        ExecPlan {
            structure: self.structure,
            scalars: Arc::new(ShapeScalars {
                dur_s: self.dur_s,
                aux: self.aux,
                sim_steps,
                comm_bytes_per_step,
            }),
        }
    }
}

impl PlanSink for ShapeBinding {
    fn compute(&mut self, ranks: Range<usize>, timing: ModuleTiming, module: ModuleKind, layer: u16, step: u32) {
        self.slot(OpKind::Compute, ranks, module, layer, step, timing.dur_s, timing.util);
    }

    fn collective_tiered(
        &mut self,
        ranks: Range<usize>,
        module: ModuleKind,
        layer: u16,
        step: u32,
        transfer_s: f64,
        wire_w: f64,
        jitter: bool,
        record: WaitRecord,
    ) {
        let i = self.at;
        debug_assert!(
            i >= self.structure.len() || self.structure.jitter[i] == jitter,
            "op {i}: jitter flag drifted from the cached structure"
        );
        debug_assert!(
            i >= self.structure.len() || self.structure.record[i] == record,
            "op {i}: wait-record policy drifted from the cached structure"
        );
        self.slot(OpKind::Collective, ranks, module, layer, step, transfer_s, wire_w);
    }

    fn send_tiered(&mut self, ranks: Range<usize>, layer: u16, step: u32, transfer_s: f64, wire_w: f64) -> u32 {
        let edge = self.edges;
        self.edges += 1;
        let i = self.at;
        debug_assert!(
            i >= self.structure.len() || self.structure.edge[i] == edge,
            "op {i}: edge id drifted from the cached structure"
        );
        self.slot(OpKind::Send, ranks, ModuleKind::P2PTransfer, layer, step, transfer_s, wire_w);
        edge
    }

    fn recv(&mut self, ranks: Range<usize>, layer: u16, step: u32, edge: u32) {
        let i = self.at;
        debug_assert!(
            i >= self.structure.len() || self.structure.edge[i] == edge,
            "op {i}: edge id drifted from the cached structure"
        );
        self.slot(OpKind::Recv, ranks, ModuleKind::P2PTransfer, layer, step, 0.0, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::PlanBuilder;

    fn timing(dur: f64) -> ModuleTiming {
        ModuleTiming { dur_s: dur, util: 0.7 }
    }

    fn sample_plan() -> Plan {
        let mut b = PlanBuilder::new(4);
        b.compute(0..4, timing(1e-3), ModuleKind::Mlp, 0, 0);
        b.collective(0..4, ModuleKind::AllReduce, 0, 0, 1e-4, true, WaitRecord::All);
        let e = b.send(0..2, 1, 1, 2e-4);
        b.recv(2..4, 1, 1, e);
        b.compute(2..4, timing(3e-3), ModuleKind::LogitsHead, 2, 1);
        b.finish(2, 64.0, true)
    }

    #[test]
    fn compile_preserves_census_and_scalars() {
        let plan = sample_plan();
        let ep = compile(&plan);
        assert_eq!(ep.op_census(), plan.op_census());
        assert_eq!(ep.len(), plan.ops.len());
        assert_eq!(ep.num_ranks(), plan.num_ranks);
        assert_eq!(ep.structure.num_edges, plan.num_edges);
        assert!(ep.structure.draws_sync_jitter);
        assert!(!ep.structure.draws_route_bias, "no all-to-all ops here");
        assert_eq!(ep.scalars.sim_steps, 2);
        assert_eq!(ep.scalars.comm_bytes_per_step, 64.0);
        assert_eq!(ep.scalars.dur_s, vec![1e-3, 1e-4, 2e-4, 0.0, 3e-3]);
        assert_eq!(ep.scalars.aux, vec![0.7, 0.0, 0.0, 0.0, 0.7]);
        assert_eq!(ep.structure.kind[1], OpKind::Collective);
        assert!(ep.structure.jitter[1]);
        assert_eq!(ep.structure.edge[2], 0);
        assert_eq!(ep.structure.edge[3], 0);
    }

    #[test]
    fn structure_builder_matches_compiled_plan() {
        // Emitting the same sequence through the SoA sink reproduces the
        // compile() conversion exactly.
        let via_plan = compile(&sample_plan());
        let mut b = StructureBuilder::new(4);
        b.compute(0..4, timing(1e-3), ModuleKind::Mlp, 0, 0);
        b.collective(0..4, ModuleKind::AllReduce, 0, 0, 1e-4, true, WaitRecord::All);
        let e = b.send(0..2, 1, 1, 2e-4);
        b.recv(2..4, 1, 1, e);
        b.compute(2..4, timing(3e-3), ModuleKind::LogitsHead, 2, 1);
        let direct = b.finish(2, 64.0, true);
        assert_eq!(direct.structure.kind, via_plan.structure.kind);
        assert_eq!(direct.structure.ranks, via_plan.structure.ranks);
        assert_eq!(direct.structure.step, via_plan.structure.step);
        assert_eq!(direct.structure.edge, via_plan.structure.edge);
        assert_eq!(direct.scalars.dur_s, via_plan.scalars.dur_s);
        assert_eq!(direct.scalars.aux, via_plan.scalars.aux);
    }

    #[test]
    fn shape_binding_rebinds_only_scalars() {
        let base = compile(&sample_plan());
        let mut r = ShapeBinding::new(Arc::clone(&base.structure));
        r.compute(0..4, timing(2e-3), ModuleKind::Mlp, 0, 0);
        r.collective(0..4, ModuleKind::AllReduce, 0, 0, 5e-4, true, WaitRecord::All);
        let e = r.send(0..2, 1, 1, 9e-4);
        r.recv(2..4, 1, 1, e);
        r.compute(2..4, timing(4e-3), ModuleKind::LogitsHead, 2, 1);
        let rebound = r.finish(2, 128.0, true);
        assert!(Arc::ptr_eq(&rebound.structure, &base.structure), "structure is shared, not copied");
        assert_eq!(rebound.scalars.dur_s, vec![2e-3, 5e-4, 9e-4, 0.0, 4e-3]);
        assert_eq!(rebound.scalars.comm_bytes_per_step, 128.0);
    }

    #[test]
    #[should_panic(expected = "different op count")]
    fn shape_binding_rejects_short_replay() {
        let base = compile(&sample_plan());
        let mut r = ShapeBinding::new(Arc::clone(&base.structure));
        r.compute(0..4, timing(2e-3), ModuleKind::Mlp, 0, 0);
        let _ = r.finish(2, 0.0, true);
    }

    #[test]
    fn exec_batch_interleaves_lane_columns() {
        let base = compile(&sample_plan());
        let mut r = ShapeBinding::new(Arc::clone(&base.structure));
        r.compute(0..4, timing(2e-3), ModuleKind::Mlp, 0, 0);
        r.collective(0..4, ModuleKind::AllReduce, 0, 0, 5e-4, true, WaitRecord::All);
        let e = r.send(0..2, 1, 1, 9e-4);
        r.recv(2..4, 1, 1, e);
        r.compute(2..4, timing(4e-3), ModuleKind::LogitsHead, 2, 1);
        let rebound = r.finish(2, 128.0, true);
        let batch = ExecBatch::new(vec![base.clone(), rebound]);
        assert_eq!(batch.width(), 2);
        assert!(Arc::ptr_eq(&batch.structure, &base.structure));
        // Op-major, lane-minor: op 0 carries both lanes' durations first.
        assert_eq!(batch.dur_s[0], 1e-3);
        assert_eq!(batch.dur_s[1], 2e-3);
        assert_eq!(batch.dur_s[2], 1e-4);
        assert_eq!(batch.dur_s[3], 5e-4);
        assert_eq!(batch.dur_s.len(), base.len() * 2);
        assert_eq!(batch.aux.len(), base.len() * 2);
        // A width-1 batch is just the plan's own columns.
        let solo = ExecBatch::new(vec![base.clone()]);
        assert_eq!(solo.dur_s, base.scalars.dur_s);
        assert_eq!(solo.aux, base.scalars.aux);
    }

    #[test]
    #[should_panic(expected = "different mesh structure")]
    fn exec_batch_rejects_foreign_structures() {
        let a = compile(&sample_plan());
        let b = compile(&sample_plan()); // equal layout, different Arc
        let _ = ExecBatch::new(vec![a, b]);
    }

    #[test]
    fn alltoall_structures_flag_route_bias_and_survive_slicing() {
        let mut b = StructureBuilder::new(4);
        b.compute(0..4, timing(1e-3), ModuleKind::SelfAttention, 0, 0);
        b.collective(0..4, ModuleKind::AllToAll, 0, 0, 1e-4, true, WaitRecord::All);
        b.compute(0..4, timing(2e-3), ModuleKind::Mlp, 0, 1);
        b.collective(0..4, ModuleKind::AllToAll, 0, 1, 1e-4, true, WaitRecord::All);
        let ep = b.finish(1, 32.0, true);
        assert!(ep.structure.draws_route_bias);
        let decode = ep.slice_steps(|s| s > 0);
        assert!(decode.structure.draws_route_bias, "slices keep the flag");
    }

    #[test]
    fn slice_steps_partitions_and_keeps_edges() {
        let ep = compile(&sample_plan());
        let prefill = ep.slice_steps(|s| s == 0);
        let decode = ep.slice_steps(|s| s > 0);
        assert_eq!(prefill.len() + decode.len(), ep.len());
        assert!(prefill.structure.step.iter().all(|&s| s == 0));
        assert!(decode.structure.step.iter().all(|&s| s > 0));
        // Edge ids survive slicing; the decode slice holds both endpoints.
        assert_eq!(decode.structure.num_edges, ep.structure.num_edges);
        assert_eq!(decode.op_census().2, 1);
        assert_eq!(decode.op_census().3, 1);
        assert_eq!(decode.scalars.sim_steps, 1);
    }
}
