//! FLOPs accounting per module, from the standard dense-transformer
//! formulas (2 FLOPs per weight parameter per token, plus the attention
//! score/context terms). These feed the "FLOPs per token" execution feature
//! (Table 1) and the Table-2 "FLOPs/Block" column.

use super::{MlpKind, ModelSpec};

/// FLOPs for one forward pass of each module type, per *token* unless noted.
#[derive(Debug, Clone, Copy)]
pub struct ModuleFlops {
    pub attention: f64,
    pub mlp: f64,
    pub norm: f64,
    /// Per block = attention + mlp + 2 norms.
    pub block: f64,
    /// Logits head (per final token position).
    pub logits: f64,
}

impl ModuleFlops {
    /// FLOPs per token at a given KV-context length (decode step with
    /// `context` cached tokens). Prefill uses the average context S/2.
    pub fn per_token(spec: &ModelSpec, context: usize) -> Self {
        let h = spec.hidden as f64;
        let dh = spec.head_dim() as f64;
        let heads = spec.heads as f64;
        let kv_heads = spec.kv_heads as f64;
        let ctx = context as f64;

        // Projections: q [h -> heads*dh], k/v [h -> kv*dh], out [heads*dh -> h].
        let proj = 2.0 * h * (heads * dh) * 2.0 + 2.0 * h * (kv_heads * dh) * 2.0;
        // Scores + context: 2 * heads * dh * ctx each.
        let attn_core = 2.0 * 2.0 * heads * dh * ctx;
        let attention = proj + attn_core;

        let mlp = match spec.mlp {
            MlpKind::Gelu => 2.0 * 2.0 * h * spec.ffn as f64,
            MlpKind::SwiGlu => 3.0 * 2.0 * h * spec.ffn as f64,
        };
        let norm = 4.0 * h; // square, mean, rsqrt-mul, gain-mul
        let block = attention + mlp + 2.0 * norm;
        let logits = 2.0 * h * spec.vocab as f64;
        ModuleFlops {
            attention,
            mlp,
            norm,
            block,
            logits,
        }
    }

    /// GFLOPs per block for the paper's Table-2 reference workload: one
    /// 512-token sequence (average KV context 256) — the basis of the
    /// "FLOPs/Block" column.
    pub fn table2_gflops_per_block(spec: &ModelSpec) -> f64 {
        let f = Self::per_token(spec, 256);
        f.block * 512.0 / 1e9
    }
}

/// Whole-model FLOPs per generated token at TP degree `g` (per-GPU share).
pub fn model_flops_per_token(spec: &ModelSpec, context: usize, g: usize) -> f64 {
    let f = ModuleFlops::per_token(spec, context);
    (f.block * spec.layers as f64 + f.logits) / g as f64
}

/// Billions of FLOPs per token for the feature vector (whole model, g=1).
pub fn flops_per_token_billion(spec: &ModelSpec, context: usize) -> f64 {
    model_flops_per_token(spec, context, 1) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{by_name, zoo};

    #[test]
    fn table2_ordering_matches_paper() {
        // Paper Table 2: Vicuna 187 < Llama 203 < Qwen 213 < Mistral 245
        // GFLOPs/block (7-8B variants). Our formulas must preserve the
        // ordering (absolute values depend on the reference workload).
        let g = |n: &str| ModuleFlops::table2_gflops_per_block(&by_name(n).unwrap());
        let (v, l, q, m) = (
            g("Vicuna-7B"),
            g("Llama-7B"),
            g("Qwen-8B"),
            g("Mistral-8B"),
        );
        assert!(v <= l && l <= q && q <= m, "v={v:.0} l={l:.0} q={q:.0} m={m:.0}");
        // And the magnitudes are in the paper's range (≈150–300 GFLOPs).
        for x in [v, l, q, m] {
            assert!((100.0..400.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn flops_scale_with_context() {
        let m = by_name("Llama-13B").unwrap();
        let short = ModuleFlops::per_token(&m, 128).attention;
        let long = ModuleFlops::per_token(&m, 1024).attention;
        assert!(long > short);
        // Projections dominate at small context; core grows linearly.
        assert!(long < 3.0 * short);
    }

    #[test]
    fn tp_divides_model_flops() {
        let m = by_name("Qwen-14B").unwrap();
        let one = model_flops_per_token(&m, 512, 1);
        let four = model_flops_per_token(&m, 512, 4);
        assert!((one / four - 4.0).abs() < 1e-9);
    }

    #[test]
    fn larger_models_more_flops() {
        for fam in crate::models::Family::ALL {
            let vs = crate::models::family_variants(fam);
            let f: Vec<f64> = vs
                .iter()
                .map(|m| model_flops_per_token(m, 512, 1))
                .collect();
            assert!(f[0] < f[1] && f[1] < f[2], "{fam:?}: {f:?}");
        }
    }

    #[test]
    fn gqa_reduces_projection_flops() {
        // Same hidden size: Mistral-8B (kv=8) vs Vicuna-7B (kv=32):
        // Mistral's k/v projections are cheaper per token.
        let mi = by_name("Mistral-8B").unwrap();
        let vi = by_name("Vicuna-7B").unwrap();
        let mi_attn = ModuleFlops::per_token(&mi, 0).attention;
        let vi_attn = ModuleFlops::per_token(&vi, 0).attention;
        assert!(mi_attn < vi_attn);
    }

    #[test]
    fn all_flops_positive() {
        for m in zoo() {
            let f = ModuleFlops::per_token(&m, 512);
            for x in [f.attention, f.mlp, f.norm, f.block, f.logits] {
                assert!(x > 0.0, "{}", m.name);
            }
        }
    }
}
