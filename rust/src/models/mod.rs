//! Model zoo: architecture descriptors for the four LLM families the paper
//! evaluates (Vicuna, Mistral, Llama, Qwen) at the paper's sizes (7B–70B).
//!
//! Energy in the reproduction substrate depends on the architecture *shape*
//! — parameter bytes moved per token, FLOPs per module, tensor sizes
//! synchronized across GPUs — not on trained weights, so a descriptor is a
//! faithful stand-in for a checkpoint (DESIGN.md §2). Structural features
//! (Table 1, starred rows) are read directly from these descriptors.

pub mod flops;

pub use flops::ModuleFlops;

/// The four evaluated families.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Family {
    Vicuna,
    Mistral,
    Llama,
    Qwen,
}

impl Family {
    pub const ALL: [Family; 4] = [Family::Vicuna, Family::Mistral, Family::Llama, Family::Qwen];

    pub fn name(&self) -> &'static str {
        match self {
            Family::Vicuna => "Vicuna",
            Family::Mistral => "Mistral",
            Family::Llama => "Llama",
            Family::Qwen => "Qwen",
        }
    }

    pub fn parse(s: &str) -> Option<Family> {
        match s.to_ascii_lowercase().as_str() {
            "vicuna" => Some(Family::Vicuna),
            "mistral" => Some(Family::Mistral),
            "llama" => Some(Family::Llama),
            "qwen" => Some(Family::Qwen),
            _ => None,
        }
    }
}

/// Attention mechanism, per the paper's Table 2 "Modules/Block" column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    /// Standard multi-head attention (kv_heads == heads). Vicuna.
    MultiHead,
    /// Grouped-query attention (1 < kv_heads < heads). Mistral, Llama-70B.
    GroupedQuery,
    /// Multi-query attention (kv_heads == 1 or very few). Qwen.
    MultiQuery,
}

/// MLP activation family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MlpKind {
    /// Two-matrix GELU MLP.
    Gelu,
    /// Three-matrix SwiGLU (gate/up/down). Llama-family lineage.
    SwiGlu,
}

/// One model variant (e.g. "Vicuna 13B").
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub family: Family,
    /// Display name, e.g. "Vicuna-13B".
    pub name: &'static str,
    /// Nominal parameter count in billions (paper naming).
    pub params_b: f64,
    /// Hidden embedding size (d_model).
    pub hidden: usize,
    /// Attention heads.
    pub heads: usize,
    /// Key-value heads (GQA/MQA).
    pub kv_heads: usize,
    /// Feed-forward dimension.
    pub ffn: usize,
    /// Transformer blocks.
    pub layers: usize,
    /// Vocabulary size.
    pub vocab: usize,
    pub attn: AttnKind,
    pub mlp: MlpKind,
    /// Weight precision in bytes (fp16 inference).
    pub dtype_bytes: usize,
}

impl ModelSpec {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Execution-irregularity multiplier of the block (Appendix C / Table 2
    /// of the paper): more sophisticated attention mechanisms (grouped- and
    /// multi-query) generate more complex, less regular communication and
    /// memory-access patterns during synchronization, which widens timing
    /// variance. Applied to the skew/sync-jitter knobs by the planners.
    pub fn complexity_factor(&self) -> f64 {
        match self.attn {
            AttnKind::MultiHead => 1.0,
            AttnKind::MultiQuery => 1.18,
            AttnKind::GroupedQuery => 1.30,
        }
    }

    /// Exact parameter count from the architecture (differs slightly from
    /// the nominal billions in `params_b`, as with real checkpoints).
    pub fn param_count(&self) -> f64 {
        let h = self.hidden as f64;
        let dh = self.head_dim() as f64;
        let attn = h * (self.heads as f64 * dh) // Wq
            + 2.0 * h * (self.kv_heads as f64 * dh) // Wk, Wv
            + (self.heads as f64 * dh) * h; // Wo
        let mlp = match self.mlp {
            MlpKind::Gelu => 2.0 * h * self.ffn as f64,
            MlpKind::SwiGlu => 3.0 * h * self.ffn as f64,
        };
        let norms = 2.0 * h;
        let per_block = attn + mlp + norms;
        let embed = self.vocab as f64 * h; // tied in/out embedding
        per_block * self.layers as f64 + embed + h
    }

    /// Weight bytes resident per GPU under tensor parallelism of degree g
    /// (attention + MLP split; norms/embeddings replicated).
    pub fn weight_bytes_per_gpu_tp(&self, g: usize) -> f64 {
        let total = self.param_count() * self.dtype_bytes as f64;
        let replicated =
            (self.vocab as f64 * self.hidden as f64 + 2.0 * self.hidden as f64 * self.layers as f64)
                * self.dtype_bytes as f64;
        (total - replicated) / g as f64 + replicated
    }

    /// Does the model fit in `vram_bytes` per GPU at TP degree g? Margin of
    /// 5% over resident weights for runtime state; KV cache is bounded
    /// separately by the serving layer (as vLLM does on the paper testbed).
    pub fn fits_tp(&self, g: usize, vram_bytes: f64) -> bool {
        self.weight_bytes_per_gpu_tp(g) * 1.05 < vram_bytes
    }

    /// Bytes of the tensor AllReduced after the attention out-projection or
    /// the MLP down-projection under TP: one activation tensor [B, S, H].
    pub fn allreduce_payload_bytes(&self, batch: usize, tokens_per_step: usize) -> f64 {
        (batch * tokens_per_step * self.hidden * self.dtype_bytes) as f64
    }

    /// Activation bytes crossing a pipeline stage boundary per microbatch.
    pub fn p2p_payload_bytes(&self, microbatch: usize, tokens_per_step: usize) -> f64 {
        (microbatch * tokens_per_step * self.hidden * self.dtype_bytes) as f64
    }

    /// Logit bytes exchanged by the terminal data-parallel AllGather.
    pub fn allgather_payload_bytes(&self, batch: usize) -> f64 {
        (batch * self.vocab * self.dtype_bytes) as f64
    }
}

macro_rules! spec {
    ($family:ident, $name:literal, $pb:literal, h=$h:literal, heads=$heads:literal,
     kv=$kv:literal, ffn=$ffn:literal, layers=$layers:literal, vocab=$vocab:literal,
     $attn:ident, $mlp:ident) => {
        ModelSpec {
            family: Family::$family,
            name: $name,
            params_b: $pb,
            hidden: $h,
            heads: $heads,
            kv_heads: $kv,
            ffn: $ffn,
            layers: $layers,
            vocab: $vocab,
            attn: AttnKind::$attn,
            mlp: MlpKind::$mlp,
            dtype_bytes: 2,
        }
    };
}

/// The paper's evaluated variants (Section 5): Vicuna 7/13/33B,
/// Mistral 8/24/48B, Llama 7/13/70B, Qwen 8/14/32B. Hyperparameters follow
/// the public configs where they exist (Vicuna = LLaMA-1 shapes, Llama-70B
/// GQA, Qwen MQA-style low-kv) and sensible interpolations for the paper's
/// scaled variants (Mistral 24/48B).
pub fn zoo() -> Vec<ModelSpec> {
    vec![
        // Vicuna: standard self-attention + (historically) GELU-style MLP;
        // the paper calls its blocks "Standard Self-Attn., MLP".
        spec!(Vicuna, "Vicuna-7B", 7.0, h = 4096, heads = 32, kv = 32, ffn = 11008, layers = 32, vocab = 32000, MultiHead, SwiGlu),
        spec!(Vicuna, "Vicuna-13B", 13.0, h = 5120, heads = 40, kv = 40, ffn = 13824, layers = 40, vocab = 32000, MultiHead, SwiGlu),
        spec!(Vicuna, "Vicuna-33B", 33.0, h = 6656, heads = 52, kv = 52, ffn = 17920, layers = 60, vocab = 32000, MultiHead, SwiGlu),
        // Mistral: grouped-query attention (8 kv heads) + SwiGLU.
        spec!(Mistral, "Mistral-8B", 8.0, h = 4096, heads = 32, kv = 8, ffn = 14336, layers = 32, vocab = 32768, GroupedQuery, SwiGlu),
        spec!(Mistral, "Mistral-24B", 24.0, h = 6144, heads = 48, kv = 8, ffn = 20480, layers = 48, vocab = 32768, GroupedQuery, SwiGlu),
        spec!(Mistral, "Mistral-48B", 48.0, h = 8192, heads = 64, kv = 8, ffn = 24576, layers = 56, vocab = 32768, GroupedQuery, SwiGlu),
        // Llama: rotary embeddings + RMSNorm; 70B uses GQA.
        spec!(Llama, "Llama-7B", 7.0, h = 4096, heads = 32, kv = 32, ffn = 11008, layers = 32, vocab = 32000, MultiHead, SwiGlu),
        spec!(Llama, "Llama-13B", 13.0, h = 5120, heads = 40, kv = 40, ffn = 13824, layers = 40, vocab = 32000, MultiHead, SwiGlu),
        spec!(Llama, "Llama-70B", 70.0, h = 8192, heads = 64, kv = 8, ffn = 28672, layers = 80, vocab = 32000, GroupedQuery, SwiGlu),
        // Qwen: multi-query-style attention (few kv heads) + rotary.
        spec!(Qwen, "Qwen-8B", 8.0, h = 4096, heads = 32, kv = 4, ffn = 13952, layers = 36, vocab = 151936, MultiQuery, SwiGlu),
        spec!(Qwen, "Qwen-14B", 14.0, h = 5120, heads = 40, kv = 4, ffn = 13696, layers = 48, vocab = 151936, MultiQuery, SwiGlu),
        spec!(Qwen, "Qwen-32B", 32.0, h = 6656, heads = 52, kv = 4, ffn = 17920, layers = 60, vocab = 151936, MultiQuery, SwiGlu),
    ]
}

/// Look a variant up by display name (case-insensitive).
pub fn by_name(name: &str) -> Option<ModelSpec> {
    zoo().into_iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
}

/// All variants of one family.
pub fn family_variants(family: Family) -> Vec<ModelSpec> {
    zoo().into_iter().filter(|m| m.family == family).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_twelve_variants_three_per_family() {
        let z = zoo();
        assert_eq!(z.len(), 12);
        for f in Family::ALL {
            assert_eq!(z.iter().filter(|m| m.family == f).count(), 3, "{f:?}");
        }
    }

    #[test]
    fn param_counts_near_nominal() {
        for m in zoo() {
            let actual_b = m.param_count() / 1e9;
            let ratio = actual_b / m.params_b;
            assert!(
                (0.55..1.45).contains(&ratio),
                "{}: nominal {}B vs derived {:.2}B",
                m.name,
                m.params_b,
                actual_b
            );
        }
    }

    #[test]
    fn head_dims_divide() {
        for m in zoo() {
            assert_eq!(m.hidden % m.heads, 0, "{}", m.name);
            assert_eq!(m.heads % m.kv_heads, 0, "{}", m.name);
        }
    }

    #[test]
    fn tp_sharding_reduces_per_gpu_bytes() {
        let m = by_name("Vicuna-13B").unwrap();
        let one = m.weight_bytes_per_gpu_tp(1);
        let two = m.weight_bytes_per_gpu_tp(2);
        let four = m.weight_bytes_per_gpu_tp(4);
        assert!(two < one && four < two);
        // Sharded part halves; replicated part doesn't.
        assert!(four > one / 4.0);
    }

    #[test]
    fn paper_memory_gates_hold() {
        // Models exceeding one 48GB A6000: Vicuna-33B, Mistral-48B,
        // Qwen-32B, Llama-70B (Section 5); Llama-70B needs 4 GPUs.
        let vram = 48.0 * 1024.0 * 1024.0 * 1024.0;
        let gated = ["Vicuna-33B", "Mistral-48B", "Qwen-32B", "Llama-70B"];
        for m in zoo() {
            let fits1 = m.fits_tp(1, vram);
            assert_eq!(
                fits1,
                !gated.contains(&m.name),
                "{}: fits_tp(1)={} (weights/gpu {:.1} GiB)",
                m.name,
                fits1,
                m.weight_bytes_per_gpu_tp(1) / (1 << 30) as f64
            );
        }
        let llama70 = by_name("Llama-70B").unwrap();
        assert!(!llama70.fits_tp(2, vram), "Llama-70B must need 4 GPUs");
        assert!(llama70.fits_tp(4, vram));
    }

    #[test]
    fn payload_sizes_scale_with_batch_and_hidden() {
        let m = by_name("Mistral-8B").unwrap();
        assert_eq!(m.allreduce_payload_bytes(8, 1), (8 * 4096 * 2) as f64);
        assert!(m.allgather_payload_bytes(16) > m.allgather_payload_bytes(8));
    }

    #[test]
    fn family_lookup() {
        assert_eq!(Family::parse("vicuna"), Some(Family::Vicuna));
        assert_eq!(Family::parse("QWEN"), Some(Family::Qwen));
        assert_eq!(Family::parse("gpt"), None);
        assert_eq!(family_variants(Family::Llama).len(), 3);
    }
}
