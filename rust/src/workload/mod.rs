//! Experiment grids: the configuration sets behind each paper experiment.
//!
//! The paper's sampling regime (Appendix L): batch sizes {8, 16, 32, 64},
//! output lengths {512, 1024}, 2- and 4-GPU configurations, with models
//! that exceed single-GPU memory restricted to multi-GPU configs
//! (Llama-70B to 4 GPUs only).

use crate::config::{HwSpec, Parallelism, RunConfig, Strategy};
use crate::models::{self, Family, MlpKind, ModelSpec};

pub const BATCHES: [usize; 4] = [8, 16, 32, 64];
pub const SEQ_OUTS: [usize; 2] = [512, 1024];
pub const GPU_COUNTS: [usize; 2] = [2, 4];

/// KV-cache bytes one resident token costs across the whole mesh (K and V
/// per layer). The single definition behind the simulator's memory
/// features (`simulator::run`) and the serving layer's admission budget
/// (`serve::batcher`); every strategy shards the KV cache over all ranks.
pub fn kv_bytes_per_token(spec: &ModelSpec) -> f64 {
    2.0 * spec.kv_heads as f64 * spec.head_dim() as f64 * spec.dtype_bytes as f64 * spec.layers as f64
}

/// Weight bytes resident per GPU under any (pure or hybrid) parallelism.
/// This is the single memory model behind both `runnable` VRAM gating and
/// the simulator's memory-utilization features.
pub fn weights_per_gpu_bytes(spec: &ModelSpec, parallelism: Parallelism, gpus: usize) -> f64 {
    let total = spec.param_count() * spec.dtype_bytes as f64;
    match parallelism {
        Parallelism::Tensor => spec.weight_bytes_per_gpu_tp(gpus),
        // Pipeline shards layers: per-stage weights ≈ total/g.
        Parallelism::Pipeline => total / gpus as f64,
        // Data parallelism replicates the full model per GPU.
        Parallelism::Data => total,
        // Expert parallelism shards only the MLP (expert) weights across
        // the mesh; attention, norms, and embeddings are replicated like
        // data parallelism.
        Parallelism::Expert { .. } => {
            let h = spec.hidden as f64;
            let mlp_per_layer = match spec.mlp {
                MlpKind::Gelu => 2.0 * h * spec.ffn as f64,
                MlpKind::SwiGlu => 3.0 * h * spec.ffn as f64,
            };
            let mlp_total = mlp_per_layer * spec.layers as f64 * spec.dtype_bytes as f64;
            (total - mlp_total) + mlp_total / gpus as f64
        }
        Parallelism::Hybrid {
            inner,
            outer,
            inner_degree,
        } => {
            let di = inner_degree.max(1);
            let do_ = (gpus / di).max(1);
            match (inner, outer) {
                // TP within a stage, stages across groups.
                (Strategy::Tensor, Strategy::Pipeline) => spec.weight_bytes_per_gpu_tp(di) / do_ as f64,
                // TP within a replica group, full model per group.
                (Strategy::Tensor, Strategy::Data) => spec.weight_bytes_per_gpu_tp(di),
                // Pipeline within a replica group.
                (Strategy::Pipeline, Strategy::Data) => total / di as f64,
                _ => total,
            }
        }
    }
}

/// Can `spec` run under (parallelism, gpus) on this hardware? Checks the
/// mesh factorization for hybrids and a 5% runtime-state margin over the
/// resident weights for every strategy.
pub fn runnable(spec: &ModelSpec, parallelism: Parallelism, gpus: usize, hw: &HwSpec) -> bool {
    if gpus > hw.num_gpus {
        return false;
    }
    if let Parallelism::Hybrid { inner_degree, .. } = parallelism {
        // Both mesh axes need degree >= 2 and must tile the GPU count.
        if inner_degree < 2 || gpus % inner_degree != 0 || gpus / inner_degree < 2 {
            return false;
        }
    }
    if let Parallelism::Expert { degree, .. } = parallelism {
        // Expert parallelism spans the whole mesh: the label's degree must
        // name the GPU count exactly (ep4 is a 4-rank deployment).
        if degree != gpus || gpus < 2 {
            return false;
        }
    }
    weights_per_gpu_bytes(spec, parallelism, gpus) * 1.05 < hw.vram_bytes
}

/// Full grid for one model under one parallelism (paper sampling regime).
pub fn model_grid(
    spec: &ModelSpec,
    parallelism: Parallelism,
    hw: &HwSpec,
) -> Vec<RunConfig> {
    let mut out = Vec::new();
    for &g in &GPU_COUNTS {
        if !runnable(spec, parallelism, g, hw) {
            continue;
        }
        for &b in &BATCHES {
            for &s in &SEQ_OUTS {
                out.push(RunConfig::new(spec.name, parallelism, g, b).with_seq_out(s));
            }
        }
    }
    out
}

/// Tensor-parallel grid over every variant of a family.
pub fn family_grid_tp(family: Family, hw: &HwSpec) -> Vec<RunConfig> {
    models::family_variants(family)
        .iter()
        .flat_map(|m| model_grid(m, Parallelism::Tensor, hw))
        .collect()
}

/// The Figure-2 campaign: all four families under tensor parallelism.
pub fn paper_grid_tp(hw: &HwSpec) -> Vec<RunConfig> {
    Family::ALL
        .iter()
        .flat_map(|&f| family_grid_tp(f, hw))
        .collect()
}

/// Figure-4 campaigns: Vicuna family under pipeline / data parallelism.
pub fn vicuna_grid(parallelism: Parallelism, hw: &HwSpec) -> Vec<RunConfig> {
    models::family_variants(Family::Vicuna)
        .iter()
        .flat_map(|m| model_grid(m, parallelism, hw))
        .collect()
}

/// Expert-parallel grid over one family: full-mesh EP (`ep{g}`) at each
/// GPU count of the paper regime, gated by the EP VRAM model (only the
/// MLP/expert weights shard across ranks).
pub fn family_grid_expert(family: Family, hw: &HwSpec) -> Vec<RunConfig> {
    let mut out = Vec::new();
    for spec in models::family_variants(family) {
        for &g in &GPU_COUNTS {
            let par = Parallelism::expert(g);
            if !runnable(&spec, par, g, hw) {
                continue;
            }
            for &b in &BATCHES {
                for &s in &SEQ_OUTS {
                    out.push(RunConfig::new(spec.name, par, g, b).with_seq_out(s));
                }
            }
        }
    }
    out
}

/// Inner degrees that factor a `gpus`-rank mesh into two axes of degree
/// >= 2 each (e.g. 4 -> [2], 8 -> [2, 4], 2 -> []).
pub fn hybrid_inner_degrees(gpus: usize) -> Vec<usize> {
    (2..=gpus / 2).filter(|d| gpus % d == 0).collect()
}

/// Every deployment strategy realizable on a `gpus`-rank mesh: the three
/// pure paper strategies, every canonical hybrid factorization, and (on
/// meshes of ≥ 2 ranks) full-mesh expert parallelism — the search axis of
/// the energy-aware autotuner (`eval::tune`).
pub fn deployment_candidates(gpus: usize) -> Vec<Parallelism> {
    let mut out = Parallelism::ALL.to_vec();
    out.extend(hybrid_parallelisms(gpus));
    if gpus >= 2 {
        out.push(Parallelism::expert(gpus));
    }
    out
}

/// Every canonical hybrid parallelism realizable on a `gpus`-rank mesh.
pub fn hybrid_parallelisms(gpus: usize) -> Vec<Parallelism> {
    let mut out = Vec::new();
    for d in hybrid_inner_degrees(gpus) {
        for (inner, outer) in Parallelism::HYBRID_COMBOS {
            if let Some(p) = Parallelism::hybrid(inner, outer, d) {
                out.push(p);
            }
        }
    }
    out
}

/// Hybrid grid for one (inner, outer) combination over the whole zoo:
/// every GPU count that admits a 2-D mesh, the paper's batch/output-length
/// regime, gated by the `runnable` VRAM checks.
pub fn hybrid_combo_grid(inner: Strategy, outer: Strategy, hw: &HwSpec) -> Vec<RunConfig> {
    let mut out = Vec::new();
    for spec in models::zoo() {
        for &g in &GPU_COUNTS {
            for d in hybrid_inner_degrees(g) {
                let Some(par) = Parallelism::hybrid(inner, outer, d) else {
                    continue;
                };
                if !runnable(&spec, par, g, hw) {
                    continue;
                }
                for &b in &BATCHES {
                    for &s in &SEQ_OUTS {
                        out.push(RunConfig::new(spec.name, par, g, b).with_seq_out(s));
                    }
                }
            }
        }
    }
    out
}

/// The full hybrid campaign: all three canonical combinations.
pub fn hybrid_grid(hw: &HwSpec) -> Vec<RunConfig> {
    Parallelism::HYBRID_COMBOS
        .iter()
        .flat_map(|&(inner, outer)| hybrid_combo_grid(inner, outer, hw))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwSpec {
        HwSpec::default()
    }

    #[test]
    fn llama70b_only_on_4_gpus_tp() {
        let spec = models::by_name("Llama-70B").unwrap();
        assert!(!runnable(&spec, Parallelism::Tensor, 2, &hw()));
        assert!(runnable(&spec, Parallelism::Tensor, 4, &hw()));
        let grid = model_grid(&spec, Parallelism::Tensor, &hw());
        assert!(grid.iter().all(|c| c.gpus == 4));
        assert_eq!(grid.len(), 8); // 4 batches × 2 seqs
    }

    #[test]
    fn vicuna33b_excluded_from_dp() {
        // Section 5.3: Vicuna-33B does not fit in single-GPU memory, so no
        // data-parallel configs exist for it.
        let spec = models::by_name("Vicuna-33B").unwrap();
        assert!(model_grid(&spec, Parallelism::Data, &hw()).is_empty());
        // But it runs under TP and PP.
        assert!(!model_grid(&spec, Parallelism::Tensor, &hw()).is_empty());
        assert!(!model_grid(&spec, Parallelism::Pipeline, &hw()).is_empty());
    }

    #[test]
    fn small_models_get_both_gpu_counts() {
        let spec = models::by_name("Vicuna-7B").unwrap();
        let grid = model_grid(&spec, Parallelism::Tensor, &hw());
        assert_eq!(grid.len(), 16); // 2 gpu counts × 4 batches × 2 seqs
        assert!(grid.iter().any(|c| c.gpus == 2));
        assert!(grid.iter().any(|c| c.gpus == 4));
    }

    #[test]
    fn paper_grid_covers_all_families() {
        let grid = paper_grid_tp(&hw());
        for f in Family::ALL {
            assert!(
                grid.iter()
                    .any(|c| models::by_name(&c.model).unwrap().family == f),
                "{f:?} missing"
            );
        }
        // Sanity on total size: 12 variants × ≤16 configs.
        assert!(grid.len() > 100 && grid.len() <= 12 * 16, "{}", grid.len());
    }

    #[test]
    fn pipeline_admits_large_models() {
        let spec = models::by_name("Mistral-48B").unwrap();
        assert!(runnable(&spec, Parallelism::Pipeline, 4, &hw()));
        assert!(!runnable(&spec, Parallelism::Data, 2, &hw()));
    }

    #[test]
    fn gpu_count_exceeding_host_rejected() {
        let spec = models::by_name("Vicuna-7B").unwrap();
        assert!(!runnable(&spec, Parallelism::Tensor, 8, &hw()));
    }

    #[test]
    fn hybrid_inner_degree_factorizations() {
        assert!(hybrid_inner_degrees(2).is_empty());
        assert_eq!(hybrid_inner_degrees(4), vec![2]);
        assert_eq!(hybrid_inner_degrees(8), vec![2, 4]);
        assert_eq!(hybrid_inner_degrees(6), vec![2, 3]);
        // 4 GPUs admit exactly the three canonical combos at degree 2.
        assert_eq!(hybrid_parallelisms(4).len(), 3);
        assert!(hybrid_parallelisms(2).is_empty());
    }

    #[test]
    fn deployment_candidates_cover_pure_hybrid_and_expert() {
        let c2 = deployment_candidates(2);
        assert_eq!(c2.len(), 3 + 1); // pure strategies + ep2
        assert!(c2.contains(&Parallelism::expert(2)));
        let c4 = deployment_candidates(4);
        assert_eq!(c4.len(), 3 + 3 + 1);
        assert!(c4.contains(&Parallelism::Tensor));
        assert!(c4.iter().any(|p| p.is_hybrid()));
        assert!(c4.contains(&Parallelism::expert(4)));
    }

    #[test]
    fn expert_vram_sits_between_tensor_and_data() {
        // EP shards only the MLP weights: heavier than TP (which also
        // shards attention) but lighter than full DP replication.
        let spec = models::by_name("Vicuna-13B").unwrap();
        let total = spec.param_count() * spec.dtype_bytes as f64;
        let ep = weights_per_gpu_bytes(&spec, Parallelism::expert(4), 4);
        let tp = weights_per_gpu_bytes(&spec, Parallelism::Tensor, 4);
        assert!(ep > tp, "ep {ep} vs tp {tp}");
        assert!(ep < total, "ep {ep} vs dp {total}");
        // And EP admits models DP cannot host.
        let v33 = models::by_name("Vicuna-33B").unwrap();
        assert!(!runnable(&v33, Parallelism::Data, 4, &hw()));
        assert!(runnable(&v33, Parallelism::expert(4), 4, &hw()));
        // The label's degree must name the mesh exactly.
        assert!(!runnable(&spec, Parallelism::expert(4), 2, &hw()));
        assert!(!runnable(&spec, Parallelism::expert(2), 4, &hw()));
    }

    #[test]
    fn expert_grid_spans_the_vicuna_family() {
        let grid = family_grid_expert(Family::Vicuna, &hw());
        assert!(!grid.is_empty());
        for c in &grid {
            // Degree always tracks the GPU count, and every config
            // re-validates against the EP VRAM model.
            assert_eq!(c.parallelism.expert_degree(c.gpus), c.gpus, "{}", c.key());
            let spec = models::by_name(&c.model).unwrap();
            assert!(runnable(&spec, c.parallelism, c.gpus, &hw()), "{}", c.key());
        }
        // The 33B — which DP cannot host at all — appears under EP, and
        // the 7B gets both GPU counts of the paper regime.
        assert!(grid.iter().any(|c| c.model == "Vicuna-33B" && c.gpus == 4));
        assert!(grid.iter().any(|c| c.model == "Vicuna-7B" && c.gpus == 2));
        assert!(grid.iter().any(|c| c.model == "Vicuna-7B" && c.gpus == 4));
    }

    #[test]
    fn hybrid_needs_a_two_by_two_mesh() {
        let spec = models::by_name("Vicuna-7B").unwrap();
        let p = Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap();
        assert!(runnable(&spec, p, 4, &hw()));
        assert!(!runnable(&spec, p, 2, &hw()), "no outer axis on 2 GPUs");
        // Degree must tile the mesh.
        let p3 = Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 3).unwrap();
        assert!(!runnable(&spec, p3, 4, &hw()));
    }

    #[test]
    fn hybrid_vram_gating_llama70b() {
        // Llama-70B on 4 GPUs: only TP×PP shards weights across both axes
        // aggressively enough; TP×DP needs the whole model per 2-rank group
        // and PP×DP per 2-stage replica — both exceed 48 GB/GPU.
        let spec = models::by_name("Llama-70B").unwrap();
        let tp_pp = Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap();
        let tp_dp = Parallelism::hybrid(Strategy::Tensor, Strategy::Data, 2).unwrap();
        let pp_dp = Parallelism::hybrid(Strategy::Pipeline, Strategy::Data, 2).unwrap();
        assert!(runnable(&spec, tp_pp, 4, &hw()));
        assert!(!runnable(&spec, tp_dp, 4, &hw()));
        assert!(!runnable(&spec, pp_dp, 4, &hw()));
    }

    #[test]
    fn hybrid_grid_covers_all_combos_and_respects_gating() {
        let grid = hybrid_grid(&hw());
        assert!(!grid.is_empty());
        for (inner, outer) in Parallelism::HYBRID_COMBOS {
            assert!(
                grid.iter().any(|c| {
                    matches!(c.parallelism, Parallelism::Hybrid { inner: i, outer: o, .. }
                        if i == inner && o == outer)
                }),
                "{inner:?}x{outer:?} missing"
            );
        }
        // Every config re-validates against runnable and sits on >= 4 GPUs.
        for c in &grid {
            let spec = models::by_name(&c.model).unwrap();
            assert!(runnable(&spec, c.parallelism, c.gpus, &hw()), "{}", c.key());
            assert!(c.gpus >= 4);
        }
        // Llama-70B only appears under TP×PP.
        for c in grid.iter().filter(|c| c.model == "Llama-70B") {
            match c.parallelism {
                Parallelism::Hybrid { inner, outer, .. } => {
                    assert_eq!((inner, outer), (Strategy::Tensor, Strategy::Pipeline));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn weights_per_gpu_consistent_with_pure_strategies() {
        let spec = models::by_name("Vicuna-13B").unwrap();
        let total = spec.param_count() * spec.dtype_bytes as f64;
        assert_eq!(weights_per_gpu_bytes(&spec, Parallelism::Data, 4), total);
        assert_eq!(
            weights_per_gpu_bytes(&spec, Parallelism::Tensor, 4),
            spec.weight_bytes_per_gpu_tp(4)
        );
        assert_eq!(weights_per_gpu_bytes(&spec, Parallelism::Pipeline, 4), total / 4.0);
        // Hybrids shard across both axes.
        let tp_pp = Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap();
        let w = weights_per_gpu_bytes(&spec, tp_pp, 4);
        assert!(w < weights_per_gpu_bytes(&spec, Parallelism::Tensor, 2));
        assert!(w < total / 2.0);
    }
}
