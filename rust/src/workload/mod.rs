//! Experiment grids: the configuration sets behind each paper experiment.
//!
//! The paper's sampling regime (Appendix L): batch sizes {8, 16, 32, 64},
//! output lengths {512, 1024}, 2- and 4-GPU configurations, with models
//! that exceed single-GPU memory restricted to multi-GPU configs
//! (Llama-70B to 4 GPUs only).

use crate::config::{HwSpec, Parallelism, RunConfig};
use crate::models::{self, Family, ModelSpec};

pub const BATCHES: [usize; 4] = [8, 16, 32, 64];
pub const SEQ_OUTS: [usize; 2] = [512, 1024];
pub const GPU_COUNTS: [usize; 2] = [2, 4];

/// Can `spec` run under (parallelism, gpus) on this hardware?
pub fn runnable(spec: &ModelSpec, parallelism: Parallelism, gpus: usize, hw: &HwSpec) -> bool {
    if gpus > hw.num_gpus {
        return false;
    }
    match parallelism {
        Parallelism::Tensor => spec.fits_tp(gpus, hw.vram_bytes),
        // Pipeline shards layers: per-stage weights ≈ total/g.
        Parallelism::Pipeline => {
            spec.param_count() * spec.dtype_bytes as f64 / gpus as f64 * 1.05 < hw.vram_bytes
        }
        // Data parallelism replicates the full model per GPU.
        Parallelism::Data => spec.fits_tp(1, hw.vram_bytes),
    }
}

/// Full grid for one model under one parallelism (paper sampling regime).
pub fn model_grid(
    spec: &ModelSpec,
    parallelism: Parallelism,
    hw: &HwSpec,
) -> Vec<RunConfig> {
    let mut out = Vec::new();
    for &g in &GPU_COUNTS {
        if !runnable(spec, parallelism, g, hw) {
            continue;
        }
        for &b in &BATCHES {
            for &s in &SEQ_OUTS {
                out.push(RunConfig::new(spec.name, parallelism, g, b).with_seq_out(s));
            }
        }
    }
    out
}

/// Tensor-parallel grid over every variant of a family.
pub fn family_grid_tp(family: Family, hw: &HwSpec) -> Vec<RunConfig> {
    models::family_variants(family)
        .iter()
        .flat_map(|m| model_grid(m, Parallelism::Tensor, hw))
        .collect()
}

/// The Figure-2 campaign: all four families under tensor parallelism.
pub fn paper_grid_tp(hw: &HwSpec) -> Vec<RunConfig> {
    Family::ALL
        .iter()
        .flat_map(|&f| family_grid_tp(f, hw))
        .collect()
}

/// Figure-4 campaigns: Vicuna family under pipeline / data parallelism.
pub fn vicuna_grid(parallelism: Parallelism, hw: &HwSpec) -> Vec<RunConfig> {
    models::family_variants(Family::Vicuna)
        .iter()
        .flat_map(|m| model_grid(m, parallelism, hw))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwSpec {
        HwSpec::default()
    }

    #[test]
    fn llama70b_only_on_4_gpus_tp() {
        let spec = models::by_name("Llama-70B").unwrap();
        assert!(!runnable(&spec, Parallelism::Tensor, 2, &hw()));
        assert!(runnable(&spec, Parallelism::Tensor, 4, &hw()));
        let grid = model_grid(&spec, Parallelism::Tensor, &hw());
        assert!(grid.iter().all(|c| c.gpus == 4));
        assert_eq!(grid.len(), 8); // 4 batches × 2 seqs
    }

    #[test]
    fn vicuna33b_excluded_from_dp() {
        // Section 5.3: Vicuna-33B does not fit in single-GPU memory, so no
        // data-parallel configs exist for it.
        let spec = models::by_name("Vicuna-33B").unwrap();
        assert!(model_grid(&spec, Parallelism::Data, &hw()).is_empty());
        // But it runs under TP and PP.
        assert!(!model_grid(&spec, Parallelism::Tensor, &hw()).is_empty());
        assert!(!model_grid(&spec, Parallelism::Pipeline, &hw()).is_empty());
    }

    #[test]
    fn small_models_get_both_gpu_counts() {
        let spec = models::by_name("Vicuna-7B").unwrap();
        let grid = model_grid(&spec, Parallelism::Tensor, &hw());
        assert_eq!(grid.len(), 16); // 2 gpu counts × 4 batches × 2 seqs
        assert!(grid.iter().any(|c| c.gpus == 2));
        assert!(grid.iter().any(|c| c.gpus == 4));
    }

    #[test]
    fn paper_grid_covers_all_families() {
        let grid = paper_grid_tp(&hw());
        for f in Family::ALL {
            assert!(
                grid.iter()
                    .any(|c| models::by_name(&c.model).unwrap().family == f),
                "{f:?} missing"
            );
        }
        // Sanity on total size: 12 variants × ≤16 configs.
        assert!(grid.len() > 100 && grid.len() <= 12 * 16, "{}", grid.len());
    }

    #[test]
    fn pipeline_admits_large_models() {
        let spec = models::by_name("Mistral-48B").unwrap();
        assert!(runnable(&spec, Parallelism::Pipeline, 4, &hw()));
        assert!(!runnable(&spec, Parallelism::Data, 2, &hw()));
    }

    #[test]
    fn gpu_count_exceeding_host_rejected() {
        let spec = models::by_name("Vicuna-7B").unwrap();
        assert!(!runnable(&spec, Parallelism::Tensor, 8, &hw()));
    }
}
