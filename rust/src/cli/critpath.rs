//! `piep critpath` — critical-path energy attribution per strategy
//! (DESIGN.md §15).
//!
//! Runs each strategy once with the execution trace captured, extracts the
//! makespan-defining chain (`trace::critpath`), and reports on-path vs.
//! off-path (slack) vs. idle energy, the binding resource, and the
//! per-module on-path split. `--export FILE` writes the first strategy's
//! Perfetto/Chrome trace-event JSON; `--out DIR` saves the summary CSV
//! plus one trace JSON per strategy (the CI smoke artifacts).

use crate::config::{Parallelism, RunConfig, SimKnobs, Strategy};
use crate::simulator::run::execute_traced;
use crate::trace::critpath::critical_path_with;
use crate::trace::export::perfetto_json;
use crate::util::cli::Args;
use crate::util::table::{fnum, pct, Table};

pub(crate) fn cmd_critpath(args: &Args) {
    let smoke = args.has("smoke");
    // --smoke pins the CI scenario set: TP/PP/tp2xpp on the shared 2-node
    // NVLink+IB cluster testbed.
    let testbed = super::topo::parse_testbed(args, true);
    let hw = testbed.hw();

    let model = args.get_or("model", "Vicuna-7B").to_string();
    let gpus = args.get_usize("gpus", hw.num_gpus);
    let batch = args.get_usize("batch", 8);
    let seq_out = args.get_usize("seq-out", 512);
    let seed = args.get_u64("seed", 0xC817);
    let knobs = SimKnobs {
        sim_decode_steps: args.get_usize("steps", if smoke { 4 } else { 8 }),
        ..SimKnobs::default()
    };

    let strategies: Vec<Parallelism> = args
        .get("strategies")
        .map(|list| {
            list.split(',')
                .map(|l| Parallelism::parse(l.trim()).unwrap_or_else(|| panic!("bad strategy label {l}")))
                .collect()
        })
        .unwrap_or_else(|| {
            let mut out = vec![Parallelism::Tensor, Parallelism::Pipeline];
            if gpus >= 4 {
                out.push(Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap());
            }
            if gpus >= 2 {
                out.push(Parallelism::expert(gpus));
            }
            out
        });

    eprintln!(
        "[critpath] {model} on {} ({} GPUs): {} strategies, batch {batch}, seed {seed:#x}",
        testbed.label(),
        gpus,
        strategies.len()
    );

    let topo = hw.topo();
    let mut summary = Table::new(
        "Critpath — makespan-defining chain and energy attribution per strategy",
        &["Strategy", "Makespan s", "CritLen s", "OnPath J", "OffPath J", "Idle J", "CritPct", "BoundBy"],
    );
    let mut modules = Table::new(
        "Critpath — on-path energy by module",
        &["Strategy", "Module", "OnPath J", "Share"],
    );
    let mut steps_t = Table::new(
        "Critpath — per-step on-path slices",
        &["Strategy", "Step", "OnPath s", "OnPath J", "BoundBy"],
    );
    let mut exported = false;
    let mut traces: Vec<(String, String)> = Vec::new();
    let need_json = args.get("export").is_some() || args.get("out").is_some();

    for &par in &strategies {
        let cfg = RunConfig::new(&model, par, gpus, batch)
            .with_seq_out(seq_out)
            .with_seed(seed);
        let (plan, built) = execute_traced(&cfg, &hw, &knobs);
        let trace = built.trace.as_ref().expect("execute_traced captures the trace");
        let tl = &built.timeline;
        let cp = critical_path_with(tl, Some((trace, &plan, &topo)));

        // The three buckets partition the timeline: conservation is exact.
        let total = tl.gpu_energy_j();
        let attributed = cp.on_path_j + cp.off_path_j + cp.idle_j;
        assert!(
            (attributed - total).abs() <= 1e-9 * total.max(1e-12),
            "critpath attribution must conserve timeline energy ({attributed} vs {total})"
        );

        summary.row(vec![
            par.label(),
            fnum(cp.makespan_s, 4),
            fnum(cp.len_s, 4),
            fnum(cp.on_path_j, 1),
            fnum(cp.off_path_j, 1),
            fnum(cp.idle_j, 1),
            pct(100.0 * cp.on_path_share()),
            cp.bound_by().name().into(),
        ]);
        for (m, j) in &cp.energy_by_module {
            modules.row(vec![
                par.label(),
                m.name().into(),
                fnum(*j, 1),
                pct(100.0 * j / cp.on_path_j.max(1e-12)),
            ]);
        }
        if args.has("per-step") {
            for s in &cp.steps {
                steps_t.row(vec![
                    par.label(),
                    s.step.to_string(),
                    fnum(s.on_s, 5),
                    fnum(s.on_j, 2),
                    s.bound_by.name().into(),
                ]);
            }
        }

        if need_json {
            let json = perfetto_json(tl, trace, Some(&plan), Some(&topo));
            if !exported {
                if let Some(path) = args.get("export") {
                    std::fs::write(path, &json).expect("write trace export");
                    println!("exported Perfetto trace (first strategy) -> {path}");
                }
                exported = true;
            }
            traces.push((par.label(), json));
        }
    }

    print!("{}", summary.render());
    print!("{}", modules.render());
    if args.has("per-step") {
        print!("{}", steps_t.render());
    }

    if let Some(out) = args.get("out") {
        std::fs::create_dir_all(out).expect("create --out dir");
        match summary.save_csv(out, "critpath") {
            Ok(path) => println!("  -> {path}"),
            Err(e) => eprintln!("  !! could not save critpath.csv: {e}"),
        }
        for (label, json) in &traces {
            let path = format!("{out}/trace_{label}.json");
            std::fs::write(&path, json).expect("write trace json");
            println!("  -> {path}");
        }
    }
}
