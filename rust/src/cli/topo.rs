//! Shared testbed flags: one vocabulary for every subcommand.
//!
//! `--gpus` (flat box) and `--nodes/--gpus-per-node/--intra/--inter/--fleet`
//! (cluster) describe *where* a subcommand runs. `plan`, `sweep`, `serve`,
//! `bench-sim`, `tune`, and `fleet` all parse them through this module, so
//! the flags mean exactly the same thing everywhere and the help text has
//! one block to document them. Parsing produces a [`TestbedSpec`] — the
//! config-layer value that resolves to an `HwSpec` — rather than raw
//! hardware, so drivers can also label and forward the testbed.

use crate::cluster::{GpuSpec, LinkTier};
use crate::config::{HwSpec, TestbedSpec};
use crate::util::cli::Args;

/// Help block for the shared testbed flags (printed once in `piep help`).
pub(crate) const TOPO_HELP: &str = "\
\x20 --gpus N                   flat single-node testbed with N GPUs\n\
\x20 --nodes N                  cluster testbed: node count (any cluster flag\n\
\x20                            below selects the cluster form)\n\
\x20 --gpus-per-node N          cluster testbed: GPUs per node\n\
\x20 --intra nvlink|pcie|ib     intra-node link tier (default nvlink)\n\
\x20 --inter nvlink|pcie|ib     inter-node link tier (default ib)\n\
\x20 --fleet a6000,h100,l40     heterogeneous per-node GPU classes";

/// Parse the shared testbed flags into a [`TestbedSpec`].
///
/// Any explicit cluster-shaping flag (including `--nodes 1` or a bare
/// `--gpus-per-node`) builds the cluster form; a flagless invocation keeps
/// the flat default box. When `smoke_implies_cluster` is set (tune, fleet),
/// `--smoke` also pins the CI cluster: 2 nodes × 2 GPUs over NVLink + IB —
/// subcommands whose `--smoke` only shrinks the workload pass `false` so
/// their testbed is unchanged.
pub(crate) fn parse_testbed(args: &Args, smoke_implies_cluster: bool) -> TestbedSpec {
    let smoke = smoke_implies_cluster && args.has("smoke");
    let nodes = args.get_usize("nodes", if smoke { 2 } else { 1 });
    let default_gpn = if smoke { 2 } else { HwSpec::default().num_gpus };
    let gpus_per_node = args.get_usize("gpus-per-node", default_gpn);
    let cluster_requested = smoke
        || args.has("nodes")
        || args.has("gpus-per-node")
        || args.has("intra")
        || args.has("inter")
        || args.has("fleet");
    if cluster_requested {
        let intra = LinkTier::parse(args.get_or("intra", "nvlink")).expect("intra tier (nvlink|pcie|ib)");
        let inter = LinkTier::parse(args.get_or("inter", "ib")).expect("inter tier (nvlink|pcie|ib)");
        let fleet: Vec<GpuSpec> = args
            .get("fleet")
            .map(|s| {
                s.split(',')
                    .map(|name| GpuSpec::parse(name.trim()).unwrap_or_else(|| panic!("unknown GPU class {name}")))
                    .collect()
            })
            .unwrap_or_default();
        TestbedSpec::Cluster {
            nodes,
            gpus_per_node,
            intra,
            inter,
            fleet,
        }
    } else {
        TestbedSpec::Flat {
            gpus: args.get_usize("gpus", HwSpec::default().num_gpus),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn flagless_invocation_keeps_the_flat_default() {
        let t = parse_testbed(&parse("sweep"), false);
        assert_eq!(t, TestbedSpec::Flat { gpus: HwSpec::default().num_gpus });
        let t = parse_testbed(&parse("serve --gpus 8"), false);
        assert_eq!(t, TestbedSpec::Flat { gpus: 8 });
    }

    #[test]
    fn any_cluster_flag_selects_the_cluster_form() {
        for argv in ["tune --nodes 1", "plan --gpus-per-node 4", "sim --inter pcie", "sweep --fleet h100"] {
            let t = parse_testbed(&parse(argv), false);
            assert!(matches!(t, TestbedSpec::Cluster { .. }), "{argv}");
        }
        let t = parse_testbed(&parse("tune --nodes 3 --gpus-per-node 2 --intra pcie --inter ib --fleet a6000,h100"), false);
        match t {
            TestbedSpec::Cluster { nodes, gpus_per_node, intra, inter, fleet } => {
                assert_eq!((nodes, gpus_per_node), (3, 2));
                assert_eq!((intra, inter), (LinkTier::PciE, LinkTier::InfiniBand));
                assert_eq!(fleet.len(), 2);
            }
            other => panic!("expected cluster, got {other:?}"),
        }
    }

    #[test]
    fn smoke_pins_the_ci_cluster_only_where_asked() {
        let args = parse("tune --smoke");
        let t = parse_testbed(&args, true);
        assert_eq!(
            t,
            TestbedSpec::Cluster {
                nodes: 2,
                gpus_per_node: 2,
                intra: LinkTier::NvLink,
                inter: LinkTier::InfiniBand,
                fleet: Vec::new(),
            }
        );
        // serve/sweep/sim/plan --smoke only shrinks the workload.
        assert_eq!(parse_testbed(&args, false), TestbedSpec::Flat { gpus: HwSpec::default().num_gpus });
    }

    #[test]
    fn resolved_hardware_matches_the_direct_constructors() {
        let flat = parse_testbed(&parse("plan --gpus 2"), false).hw();
        assert_eq!(flat.num_gpus, 2);
        let cluster = parse_testbed(&parse("tune --nodes 2 --gpus-per-node 2"), false).hw();
        let direct = HwSpec::cluster_testbed(2, 2, LinkTier::NvLink, LinkTier::InfiniBand, &[]);
        assert_eq!(cluster.num_gpus, direct.num_gpus);
    }
}
