//! `piep runtime` / `piep bench-sim` — AOT artifact validation and quick
//! simulator throughput numbers.

use crate::config::{Parallelism, RunConfig, SimKnobs};
use crate::util::cli::Args;

pub(crate) fn cmd_runtime(args: &Args) {
    let dir = args.get_or("artifacts", "artifacts");
    let rt = match crate::runtime::Runtime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime: {e}");
            eprintln!("hint: run `make artifacts` to generate the AOT manifest + HLO files");
            return;
        }
    };
    println!("{} — {} AOT modules validated", rt.platform_name(), rt.modules.len());
    for c in rt.modules.values() {
        println!(
            "  {:<16} inputs {:?} -> output {:?}",
            c.info.name, c.info.inputs, c.info.output
        );
    }
    // Exercise the prediction hot path (native ridge evaluation).
    let mut rng = crate::util::rng::Rng::new(7);
    let rows: Vec<Vec<f64>> = (0..rt.predict_batch)
        .map(|_| (0..rt.feature_dim).map(|_| rng.range(-1.0, 1.0)).collect())
        .collect();
    let w: Vec<f64> = (0..rt.feature_dim).map(|_| rng.range(-0.5, 0.5)).collect();
    let t0 = std::time::Instant::now();
    let y = rt.predict_batch(&rows, &w, 0.25).expect("predict_batch");
    println!(
        "ridge_predict hot path: {} rows in {:?} (first: {:+.4})",
        y.len(),
        t0.elapsed(),
        y.first().copied().unwrap_or(0.0)
    );
    let functional = rt
        .random_inputs("block", 1, 0.05)
        .and_then(|inputs| rt.execute("block", &inputs));
    match functional {
        Err(e) => println!("functional forwards: {e}"),
        Ok(_) => println!("functional forwards: PJRT backend active"),
    }
}

pub(crate) fn cmd_bench_sim(args: &Args) {
    let knobs = SimKnobs {
        sim_decode_steps: args.get_usize("steps", 16),
        ..SimKnobs::default()
    };
    let hw = super::topo::parse_testbed(args, false).hw();
    let cfg = RunConfig::new("Llama-70B", Parallelism::Tensor, args.get_usize("gpus", 4), 32);
    let t0 = std::time::Instant::now();
    let n = args.get_usize("runs", 20);
    let mut samples = 0usize;
    for seed in 0..n as u64 {
        let r = crate::simulator::simulate_run(&cfg.clone().with_seed(seed), &hw, &knobs);
        samples += r.wait_samples.len();
    }
    let dt = t0.elapsed();
    println!(
        "{n} Llama-70B g=4 runs in {dt:?} ({:.1} runs/s, {} wait samples)",
        n as f64 / dt.as_secs_f64(),
        samples
    );
}
