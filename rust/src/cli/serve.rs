//! `piep serve` — trace-driven serving driver.

use crate::config::Parallelism;
use crate::util::cli::Args;

use super::campaign_from;

pub(crate) fn cmd_serve(args: &Args) {
    use crate::profiler::store;
    use crate::serve::{serve, synthesize, ArrivalKind, Policy, ServeConfig, SynthSpec, Trace};
    use crate::util::table::{fnum, pct, Table};

    let smoke = args.has("smoke");
    let model = args.get_or("model", "Vicuna-7B").to_string();
    let par = Parallelism::parse(args.get_or("parallelism", "tensor")).expect("parallelism");
    let gpus = args.get_usize("gpus", 4);
    let policy = Policy::parse(args.get_or("policy", "fcfs")).expect("policy (fcfs|spf)");
    let seed = args.get_u64("seed", 0x5EB5E);
    let campaign = campaign_from(args);

    // Trace source: a JSONL file, or a seeded synthetic generator.
    let trace = if let Some(path) = args.get("trace") {
        let t = Trace::load_jsonl(path).expect("load trace");
        eprintln!("[serve] loaded {} requests from {path}", t.len());
        t
    } else {
        let kind = ArrivalKind::parse(args.get_or("synthetic", "poisson")).expect("synthetic (poisson|bursty|diurnal)");
        let spec = SynthSpec {
            kind,
            requests: args.get_usize("requests", if smoke { 8 } else { 32 }),
            rate_rps: args.get_f64("rate", 2.0),
            ..SynthSpec::default()
        };
        eprintln!("[serve] synthetic {} trace: {} requests at {} rps", kind.name(), spec.requests, spec.rate_rps);
        synthesize(&spec, seed)
    };

    let mut cfg = ServeConfig::new(&model, par, gpus);
    cfg.policy = policy;
    cfg.base_seed = seed;
    cfg.max_batch_requests = args.get_usize("max-batch", cfg.max_batch_requests);
    cfg.max_batch_tokens = args.get_usize("max-batch-tokens", cfg.max_batch_tokens);
    let t0 = std::time::Instant::now();
    let res = serve(&trace, &cfg, &campaign.hw, &campaign.knobs);
    let wall = t0.elapsed();

    let mut per_req = Table::new(
        "Serving — per-request energy attribution",
        &["Req", "Prompt", "Out", "Arrive s", "Queue s", "TTFT s", "Latency s", "J", "J/token", "Sync J"],
    );
    for r in &res.requests {
        if r.rejected {
            per_req.row(vec![
                format!("{}*", r.id),
                r.prompt_tokens.to_string(),
                r.output_tokens.to_string(),
                fnum(r.arrival_s, 2),
                "-".into(),
                "-".into(),
                "-".into(),
                "rejected".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        per_req.row(vec![
            r.id.to_string(),
            r.prompt_tokens.to_string(),
            r.output_tokens.to_string(),
            fnum(r.arrival_s, 2),
            fnum(r.queue_delay_s(), 2),
            fnum(r.first_token_s - r.arrival_s, 2),
            fnum(r.latency_s(), 2),
            fnum(r.energy_j, 1),
            fnum(r.energy_per_token_j(), 1),
            fnum(r.sync_energy_j, 1),
        ]);
    }
    print!("{}", per_req.render());

    let served: Vec<f64> = res.served().map(|r| r.energy_j).collect();
    let mut summary = Table::new(
        "Serving — summary",
        &["Trace", "Policy", "Strategy", "Reqs", "Steps", "J/req p50", "J/req p99", "J/token", "Occup", "Busy%", "Wait%", "Sync%"],
    );
    summary.row(vec![
        args.get("trace").map(|_| "jsonl".to_string()).unwrap_or_else(|| args.get_or("synthetic", "poisson").into()),
        policy.name().into(),
        cfg.parallelism.label(),
        format!("{}/{}", served.len(), res.requests.len()),
        res.steps.len().to_string(),
        fnum(res.energy_percentile_j(50.0), 1),
        fnum(res.energy_percentile_j(99.0), 1),
        fnum(res.energy_per_token_j(), 2),
        pct(100.0 * res.occupancy),
        pct(100.0 * res.busy_frac),
        pct(100.0 * res.wait_frac),
        pct(100.0 * res.sync_share),
    ]);
    print!("{}", summary.render());

    // Per-step binding-resource histogram from the critical-path pass.
    let mut bound_t = Table::new(
        "Serving — steps per critical-path binding resource",
        &["BoundBy", "Steps", "Share"],
    );
    for (b, n) in &res.bound_hist {
        bound_t.row(vec![
            b.clone(),
            n.to_string(),
            pct(100.0 * *n as f64 / res.steps.len().max(1) as f64),
        ]);
    }
    print!("{}", bound_t.render());
    println!(
        "[serve] {} steps over {:.1}s of traffic in {wall:?}; Σ energy {:.1} J; peak KV {:.2}/{:.2} GiB",
        res.steps.len(),
        res.makespan_s,
        res.total_energy_j,
        res.peak_kv_bytes / (1u64 << 30) as f64,
        res.kv_budget_bytes / (1u64 << 30) as f64,
    );
    // Conservation check (the serve invariant; cheap enough to always run).
    let req_j: f64 = res.requests.iter().map(|r| r.energy_j).sum();
    assert!(
        (req_j - res.total_energy_j).abs() / res.total_energy_j.max(1e-12) < 1e-9,
        "per-request attribution must conserve batch energy"
    );

    let out = args.get_or("out", "reports");
    for (t, slug) in [(&per_req, "serving_requests"), (&summary, "serving_summary"), (&bound_t, "serving_bound")] {
        match t.save_csv(out, slug) {
            Ok(path) => println!("  -> {path}"),
            Err(e) => eprintln!("  !! could not save {slug}.csv: {e}"),
        }
    }
    if let Some(path) = args.get("save") {
        store::save_serve_records(&res.requests, path).expect("save serving records");
        println!("saved per-request records (piep-serve-v3) -> {path}");
    }
}
