//! `piep reproduce` and the individual experiment harness ids.
//!
//! Every experiment lives in exactly one table below: the tables generate
//! the `reproduce --all` order, the bare-id dispatch (`piep table3`), and
//! the id lists in `piep help` — adding a harness means adding one row.

use crate::report::{self, ReportCtx};
use crate::util::cli::Args;
use crate::util::table::Table;

use super::campaign_from;

pub(crate) type Harness = fn(&mut ReportCtx) -> Table;

/// The paper's tables and figures, in presentation order.
pub(crate) const PAPER_EXPERIMENTS: [(&str, Harness); 15] = [
    ("figure2", report::figure2),
    ("table2", report::table2),
    ("table3", report::table3),
    ("table4", report::table4),
    ("figure3", report::figure3),
    ("figure4", report::figure4),
    ("figure5", report::figure5),
    ("figure6", report::figure6),
    ("table5", report::table5),
    ("table6", report::table6),
    ("table7", report::table7),
    ("table8", report::table8),
    ("figure7", report::figure7),
    ("figure8", report::figure8),
    ("table9", report::table9),
];

/// Extension studies beyond the paper's evaluation (see DESIGN.md).
pub(crate) const EXTENSION_EXPERIMENTS: [(&str, Harness); 8] = [
    ("crosshw", report::crosshw),
    ("sensitivity", report::sensitivity),
    ("ablate-ring", report::ablate_ring),
    ("parallelism-matrix", report::parallelism_matrix),
    ("expert", report::expert_study),
    ("serving", report::serving),
    ("tune-study", report::tune_study),
    // Shadowed by the `fleet` subcommand at the top level; run it as
    // `piep reproduce fleet`.
    ("fleet", report::fleet),
];

fn harness(id: &str) -> Option<Harness> {
    PAPER_EXPERIMENTS
        .iter()
        .chain(EXTENSION_EXPERIMENTS.iter())
        .find(|(name, _)| *name == id)
        .map(|&(_, f)| f)
}

/// Does `id` name an individual experiment harness (dispatched without the
/// `reproduce` prefix)?
pub(crate) fn is_experiment_id(id: &str) -> bool {
    harness(id).is_some()
}

/// Comma-ish id list for the help text.
pub(crate) fn id_list(experiments: &[(&'static str, Harness)]) -> String {
    experiments.iter().map(|(name, _)| *name).collect::<Vec<_>>().join(" | ")
}

fn run_experiments(ctx: &mut ReportCtx, ids: &[String]) {
    for id in ids {
        match harness(id) {
            Some(f) => drop(f(ctx)),
            None => eprintln!("unknown experiment id: {id}"),
        }
    }
}

pub(crate) fn cmd_reproduce(args: &Args) {
    let out = args.get_or("out", "reports").to_string();
    let mut ctx = ReportCtx::new(&out, campaign_from(args));
    let ids: Vec<String> = if args.has("all") || args.positional.is_empty() {
        PAPER_EXPERIMENTS
            .iter()
            .chain(EXTENSION_EXPERIMENTS.iter())
            .map(|(name, _)| name.to_string())
            .collect()
    } else {
        args.positional.clone()
    };
    let t0 = std::time::Instant::now();
    run_experiments(&mut ctx, &ids);
    eprintln!("[reproduce] {} experiments in {:?}", ids.len(), t0.elapsed());
}

pub(crate) fn cmd_single(args: &Args, id: &str) {
    let out = args.get_or("out", "reports").to_string();
    let mut ctx = ReportCtx::new(&out, campaign_from(args));
    run_experiments(&mut ctx, &[id.to_string()]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_tables_are_disjoint_and_dispatchable() {
        let ids: Vec<&str> = PAPER_EXPERIMENTS
            .iter()
            .chain(EXTENSION_EXPERIMENTS.iter())
            .map(|(name, _)| *name)
            .collect();
        assert_eq!(ids.len(), 23);
        for id in &ids {
            assert!(is_experiment_id(id), "{id} must dispatch");
        }
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate experiment id");
        assert!(!is_experiment_id("figure9"), "membership, not prefix match");
        assert!(is_experiment_id("fleet"));
        assert!(id_list(&EXTENSION_EXPERIMENTS).contains("tune-study | fleet"));
    }
}
