//! `piep reproduce` and the individual experiment harness ids.

use crate::report::{self, ReportCtx};
use crate::util::cli::Args;

use super::campaign_from;

fn run_experiments(ctx: &mut ReportCtx, ids: &[String]) {
    for id in ids {
        match id.as_str() {
            "figure2" => drop(report::figure2(ctx)),
            "figure3" => drop(report::figure3(ctx)),
            "figure4" => drop(report::figure4(ctx)),
            "figure5" => drop(report::figure5(ctx)),
            "figure6" => drop(report::figure6(ctx)),
            "figure7" => drop(report::figure7(ctx)),
            "figure8" => drop(report::figure8(ctx)),
            "table2" => drop(report::table2(ctx)),
            "table3" => drop(report::table3(ctx)),
            "table4" => drop(report::table4(ctx)),
            "table5" => drop(report::table5(ctx)),
            "table6" => drop(report::table6(ctx)),
            "table7" => drop(report::table7(ctx)),
            "table8" => drop(report::table8(ctx)),
            "table9" => drop(report::table9(ctx)),
            "crosshw" => drop(report::crosshw(ctx)),
            "sensitivity" => drop(report::sensitivity(ctx)),
            "ablate-ring" => drop(report::ablate_ring(ctx)),
            "parallelism-matrix" => drop(report::parallelism_matrix(ctx)),
            "serving" => drop(report::serving(ctx)),
            "tune-study" => drop(report::tune_study(ctx)),
            other => eprintln!("unknown experiment id: {other}"),
        }
    }
}

const ALL_EXPERIMENTS: [&str; 21] = [
    "figure2", "table2", "table3", "table4", "figure3", "figure4", "figure5", "figure6",
    "table5", "table6", "table7", "table8", "figure7", "figure8", "table9",
    // extension studies (not in the paper's evaluation; see DESIGN.md)
    "crosshw", "sensitivity", "ablate-ring", "parallelism-matrix", "serving", "tune-study",
];

/// Does `id` name an individual experiment harness (dispatched without the
/// `reproduce` prefix)?
pub(crate) fn is_experiment_id(id: &str) -> bool {
    id.starts_with("figure")
        || id.starts_with("table")
        || matches!(
            id,
            "crosshw" | "sensitivity" | "ablate-ring" | "parallelism-matrix" | "serving" | "tune-study"
        )
}

pub(crate) fn cmd_reproduce(args: &Args) {
    let out = args.get_or("out", "reports").to_string();
    let mut ctx = ReportCtx::new(&out, campaign_from(args));
    let ids: Vec<String> = if args.has("all") || args.positional.is_empty() {
        ALL_EXPERIMENTS.iter().map(|s| s.to_string()).collect()
    } else {
        args.positional.clone()
    };
    let t0 = std::time::Instant::now();
    run_experiments(&mut ctx, &ids);
    eprintln!("[reproduce] {} experiments in {:?}", ids.len(), t0.elapsed());
}

pub(crate) fn cmd_single(args: &Args, id: &str) {
    let out = args.get_or("out", "reports").to_string();
    let mut ctx = ReportCtx::new(&out, campaign_from(args));
    run_experiments(&mut ctx, &[id.to_string()]);
}
