//! `piep plan` — compiled-plan introspection: per-strategy op counts and
//! collective bytes, and (with `--stats`) the structure-vs-scalar hit
//! rates of the two-level plan cache over a shape grid, so rebinding wins
//! are observable from the CLI.

use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
use crate::plan::PlanCache;
use crate::util::cli::Args;
use crate::util::table::{fnum, pct, Table};
use crate::workload;

/// Pure strategies plus every hybrid realizable on `gpus`, VRAM-gated.
fn strategies_for(model: &str, gpus: usize, hw: &HwSpec) -> Vec<Parallelism> {
    let spec = crate::models::by_name(model).unwrap_or_else(|| panic!("unknown model {model}"));
    let mut pars = vec![Parallelism::Tensor, Parallelism::Pipeline, Parallelism::Data];
    pars.extend(workload::hybrid_parallelisms(gpus));
    pars.into_iter()
        .filter(|&par| workload::runnable(&spec, par, gpus, hw))
        .collect()
}

pub(crate) fn cmd_plan(args: &Args) {
    let model = args.get_or("model", "Vicuna-7B").to_string();
    let gpus = args.get_usize("gpus", 4);
    let batch = args.get_usize("batch", 8);
    let seq_out = args.get_usize("seq-out", 512);
    let knobs = SimKnobs {
        sim_decode_steps: args.get_usize("steps", 8),
        batch_execution: !args.has("no-batch"),
        affine_rebind: !args.has("no-affine"),
        ..SimKnobs::default()
    };
    let hw = super::topo::parse_testbed(args, false).hw();
    let spec = crate::models::by_name(&model).expect("model");
    let pars = strategies_for(&model, gpus, &hw);

    let mut shapes = Table::new(
        "Plan — per-strategy compiled structure (ops, edges, collective bytes)",
        &["Strategy", "Ops", "Compute", "Collective", "Send", "Recv", "Edges", "Comm KB/step", "Structure key"],
    );
    for &par in &pars {
        let cfg = RunConfig::new(&model, par, gpus, batch).with_seq_out(seq_out);
        let ep = crate::parallelism::compile(&spec, &hw, &knobs, &cfg);
        let (compute, coll, send, recv) = ep.op_census();
        shapes.row(vec![
            par.label(),
            ep.len().to_string(),
            compute.to_string(),
            coll.to_string(),
            send.to_string(),
            recv.to_string(),
            ep.structure.num_edges.to_string(),
            fnum(ep.scalars.comm_bytes_per_step / 1024.0, 1),
            crate::parallelism::structure_key(&knobs, &cfg),
        ]);
    }
    print!("{}", shapes.render());

    if !args.has("stats") {
        println!("(pass --stats for the two-level plan-cache hit rates over a shape grid)");
        return;
    }

    // ---- cache stats: a batch × prompt-length shape grid per strategy ----
    // Batches and prompt lengths vary the *shape*; the mesh structure only
    // changes where a pipeline axis changes its microbatch count — so the
    // grid shows how few full lowerings a sweep actually pays.
    let batches = [4usize, 8, 16, 32];
    let seq_ins = [64usize, 128, 256, 512];
    let cache = PlanCache::new();
    let mut grid_cfgs: Vec<RunConfig> = Vec::new();
    let mut per_strategy = Table::new(
        "Plan — two-level cache over the shape grid (per strategy)",
        &["Strategy", "Shapes", "Structure lowerings", "Scalar rebinds", "Reuse", "Affine"],
    );
    for &par in &pars {
        let before = cache.stats();
        let mut shapes_n = 0usize;
        for &b in &batches {
            for &seq_in in &seq_ins {
                let mut cfg = RunConfig::new(&model, par, gpus, b).with_seq_out(seq_out);
                cfg.seq_in = seq_in;
                cache.get_or_lower(&cfg, &hw, &knobs);
                grid_cfgs.push(cfg);
                shapes_n += 1;
            }
        }
        let after = cache.stats();
        let lowered = after.structure_lowerings - before.structure_lowerings;
        let rebound = after.rebinds - before.rebinds;
        let affine = after.affine_rebinds - before.affine_rebinds;
        // "-" when a strategy never rebound (every shape lowered fresh):
        // affine coverage of zero rebinds is undefined, not 0%.
        let affine_label = if rebound == 0 {
            "-".to_string()
        } else {
            pct(100.0 * affine as f64 / rebound as f64)
        };
        per_strategy.row(vec![
            par.label(),
            shapes_n.to_string(),
            lowered.to_string(),
            rebound.to_string(),
            pct(100.0 * (shapes_n - lowered) as f64 / shapes_n as f64),
            affine_label,
        ]);
    }
    print!("{}", per_strategy.render());

    let st = cache.stats();
    let (structures, shapes_cached) = cache.sizes();
    println!(
        "[plan] {} shape accesses -> {} structure lowerings, {} scalar rebinds, {} shape hits \
         ({} structures / {} shapes cached; {:.0}% of accesses avoided a full lowering)",
        st.accesses(),
        st.structure_lowerings,
        st.rebinds,
        st.shape_hits,
        structures,
        shapes_cached,
        100.0 * st.reuse_rate()
    );
    println!(
        "[plan] affine rebinds: {} of {} ({} coverage), {} replay fallbacks, {} probe-rejected ops",
        st.affine_rebinds,
        st.rebinds,
        st.affine_coverage_label(),
        st.replay_fallbacks,
        st.probe_rejected_ops
    );

    // ---- batched execution over the same grid: one engine walk per mesh
    // (DESIGN.md §14; --no-batch falls back to one walk per shape). ----
    let t0 = std::time::Instant::now();
    let ds = crate::profiler::Campaign::new()
        .with_hw(hw.clone())
        .with_knobs(knobs.clone())
        .with_passes(1)
        .profile(&grid_cfgs);
    println!(
        "[plan] batched execution of the grid in {:?}: {} batched walk(s) × {} lanes mean \
         ({} lanes total), {} serial fallbacks",
        t0.elapsed(),
        ds.cache.batches,
        ds.cache.mean_batch_width_label(),
        ds.cache.batched_lanes,
        ds.cache.serial_fallbacks
    );
}
