//! `piep fleet` — fleet-scale multi-replica serving driver.

use crate::util::cli::Args;

use super::topo;

pub(crate) fn cmd_fleet(args: &Args) {
    use crate::config::{Parallelism, SimKnobs};
    use crate::eval::fleet::{cell_config, fleet_trace, run_fleet_eval, FleetOptions};
    use crate::fleet::{simulate_fleet, AutoscaleConfig, RouterPolicy};
    use crate::profiler::store;
    use crate::serve::{ArrivalKind, Policy};
    use crate::util::table::{fnum, Table};

    let smoke = args.has("smoke");
    // --smoke pins the CI fleet: replicas 1,2 × {jsq, energy} on the
    // shared 2-node NVLink+IB cluster testbed.
    let testbed = topo::parse_testbed(args, true);

    let replica_counts: Vec<usize> = args
        .get("replicas")
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![1, 2]);
    let policies: Vec<RouterPolicy> = args
        .get("policies")
        .map(|s| {
            s.split(',')
                .map(|p| RouterPolicy::parse(p.trim()).unwrap_or_else(|| panic!("unknown router policy {p}")))
                .collect()
        })
        .unwrap_or_else(|| {
            if smoke {
                vec![RouterPolicy::JoinShortestQueue, RouterPolicy::EnergyAware]
            } else {
                RouterPolicy::ALL.to_vec()
            }
        });
    let autoscale = if args.has("autoscale") {
        Some(AutoscaleConfig {
            interval_s: args.get_f64("scale-interval", 2.0),
            target_inflight: args.get_usize("target-inflight", 4),
            min_replicas: args.get_usize("min-replicas", 1),
            cold_start_s: args.get_f64("cold-start-s", 1.0),
            cold_start_j: args.get_f64("cold-start-j", 150.0),
        })
    } else {
        None
    };

    let opts = FleetOptions {
        model: args.get_or("model", "Vicuna-7B").to_string(),
        parallelism: Parallelism::parse(args.get_or("parallelism", "tensor")).expect("parallelism"),
        testbed,
        replica_counts,
        policies,
        admission: Policy::parse(args.get_or("policy", "fcfs")).expect("policy (fcfs|spf)"),
        max_batch_requests: args.get_usize("max-batch", 8),
        requests: args.get_usize("requests", if smoke { 10 } else { 32 }),
        rate_rps: args.get_f64("rate", 2.0),
        arrival: ArrivalKind::parse(args.get_or("arrival", "diurnal")).expect("arrival (poisson|bursty|diurnal)"),
        sessions: args.get_usize("sessions", 4),
        autoscale,
        knobs: SimKnobs::default()
            .with_batch_execution(!args.has("no-batch"))
            .with_affine_rebind(!args.has("no-affine")),
        seed: args.get_u64("seed", 0xF1EE7),
        threads: args.get_usize("threads", 0),
    };

    eprintln!(
        "[fleet] {} ({}) on {} per replica: {} requests ({}), replicas {:?} × policies {:?}{}",
        opts.model,
        opts.parallelism.label(),
        opts.testbed.label(),
        opts.requests,
        opts.arrival.name(),
        opts.replica_counts,
        opts.policies.iter().map(|p| p.name()).collect::<Vec<_>>(),
        if opts.autoscale.is_some() { ", autoscaled" } else { "" }
    );
    let t0 = std::time::Instant::now();
    let res = run_fleet_eval(&opts);
    let wall = t0.elapsed();

    let mut grid = Table::new(
        "Fleet — cluster J/token and latency vs replica count × router",
        &["Replicas", "Router", "J/token", "p50 s", "p99 s", "Cluster J", "Cold J", "Served", "Makespan s", "Scale ev", "BoundBy"],
    );
    for c in &res.cells {
        grid.row(vec![
            c.replicas.to_string(),
            c.policy.name().into(),
            fnum(c.j_per_token, 3),
            fnum(c.p50_latency_s, 2),
            fnum(c.p99_latency_s, 2),
            fnum(c.cluster_energy_j, 1),
            fnum(c.cold_start_j, 1),
            format!("{}/{}", c.served, c.served + c.rejected),
            fnum(c.makespan_s, 2),
            c.scale_events.to_string(),
            c.bound_by(),
        ]);
    }
    print!("{}", grid.render());

    let mut argmin_t = Table::new(
        "Fleet — argmin deployment by cluster J/token",
        &["Replicas", "Router", "J/token", "p99 s", "Cluster J"],
    );
    if let Some(c) = &res.argmin {
        argmin_t.row(vec![
            c.replicas.to_string(),
            c.policy.name().into(),
            fnum(c.j_per_token, 3),
            fnum(c.p99_latency_s, 2),
            fnum(c.cluster_energy_j, 1),
        ]);
    }
    print!("{}", argmin_t.render());

    // Re-run the winning cell for the conservation invariant and the
    // optional per-request record dump (cheap: one cell).
    if let Some(best) = &res.argmin {
        let full = simulate_fleet(&res.trace, &cell_config(&opts, best.replicas, best.policy));
        let attributed = full.attributed_energy_j();
        assert!(
            (attributed - full.cluster_energy_j).abs() / full.cluster_energy_j.max(1e-12) < 1e-9,
            "fleet attribution must conserve cluster energy"
        );
        println!(
            "[fleet] best {}: Σ replica J + cold-start J == cluster J ({:.1} J over {} replicas, \
             {} shared lowerer(s), {} structure lowering(s), {} affine rebind(s) ({} coverage), \
             {} batched step walk(s) × {} lanes)",
            best.label,
            full.cluster_energy_j,
            best.replicas,
            full.shared_lowerers,
            full.cache.structure_lowerings,
            full.cache.affine_rebinds,
            full.cache.affine_coverage_label(),
            full.cache.batches,
            full.cache.mean_batch_width_label(),
        );
        if let Some(path) = args.get("save") {
            store::save_fleet_records(&full.requests, path).expect("save fleet records");
            println!("saved per-request fleet records (piep-fleet-v4) -> {path}");
        }
    }
    println!("[fleet] {} cells on one shared {}-request trace in {wall:?}", res.cells.len(), res.trace.len());

    let out = args.get_or("out", "reports");
    for (t, slug) in [(&grid, "fleet_grid"), (&argmin_t, "fleet_argmin")] {
        match t.save_csv(out, slug) {
            Ok(path) => println!("  -> {path}"),
            Err(e) => eprintln!("  !! could not save {slug}.csv: {e}"),
        }
    }
    // Trace round-trip dump mirrors `serve --save-trace`-style workflows.
    if let Some(path) = args.get("save-trace") {
        res.trace.save_jsonl(path).expect("save trace");
        println!("saved shared trace -> {path}");
    }
}
