//! `piep` — CLI for the PIE-P reproduction.
//!
//! Subcommands:
//!   profile     run a profiling campaign and print run summaries
//!   train       fit PIE-P on a family and report CV error
//!   predict     per-run prediction demo on a config
//!   plan        per-strategy compiled-plan shapes; --stats adds the
//!               structure-vs-scalar cache hit rates of a shape grid
//!   sweep       parallel sweep over the full paper + hybrid scenario grid
//!   serve       trace-driven serving: continuous batching + per-request energy
//!   tune        energy-aware strategy autotuner over a (multi-node) fleet
//!   reproduce   regenerate paper tables/figures (`--all` or ids)
//!   figure2..8, table2..9   individual experiments
//!   crosshw, sensitivity, ablate-ring, parallelism-matrix, serving, tune-study
//!               extension studies beyond the paper's evaluation
//!   runtime     validate AOT artifacts, exercise the prediction hot path
//!   bench-sim   quick simulator throughput numbers
//!
//! Common flags: --passes N --steps N --seed N --out DIR --threads N
//!
//! Argument parsing lives in `util::cli::Args`; each subcommand family has
//! its own driver module below (split out of the former ~790-line
//! `main.rs` with no change to flags or help text).

mod plan;
mod profile;
mod reproduce;
mod serve;
mod sim;
mod sweep;
mod train;
mod tune;

use crate::config::SimKnobs;
use crate::profiler::Campaign;
use crate::util::cli::Args;

/// Campaign shared by every profiling-driven subcommand, shaped by the
/// common flags.
pub(crate) fn campaign_from(args: &Args) -> Campaign {
    let mut c = Campaign::default();
    c.passes = args.get_usize("passes", 5);
    c.knobs = SimKnobs {
        sim_decode_steps: args.get_usize("steps", 16),
        engine_threads: args.get_usize("engine-threads", 1),
        ..SimKnobs::default()
    };
    c.base_seed = args.get_u64("seed", c.base_seed);
    c.threads = args.get_usize("threads", 0);
    c
}

/// Parse the process arguments and dispatch to the subcommand driver.
pub fn run() {
    let args = Args::from_env();
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "profile" => profile::cmd_profile(&args),
        "train" => train::cmd_train(&args),
        "predict" => train::cmd_predict(&args),
        "plan" => plan::cmd_plan(&args),
        "sweep" => sweep::cmd_sweep(&args),
        "serve" => serve::cmd_serve(&args),
        "tune" => tune::cmd_tune(&args),
        "runtime" => sim::cmd_runtime(&args),
        "bench-sim" => sim::cmd_bench_sim(&args),
        "reproduce" => reproduce::cmd_reproduce(&args),
        id if reproduce::is_experiment_id(id) => reproduce::cmd_single(&args, id),
        _ => help(),
    }
}

fn help() {
    println!(
        "piep — Parallelized Inference Energy Predictor (reproduction)\n\n\
         USAGE: piep <command> [flags]\n\n\
         COMMANDS\n\
         \x20 reproduce [--all | ids…]   regenerate paper tables/figures into --out\n\
         \x20 figure2..figure8           individual figure harnesses\n\
         \x20 table2..table9             individual table harnesses\n\
         \x20 crosshw | sensitivity | ablate-ring | parallelism-matrix | serving |\n\
         \x20 tune-study                 extension studies (see DESIGN.md)\n\
         \x20 profile                    profile one configuration (passes × seeds)\n\
         \x20 train                      fit PIE-P on a family, report 3-fold CV MAPE\n\
         \x20 predict                    leave-variant-out prediction demo\n\
         \x20 plan [--stats]             per-strategy compiled-plan shapes (op counts,\n\
         \x20                            collective bytes); --stats adds the\n\
         \x20                            structure-vs-scalar cache hit rates of a\n\
         \x20                            batch x prompt-length shape grid\n\
         \x20 sweep                      parallel sweep: paper grid + hybrid meshes,\n\
         \x20                            per-config MAPE + sync-wait share (--serial,\n\
         \x20                            --bench [--baseline FILE], --per-config)\n\
         \x20 serve                      trace-driven serving: continuous batching +\n\
         \x20                            per-request energy (--trace FILE | --synthetic\n\
         \x20                            poisson|bursty|diurnal, --policy fcfs|spf,\n\
         \x20                            --requests N --rate RPS --max-batch N --smoke\n\
         \x20                            --save FILE)\n\
         \x20 tune                       energy-aware strategy autotuner: search strategy\n\
         \x20                            x degree x batch on a fleet, emit Pareto front +\n\
         \x20                            argmin tables (--nodes N --gpus-per-node N\n\
         \x20                            --intra nvlink|pcie|ib --inter nvlink|pcie|ib\n\
         \x20                            --fleet a6000,h100,l40 --gpus 2,4 --batches 8,16\n\
         \x20                            --slo-ms F --strategies tp,pp,tp2xpp --smoke)\n\
         \x20 runtime                    validate AOT artifacts, run the native hot path\n\
         \x20 bench-sim                  simulator throughput check\n\n\
         FLAGS\n\
         \x20 --model NAME --family NAME --gpus N --batch N\n\
         \x20 --parallelism tp|pp|dp|<hybrid label, e.g. tp2xpp>\n\
         \x20 --seq-out N --passes N --steps N --seed N --threads N\n\
         \x20 --engine-threads N (per-rank event-engine pool; 1 = serial) --out DIR\n"
    );
}
