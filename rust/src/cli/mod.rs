//! `piep` — CLI for the PIE-P reproduction.
//!
//! Every subcommand lives in one row of [`COMMANDS`]: the table generates
//! the dispatch match and the help text, so a new driver means one row
//! plus its module. Individual experiment harnesses (`figure2..figure8`,
//! `table2..table9`, the extension studies) dispatch through the
//! experiment tables in `reproduce`, and the shared testbed flags
//! (`--gpus`, `--nodes/--gpus-per-node/--intra/--inter/--fleet`) parse
//! through `topo` so they mean the same thing in every subcommand.
//!
//! Argument parsing lives in `util::cli::Args`; each subcommand family has
//! its own driver module below.

mod critpath;
mod fleet;
mod plan;
mod profile;
mod reproduce;
mod serve;
mod sim;
mod sweep;
pub(crate) mod topo;
mod train;
mod tune;

use crate::config::SimKnobs;
use crate::profiler::Campaign;
use crate::util::cli::Args;

type Driver = fn(&Args);

/// One row per subcommand: (name, driver, help). The help column may hold
/// embedded newlines; continuation lines are indented under the name.
const COMMANDS: [(&str, Driver, &str); 13] = [
    (
        "reproduce",
        reproduce::cmd_reproduce,
        "regenerate paper tables/figures into --out (--all | ids…)",
    ),
    ("profile", profile::cmd_profile, "profile one configuration (passes × seeds)"),
    ("train", train::cmd_train, "fit PIE-P on a family, report 3-fold CV MAPE"),
    ("predict", train::cmd_predict, "leave-variant-out prediction demo"),
    (
        "plan",
        plan::cmd_plan,
        "per-strategy compiled-plan shapes (op counts,\ncollective bytes); --stats adds the structure-\nvs-scalar cache hit rates of a shape grid",
    ),
    (
        "sweep",
        sweep::cmd_sweep,
        "parallel sweep: paper grid + hybrid meshes,\nper-config MAPE + sync-wait share (--serial,\n--bench [--baseline FILE], --per-config,\n--no-batch)",
    ),
    (
        "serve",
        serve::cmd_serve,
        "trace-driven serving: continuous batching +\nper-request energy (--trace FILE | --synthetic\npoisson|bursty|diurnal, --policy fcfs|spf,\n--requests N --rate RPS --max-batch N --smoke\n--save FILE)",
    ),
    (
        "tune",
        tune::cmd_tune,
        "energy-aware strategy autotuner: search strategy\nx degree x batch on a testbed, emit Pareto front\n+ argmin tables (--gpus 2,4 --batches 8,16\n--slo-ms F --strategies tp,pp,tp2xpp --smoke\n--no-batch)",
    ),
    (
        "fleet",
        fleet::cmd_fleet,
        "fleet-scale serving: replicas × router policies\nover one trace, cluster J/token + p50/p99 tables\n(--replicas 1,2 --policies rr,jsq,energy,session\n--arrival diurnal --sessions N --autoscale\n--requests N --rate RPS --save FILE --smoke\n--no-batch)",
    ),
    (
        "critpath",
        critpath::cmd_critpath,
        "critical-path energy attribution per strategy:\non/off-path J, binding resource, Perfetto trace\n(--per-step, --export FILE, --out DIR, --smoke,\n--strategies tp,pp,tp2xpp)",
    ),
    ("runtime", sim::cmd_runtime, "validate AOT artifacts, run the native hot path"),
    ("bench-sim", sim::cmd_bench_sim, "simulator throughput check"),
    ("help", |_| help(), "this text"),
];

/// Campaign shared by every profiling-driven subcommand, shaped by the
/// common flags (including the shared testbed flags).
pub(crate) fn campaign_from(args: &Args) -> Campaign {
    let mut c = Campaign::default();
    c.hw = topo::parse_testbed(args, false).hw();
    c.passes = args.get_usize("passes", 5);
    c.knobs = SimKnobs {
        sim_decode_steps: args.get_usize("steps", 16),
        engine_threads: args.get_usize("engine-threads", 1),
        batch_execution: !args.has("no-batch"),
        affine_rebind: !args.has("no-affine"),
        ..SimKnobs::default()
    };
    c.base_seed = args.get_u64("seed", c.base_seed);
    c.threads = args.get_usize("threads", 0);
    c
}

/// Parse the process arguments and dispatch to the subcommand driver.
pub fn run() {
    let args = Args::from_env();
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    if let Some(&(_, driver, _)) = COMMANDS.iter().find(|(name, _, _)| *name == cmd.as_str()) {
        driver(&args);
    } else if reproduce::is_experiment_id(&cmd) {
        reproduce::cmd_single(&args, &cmd);
    } else {
        help();
    }
}

fn help() {
    print!("{}", help_text());
}

/// The full `piep help` text, generated from [`COMMANDS`] so the table and
/// the help screen cannot drift apart (asserted in tests).
fn help_text() -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "piep — Parallelized Inference Energy Predictor (reproduction)\n");
    let _ = writeln!(out, "USAGE: piep <command> [flags]\n");
    let _ = writeln!(out, "COMMANDS");
    for (name, _, desc) in COMMANDS {
        let mut lines = desc.lines();
        let _ = writeln!(out, "  {name:<12} {}", lines.next().unwrap_or(""));
        for l in lines {
            let _ = writeln!(out, "  {:<12} {l}", "");
        }
    }
    let _ = writeln!(out, "  {:<12} paper experiment harnesses:", "<experiment>");
    let _ = writeln!(out, "  {:<12} {}", "", reproduce::id_list(&reproduce::PAPER_EXPERIMENTS));
    let _ = writeln!(out, "  {:<12} extension studies (see DESIGN.md):", "");
    let _ = writeln!(out, "  {:<12} {}", "", reproduce::id_list(&reproduce::EXTENSION_EXPERIMENTS));
    let _ = writeln!(
        out,
        "\nTESTBED FLAGS (shared by plan, sweep, serve, bench-sim, tune, fleet, critpath)\n{}",
        topo::TOPO_HELP
    );
    let _ = writeln!(
        out,
        "\nFLAGS\n\
         \x20 --model NAME --family NAME --batch N\n\
         \x20 --parallelism tp|pp|dp|ep<N> (expert/MoE, e.g. ep4)\n\
         \x20               |<hybrid label, e.g. tp2xpp>\n\
         \x20 --seq-out N --passes N --steps N --seed N --threads N\n\
         \x20 --engine-threads N (per-rank event-engine pool; 1 = serial) --out DIR\n\
         \x20 --no-batch (sweep, tune, fleet: disable batched multi-candidate\n\
         \x20            execution; one engine walk per candidate, the pinned\n\
         \x20            serial reference)\n\
         \x20 --no-prune (tune: keep the exhaustive search; by default\n\
         \x20            candidates whose critical-path energy lower bound\n\
         \x20            exceeds the incumbent J/token are skipped unsimulated)\n\
         \x20 --no-affine (disable shape-affine rebind compilation; every\n\
         \x20            cache rebind replays the lowerer, the pinned\n\
         \x20            reference — results are bit-identical either way)"
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn command_table_is_unique_and_complete() {
        let mut names: Vec<&str> = COMMANDS.iter().map(|(name, _, _)| *name).collect();
        for expected in ["reproduce", "plan", "sweep", "serve", "tune", "fleet", "critpath", "bench-sim"] {
            assert!(names.contains(&expected), "{expected} missing from COMMANDS");
        }
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), COMMANDS.len(), "duplicate subcommand name");
        // The `fleet` subcommand wins over the `fleet` report experiment;
        // the experiment stays reachable as `piep reproduce fleet`.
        assert!(reproduce::is_experiment_id("fleet"));
    }

    #[test]
    fn help_names_every_subcommand_and_the_ep_label() {
        let text = help_text();
        for (name, _, _) in COMMANDS {
            assert!(
                text.lines().any(|l| l.trim_start().starts_with(name)),
                "{name} missing from help"
            );
        }
        // The strategy flag documents the expert-parallel label family.
        assert!(text.contains("ep<N>"), "expert label missing from FLAGS");
        assert!(text.contains("ep4"), "ep example missing from FLAGS");
    }
}
