//! `piep tune` — the energy-aware strategy autotuner driver.

use crate::config::{Parallelism, SimKnobs};
use crate::util::cli::Args;

pub(crate) fn cmd_tune(args: &Args) {
    use crate::config::Strategy;
    use crate::eval::tune::{run_tune, TuneOptions};
    use crate::util::table::{fnum, pct, Table};

    let smoke = args.has("smoke");

    // ---- testbed ----
    // The shared testbed flags (`cli::topo`) describe the fleet; --smoke
    // pins the CI grid: TP/PP/tp2xpp on a 2-node NVLink+IB cluster.
    let hw = super::topo::parse_testbed(args, true).hw();

    // ---- search space ----
    let model = args.get_or("model", "Vicuna-7B").to_string();
    let gpu_counts: Vec<usize> = args
        .get("gpus")
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| {
            let mut out: Vec<usize> = [2usize, 4, 8].iter().copied().filter(|&g| g <= hw.num_gpus).collect();
            if out.is_empty() {
                out.push(hw.num_gpus);
            }
            out
        });
    let batches: Vec<usize> = args
        .get("batches")
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| if smoke { vec![8, 16] } else { vec![8, 16, 32] });
    let strategies = if smoke {
        Some(vec![
            crate::config::Parallelism::Tensor,
            crate::config::Parallelism::Pipeline,
            crate::config::Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap(),
            crate::config::Parallelism::expert(4),
        ])
    } else {
        args.get("strategies").map(|s| {
            s.split(',')
                .map(|l| Parallelism::parse(l.trim()).unwrap_or_else(|| panic!("bad strategy label {l}")))
                .collect()
        })
    };

    let opts = TuneOptions {
        hw,
        knobs: SimKnobs {
            sim_decode_steps: args.get_usize("steps", if smoke { 4 } else { 8 }),
            batch_execution: !args.has("no-batch"),
            affine_rebind: !args.has("no-affine"),
            ..SimKnobs::default()
        },
        model,
        gpu_counts,
        batches,
        seq_in: args.get_usize("seq-in", 128),
        seq_out: args.get_usize("seq-out", 512),
        passes: args.get_usize("passes", if smoke { 2 } else { 3 }),
        base_seed: args.get_u64("seed", 0x70E5),
        slo_ms_per_token: args.get("slo-ms").and_then(|v| v.parse().ok()),
        strategies,
        threads: args.get_usize("threads", 0),
        // Critical-path bound pruning is on by default; --no-prune keeps
        // the exhaustive path (and an SLO disables pruning internally).
        prune: !args.has("no-prune"),
    };

    eprintln!(
        "[tune] {} on {} GPUs ({} node(s)): {} batches × gpu counts {:?}{}",
        opts.model,
        opts.hw.num_gpus,
        opts.hw.topo().nodes_spanned(0, opts.hw.num_gpus).max(1),
        opts.batches.len(),
        opts.gpu_counts,
        opts.slo_ms_per_token.map(|s| format!(", SLO {s} ms/token")).unwrap_or_default()
    );
    let t0 = std::time::Instant::now();
    let res = run_tune(&opts);
    let wall = t0.elapsed();

    let row_of = |c: &crate::eval::tune::TuneCandidate| {
        vec![
            c.parallelism.label(),
            c.gpus.to_string(),
            c.batch.to_string(),
            fnum(c.j_per_token, 3),
            fnum(c.j_per_request, 1),
            fnum(c.ms_per_token, 2),
            pct(100.0 * c.sync_share),
            if c.meets_slo { "yes" } else { "no" }.into(),
        ]
    };
    let headers = ["Strategy", "GPUs", "Batch", "J/token", "J/req", "ms/token", "Sync%", "SLO ok"];

    let mut all = Table::new("Tune — scored deployment candidates (J/token ascending)", &headers);
    for c in &res.candidates {
        all.row(row_of(c));
    }
    print!("{}", all.render());

    let mut front = Table::new("Tune — Pareto front over (J/token, ms/token), SLO-feasible", &headers);
    for c in &res.pareto {
        front.row(row_of(c));
    }
    print!("{}", front.render());

    let argmin_headers = ["Objective", "Strategy", "GPUs", "Batch", "J/token", "J/req", "ms/token"];
    let mut argmin = Table::new("Tune — argmin deployments", &argmin_headers);
    for (label, c) in [("J/token", &res.argmin_j_token), ("J/request", &res.argmin_j_request)] {
        if let Some(c) = c {
            argmin.row(vec![
                label.into(),
                c.parallelism.label(),
                c.gpus.to_string(),
                c.batch.to_string(),
                fnum(c.j_per_token, 3),
                fnum(c.j_per_request, 1),
                fnum(c.ms_per_token, 2),
            ]);
        }
    }
    print!("{}", argmin.render());
    println!(
        "[tune] {} candidates scored, {} pruned by the critical-path bound \
         ({} on the Pareto front) in {wall:?}; \
         plan cache: {} lowerings, {} rebinds ({} affine, {} replay), {} shape hits; \
         batched execution: {} batches × {} lanes mean, {} serial fallbacks",
        res.candidates.len(),
        res.pruned,
        res.pareto.len(),
        res.cache.structure_lowerings,
        res.cache.rebinds,
        res.cache.affine_rebinds,
        res.cache.replay_fallbacks,
        res.cache.shape_hits,
        res.cache.batches,
        res.cache.mean_batch_width_label(),
        res.cache.serial_fallbacks
    );

    let out = args.get_or("out", "reports");
    for (t, slug) in [(&all, "tune_candidates"), (&front, "tune_pareto"), (&argmin, "tune_argmin")] {
        match t.save_csv(out, slug) {
            Ok(path) => println!("  -> {path}"),
            Err(e) => eprintln!("  !! could not save {slug}.csv: {e}"),
        }
    }
}
