//! `piep train` / `piep predict` — fitting PIE-P and the per-run
//! prediction demo.

use crate::config::{Parallelism, RunConfig};
use crate::util::cli::Args;

use super::campaign_from;

pub(crate) fn cmd_train(args: &Args) {
    use crate::eval;
    use crate::models::Family;
    use crate::predict::PiepOptions;
    use crate::workload;

    let family = Family::parse(args.get_or("family", "vicuna")).expect("family");
    let campaign = campaign_from(args);
    // Reuse a saved dataset when provided (offline-profiling workflow).
    let ds = if let Some(path) = args.get("dataset") {
        crate::profiler::store::load_dataset(path).expect("load dataset")
    } else {
        let grid = workload::family_grid_tp(family, &campaign.hw);
        eprintln!("[profile] {} configs × {} passes", grid.len(), campaign.passes);
        let ds = campaign.profile(&grid);
        if let Some(path) = args.get("save") {
            crate::profiler::store::save_dataset(&ds.runs, path).expect("save dataset");
            eprintln!("saved dataset -> {path}");
        }
        ds
    };
    let (m, se) = eval::cv_mape(&ds.runs, &ds.sync_db, PiepOptions::default(), 3, 7);
    println!("{}: 3-fold CV MAPE {:.2}% (±{:.2})", family.name(), m, se);
    if let Some(path) = args.get("save-model") {
        let model = crate::predict::PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        crate::profiler::store::save_model(&model, path).expect("save model");
        println!("saved fitted PIE-P -> {path}");
    }
}

pub(crate) fn cmd_predict(args: &Args) {
    use crate::predict::{PieP, PiepOptions};
    use crate::workload;

    let model = args.get_or("model", "Vicuna-7B").to_string();
    let spec = crate::models::by_name(&model).expect("model");
    let par = Parallelism::parse(args.get_or("parallelism", "tensor")).expect("parallelism");
    let gpus = args.get_usize("gpus", 2);
    let batch = args.get_usize("batch", 8);
    let campaign = campaign_from(args);

    // Train on the rest of the family (leave-this-variant-out).
    let train_grid: Vec<RunConfig> = workload::family_grid_tp(spec.family, &campaign.hw)
        .into_iter()
        .filter(|c| c.model != model)
        .collect();
    eprintln!("[profile] training on {} configs", train_grid.len());
    let ds = campaign.profile(&train_grid);
    let piep = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());

    let cfg = RunConfig::new(&model, par, gpus, batch).with_seed(424242);
    let target = crate::simulator::simulate_run(&cfg, &campaign.hw, &campaign.knobs);
    let pred = piep.predict_total(&target, &ds.sync_db);
    println!("config: {}", cfg.key());
    println!("predicted energy : {:>10.1} J  ({:.3} Wh)", pred, pred / 3600.0);
    println!(
        "measured (meter) : {:>10.1} J  ({:.3} Wh)",
        target.meter_total_j,
        target.meter_total_j / 3600.0
    );
    println!(
        "error            : {:>9.1}%",
        100.0 * (pred - target.meter_total_j).abs() / target.meter_total_j
    );
    println!("\nmodule-level predictions (J):");
    for kind in crate::simulator::timeline::ModuleKind::ALL {
        if let Some(p) = piep.predict_module(&target, kind, &ds.sync_db) {
            let truth = target.module_energy_j.get(&kind).copied().unwrap_or(0.0);
            println!("  {:<20} pred {:>9.1}   measured {:>9.1}", kind.name(), p, truth);
        }
    }
}
