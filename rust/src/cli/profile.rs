//! `piep profile` — one profiling campaign, run summaries + attribution.

use crate::config::{Parallelism, RunConfig};
use crate::util::cli::Args;

use super::campaign_from;

pub(crate) fn cmd_profile(args: &Args) {
    let model = args.get_or("model", "Vicuna-7B").to_string();
    let par = Parallelism::parse(args.get_or("parallelism", "tensor")).expect("parallelism");
    let gpus = args.get_usize("gpus", 2);
    let batch = args.get_usize("batch", 8);
    let seq = args.get_usize("seq-out", 512);
    let campaign = campaign_from(args);
    let cfg = RunConfig::new(&model, par, gpus, batch).with_seq_out(seq);
    let ds = campaign.profile(&[cfg]);
    println!("profiled {} passes of {}", ds.runs.len(), ds.runs[0].config.key());
    for r in &ds.runs {
        println!(
            "  pass: wall {:.2}s  meter {:.1} J ({:.2} Wh)  nvml {:.1} J  comm {:.1} J  wait_mean {:.1} µs",
            r.wall_s,
            r.meter_total_j,
            r.meter_total_j / 3600.0,
            r.nvml_total_j,
            r.comm_energy_j(),
            r.wait_mean_s * 1e6,
        );
    }
    println!("module attribution (pass 0, J):");
    for (k, v) in &ds.runs[0].module_energy_j {
        println!("  {:<20} {:>10.1}", k.name(), v);
    }
    if !ds.runs[0].comm_split_j.is_empty() {
        println!("comm phase split (pass 0, J):");
        for (k, (wait, xfer)) in &ds.runs[0].comm_split_j {
            println!(
                "  {:<20} sync-wait {:>9.1}   transfer {:>9.1}   ({:.0}% waiting)",
                k.name(),
                wait,
                xfer,
                100.0 * wait / (wait + xfer).max(1e-12)
            );
        }
    }
    if let Some(path) = args.get("save") {
        crate::profiler::store::save_dataset(&ds.runs, path).expect("save dataset");
        println!("saved dataset -> {path}");
    }
}
