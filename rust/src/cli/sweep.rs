//! `piep sweep` — scenario sweep driver, the `--bench` perf-trajectory
//! recorder (`BENCH_sweep.json`), and the CI regression gate.

use crate::config::RunConfig;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::campaign_from;

/// `BENCH_sweep.json` columns that legitimately carry `null` in the
/// committed seed (the authoring container has no toolchain to measure
/// wall-times). A null anywhere else means a corrupt or hand-edited
/// baseline — the gate fails loudly instead of silently disarming.
const NULLABLE_COLUMNS: [&str; 20] = [
    "threads",
    "configs",
    "runs",
    "serial_wall_s",
    "parallel_wall_s",
    "speedup",
    "lower_wall_s",
    "rebind_wall_s",
    "rebind_speedup",
    "structure_lowerings",
    "shape_rebinds",
    "batch_wall_s",
    "batch_speedup",
    "batched_candidates",
    "prune_wall_s",
    "prune_speedup",
    "pruned_candidates",
    "affine_wall_s",
    "affine_speedup",
    "affine_ops_pct",
];

/// Schema-tolerant baseline validation: v1 baselines simply lack the
/// lower/rebind columns added in v2, v1/v2 baselines lack the batched
/// execution columns added in v3, v1..v3 baselines lack the pruning
/// columns added in v4, v1..v4 baselines lack the affine-rebind columns
/// added in v5 (absence is fine — the gate skips the missing column and
/// says so), and unknown *extra* columns are ignored.
/// Only two things are fatal: a schema outside the `piep-sweep-bench-*`
/// family, and a null in a column not known to be nullable.
fn validate_baseline(path: &str, base: &Json) {
    match base.get("schema").and_then(Json::as_str) {
        Some(schema) if schema.starts_with("piep-sweep-bench-") => {}
        other => {
            eprintln!("sweep --baseline {path}: unrecognized schema {other:?} (expected piep-sweep-bench-*)");
            std::process::exit(2);
        }
    }
    if let Some(obj) = base.as_obj() {
        for (key, value) in obj {
            if *value == Json::Null && !NULLABLE_COLUMNS.contains(&key.as_str()) {
                eprintln!(
                    "sweep --baseline {path}: unexpected null in column {key:?} — the baseline is \
                     corrupt; regenerate it with `piep sweep --bench --save-bench {path}`"
                );
                std::process::exit(2);
            }
        }
    }
}

pub(crate) fn cmd_sweep(args: &Args) {
    use crate::eval::sweep::{paper_scenarios, run_sweep, SweepOptions};
    use crate::util::json::{arr, num, obj, s};
    use crate::util::table::{fnum, pct, Table};

    let campaign = {
        let mut c = campaign_from(args);
        // The sweep covers a much larger grid than one experiment; default
        // to a lighter per-run sampling unless overridden.
        c.passes = args.get_usize("passes", 3);
        c.knobs.sim_decode_steps = args.get_usize("steps", 8);
        c
    };
    let scenarios = paper_scenarios(&campaign.hw);
    let total_cfgs: usize = scenarios.iter().map(|s| s.configs.len()).sum();
    eprintln!(
        "[sweep] {} scenarios, {} configs × {} passes",
        scenarios.len(),
        total_cfgs,
        campaign.passes
    );
    let opts = SweepOptions {
        campaign,
        folds: args.get_usize("folds", 3),
        parallel: !args.has("serial"),
        threads: args.get_usize("threads", 0),
        ..SweepOptions::default()
    };

    // --bench: time the serial baseline against the parallel engine on the
    // same grid, time one full lowering per config against the two-level
    // cache's structure-sharing rebind path, time batched-vs-serial
    // candidate execution on the autotuner grid, and record the
    // perf-trajectory file. With --baseline FILE, compare against a
    // previously committed baseline and fail (exit 2) on a >2× wall-time
    // regression in any armed column — the CI perf gate.
    if args.has("bench") {
        // Read the committed baseline before anything overwrites it. A
        // missing or corrupt baseline is a misconfigured gate, not a
        // dormant one — fail loudly rather than silently disarming.
        let baseline = args.get("baseline").map(|p| {
            let src = std::fs::read_to_string(p).unwrap_or_else(|e| {
                eprintln!("sweep --baseline {p}: unreadable ({e})");
                std::process::exit(2);
            });
            let parsed = Json::parse(&src).unwrap_or_else(|e| {
                eprintln!("sweep --baseline {p}: invalid JSON ({e})");
                std::process::exit(2);
            });
            validate_baseline(p, &parsed);
            parsed
        });
        let t0 = std::time::Instant::now();
        let serial = run_sweep(&scenarios, &SweepOptions { parallel: false, ..opts.clone() });
        let serial_s = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let parallel = run_sweep(&scenarios, &SweepOptions { parallel: true, ..opts.clone() });
        let parallel_s = t1.elapsed().as_secs_f64();
        let threads = crate::util::par::effective_threads(opts.threads);
        println!(
            "sweep bench: serial {serial_s:.2}s vs parallel {parallel_s:.2}s on {threads} threads ({:.2}x)",
            serial_s / parallel_s.max(1e-9)
        );
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.mape, b.mape, "{}: serial/parallel MAPE must agree", a.label);
        }

        // Lower-vs-rebind microtiming on the same grid: every config
        // compiled from scratch (one full structure lowering each) vs the
        // grid replayed through the two-level cache (one lowering per mesh
        // topology, scalar rebinds for the rest).
        let all_cfgs: Vec<&RunConfig> = scenarios.iter().flat_map(|s| s.configs.iter()).collect();
        let bench_knobs = &opts.campaign.knobs;
        let bench_hw = &opts.campaign.hw;
        let t2 = std::time::Instant::now();
        for cfg in &all_cfgs {
            let spec = crate::models::by_name(&cfg.model).expect("model");
            std::hint::black_box(crate::parallelism::compile(&spec, bench_hw, bench_knobs, cfg));
        }
        let lower_s = t2.elapsed().as_secs_f64();
        let cache = crate::plan::PlanCache::new();
        let t3 = std::time::Instant::now();
        for cfg in &all_cfgs {
            std::hint::black_box(cache.get_or_lower(cfg, bench_hw, bench_knobs));
        }
        let rebind_s = t3.elapsed().as_secs_f64();
        let cstats = cache.stats();
        println!(
            "sweep bench: lower {:.1}ms vs cached rebind {:.1}ms over {} configs ({:.2}x; {} structures, {} rebinds)",
            lower_s * 1e3,
            rebind_s * 1e3,
            all_cfgs.len(),
            lower_s / rebind_s.max(1e-9),
            cstats.structure_lowerings,
            cstats.rebinds
        );

        // Batched-vs-serial candidate execution on the autotuner grid
        // (DESIGN.md §14): the same candidates × passes scored once on the
        // pinned serial path (one engine walk per lane) and once with each
        // mesh's lanes resolved in a single batched walk. Both sides run
        // with threads: 1 so the ratio isolates the batched walk itself,
        // not the worker pool.
        let tune_opts = crate::eval::tune::TuneOptions {
            hw: opts.campaign.hw.clone(),
            knobs: opts.campaign.knobs.clone(),
            passes: opts.campaign.passes,
            threads: 1,
            ..crate::eval::tune::TuneOptions::default()
        };
        let t4 = std::time::Instant::now();
        let tune_serial = crate::eval::tune::run_tune(&crate::eval::tune::TuneOptions {
            knobs: tune_opts.knobs.clone().with_batch_execution(false),
            ..tune_opts.clone()
        });
        let batch_off_s = t4.elapsed().as_secs_f64();
        let t5 = std::time::Instant::now();
        let tune_batched = crate::eval::tune::run_tune(&crate::eval::tune::TuneOptions {
            knobs: tune_opts.knobs.clone().with_batch_execution(true),
            ..tune_opts.clone()
        });
        let batch_on_s = t5.elapsed().as_secs_f64();
        let batch_speedup = batch_off_s / batch_on_s.max(1e-9);
        assert_eq!(tune_serial.candidates.len(), tune_batched.candidates.len());
        for (a, b) in tune_serial.candidates.iter().zip(&tune_batched.candidates) {
            assert_eq!(
                (a.key.as_str(), a.j_per_token, a.ms_per_token),
                (b.key.as_str(), b.j_per_token, b.ms_per_token),
                "batched/serial tuner scores must agree bit-for-bit"
            );
        }
        let batched_candidates = tune_batched.cache.batched_lanes;
        println!(
            "sweep bench: tune grid serial {:.1}ms vs batched {:.1}ms ({batch_speedup:.2}x; \
             {batched_candidates} lanes over {} batched walks)",
            batch_off_s * 1e3,
            batch_on_s * 1e3,
            tune_batched.cache.batches
        );

        // Critical-path bound pruning on the same tune grid: the exhaustive
        // batched search above vs the branch-and-bound search that skips
        // candidates whose energy floor exceeds the incumbent. The argmin
        // must survive pruning bit-for-bit (also property-pinned).
        let t6 = std::time::Instant::now();
        let tune_pruned = crate::eval::tune::run_tune(&crate::eval::tune::TuneOptions {
            knobs: tune_opts.knobs.clone().with_batch_execution(true),
            prune: true,
            ..tune_opts.clone()
        });
        let prune_s = t6.elapsed().as_secs_f64();
        let prune_speedup = batch_on_s / prune_s.max(1e-9);
        assert_eq!(
            tune_batched.argmin_j_token.as_ref().map(|c| (c.key.as_str(), c.j_per_token)),
            tune_pruned.argmin_j_token.as_ref().map(|c| (c.key.as_str(), c.j_per_token)),
            "pruned tuner must keep the exhaustive argmin"
        );
        println!(
            "sweep bench: tune grid exhaustive {:.1}ms vs pruned {:.1}ms ({prune_speedup:.2}x; \
             {} of {} candidates skipped unsimulated)",
            batch_on_s * 1e3,
            prune_s * 1e3,
            tune_pruned.pruned,
            tune_pruned.candidates.len() + tune_pruned.pruned
        );

        // Affine-vs-replay rebind microtiming (DESIGN.md §17): both caches
        // are warmed on the sweep grid (structure lowerings, program
        // capture + probe verification all paid up front), then a second
        // shape grid — the same configs at a shifted seq_out, which changes
        // the shape key but never the mesh structure — is rebound through
        // each. The affine side evaluates the accepted scalar programs in
        // O(ops); the replay side re-runs the lowerer per shape
        // (`--no-affine` semantics). The assert pins bit-identity between
        // the two paths over the whole grid.
        let knobs_replay = bench_knobs.clone().with_affine_rebind(false);
        let cache_affine = crate::plan::PlanCache::new();
        let cache_replay = crate::plan::PlanCache::new();
        for cfg in &all_cfgs {
            std::hint::black_box(cache_affine.get_or_lower(cfg, bench_hw, bench_knobs));
            std::hint::black_box(cache_replay.get_or_lower(cfg, bench_hw, &knobs_replay));
        }
        let rebind_cfgs: Vec<RunConfig> =
            all_cfgs.iter().map(|c| (*c).clone().with_seq_out(c.seq_out + 32)).collect();
        let t7 = std::time::Instant::now();
        for cfg in &rebind_cfgs {
            std::hint::black_box(cache_affine.get_or_lower(cfg, bench_hw, bench_knobs));
        }
        let affine_s = t7.elapsed().as_secs_f64();
        let t8 = std::time::Instant::now();
        for cfg in &rebind_cfgs {
            std::hint::black_box(cache_replay.get_or_lower(cfg, bench_hw, &knobs_replay));
        }
        let replay_s = t8.elapsed().as_secs_f64();
        for cfg in &rebind_cfgs {
            let a = cache_affine.get_or_lower(cfg, bench_hw, bench_knobs);
            let r = cache_replay.get_or_lower(cfg, bench_hw, &knobs_replay);
            assert_eq!(
                crate::plan::affine::scalars_mismatch(&a.scalars, &r.scalars),
                0,
                "affine rebind must be bit-identical to lowerer replay for {}",
                cfg.key()
            );
        }
        let affine_speedup = replay_s / affine_s.max(1e-9);
        let astats = cache_affine.stats();
        let affine_ops_pct = 100.0 * astats.affine_coverage();
        println!(
            "sweep bench: replay rebind {:.1}ms vs affine rebind {:.1}ms over {} shapes \
             ({affine_speedup:.2}x; {} coverage, {} probe-rejected ops)",
            replay_s * 1e3,
            affine_s * 1e3,
            rebind_cfgs.len(),
            astats.affine_coverage_label(),
            astats.probe_rejected_ops
        );

        let path = args.get_or("save-bench", "BENCH_sweep.json");
        let j = obj(vec![
            ("schema", s("piep-sweep-bench-v5")),
            ("threads", num(threads as f64)),
            ("passes", num(opts.campaign.passes as f64)),
            ("sim_decode_steps", num(opts.campaign.knobs.sim_decode_steps as f64)),
            ("configs", num(total_cfgs as f64)),
            ("runs", num(parallel.iter().map(|r| r.runs).sum::<usize>() as f64)),
            ("serial_wall_s", num(serial_s)),
            ("parallel_wall_s", num(parallel_s)),
            ("speedup", num(serial_s / parallel_s.max(1e-9))),
            ("lower_wall_s", num(lower_s)),
            ("rebind_wall_s", num(rebind_s)),
            ("rebind_speedup", num(lower_s / rebind_s.max(1e-9))),
            ("structure_lowerings", num(cstats.structure_lowerings as f64)),
            ("shape_rebinds", num(cstats.rebinds as f64)),
            ("batch_wall_s", num(batch_on_s)),
            ("batch_speedup", num(batch_speedup)),
            ("batched_candidates", num(batched_candidates as f64)),
            ("prune_wall_s", num(prune_s)),
            ("prune_speedup", num(prune_speedup)),
            ("pruned_candidates", num(tune_pruned.pruned as f64)),
            ("affine_wall_s", num(affine_s)),
            ("affine_speedup", num(affine_speedup)),
            ("affine_ops_pct", num(affine_ops_pct)),
            (
                "scenarios",
                arr(parallel
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("label", s(&r.label)),
                            ("configs", num(r.configs as f64)),
                            ("runs", num(r.runs as f64)),
                            ("mape", num(r.mape)),
                            ("sync_share", num(r.sync_share)),
                            ("wall_s", num(r.wall_s)),
                        ])
                    })
                    .collect()),
            ),
        ]);
        std::fs::write(path, j.render()).expect("write bench file");
        println!("saved sweep baseline -> {path}");
        // Regression gate: only armed once a baseline with real wall-times
        // has been committed (the seed file carries nulls), and only when
        // the baseline was measured on the same workload — comparing
        // wall-times across different grids/passes/steps is meaningless.
        if let Some(base) = baseline.as_ref() {
            let basef = |k: &str| base.get(k).and_then(|v| v.as_f64());
            let workload_matches = basef("passes") == Some(opts.campaign.passes as f64)
                && basef("sim_decode_steps") == Some(opts.campaign.knobs.sim_decode_steps as f64)
                && basef("configs") == Some(total_cfgs as f64);
            // Gate columns with their per-column comparability: wall-times
            // only compare when the baseline measured the same work. The
            // batch column additionally requires the same tune-grid lane
            // count (grid or pass changes would skew the ratio).
            let gate_cols: [(&str, f64, bool); 4] = [
                ("parallel_wall_s", parallel_s, workload_matches),
                (
                    "batch_wall_s",
                    batch_on_s,
                    workload_matches && basef("batched_candidates") == Some(batched_candidates as f64),
                ),
                (
                    "prune_wall_s",
                    prune_s,
                    workload_matches && basef("pruned_candidates") == Some(tune_pruned.pruned as f64),
                ),
                ("affine_wall_s", affine_s, workload_matches),
            ];
            for (col, measured, comparable) in gate_cols {
                match base.get(col).map(|v| v.as_f64()) {
                    // Older baselines predate the column: skip only it, and
                    // say so — one fresh column must not disarm the others.
                    None => println!("baseline lacks column {col:?} (older schema); its gate skipped"),
                    Some(Some(base_wall)) if comparable => {
                        let ratio = measured / base_wall.max(1e-9);
                        println!("baseline {col}: {base_wall:.2}s -> ratio {ratio:.2}x (gate: 2.0x)");
                        if ratio > 2.0 {
                            eprintln!(
                                "sweep regression in {col}: {measured:.2}s exceeds 2x baseline {base_wall:.2}s"
                            );
                            std::process::exit(2);
                        }
                    }
                    Some(Some(_)) => println!(
                        "baseline workload differs (passes/steps/configs/lanes); {col} gate skipped"
                    ),
                    // A baseline without a measurement disarms that column's
                    // gate. That is only legitimate for the committed seed
                    // on a fresh cache (CI passes --allow-null-baseline for
                    // exactly that case); a *restored* null baseline means
                    // the gate is misconfigured — fail loudly, naming the
                    // column, instead of silently skipping.
                    Some(None) if args.has("allow-null-baseline") => {
                        println!("baseline {col} has no wall-time yet; its gate dormant (first run)")
                    }
                    Some(None) => {
                        eprintln!(
                            "sweep --baseline: column {col:?} is null, so its >2x regression \
                             gate cannot arm. If this is the first run on a fresh cache (the \
                             committed seed), pass --allow-null-baseline; otherwise regenerate \
                             the baseline with `piep sweep --bench --save-bench BENCH_sweep.json`."
                        );
                        std::process::exit(2);
                    }
                }
            }
        }
        return;
    }

    let t0 = std::time::Instant::now();
    let results = run_sweep(&scenarios, &opts);
    let wall = t0.elapsed();

    let mut summary = Table::new(
        "Sweep — PIE-P cross-validated MAPE per scenario (pure + hybrid)",
        &["Scenario", "Configs", "Runs", "MAPE", "±se", "Sync%", "CritPct", "BoundBy", "Wall s"],
    );
    for r in &results {
        summary.row(vec![
            r.label.clone(),
            r.configs.to_string(),
            r.runs.to_string(),
            pct(r.mape),
            fnum(r.std_err, 2),
            pct(100.0 * r.sync_share),
            pct(100.0 * r.crit_share),
            r.bound_by.clone(),
            fnum(r.wall_s, 1),
        ]);
    }
    print!("{}", summary.render());
    println!(
        "[sweep] total {:?} ({}, {} threads)\n",
        wall,
        if opts.parallel { "parallel" } else { "serial" },
        crate::util::par::effective_threads(opts.threads)
    );

    let mut per_config = Table::new(
        "Sweep — per-config MAPE",
        &["Scenario", "Config", "MAPE", "±se", "n"],
    );
    for r in &results {
        for c in &r.per_config {
            per_config.row(vec![
                r.label.clone(),
                c.key.clone(),
                pct(c.mape),
                fnum(c.std_err, 2),
                c.n.to_string(),
            ]);
        }
    }
    if args.has("per-config") {
        print!("{}", per_config.render());
    }
    let out = args.get_or("out", "reports");
    for (t, slug) in [(&summary, "sweep_summary"), (&per_config, "sweep_per_config")] {
        match t.save_csv(out, slug) {
            Ok(path) => println!("  -> {path}"),
            Err(e) => eprintln!("  !! could not save {slug}.csv: {e}"),
        }
    }
}
