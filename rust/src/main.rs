//! `piep` binary entry point. The CLI lives in `piep::cli` — argument
//! parsing in `util::cli::Args`, one driver module per subcommand family
//! (the former monolithic `main.rs`, split without behavior change).

fn main() {
    piep::cli::run();
}
