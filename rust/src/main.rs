//! `piep` — CLI for the PIE-P reproduction.
//!
//! Subcommands:
//!   profile     run a profiling campaign and print run summaries
//!   train       fit PIE-P on a family and report CV error
//!   predict     per-run prediction demo on a config
//!   sweep       parallel sweep over the full paper + hybrid scenario grid
//!   serve       trace-driven serving: continuous batching + per-request energy
//!   tune        energy-aware strategy autotuner over a (multi-node) fleet
//!   reproduce   regenerate paper tables/figures (`--all` or ids)
//!   figure2..8, table2..9   individual experiments
//!   crosshw, sensitivity, ablate-ring, parallelism-matrix, serving, tune-study
//!               extension studies beyond the paper's evaluation
//!   runtime     validate AOT artifacts, exercise the prediction hot path
//!   bench-sim   quick simulator throughput numbers
//!
//! Common flags: --passes N --steps N --seed N --out DIR --threads N

use piep::config::{Parallelism, RunConfig, SimKnobs};
use piep::profiler::Campaign;
use piep::report::{self, ReportCtx};
use piep::util::cli::Args;

fn campaign_from(args: &Args) -> Campaign {
    let mut c = Campaign::default();
    c.passes = args.get_usize("passes", 5);
    c.knobs = SimKnobs {
        sim_decode_steps: args.get_usize("steps", 16),
        engine_threads: args.get_usize("engine-threads", 1),
        ..SimKnobs::default()
    };
    c.base_seed = args.get_u64("seed", c.base_seed);
    c.threads = args.get_usize("threads", 0);
    c
}

fn cmd_profile(args: &Args) {
    let model = args.get_or("model", "Vicuna-7B").to_string();
    let par = Parallelism::parse(args.get_or("parallelism", "tensor")).expect("parallelism");
    let gpus = args.get_usize("gpus", 2);
    let batch = args.get_usize("batch", 8);
    let seq = args.get_usize("seq-out", 512);
    let campaign = campaign_from(args);
    let cfg = RunConfig::new(&model, par, gpus, batch).with_seq_out(seq);
    let ds = campaign.profile(&[cfg]);
    println!("profiled {} passes of {}", ds.runs.len(), ds.runs[0].config.key());
    for r in &ds.runs {
        println!(
            "  pass: wall {:.2}s  meter {:.1} J ({:.2} Wh)  nvml {:.1} J  comm {:.1} J  wait_mean {:.1} µs",
            r.wall_s,
            r.meter_total_j,
            r.meter_total_j / 3600.0,
            r.nvml_total_j,
            r.comm_energy_j(),
            r.wait_mean_s * 1e6,
        );
    }
    println!("module attribution (pass 0, J):");
    for (k, v) in &ds.runs[0].module_energy_j {
        println!("  {:<20} {:>10.1}", k.name(), v);
    }
    if !ds.runs[0].comm_split_j.is_empty() {
        println!("comm phase split (pass 0, J):");
        for (k, (wait, xfer)) in &ds.runs[0].comm_split_j {
            println!(
                "  {:<20} sync-wait {:>9.1}   transfer {:>9.1}   ({:.0}% waiting)",
                k.name(),
                wait,
                xfer,
                100.0 * wait / (wait + xfer).max(1e-12)
            );
        }
    }
    if let Some(path) = args.get("save") {
        piep::profiler::store::save_dataset(&ds.runs, path).expect("save dataset");
        println!("saved dataset -> {path}");
    }
}

fn cmd_train(args: &Args) {
    use piep::eval;
    use piep::models::Family;
    use piep::predict::PiepOptions;
    use piep::workload;

    let family = Family::parse(args.get_or("family", "vicuna")).expect("family");
    let campaign = campaign_from(args);
    // Reuse a saved dataset when provided (offline-profiling workflow).
    let ds = if let Some(path) = args.get("dataset") {
        piep::profiler::store::load_dataset(path).expect("load dataset")
    } else {
        let grid = workload::family_grid_tp(family, &campaign.hw);
        eprintln!("[profile] {} configs × {} passes", grid.len(), campaign.passes);
        let ds = campaign.profile(&grid);
        if let Some(path) = args.get("save") {
            piep::profiler::store::save_dataset(&ds.runs, path).expect("save dataset");
            eprintln!("saved dataset -> {path}");
        }
        ds
    };
    let (m, se) = eval::cv_mape(&ds.runs, &ds.sync_db, PiepOptions::default(), 3, 7);
    println!("{}: 3-fold CV MAPE {:.2}% (±{:.2})", family.name(), m, se);
    if let Some(path) = args.get("save-model") {
        let model = piep::predict::PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        piep::profiler::store::save_model(&model, path).expect("save model");
        println!("saved fitted PIE-P -> {path}");
    }
}

fn cmd_predict(args: &Args) {
    use piep::predict::{PieP, PiepOptions};
    use piep::workload;

    let model = args.get_or("model", "Vicuna-7B").to_string();
    let spec = piep::models::by_name(&model).expect("model");
    let par = Parallelism::parse(args.get_or("parallelism", "tensor")).expect("parallelism");
    let gpus = args.get_usize("gpus", 2);
    let batch = args.get_usize("batch", 8);
    let campaign = campaign_from(args);

    // Train on the rest of the family (leave-this-variant-out).
    let train_grid: Vec<RunConfig> = workload::family_grid_tp(spec.family, &campaign.hw)
        .into_iter()
        .filter(|c| c.model != model)
        .collect();
    eprintln!("[profile] training on {} configs", train_grid.len());
    let ds = campaign.profile(&train_grid);
    let piep = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());

    let cfg = RunConfig::new(&model, par, gpus, batch).with_seed(424242);
    let target = piep::simulator::simulate_run(&cfg, &campaign.hw, &campaign.knobs);
    let pred = piep.predict_total(&target, &ds.sync_db);
    println!("config: {}", cfg.key());
    println!("predicted energy : {:>10.1} J  ({:.3} Wh)", pred, pred / 3600.0);
    println!(
        "measured (meter) : {:>10.1} J  ({:.3} Wh)",
        target.meter_total_j,
        target.meter_total_j / 3600.0
    );
    println!(
        "error            : {:>9.1}%",
        100.0 * (pred - target.meter_total_j).abs() / target.meter_total_j
    );
    println!("\nmodule-level predictions (J):");
    for kind in piep::simulator::timeline::ModuleKind::ALL {
        if let Some(p) = piep.predict_module(&target, kind, &ds.sync_db) {
            let truth = target.module_energy_j.get(&kind).copied().unwrap_or(0.0);
            println!("  {:<20} pred {:>9.1}   measured {:>9.1}", kind.name(), p, truth);
        }
    }
}

fn cmd_runtime(args: &Args) {
    let dir = args.get_or("artifacts", "artifacts");
    let rt = match piep::runtime::Runtime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime: {e}");
            eprintln!("hint: run `make artifacts` to generate the AOT manifest + HLO files");
            return;
        }
    };
    println!("{} — {} AOT modules validated", rt.platform_name(), rt.modules.len());
    for c in rt.modules.values() {
        println!(
            "  {:<16} inputs {:?} -> output {:?}",
            c.info.name, c.info.inputs, c.info.output
        );
    }
    // Exercise the prediction hot path (native ridge evaluation).
    let mut rng = piep::util::rng::Rng::new(7);
    let rows: Vec<Vec<f64>> = (0..rt.predict_batch)
        .map(|_| (0..rt.feature_dim).map(|_| rng.range(-1.0, 1.0)).collect())
        .collect();
    let w: Vec<f64> = (0..rt.feature_dim).map(|_| rng.range(-0.5, 0.5)).collect();
    let t0 = std::time::Instant::now();
    let y = rt.predict_batch(&rows, &w, 0.25).expect("predict_batch");
    println!(
        "ridge_predict hot path: {} rows in {:?} (first: {:+.4})",
        y.len(),
        t0.elapsed(),
        y.first().copied().unwrap_or(0.0)
    );
    let functional = rt
        .random_inputs("block", 1, 0.05)
        .and_then(|inputs| rt.execute("block", &inputs));
    match functional {
        Err(e) => println!("functional forwards: {e}"),
        Ok(_) => println!("functional forwards: PJRT backend active"),
    }
}

fn cmd_sweep(args: &Args) {
    use piep::eval::sweep::{paper_scenarios, run_sweep, SweepOptions};
    use piep::util::json::{arr, num, obj, s};
    use piep::util::table::{fnum, pct, Table};

    let campaign = {
        let mut c = campaign_from(args);
        // The sweep covers a much larger grid than one experiment; default
        // to a lighter per-run sampling unless overridden.
        c.passes = args.get_usize("passes", 3);
        c.knobs.sim_decode_steps = args.get_usize("steps", 8);
        c
    };
    let scenarios = paper_scenarios(&campaign.hw);
    let total_cfgs: usize = scenarios.iter().map(|s| s.configs.len()).sum();
    eprintln!(
        "[sweep] {} scenarios, {} configs × {} passes",
        scenarios.len(),
        total_cfgs,
        campaign.passes
    );
    let opts = SweepOptions {
        campaign,
        folds: args.get_usize("folds", 3),
        parallel: !args.has("serial"),
        threads: args.get_usize("threads", 0),
        ..SweepOptions::default()
    };

    // --bench: time the serial baseline against the parallel engine on the
    // same grid and record the perf-trajectory file. With --baseline FILE,
    // compare against a previously committed baseline and fail (exit 2) on
    // a >2× parallel-wall-time regression — the CI perf gate.
    if args.has("bench") {
        // Read the committed baseline before anything overwrites it. A
        // missing or corrupt baseline is a misconfigured gate, not a
        // dormant one — fail loudly rather than silently disarming.
        let baseline = args.get("baseline").map(|p| {
            let src = std::fs::read_to_string(p).unwrap_or_else(|e| {
                eprintln!("sweep --baseline {p}: unreadable ({e})");
                std::process::exit(2);
            });
            piep::util::json::Json::parse(&src).unwrap_or_else(|e| {
                eprintln!("sweep --baseline {p}: invalid JSON ({e})");
                std::process::exit(2);
            })
        });
        let t0 = std::time::Instant::now();
        let serial = run_sweep(&scenarios, &SweepOptions { parallel: false, ..opts.clone() });
        let serial_s = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let parallel = run_sweep(&scenarios, &SweepOptions { parallel: true, ..opts.clone() });
        let parallel_s = t1.elapsed().as_secs_f64();
        let threads = piep::util::par::effective_threads(opts.threads);
        println!(
            "sweep bench: serial {serial_s:.2}s vs parallel {parallel_s:.2}s on {threads} threads ({:.2}x)",
            serial_s / parallel_s.max(1e-9)
        );
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.mape, b.mape, "{}: serial/parallel MAPE must agree", a.label);
        }
        let path = args.get_or("save-bench", "BENCH_sweep.json");
        let j = obj(vec![
            ("schema", s("piep-sweep-bench-v1")),
            ("threads", num(threads as f64)),
            ("passes", num(opts.campaign.passes as f64)),
            ("sim_decode_steps", num(opts.campaign.knobs.sim_decode_steps as f64)),
            ("configs", num(total_cfgs as f64)),
            ("runs", num(parallel.iter().map(|r| r.runs).sum::<usize>() as f64)),
            ("serial_wall_s", num(serial_s)),
            ("parallel_wall_s", num(parallel_s)),
            ("speedup", num(serial_s / parallel_s.max(1e-9))),
            (
                "scenarios",
                arr(parallel
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("label", s(&r.label)),
                            ("configs", num(r.configs as f64)),
                            ("runs", num(r.runs as f64)),
                            ("mape", num(r.mape)),
                            ("sync_share", num(r.sync_share)),
                            ("wall_s", num(r.wall_s)),
                        ])
                    })
                    .collect()),
            ),
        ]);
        std::fs::write(path, j.render()).expect("write bench file");
        println!("saved sweep baseline -> {path}");
        // Regression gate: only armed once a baseline with real wall-times
        // has been committed (the seed file carries nulls), and only when
        // the baseline was measured on the same workload — comparing
        // wall-times across different grids/passes/steps is meaningless.
        if let Some(base) = baseline.as_ref() {
            let basef = |k: &str| base.get(k).and_then(|v| v.as_f64());
            let comparable = basef("passes") == Some(opts.campaign.passes as f64)
                && basef("sim_decode_steps") == Some(opts.campaign.knobs.sim_decode_steps as f64)
                && basef("configs") == Some(total_cfgs as f64);
            match basef("parallel_wall_s") {
                Some(base_wall) if comparable => {
                    let ratio = parallel_s / base_wall.max(1e-9);
                    println!("baseline parallel wall: {base_wall:.2}s -> ratio {ratio:.2}x (gate: 2.0x)");
                    if ratio > 2.0 {
                        eprintln!(
                            "sweep regression: parallel wall {parallel_s:.2}s exceeds 2x baseline {base_wall:.2}s"
                        );
                        std::process::exit(2);
                    }
                }
                Some(_) => println!(
                    "baseline workload differs (passes/steps/configs); regression gate skipped"
                ),
                // A baseline without measurements disarms the gate. That is
                // only legitimate for the committed seed on a fresh cache
                // (CI passes --allow-null-baseline for exactly that case);
                // a *restored* null baseline means the gate is
                // misconfigured — fail loudly instead of silently skipping.
                None if args.has("allow-null-baseline") => {
                    println!("baseline has no wall-times yet; regression gate dormant (first run)")
                }
                None => {
                    eprintln!(
                        "sweep --baseline: baseline has null wall-times, so the >2x regression \
                         gate cannot arm. If this is the first run on a fresh cache (the \
                         committed seed), pass --allow-null-baseline; otherwise regenerate the \
                         baseline with `piep sweep --bench --save-bench BENCH_sweep.json`."
                    );
                    std::process::exit(2);
                }
            }
        }
        return;
    }

    let t0 = std::time::Instant::now();
    let results = run_sweep(&scenarios, &opts);
    let wall = t0.elapsed();

    let mut summary = Table::new(
        "Sweep — PIE-P cross-validated MAPE per scenario (pure + hybrid)",
        &["Scenario", "Configs", "Runs", "MAPE", "±se", "Sync%", "Wall s"],
    );
    for r in &results {
        summary.row(vec![
            r.label.clone(),
            r.configs.to_string(),
            r.runs.to_string(),
            pct(r.mape),
            fnum(r.std_err, 2),
            pct(100.0 * r.sync_share),
            fnum(r.wall_s, 1),
        ]);
    }
    print!("{}", summary.render());
    println!(
        "[sweep] total {:?} ({}, {} threads)\n",
        wall,
        if opts.parallel { "parallel" } else { "serial" },
        piep::util::par::effective_threads(opts.threads)
    );

    let mut per_config = Table::new(
        "Sweep — per-config MAPE",
        &["Scenario", "Config", "MAPE", "±se", "n"],
    );
    for r in &results {
        for c in &r.per_config {
            per_config.row(vec![
                r.label.clone(),
                c.key.clone(),
                pct(c.mape),
                fnum(c.std_err, 2),
                c.n.to_string(),
            ]);
        }
    }
    if args.has("per-config") {
        print!("{}", per_config.render());
    }
    let out = args.get_or("out", "reports");
    for (t, slug) in [(&summary, "sweep_summary"), (&per_config, "sweep_per_config")] {
        match t.save_csv(out, slug) {
            Ok(path) => println!("  -> {path}"),
            Err(e) => eprintln!("  !! could not save {slug}.csv: {e}"),
        }
    }
}

fn cmd_tune(args: &Args) {
    use piep::cluster::{GpuSpec, LinkTier};
    use piep::config::{HwSpec, Strategy};
    use piep::eval::tune::{run_tune, TuneOptions};
    use piep::util::table::{fnum, pct, Table};

    let smoke = args.has("smoke");

    // ---- fleet ----
    // --nodes/--gpus-per-node + --intra/--inter tiers + --fleet GPU classes
    // describe a cluster; without --nodes the flat single-node testbed is
    // used. --smoke pins the CI grid: TP/PP/tp2xpp on a 2-node NVLink+IB
    // fleet.
    let nodes = args.get_usize("nodes", if smoke { 2 } else { 1 });
    let default_gpn = if smoke { 2 } else { HwSpec::default().num_gpus };
    let gpn = args.get_usize("gpus-per-node", default_gpn);
    // Any explicit fleet-shaping flag (including --nodes 1 / a bare
    // --gpus-per-node) builds a cluster testbed; only a flagless
    // non-smoke invocation keeps the default flat box.
    let cluster_requested = smoke
        || args.has("nodes")
        || args.has("gpus-per-node")
        || args.has("intra")
        || args.has("inter")
        || args.has("fleet");
    let hw = if cluster_requested {
        let intra = LinkTier::parse(args.get_or("intra", "nvlink")).expect("intra tier (nvlink|pcie|ib)");
        let inter = LinkTier::parse(args.get_or("inter", "ib")).expect("inter tier (nvlink|pcie|ib)");
        let fleet: Vec<GpuSpec> = args
            .get("fleet")
            .map(|s| {
                s.split(',')
                    .map(|name| GpuSpec::parse(name.trim()).unwrap_or_else(|| panic!("unknown GPU class {name}")))
                    .collect()
            })
            .unwrap_or_default();
        HwSpec::cluster_testbed(nodes, gpn, intra, inter, &fleet)
    } else {
        HwSpec::default()
    };

    // ---- search space ----
    let model = args.get_or("model", "Vicuna-7B").to_string();
    let gpu_counts: Vec<usize> = args
        .get("gpus")
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| {
            let mut out: Vec<usize> = [2usize, 4, 8].iter().copied().filter(|&g| g <= hw.num_gpus).collect();
            if out.is_empty() {
                out.push(hw.num_gpus);
            }
            out
        });
    let batches: Vec<usize> = args
        .get("batches")
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| if smoke { vec![8, 16] } else { vec![8, 16, 32] });
    let strategies = if smoke {
        Some(vec![
            piep::config::Parallelism::Tensor,
            piep::config::Parallelism::Pipeline,
            piep::config::Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap(),
        ])
    } else {
        args.get("strategies").map(|s| {
            s.split(',')
                .map(|l| Parallelism::parse(l.trim()).unwrap_or_else(|| panic!("bad strategy label {l}")))
                .collect()
        })
    };

    let opts = TuneOptions {
        hw,
        knobs: SimKnobs {
            sim_decode_steps: args.get_usize("steps", if smoke { 4 } else { 8 }),
            ..SimKnobs::default()
        },
        model,
        gpu_counts,
        batches,
        seq_in: args.get_usize("seq-in", 128),
        seq_out: args.get_usize("seq-out", 512),
        passes: args.get_usize("passes", if smoke { 2 } else { 3 }),
        base_seed: args.get_u64("seed", 0x70E5),
        slo_ms_per_token: args.get("slo-ms").and_then(|v| v.parse().ok()),
        strategies,
        threads: args.get_usize("threads", 0),
    };

    eprintln!(
        "[tune] {} on {} GPUs ({} node(s)): {} batches × gpu counts {:?}{}",
        opts.model,
        opts.hw.num_gpus,
        opts.hw.topo().nodes_spanned(0, opts.hw.num_gpus).max(1),
        opts.batches.len(),
        opts.gpu_counts,
        opts.slo_ms_per_token.map(|s| format!(", SLO {s} ms/token")).unwrap_or_default()
    );
    let t0 = std::time::Instant::now();
    let res = run_tune(&opts);
    let wall = t0.elapsed();

    let row_of = |c: &piep::eval::tune::TuneCandidate| {
        vec![
            c.parallelism.label(),
            c.gpus.to_string(),
            c.batch.to_string(),
            fnum(c.j_per_token, 3),
            fnum(c.j_per_request, 1),
            fnum(c.ms_per_token, 2),
            pct(100.0 * c.sync_share),
            if c.meets_slo { "yes" } else { "no" }.into(),
        ]
    };
    let headers = ["Strategy", "GPUs", "Batch", "J/token", "J/req", "ms/token", "Sync%", "SLO ok"];

    let mut all = Table::new("Tune — scored deployment candidates (J/token ascending)", &headers);
    for c in &res.candidates {
        all.row(row_of(c));
    }
    print!("{}", all.render());

    let mut front = Table::new("Tune — Pareto front over (J/token, ms/token), SLO-feasible", &headers);
    for c in &res.pareto {
        front.row(row_of(c));
    }
    print!("{}", front.render());

    let argmin_headers = ["Objective", "Strategy", "GPUs", "Batch", "J/token", "J/req", "ms/token"];
    let mut argmin = Table::new("Tune — argmin deployments", &argmin_headers);
    for (label, c) in [("J/token", &res.argmin_j_token), ("J/request", &res.argmin_j_request)] {
        if let Some(c) = c {
            argmin.row(vec![
                label.into(),
                c.parallelism.label(),
                c.gpus.to_string(),
                c.batch.to_string(),
                fnum(c.j_per_token, 3),
                fnum(c.j_per_request, 1),
                fnum(c.ms_per_token, 2),
            ]);
        }
    }
    print!("{}", argmin.render());
    println!(
        "[tune] {} candidates ({} on the Pareto front) in {wall:?}",
        res.candidates.len(),
        res.pareto.len()
    );

    let out = args.get_or("out", "reports");
    for (t, slug) in [(&all, "tune_candidates"), (&front, "tune_pareto"), (&argmin, "tune_argmin")] {
        match t.save_csv(out, slug) {
            Ok(path) => println!("  -> {path}"),
            Err(e) => eprintln!("  !! could not save {slug}.csv: {e}"),
        }
    }
}

fn cmd_serve(args: &Args) {
    use piep::profiler::store;
    use piep::serve::{serve, synthesize, ArrivalKind, Policy, ServeConfig, SynthSpec, Trace};
    use piep::util::table::{fnum, pct, Table};

    let smoke = args.has("smoke");
    let model = args.get_or("model", "Vicuna-7B").to_string();
    let par = Parallelism::parse(args.get_or("parallelism", "tensor")).expect("parallelism");
    let gpus = args.get_usize("gpus", 4);
    let policy = Policy::parse(args.get_or("policy", "fcfs")).expect("policy (fcfs|spf)");
    let seed = args.get_u64("seed", 0x5EB5E);
    let campaign = campaign_from(args);

    // Trace source: a JSONL file, or a seeded synthetic generator.
    let trace = if let Some(path) = args.get("trace") {
        let t = Trace::load_jsonl(path).expect("load trace");
        eprintln!("[serve] loaded {} requests from {path}", t.len());
        t
    } else {
        let kind = ArrivalKind::parse(args.get_or("synthetic", "poisson")).expect("synthetic (poisson|bursty|diurnal)");
        let spec = SynthSpec {
            kind,
            requests: args.get_usize("requests", if smoke { 8 } else { 32 }),
            rate_rps: args.get_f64("rate", 2.0),
            ..SynthSpec::default()
        };
        eprintln!("[serve] synthetic {} trace: {} requests at {} rps", kind.name(), spec.requests, spec.rate_rps);
        synthesize(&spec, seed)
    };

    let mut cfg = ServeConfig::new(&model, par, gpus);
    cfg.policy = policy;
    cfg.base_seed = seed;
    cfg.max_batch_requests = args.get_usize("max-batch", cfg.max_batch_requests);
    cfg.max_batch_tokens = args.get_usize("max-batch-tokens", cfg.max_batch_tokens);
    let t0 = std::time::Instant::now();
    let res = serve(&trace, &cfg, &campaign.hw, &campaign.knobs);
    let wall = t0.elapsed();

    let mut per_req = Table::new(
        "Serving — per-request energy attribution",
        &["Req", "Prompt", "Out", "Arrive s", "Queue s", "TTFT s", "Latency s", "J", "J/token", "Sync J"],
    );
    for r in &res.requests {
        if r.rejected {
            per_req.row(vec![
                format!("{}*", r.id),
                r.prompt_tokens.to_string(),
                r.output_tokens.to_string(),
                fnum(r.arrival_s, 2),
                "-".into(),
                "-".into(),
                "-".into(),
                "rejected".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        per_req.row(vec![
            r.id.to_string(),
            r.prompt_tokens.to_string(),
            r.output_tokens.to_string(),
            fnum(r.arrival_s, 2),
            fnum(r.queue_delay_s(), 2),
            fnum(r.first_token_s - r.arrival_s, 2),
            fnum(r.latency_s(), 2),
            fnum(r.energy_j, 1),
            fnum(r.energy_per_token_j(), 1),
            fnum(r.sync_energy_j, 1),
        ]);
    }
    print!("{}", per_req.render());

    let served: Vec<f64> = res.served().map(|r| r.energy_j).collect();
    let mut summary = Table::new(
        "Serving — summary",
        &["Trace", "Policy", "Strategy", "Reqs", "Steps", "J/req p50", "J/req p99", "J/token", "Occup", "Sync%"],
    );
    summary.row(vec![
        args.get("trace").map(|_| "jsonl".to_string()).unwrap_or_else(|| args.get_or("synthetic", "poisson").into()),
        policy.name().into(),
        cfg.parallelism.label(),
        format!("{}/{}", served.len(), res.requests.len()),
        res.steps.len().to_string(),
        fnum(res.energy_percentile_j(50.0), 1),
        fnum(res.energy_percentile_j(99.0), 1),
        fnum(res.energy_per_token_j(), 2),
        pct(100.0 * res.occupancy),
        pct(100.0 * res.sync_share),
    ]);
    print!("{}", summary.render());
    println!(
        "[serve] {} steps over {:.1}s of traffic in {wall:?}; Σ energy {:.1} J; peak KV {:.2}/{:.2} GiB",
        res.steps.len(),
        res.makespan_s,
        res.total_energy_j,
        res.peak_kv_bytes / (1u64 << 30) as f64,
        res.kv_budget_bytes / (1u64 << 30) as f64,
    );
    // Conservation check (the serve invariant; cheap enough to always run).
    let req_j: f64 = res.requests.iter().map(|r| r.energy_j).sum();
    assert!(
        (req_j - res.total_energy_j).abs() / res.total_energy_j.max(1e-12) < 1e-9,
        "per-request attribution must conserve batch energy"
    );

    let out = args.get_or("out", "reports");
    for (t, slug) in [(&per_req, "serving_requests"), (&summary, "serving_summary")] {
        match t.save_csv(out, slug) {
            Ok(path) => println!("  -> {path}"),
            Err(e) => eprintln!("  !! could not save {slug}.csv: {e}"),
        }
    }
    if let Some(path) = args.get("save") {
        store::save_serve_records(&res.requests, path).expect("save serving records");
        println!("saved per-request records (piep-serve-v3) -> {path}");
    }
}

fn cmd_bench_sim(args: &Args) {
    use piep::config::HwSpec;
    let knobs = SimKnobs {
        sim_decode_steps: args.get_usize("steps", 16),
        ..SimKnobs::default()
    };
    let hw = HwSpec::default();
    let cfg = RunConfig::new("Llama-70B", Parallelism::Tensor, 4, 32);
    let t0 = std::time::Instant::now();
    let n = args.get_usize("runs", 20);
    let mut samples = 0usize;
    for seed in 0..n as u64 {
        let r = piep::simulator::simulate_run(&cfg.clone().with_seed(seed), &hw, &knobs);
        samples += r.wait_samples.len();
    }
    let dt = t0.elapsed();
    println!(
        "{n} Llama-70B g=4 runs in {dt:?} ({:.1} runs/s, {} wait samples)",
        n as f64 / dt.as_secs_f64(),
        samples
    );
}

fn run_experiments(ctx: &mut ReportCtx, ids: &[String]) {
    for id in ids {
        match id.as_str() {
            "figure2" => drop(report::figure2(ctx)),
            "figure3" => drop(report::figure3(ctx)),
            "figure4" => drop(report::figure4(ctx)),
            "figure5" => drop(report::figure5(ctx)),
            "figure6" => drop(report::figure6(ctx)),
            "figure7" => drop(report::figure7(ctx)),
            "figure8" => drop(report::figure8(ctx)),
            "table2" => drop(report::table2(ctx)),
            "table3" => drop(report::table3(ctx)),
            "table4" => drop(report::table4(ctx)),
            "table5" => drop(report::table5(ctx)),
            "table6" => drop(report::table6(ctx)),
            "table7" => drop(report::table7(ctx)),
            "table8" => drop(report::table8(ctx)),
            "table9" => drop(report::table9(ctx)),
            "crosshw" => drop(report::crosshw(ctx)),
            "sensitivity" => drop(report::sensitivity(ctx)),
            "ablate-ring" => drop(report::ablate_ring(ctx)),
            "parallelism-matrix" => drop(report::parallelism_matrix(ctx)),
            "serving" => drop(report::serving(ctx)),
            "tune-study" => drop(report::tune_study(ctx)),
            other => eprintln!("unknown experiment id: {other}"),
        }
    }
}

const ALL_EXPERIMENTS: [&str; 21] = [
    "figure2", "table2", "table3", "table4", "figure3", "figure4", "figure5", "figure6",
    "table5", "table6", "table7", "table8", "figure7", "figure8", "table9",
    // extension studies (not in the paper's evaluation; see DESIGN.md)
    "crosshw", "sensitivity", "ablate-ring", "parallelism-matrix", "serving", "tune-study",
];

fn main() {
    let args = Args::from_env();
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "profile" => cmd_profile(&args),
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "tune" => cmd_tune(&args),
        "runtime" => cmd_runtime(&args),
        "bench-sim" => cmd_bench_sim(&args),
        "reproduce" => {
            let out = args.get_or("out", "reports").to_string();
            let mut ctx = ReportCtx::new(&out, campaign_from(&args));
            let ids: Vec<String> = if args.has("all") || args.positional.is_empty() {
                ALL_EXPERIMENTS.iter().map(|s| s.to_string()).collect()
            } else {
                args.positional.clone()
            };
            let t0 = std::time::Instant::now();
            run_experiments(&mut ctx, &ids);
            eprintln!("[reproduce] {} experiments in {:?}", ids.len(), t0.elapsed());
        }
        id if id.starts_with("figure")
            || id.starts_with("table")
            || matches!(
                id,
                "crosshw" | "sensitivity" | "ablate-ring" | "parallelism-matrix" | "serving" | "tune-study"
            ) => {
            let out = args.get_or("out", "reports").to_string();
            let mut ctx = ReportCtx::new(&out, campaign_from(&args));
            run_experiments(&mut ctx, &[id.to_string()]);
        }
        _ => {
            println!(
                "piep — Parallelized Inference Energy Predictor (reproduction)\n\n\
                 USAGE: piep <command> [flags]\n\n\
                 COMMANDS\n\
                 \x20 reproduce [--all | ids…]   regenerate paper tables/figures into --out\n\
                 \x20 figure2..figure8           individual figure harnesses\n\
                 \x20 table2..table9             individual table harnesses\n\
                 \x20 crosshw | sensitivity | ablate-ring | parallelism-matrix | serving |\n\
                 \x20 tune-study                 extension studies (see DESIGN.md)\n\
                 \x20 profile                    profile one configuration (passes × seeds)\n\
                 \x20 train                      fit PIE-P on a family, report 3-fold CV MAPE\n\
                 \x20 predict                    leave-variant-out prediction demo\n\
                 \x20 sweep                      parallel sweep: paper grid + hybrid meshes,\n\
                 \x20                            per-config MAPE + sync-wait share (--serial,\n\
                 \x20                            --bench [--baseline FILE], --per-config)\n\
                 \x20 serve                      trace-driven serving: continuous batching +\n\
                 \x20                            per-request energy (--trace FILE | --synthetic\n\
                 \x20                            poisson|bursty|diurnal, --policy fcfs|spf,\n\
                 \x20                            --requests N --rate RPS --max-batch N --smoke\n\
                 \x20                            --save FILE)\n\
                 \x20 tune                       energy-aware strategy autotuner: search strategy\n\
                 \x20                            x degree x batch on a fleet, emit Pareto front +\n\
                 \x20                            argmin tables (--nodes N --gpus-per-node N\n\
                 \x20                            --intra nvlink|pcie|ib --inter nvlink|pcie|ib\n\
                 \x20                            --fleet a6000,h100,l40 --gpus 2,4 --batches 8,16\n\
                 \x20                            --slo-ms F --strategies tp,pp,tp2xpp --smoke)\n\
                 \x20 runtime                    validate AOT artifacts, run the native hot path\n\
                 \x20 bench-sim                  simulator throughput check\n\n\
                 FLAGS\n\
                 \x20 --model NAME --family NAME --gpus N --batch N\n\
                 \x20 --parallelism tp|pp|dp|<hybrid label, e.g. tp2xpp>\n\
                 \x20 --seq-out N --passes N --steps N --seed N --threads N\n\
                 \x20 --engine-threads N (per-rank event-engine pool; 1 = serial) --out DIR\n"
            );
        }
    }
}
