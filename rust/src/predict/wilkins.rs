//! Wilkins et al. (2024) token-count regression — Eq. 2 of the paper:
//!
//! `e(τ_in, τ_out) = α₀ τ_in + α₁ τ_out + α₂ τ_in τ_out`
//!
//! Per-request energy as a function of input/output token counts only,
//! fitted by least squares on a calibration set. Deployment-friendly, but
//! blind to parallelism degree, hardware variance, and communication —
//! which is why its error grows with GPU count (Section 5.1).

use crate::simulator::run::RunRecord;
use crate::util::stats::cholesky_solve;

#[derive(Debug, Clone, Copy)]
pub struct Wilkins {
    pub a0: f64,
    pub a1: f64,
    pub a2: f64,
}

impl Wilkins {
    /// Least-squares fit on runs (features: batch-total token counts).
    pub fn fit(train: &[RunRecord]) -> Wilkins {
        assert!(!train.is_empty());
        let mut xtx = vec![0.0; 9];
        let mut xty = vec![0.0; 3];
        for r in train {
            let x = Self::basis(r);
            let y = r.meter_total_j;
            for i in 0..3 {
                xty[i] += x[i] * y;
                for j in 0..3 {
                    xtx[i * 3 + j] += x[i] * x[j];
                }
            }
        }
        for i in 0..3 {
            xtx[i * 3 + i] += 1e-6 * train.len() as f64;
        }
        cholesky_solve(&mut xtx, &mut xty, 3);
        Wilkins {
            a0: xty[0],
            a1: xty[1],
            a2: xty[2],
        }
    }

    fn basis(r: &RunRecord) -> [f64; 3] {
        let tin = (r.config.batch * r.config.seq_in) as f64;
        let tout = (r.config.batch * r.config.seq_out) as f64;
        [tin, tout, tin * tout / 1e6]
    }

    pub fn predict(&self, r: &RunRecord) -> f64 {
        let x = Self::basis(r);
        self.a0 * x[0] + self.a1 * x[1] + self.a2 * x[2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
    use crate::simulator::simulate_run;
    use crate::util::stats::mape;

    fn runs(model: &str) -> Vec<RunRecord> {
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 6,
            ..SimKnobs::default()
        };
        let mut out = Vec::new();
        for g in [2usize, 4] {
            for b in [8usize, 32] {
                for s in [512usize, 1024] {
                    for seed in 0..2u64 {
                        let cfg = RunConfig::new(model, Parallelism::Tensor, g, b)
                            .with_seq_out(s)
                            .with_seed(seed);
                        out.push(simulate_run(&cfg, &hw, &knobs));
                    }
                }
            }
        }
        out
    }

    #[test]
    fn fit_and_predict_same_distribution() {
        let rs = runs("Vicuna-7B");
        let m = Wilkins::fit(&rs);
        let pred: Vec<f64> = rs.iter().map(|r| m.predict(r)).collect();
        let truth: Vec<f64> = rs.iter().map(|r| r.meter_total_j).collect();
        // Token counts alone cannot separate 2- vs 4-GPU runs: error is
        // real but bounded in-sample.
        let e = mape(&pred, &truth);
        assert!(e > 5.0, "tokens-only must not be near-perfect: {e:.1}%");
        assert!(e < 120.0, "but not absurd: {e:.1}%");
    }

    #[test]
    fn blind_to_gpu_count() {
        let rs = runs("Vicuna-7B");
        let m = Wilkins::fit(&rs);
        let a = &rs[0];
        // Same tokens, different GPU count ⇒ identical prediction.
        let twin = rs
            .iter()
            .find(|r| {
                r.config.batch == a.config.batch
                    && r.config.seq_out == a.config.seq_out
                    && r.config.gpus != a.config.gpus
            })
            .unwrap();
        assert_eq!(m.predict(a), m.predict(twin));
    }
}
