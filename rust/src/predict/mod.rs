//! Energy predictors: PIE-P, its ablations, and all paper baselines.
//!
//! * `ridge` — standardized ridge regression (closed form, Cholesky), the
//!   leaf/module regressor family.
//! * `combiner` — the paper's Eq. 1 multi-level tree combiner
//!   (`α(c) = 1 + tanh(W·feat(c) + b)/τ`), trained by gradient descent on
//!   root-level error.
//! * `piep` — the full predictor: per-module leaf regressors over the
//!   expanded model tree + combiner; options toggle the ablations
//!   (w/o waiting, w/o model features) and the IrEne baseline (no
//!   communication modules).
//! * `codecarbon` — telemetry-based estimator (NVML + CPU-TDP heuristic).
//! * `wilkins` — token-in/token-out regression (Eq. 2).
//! * `nvml_proxy` — linear regression on NVML energy alone (Appendix G/H).

pub mod codecarbon;
pub mod combiner;
pub mod nvml_proxy;
pub mod piep;
pub mod ridge;
pub mod wilkins;

pub use combiner::Combiner;
pub use piep::{PieP, PiepOptions};
pub use ridge::Ridge;
