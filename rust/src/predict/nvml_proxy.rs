//! NVML-as-proxy regression (Appendices G and H).
//!
//! Can readily-available NVML GPU energy predict total system energy
//! through a simple regression? The paper shows it cannot: GPU-only
//! measurements miss host/PSU dynamics that vary with configuration, so
//! both in-sample error (Table 6) and leave-one-out generalization
//! (Table 7) are poor.

use crate::simulator::run::RunRecord;
use crate::predict::ridge::Ridge;

#[derive(Debug, Clone)]
pub struct NvmlProxy {
    model: Ridge,
}

impl NvmlProxy {
    pub fn fit(train: &[RunRecord]) -> NvmlProxy {
        let xs: Vec<Vec<f64>> = train.iter().map(|r| vec![r.nvml_total_j]).collect();
        let ys: Vec<f64> = train.iter().map(|r| r.meter_total_j).collect();
        NvmlProxy {
            model: Ridge::fit(&xs, &ys, 1e-6, false),
        }
    }

    pub fn predict(&self, r: &RunRecord) -> f64 {
        self.model.predict(&[r.nvml_total_j])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
    use crate::simulator::simulate_run;
    use crate::util::stats::mape;

    #[test]
    fn proxy_fits_scale_but_misses_configuration_effects() {
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 6,
            ..SimKnobs::default()
        };
        let mut rs = Vec::new();
        for model in ["Vicuna-7B", "Vicuna-13B"] {
            for g in [2usize, 4] {
                for b in [8usize, 64] {
                    for seed in 0..3u64 {
                        let cfg =
                            RunConfig::new(model, Parallelism::Tensor, g, b).with_seed(seed);
                        rs.push(simulate_run(&cfg, &hw, &knobs));
                    }
                }
            }
        }
        let m = NvmlProxy::fit(&rs);
        let pred: Vec<f64> = rs.iter().map(|r| m.predict(r)).collect();
        let truth: Vec<f64> = rs.iter().map(|r| r.meter_total_j).collect();
        let e = mape(&pred, &truth);
        // One scalar can track overall scale but not host-side variation.
        assert!(e > 3.0, "{e:.1}%");
        assert!(e < 80.0, "{e:.1}%");
    }
}
