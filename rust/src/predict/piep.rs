//! The PIE-P predictor (Section 4) and its tree-structured variants.
//!
//! Architecture: one ridge leaf regressor per module kind over the expanded
//! model tree (communication modules included), features per Table 1 plus
//! module descriptors and synchronization-sampling statistics; the Eq. 1
//! combiner composes leaf predictions into the model-level estimate.
//!
//! The same struct implements the paper's ablations and the IrEne baseline
//! through `PiepOptions`:
//! * `include_comm = false`  → IrEne (no inter-GPU collectives in the tree);
//! * `use_wait = false`      → "PIE-P w/o waiting" (Appendix J): AllReduce
//!   leaves are trained on *network-transfer-only* energy and the wait
//!   features are dropped;
//! * `use_struct = false`    → Table-9 ablation (no model-structure
//!   features).

use std::collections::BTreeMap;

use crate::features::{module_features, FeatureOpts, SyncDb};
use crate::predict::combiner::{Child, Combiner, Example};
use crate::predict::ridge::Ridge;
use crate::simulator::run::RunRecord;
use crate::simulator::timeline::ModuleKind;
use crate::tree;

/// What the model-level combiner regresses against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombinerTarget {
    /// The external wall-meter measurement — full PIE-P, whose expanded
    /// abstraction accounts for every energy source.
    MeterTotal,
    /// The summed measured energy of the modules the abstraction *covers*.
    /// This is what a method that "excludes AllReduce energy completely
    /// from the regression" (Appendix L) can be trained on: it never sees
    /// the energy its tree does not represent, so its model-level
    /// prediction systematically omits it.
    CoveredModules,
}

#[derive(Debug, Clone, Copy)]
pub struct PiepOptions {
    /// Include communication modules in the tree (false ⇒ IrEne baseline).
    pub include_comm: bool,
    /// Use synchronization sampling (false ⇒ w/o-waiting ablation).
    pub use_wait: bool,
    /// Use model-structure features (false ⇒ Table-9 ablation).
    pub use_struct: bool,
    pub target: CombinerTarget,
    pub lambda: f64,
    pub tau: f64,
    pub combiner_iters: usize,
    pub combiner_lr: f64,
}

impl Default for PiepOptions {
    fn default() -> Self {
        PiepOptions {
            include_comm: true,
            use_wait: true,
            use_struct: true,
            target: CombinerTarget::MeterTotal,
            lambda: 3e-3,
            tau: 4.0,
            combiner_iters: 300,
            combiner_lr: 0.2,
        }
    }
}

impl PiepOptions {
    /// IrEne (Cao et al. 2021) extended with aggregated runtime features
    /// but no communication modules: its regression never represents
    /// inter-GPU energy (Appendix L).
    pub fn irene() -> Self {
        PiepOptions {
            include_comm: false,
            target: CombinerTarget::CoveredModules,
            ..Default::default()
        }
    }

    /// "PIE-P w/o waiting" (Appendix J): AllReduce reduced to its
    /// network-transfer component; the waiting-phase energy is not
    /// represented anywhere in the regression.
    pub fn without_waiting() -> Self {
        PiepOptions {
            use_wait: false,
            target: CombinerTarget::CoveredModules,
            ..Default::default()
        }
    }

    pub fn without_struct_features() -> Self {
        PiepOptions {
            use_struct: false,
            ..Default::default()
        }
    }

    fn feature_opts(&self) -> FeatureOpts {
        FeatureOpts {
            use_struct: self.use_struct,
            use_wait: self.use_wait,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PieP {
    pub opts: PiepOptions,
    pub leaf: BTreeMap<ModuleKind, Ridge>,
    pub combiner: Combiner,
}

/// Leaf training target for a module kind on a run: the measured module
/// energy, except for the w/o-waiting ablation where the AllReduce target
/// is the network-transfer component only (Appendix L).
fn leaf_target(r: &RunRecord, kind: ModuleKind, opts: &PiepOptions) -> Option<f64> {
    let full = r.module_energy_j.get(&kind).copied()?;
    if kind == ModuleKind::AllReduce && !opts.use_wait {
        Some(r.allreduce_split_j.1)
    } else {
        Some(full)
    }
}

/// The tree leaves (kind, multiplicity) for a run under `opts`.
fn leaves(r: &RunRecord, opts: &PiepOptions) -> Vec<(ModuleKind, f64)> {
    tree::build(&r.spec, r.config.parallelism, r.config.gpus, opts.include_comm)
        .leaf_multiplicities()
}

impl PieP {
    /// Train on profiled runs. Ground truth is the wall-meter total at the
    /// model level and the profiler's module attribution at the leaves.
    pub fn fit(train: &[RunRecord], sync_db: &SyncDb, opts: PiepOptions) -> PieP {
        assert!(!train.is_empty(), "empty training set");
        let fo = opts.feature_opts();

        // ---- leaf samples per module kind ----
        let mut xs: BTreeMap<ModuleKind, Vec<Vec<f64>>> = BTreeMap::new();
        let mut ys: BTreeMap<ModuleKind, Vec<f64>> = BTreeMap::new();
        for r in train {
            for (kind, mult) in leaves(r, &opts) {
                if let Some(y) = leaf_target(r, kind, &opts) {
                    if y <= 0.0 {
                        continue;
                    }
                    let x = module_features(r, kind, mult, Some(sync_db), fo);
                    xs.entry(kind).or_default().push(x);
                    ys.entry(kind).or_default().push(y);
                }
            }
        }
        let mut leaf = BTreeMap::new();
        for (kind, x) in xs {
            let y = &ys[&kind];
            if x.len() >= 4 {
                leaf.insert(kind, Ridge::fit(&x, y, opts.lambda, true));
            }
        }
        assert!(
            !leaf.is_empty(),
            "training set too small: no module kind has the ≥4 samples a \
             leaf regressor needs (got {} runs)",
            train.len()
        );

        // ---- combiner on the model-level target ----
        let mut examples = Vec::with_capacity(train.len());
        for r in train {
            let children = Self::children_for(&leaf, r, sync_db, &opts);
            if children.is_empty() {
                continue;
            }
            let target_j = match opts.target {
                CombinerTarget::MeterTotal => r.meter_total_j,
                CombinerTarget::CoveredModules => leaves(r, &opts)
                    .iter()
                    .filter_map(|(k, _)| leaf_target(r, *k, &opts))
                    .sum(),
            };
            examples.push(Example {
                children,
                target_j,
            });
        }
        let combiner = if examples.is_empty() {
            Combiner::identity(crate::features::FEATURE_DIM, opts.tau)
        } else {
            Combiner::fit(&examples, opts.tau, opts.combiner_iters, opts.combiner_lr)
        };

        PieP {
            opts,
            leaf,
            combiner,
        }
    }

    fn children_for(
        leaf: &BTreeMap<ModuleKind, Ridge>,
        r: &RunRecord,
        sync_db: &SyncDb,
        opts: &PiepOptions,
    ) -> Vec<Child> {
        let fo = opts.feature_opts();
        let mut out = Vec::new();
        for (kind, mult) in leaves(r, opts) {
            if let Some(model) = leaf.get(&kind) {
                let x = module_features(r, kind, mult, Some(sync_db), fo);
                let e = model.predict(&x);
                out.push(Child {
                    feat: x,
                    energy_j: e,
                });
            }
        }
        out
    }

    /// Model-level energy prediction (J) from runtime/execution/structural
    /// features only (never the run's measured energies).
    pub fn predict_total(&self, r: &RunRecord, sync_db: &SyncDb) -> f64 {
        let children = Self::children_for(&self.leaf, r, sync_db, &self.opts);
        self.combiner.predict(&children)
    }

    /// Module-level prediction for one kind (total across its instances).
    pub fn predict_module(
        &self,
        r: &RunRecord,
        kind: ModuleKind,
        sync_db: &SyncDb,
    ) -> Option<f64> {
        let (k, mult) = leaves(r, &self.opts)
            .into_iter()
            .find(|(k, _)| *k == kind)?;
        let model = self.leaf.get(&k)?;
        let x = module_features(r, k, mult, Some(sync_db), self.opts.feature_opts());
        Some(model.predict(&x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Parallelism, RunConfig, SimKnobs};
    use crate::profiler::Campaign;
    use crate::util::stats::mape;

    fn quick_dataset() -> crate::profiler::Dataset {
        let c = Campaign {
            passes: 4,
            knobs: SimKnobs {
                sim_decode_steps: 6,
                ..SimKnobs::default()
            },
            ..Campaign::default()
        };
        let mut cfgs = Vec::new();
        for model in ["Vicuna-7B", "Vicuna-13B"] {
            for g in [2usize, 4] {
                for b in [8usize, 32] {
                    cfgs.push(RunConfig::new(model, Parallelism::Tensor, g, b));
                }
            }
        }
        c.profile(&cfgs)
    }

    #[test]
    fn piep_beats_irene_on_tensor_parallel() {
        let ds = quick_dataset();
        let (train, test): (Vec<_>, Vec<_>) = ds
            .runs
            .iter()
            .cloned()
            .enumerate()
            .partition(|(i, _)| i % 4 != 0);
        let train: Vec<_> = train.into_iter().map(|(_, r)| r).collect();
        let test: Vec<_> = test.into_iter().map(|(_, r)| r).collect();

        let piep = PieP::fit(&train, &ds.sync_db, PiepOptions::default());
        let irene = PieP::fit(&train, &ds.sync_db, PiepOptions::irene());

        let truth: Vec<f64> = test.iter().map(|r| r.meter_total_j).collect();
        let p_pred: Vec<f64> = test
            .iter()
            .map(|r| piep.predict_total(r, &ds.sync_db))
            .collect();
        let i_pred: Vec<f64> = test
            .iter()
            .map(|r| irene.predict_total(r, &ds.sync_db))
            .collect();
        let (pm, im) = (mape(&p_pred, &truth), mape(&i_pred, &truth));
        assert!(pm < im, "PIE-P {pm:.1}% vs IrEne {im:.1}%");
        assert!(pm < 40.0, "PIE-P MAPE sane: {pm:.1}%");
    }

    #[test]
    fn leaf_regressors_cover_comm_modules() {
        let ds = quick_dataset();
        let piep = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        assert!(piep.leaf.contains_key(&ModuleKind::AllReduce));
        assert!(piep.leaf.contains_key(&ModuleKind::SelfAttention));
        let irene = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::irene());
        assert!(!irene.leaf.contains_key(&ModuleKind::AllReduce));
    }

    #[test]
    fn module_prediction_close_to_attribution() {
        let ds = quick_dataset();
        let piep = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        let mut preds = Vec::new();
        let mut truths = Vec::new();
        for r in &ds.runs {
            if let Some(p) = piep.predict_module(r, ModuleKind::Mlp, &ds.sync_db) {
                preds.push(p);
                truths.push(r.module_energy_j[&ModuleKind::Mlp]);
            }
        }
        let m = mape(&preds, &truths);
        assert!(m < 35.0, "in-sample MLP module MAPE {m:.1}%");
    }

    #[test]
    fn ablation_without_waiting_underpredicts_allreduce() {
        let ds = quick_dataset();
        let full = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        let ablated = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::without_waiting());
        let r = &ds.runs[0];
        let pf = full.predict_module(r, ModuleKind::AllReduce, &ds.sync_db).unwrap();
        let pa = ablated
            .predict_module(r, ModuleKind::AllReduce, &ds.sync_db)
            .unwrap();
        assert!(pa < pf, "transfer-only {pa} < full {pf}");
    }
}
