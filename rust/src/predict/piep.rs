//! The PIE-P predictor (Section 4) and its tree-structured variants.
//!
//! Architecture: one ridge leaf regressor per tree leaf — module kind ×
//! execution part — over the expanded model tree, with communication
//! modules split into *sync-wait* and *transfer* leaves (the event
//! engine's phase-resolved attribution). Sync leaves regress the
//! straggler-waiting energy from the synchronization-sampling statistics;
//! transfer leaves regress the network-transfer energy from payload/ring
//! descriptors; the Eq. 1 combiner composes leaf predictions into the
//! model-level estimate.
//!
//! The same struct implements the paper's ablations and the IrEne baseline
//! through `PiepOptions`:
//! * `include_comm = false`  → IrEne (no inter-GPU collectives in the tree);
//! * `use_wait = false`      → "PIE-P w/o waiting" (Appendix J): the
//!   sync-wait leaves are dropped from the tree, so waiting energy is not
//!   represented anywhere in the regression, and the wait features vanish;
//! * `use_struct = false`    → Table-9 ablation (no model-structure
//!   features).

use std::collections::BTreeMap;

use crate::features::{module_features, FeatureOpts, SyncDb};
use crate::predict::combiner::{Child, Combiner, Example};
use crate::predict::ridge::Ridge;
use crate::simulator::run::RunRecord;
use crate::simulator::timeline::ModuleKind;
use crate::tree::{self, CommDetail, Leaf, LeafPart};

/// What the model-level combiner regresses against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombinerTarget {
    /// The external wall-meter measurement — full PIE-P, whose expanded
    /// abstraction accounts for every energy source.
    MeterTotal,
    /// The summed measured energy of the leaves the abstraction *covers*.
    /// This is what a method that "excludes AllReduce energy completely
    /// from the regression" (Appendix L) can be trained on: it never sees
    /// the energy its tree does not represent, so its model-level
    /// prediction systematically omits it.
    CoveredModules,
}

#[derive(Debug, Clone, Copy)]
pub struct PiepOptions {
    /// Include communication modules in the tree (false ⇒ IrEne baseline).
    pub include_comm: bool,
    /// Use synchronization sampling (false ⇒ w/o-waiting ablation: no
    /// sync-wait leaves, no wait features).
    pub use_wait: bool,
    /// Use model-structure features (false ⇒ Table-9 ablation).
    pub use_struct: bool,
    pub target: CombinerTarget,
    pub lambda: f64,
    pub tau: f64,
    pub combiner_iters: usize,
    pub combiner_lr: f64,
}

impl Default for PiepOptions {
    fn default() -> Self {
        PiepOptions {
            include_comm: true,
            use_wait: true,
            use_struct: true,
            target: CombinerTarget::MeterTotal,
            lambda: 3e-3,
            tau: 4.0,
            combiner_iters: 300,
            combiner_lr: 0.2,
        }
    }
}

impl PiepOptions {
    /// IrEne (Cao et al. 2021) extended with aggregated runtime features
    /// but no communication modules: its regression never represents
    /// inter-GPU energy (Appendix L).
    pub fn irene() -> Self {
        PiepOptions {
            include_comm: false,
            target: CombinerTarget::CoveredModules,
            ..Default::default()
        }
    }

    /// "PIE-P w/o waiting" (Appendix J): communication reduced to its
    /// network-transfer leaves; the waiting-phase energy is not
    /// represented anywhere in the regression.
    pub fn without_waiting() -> Self {
        PiepOptions {
            use_wait: false,
            target: CombinerTarget::CoveredModules,
            ..Default::default()
        }
    }

    pub fn without_struct_features() -> Self {
        PiepOptions {
            use_struct: false,
            ..Default::default()
        }
    }

    fn feature_opts(&self) -> FeatureOpts {
        FeatureOpts {
            use_struct: self.use_struct,
            use_wait: self.use_wait,
            ..FeatureOpts::default()
        }
    }

    /// Communication-leaf granularity of the tree these options induce.
    pub fn comm_detail(&self) -> CommDetail {
        if !self.include_comm {
            CommDetail::Omit
        } else if !self.use_wait {
            CommDetail::TransferOnly
        } else {
            CommDetail::SyncAndTransfer
        }
    }
}

#[derive(Debug, Clone)]
pub struct PieP {
    pub opts: PiepOptions,
    pub leaf: BTreeMap<Leaf, Ridge>,
    pub combiner: Combiner,
}

/// Leaf training target on a run: the measured (phase-resolved) energy of
/// the part the leaf stands for. Shared with the report harness so
/// leaf-level scoring uses exactly the trained target definition.
pub(crate) fn leaf_target(r: &RunRecord, leaf: Leaf) -> Option<f64> {
    match leaf.part {
        LeafPart::Compute => r.module_energy_j.get(&leaf.kind).copied(),
        LeafPart::Sync => r.comm_split_j.get(&leaf.kind).map(|(w, _)| *w),
        LeafPart::Transfer => r.comm_split_j.get(&leaf.kind).map(|(_, x)| *x),
    }
}

/// The tree leaves (leaf, multiplicity) for a run under `opts`.
fn leaves(r: &RunRecord, opts: &PiepOptions) -> Vec<(Leaf, f64)> {
    tree::build(&r.spec, r.config.parallelism, r.config.gpus, opts.comm_detail())
        .leaf_multiplicities()
}

impl PieP {
    /// Train on profiled runs. Ground truth is the wall-meter total at the
    /// model level and the profiler's phase-resolved module attribution at
    /// the leaves.
    pub fn fit(train: &[RunRecord], sync_db: &SyncDb, opts: PiepOptions) -> PieP {
        assert!(!train.is_empty(), "empty training set");
        let fo = opts.feature_opts();

        // ---- leaf samples per tree leaf ----
        let mut xs: BTreeMap<Leaf, Vec<Vec<f64>>> = BTreeMap::new();
        let mut ys: BTreeMap<Leaf, Vec<f64>> = BTreeMap::new();
        for r in train {
            for (leaf, mult) in leaves(r, &opts) {
                if let Some(y) = leaf_target(r, leaf) {
                    if y <= 0.0 {
                        continue;
                    }
                    let x = module_features(r, leaf, mult, Some(sync_db), fo);
                    xs.entry(leaf).or_default().push(x);
                    ys.entry(leaf).or_default().push(y);
                }
            }
        }
        let mut leaf = BTreeMap::new();
        for (l, x) in xs {
            let y = &ys[&l];
            if x.len() >= 4 {
                leaf.insert(l, Ridge::fit(&x, y, opts.lambda, true));
            }
        }
        assert!(
            !leaf.is_empty(),
            "training set too small: no tree leaf has the ≥4 samples a \
             leaf regressor needs (got {} runs)",
            train.len()
        );

        // ---- combiner on the model-level target ----
        let mut examples = Vec::with_capacity(train.len());
        for r in train {
            let children = Self::children_for(&leaf, r, sync_db, &opts);
            if children.is_empty() {
                continue;
            }
            let target_j = match opts.target {
                CombinerTarget::MeterTotal => r.meter_total_j,
                CombinerTarget::CoveredModules => leaves(r, &opts)
                    .iter()
                    .filter_map(|(l, _)| leaf_target(r, *l))
                    .sum(),
            };
            examples.push(Example {
                children,
                target_j,
            });
        }
        let combiner = if examples.is_empty() {
            Combiner::identity(crate::features::FEATURE_DIM, opts.tau)
        } else {
            Combiner::fit(&examples, opts.tau, opts.combiner_iters, opts.combiner_lr)
        };

        PieP {
            opts,
            leaf,
            combiner,
        }
    }

    fn children_for(
        leaf: &BTreeMap<Leaf, Ridge>,
        r: &RunRecord,
        sync_db: &SyncDb,
        opts: &PiepOptions,
    ) -> Vec<Child> {
        let fo = opts.feature_opts();
        let mut out = Vec::new();
        for (l, mult) in leaves(r, opts) {
            if let Some(model) = leaf.get(&l) {
                let x = module_features(r, l, mult, Some(sync_db), fo);
                let e = model.predict(&x);
                out.push(Child {
                    feat: x,
                    energy_j: e,
                });
            }
        }
        out
    }

    /// Model-level energy prediction (J) from runtime/execution/structural
    /// features only (never the run's measured energies).
    pub fn predict_total(&self, r: &RunRecord, sync_db: &SyncDb) -> f64 {
        let children = Self::children_for(&self.leaf, r, sync_db, &self.opts);
        self.combiner.predict(&children)
    }

    /// Prediction for one tree leaf (total across its instances), when the
    /// run's tree contains it and a regressor was trained for it.
    pub fn predict_part(&self, r: &RunRecord, leaf: Leaf, sync_db: &SyncDb) -> Option<f64> {
        let (l, mult) = leaves(r, &self.opts).into_iter().find(|(l, _)| *l == leaf)?;
        let model = self.leaf.get(&l)?;
        let x = module_features(r, l, mult, Some(sync_db), self.opts.feature_opts());
        Some(model.predict(&x))
    }

    /// Module-level prediction for one kind: the sum over the module's
    /// leaves (sync-wait + transfer for communication modules). The tree
    /// is enumerated once, not per part.
    pub fn predict_module(
        &self,
        r: &RunRecord,
        kind: ModuleKind,
        sync_db: &SyncDb,
    ) -> Option<f64> {
        let fo = self.opts.feature_opts();
        let parts: Vec<f64> = leaves(r, &self.opts)
            .into_iter()
            .filter(|(l, _)| l.kind == kind)
            .filter_map(|(l, mult)| {
                let model = self.leaf.get(&l)?;
                Some(model.predict(&module_features(r, l, mult, Some(sync_db), fo)))
            })
            .collect();
        (!parts.is_empty()).then(|| parts.iter().sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Parallelism, RunConfig, SimKnobs};
    use crate::profiler::Campaign;
    use crate::util::stats::mape;

    fn quick_dataset() -> crate::profiler::Dataset {
        let c = Campaign {
            passes: 4,
            knobs: SimKnobs {
                sim_decode_steps: 6,
                ..SimKnobs::default()
            },
            ..Campaign::default()
        };
        let mut cfgs = Vec::new();
        for model in ["Vicuna-7B", "Vicuna-13B"] {
            for g in [2usize, 4] {
                for b in [8usize, 32] {
                    cfgs.push(RunConfig::new(model, Parallelism::Tensor, g, b));
                }
            }
        }
        c.profile(&cfgs)
    }

    #[test]
    fn piep_beats_irene_on_tensor_parallel() {
        let ds = quick_dataset();
        let (train, test): (Vec<_>, Vec<_>) = ds
            .runs
            .iter()
            .cloned()
            .enumerate()
            .partition(|(i, _)| i % 4 != 0);
        let train: Vec<_> = train.into_iter().map(|(_, r)| r).collect();
        let test: Vec<_> = test.into_iter().map(|(_, r)| r).collect();

        let piep = PieP::fit(&train, &ds.sync_db, PiepOptions::default());
        let irene = PieP::fit(&train, &ds.sync_db, PiepOptions::irene());

        let truth: Vec<f64> = test.iter().map(|r| r.meter_total_j).collect();
        let p_pred: Vec<f64> = test
            .iter()
            .map(|r| piep.predict_total(r, &ds.sync_db))
            .collect();
        let i_pred: Vec<f64> = test
            .iter()
            .map(|r| irene.predict_total(r, &ds.sync_db))
            .collect();
        let (pm, im) = (mape(&p_pred, &truth), mape(&i_pred, &truth));
        assert!(pm < im, "PIE-P {pm:.1}% vs IrEne {im:.1}%");
        assert!(pm < 40.0, "PIE-P MAPE sane: {pm:.1}%");
    }

    #[test]
    fn leaf_regressors_cover_split_comm_modules() {
        let ds = quick_dataset();
        let piep = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        assert!(piep.leaf.contains_key(&Leaf::sync(ModuleKind::AllReduce)));
        assert!(piep.leaf.contains_key(&Leaf::transfer(ModuleKind::AllReduce)));
        assert!(piep.leaf.contains_key(&Leaf::compute(ModuleKind::SelfAttention)));
        let irene = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::irene());
        assert!(!irene.leaf.keys().any(|l| l.kind == ModuleKind::AllReduce));
        let ablated = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::without_waiting());
        assert!(!ablated.leaf.keys().any(|l| l.part == LeafPart::Sync));
        assert!(ablated.leaf.contains_key(&Leaf::transfer(ModuleKind::AllReduce)));
    }

    #[test]
    fn module_prediction_close_to_attribution() {
        let ds = quick_dataset();
        let piep = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        let mut preds = Vec::new();
        let mut truths = Vec::new();
        for r in &ds.runs {
            if let Some(p) = piep.predict_module(r, ModuleKind::Mlp, &ds.sync_db) {
                preds.push(p);
                truths.push(r.module_energy_j[&ModuleKind::Mlp]);
            }
        }
        let m = mape(&preds, &truths);
        assert!(m < 35.0, "in-sample MLP module MAPE {m:.1}%");
    }

    #[test]
    fn part_predictions_compose_the_module_prediction() {
        let ds = quick_dataset();
        let piep = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        let r = &ds.runs[0];
        let sync = piep.predict_part(r, Leaf::sync(ModuleKind::AllReduce), &ds.sync_db).unwrap();
        let xfer = piep
            .predict_part(r, Leaf::transfer(ModuleKind::AllReduce), &ds.sync_db)
            .unwrap();
        let module = piep.predict_module(r, ModuleKind::AllReduce, &ds.sync_db).unwrap();
        assert!(sync > 0.0 && xfer > 0.0);
        assert!((sync + xfer - module).abs() < 1e-9 * module.abs().max(1.0));
    }

    #[test]
    fn ablation_without_waiting_underpredicts_allreduce() {
        let ds = quick_dataset();
        let full = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::default());
        let ablated = PieP::fit(&ds.runs, &ds.sync_db, PiepOptions::without_waiting());
        let r = &ds.runs[0];
        let pf = full.predict_module(r, ModuleKind::AllReduce, &ds.sync_db).unwrap();
        let pa = ablated
            .predict_module(r, ModuleKind::AllReduce, &ds.sync_db)
            .unwrap();
        assert!(pa < pf, "transfer-only {pa} < full {pf}");
    }
}
