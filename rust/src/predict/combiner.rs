//! The paper's Eq. 1 multi-level combiner.
//!
//! `P(n) = Σ_{c ∈ child(n)} α(c) · P(c)` with
//! `α(c) = 1 + tanh(W·feat(c) + b) / τ`.
//!
//! Children here are the (collapsed) tree leaves — compute modules plus
//! the phase-resolved *sync-wait* and *transfer* leaves of every
//! communication module: each contributes its leaf prediction times
//! multiplicity; α learns per-child corrections from the child's feature
//! vector (shared `W`, as in the paper where weights are learned over a
//! training set of ground-truth measurements; the `IS_SYNC` descriptor
//! lets α correct the two comm parts differently). Training is full-batch
//! gradient descent on squared root-level error; with `W = 0` the
//! combiner is the identity sum, so it can only improve on it.

#[derive(Debug, Clone)]
pub struct Combiner {
    pub w: Vec<f64>,
    pub b: f64,
    pub tau: f64,
    /// Feature standardization (fitted on training children).
    pub x_mean: Vec<f64>,
    pub x_std: Vec<f64>,
}

/// One child node instance for the combiner: features, leaf-level energy
/// prediction (already multiplied by multiplicity), used for both training
/// and inference.
#[derive(Debug, Clone)]
pub struct Child {
    pub feat: Vec<f64>,
    pub energy_j: f64,
}

/// One training example: the children of a root plus the measured total.
#[derive(Debug, Clone)]
pub struct Example {
    pub children: Vec<Child>,
    pub target_j: f64,
}

impl Combiner {
    pub fn identity(dim: usize, tau: f64) -> Combiner {
        Combiner {
            w: vec![0.0; dim],
            b: 0.0,
            tau,
            x_mean: vec![0.0; dim],
            x_std: vec![1.0; dim],
        }
    }

    fn z(&self, feat: &[f64]) -> f64 {
        let mut acc = self.b;
        for j in 0..self.w.len() {
            acc += self.w[j] * (feat[j] - self.x_mean[j]) / self.x_std[j];
        }
        acc
    }

    pub fn alpha(&self, feat: &[f64]) -> f64 {
        1.0 + self.z(feat).tanh() / self.tau
    }

    /// Root prediction over a set of children.
    pub fn predict(&self, children: &[Child]) -> f64 {
        children
            .iter()
            .map(|c| self.alpha(&c.feat) * c.energy_j)
            .sum()
    }

    /// Train by full-batch GD on relative squared error.
    pub fn fit(examples: &[Example], tau: f64, iters: usize, lr: f64) -> Combiner {
        assert!(!examples.is_empty());
        let dim = examples[0].children[0].feat.len();

        // Standardize over all children.
        let mut mean = vec![0.0; dim];
        let mut count = 0usize;
        for e in examples {
            for c in &e.children {
                for j in 0..dim {
                    mean[j] += c.feat[j];
                }
                count += 1;
            }
        }
        for m in &mut mean {
            *m /= count as f64;
        }
        let mut std = vec![0.0; dim];
        for e in examples {
            for c in &e.children {
                for j in 0..dim {
                    let d = c.feat[j] - mean[j];
                    std[j] += d * d;
                }
            }
        }
        for s in &mut std {
            *s = (*s / count as f64).sqrt();
            if *s < 1e-12 {
                *s = 1.0;
            }
        }

        let mut cb = Combiner {
            w: vec![0.0; dim],
            b: 0.0,
            tau,
            x_mean: mean,
            x_std: std,
        };

        // Pre-standardize every child's features once (EXPERIMENTS.md
        // §Perf: the per-iteration (x−μ)/σ recomputation dominated fit
        // time). `zs` is a flat [total_children × dim] matrix; `offsets`
        // marks each example's child range.
        let mut zs: Vec<f64> = Vec::with_capacity(count * dim);
        let mut energies: Vec<f64> = Vec::with_capacity(count);
        let mut offsets: Vec<(usize, usize)> = Vec::with_capacity(examples.len());
        for e in examples {
            let start = energies.len();
            for c in &e.children {
                for j in 0..dim {
                    zs.push((c.feat[j] - cb.x_mean[j]) / cb.x_std[j]);
                }
                energies.push(c.energy_j);
            }
            offsets.push((start, energies.len()));
        }

        let mut gw = vec![0.0; dim];
        for _ in 0..iters {
            gw.iter_mut().for_each(|g| *g = 0.0);
            let mut gb = 0.0;
            for (e, &(lo, hi)) in examples.iter().zip(&offsets) {
                // Forward: prediction over pre-standardized children.
                let mut pred = 0.0;
                for ci in lo..hi {
                    let zrow = &zs[ci * dim..(ci + 1) * dim];
                    let z: f64 =
                        cb.b + cb.w.iter().zip(zrow).map(|(w, x)| w * x).sum::<f64>();
                    pred += (1.0 + z.tanh() / cb.tau) * energies[ci];
                }
                // Relative error keeps large-model runs from dominating.
                let scale = e.target_j.max(1e-9);
                let err = 2.0 * (pred - e.target_j) / (scale * scale);
                for ci in lo..hi {
                    let zrow = &zs[ci * dim..(ci + 1) * dim];
                    let z: f64 =
                        cb.b + cb.w.iter().zip(zrow).map(|(w, x)| w * x).sum::<f64>();
                    let sech2 = 1.0 - z.tanh() * z.tanh();
                    let g = err * energies[ci] * sech2 / cb.tau;
                    for j in 0..dim {
                        gw[j] += g * zrow[j];
                    }
                    gb += g;
                }
            }
            let n = examples.len() as f64;
            for j in 0..dim {
                cb.w[j] -= lr * gw[j] / n;
            }
            cb.b -= lr * gb / n;
        }
        cb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Children whose true total is a fixed 1.15× of the naive sum when a
    /// marker feature is 1, and 1.0× when 0 — the combiner must learn it.
    fn synth(n: usize, seed: u64) -> Vec<Example> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let marker = if rng.chance(0.5) { 1.0 } else { 0.0 };
                let children: Vec<Child> = (0..4)
                    .map(|_| Child {
                        feat: vec![marker, rng.uniform()],
                        energy_j: rng.range(5.0, 50.0),
                    })
                    .collect();
                let naive: f64 = children.iter().map(|c| c.energy_j).sum();
                let factor = if marker > 0.5 { 1.15 } else { 1.0 };
                Example {
                    children,
                    target_j: naive * factor,
                }
            })
            .collect()
    }

    #[test]
    fn identity_combiner_is_plain_sum() {
        let cb = Combiner::identity(2, 4.0);
        let kids = vec![
            Child {
                feat: vec![1.0, 2.0],
                energy_j: 10.0,
            },
            Child {
                feat: vec![0.0, 0.0],
                energy_j: 5.0,
            },
        ];
        assert!((cb.predict(&kids) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn alpha_bounded_by_tau() {
        let mut cb = Combiner::identity(1, 4.0);
        cb.w = vec![100.0];
        assert!(cb.alpha(&[1e9]) <= 1.25 + 1e-9);
        assert!(cb.alpha(&[-1e9]) >= 0.75 - 1e-9);
    }

    #[test]
    fn learns_marker_correction() {
        let train = synth(300, 1);
        let cb = Combiner::fit(&train, 4.0, 400, 0.5);
        let test = synth(100, 2);
        let mut worst: f64 = 0.0;
        for e in &test {
            let rel = (cb.predict(&e.children) - e.target_j).abs() / e.target_j;
            worst = worst.max(rel);
        }
        assert!(worst < 0.05, "worst rel err {worst}");
    }

    #[test]
    fn fit_never_worse_than_identity() {
        let train = synth(200, 3);
        let cb = Combiner::fit(&train, 4.0, 200, 0.3);
        let id = Combiner::identity(2, 4.0);
        let sse = |c: &Combiner| {
            train
                .iter()
                .map(|e| {
                    let d = (c.predict(&e.children) - e.target_j) / e.target_j;
                    d * d
                })
                .sum::<f64>()
        };
        assert!(sse(&cb) <= sse(&id) + 1e-9);
    }
}
