//! Standardized ridge regression with optional log-space targets.
//!
//! Module energies span four orders of magnitude across variants and
//! configurations, so leaf regressors fit `log(J)` by default and
//! exponentiate at prediction time; features are z-scored with the training
//! statistics. Solve is closed-form `(XᵀX + λI) w = Xᵀy` via Cholesky.

use crate::util::stats::cholesky_solve;

#[derive(Debug, Clone)]
pub struct Ridge {
    pub w: Vec<f64>,
    pub b: f64,
    pub x_mean: Vec<f64>,
    pub x_std: Vec<f64>,
    pub log_target: bool,
    pub lambda: f64,
}

impl Ridge {
    /// Fit on rows `xs` with targets `ys`.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], lambda: f64, log_target: bool) -> Ridge {
        assert!(!xs.is_empty());
        assert_eq!(xs.len(), ys.len());
        let d = xs[0].len();
        let n = xs.len();

        // Standardize features.
        let mut x_mean = vec![0.0; d];
        for x in xs {
            for j in 0..d {
                x_mean[j] += x[j];
            }
        }
        for m in &mut x_mean {
            *m /= n as f64;
        }
        let mut x_std = vec![0.0; d];
        for x in xs {
            for j in 0..d {
                let c = x[j] - x_mean[j];
                x_std[j] += c * c;
            }
        }
        for s in &mut x_std {
            *s = (*s / n as f64).sqrt();
            if *s < 1e-12 {
                *s = 1.0; // constant feature: zero after centering
            }
        }

        let ty: Vec<f64> = ys
            .iter()
            .map(|&y| if log_target { y.max(1e-9).ln() } else { y })
            .collect();
        let y_mean = ty.iter().sum::<f64>() / n as f64;

        // Normal equations on standardized, centered data.
        let mut xtx = vec![0.0; d * d];
        let mut xty = vec![0.0; d];
        let mut z = vec![0.0; d];
        for (x, &y) in xs.iter().zip(&ty) {
            for j in 0..d {
                z[j] = (x[j] - x_mean[j]) / x_std[j];
            }
            let yc = y - y_mean;
            for j in 0..d {
                xty[j] += z[j] * yc;
                for k in j..d {
                    xtx[j * d + k] += z[j] * z[k];
                }
            }
        }
        // Mirror + ridge.
        for j in 0..d {
            for k in 0..j {
                xtx[j * d + k] = xtx[k * d + j];
            }
            xtx[j * d + j] += lambda * n as f64;
        }
        let mut w = xty;
        cholesky_solve(&mut xtx, &mut w, d);

        Ridge {
            w,
            b: y_mean,
            x_mean,
            x_std,
            log_target,
            lambda,
        }
    }

    /// Linear response in (possibly log) target space.
    pub fn raw(&self, x: &[f64]) -> f64 {
        let mut acc = self.b;
        for j in 0..self.w.len() {
            acc += self.w[j] * (x[j] - self.x_mean[j]) / self.x_std[j];
        }
        acc
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        let r = self.raw(x);
        if self.log_target {
            r.exp()
        } else {
            r
        }
    }

    /// Standardized weight vector (for the PJRT batched-predict path):
    /// returns (w', b') such that prediction = w'·x + b' in raw space.
    pub fn flatten(&self) -> (Vec<f64>, f64) {
        let mut w = vec![0.0; self.w.len()];
        let mut b = self.b;
        for j in 0..self.w.len() {
            w[j] = self.w[j] / self.x_std[j];
            b -= self.w[j] * self.x_mean[j] / self.x_std[j];
        }
        (w, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.range(0.0, 10.0);
            let b = rng.range(-5.0, 5.0);
            let c = rng.range(0.0, 1.0);
            xs.push(vec![a, b, c]);
            ys.push(3.0 * a - 2.0 * b + 0.5 + rng.normal() * 0.01);
        }
        (xs, ys)
    }

    #[test]
    fn recovers_linear_relationship() {
        let (xs, ys) = synth(500, 1);
        let m = Ridge::fit(&xs, &ys, 1e-6, false);
        for (x, &y) in xs.iter().zip(&ys).take(50) {
            assert!((m.predict(x) - y).abs() < 0.1, "{} vs {}", m.predict(x), y);
        }
    }

    #[test]
    fn log_target_handles_scale_spread() {
        let mut rng = Rng::new(2);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..400 {
            let a = rng.range(0.0, 6.0);
            xs.push(vec![a]);
            ys.push((a).exp() * rng.lognormal_mean_cv(1.0, 0.02));
        }
        let m = Ridge::fit(&xs, &ys, 1e-6, true);
        for (x, &y) in xs.iter().zip(&ys).take(50) {
            let rel = (m.predict(x) - y).abs() / y;
            assert!(rel < 0.15, "rel={rel}");
        }
    }

    #[test]
    fn constant_features_do_not_break_fit() {
        let xs = vec![vec![1.0, 5.0], vec![2.0, 5.0], vec![3.0, 5.0], vec![4.0, 5.0]];
        let ys = vec![2.0, 4.0, 6.0, 8.0];
        let m = Ridge::fit(&xs, &ys, 1e-9, false);
        assert!((m.predict(&[2.5, 5.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn flatten_matches_predict() {
        let (xs, ys) = synth(200, 3);
        let m = Ridge::fit(&xs, &ys, 1e-4, false);
        let (w, b) = m.flatten();
        for x in xs.iter().take(20) {
            let flat: f64 = b + w.iter().zip(x).map(|(wi, xi)| wi * xi).sum::<f64>();
            assert!((flat - m.raw(x)).abs() < 1e-9);
        }
    }

    #[test]
    fn heavier_ridge_shrinks_weights() {
        let (xs, ys) = synth(300, 4);
        let light = Ridge::fit(&xs, &ys, 1e-8, false);
        let heavy = Ridge::fit(&xs, &ys, 10.0, false);
        let norm = |w: &[f64]| w.iter().map(|v| v * v).sum::<f64>();
        assert!(norm(&heavy.w) < norm(&light.w));
    }
}
