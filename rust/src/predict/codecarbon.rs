//! CodeCarbon-style estimator (Courty et al., 2024), the paper's second
//! baseline.
//!
//! CodeCarbon is a *measurement-path* estimator, not a trained model: it
//! sums GPU energy as reported by NVML, a CPU term from a TDP heuristic
//! (it cannot see package power on most servers, so it assumes the CPU
//! draws a fixed fraction of TDP while the process runs), and a RAM
//! heuristic of ~0.375 W per GB of system memory. PSU conversion losses
//! and board/fan overheads are invisible to it, and NVML's sampling misses
//! short sync/transfer events — the sources of its systematic
//! underestimate in Figures 2 and 4.

use crate::simulator::run::RunRecord;

/// CodeCarbon's default CPU load factor when package power is unavailable.
const CPU_TDP_FRACTION: f64 = 0.5;
/// CodeCarbon's RAM heuristic: 3 W per 8 GB slot.
const RAM_W_PER_GB: f64 = 3.0 / 8.0;
/// Host RAM of the simulated testbed, GB.
const HOST_RAM_GB: f64 = 256.0;

#[derive(Debug, Clone, Copy, Default)]
pub struct CodeCarbon {
    /// CPU TDP of the tracked machine, W (EPYC 7543P: 225).
    pub cpu_tdp_w: f64,
}

impl CodeCarbon {
    pub fn new(cpu_tdp_w: f64) -> Self {
        CodeCarbon { cpu_tdp_w }
    }

    /// Energy estimate for a run, J.
    pub fn estimate(&self, r: &RunRecord) -> f64 {
        let gpu = r.nvml_total_j;
        let cpu = CPU_TDP_FRACTION * self.cpu_tdp_w * r.wall_s;
        let ram = RAM_W_PER_GB * HOST_RAM_GB * r.wall_s;
        gpu + cpu + ram
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
    use crate::simulator::simulate_run;

    fn record(g: usize, seed: u64) -> RunRecord {
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, g, 8).with_seed(seed);
        simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default())
    }

    #[test]
    fn estimate_positive_and_misses_truth() {
        let cc = CodeCarbon::new(225.0);
        let r = record(2, 1);
        let e = cc.estimate(&r);
        assert!(e > 0.0);
        // CodeCarbon should be within a factor of 2 of the wall truth but
        // systematically off (it cannot see PSU/fans and NVML is biased).
        let rel = (e - r.true_total_j) / r.true_total_j;
        assert!(rel.abs() < 1.0, "rel={rel}");
        assert!(rel != 0.0);
    }

    #[test]
    fn estimate_scales_with_duration() {
        let cc = CodeCarbon::new(225.0);
        let short = record(4, 2);
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8)
            .with_seq_out(1024)
            .with_seed(2);
        let long = simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default());
        assert!(cc.estimate(&long) > cc.estimate(&short));
    }
}
