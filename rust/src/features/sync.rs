//! Synchronization sampling (the paper's key idea (i), Section 4).
//!
//! During offline profiling the event engine records the full distribution
//! of per-rank waiting times at every rendezvous. Rather than memorizing
//! absolute waits per configuration (which would not transfer to unseen
//! variants), the database stores waits *normalized by the per-layer
//! compute interval* between synchronization points, grouped by
//! (parallelism, GPU count): skew-induced waiting scales with the compute
//! phase it trails. At prediction time the estimate is
//! `κ(g) × (decode time / steps / layers)` computed purely from the target
//! run's execution features; it populates the wait descriptors of the
//! *sync-wait leaves* of the expanded model tree (`tree::LeafPart::Sync`),
//! the leaves whose energy target is the phase-resolved waiting energy the
//! engine isolates.

use std::collections::BTreeMap;

use crate::config::Parallelism;
use crate::simulator::run::RunRecord;
use crate::util::stats;

#[derive(Debug, Clone, Copy, Default)]
struct Kappa {
    /// mean(wait) / layer-interval.
    mean: f64,
    /// std(wait) / layer-interval.
    std: f64,
    n: usize,
}

/// Offline wait-time distribution database.
#[derive(Debug, Clone, Default)]
pub struct SyncDb {
    by_gpus: BTreeMap<(Parallelism, usize), Kappa>,
}

/// Per-layer synchronization interval of a run: decode time per step per
/// layer (the compute span between consecutive collectives).
fn layer_interval(r: &RunRecord) -> f64 {
    let steps = r.config.seq_out.max(1) as f64;
    (r.decode_s / steps / r.spec.layers as f64).max(1e-9)
}

impl SyncDb {
    /// Build from profiled runs (uses their recorded wait samples — this is
    /// the offline, training-side pass).
    pub fn build(runs: &[RunRecord]) -> SyncDb {
        let mut acc: BTreeMap<(Parallelism, usize), (Vec<f64>, Vec<f64>)> = BTreeMap::new();
        for r in runs {
            if r.wait_samples.is_empty() || r.config.gpus < 2 {
                continue;
            }
            let li = layer_interval(r);
            let e = acc
                .entry((r.config.parallelism, r.config.gpus))
                .or_default();
            e.0.push(stats::mean(&r.wait_samples) / li);
            e.1.push(stats::std_dev(&r.wait_samples) / li);
        }
        let by_gpus = acc
            .into_iter()
            .map(|(k, (means, stds))| {
                (
                    k,
                    Kappa {
                        mean: stats::mean(&means),
                        std: stats::mean(&stds),
                        n: means.len(),
                    },
                )
            })
            .collect();
        SyncDb { by_gpus }
    }

    /// Predicted (wait_mean_s, wait_std_s) for a run, from its execution
    /// features and the offline κ table only.
    pub fn wait_estimate(&self, r: &RunRecord) -> (f64, f64) {
        let key = (r.config.parallelism, r.config.gpus);
        match self.by_gpus.get(&key) {
            Some(k) if k.n > 0 => {
                let li = layer_interval(r);
                (k.mean * li, k.std * li)
            }
            _ => (0.0, 0.0),
        }
    }

    pub fn groups(&self) -> usize {
        self.by_gpus.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwSpec, RunConfig, SimKnobs};
    use crate::simulator::simulate_run;

    fn runs(g: usize, n: u64) -> Vec<RunRecord> {
        (0..n)
            .map(|s| {
                let cfg =
                    RunConfig::new("Vicuna-7B", Parallelism::Tensor, g, 8).with_seed(s);
                simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default())
            })
            .collect()
    }

    #[test]
    fn db_builds_groups_per_gpu_count() {
        let mut rs = runs(2, 3);
        rs.extend(runs(4, 3));
        let db = SyncDb::build(&rs);
        assert_eq!(db.groups(), 2);
    }

    #[test]
    fn estimate_close_to_observed_waits() {
        let rs = runs(4, 6);
        let db = SyncDb::build(&rs);
        for r in &rs {
            let (wm, _) = db.wait_estimate(r);
            assert!(wm > 0.0);
            // κ-based estimate within 3× of the run's own measured mean.
            let obs = stats::mean(&r.wait_samples);
            assert!(wm / obs < 3.0 && obs / wm < 3.0, "wm={wm} obs={obs}");
        }
    }

    #[test]
    fn estimate_transfers_to_unseen_model() {
        // Build the DB on Vicuna, query for Mistral: κ transfers because it
        // is normalized by the layer interval.
        let db = SyncDb::build(&runs(2, 5));
        let cfg = RunConfig::new("Mistral-8B", Parallelism::Tensor, 2, 8).with_seed(99);
        let r = simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default());
        let (wm, ws) = db.wait_estimate(&r);
        assert!(wm > 0.0 && ws > 0.0);
        let obs = stats::mean(&r.wait_samples);
        assert!(wm / obs < 4.0 && obs / wm < 4.0, "wm={wm} obs={obs}");
    }

    #[test]
    fn unknown_group_returns_zero() {
        let db = SyncDb::build(&runs(2, 2));
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Data, 4, 8).with_seed(1);
        let r = simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default());
        assert_eq!(db.wait_estimate(&r), (0.0, 0.0));
    }
}
