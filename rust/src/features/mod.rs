//! Feature pipeline (Table 1 of the paper).
//!
//! Three groups: resource-utilization features (cross-GPU statistical
//! aggregates — mean/std/min/max — for scalability across parallelization
//! degrees), execution features, and the model-structure features PIE-P
//! adds. Module-level samples append module descriptors keyed by the tree
//! leaf's *part* (see `tree::LeafPart`): compute leaves carry FLOPs,
//! transfer leaves carry payload/ring geometry, and sync-wait leaves carry
//! the synchronization-sampling statistics plus a part indicator — the
//! phase-resolved attribution threaded up from the event engine.
//!
//! The vector is padded to `FEATURE_DIM` = 48, which is part of the AOT
//! artifact ABI (`python/compile/model.py::FEATURE_DIM`): the batched
//! ridge-predict executable is lowered once for `[256, 48]` inputs.

pub mod sync;

use crate::models::{flops, ModelSpec};
use crate::simulator::run::RunRecord;
use crate::simulator::timeline::ModuleKind;
use crate::tree::{Leaf, LeafPart};
use crate::util::stats::Aggregates;

pub use sync::SyncDb;

/// Padded feature width (must equal python `FEATURE_DIM`).
pub const FEATURE_DIM: usize = 48;

/// Number of run-level (shared) features before module descriptors.
pub const RUN_FEATURES: usize = 32;

/// Human-readable names for the run-level features (Figure-7 heatmap rows).
pub const RUN_FEATURE_NAMES: [&str; RUN_FEATURES] = [
    "cpu_util",
    "cpu_mem_util",
    "cpu_clock",
    "cpu_mem_clock",
    "gpu_util_mean",
    "gpu_util_std",
    "gpu_util_min",
    "gpu_util_max",
    "gpu_mem_util_mean",
    "gpu_mem_util_std",
    "gpu_mem_util_min",
    "gpu_mem_util_max",
    "gpu_clock_mean",
    "gpu_clock_std",
    "gpu_clock_min",
    "gpu_clock_max",
    "gpu_mem_clock_mean",
    "gpu_mem_clock_std",
    "gpu_mem_clock_min",
    "gpu_mem_clock_max",
    "memory_gb",
    "batch_size",
    "seq_len",
    "flops_per_token_b",
    "exec_time_s",
    "nvml_energy_wh",
    "num_gpus",
    "ffn_dim_k",
    "n_blocks",
    "hidden_k",
    "attn_heads",
    "kv_heads",
];

/// Offsets of the module-descriptor features (after the run features).
pub mod module_feat {
    pub const FLOPS_B: usize = super::RUN_FEATURES;
    pub const TIME_SHARE: usize = super::RUN_FEATURES + 1;
    pub const PAYLOAD_MB: usize = super::RUN_FEATURES + 2;
    pub const RING_STEPS: usize = super::RUN_FEATURES + 3;
    pub const WAIT_MEAN_MS: usize = super::RUN_FEATURES + 4;
    pub const WAIT_STD_MS: usize = super::RUN_FEATURES + 5;
    pub const COMM_MBPS_STEP: usize = super::RUN_FEATURES + 6;
    pub const MULTIPLICITY: usize = super::RUN_FEATURES + 7;
    /// 1.0 on synchronization-wait leaves, 0.0 elsewhere.
    pub const IS_SYNC: usize = super::RUN_FEATURES + 8;
    /// `ln(1 + nodes − 1)` on comm leaves: how many nodes the mesh spans
    /// (0.0 on the flat single-node testbed — tier descriptors from the
    /// cluster topology, DESIGN.md §11).
    pub const TIER_NODES: usize = super::RUN_FEATURES + 9;
    /// `ln(1 + intra_bw/inter_bw − 1)` on comm leaves: how much slower the
    /// boundary-crossing ring steps run (0.0 when single-tier).
    pub const TIER_BW_RATIO: usize = super::RUN_FEATURES + 10;
    /// `ln(1 + ep − 1)` on all-to-all leaves: the expert-parallel degree
    /// (how many expert hosts the token exchange spans). 0.0 on every
    /// non-expert strategy, so pre-EP feature vectors are unchanged
    /// (DESIGN.md §16).
    pub const EP_DEGREE: usize = super::RUN_FEATURES + 12;
    /// `ln(1 + top_k·capacity − 1)` on all-to-all leaves: the routing
    /// fan-out pressure (tokens buffered per slot) that drives the
    /// routing-imbalance width of the rendezvous. 0.0 off all-to-all.
    pub const EP_ROUTING: usize = super::RUN_FEATURES + 13;
}

/// Indices of the model-structure features (for the Table-9 ablation).
pub const STRUCT_FEATURE_IDX: [usize; 5] = [27, 28, 29, 30, 31];

/// Index of the critical-path energy-share feature (`RunRecord::crit_frac`,
/// DESIGN.md §15). Default-off (`FeatureOpts::use_crit`) so the trained
/// models and their padding contract are byte-stable; lives in the padding
/// tail, past the last module-descriptor slot.
pub const CRIT_SHARE_IDX: usize = 43;

/// Options controlling which feature groups are populated (ablations).
#[derive(Debug, Clone, Copy)]
pub struct FeatureOpts {
    /// Include the model-structure features (Table 9 ablation toggles off).
    pub use_struct: bool,
    /// Include synchronization-sampling wait features (Appendix J ablation
    /// — "PIE-P w/o waiting" — toggles off).
    pub use_wait: bool,
    /// Include the critical-path energy-share feature
    /// (`CRIT_SHARE_IDX`). Off by default: the padding tail of the
    /// feature vector is part of the trained-model contract.
    pub use_crit: bool,
}

impl Default for FeatureOpts {
    fn default() -> Self {
        FeatureOpts {
            use_struct: true,
            use_wait: true,
            use_crit: false,
        }
    }
}

/// Scale-type features are stored as `ln(1+x)`: the leaf regressors fit
/// log-energy, so log features make them power laws — which is what keeps
/// leave-one-size-out extrapolation (7B→70B) finite. Utilization, clocks
/// and wait statistics stay linear.
#[inline]
fn logf(x: f64) -> f64 {
    x.max(0.0).ln_1p()
}

/// Run-level feature vector (length `FEATURE_DIM`, module slots zero).
pub fn run_features(r: &RunRecord, opts: FeatureOpts) -> Vec<f64> {
    let mut x = vec![0.0; FEATURE_DIM];
    let gu = Aggregates::of(&r.gpu_util);
    let gm = Aggregates::of(&r.gpu_mem_util);
    let gc = Aggregates::of(&r.gpu_clock_ghz);
    let gmc = Aggregates::of(&r.gpu_mem_clock_ghz);
    x[0] = r.cpu_util_pct / 100.0;
    x[1] = r.cpu_mem_util_pct / 100.0;
    x[2] = r.cpu_clock_ghz;
    x[3] = r.cpu_mem_clock_ghz;
    x[4] = gu.mean;
    x[5] = gu.std;
    x[6] = gu.min;
    x[7] = gu.max;
    x[8] = gm.mean;
    x[9] = gm.std;
    x[10] = gm.min;
    x[11] = gm.max;
    x[12] = gc.mean;
    x[13] = gc.std;
    x[14] = gc.min;
    x[15] = gc.max;
    x[16] = gmc.mean;
    x[17] = gmc.std;
    x[18] = gmc.min;
    x[19] = gmc.max;
    x[20] = logf(r.mem_bytes / 1e9);
    x[21] = logf(r.config.batch as f64);
    x[22] = logf(r.config.seq_out as f64 / 1e3);
    let context = r.config.seq_in + r.config.seq_out / 2;
    x[23] = logf(flops::flops_per_token_billion(&r.spec, context));
    x[24] = logf(r.wall_s);
    x[25] = logf(r.nvml_total_j / 3600.0); // Wh, as NVML tooling reports
    x[26] = r.config.gpus as f64;
    if opts.use_struct {
        x[27] = logf(r.spec.ffn as f64 / 1e3);
        x[28] = logf(r.spec.layers as f64);
        x[29] = logf(r.spec.hidden as f64 / 1e3);
        x[30] = logf(r.spec.heads as f64);
        x[31] = logf(r.spec.kv_heads as f64);
    }
    if opts.use_crit {
        x[CRIT_SHARE_IDX] = r.crit_frac();
    }
    x
}

/// Module FLOPs per token (billions) for the descriptor slot.
fn module_flops_b(spec: &ModelSpec, kind: ModuleKind, context: usize) -> f64 {
    let f = crate::models::ModuleFlops::per_token(spec, context);
    let v = match kind {
        ModuleKind::SelfAttention => f.attention,
        ModuleKind::Mlp => f.mlp,
        ModuleKind::Norm => f.norm,
        ModuleKind::LogitsHead => f.logits,
        ModuleKind::Embedding => 2.0 * spec.hidden as f64,
        // Communication modules do no arithmetic.
        _ => 0.0,
    };
    v / 1e9
}

/// Full module-level feature vector for one tree leaf: run features +
/// part-specific descriptors.
///
/// Wait statistics come from the *offline* synchronization-sampling
/// database (`SyncDb`), never from the run's own measured waits — this is
/// what makes the features legal at prediction time for unseen runs.
pub fn module_features(
    r: &RunRecord,
    leaf: Leaf,
    multiplicity: f64,
    sync_db: Option<&SyncDb>,
    opts: FeatureOpts,
) -> Vec<f64> {
    let kind = leaf.kind;
    let mut x = run_features(r, opts);
    let context = r.config.seq_in + r.config.seq_out / 2;
    x[module_feat::FLOPS_B] = logf(module_flops_b(&r.spec, kind, context));
    let total_busy: f64 = r.module_time_s.values().sum();
    x[module_feat::TIME_SHARE] =
        r.module_time_s.get(&kind).copied().unwrap_or(0.0) / total_busy.max(1e-12);
    x[module_feat::MULTIPLICITY] = logf(multiplicity);

    if kind.is_comm() {
        // Communicator geometry: under a hybrid mesh each collective runs
        // over its strategy's own axis, not the full GPU count — AllReduce
        // rings span the TP degree, stage transfers the pipeline axis, and
        // payloads shrink with replica/microbatch sharding. Pure strategies
        // reduce to the original whole-mesh descriptors.
        let g = r.config.gpus;
        let par = r.config.parallelism;
        let (tp, pp, dp) = (par.tensor_degree(g), par.pipeline_degree(g), par.data_degree(g));
        let ep = par.expert_degree(g);
        let (top_k, capacity) = match par {
            crate::config::Parallelism::Expert { top_k, capacity_pct, .. } => {
                (top_k.max(1), capacity_pct.max(100) as f64 / 100.0)
            }
            _ => (2, 1.25),
        };
        let (ar_batch, p2p_micro, ag_batch) = if par.is_hybrid() {
            let shard = (r.config.batch + dp - 1) / dp; // per-replica batch
            let micro = (shard + pp - 1) / pp; // per-stage microbatch
            (micro.max(1), micro.max(1), shard.max(1))
        } else {
            // Pure strategies keep the original whole-batch descriptors.
            (r.config.batch, (r.config.batch + g - 1) / g, r.config.batch)
        };
        // The ring geometry shapes both the transfer time and the number of
        // rendezvous participants, so both parts carry it.
        let ag_ring = if tp > 1 { tp } else { dp };
        x[module_feat::RING_STEPS] = match kind {
            ModuleKind::AllReduce => (2 * tp.saturating_sub(1)) as f64,
            ModuleKind::AllGather => ag_ring.saturating_sub(1) as f64,
            ModuleKind::P2PTransfer => 1.0,
            ModuleKind::AllToAll => ep.saturating_sub(1) as f64,
            _ => 0.0,
        };
        if kind == ModuleKind::AllToAll {
            // Expert-parallel descriptors (comm-leaf-only: the run-level
            // padding contract keeps these slots zero everywhere else).
            x[module_feat::EP_DEGREE] = logf(ep as f64 - 1.0);
            x[module_feat::EP_ROUTING] = logf(top_k as f64 * capacity - 1.0);
        }
        // Cluster-tier descriptors: zero on the flat single-node testbed,
        // so pre-topology feature vectors are unchanged.
        x[module_feat::TIER_NODES] = logf(r.nodes as f64 - 1.0);
        x[module_feat::TIER_BW_RATIO] = logf(r.tier_bw_ratio - 1.0);
        if leaf.part == LeafPart::Transfer {
            // Payload-driven descriptors belong to the transfer phase.
            let payload = match kind {
                ModuleKind::AllReduce => r.spec.allreduce_payload_bytes(ar_batch, 1),
                ModuleKind::AllGather => r.spec.allgather_payload_bytes(ag_batch),
                ModuleKind::P2PTransfer => r.spec.p2p_payload_bytes(p2p_micro, 1) / tp as f64,
                // Per-rank token-exchange payload: the rank's batch shard
                // routed to top_k experts with capacity headroom.
                ModuleKind::AllToAll => {
                    let shard = (r.config.batch + ep - 1) / ep;
                    (shard * r.spec.hidden * r.spec.dtype_bytes) as f64 * top_k as f64 * capacity
                }
                _ => 0.0,
            };
            x[module_feat::PAYLOAD_MB] = logf(payload / 1e6);
            x[module_feat::COMM_MBPS_STEP] = logf(r.comm_bytes_per_step / 1e6);
        }
        if leaf.part == LeafPart::Sync {
            x[module_feat::IS_SYNC] = 1.0;
            if opts.use_wait {
                if let Some(db) = sync_db {
                    let (wm, ws) = db.wait_estimate(r);
                    x[module_feat::WAIT_MEAN_MS] = wm * 1e3;
                    x[module_feat::WAIT_STD_MS] = ws * 1e3;
                }
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
    use crate::simulator::simulate_run;

    fn record() -> RunRecord {
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8).with_seed(1);
        simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default())
    }

    #[test]
    fn run_features_have_expected_width_and_padding() {
        let x = run_features(&record(), FeatureOpts::default());
        assert_eq!(x.len(), FEATURE_DIM);
        // Module slots are zero at run level.
        assert_eq!(x[module_feat::PAYLOAD_MB], 0.0);
        assert_eq!(x[module_feat::IS_SYNC], 0.0);
        // Padding tail is zero.
        assert!(x[41..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn struct_ablation_zeroes_struct_slots() {
        let r = record();
        let with = run_features(&r, FeatureOpts::default());
        let without = run_features(
            &r,
            FeatureOpts {
                use_struct: false,
                ..FeatureOpts::default()
            },
        );
        for &i in &STRUCT_FEATURE_IDX {
            assert!(with[i] > 0.0);
            assert_eq!(without[i], 0.0);
        }
        // Other slots untouched.
        assert_eq!(with[21], without[21]);
    }

    #[test]
    fn transfer_leaf_gets_payload_sync_leaf_gets_marker() {
        let r = record();
        let xfer = module_features(
            &r,
            Leaf::transfer(ModuleKind::AllReduce),
            64.0,
            None,
            FeatureOpts::default(),
        );
        assert!(xfer[module_feat::PAYLOAD_MB] > 0.0);
        assert_eq!(xfer[module_feat::RING_STEPS], 2.0);
        assert_eq!(xfer[module_feat::MULTIPLICITY], 64.0f64.ln_1p());
        assert_eq!(xfer[module_feat::IS_SYNC], 0.0);

        let sync = module_features(
            &r,
            Leaf::sync(ModuleKind::AllReduce),
            64.0,
            None,
            FeatureOpts::default(),
        );
        assert_eq!(sync[module_feat::PAYLOAD_MB], 0.0);
        assert_eq!(sync[module_feat::RING_STEPS], 2.0);
        assert_eq!(sync[module_feat::IS_SYNC], 1.0);
        // No sync DB provided ⇒ wait slots zero.
        assert_eq!(sync[module_feat::WAIT_MEAN_MS], 0.0);
    }

    #[test]
    fn sync_leaf_wait_features_come_from_the_db() {
        let runs: Vec<RunRecord> = (0..4u64)
            .map(|s| {
                let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8).with_seed(s);
                simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default())
            })
            .collect();
        let db = SyncDb::build(&runs);
        let x = module_features(
            &runs[0],
            Leaf::sync(ModuleKind::AllReduce),
            64.0,
            Some(&db),
            FeatureOpts::default(),
        );
        assert!(x[module_feat::WAIT_MEAN_MS] > 0.0);
        // The w/o-waiting ablation drops them even with a DB at hand.
        let ablated = module_features(
            &runs[0],
            Leaf::sync(ModuleKind::AllReduce),
            64.0,
            Some(&db),
            FeatureOpts {
                use_wait: false,
                ..FeatureOpts::default()
            },
        );
        assert_eq!(ablated[module_feat::WAIT_MEAN_MS], 0.0);
    }

    #[test]
    fn compute_module_has_flops_not_payload() {
        let r = record();
        let x = module_features(
            &r,
            Leaf::compute(ModuleKind::Mlp),
            32.0,
            None,
            FeatureOpts::default(),
        );
        assert!(x[module_feat::FLOPS_B] > 0.0);
        assert_eq!(x[module_feat::PAYLOAD_MB], 0.0);
        assert!(x[module_feat::TIME_SHARE] > 0.0);
    }

    #[test]
    fn feature_names_match_count() {
        assert_eq!(RUN_FEATURE_NAMES.len(), RUN_FEATURES);
    }

    #[test]
    fn crit_feature_is_opt_in_and_stays_in_the_padding_tail() {
        let r = record();
        let off = run_features(&r, FeatureOpts::default());
        assert_eq!(off[CRIT_SHARE_IDX], 0.0, "default-off keeps padding zero");
        let on = run_features(
            &r,
            FeatureOpts {
                use_crit: true,
                ..FeatureOpts::default()
            },
        );
        assert!(on[CRIT_SHARE_IDX] > 0.0 && on[CRIT_SHARE_IDX] <= 1.0);
        // Only the crit slot differs.
        for i in 0..FEATURE_DIM {
            if i != CRIT_SHARE_IDX {
                assert_eq!(off[i], on[i], "slot {i}");
            }
        }
    }

    #[test]
    fn tier_slots_zero_on_flat_runs_and_set_on_multi_node_runs() {
        use crate::cluster::LinkTier;
        let flat = module_features(
            &record(),
            Leaf::transfer(ModuleKind::AllReduce),
            64.0,
            None,
            FeatureOpts::default(),
        );
        assert_eq!(flat[module_feat::TIER_NODES], 0.0);
        assert_eq!(flat[module_feat::TIER_BW_RATIO], 0.0);

        let hw = HwSpec::cluster_testbed(2, 2, LinkTier::NvLink, LinkTier::InfiniBand, &[]);
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 4, 8).with_seed(1);
        let r = simulate_run(&cfg, &hw, &SimKnobs::default());
        let tiered = module_features(
            &r,
            Leaf::transfer(ModuleKind::AllReduce),
            64.0,
            None,
            FeatureOpts::default(),
        );
        assert!(tiered[module_feat::TIER_NODES] > 0.0);
        assert!(tiered[module_feat::TIER_BW_RATIO] > 0.0);
        // Compute leaves carry no tier descriptors.
        let mlp = module_features(&r, Leaf::compute(ModuleKind::Mlp), 32.0, None, FeatureOpts::default());
        assert_eq!(mlp[module_feat::TIER_NODES], 0.0);
    }

    #[test]
    fn alltoall_leaves_carry_expert_descriptors() {
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::expert(4), 4, 8).with_seed(1);
        let r = simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default());
        let xfer = module_features(
            &r,
            Leaf::transfer(ModuleKind::AllToAll),
            64.0,
            None,
            FeatureOpts::default(),
        );
        assert!(xfer[module_feat::PAYLOAD_MB] > 0.0);
        assert_eq!(xfer[module_feat::RING_STEPS], 3.0); // ep − 1
        assert!(xfer[module_feat::EP_DEGREE] > 0.0);
        assert!(xfer[module_feat::EP_ROUTING] > 0.0);
        // Non-expert comm leaves keep the EP slots zero (padding contract).
        let tp = record();
        let ar = module_features(
            &tp,
            Leaf::transfer(ModuleKind::AllReduce),
            64.0,
            None,
            FeatureOpts::default(),
        );
        assert_eq!(ar[module_feat::EP_DEGREE], 0.0);
        assert_eq!(ar[module_feat::EP_ROUTING], 0.0);
        // And EP run-level vectors keep the tail past the comm slots zero.
        let run = run_features(&r, FeatureOpts::default());
        assert!(run[module_feat::EP_DEGREE] == 0.0 && run[module_feat::EP_ROUTING] == 0.0);
    }

    #[test]
    fn hybrid_comm_descriptors_use_strategy_axes() {
        use crate::config::Strategy;
        let par = crate::config::Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap();
        let cfg = RunConfig::new("Vicuna-7B", par, 4, 8).with_seed(1);
        let r = simulate_run(&cfg, &HwSpec::default(), &SimKnobs::default());
        let ar = module_features(
            &r,
            Leaf::transfer(ModuleKind::AllReduce),
            64.0,
            None,
            FeatureOpts::default(),
        );
        // AllReduce ring spans the TP axis (degree 2), not all 4 GPUs.
        assert_eq!(ar[module_feat::RING_STEPS], 2.0);
        // Payload reflects the per-stage microbatch (8 / 2 stages = 4), not
        // the full batch.
        let full = run_features(&r, FeatureOpts::default());
        assert!(ar[module_feat::PAYLOAD_MB] > 0.0);
        assert_eq!(full[module_feat::PAYLOAD_MB], 0.0);
        let p2p = module_features(
            &r,
            Leaf::transfer(ModuleKind::P2PTransfer),
            1.0,
            None,
            FeatureOpts::default(),
        );
        assert_eq!(p2p[module_feat::RING_STEPS], 1.0);
        assert!(p2p[module_feat::PAYLOAD_MB] > 0.0);
    }
}
