//! PIE-P's expanded model-tree abstraction (Section 4, Appendix A/B).
//!
//! IrEne's model tree captures the computational structure of the model;
//! PIE-P expands it with dedicated *communication modules* at the precise
//! synchronization points of each parallelism strategy:
//!
//! * tensor: an `AllReduce` node after the self-attention output projection
//!   and after the MLP, inside every block; an `AllGather` at the
//!   vocab-parallel logits head;
//! * pipeline: `P2PTransfer` nodes at each stage boundary;
//! * data: a terminal `AllGather` (batch-output module).
//!
//! With the event engine's phase-resolved attribution, every communication
//! node further splits into a **sync-wait leaf** (the straggler-determined
//! rendezvous waiting phase) and a **transfer leaf** (the network-transfer
//! phase) — the two have different energy physics (busy-spin power vs
//! interconnect-drive power) and different predictive features (wait
//! statistics vs payload/ring geometry), so PIE-P regresses them
//! separately. `CommDetail` selects the granularity: `Omit` reproduces
//! IrEne's abstraction, `TransferOnly` the w/o-waiting ablation.
//!
//! Because every transformer block is structurally identical, the tree
//! stores one `Block` child with a *multiplicity* equal to the layer count
//! (and boundary counts for P2P) — an exactly equivalent collapsed form of
//! the paper's per-block tree, since combiner weights are shared by node
//! kind (Eq. 1 applies `W` to each child's features, not per layer).

use crate::config::Parallelism;
use crate::models::ModelSpec;
use crate::simulator::timeline::ModuleKind;

/// Which execution phase of a module a leaf stands for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LeafPart {
    /// The module's arithmetic (all compute modules).
    Compute,
    /// A communication module's synchronization-wait phase.
    Sync,
    /// A communication module's network-transfer phase.
    Transfer,
}

/// A tree leaf: a module kind plus the execution part it covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Leaf {
    pub kind: ModuleKind,
    pub part: LeafPart,
}

impl Leaf {
    pub fn compute(kind: ModuleKind) -> Leaf {
        Leaf {
            kind,
            part: LeafPart::Compute,
        }
    }

    pub fn sync(kind: ModuleKind) -> Leaf {
        Leaf {
            kind,
            part: LeafPart::Sync,
        }
    }

    pub fn transfer(kind: ModuleKind) -> Leaf {
        Leaf {
            kind,
            part: LeafPart::Transfer,
        }
    }

    pub fn name(&self) -> String {
        match self.part {
            LeafPart::Compute => self.kind.name().to_string(),
            LeafPart::Sync => format!("{} (sync-wait)", self.kind.name()),
            LeafPart::Transfer => format!("{} (transfer)", self.kind.name()),
        }
    }
}

/// Granularity of the communication nodes in the tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommDetail {
    /// No communication nodes at all (IrEne's original abstraction).
    Omit,
    /// Transfer leaves only — the waiting phase is not represented
    /// anywhere in the regression ("PIE-P w/o waiting", Appendix J).
    TransferOnly,
    /// Full phase-resolved decomposition: sync-wait + transfer leaves.
    SyncAndTransfer,
}

impl CommDetail {
    fn leaves(&self, kind: ModuleKind, multiplicity: f64, out: &mut Vec<Node>) {
        match self {
            CommDetail::Omit => {}
            CommDetail::TransferOnly => out.push(Node::leaf(Leaf::transfer(kind), multiplicity)),
            CommDetail::SyncAndTransfer => {
                out.push(Node::leaf(Leaf::sync(kind), multiplicity));
                out.push(Node::leaf(Leaf::transfer(kind), multiplicity));
            }
        }
    }
}

/// A node of the model tree.
#[derive(Debug, Clone)]
pub struct Node {
    pub kind: NodeKind,
    /// How many times this node occurs under its parent.
    pub multiplicity: f64,
    pub children: Vec<Node>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    Root,
    Block,
    Leaf(Leaf),
}

impl Node {
    fn leaf(leaf: Leaf, multiplicity: f64) -> Node {
        Node {
            kind: NodeKind::Leaf(leaf),
            multiplicity,
            children: Vec::new(),
        }
    }

    /// All leaf (leaf, total multiplicity from the root) pairs.
    pub fn leaf_multiplicities(&self) -> Vec<(Leaf, f64)> {
        fn walk(n: &Node, mult: f64, out: &mut Vec<(Leaf, f64)>) {
            let m = mult * n.multiplicity;
            match n.kind {
                NodeKind::Leaf(leaf) => out.push((leaf, m)),
                _ => {
                    for c in &n.children {
                        walk(c, m, out);
                    }
                }
            }
        }
        let mut out = Vec::new();
        walk(self, 1.0, &mut out);
        out
    }

    pub fn count_nodes(&self) -> usize {
        1 + self.children.iter().map(Node::count_nodes).sum::<usize>()
    }
}

/// Build the model tree for a (model, parallelism, degree) configuration.
/// `comm` selects the communication-node granularity (`CommDetail::Omit`
/// reproduces IrEne's original abstraction).
pub fn build(spec: &ModelSpec, parallelism: Parallelism, gpus: usize, comm: CommDetail) -> Node {
    let mut block_children = vec![
        Node::leaf(Leaf::compute(ModuleKind::Norm), 2.0),
        Node::leaf(Leaf::compute(ModuleKind::SelfAttention), 1.0),
        Node::leaf(Leaf::compute(ModuleKind::Mlp), 1.0),
    ];
    let mut root_children = vec![Node::leaf(Leaf::compute(ModuleKind::Embedding), 1.0)];

    // Decompose the (possibly hybrid) parallelism into its per-strategy
    // degrees; a hybrid contributes the communication modules of both of
    // its component strategies.
    let comm = if gpus > 1 { comm } else { CommDetail::Omit };
    let tp = parallelism.tensor_degree(gpus);
    let pp = parallelism.pipeline_degree(gpus);
    let dp = parallelism.data_degree(gpus);

    if tp > 1 {
        // After attention out-projection and after the MLP (Section 4).
        comm.leaves(ModuleKind::AllReduce, 2.0, &mut block_children);
    }

    root_children.push(Node {
        kind: NodeKind::Block,
        multiplicity: spec.layers as f64,
        children: block_children,
    });
    root_children.push(Node::leaf(Leaf::compute(ModuleKind::LogitsHead), 1.0));

    // Vocab-parallel logits collation (TP) and/or terminal replica
    // collation (DP, Appendix E) — one AllGather node each.
    let allgathers = usize::from(tp > 1) + usize::from(dp > 1);
    if allgathers > 0 {
        comm.leaves(ModuleKind::AllGather, allgathers as f64, &mut root_children);
    }
    if pp > 1 {
        // One transfer node per stage boundary.
        comm.leaves(ModuleKind::P2PTransfer, (pp - 1) as f64, &mut root_children);
    }

    Node {
        kind: NodeKind::Root,
        multiplicity: 1.0,
        children: root_children,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    fn mult(leaves: &[(Leaf, f64)], leaf: Leaf) -> Option<f64> {
        leaves.iter().find(|(l, _)| *l == leaf).map(|(_, m)| *m)
    }

    #[test]
    fn tensor_tree_has_split_allreduce_inside_blocks() {
        let spec = by_name("Vicuna-7B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 2, CommDetail::SyncAndTransfer);
        let leaves = tree.leaf_multiplicities();
        // 2 AllReduces per block × 32 blocks, each as sync + transfer.
        assert_eq!(mult(&leaves, Leaf::sync(ModuleKind::AllReduce)), Some(64.0));
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllReduce)), Some(64.0));
        assert!(leaves.iter().any(|(l, _)| l.kind == ModuleKind::AllGather));
    }

    #[test]
    fn transfer_only_drops_sync_leaves() {
        let spec = by_name("Vicuna-7B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 2, CommDetail::TransferOnly);
        let leaves = tree.leaf_multiplicities();
        assert_eq!(mult(&leaves, Leaf::sync(ModuleKind::AllReduce)), None);
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllReduce)), Some(64.0));
    }

    #[test]
    fn irene_tree_has_no_comm_nodes() {
        let spec = by_name("Vicuna-7B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 4, CommDetail::Omit);
        assert!(!tree
            .leaf_multiplicities()
            .iter()
            .any(|(l, _)| l.kind.is_comm()));
    }

    #[test]
    fn single_gpu_tree_has_no_comm_nodes() {
        let spec = by_name("Vicuna-7B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 1, CommDetail::SyncAndTransfer);
        assert!(!tree
            .leaf_multiplicities()
            .iter()
            .any(|(l, _)| l.kind.is_comm()));
    }

    #[test]
    fn pipeline_tree_has_boundary_transfers() {
        let spec = by_name("Llama-70B").unwrap();
        let tree = build(&spec, Parallelism::Pipeline, 4, CommDetail::SyncAndTransfer);
        let leaves = tree.leaf_multiplicities();
        assert_eq!(mult(&leaves, Leaf::sync(ModuleKind::P2PTransfer)), Some(3.0));
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::P2PTransfer)), Some(3.0));
    }

    #[test]
    fn data_tree_has_single_terminal_allgather() {
        let spec = by_name("Vicuna-13B").unwrap();
        let tree = build(&spec, Parallelism::Data, 4, CommDetail::SyncAndTransfer);
        let leaves = tree.leaf_multiplicities();
        assert_eq!(mult(&leaves, Leaf::sync(ModuleKind::AllGather)), Some(1.0));
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllGather)), Some(1.0));
    }

    #[test]
    fn hybrid_trees_compose_both_strategies_comm_modules() {
        use crate::config::Strategy;
        let spec = by_name("Vicuna-7B").unwrap();

        let tp_pp = Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap();
        let leaves = build(&spec, tp_pp, 4, CommDetail::SyncAndTransfer).leaf_multiplicities();
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllReduce)), Some(64.0)); // 2 × 32 blocks
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::P2PTransfer)), Some(1.0)); // 2 stages → 1 boundary
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllGather)), Some(1.0)); // logits collation
        assert_eq!(mult(&leaves, Leaf::sync(ModuleKind::AllReduce)), Some(64.0));

        let tp_dp = Parallelism::hybrid(Strategy::Tensor, Strategy::Data, 2).unwrap();
        let leaves = build(&spec, tp_dp, 4, CommDetail::SyncAndTransfer).leaf_multiplicities();
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllReduce)), Some(64.0));
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllGather)), Some(2.0)); // logits + terminal
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::P2PTransfer)), None);

        let pp_dp = Parallelism::hybrid(Strategy::Pipeline, Strategy::Data, 2).unwrap();
        let leaves = build(&spec, pp_dp, 4, CommDetail::SyncAndTransfer).leaf_multiplicities();
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllReduce)), None);
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::P2PTransfer)), Some(1.0));
        assert_eq!(mult(&leaves, Leaf::transfer(ModuleKind::AllGather)), Some(1.0)); // terminal collation
    }

    #[test]
    fn norm_multiplicity_two_per_block() {
        let spec = by_name("Qwen-14B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 2, CommDetail::SyncAndTransfer);
        let norm = tree
            .leaf_multiplicities()
            .into_iter()
            .find(|(l, _)| l.kind == ModuleKind::Norm)
            .unwrap();
        assert_eq!(norm.1, 2.0 * spec.layers as f64);
    }

    #[test]
    fn node_counts_reasonable() {
        let spec = by_name("Vicuna-7B").unwrap();
        let t = build(&spec, Parallelism::Tensor, 2, CommDetail::SyncAndTransfer);
        assert!(t.count_nodes() >= 7);
        assert!(
            t.count_nodes()
                > build(&spec, Parallelism::Tensor, 2, CommDetail::TransferOnly).count_nodes()
        );
    }

    #[test]
    fn leaf_names_distinguish_parts() {
        assert_eq!(Leaf::compute(ModuleKind::Mlp).name(), "MLP");
        assert_eq!(Leaf::sync(ModuleKind::AllReduce).name(), "AllReduce (sync-wait)");
        assert_eq!(Leaf::transfer(ModuleKind::AllReduce).name(), "AllReduce (transfer)");
    }
}
