//! PIE-P's expanded model-tree abstraction (Section 4, Appendix A/B).
//!
//! IrEne's model tree captures the computational structure of the model;
//! PIE-P expands it with dedicated *communication modules* at the precise
//! synchronization points of each parallelism strategy:
//!
//! * tensor: an `AllReduce` node after the self-attention output projection
//!   and after the MLP, inside every block; an `AllGather` at the
//!   vocab-parallel logits head;
//! * pipeline: `P2PTransfer` nodes at each stage boundary;
//! * data: a terminal `AllGather` (batch-output module).
//!
//! Because every transformer block is structurally identical, the tree
//! stores one `Block` child with a *multiplicity* equal to the layer count
//! (and boundary counts for P2P) — an exactly equivalent collapsed form of
//! the paper's per-block tree, since combiner weights are shared by node
//! kind (Eq. 1 applies `W` to each child's features, not per layer).

use crate::config::Parallelism;
use crate::models::ModelSpec;
use crate::simulator::timeline::ModuleKind;

/// A node of the model tree.
#[derive(Debug, Clone)]
pub struct Node {
    pub kind: NodeKind,
    /// How many times this node occurs under its parent.
    pub multiplicity: f64,
    pub children: Vec<Node>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    Root,
    Block,
    Leaf(ModuleKind),
}

impl Node {
    fn leaf(kind: ModuleKind, multiplicity: f64) -> Node {
        Node {
            kind: NodeKind::Leaf(kind),
            multiplicity,
            children: Vec::new(),
        }
    }

    /// All leaf (kind, total multiplicity from the root) pairs.
    pub fn leaf_multiplicities(&self) -> Vec<(ModuleKind, f64)> {
        fn walk(n: &Node, mult: f64, out: &mut Vec<(ModuleKind, f64)>) {
            let m = mult * n.multiplicity;
            match n.kind {
                NodeKind::Leaf(k) => out.push((k, m)),
                _ => {
                    for c in &n.children {
                        walk(c, m, out);
                    }
                }
            }
        }
        let mut out = Vec::new();
        walk(self, 1.0, &mut out);
        out
    }

    pub fn count_nodes(&self) -> usize {
        1 + self.children.iter().map(Node::count_nodes).sum::<usize>()
    }
}

/// Build the model tree for a (model, parallelism, degree) configuration.
/// `include_comm = false` reproduces IrEne's original abstraction (the
/// baseline that omits inter-GPU collectives).
pub fn build(spec: &ModelSpec, parallelism: Parallelism, gpus: usize, include_comm: bool) -> Node {
    let mut block_children = vec![
        Node::leaf(ModuleKind::Norm, 2.0),
        Node::leaf(ModuleKind::SelfAttention, 1.0),
        Node::leaf(ModuleKind::Mlp, 1.0),
    ];
    let mut root_children = vec![Node::leaf(ModuleKind::Embedding, 1.0)];

    // Decompose the (possibly hybrid) parallelism into its per-strategy
    // degrees; a hybrid contributes the communication modules of both of
    // its component strategies.
    let comm = include_comm && gpus > 1;
    let tp = parallelism.tensor_degree(gpus);
    let pp = parallelism.pipeline_degree(gpus);
    let dp = parallelism.data_degree(gpus);

    if comm && tp > 1 {
        // After attention out-projection and after the MLP (Section 4).
        block_children.push(Node::leaf(ModuleKind::AllReduce, 2.0));
    }

    root_children.push(Node {
        kind: NodeKind::Block,
        multiplicity: spec.layers as f64,
        children: block_children,
    });
    root_children.push(Node::leaf(ModuleKind::LogitsHead, 1.0));

    if comm {
        // Vocab-parallel logits collation (TP) and/or terminal replica
        // collation (DP, Appendix E) — one AllGather node each.
        let allgathers = usize::from(tp > 1) + usize::from(dp > 1);
        if allgathers > 0 {
            root_children.push(Node::leaf(ModuleKind::AllGather, allgathers as f64));
        }
        if pp > 1 {
            // One transfer node per stage boundary.
            root_children.push(Node::leaf(ModuleKind::P2PTransfer, (pp - 1) as f64));
        }
    }

    Node {
        kind: NodeKind::Root,
        multiplicity: 1.0,
        children: root_children,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    #[test]
    fn tensor_tree_has_allreduce_inside_blocks() {
        let spec = by_name("Vicuna-7B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 2, true);
        let leaves = tree.leaf_multiplicities();
        let ar = leaves
            .iter()
            .find(|(k, _)| *k == ModuleKind::AllReduce)
            .unwrap();
        // 2 AllReduces per block × 32 blocks.
        assert_eq!(ar.1, 64.0);
        assert!(leaves.iter().any(|(k, _)| *k == ModuleKind::AllGather));
    }

    #[test]
    fn irene_tree_has_no_comm_nodes() {
        let spec = by_name("Vicuna-7B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 4, false);
        assert!(!tree
            .leaf_multiplicities()
            .iter()
            .any(|(k, _)| k.is_comm()));
    }

    #[test]
    fn single_gpu_tree_has_no_comm_nodes() {
        let spec = by_name("Vicuna-7B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 1, true);
        assert!(!tree
            .leaf_multiplicities()
            .iter()
            .any(|(k, _)| k.is_comm()));
    }

    #[test]
    fn pipeline_tree_has_boundary_transfers() {
        let spec = by_name("Llama-70B").unwrap();
        let tree = build(&spec, Parallelism::Pipeline, 4, true);
        let p2p = tree
            .leaf_multiplicities()
            .into_iter()
            .find(|(k, _)| *k == ModuleKind::P2PTransfer)
            .unwrap();
        assert_eq!(p2p.1, 3.0);
    }

    #[test]
    fn data_tree_has_single_terminal_allgather() {
        let spec = by_name("Vicuna-13B").unwrap();
        let tree = build(&spec, Parallelism::Data, 4, true);
        let ag = tree
            .leaf_multiplicities()
            .into_iter()
            .find(|(k, _)| *k == ModuleKind::AllGather)
            .unwrap();
        assert_eq!(ag.1, 1.0);
    }

    #[test]
    fn hybrid_trees_compose_both_strategies_comm_modules() {
        use crate::config::Strategy;
        let spec = by_name("Vicuna-7B").unwrap();

        let tp_pp = Parallelism::hybrid(Strategy::Tensor, Strategy::Pipeline, 2).unwrap();
        let leaves = build(&spec, tp_pp, 4, true).leaf_multiplicities();
        let get = |kind: ModuleKind| leaves.iter().find(|(k, _)| *k == kind).map(|(_, m)| *m);
        assert_eq!(get(ModuleKind::AllReduce), Some(64.0)); // 2 × 32 blocks
        assert_eq!(get(ModuleKind::P2PTransfer), Some(1.0)); // 2 stages → 1 boundary
        assert_eq!(get(ModuleKind::AllGather), Some(1.0)); // logits collation

        let tp_dp = Parallelism::hybrid(Strategy::Tensor, Strategy::Data, 2).unwrap();
        let leaves = build(&spec, tp_dp, 4, true).leaf_multiplicities();
        let get = |kind: ModuleKind| leaves.iter().find(|(k, _)| *k == kind).map(|(_, m)| *m);
        assert_eq!(get(ModuleKind::AllReduce), Some(64.0));
        assert_eq!(get(ModuleKind::AllGather), Some(2.0)); // logits + terminal
        assert_eq!(get(ModuleKind::P2PTransfer), None);

        let pp_dp = Parallelism::hybrid(Strategy::Pipeline, Strategy::Data, 2).unwrap();
        let leaves = build(&spec, pp_dp, 4, true).leaf_multiplicities();
        let get = |kind: ModuleKind| leaves.iter().find(|(k, _)| *k == kind).map(|(_, m)| *m);
        assert_eq!(get(ModuleKind::AllReduce), None);
        assert_eq!(get(ModuleKind::P2PTransfer), Some(1.0));
        assert_eq!(get(ModuleKind::AllGather), Some(1.0)); // terminal collation
    }

    #[test]
    fn norm_multiplicity_two_per_block() {
        let spec = by_name("Qwen-14B").unwrap();
        let tree = build(&spec, Parallelism::Tensor, 2, true);
        let norm = tree
            .leaf_multiplicities()
            .into_iter()
            .find(|(k, _)| *k == ModuleKind::Norm)
            .unwrap();
        assert_eq!(norm.1, 2.0 * spec.layers as f64);
    }

    #[test]
    fn node_counts_reasonable() {
        let spec = by_name("Vicuna-7B").unwrap();
        let t = build(&spec, Parallelism::Tensor, 2, true);
        assert!(t.count_nodes() >= 7);
    }
}
