//! Front-door request router.
//!
//! The router picks one routable replica per arriving request from the
//! replicas' load signals (`ReplicaView`). Every policy is a pure
//! deterministic function of its inputs with id-ordered tie-breaks, so
//! routing decisions — and therefore the whole fleet simulation — are
//! bit-reproducible per seed (proptest-pinned).

use crate::serve::Request;

/// Routing policy of the fleet front door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cyclic scan over routable replicas.
    RoundRobin,
    /// Fewest in-flight requests (queued + resident), lowest id on ties.
    JoinShortestQueue,
    /// Lowest observed J/token so far; replicas with no history yet score
    /// zero, so a cold fleet degenerates to JSQ-like spreading via the
    /// in-flight tie-break.
    EnergyAware,
    /// Hash the request's session id (request id when absent) onto the
    /// replica ring, then cyclic-scan to the first routable replica —
    /// requests of one conversation stick to one warm KV home.
    SessionAffinity,
}

impl RouterPolicy {
    pub const ALL: [RouterPolicy; 4] = [
        RouterPolicy::RoundRobin,
        RouterPolicy::JoinShortestQueue,
        RouterPolicy::EnergyAware,
        RouterPolicy::SessionAffinity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "rr",
            RouterPolicy::JoinShortestQueue => "jsq",
            RouterPolicy::EnergyAware => "energy",
            RouterPolicy::SessionAffinity => "session",
        }
    }

    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" => Some(RouterPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" => Some(RouterPolicy::JoinShortestQueue),
            "energy" | "energy-aware" => Some(RouterPolicy::EnergyAware),
            "session" | "session-affinity" => Some(RouterPolicy::SessionAffinity),
            _ => None,
        }
    }
}

/// One replica's router-visible load signals at a routing instant.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    pub id: usize,
    /// Up or warming up — the autoscaler's drained/stopped replicas are
    /// not routable.
    pub routable: bool,
    /// Requests routed here and not yet finished.
    pub in_flight: usize,
    /// Observed energy per generated token so far, J (0 before the first
    /// step).
    pub j_per_token: f64,
}

/// Pick the serving replica for `req`. `rr_next` carries the round-robin
/// cursor between calls (ignored by the other policies). Panics if no
/// replica is routable — the autoscaler's `min_replicas` floor guarantees
/// one.
pub fn route(policy: RouterPolicy, req: &Request, views: &[ReplicaView], rr_next: &mut usize) -> usize {
    assert!(views.iter().any(|v| v.routable), "no routable replica");
    let scan_from = |start: usize| -> usize {
        (0..views.len())
            .map(|k| (start + k) % views.len())
            .find(|&i| views[i].routable)
            .expect("checked a routable replica exists")
    };
    match policy {
        RouterPolicy::RoundRobin => {
            let i = scan_from(*rr_next % views.len());
            *rr_next = (i + 1) % views.len();
            i
        }
        RouterPolicy::JoinShortestQueue => {
            views
                .iter()
                .filter(|v| v.routable)
                .min_by_key(|v| (v.in_flight, v.id))
                .expect("checked a routable replica exists")
                .id
        }
        RouterPolicy::EnergyAware => {
            views
                .iter()
                .filter(|v| v.routable)
                .min_by(|a, b| {
                    a.j_per_token
                        .total_cmp(&b.j_per_token)
                        .then_with(|| a.in_flight.cmp(&b.in_flight))
                        .then_with(|| a.id.cmp(&b.id))
                })
                .expect("checked a routable replica exists")
                .id
        }
        RouterPolicy::SessionAffinity => {
            let key = req.session.unwrap_or(req.id) as usize;
            scan_from(key % views.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u32, session: Option<u32>) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt_tokens: 8,
            output_tokens: 2,
            session,
        }
    }

    fn views(loads: &[(bool, usize, f64)]) -> Vec<ReplicaView> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &(routable, in_flight, j_per_token))| ReplicaView {
                id,
                routable,
                in_flight,
                j_per_token,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles_over_routable_replicas() {
        let v = views(&[(true, 0, 0.0), (false, 0, 0.0), (true, 0, 0.0)]);
        let mut cursor = 0;
        let picks: Vec<usize> = (0..4).map(|i| route(RouterPolicy::RoundRobin, &req(i, None), &v, &mut cursor)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "skips the non-routable replica");
    }

    #[test]
    fn jsq_picks_least_loaded_lowest_id() {
        let v = views(&[(true, 3, 0.0), (true, 1, 0.0), (true, 1, 0.0)]);
        let mut cursor = 0;
        assert_eq!(route(RouterPolicy::JoinShortestQueue, &req(0, None), &v, &mut cursor), 1);
    }

    #[test]
    fn energy_aware_prefers_cheap_history_then_load() {
        let v = views(&[(true, 0, 2.0), (true, 5, 1.0), (false, 0, 0.5)]);
        let mut cursor = 0;
        assert_eq!(route(RouterPolicy::EnergyAware, &req(0, None), &v, &mut cursor), 1);
        // A cold fleet (no history) falls back to load, then id.
        let cold = views(&[(true, 2, 0.0), (true, 1, 0.0)]);
        assert_eq!(route(RouterPolicy::EnergyAware, &req(0, None), &cold, &mut cursor), 1);
    }

    #[test]
    fn session_affinity_sticks_and_falls_back_to_id_hash() {
        let v = views(&[(true, 0, 0.0), (true, 0, 0.0), (true, 0, 0.0)]);
        let mut cursor = 0;
        for id in 0..9 {
            assert_eq!(route(RouterPolicy::SessionAffinity, &req(id, Some(4)), &v, &mut cursor), 1);
        }
        // Without a session id, the request id seeds the hash.
        assert_eq!(route(RouterPolicy::SessionAffinity, &req(5, None), &v, &mut cursor), 2);
        // A non-routable home shifts to the next replica on the ring.
        let v2 = views(&[(true, 0, 0.0), (false, 0, 0.0), (true, 0, 0.0)]);
        assert_eq!(route(RouterPolicy::SessionAffinity, &req(0, Some(4)), &v2, &mut cursor), 2);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in RouterPolicy::ALL {
            assert_eq!(RouterPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RouterPolicy::parse("energy-aware"), Some(RouterPolicy::EnergyAware));
        assert_eq!(RouterPolicy::parse("random"), None);
    }
}
