//! Replica autoscaler: a fixed-interval control loop over the fleet.
//!
//! Every `interval_s` the controller compares total in-flight load against
//! a per-replica target and moves replicas through the lifecycle
//!
//! ```text
//! Down --Start(cold_start_s, cold_start_j)--> Starting --ready--> Up
//! Up --Drain--> Draining --queue empty--> Down (Stop)
//! ```
//!
//! Scale-up prefers reviving a Draining replica (still warm: no cold-start
//! cost) before cold-starting the lowest-index Down replica, which accrues
//! `cold_start_j` into the cluster energy and delays readiness by
//! `cold_start_s`. Scale-down drains the highest-index Up replica:
//! draining replicas take no new requests but finish everything already
//! routed to them (drain-before-shutdown), and only transition Down once
//! empty. `min_replicas` keeps a routable floor so the router always has a
//! target. Everything is a pure function of (tick time, in-flight counts),
//! so scaling decisions are bit-deterministic.

/// One replica's lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReplicaState {
    /// Serving and routable.
    Up,
    /// Cold-starting; routable (requests queue), but the replica's clock
    /// cannot schedule before `ready_at_s`.
    Starting { ready_at_s: f64 },
    /// Not routable; finishing its already-routed requests.
    Draining,
    /// Off. Costs nothing, serves nothing.
    Down,
}

impl ReplicaState {
    /// May the router send new requests here?
    pub fn routable(&self) -> bool {
        matches!(self, ReplicaState::Up | ReplicaState::Starting { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplicaState::Up => "up",
            ReplicaState::Starting { .. } => "starting",
            ReplicaState::Draining => "draining",
            ReplicaState::Down => "down",
        }
    }
}

/// What a scale event did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Down/Draining → Starting/Up.
    Start,
    /// Up → Draining.
    Drain,
    /// Draining → Down (queue drained).
    Stop,
}

/// One logged autoscaler decision.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleEvent {
    /// Control-tick time, s.
    pub t_s: f64,
    pub replica: usize,
    pub action: ScaleAction,
}

/// Autoscaler control parameters.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Control-loop tick interval, s.
    pub interval_s: f64,
    /// Target in-flight requests per routable replica; the desired
    /// replica count is `ceil(total_in_flight / target)`.
    pub target_inflight: usize,
    /// Routable floor (clamped to the fleet size).
    pub min_replicas: usize,
    /// Wall-clock from a Start decision to readiness, s.
    pub cold_start_s: f64,
    /// Energy cost of one cold start (weight load, CUDA context, fans), J.
    pub cold_start_j: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            interval_s: 2.0,
            target_inflight: 4,
            min_replicas: 1,
            cold_start_s: 1.0,
            cold_start_j: 150.0,
        }
    }
}

/// The control loop's mutable state: tick cursor, event log, accrued
/// cold-start energy.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    /// Every decision taken, in tick order.
    pub events: Vec<ScaleEvent>,
    /// Σ cold-start energy accrued, J (part of the cluster total).
    pub cold_start_j: f64,
    next_tick_s: f64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        assert!(cfg.interval_s > 0.0, "degenerate autoscale interval");
        let first = cfg.interval_s;
        Autoscaler {
            cfg,
            events: Vec::new(),
            cold_start_j: 0.0,
            next_tick_s: first,
        }
    }

    /// Initial fleet states: the routable floor Up, the rest Down.
    pub fn initial_states(&self, n: usize) -> Vec<ReplicaState> {
        let floor = self.cfg.min_replicas.clamp(1, n.max(1));
        (0..n)
            .map(|i| if i < floor { ReplicaState::Up } else { ReplicaState::Down })
            .collect()
    }

    /// Time of the next control tick, s.
    pub fn next_tick_s(&self) -> f64 {
        self.next_tick_s
    }

    /// Run the control tick at `self.next_tick_s()`. `in_flight[i]` is
    /// replica i's queued + resident count at the tick. Mutates `states`
    /// and returns the indices cold-started this tick with their
    /// `ready_at_s` (so the fleet can hold their serving clocks).
    pub fn tick(&mut self, in_flight: &[usize], states: &mut [ReplicaState]) -> Vec<(usize, f64)> {
        assert_eq!(in_flight.len(), states.len());
        let t = self.next_tick_s;
        self.next_tick_s += self.cfg.interval_s;
        let n = states.len();

        // Promotions first: warm-ups that became ready, drains that emptied.
        for i in 0..n {
            match states[i] {
                ReplicaState::Starting { ready_at_s } if ready_at_s <= t => states[i] = ReplicaState::Up,
                ReplicaState::Draining if in_flight[i] == 0 => {
                    states[i] = ReplicaState::Down;
                    self.events.push(ScaleEvent {
                        t_s: t,
                        replica: i,
                        action: ScaleAction::Stop,
                    });
                }
                _ => {}
            }
        }

        let total: usize = (0..n).filter(|&i| states[i].routable()).map(|i| in_flight[i]).sum();
        let floor = self.cfg.min_replicas.clamp(1, n);
        let desired = total.div_ceil(self.cfg.target_inflight.max(1)).clamp(floor, n);
        let mut routable = (0..n).filter(|&i| states[i].routable()).count();

        let mut started = Vec::new();
        while routable < desired {
            // Revive a warm draining replica first (free), else cold-start
            // the lowest-index Down replica.
            if let Some(i) = (0..n).find(|&i| states[i] == ReplicaState::Draining) {
                states[i] = ReplicaState::Up;
                self.events.push(ScaleEvent {
                    t_s: t,
                    replica: i,
                    action: ScaleAction::Start,
                });
            } else if let Some(i) = (0..n).find(|&i| states[i] == ReplicaState::Down) {
                let ready_at_s = t + self.cfg.cold_start_s;
                states[i] = ReplicaState::Starting { ready_at_s };
                self.cold_start_j += self.cfg.cold_start_j;
                self.events.push(ScaleEvent {
                    t_s: t,
                    replica: i,
                    action: ScaleAction::Start,
                });
                started.push((i, ready_at_s));
            } else {
                break; // everything already routable
            }
            routable += 1;
        }
        while routable > desired {
            // Drain the highest-index Up replica; Starting replicas keep
            // warming (their cold-start cost is already sunk).
            match (0..n).rev().find(|&i| states[i] == ReplicaState::Up) {
                Some(i) => {
                    states[i] = ReplicaState::Draining;
                    self.events.push(ScaleEvent {
                        t_s: t,
                        replica: i,
                        action: ScaleAction::Drain,
                    });
                    routable -= 1;
                }
                None => break,
            }
        }
        started
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(target: usize, min: usize) -> Autoscaler {
        Autoscaler::new(AutoscaleConfig {
            target_inflight: target,
            min_replicas: min,
            ..AutoscaleConfig::default()
        })
    }

    #[test]
    fn initial_states_respect_the_floor() {
        let s = scaler(4, 2);
        let states = s.initial_states(4);
        assert_eq!(states[..2], [ReplicaState::Up, ReplicaState::Up]);
        assert_eq!(states[2..], [ReplicaState::Down, ReplicaState::Down]);
        assert!(states[0].routable() && !states[2].routable());
    }

    #[test]
    fn load_scales_up_with_cold_start_cost_and_down_with_drain() {
        let mut s = scaler(2, 1);
        let mut states = s.initial_states(3);
        // 6 in-flight on one replica at target 2 -> desired 3: two cold starts.
        let started = s.tick(&[6, 0, 0], &mut states);
        assert_eq!(started.len(), 2);
        assert_eq!(started[0].0, 1);
        assert!(started[0].1 > s.cfg.interval_s);
        assert_eq!(s.cold_start_j, 2.0 * s.cfg.cold_start_j);
        assert!(states.iter().all(|st| st.routable()));
        // Next tick: the starters are ready; load collapsed -> drain back
        // to the floor, highest index first.
        let started = s.tick(&[1, 0, 0], &mut states);
        assert!(started.is_empty());
        assert_eq!(states[0], ReplicaState::Up);
        assert_eq!(states[1], ReplicaState::Draining);
        assert_eq!(states[2], ReplicaState::Draining);
        // Drained queues empty -> Stop events, replicas Down.
        s.tick(&[1, 0, 0], &mut states);
        assert_eq!(states[1], ReplicaState::Down);
        assert_eq!(states[2], ReplicaState::Down);
        let stops = s.events.iter().filter(|e| e.action == ScaleAction::Stop).count();
        assert_eq!(stops, 2);
    }

    #[test]
    fn draining_replica_is_revived_for_free() {
        let mut s = scaler(2, 1);
        let mut states = vec![ReplicaState::Up, ReplicaState::Draining];
        let j_before = s.cold_start_j;
        // Desired 2 -> revive the draining replica rather than cold-start.
        let started = s.tick(&[4, 3], &mut states);
        assert!(started.is_empty(), "revival is not a cold start");
        assert_eq!(states[1], ReplicaState::Up);
        assert_eq!(s.cold_start_j, j_before);
    }

    #[test]
    fn busy_draining_replica_keeps_draining() {
        let mut s = scaler(100, 1);
        let mut states = vec![ReplicaState::Up, ReplicaState::Draining];
        // Low load: desired stays 1; the draining replica still has work.
        s.tick(&[0, 2], &mut states);
        assert_eq!(states[1], ReplicaState::Draining, "drain-before-shutdown");
        s.tick(&[0, 0], &mut states);
        assert_eq!(states[1], ReplicaState::Down);
    }
}
