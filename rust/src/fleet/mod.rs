//! Fleet-scale multi-replica serving: the cluster layer over the serving
//! simulator (DESIGN.md §13).
//!
//! A [`Fleet` configuration](FleetConfig) describes N independent
//! replicas — each a full ExecPlan-backed serving mesh
//! ([`serve::Session`](crate::serve::Session)), possibly heterogeneous via
//! its own [`TestbedSpec`] and possibly running a different tuned strategy
//! — behind a front-door [`router`] and an optional [`autoscaler`].
//! `simulate_fleet` replays one trace through the cluster: arrivals route
//! to a replica, every replica advances its own serving clock between
//! routing instants, and the autoscaler's control loop spins replicas
//! up/down against the load with cold-start energy cost and
//! drain-before-shutdown semantics.
//!
//! Replicas with the same mesh (model / parallelism / GPU count /
//! testbed) share one `Arc<StepLowerer>`, so plan structures lower once
//! per mesh topology across the whole fleet — the serving win of the
//! compiled plan cache, at cluster scale.
//!
//! Two invariants carry up from the serving layer unchanged and are
//! property-tested across every router policy:
//!
//! * **conservation** — Σ per-request attributed J + cold-start J ==
//!   Σ replica step J + cold-start J == cluster J (rel 1e-9);
//! * **bit-determinism** — the same (trace, config, seed) reproduces
//!   identical routing decisions, per-request records, and cluster energy.
//!
//! # Example: route one trace through a two-replica fleet
//!
//! ```
//! use piep::config::{Parallelism, TestbedSpec};
//! use piep::fleet::{simulate_fleet, FleetConfig, ReplicaSpec, RouterPolicy};
//! use piep::serve::{synthesize, ServeConfig, SynthSpec};
//!
//! let trace = synthesize(
//!     &SynthSpec {
//!         requests: 3,
//!         prompt_mean: 32.0,
//!         prompt_range: (8, 64),
//!         output_mean: 4.0,
//!         output_range: (2, 6),
//!         ..SynthSpec::default()
//!     },
//!     7,
//! );
//! let replica = || ReplicaSpec::new(
//!     ServeConfig::new("Vicuna-7B", Parallelism::Tensor, 2),
//!     TestbedSpec::Flat { gpus: 2 },
//! );
//! let cfg = FleetConfig::new(vec![replica(), replica()])
//!     .with_router(RouterPolicy::EnergyAware)
//!     .with_base_seed(7);
//! let res = simulate_fleet(&trace, &cfg);
//! assert_eq!(res.requests.len(), trace.len());
//! // Conservation: attributed + cold-start energy equals the cluster total.
//! let attributed = res.attributed_energy_j();
//! assert!((attributed - res.cluster_energy_j).abs() <= 1e-9 * res.cluster_energy_j);
//! ```

pub mod autoscaler;
pub mod router;

pub use autoscaler::{AutoscaleConfig, Autoscaler, ReplicaState, ScaleAction, ScaleEvent};
pub use router::{route, ReplicaView, RouterPolicy};

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{SimKnobs, TestbedSpec};
use crate::plan::CacheStats;
use crate::serve::{
    prefetch_shared_steps, RequestRecord, ServeConfig, ServeResult, Session, StepLowerer, Trace,
};
use crate::util::stats::percentile;

/// One replica of the fleet: its serving configuration and the testbed
/// its mesh runs on.
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    /// Per-replica serving configuration. `base_seed` is a *fleet-relative*
    /// base: `simulate_fleet` folds the replica index into it so replicas
    /// draw independent substrate streams.
    pub serve: ServeConfig,
    /// Where the replica's mesh runs.
    pub testbed: TestbedSpec,
}

impl ReplicaSpec {
    /// Pair a serving configuration with a testbed; the mesh size follows
    /// the testbed (`serve.gpus` is overwritten with `testbed.gpus()`).
    pub fn new(mut serve: ServeConfig, testbed: TestbedSpec) -> ReplicaSpec {
        serve.gpus = testbed.gpus();
        ReplicaSpec { serve, testbed }
    }

    /// Mesh identity: replicas with equal keys share one step lowerer
    /// (and therefore one set of plan structures).
    pub fn mesh_key(&self) -> String {
        format!(
            "{}/{}/g{}/{}",
            self.serve.model,
            self.serve.parallelism.label(),
            self.serve.gpus,
            self.testbed.label()
        )
    }
}

/// The whole cluster: replicas, front-door policy, optional autoscaler.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub replicas: Vec<ReplicaSpec>,
    pub router: RouterPolicy,
    /// `None` ⇒ every replica is Up for the whole trace (no cold starts).
    pub autoscale: Option<AutoscaleConfig>,
    /// Substrate knobs shared by every replica's step simulations.
    pub knobs: SimKnobs,
    /// Cluster seed; replica substrate seeds derive from it.
    pub base_seed: u64,
}

impl FleetConfig {
    pub fn new(replicas: Vec<ReplicaSpec>) -> FleetConfig {
        FleetConfig {
            replicas,
            router: RouterPolicy::JoinShortestQueue,
            autoscale: None,
            knobs: SimKnobs::default(),
            base_seed: 0xF1EE7, // "FLEET"
        }
    }

    /// Chainable: set the router policy.
    pub fn with_router(mut self, router: RouterPolicy) -> FleetConfig {
        self.router = router;
        self
    }

    /// Chainable: enable the autoscaler.
    pub fn with_autoscale(mut self, autoscale: AutoscaleConfig) -> FleetConfig {
        self.autoscale = Some(autoscale);
        self
    }

    /// Chainable: set the substrate knobs.
    pub fn with_knobs(mut self, knobs: SimKnobs) -> FleetConfig {
        self.knobs = knobs;
        self
    }

    /// Chainable: set the cluster seed.
    pub fn with_base_seed(mut self, seed: u64) -> FleetConfig {
        self.base_seed = seed;
        self
    }
}

/// One request's record plus the replica that served (or rejected) it.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetRequest {
    pub replica: usize,
    pub record: RequestRecord,
}

/// One replica's outcome.
#[derive(Debug, Clone)]
pub struct ReplicaSummary {
    pub id: usize,
    pub mesh_key: String,
    /// Requests the router sent here.
    pub routed: usize,
    pub result: ServeResult,
}

/// Outcome of replaying one trace through the cluster.
#[derive(Debug, Clone)]
pub struct FleetResult {
    /// Per-request records tagged with their replica, sorted by id.
    pub requests: Vec<FleetRequest>,
    pub replicas: Vec<ReplicaSummary>,
    /// Autoscaler decision log (empty without an autoscaler).
    pub scale_events: Vec<ScaleEvent>,
    /// Σ cold-start energy, J.
    pub cold_start_j: f64,
    /// Cluster energy: Σ replica step energy + cold-start energy, J.
    pub cluster_energy_j: f64,
    /// Cluster makespan: the slowest replica's serving clock, s.
    pub makespan_s: f64,
    /// Plan-cache counters aggregated over the fleet's shared lowerers.
    pub cache: CacheStats,
    /// Distinct mesh topologies across the fleet (shared lowerers).
    pub shared_lowerers: usize,
}

impl FleetResult {
    /// Served (non-rejected) request records with their replica.
    pub fn served(&self) -> impl Iterator<Item = &FleetRequest> {
        self.requests.iter().filter(|f| !f.record.rejected)
    }

    /// Generated tokens across served requests.
    pub fn generated_tokens(&self) -> usize {
        self.served().map(|f| f.record.output_tokens).sum()
    }

    /// Σ attributed per-request energy + cold-start energy, J. Equals
    /// `cluster_energy_j` within 1e-9 relative (the conservation
    /// invariant, property-tested).
    pub fn attributed_energy_j(&self) -> f64 {
        self.requests.iter().map(|f| f.record.energy_j).sum::<f64>() + self.cold_start_j
    }

    /// Cluster energy per generated token, J — the headline metric.
    pub fn j_per_token(&self) -> f64 {
        self.cluster_energy_j / self.generated_tokens().max(1) as f64
    }

    /// Percentile of end-to-end latency over served requests, s.
    pub fn latency_percentile_s(&self, p: f64) -> f64 {
        let xs: Vec<f64> = self.served().map(|f| f.record.latency_s()).collect();
        percentile(&xs, p)
    }

    /// Steps per critical-path binding resource, aggregated across every
    /// replica (`ServeResult::bound_hist` summed cluster-wide).
    pub fn bound_hist(&self) -> BTreeMap<String, usize> {
        let mut out: BTreeMap<String, usize> = BTreeMap::new();
        for r in &self.replicas {
            for (b, n) in &r.result.bound_hist {
                *out.entry(b.clone()).or_insert(0) += n;
            }
        }
        out
    }
}

/// Advance every replica to `t`. With `batch_execution` on, each round of
/// the lockstep loop first speculatively executes the replicas' predicted
/// next steps, batching the ones that coincide on (mesh, shape) into one
/// engine walk (`serve::prefetch_shared_steps`, DESIGN.md §14) — replicas
/// evolve independently between routing instants, so the interleaving is
/// record-for-record identical to advancing them one by one.
fn advance_replicas(sessions: &mut [Session], t: f64, batched: bool) {
    if !batched {
        for s in sessions.iter_mut() {
            s.advance_to(t);
        }
        return;
    }
    loop {
        prefetch_shared_steps(sessions, t);
        let mut progressed = false;
        for s in sessions.iter_mut() {
            if s.clock() < t && s.round() {
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
}

/// Replay `trace` through the cluster. Bit-deterministic per
/// (`trace`, `cfg`); panics if the fleet is empty or a replica's model
/// does not fit its testbed.
pub fn simulate_fleet(trace: &Trace, cfg: &FleetConfig) -> FleetResult {
    assert!(!cfg.replicas.is_empty(), "fleet needs at least one replica");
    // One shared lowerer per distinct mesh: plan structures lower once
    // per topology, not once per replica.
    let mut lowerers: BTreeMap<String, Arc<StepLowerer>> = BTreeMap::new();
    let mut sessions: Vec<Session> = Vec::with_capacity(cfg.replicas.len());
    let mut mesh_keys: Vec<String> = Vec::with_capacity(cfg.replicas.len());
    for (i, spec) in cfg.replicas.iter().enumerate() {
        let hw = spec.testbed.hw();
        let key = spec.mesh_key();
        let lowerer = lowerers
            .entry(key.clone())
            .or_insert_with(|| {
                Arc::new(StepLowerer::new(
                    &spec.serve.model,
                    spec.serve.parallelism,
                    spec.serve.gpus,
                    hw.clone(),
                    &cfg.knobs,
                ))
            })
            .clone();
        let scfg = ServeConfig {
            base_seed: cfg.base_seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ..spec.serve.clone()
        };
        sessions.push(Session::with_lowerer(&scfg, &hw, lowerer));
        mesh_keys.push(key);
    }

    let mut scaler = cfg.autoscale.clone().map(Autoscaler::new);
    let mut states: Vec<ReplicaState> = match &scaler {
        Some(s) => s.initial_states(sessions.len()),
        None => vec![ReplicaState::Up; sessions.len()],
    };
    let mut routed_counts = vec![0usize; sessions.len()];
    let mut rr_next = 0usize;
    let batched = cfg.knobs.batch_execution;

    for req in &trace.requests {
        let t = req.arrival_s;
        // Control ticks due before this arrival.
        if let Some(sc) = scaler.as_mut() {
            while sc.next_tick_s() <= t {
                let tick = sc.next_tick_s();
                advance_replicas(&mut sessions, tick, batched);
                let in_flight: Vec<usize> = sessions.iter().map(Session::in_flight).collect();
                for (i, ready_at_s) in sc.tick(&in_flight, &mut states) {
                    // A cold-started replica cannot schedule before it is
                    // ready; its queue waits.
                    sessions[i].skip_to(ready_at_s);
                }
            }
        }
        // Bring every replica's clock to the routing instant (steps in
        // progress finish; queues admit at their decode boundaries).
        advance_replicas(&mut sessions, t, batched);
        let views: Vec<ReplicaView> = sessions
            .iter()
            .enumerate()
            .map(|(i, s)| ReplicaView {
                id: i,
                routable: states[i].routable(),
                in_flight: s.in_flight(),
                j_per_token: s.j_per_token_so_far(),
            })
            .collect();
        let target = route(cfg.router, req, &views, &mut rr_next);
        sessions[target].enqueue(req.clone());
        routed_counts[target] += 1;
    }
    advance_replicas(&mut sessions, f64::INFINITY, batched);

    let mut cache = CacheStats::default();
    for lw in lowerers.values() {
        let (c, _) = lw.stats();
        cache.structure_lowerings += c.structure_lowerings;
        cache.rebinds += c.rebinds;
        cache.affine_rebinds += c.affine_rebinds;
        cache.replay_fallbacks += c.replay_fallbacks;
        cache.probe_rejected_ops += c.probe_rejected_ops;
        cache.shape_hits += c.shape_hits;
        cache.batches += c.batches;
        cache.batched_lanes += c.batched_lanes;
        cache.serial_fallbacks += c.serial_fallbacks;
    }
    let shared_lowerers = lowerers.len();

    let results: Vec<ServeResult> = sessions.into_iter().map(Session::finish).collect();
    let mut requests: Vec<FleetRequest> = Vec::with_capacity(trace.len());
    for (i, res) in results.iter().enumerate() {
        for rec in &res.requests {
            requests.push(FleetRequest {
                replica: i,
                record: rec.clone(),
            });
        }
    }
    requests.sort_by_key(|f| f.record.id);

    let replica_energy_j: f64 = results.iter().map(|r| r.total_energy_j).sum();
    let (scale_events, cold_start_j) = match scaler {
        Some(s) => (s.events, s.cold_start_j),
        None => (Vec::new(), 0.0),
    };
    let makespan_s = results.iter().map(|r| r.makespan_s).fold(0.0, f64::max);
    let replicas = results
        .into_iter()
        .enumerate()
        .map(|(id, result)| ReplicaSummary {
            id,
            mesh_key: mesh_keys[id].clone(),
            routed: routed_counts[id],
            result,
        })
        .collect();
    FleetResult {
        requests,
        replicas,
        scale_events,
        cold_start_j,
        cluster_energy_j: replica_energy_j + cold_start_j,
        makespan_s,
        cache,
        shared_lowerers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;
    use crate::serve::{synthesize, ArrivalKind, SynthSpec};

    fn tiny_trace(requests: usize, seed: u64) -> Trace {
        synthesize(
            &SynthSpec {
                requests,
                rate_rps: 4.0,
                prompt_mean: 32.0,
                prompt_range: (8, 64),
                output_mean: 4.0,
                output_range: (2, 8),
                sessions: 3,
                ..SynthSpec::default()
            },
            seed,
        )
    }

    fn tiny_replica() -> ReplicaSpec {
        ReplicaSpec::new(
            ServeConfig::new("Vicuna-7B", Parallelism::Tensor, 2).with_max_batch_requests(4),
            TestbedSpec::Flat { gpus: 2 },
        )
    }

    fn tiny_fleet(n: usize) -> FleetConfig {
        FleetConfig::new(vec![tiny_replica(); n])
    }

    #[test]
    fn fleet_serves_every_request_and_conserves_energy() {
        let trace = tiny_trace(8, 1);
        for policy in RouterPolicy::ALL {
            let res = simulate_fleet(&trace, &tiny_fleet(2).with_router(policy));
            assert_eq!(res.requests.len(), trace.len(), "{policy:?}");
            let routed: usize = res.replicas.iter().map(|r| r.routed).sum();
            assert_eq!(routed, trace.len());
            let rel = (res.attributed_energy_j() - res.cluster_energy_j).abs() / res.cluster_energy_j;
            assert!(rel < 1e-9, "{policy:?}: rel {rel}");
            assert!(res.cluster_energy_j > 0.0 && res.makespan_s > 0.0);
            assert!(res.j_per_token() > 0.0);
            // Binding-resource histogram covers every executed step.
            let total_steps: usize = res.replicas.iter().map(|r| r.result.steps.len()).sum();
            let counted: usize = res.bound_hist().values().sum();
            assert_eq!(counted, total_steps, "{policy:?}");
        }
    }

    #[test]
    fn fleet_is_bit_deterministic_per_seed() {
        let trace = tiny_trace(8, 2);
        let cfg = tiny_fleet(2).with_router(RouterPolicy::EnergyAware);
        let a = simulate_fleet(&trace, &cfg);
        let b = simulate_fleet(&trace, &cfg);
        assert_eq!(a.requests, b.requests, "identical routing + records");
        assert_eq!(a.cluster_energy_j, b.cluster_energy_j);
        let c = simulate_fleet(&trace, &cfg.clone().with_base_seed(99));
        assert_ne!(a.cluster_energy_j, c.cluster_energy_j);
    }

    #[test]
    fn same_mesh_replicas_share_one_lowerer() {
        let trace = tiny_trace(6, 3);
        let res = simulate_fleet(&trace, &tiny_fleet(3));
        assert_eq!(res.shared_lowerers, 1, "one mesh topology across the fleet");
        assert_eq!(res.cache.structure_lowerings, 1, "structures lower once per mesh");
        // A heterogeneous fleet (different strategy on replica 1) needs two.
        let mut cfg = tiny_fleet(2);
        cfg.replicas[1] = ReplicaSpec::new(
            ServeConfig::new("Vicuna-7B", Parallelism::Pipeline, 2).with_max_batch_requests(4),
            TestbedSpec::Flat { gpus: 2 },
        );
        let het = simulate_fleet(&trace, &cfg);
        assert_eq!(het.shared_lowerers, 2);
        let rel = (het.attributed_energy_j() - het.cluster_energy_j).abs() / het.cluster_energy_j;
        assert!(rel < 1e-9, "heterogeneous conservation: rel {rel}");
    }

    #[test]
    fn batched_fleet_matches_serial_fleet_and_batches_coinciding_steps() {
        use crate::serve::Request;
        // Two identical requests routed to two identical replicas decode
        // in lockstep: every decode round coincides on (mesh, shape) and
        // resolves as one two-lane batched walk.
        let reqs: Vec<Request> = (0..2)
            .map(|id| Request {
                id,
                arrival_s: 0.0,
                prompt_tokens: 32,
                output_tokens: 4,
                session: None,
            })
            .collect();
        let trace = Trace::new(reqs);
        let cfg = tiny_fleet(2).with_router(RouterPolicy::RoundRobin);
        let on = simulate_fleet(&trace, &cfg);
        let off = simulate_fleet(
            &trace,
            &cfg.clone().with_knobs(SimKnobs::default().with_batch_execution(false)),
        );
        assert_eq!(on.requests, off.requests, "bit-identical with batching off");
        assert_eq!(on.cluster_energy_j, off.cluster_energy_j);
        assert_eq!(on.makespan_s, off.makespan_s);
        // output_tokens = 4 ⇒ 3 decode iterations per replica; the first
        // rides the admission round (unpredictable), the remaining two
        // coincide and batch.
        assert_eq!(on.cache.batches, 2, "one batched walk per coinciding decode round");
        assert_eq!(on.cache.batched_lanes, 4);
        assert_eq!(off.cache.batches, 0);
        assert!(off.cache.serial_fallbacks > on.cache.serial_fallbacks);
    }

    #[test]
    fn session_affinity_pins_conversations_to_one_replica() {
        let trace = tiny_trace(10, 4);
        let res = simulate_fleet(&trace, &tiny_fleet(3).with_router(RouterPolicy::SessionAffinity));
        // All replicas Up and routability never changes, so each session
        // maps to exactly one replica.
        let mut home: std::collections::BTreeMap<u32, usize> = std::collections::BTreeMap::new();
        for (req, f) in trace.requests.iter().zip(res.requests.iter()) {
            assert_eq!(req.id, f.record.id);
            let s = req.session.expect("synth trace has sessions");
            let prev = home.insert(s, f.replica);
            if let Some(p) = prev {
                assert_eq!(p, f.replica, "session {s} moved replicas");
            }
        }
    }

    #[test]
    fn autoscaler_scales_and_cold_starts_cost_energy() {
        let trace = synthesize(
            &SynthSpec {
                kind: ArrivalKind::Bursty,
                requests: 12,
                rate_rps: 6.0,
                prompt_mean: 32.0,
                prompt_range: (8, 64),
                output_mean: 4.0,
                output_range: (2, 8),
                ..SynthSpec::default()
            },
            5,
        );
        let cfg = tiny_fleet(3).with_autoscale(AutoscaleConfig {
            interval_s: 0.25,
            target_inflight: 1,
            ..AutoscaleConfig::default()
        });
        let res = simulate_fleet(&trace, &cfg);
        assert!(!res.scale_events.is_empty(), "bursty load must trigger scaling");
        let cold_starts = res
            .scale_events
            .iter()
            .filter(|e| e.action == ScaleAction::Start)
            .count();
        assert!(cold_starts > 0);
        assert!(res.cold_start_j > 0.0);
        // Conservation includes the cold-start term on both sides.
        let rel = (res.attributed_energy_j() - res.cluster_energy_j).abs() / res.cluster_energy_j;
        assert!(rel < 1e-9, "rel {rel}");
        // Every request still gets served or explicitly rejected.
        assert_eq!(res.requests.len(), trace.len());
    }
}
