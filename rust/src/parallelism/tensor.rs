//! Tensor-parallel planner (Megatron-style).
//!
//! Every transformer block runs its attention and MLP shards on all g GPUs
//! concurrently; results are combined by a ring AllReduce after (1) the
//! attention output projection and (2) the MLP down-projection — exactly
//! the two synchronization points PIE-P adds to the model tree. Because
//! ranks skew during compute, each AllReduce opens with a non-deterministic
//! waiting phase (recorded per rank into `wait_samples`).

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::simulator::collective;
use crate::simulator::perf::{ModuleTiming, PerfModel};
use crate::simulator::power::PowerModel;
use crate::simulator::skew::SkewModel;
use crate::simulator::timeline::{ModuleKind, PhaseKind, Timeline};
use crate::util::rng::Rng;

use super::BuiltRun;

pub fn build(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    power: &PowerModel,
    rng: &mut Rng,
) -> BuiltRun {
    let g = cfg.gpus;
    let perf = PerfModel::new(hw);
    let skew = SkewModel::with_complexity(knobs, g, spec.complexity_factor(), rng);
    let mut tl = Timeline::new(g, power.gpu_power(PhaseKind::Idle, 0.0));
    let mut wait_samples = Vec::new();
    let mut comm_bytes_per_step = 0.0;

    let sim_steps = knobs.sim_decode_steps.min(cfg.seq_out).max(1);

    // Per-module compute helper: sample skewed duration per rank, push.
    let compute =
        |tl: &mut Timeline,
         rng: &mut Rng,
         timing: ModuleTiming,
         module: ModuleKind,
         layer: u16,
         step: u32| {
            for rank in 0..g {
                let dur = skew.sample_module(timing.dur_s, rank, module, rng);
                let p = power.gpu_power(PhaseKind::Compute, timing.util);
                tl.push(rank, PhaseKind::Compute, module, layer, step, dur, p);
            }
        };

    // Ring AllReduce sync: each rank arrives with its own launch-desync
    // delay, waits for the slowest, then all transfer in lockstep. Returns
    // per-rank waits into wait_samples.
    let sync_jitter = knobs.sync_jitter_s
        * spec.complexity_factor()
        * rng.lognormal_mean_cv(1.0, knobs.sync_jitter_cv);
    let allreduce = |tl: &mut Timeline,
                         rng: &mut Rng,
                         wait_samples: &mut Vec<f64>,
                         payload: f64,
                         layer: u16,
                         step: u32| {
        if g == 1 {
            // No collective is emitted at all on a single GPU.
            return 0.0;
        }
        let wait_w = power.gpu_power(PhaseKind::Wait, 0.0);
        // Launch desynchronization: host-side skew before the collective
        // kernel is live on each rank (recorded as waiting-phase energy —
        // the GPU spins in the NCCL kernel).
        let arrive_max = (0..g)
            .map(|r| tl.clock(r) + rng.exponential(sync_jitter))
            .fold(0.0, f64::max);
        for rank in 0..g {
            let w = tl.wait_until(rank, arrive_max, ModuleKind::AllReduce, layer, step, wait_w);
            wait_samples.push(w);
        }
        let cost = collective::allreduce(hw, g, payload);
        let comm_w = power.gpu_power(PhaseKind::Transfer, 0.0);
        for rank in 0..g {
            tl.push(
                rank,
                PhaseKind::Transfer,
                ModuleKind::AllReduce,
                layer,
                step,
                cost.transfer_s,
                comm_w,
            );
        }
        cost.bytes_moved
    };

    // ---- Prefill (step 0): compute-bound pass over the prompt.
    let prefill_payload = (cfg.batch * cfg.seq_in * spec.hidden * spec.dtype_bytes) as f64;
    compute(
        &mut tl,
        rng,
        perf.embed_decode(spec, cfg.batch * cfg.seq_in),
        ModuleKind::Embedding,
        0,
        0,
    );
    for layer in 0..spec.layers as u16 {
        compute(&mut tl, rng, perf.norm_prefill(spec, cfg.batch, cfg.seq_in), ModuleKind::Norm, layer, 0);
        compute(&mut tl, rng, perf.attn_prefill(spec, cfg.batch, cfg.seq_in, g), ModuleKind::SelfAttention, layer, 0);
        allreduce(&mut tl, rng, &mut wait_samples, prefill_payload, layer, 0);
        compute(&mut tl, rng, perf.norm_prefill(spec, cfg.batch, cfg.seq_in), ModuleKind::Norm, layer, 0);
        compute(&mut tl, rng, perf.mlp_prefill(spec, cfg.batch, cfg.seq_in, g), ModuleKind::Mlp, layer, 0);
        allreduce(&mut tl, rng, &mut wait_samples, prefill_payload, layer, 0);
    }
    let prefill_end = tl.makespan();

    // ---- Decode: `sim_steps` representative steps spread over seq_out.
    let decode_payload = spec.allreduce_payload_bytes(cfg.batch, 1);
    for si in 0..sim_steps {
        let step = (si + 1) as u32;
        // Representative KV context for this sampled step.
        let frac = (si as f64 + 0.5) / sim_steps as f64;
        let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;

        compute(&mut tl, rng, perf.embed_decode(spec, cfg.batch), ModuleKind::Embedding, 0, step);
        for layer in 0..spec.layers as u16 {
            compute(&mut tl, rng, perf.norm_decode(spec, cfg.batch), ModuleKind::Norm, layer, step);
            compute(&mut tl, rng, perf.attn_decode(spec, cfg.batch, context, g), ModuleKind::SelfAttention, layer, step);
            let b1 = allreduce(&mut tl, rng, &mut wait_samples, decode_payload, layer, step);
            compute(&mut tl, rng, perf.norm_decode(spec, cfg.batch), ModuleKind::Norm, layer, step);
            compute(&mut tl, rng, perf.mlp_decode(spec, cfg.batch, g), ModuleKind::Mlp, layer, step);
            let b2 = allreduce(&mut tl, rng, &mut wait_samples, decode_payload, layer, step);
            if si == 0 {
                comm_bytes_per_step += b1 + b2;
            }
        }
        // Vocab-parallel logits + AllGather of the shards.
        compute(&mut tl, rng, perf.logits_decode(spec, cfg.batch, g), ModuleKind::LogitsHead, 0, step);
        if g > 1 {
            let arrive_max = (0..g).map(|r| tl.clock(r)).fold(0.0, f64::max);
            let wait_w = power.gpu_power(PhaseKind::Wait, 0.0);
            for rank in 0..g {
                let w = tl.wait_until(rank, arrive_max, ModuleKind::AllGather, 0, step, wait_w);
                wait_samples.push(w);
            }
            let shard = spec.allgather_payload_bytes(cfg.batch) / g as f64;
            let cost = collective::allgather(hw, g, shard);
            let comm_w = power.gpu_power(PhaseKind::Transfer, 0.0);
            for rank in 0..g {
                tl.push(rank, PhaseKind::Transfer, ModuleKind::AllGather, 0, step, cost.transfer_s, comm_w);
            }
            if si == 0 {
                comm_bytes_per_step += cost.bytes_moved;
            }
        }
    }

    tl.finalize();
    BuiltRun {
        timeline: tl,
        wait_samples,
        prefill_end,
        sim_steps,
        comm_bytes_per_step,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;
    use crate::models::by_name;

    fn build_run(gpus: usize, seed: u64) -> BuiltRun {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, gpus, 8).with_seed(seed);
        let power = PowerModel::new(&hw);
        let mut rng = Rng::new(seed);
        build(&spec, &hw, &knobs, &cfg, &power, &mut rng)
    }

    #[test]
    fn allreduce_count_matches_structure() {
        let r = build_run(2, 1);
        // 2 AllReduces per layer per step (prefill + 4 decode steps).
        let ar_xfers = r
            .timeline
            .phases
            .iter()
            .filter(|p| p.module == ModuleKind::AllReduce && p.kind == PhaseKind::Transfer)
            .count();
        let expected = 2 * 32 * (1 + 4) * 2; // syncs × ranks
        assert_eq!(ar_xfers, expected);
    }

    #[test]
    fn waits_are_nonnegative_and_some_positive() {
        let r = build_run(4, 2);
        assert!(r.wait_samples.iter().all(|&w| w >= 0.0));
        let positive = r.wait_samples.iter().filter(|&&w| w > 0.0).count();
        // With skew, all but the slowest rank wait at nearly every sync.
        assert!(positive as f64 > 0.5 * r.wait_samples.len() as f64);
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let r = build_run(1, 3);
        assert!(!r
            .timeline
            .phases
            .iter()
            .any(|p| p.kind == PhaseKind::Transfer));
        assert!(r.wait_samples.iter().all(|&w| w == 0.0));
    }

    #[test]
    fn more_gpus_faster_decode() {
        let r2 = build_run(2, 4);
        let r4 = build_run(4, 4);
        let d2 = r2.timeline.makespan() - r2.prefill_end;
        let d4 = r4.timeline.makespan() - r4.prefill_end;
        assert!(d4 < d2, "decode g=4 {d4} vs g=2 {d2}");
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let a = build_run(2, 9);
        let b = build_run(2, 9);
        assert_eq!(a.timeline.makespan(), b.timeline.makespan());
        assert_eq!(a.wait_samples, b.wait_samples);
    }

    #[test]
    fn ranks_synchronized_after_final_collective() {
        let r = build_run(4, 5);
        let clocks: Vec<f64> = (0..4).map(|g| r.timeline.clock(g)).collect();
        for c in &clocks {
            assert!((c - clocks[0]).abs() < 1e-12);
        }
    }
}
