//! Tensor-parallel lowerer (Megatron-style).
//!
//! Every transformer block runs its attention and MLP shards on all g GPUs
//! concurrently; results are combined by a ring AllReduce after (1) the
//! attention output projection and (2) the MLP down-projection — exactly
//! the two synchronization points PIE-P adds to the model tree. The
//! AllReduce ops are *jittered rendezvous* events: at execution each rank
//! arrives with its own launch-desync delay and the straggler determines
//! the start, producing the non-deterministic waiting phase the paper
//! samples.

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::plan::affine::{BatchArg, CollKind, CommBase, CommScale, CommTerm, ComputeRule, OpRule, PayloadRule};
use crate::plan::{Plan, PlanBuilder, PlanSink, WaitRecord};
use crate::simulator::collective;
use crate::simulator::perf::PerfModel;
use crate::simulator::timeline::ModuleKind;

use super::LowerMeta;

/// Reference lowering into the interpreted `Plan` representation.
pub fn lower(spec: &ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &RunConfig) -> Plan {
    let mut b = PlanBuilder::new(cfg.gpus);
    let m = lower_into(spec, hw, knobs, cfg, &mut b);
    b.finish(m.sim_steps, m.comm_bytes_per_step, m.draws_sync_jitter)
}

/// Lowering pass, generic over the sink (reference build, SoA compile, or
/// shape rebind — see `plan::PlanSink`).
pub fn lower_into<S: PlanSink>(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    b: &mut S,
) -> LowerMeta {
    let g = cfg.gpus;
    let perf = PerfModel::new(hw);
    let topo = hw.topo();
    let mut comm_bytes_per_step = 0.0;
    let sim_steps = knobs.sim_decode_steps.min(cfg.seq_out).max(1);

    // Ring AllReduce rendezvous over all g ranks — hierarchical when the
    // mesh spans nodes (intra-node reduce, inter-node exchange, intra-node
    // broadcast). Returns bytes moved.
    let topo_ref = &topo;
    let gu = g as u32;
    let ar_coll = CollKind::AllReduceHier { first: 0, n: gu };
    let allreduce = move |b: &mut S, payload: f64, pr: PayloadRule, layer: u16, step: u32| -> f64 {
        if g == 1 {
            // No collective is emitted at all on a single GPU.
            return 0.0;
        }
        let t = collective::allreduce_hier(topo_ref, 0, g, payload);
        let (xfer, wire) = (t.cost.transfer_s, t.wire_w);
        b.rule(OpRule::Collective { coll: ar_coll, payload: pr });
        b.collective_tiered(0..g, ModuleKind::AllReduce, layer, step, xfer, wire, true, WaitRecord::All);
        t.cost.bytes_moved
    };

    // ---- Prefill (step 0): compute-bound pass over the prompt.
    let prefill_payload = (cfg.batch * cfg.seq_in * spec.hidden * spec.dtype_bytes) as f64;
    let pr_prefill = PayloadRule::Acts { batch: BatchArg::Full, times_seq_in: true };
    b.rule(OpRule::Compute(ComputeRule::Embed { batch: BatchArg::Full, times_seq_in: true }));
    b.compute(0..g, perf.embed_decode(spec, cfg.batch * cfg.seq_in), ModuleKind::Embedding, 0, 0);
    for layer in 0..spec.layers as u16 {
        b.rule(OpRule::Compute(ComputeRule::NormPrefill { batch: BatchArg::Full }));
        b.compute(0..g, perf.norm_prefill(spec, cfg.batch, cfg.seq_in), ModuleKind::Norm, layer, 0);
        b.rule(OpRule::Compute(ComputeRule::AttnPrefill { batch: BatchArg::Full, g: gu }));
        b.compute(0..g, perf.attn_prefill(spec, cfg.batch, cfg.seq_in, g), ModuleKind::SelfAttention, layer, 0);
        allreduce(&mut *b, prefill_payload, pr_prefill, layer, 0);
        b.rule(OpRule::Compute(ComputeRule::NormPrefill { batch: BatchArg::Full }));
        b.compute(0..g, perf.norm_prefill(spec, cfg.batch, cfg.seq_in), ModuleKind::Norm, layer, 0);
        b.rule(OpRule::Compute(ComputeRule::MlpPrefill { batch: BatchArg::Full, g: gu }));
        b.compute(0..g, perf.mlp_prefill(spec, cfg.batch, cfg.seq_in, g), ModuleKind::Mlp, layer, 0);
        allreduce(&mut *b, prefill_payload, pr_prefill, layer, 0);
    }

    // ---- Decode: `sim_steps` representative steps spread over seq_out.
    let decode_payload = spec.allreduce_payload_bytes(cfg.batch, 1);
    let pr_decode = PayloadRule::Acts { batch: BatchArg::Full, times_seq_in: false };
    let ag_coll = CollKind::AllGatherRing { first: 0, n: gu, ring: gu };
    let pr_ag = PayloadRule::AgShard { batch: BatchArg::Full, div: gu };
    for si in 0..sim_steps {
        let step = (si + 1) as u32;
        // Representative KV context for this sampled step.
        let frac = (si as f64 + 0.5) / sim_steps as f64;
        let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;

        b.rule(OpRule::Compute(ComputeRule::Embed { batch: BatchArg::Full, times_seq_in: false }));
        b.compute(0..g, perf.embed_decode(spec, cfg.batch), ModuleKind::Embedding, 0, step);
        for layer in 0..spec.layers as u16 {
            b.rule(OpRule::Compute(ComputeRule::NormDecode { batch: BatchArg::Full }));
            b.compute(0..g, perf.norm_decode(spec, cfg.batch), ModuleKind::Norm, layer, step);
            b.rule(OpRule::Compute(ComputeRule::AttnDecode { batch: BatchArg::Full, si: si as u32, g: gu }));
            b.compute(0..g, perf.attn_decode(spec, cfg.batch, context, g), ModuleKind::SelfAttention, layer, step);
            let b1 = allreduce(&mut *b, decode_payload, pr_decode, layer, step);
            b.rule(OpRule::Compute(ComputeRule::NormDecode { batch: BatchArg::Full }));
            b.compute(0..g, perf.norm_decode(spec, cfg.batch), ModuleKind::Norm, layer, step);
            b.rule(OpRule::Compute(ComputeRule::MlpDecode { batch: BatchArg::Full, g: gu }));
            b.compute(0..g, perf.mlp_decode(spec, cfg.batch, g), ModuleKind::Mlp, layer, step);
            let b2 = allreduce(&mut *b, decode_payload, pr_decode, layer, step);
            if si == 0 {
                // `b1 + b2` is summed before the accumulate — a CollPair
                // term, and exact (0-byte when g == 1).
                b.comm_term(CommTerm {
                    base: CommBase::CollPair { coll: ar_coll, payload: pr_decode },
                    scale: CommScale::One,
                });
                comm_bytes_per_step += b1 + b2;
            }
        }
        // Vocab-parallel logits + AllGather of the shards.
        b.rule(OpRule::Compute(ComputeRule::LogitsDecode { batch: BatchArg::Full, g: gu }));
        b.compute(0..g, perf.logits_decode(spec, cfg.batch, g), ModuleKind::LogitsHead, 0, step);
        if g > 1 {
            let shard = spec.allgather_payload_bytes(cfg.batch) / g as f64;
            let t = collective::allgather_ring(&topo, 0, g, g, shard);
            let (xfer, wire) = (t.cost.transfer_s, t.wire_w);
            b.rule(OpRule::Collective { coll: ag_coll, payload: pr_ag });
            b.collective_tiered(0..g, ModuleKind::AllGather, 0, step, xfer, wire, false, WaitRecord::All);
            if si == 0 {
                b.comm_term(CommTerm {
                    base: CommBase::Coll { coll: ag_coll, payload: pr_ag },
                    scale: CommScale::One,
                });
                comm_bytes_per_step += t.cost.bytes_moved;
            }
        }
    }

    // The tensor planner draws the per-run launch-desync scale even on a
    // single GPU (the seed stream predates the g == 1 early return).
    LowerMeta {
        sim_steps,
        comm_bytes_per_step,
        draws_sync_jitter: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;
    use crate::models::by_name;
    use crate::parallelism::BuiltRun;
    use crate::simulator::power::PowerModel;
    use crate::simulator::timeline::PhaseKind;
    use crate::util::rng::Rng;

    fn build_run(gpus: usize, seed: u64) -> BuiltRun {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, gpus, 8).with_seed(seed);
        let power = PowerModel::new(&hw);
        let mut rng = Rng::new(seed);
        crate::parallelism::build(&spec, &hw, &knobs, &cfg, &power, &mut rng)
    }

    #[test]
    fn allreduce_count_matches_structure() {
        let r = build_run(2, 1);
        // 2 AllReduces per layer per step (prefill + 4 decode steps).
        let ar_xfers = r
            .timeline
            .phases
            .iter()
            .filter(|p| p.module == ModuleKind::AllReduce && p.kind == PhaseKind::Transfer)
            .count();
        let expected = 2 * 32 * (1 + 4) * 2; // syncs × ranks
        assert_eq!(ar_xfers, expected);
    }

    #[test]
    fn plan_is_seed_free_and_structured() {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Tensor, 2, 8);
        let plan = lower(&spec, &hw, &knobs, &cfg);
        let (compute, coll, send, recv) = plan.op_census();
        assert!(compute > 0);
        // 2 AllReduces × 32 layers × 5 passes + 4 decode AllGathers.
        assert_eq!(coll, 2 * 32 * 5 + 4);
        assert_eq!((send, recv), (0, 0));
        assert!(plan.draws_sync_jitter);
        assert!(plan.comm_bytes_per_step > 0.0);
    }

    #[test]
    fn waits_are_nonnegative_and_some_positive() {
        let r = build_run(4, 2);
        assert!(r.wait_samples.iter().all(|&w| w >= 0.0));
        let positive = r.wait_samples.iter().filter(|&&w| w > 0.0).count();
        // With skew, all but the slowest rank wait at nearly every sync.
        assert!(positive as f64 > 0.5 * r.wait_samples.len() as f64);
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let r = build_run(1, 3);
        assert!(!r
            .timeline
            .phases
            .iter()
            .any(|p| p.kind == PhaseKind::Transfer));
        assert!(r.wait_samples.iter().all(|&w| w == 0.0));
    }

    #[test]
    fn more_gpus_faster_decode() {
        let r2 = build_run(2, 4);
        let r4 = build_run(4, 4);
        let d2 = r2.timeline.makespan() - r2.prefill_end;
        let d4 = r4.timeline.makespan() - r4.prefill_end;
        assert!(d4 < d2, "decode g=4 {d4} vs g=2 {d2}");
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let a = build_run(2, 9);
        let b = build_run(2, 9);
        assert_eq!(a.timeline.makespan(), b.timeline.makespan());
        assert_eq!(a.wait_samples, b.wait_samples);
    }

    #[test]
    fn ranks_synchronized_after_final_collective() {
        let r = build_run(4, 5);
        let clocks: Vec<f64> = (0..4).map(|g| r.timeline.clock(g)).collect();
        for c in &clocks {
            assert!((c - clocks[0]).abs() < 1e-12);
        }
    }
}
