//! Hybrid-parallelism lowerer: composes two base strategies over a 2-D
//! rank mesh (inner strategy within contiguous groups of `inner_degree`
//! ranks, outer strategy across the groups).
//!
//! Three canonical combinations (see `config::Parallelism::hybrid`):
//!
//! * **TP×PP** — pipeline stages across groups, Megatron-style tensor
//!   parallelism within each stage. Per-layer ring AllReduces stay
//!   group-local; stage boundaries lower to shard-wise P2P edges (rank *i*
//!   of stage *s* feeds rank *i* of stage *s+1*); the last stage collates
//!   its vocab-parallel logits with a group-local AllGather. Decode steps
//!   serialize across the whole mesh (the token sampled on the last stage
//!   feeds the first stage's embedding).
//! * **TP×DP** — independent replicas across groups, TP within each; each
//!   replica decodes its batch shard, then replicas rendezvous once and
//!   exchange final logits (terminal AllGather, ring across groups).
//! * **PP×DP** — independent replicas across groups, a GPipe-style
//!   pipeline within each; terminal replica collation as above.
//!
//! The lowerer reuses the pure lowerers' building blocks — the α–β
//! collective cost models (`simulator::collective`), the roofline perf
//! model, and `pipeline::stage_layers` — and mirrors their op sequences
//! group-locally into the shared Plan IR. The engine, profiler, feature
//! pipeline, and PIE-P regressor consume hybrid plans unchanged.

use std::ops::Range;

use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs, Strategy};
use crate::models::ModelSpec;
use crate::plan::affine::{BatchArg, CollKind, CommBase, CommScale, CommTerm, ComputeRule, OpRule, PayloadRule};
use crate::plan::{Plan, PlanBuilder, PlanSink, WaitRecord};
use crate::simulator::collective;
use crate::simulator::perf::PerfModel;
use crate::simulator::timeline::ModuleKind;

use super::pipeline::{microbatches, stage_layers};
use super::LowerMeta;

/// Lowering context shared by the mesh emitters.
struct Mesh<'a> {
    spec: &'a ModelSpec,
    perf: PerfModel,
    topo: crate::cluster::Topology,
}

impl Mesh<'_> {
    /// Group-local ring AllReduce rendezvous (jittered launch desync — the
    /// tensor planner's synchronization point); hierarchical when the
    /// group spans nodes. Returns bytes moved.
    fn allreduce<S: PlanSink>(
        &self,
        b: &mut S,
        ranks: Range<usize>,
        payload: f64,
        pr: PayloadRule,
        layer: u16,
        step: u32,
    ) -> f64 {
        let n = ranks.len();
        if n <= 1 {
            return 0.0;
        }
        let t = collective::allreduce_hier(&self.topo, ranks.start, n, payload);
        let (xfer, wire) = (t.cost.transfer_s, t.wire_w);
        b.rule(OpRule::Collective {
            coll: CollKind::AllReduceHier { first: ranks.start as u32, n: n as u32 },
            payload: pr,
        });
        b.collective_tiered(ranks, ModuleKind::AllReduce, layer, step, xfer, wire, true, WaitRecord::All);
        t.cost.bytes_moved
    }

    /// Group-local barrier + ring AllGather (the logits / replica collation
    /// point of the tensor and data planners). Returns bytes moved.
    fn allgather<S: PlanSink>(
        &self,
        b: &mut S,
        ranks: Range<usize>,
        payload_per_rank: f64,
        pr: PayloadRule,
        step: u32,
    ) -> f64 {
        let n = ranks.len();
        if n <= 1 {
            return 0.0;
        }
        let t = collective::allgather_ring(&self.topo, ranks.start, n, n, payload_per_rank);
        b.rule(OpRule::Collective {
            coll: CollKind::AllGatherRing { first: ranks.start as u32, n: n as u32, ring: n as u32 },
            payload: pr,
        });
        b.collective_tiered(ranks, ModuleKind::AllGather, 0, step, t.cost.transfer_s, t.wire_w, false, WaitRecord::All);
        t.cost.bytes_moved
    }

    /// Terminal cross-replica collation: rendezvous over all ranks, then an
    /// AllGather whose ring spans the `groups` replica groups — the
    /// inter-node tier when those groups live on different nodes.
    fn terminal_collation<S: PlanSink>(
        &self,
        b: &mut S,
        num_ranks: usize,
        groups: usize,
        payload_per_group: f64,
        pr: PayloadRule,
        step: u32,
    ) -> f64 {
        let t = collective::allgather_ring(&self.topo, 0, num_ranks, groups, payload_per_group);
        let (xfer, wire) = (t.cost.transfer_s, t.wire_w);
        b.rule(OpRule::Collective {
            coll: CollKind::AllGatherRing { first: 0, n: num_ranks as u32, ring: groups as u32 },
            payload: pr,
        });
        b.collective_tiered(0..num_ranks, ModuleKind::AllGather, 0, step, xfer, wire, false, WaitRecord::All);
        t.cost.bytes_moved
    }
}

/// Reference lowering into the interpreted `Plan` representation.
pub fn lower(spec: &ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &RunConfig) -> Plan {
    let mut b = PlanBuilder::new(cfg.gpus);
    let m = lower_into(spec, hw, knobs, cfg, &mut b);
    b.finish(m.sim_steps, m.comm_bytes_per_step, m.draws_sync_jitter)
}

/// Lowering pass, generic over the sink (reference build, SoA compile, or
/// shape rebind — see `plan::PlanSink`).
pub fn lower_into<S: PlanSink>(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    b: &mut S,
) -> LowerMeta {
    let g = cfg.gpus;
    let (inner, outer, di) = match cfg.parallelism {
        Parallelism::Hybrid {
            inner,
            outer,
            inner_degree,
        } => (inner, outer, inner_degree),
        other => panic!("hybrid lowerer invoked for {other:?}"),
    };
    assert!(
        di >= 2 && g % di == 0 && g / di >= 2,
        "invalid hybrid mesh: inner degree {di} over {g} GPUs"
    );
    let do_ = g / di;

    let mesh = Mesh {
        spec,
        perf: PerfModel::new(hw),
        topo: hw.topo(),
    };
    let sim_steps = knobs.sim_decode_steps.min(cfg.seq_out).max(1);

    let comm_bytes_per_step = match (inner, outer) {
        (Strategy::Tensor, Strategy::Pipeline) => tp_pp(&mesh, cfg, b, di, do_, sim_steps),
        (Strategy::Tensor, Strategy::Data) => tp_dp(&mesh, cfg, b, di, do_, sim_steps),
        (Strategy::Pipeline, Strategy::Data) => pp_dp(&mesh, cfg, b, di, do_, sim_steps),
        other => panic!("unsupported hybrid combination {other:?}"),
    };

    // Every hybrid run draws the launch-desync scale once (the Mesh of the
    // legacy builder sampled it at construction, PP×DP included).
    LowerMeta {
        sim_steps,
        comm_bytes_per_step,
        draws_sync_jitter: true,
    }
}

/// TP within each of `do_` pipeline stages: one pipelined pass (prefill or
/// a decode step) over all microbatches. Returns total collective/P2P bytes
/// moved during the pass.
#[allow(clippy::too_many_arguments)]
fn tp_pp_pass<S: PlanSink>(
    mesh: &Mesh,
    cfg: &RunConfig,
    b: &mut S,
    di: usize,
    do_: usize,
    ranges: &[Range<usize>],
    micro: usize,
    num_micro: usize,
    step: u32,
    context: usize,
    prefill: bool,
) -> f64 {
    let spec = mesh.spec;
    let mut bytes = 0.0;
    let mut boundary: Vec<u32> = vec![u32::MAX; num_micro];
    let p2p_payload = if prefill {
        spec.p2p_payload_bytes(micro, cfg.seq_in)
    } else {
        spec.p2p_payload_bytes(micro, 1)
    };
    let ar_payload = if prefill {
        (micro * cfg.seq_in * spec.hidden * spec.dtype_bytes) as f64
    } else {
        spec.allreduce_payload_bytes(micro, 1)
    };
    let mb_arg = BatchArg::Micro { stages: do_ as u32 };
    let pr_ar = PayloadRule::Acts { batch: mb_arg, times_seq_in: prefill };
    // The caller keeps only the first decode pass's bytes for
    // `comm_bytes_per_step`; emit comm terms on exactly that pass.
    let record = !prefill && step == 1;
    for (stage, range) in ranges.iter().enumerate() {
        let ranks = stage * di..(stage + 1) * di;
        let ar_coll = CollKind::AllReduceHier { first: ranks.start as u32, n: di as u32 };
        for mb in 0..num_micro {
            if stage > 0 {
                // Hop-local recv: every TP rank of the stage busy-waits for
                // its shard of the boundary activations (the paper's
                // timestamped producer→consumer interval).
                b.recv(ranks.clone(), range.start as u16, step, boundary[mb]);
            }
            if stage == 0 {
                let t = if prefill {
                    mesh.perf.embed_decode(spec, micro * cfg.seq_in)
                } else {
                    mesh.perf.embed_decode(spec, micro)
                };
                b.rule(OpRule::Compute(ComputeRule::Embed { batch: mb_arg, times_seq_in: prefill }));
                b.compute(ranks.clone(), t, ModuleKind::Embedding, 0, step);
            }
            for layer in range.clone() {
                let (tn, ta, tm) = if prefill {
                    (
                        mesh.perf.norm_prefill(spec, micro, cfg.seq_in),
                        mesh.perf.attn_prefill(spec, micro, cfg.seq_in, di),
                        mesh.perf.mlp_prefill(spec, micro, cfg.seq_in, di),
                    )
                } else {
                    (
                        mesh.perf.norm_decode(spec, micro),
                        mesh.perf.attn_decode(spec, micro, context, di),
                        mesh.perf.mlp_decode(spec, micro, di),
                    )
                };
                let (rn, ra, rm) = if prefill {
                    (
                        ComputeRule::NormPrefill { batch: mb_arg },
                        ComputeRule::AttnPrefill { batch: mb_arg, g: di as u32 },
                        ComputeRule::MlpPrefill { batch: mb_arg, g: di as u32 },
                    )
                } else {
                    (
                        ComputeRule::NormDecode { batch: mb_arg },
                        ComputeRule::AttnDecode { batch: mb_arg, si: step - 1, g: di as u32 },
                        ComputeRule::MlpDecode { batch: mb_arg, g: di as u32 },
                    )
                };
                b.rule(OpRule::Compute(rn));
                b.compute(ranks.clone(), tn, ModuleKind::Norm, layer as u16, step);
                b.rule(OpRule::Compute(ra));
                b.compute(ranks.clone(), ta, ModuleKind::SelfAttention, layer as u16, step);
                bytes += mesh.allreduce(b, ranks.clone(), ar_payload, pr_ar, layer as u16, step);
                if record {
                    // Two *separate* accumulations in this loop — not a
                    // summed pair — so two separate terms keep fold order.
                    b.comm_term(CommTerm {
                        base: CommBase::Coll { coll: ar_coll, payload: pr_ar },
                        scale: CommScale::One,
                    });
                }
                b.rule(OpRule::Compute(rn));
                b.compute(ranks.clone(), tn, ModuleKind::Norm, layer as u16, step);
                b.rule(OpRule::Compute(rm));
                b.compute(ranks.clone(), tm, ModuleKind::Mlp, layer as u16, step);
                bytes += mesh.allreduce(b, ranks.clone(), ar_payload, pr_ar, layer as u16, step);
                if record {
                    b.comm_term(CommTerm {
                        base: CommBase::Coll { coll: ar_coll, payload: pr_ar },
                        scale: CommScale::One,
                    });
                }
            }
            if stage + 1 == do_ {
                // Vocab-parallel logits on the last stage's TP group, then
                // the group-local shard AllGather (decode only).
                b.rule(OpRule::Compute(ComputeRule::LogitsDecode { batch: mb_arg, g: di as u32 }));
                b.compute(ranks.clone(), mesh.perf.logits_decode(spec, micro, di), ModuleKind::LogitsHead, 0, step);
                if !prefill {
                    let shard_payload = spec.allgather_payload_bytes(micro) / di as f64;
                    let pr_ag = PayloadRule::AgShard { batch: mb_arg, div: di as u32 };
                    bytes += mesh.allgather(b, ranks.clone(), shard_payload, pr_ag, step);
                    if record {
                        b.comm_term(CommTerm {
                            base: CommBase::Coll {
                                coll: CollKind::AllGatherRing {
                                    first: ranks.start as u32,
                                    n: di as u32,
                                    ring: di as u32,
                                },
                                payload: pr_ag,
                            },
                            scale: CommScale::One,
                        });
                    }
                }
            } else {
                // Shard-wise boundary edge: rank i of this stage feeds rank
                // i of the next stage (1/di of the activation tensor each);
                // it pays the inter-node tier when the stage boundary
                // crosses a node boundary for any shard pair.
                let t = collective::p2p_range(&mesh.topo, ranks.start, di, ranks.start + di, p2p_payload / di as f64);
                let p2p_coll = CollKind::P2pRange {
                    src: ranks.start as u32,
                    count: di as u32,
                    dst: (ranks.start + di) as u32,
                };
                let pr_p2p = PayloadRule::ActsShard { batch: mb_arg, times_seq_in: prefill, div: di as u32 };
                b.rule(OpRule::Send { coll: p2p_coll, payload: pr_p2p });
                boundary[mb] = b.send_tiered(ranks.clone(), range.end as u16, step, t.cost.transfer_s, t.wire_w);
                bytes += t.cost.bytes_moved * di as f64;
                if record {
                    b.comm_term(CommTerm {
                        base: CommBase::Coll { coll: p2p_coll, payload: pr_p2p },
                        scale: CommScale::Times(di as u32),
                    });
                }
            }
        }
    }
    bytes
}

fn tp_pp<S: PlanSink>(
    mesh: &Mesh,
    cfg: &RunConfig,
    b: &mut S,
    di: usize,
    do_: usize,
    sim_steps: usize,
) -> f64 {
    let spec = mesh.spec;
    let ranges = stage_layers(spec.layers, do_);
    let (micro, num_micro) = microbatches(cfg.batch, do_);
    let g = di * do_;

    tp_pp_pass(mesh, cfg, b, di, do_, &ranges, micro, num_micro, 0, cfg.seq_in, true);

    let mut comm = 0.0;
    for si in 0..sim_steps {
        let step = (si + 1) as u32;
        let frac = (si as f64 + 0.5) / sim_steps as f64;
        let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;
        let bytes = tp_pp_pass(
            mesh, cfg, b, di, do_, &ranges, micro, num_micro, step, context, false,
        );
        if si == 0 {
            comm = bytes;
        }
        // Autoregressive serialization: the token sampled on the last stage
        // gates the next step's stage-0 embedding on every rank.
        b.rule(OpRule::Barrier);
        b.collective(0..g, ModuleKind::P2PTransfer, 0, step, 0.0, false, WaitRecord::None);
    }
    comm
}

/// TP within each of `do_` independent replicas; terminal collation across.
fn tp_dp<S: PlanSink>(
    mesh: &Mesh,
    cfg: &RunConfig,
    b: &mut S,
    di: usize,
    do_: usize,
    sim_steps: usize,
) -> f64 {
    let spec = mesh.spec;
    let shard = (cfg.batch + do_ - 1) / do_;
    let mut comm = 0.0;

    let sa = BatchArg::CeilDiv(do_ as u32);
    let pr_prefill = PayloadRule::Acts { batch: sa, times_seq_in: true };
    let pr_decode = PayloadRule::Acts { batch: sa, times_seq_in: false };
    let pr_ag = PayloadRule::AgShard { batch: sa, div: di as u32 };
    for rep in 0..do_ {
        let ranks = rep * di..(rep + 1) * di;
        let ar_coll = CollKind::AllReduceHier { first: ranks.start as u32, n: di as u32 };
        let ag_coll = CollKind::AllGatherRing { first: ranks.start as u32, n: di as u32, ring: di as u32 };
        // ---- Prefill within this replica group (tensor-planner semantics).
        let prefill_payload = (shard * cfg.seq_in * spec.hidden * spec.dtype_bytes) as f64;
        b.rule(OpRule::Compute(ComputeRule::Embed { batch: sa, times_seq_in: true }));
        b.compute(ranks.clone(), mesh.perf.embed_decode(spec, shard * cfg.seq_in), ModuleKind::Embedding, 0, 0);
        for layer in 0..spec.layers as u16 {
            b.rule(OpRule::Compute(ComputeRule::NormPrefill { batch: sa }));
            b.compute(ranks.clone(), mesh.perf.norm_prefill(spec, shard, cfg.seq_in), ModuleKind::Norm, layer, 0);
            let ta = mesh.perf.attn_prefill(spec, shard, cfg.seq_in, di);
            b.rule(OpRule::Compute(ComputeRule::AttnPrefill { batch: sa, g: di as u32 }));
            b.compute(ranks.clone(), ta, ModuleKind::SelfAttention, layer, 0);
            mesh.allreduce(b, ranks.clone(), prefill_payload, pr_prefill, layer, 0);
            b.rule(OpRule::Compute(ComputeRule::NormPrefill { batch: sa }));
            b.compute(ranks.clone(), mesh.perf.norm_prefill(spec, shard, cfg.seq_in), ModuleKind::Norm, layer, 0);
            b.rule(OpRule::Compute(ComputeRule::MlpPrefill { batch: sa, g: di as u32 }));
            b.compute(ranks.clone(), mesh.perf.mlp_prefill(spec, shard, cfg.seq_in, di), ModuleKind::Mlp, layer, 0);
            mesh.allreduce(b, ranks.clone(), prefill_payload, pr_prefill, layer, 0);
        }

        // ---- Decode steps within this replica group.
        let decode_payload = spec.allreduce_payload_bytes(shard, 1);
        for si in 0..sim_steps {
            let step = (si + 1) as u32;
            let frac = (si as f64 + 0.5) / sim_steps as f64;
            let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;
            b.rule(OpRule::Compute(ComputeRule::Embed { batch: sa, times_seq_in: false }));
            b.compute(ranks.clone(), mesh.perf.embed_decode(spec, shard), ModuleKind::Embedding, 0, step);
            for layer in 0..spec.layers as u16 {
                b.rule(OpRule::Compute(ComputeRule::NormDecode { batch: sa }));
                b.compute(ranks.clone(), mesh.perf.norm_decode(spec, shard), ModuleKind::Norm, layer, step);
                let ta = mesh.perf.attn_decode(spec, shard, context, di);
                b.rule(OpRule::Compute(ComputeRule::AttnDecode { batch: sa, si: si as u32, g: di as u32 }));
                b.compute(ranks.clone(), ta, ModuleKind::SelfAttention, layer, step);
                let b1 = mesh.allreduce(b, ranks.clone(), decode_payload, pr_decode, layer, step);
                b.rule(OpRule::Compute(ComputeRule::NormDecode { batch: sa }));
                b.compute(ranks.clone(), mesh.perf.norm_decode(spec, shard), ModuleKind::Norm, layer, step);
                b.rule(OpRule::Compute(ComputeRule::MlpDecode { batch: sa, g: di as u32 }));
                b.compute(ranks.clone(), mesh.perf.mlp_decode(spec, shard, di), ModuleKind::Mlp, layer, step);
                let b2 = mesh.allreduce(b, ranks.clone(), decode_payload, pr_decode, layer, step);
                if si == 0 {
                    b.comm_term(CommTerm {
                        base: CommBase::CollPair { coll: ar_coll, payload: pr_decode },
                        scale: CommScale::One,
                    });
                    comm += b1 + b2;
                }
            }
            // Vocab-parallel logits + group-local shard AllGather.
            b.rule(OpRule::Compute(ComputeRule::LogitsDecode { batch: sa, g: di as u32 }));
            b.compute(ranks.clone(), mesh.perf.logits_decode(spec, shard, di), ModuleKind::LogitsHead, 0, step);
            let shard_payload = spec.allgather_payload_bytes(shard) / di as f64;
            let bytes = mesh.allgather(b, ranks.clone(), shard_payload, pr_ag, step);
            if si == 0 {
                b.comm_term(CommTerm {
                    base: CommBase::Coll { coll: ag_coll, payload: pr_ag },
                    scale: CommScale::One,
                });
                comm += bytes;
            }
        }
    }

    let pr_term = PayloadRule::Ag { batch: sa };
    let terminal = mesh.terminal_collation(
        b,
        di * do_,
        do_,
        spec.allgather_payload_bytes(shard),
        pr_term,
        sim_steps as u32,
    );
    b.comm_term(CommTerm {
        base: CommBase::Coll {
            coll: CollKind::AllGatherRing { first: 0, n: (di * do_) as u32, ring: do_ as u32 },
            payload: pr_term,
        },
        scale: CommScale::OverSteps,
    });
    comm + terminal / sim_steps as f64
}

/// One pipelined pass within a replica group occupying ranks
/// `base..base+stages`. Returns P2P bytes moved during the pass.
#[allow(clippy::too_many_arguments)]
fn pp_group_pass<S: PlanSink>(
    mesh: &Mesh,
    cfg: &RunConfig,
    b: &mut S,
    base: usize,
    stages: usize,
    ranges: &[Range<usize>],
    micro: usize,
    num_micro: usize,
    mb_arg: BatchArg,
    step: u32,
    context: usize,
    prefill: bool,
) -> f64 {
    let spec = mesh.spec;
    let mut boundary: Vec<u32> = vec![u32::MAX; num_micro];
    let payload = if prefill {
        spec.p2p_payload_bytes(micro, cfg.seq_in)
    } else {
        spec.p2p_payload_bytes(micro, 1)
    };
    let pr_boundary = PayloadRule::Acts { batch: mb_arg, times_seq_in: prefill };
    for (stage, range) in ranges.iter().enumerate() {
        let rank = base + stage;
        for mb in 0..num_micro {
            if stage > 0 {
                b.recv(rank..rank + 1, range.start as u16, step, boundary[mb]);
            }
            if stage == 0 {
                let t = if prefill {
                    mesh.perf.embed_decode(spec, micro * cfg.seq_in)
                } else {
                    mesh.perf.embed_decode(spec, micro)
                };
                b.rule(OpRule::Compute(ComputeRule::Embed { batch: mb_arg, times_seq_in: prefill }));
                b.compute(rank..rank + 1, t, ModuleKind::Embedding, 0, step);
            }
            for layer in range.clone() {
                let (tn, ta, tm) = if prefill {
                    (
                        mesh.perf.norm_prefill(spec, micro, cfg.seq_in),
                        mesh.perf.attn_prefill(spec, micro, cfg.seq_in, 1),
                        mesh.perf.mlp_prefill(spec, micro, cfg.seq_in, 1),
                    )
                } else {
                    (
                        mesh.perf.norm_decode(spec, micro),
                        mesh.perf.attn_decode(spec, micro, context, 1),
                        mesh.perf.mlp_decode(spec, micro, 1),
                    )
                };
                let (rn, ra, rm) = if prefill {
                    (
                        ComputeRule::NormPrefill { batch: mb_arg },
                        ComputeRule::AttnPrefill { batch: mb_arg, g: 1 },
                        ComputeRule::MlpPrefill { batch: mb_arg, g: 1 },
                    )
                } else {
                    (
                        ComputeRule::NormDecode { batch: mb_arg },
                        ComputeRule::AttnDecode { batch: mb_arg, si: step - 1, g: 1 },
                        ComputeRule::MlpDecode { batch: mb_arg, g: 1 },
                    )
                };
                for (t, rule, module) in [
                    (tn, rn, ModuleKind::Norm),
                    (ta, ra, ModuleKind::SelfAttention),
                    (tn, rn, ModuleKind::Norm),
                    (tm, rm, ModuleKind::Mlp),
                ] {
                    b.rule(OpRule::Compute(rule));
                    b.compute(rank..rank + 1, t, module, layer as u16, step);
                }
            }
            if stage + 1 == stages {
                b.rule(OpRule::Compute(ComputeRule::LogitsDecode { batch: mb_arg, g: 1 }));
                b.compute(rank..rank + 1, mesh.perf.logits_decode(spec, micro, 1), ModuleKind::LogitsHead, 0, step);
            } else {
                let t = collective::p2p_range(&mesh.topo, rank, 1, rank + 1, payload);
                b.rule(OpRule::Send {
                    coll: CollKind::P2pRange { src: rank as u32, count: 1, dst: rank as u32 + 1 },
                    payload: pr_boundary,
                });
                boundary[mb] = b.send_tiered(rank..rank + 1, range.end as u16, step, t.cost.transfer_s, t.wire_w);
            }
        }
    }
    payload * (stages - 1) as f64 * num_micro as f64
}

/// A GPipe-style pipeline within each of `do_` independent replicas.
fn pp_dp<S: PlanSink>(
    mesh: &Mesh,
    cfg: &RunConfig,
    b: &mut S,
    di: usize,
    do_: usize,
    sim_steps: usize,
) -> f64 {
    let spec = mesh.spec;
    let shard = (cfg.batch + do_ - 1) / do_;
    let ranges = stage_layers(spec.layers, di);
    let (micro, num_micro) = microbatches(shard, di);
    let mut decode_bytes_group = 0.0;
    let mb_arg = BatchArg::MicroOfCeilDiv { d: do_ as u32, stages: di as u32 };

    for rep in 0..do_ {
        let base = rep * di;
        pp_group_pass(mesh, cfg, b, base, di, &ranges, micro, num_micro, mb_arg, 0, cfg.seq_in, true);

        for si in 0..sim_steps {
            let step = (si + 1) as u32;
            let frac = (si as f64 + 0.5) / sim_steps as f64;
            let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;
            let bytes = pp_group_pass(
                mesh, cfg, b, base, di, &ranges, micro, num_micro, mb_arg, step, context, false,
            );
            if si == 0 && rep == 0 {
                b.comm_term(CommTerm {
                    base: CommBase::Boundary { stages: di as u32, batch: BatchArg::CeilDiv(do_ as u32) },
                    scale: CommScale::Times(do_ as u32),
                });
                decode_bytes_group = bytes;
            }
            // Group-local autoregressive step barrier.
            b.rule(OpRule::Barrier);
            b.collective(base..base + di, ModuleKind::P2PTransfer, 0, step, 0.0, false, WaitRecord::None);
        }
    }

    let pr_term = PayloadRule::Ag { batch: BatchArg::CeilDiv(do_ as u32) };
    let terminal = mesh.terminal_collation(
        b,
        di * do_,
        do_,
        spec.allgather_payload_bytes(shard),
        pr_term,
        sim_steps as u32,
    );
    b.comm_term(CommTerm {
        base: CommBase::Coll {
            coll: CollKind::AllGatherRing { first: 0, n: (di * do_) as u32, ring: do_ as u32 },
            payload: pr_term,
        },
        scale: CommScale::OverSteps,
    });
    decode_bytes_group * do_ as f64 + terminal / sim_steps as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;
    use crate::parallelism::BuiltRun;
    use crate::simulator::power::PowerModel;
    use crate::simulator::timeline::PhaseKind;
    use crate::util::rng::Rng;

    fn build_run(inner: Strategy, outer: Strategy, di: usize, gpus: usize, seed: u64) -> BuiltRun {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let par = Parallelism::hybrid(inner, outer, di).unwrap();
        let cfg = RunConfig::new("Vicuna-7B", par, gpus, 8).with_seed(seed);
        let power = PowerModel::new(&hw);
        let mut rng = Rng::new(seed);
        crate::parallelism::build(&spec, &hw, &knobs, &cfg, &power, &mut rng)
    }

    fn count(r: &BuiltRun, module: ModuleKind, kind: PhaseKind) -> usize {
        r.timeline
            .phases
            .iter()
            .filter(|p| p.module == module && p.kind == kind)
            .count()
    }

    #[test]
    fn tp_pp_has_group_local_allreduce_and_boundary_p2p() {
        let r = build_run(Strategy::Tensor, Strategy::Pipeline, 2, 4, 1);
        // 2 AllReduces/layer × 32 layers × 2 microbatches × (prefill + 4
        // decode passes) × 2 TP ranks per stage.
        assert_eq!(count(&r, ModuleKind::AllReduce, PhaseKind::Transfer), 2 * 32 * 2 * 5 * 2);
        // 1 stage boundary × 2 shard-wise sends × 2 microbatches × 5 passes.
        assert_eq!(count(&r, ModuleKind::P2PTransfer, PhaseKind::Transfer), 2 * 2 * 5);
        // Logits AllGather on the last stage's TP group, decode steps only.
        assert_eq!(count(&r, ModuleKind::AllGather, PhaseKind::Transfer), 2 * 2 * 4);
    }

    #[test]
    fn tp_dp_has_allreduce_and_allgather_but_no_p2p() {
        let r = build_run(Strategy::Tensor, Strategy::Data, 2, 4, 2);
        assert!(count(&r, ModuleKind::AllReduce, PhaseKind::Transfer) > 0);
        assert!(count(&r, ModuleKind::AllGather, PhaseKind::Transfer) > 0);
        assert_eq!(count(&r, ModuleKind::P2PTransfer, PhaseKind::Transfer), 0);
    }

    #[test]
    fn pp_dp_has_p2p_and_allgather_but_no_allreduce() {
        let r = build_run(Strategy::Pipeline, Strategy::Data, 2, 4, 3);
        assert!(count(&r, ModuleKind::P2PTransfer, PhaseKind::Transfer) > 0);
        // Terminal replica collation only: one transfer phase per rank.
        assert_eq!(count(&r, ModuleKind::AllGather, PhaseKind::Transfer), 4);
        assert_eq!(count(&r, ModuleKind::AllReduce, PhaseKind::Transfer), 0);
    }

    #[test]
    fn waits_are_nonnegative_and_some_positive() {
        for (inner, outer) in Parallelism::HYBRID_COMBOS {
            let r = build_run(inner, outer, 2, 4, 4);
            assert!(r.wait_samples.iter().all(|&w| w >= 0.0));
            assert!(
                r.wait_samples.iter().any(|&w| w > 0.0),
                "{inner:?}x{outer:?} records waiting"
            );
        }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        for (inner, outer) in Parallelism::HYBRID_COMBOS {
            let a = build_run(inner, outer, 2, 4, 9);
            let b = build_run(inner, outer, 2, 4, 9);
            assert_eq!(a.timeline.makespan(), b.timeline.makespan());
            assert_eq!(a.wait_samples, b.wait_samples);
        }
    }

    #[test]
    fn replica_hybrids_end_synchronized() {
        // The terminal collation aligns all ranks.
        for (inner, outer) in [(Strategy::Tensor, Strategy::Data), (Strategy::Pipeline, Strategy::Data)] {
            let r = build_run(inner, outer, 2, 4, 5);
            let clocks: Vec<f64> = (0..4).map(|g| r.timeline.clock(g)).collect();
            for c in &clocks {
                assert!((c - clocks[0]).abs() < 1e-12, "{inner:?}x{outer:?}");
            }
        }
    }

    #[test]
    fn comm_bytes_and_prefill_tracked() {
        for (inner, outer) in Parallelism::HYBRID_COMBOS {
            let r = build_run(inner, outer, 2, 4, 6);
            assert!(r.comm_bytes_per_step > 0.0, "{inner:?}x{outer:?}");
            assert!(r.prefill_end > 0.0 && r.prefill_end < r.timeline.makespan());
        }
    }

    #[test]
    #[should_panic(expected = "invalid hybrid mesh")]
    fn degenerate_mesh_rejected() {
        // 2 GPUs with inner degree 2 leaves no outer axis.
        build_run(Strategy::Tensor, Strategy::Pipeline, 2, 2, 1);
    }
}
