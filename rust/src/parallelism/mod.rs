//! Parallel-inference planners: lowering from `RunConfig` to the shared
//! Plan IR (DESIGN.md §3, §9).
//!
//! Each strategy module contains a *lowerer* that walks the model's
//! modules under its parallelism strategy and emits per-rank compute ops
//! and inter-rank communication edges into a `plan::Plan` (Section 3 of
//! the paper):
//!
//! * tensor: per-layer ring AllReduce rendezvous after the attention
//!   out-projection and after the MLP (Megatron-style), logits AllGather
//!   at the head;
//! * pipeline: stage-partitioned layers, point-to-point activation edges
//!   at stage boundaries, microbatch pipelining, autoregressive step
//!   barriers;
//! * data: independent replicas, terminal output AllGather;
//! * hybrid: pairwise compositions of the above over a 2-D rank mesh
//!   (TP×PP, TP×DP, PP×DP), reusing the same communication points
//!   group-locally.
//!
//! Lowering is deterministic (no seed enters a plan); the discrete-event
//! engine (`simulator::engine`) injects rank skew and launch-desync jitter
//! at execution time and resolves the collectives as straggler-determined
//! rendezvous events.

pub mod data;
pub mod hybrid;
pub mod pipeline;
pub mod tensor;

use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::plan::Plan;
use crate::simulator::engine;
use crate::simulator::power::PowerModel;
use crate::simulator::skew::SkewModel;
use crate::util::rng::Rng;

pub use crate::simulator::engine::BuiltRun;

/// Lower a run configuration into the shared Plan IR.
pub fn lower(spec: &ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &RunConfig) -> Plan {
    match cfg.parallelism {
        Parallelism::Tensor => tensor::lower(spec, hw, knobs, cfg),
        Parallelism::Pipeline => pipeline::lower(spec, hw, knobs, cfg),
        Parallelism::Data => data::lower(spec, hw, knobs, cfg),
        Parallelism::Hybrid { .. } => hybrid::lower(spec, hw, knobs, cfg),
    }
}

/// Execute a lowered plan under one run's stochastic conditions: sample
/// the run-level skew state and (for strategies with jittered collectives)
/// the launch-desync scale, then drive the event engine. Heterogeneous
/// fleets (`cluster::GpuSpec` per rank) rescale the sampled rank bias by
/// each rank's compute throughput — deterministically, after all draws, so
/// the seed stream matches the homogeneous path exactly.
pub fn execute_plan(
    plan: &Plan,
    spec: &ModelSpec,
    knobs: &SimKnobs,
    power: &PowerModel,
    rng: &mut Rng,
    threads: usize,
) -> BuiltRun {
    let mut skew = SkewModel::with_complexity(knobs, plan.num_ranks, spec.complexity_factor(), rng);
    if let Some(scales) = power.fleet_compute_scales(plan.num_ranks) {
        skew.apply_fleet(&scales);
    }
    let sync_jitter = if plan.draws_sync_jitter {
        knobs.sync_jitter_s
            * spec.complexity_factor()
            * rng.lognormal_mean_cv(1.0, knobs.sync_jitter_cv)
    } else {
        0.0
    };
    engine::execute(plan, power, &skew, sync_jitter, rng, threads)
}

/// Lower + execute in one call (single-run paths and planner tests; the
/// profiling campaigns cache the lowering via `plan::PlanCache`).
pub fn build(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    power: &PowerModel,
    rng: &mut Rng,
) -> BuiltRun {
    let plan = lower(spec, hw, knobs, cfg);
    execute_plan(&plan, spec, knobs, power, rng, knobs.engine_threads)
}
