//! Parallel-inference execution planners.
//!
//! Each planner turns a `RunConfig` into a power-annotated `Timeline` by
//! walking the model's modules under the given parallelism strategy,
//! sampling per-rank skew, and synchronizing ranks at the strategy's
//! communication points (Section 3 of the paper):
//!
//! * tensor: per-layer ring AllReduce after the attention out-projection
//!   and after the MLP (Megatron-style), logits AllGather at the head;
//! * pipeline: stage-partitioned layers, point-to-point activation
//!   transfers at stage boundaries, microbatch pipelining;
//! * data: independent replicas, terminal output AllGather;
//! * hybrid: pairwise compositions of the above over a 2-D rank mesh
//!   (TP×PP, TP×DP, PP×DP), reusing the same communication points.

pub mod data;
pub mod hybrid;
pub mod pipeline;
pub mod tensor;

use crate::simulator::timeline::Timeline;

/// Output of a planner: the timeline plus profiler-visible side channels.
#[derive(Debug, Clone)]
pub struct BuiltRun {
    pub timeline: Timeline,
    /// Per-sync per-rank wait durations (s) — the raw material of PIE-P's
    /// synchronization sampling.
    pub wait_samples: Vec<f64>,
    /// Time at which prefill finished (phases with step 0 are prefill).
    pub prefill_end: f64,
    /// Decode steps actually simulated (before extrapolation).
    pub sim_steps: usize,
    /// Total collective/P2P payload bytes moved per simulated decode step.
    pub comm_bytes_per_step: f64,
}
