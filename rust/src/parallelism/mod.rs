//! Parallel-inference planners: lowering from `RunConfig` to the shared
//! Plan IR (DESIGN.md §3, §9).
//!
//! Each strategy module contains a *lowerer* that walks the model's
//! modules under its parallelism strategy and emits per-rank compute ops
//! and inter-rank communication edges into a `plan::Plan` (Section 3 of
//! the paper):
//!
//! * tensor: per-layer ring AllReduce rendezvous after the attention
//!   out-projection and after the MLP (Megatron-style), logits AllGather
//!   at the head;
//! * pipeline: stage-partitioned layers, point-to-point activation edges
//!   at stage boundaries, microbatch pipelining, autoregressive step
//!   barriers;
//! * data: independent replicas, terminal output AllGather;
//! * hybrid: pairwise compositions of the above over a 2-D rank mesh
//!   (TP×PP, TP×DP, PP×DP), reusing the same communication points
//!   group-locally;
//! * expert: MoE expert parallelism — attention replicated, expert MLPs
//!   sharded across the mesh, per-layer all-to-all dispatch/combine
//!   collectives, plus a seeded top-k routing-imbalance skew source
//!   (DESIGN.md §16).
//!
//! Lowering is deterministic (no seed enters a plan); the discrete-event
//! engine (`simulator::engine`) injects rank skew and launch-desync jitter
//! at execution time and resolves the collectives as straggler-determined
//! rendezvous events.
//!
//! # Example
//!
//! Lower a configuration into the reference Plan IR and inspect its op
//! census:
//!
//! ```
//! use piep::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
//!
//! let cfg = RunConfig::builder("Vicuna-7B")
//!     .parallelism(Parallelism::expert(4))
//!     .gpus(4)
//!     .batch(8)
//!     .build();
//! let spec = piep::models::by_name("Vicuna-7B").unwrap();
//! let plan = piep::parallelism::lower(&spec, &HwSpec::default(), &SimKnobs::default(), &cfg);
//! let (compute, collective, _send, _recv) = plan.op_census();
//! assert!(compute > 0 && collective > 0);
//! ```

pub mod data;
pub mod expert;
pub mod hybrid;
pub mod pipeline;
pub mod tensor;

use std::sync::Arc;

use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs, Strategy};
use crate::models::ModelSpec;
use crate::plan::exec::{ExecBatch, ExecPlan, PlanStructure, ShapeBinding, StructureBuilder};
use crate::plan::{Plan, PlanSink};
use crate::simulator::engine;
use crate::simulator::power::PowerModel;
use crate::simulator::skew::SkewModel;
use crate::util::rng::Rng;

pub use crate::simulator::engine::BuiltRun;

/// Shape-level metadata every lowering pass produces alongside its op
/// stream (the arguments of its sink's `finish`).
#[derive(Debug, Clone, Copy)]
pub struct LowerMeta {
    /// Decode steps simulated explicitly (before extrapolation).
    pub sim_steps: usize,
    /// Collective/P2P payload bytes moved per simulated decode step.
    pub comm_bytes_per_step: f64,
    /// Whether this strategy draws the per-run launch-desync scale.
    pub draws_sync_jitter: bool,
}

/// Lower a run configuration into the shared Plan IR (the interpreted
/// reference representation — hot paths use `compile`/`rebind`).
pub fn lower(spec: &ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &RunConfig) -> Plan {
    match cfg.parallelism {
        Parallelism::Tensor => tensor::lower(spec, hw, knobs, cfg),
        Parallelism::Pipeline => pipeline::lower(spec, hw, knobs, cfg),
        Parallelism::Data => data::lower(spec, hw, knobs, cfg),
        Parallelism::Hybrid { .. } => hybrid::lower(spec, hw, knobs, cfg),
        Parallelism::Expert { .. } => expert::lower(spec, hw, knobs, cfg),
    }
}

/// Run the strategy's lowering pass into an arbitrary sink (see
/// `plan::PlanSink` for the contract the lowerers uphold).
pub fn lower_into<S: PlanSink>(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    sink: &mut S,
) -> LowerMeta {
    match cfg.parallelism {
        Parallelism::Tensor => tensor::lower_into(spec, hw, knobs, cfg, sink),
        Parallelism::Pipeline => pipeline::lower_into(spec, hw, knobs, cfg, sink),
        Parallelism::Data => data::lower_into(spec, hw, knobs, cfg, sink),
        Parallelism::Hybrid { .. } => hybrid::lower_into(spec, hw, knobs, cfg, sink),
        Parallelism::Expert { .. } => expert::lower_into(spec, hw, knobs, cfg, sink),
    }
}

/// Lower a run configuration straight into a compiled structure-of-arrays
/// `ExecPlan` (the full lowering of a mesh the cache has not seen).
pub fn compile(spec: &ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &RunConfig) -> ExecPlan {
    let mut b = StructureBuilder::new(cfg.gpus);
    let m = lower_into(spec, hw, knobs, cfg, &mut b);
    b.finish(m.sim_steps, m.comm_bytes_per_step, m.draws_sync_jitter)
}

/// Like [`compile`], but also capture the structure's shape-affine scalar
/// program (DESIGN.md §17) from the lowerer's `PlanSink::rule` /
/// `comm_term` annotations. The `ExecPlan` is always the full compile;
/// the program is `Err(n)` — with `n` the number of unannotated ops —
/// when the lowering could not be captured, in which case rebinds for
/// this structure stay on the replay path.
pub fn compile_affine(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
) -> (ExecPlan, Result<crate::plan::affine::AffineProgram, usize>) {
    let mut b = crate::plan::affine::RuleCapture::new(cfg.gpus);
    let m = lower_into(spec, hw, knobs, cfg, &mut b);
    b.finish(m.sim_steps, m.comm_bytes_per_step, m.draws_sync_jitter)
}

/// Rebind a cached mesh structure to a new shape: replay the lowering pass
/// writing only the scalar table (array-fill cost; the structure `Arc` is
/// shared, not copied). The caller guarantees `structure` was compiled for
/// the same `structure_key` as `cfg` — `ShapeBinding` asserts the replay
/// matches.
pub fn rebind(
    structure: &Arc<PlanStructure>,
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
) -> ExecPlan {
    let mut b = ShapeBinding::new(Arc::clone(structure));
    let m = lower_into(spec, hw, knobs, cfg, &mut b);
    b.finish(m.sim_steps, m.comm_bytes_per_step, m.draws_sync_jitter)
}

/// Mesh-topology identity of a configuration: everything that determines
/// the *structure* of its lowered plan (op sequence, rank ranges, tags,
/// edges) as opposed to the per-op scalars. Two configurations with equal
/// keys share one `PlanStructure`; their shapes differ only in the scalar
/// table (`parallelism::rebind`).
///
/// The key captures: model (layer count and module set), strategy label
/// (including hybrid inner degree), GPU count, the simulated decode-step
/// count (`min(knob, seq_out)` — each step emits its own tagged ops), and
/// the microbatch count of any pipeline axis (batch-dependent: GPipe
/// passes emit one op group per microbatch). Payload sizes, sequence
/// lengths, and link constants never enter the structure.
pub fn structure_key(knobs: &SimKnobs, cfg: &RunConfig) -> String {
    let sim_steps = knobs.sim_decode_steps.min(cfg.seq_out).max(1);
    let num_micro = match cfg.parallelism {
        Parallelism::Tensor | Parallelism::Data | Parallelism::Expert { .. } => 0,
        Parallelism::Pipeline => pipeline::microbatches(cfg.batch, cfg.gpus).1,
        Parallelism::Hybrid {
            inner,
            outer,
            inner_degree,
        } => {
            let do_ = cfg.gpus / inner_degree.max(1);
            match (inner, outer) {
                // TP×PP pipelines the full batch over the `do_` stages.
                (Strategy::Tensor, Strategy::Pipeline) => pipeline::microbatches(cfg.batch, do_.max(1)).1,
                // PP×DP pipelines each replica's batch shard over `di` stages.
                (Strategy::Pipeline, Strategy::Data) => {
                    let shard = (cfg.batch + do_ - 1) / do_.max(1);
                    pipeline::microbatches(shard, inner_degree).1
                }
                // TP×DP has no pipeline axis.
                _ => 0,
            }
        }
    };
    format!(
        "{}/{}/g{}/steps{}/mb{}",
        cfg.model,
        cfg.parallelism.label(),
        cfg.gpus,
        sim_steps,
        num_micro
    )
}

/// Run-level stochastic sampling shared by both execution paths: the skew
/// state (fleet-rescaled after all draws) and, for strategies with
/// jittered collectives, the launch-desync scale. The compiled and
/// reference paths must observe this sequence draw-for-draw — keeping it
/// in one place is what makes their bit-identity contract robust to edits.
pub(crate) fn run_stochastics(
    num_ranks: usize,
    draws_sync_jitter: bool,
    draws_route_bias: bool,
    spec: &ModelSpec,
    knobs: &SimKnobs,
    power: &PowerModel,
    rng: &mut Rng,
) -> (SkewModel, f64) {
    let mut skew = SkewModel::with_complexity(knobs, num_ranks, spec.complexity_factor(), rng);
    if let Some(scales) = power.fleet_compute_scales(num_ranks) {
        skew.apply_fleet(&scales);
    }
    let sync_jitter = if draws_sync_jitter {
        knobs.sync_jitter_s
            * spec.complexity_factor()
            * rng.lognormal_mean_cv(1.0, knobs.sync_jitter_cv)
    } else {
        0.0
    };
    // The MoE routing-imbalance draw comes last and is gated on the plan
    // carrying all-to-all collectives, so every pre-existing strategy's
    // seed stream is byte-identical to before this source existed.
    if draws_route_bias {
        skew.draw_route_bias(num_ranks, knobs.route_imbalance_cv, rng);
    }
    (skew, sync_jitter)
}

/// Execute a lowered plan under one run's stochastic conditions: sample
/// the run-level skew state and (for strategies with jittered collectives)
/// the launch-desync scale, then drive the event engine. Heterogeneous
/// fleets (`cluster::GpuSpec` per rank) rescale the sampled rank bias by
/// each rank's compute throughput — deterministically, after all draws, so
/// the seed stream matches the homogeneous path exactly.
pub fn execute_plan(
    plan: &Plan,
    spec: &ModelSpec,
    knobs: &SimKnobs,
    power: &PowerModel,
    rng: &mut Rng,
    threads: usize,
) -> BuiltRun {
    let (skew, sync_jitter) = run_stochastics(
        plan.num_ranks,
        plan.draws_sync_jitter,
        plan.draws_route_bias,
        spec,
        knobs,
        power,
        rng,
    );
    engine::execute(plan, power, &skew, sync_jitter, rng, threads, knobs.trace)
}

/// Execute a compiled `ExecPlan` under one run's stochastic conditions —
/// same run-level sampling as `execute_plan`, driving the engine's
/// array-walking path. Bit-identical to the interpreted path for the same
/// seed stream (property-tested).
pub fn execute_compiled(
    plan: &ExecPlan,
    spec: &ModelSpec,
    knobs: &SimKnobs,
    power: &PowerModel,
    rng: &mut Rng,
    threads: usize,
) -> BuiltRun {
    let (skew, sync_jitter) = run_stochastics(
        plan.num_ranks(),
        plan.structure.draws_sync_jitter,
        plan.structure.draws_route_bias,
        spec,
        knobs,
        power,
        rng,
    );
    engine::execute_compiled(plan, power, &skew, sync_jitter, rng, threads, knobs.trace)
}

/// Execute K shape-bindings of one mesh structure in a single engine walk
/// (`engine::execute_batch`, DESIGN.md §14). `conditions` carries each
/// lane's already-drawn run-level state — the power model and the seeded
/// RNG positioned exactly where the serial path's would be when execution
/// starts. The run-level stochastics (skew state, launch-desync scale) are
/// sampled here per lane from that lane's own stream, so every lane's
/// draw sequence — and therefore its `BuiltRun` — is bit-identical to a
/// serial `execute_compiled` of that lane alone (property-tested). The
/// per-lane `(PowerModel, Rng)` are handed back for the record-finishing
/// continuation draws.
pub fn execute_batch(
    batch: &ExecBatch,
    spec: &ModelSpec,
    knobs: &SimKnobs,
    conditions: Vec<(PowerModel, Rng)>,
    threads: usize,
) -> Vec<(BuiltRun, PowerModel, Rng)> {
    let mut lanes: Vec<engine::BatchLane> = conditions
        .into_iter()
        .map(|(power, mut rng)| {
            let (skew, sync_jitter) = run_stochastics(
                batch.structure.num_ranks,
                batch.structure.draws_sync_jitter,
                batch.structure.draws_route_bias,
                spec,
                knobs,
                &power,
                &mut rng,
            );
            engine::BatchLane {
                power,
                skew,
                sync_jitter,
                rng,
            }
        })
        .collect();
    let runs = engine::execute_batch(batch, &mut lanes, threads, knobs.trace);
    runs.into_iter()
        .zip(lanes)
        .map(|(run, lane)| (run, lane.power, lane.rng))
        .collect()
}

/// Lower + execute in one call (single-run paths and planner tests; the
/// profiling campaigns cache the lowering via `plan::PlanCache`). Uses the
/// compiled path unless `SimKnobs::reference_engine` selects the
/// interpreted reference — the two are bit-identical.
pub fn build(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    power: &PowerModel,
    rng: &mut Rng,
) -> BuiltRun {
    if knobs.reference_engine {
        let plan = lower(spec, hw, knobs, cfg);
        execute_plan(&plan, spec, knobs, power, rng, knobs.engine_threads)
    } else {
        let plan = compile(spec, hw, knobs, cfg);
        execute_compiled(&plan, spec, knobs, power, rng, knobs.engine_threads)
    }
}
