//! Expert-parallel lowerer (MoE all-to-all).
//!
//! The mesh is treated as a pool of expert hosts: attention (and the
//! small surrounding modules) runs data-parallel — each rank processes
//! its own batch shard with a full replica of the non-expert weights —
//! while the MLP weights are sharded one expert group per rank. Every
//! transformer block therefore inserts *two* all-to-all rendezvous per
//! pass: a dispatch that routes each rank's top-k token assignments to
//! the ranks hosting the selected experts, and a combine that routes the
//! expert outputs back. Both are jittered rendezvous events over the
//! tiered interconnect, and the expert MLP between them is additionally
//! stretched by a per-rank routing-imbalance multiplier (hot experts —
//! see `simulator::skew`), which is what makes the all-to-all waiting
//! phase wider and more informative than the tensor-parallel AllReduce.
//!
//! Per-rank dispatch payload is `tokens × top_k × hidden × dtype ×
//! capacity`, where `capacity` (≥ 1) buffers the routing headroom real
//! MoE runtimes allocate for imbalanced experts.

use crate::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::plan::affine::{BatchArg, CollKind, CommBase, CommScale, CommTerm, ComputeRule, OpRule, PayloadRule};
use crate::plan::{Plan, PlanBuilder, PlanSink, WaitRecord};
use crate::simulator::collective;
use crate::simulator::perf::PerfModel;
use crate::simulator::timeline::ModuleKind;

use super::LowerMeta;

/// Reference lowering into the interpreted `Plan` representation.
pub fn lower(spec: &ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &RunConfig) -> Plan {
    let mut b = PlanBuilder::new(cfg.gpus);
    let m = lower_into(spec, hw, knobs, cfg, &mut b);
    b.finish(m.sim_steps, m.comm_bytes_per_step, m.draws_sync_jitter)
}

/// Lowering pass, generic over the sink (reference build, SoA compile, or
/// shape rebind — see `plan::PlanSink`).
pub fn lower_into<S: PlanSink>(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    b: &mut S,
) -> LowerMeta {
    let g = cfg.gpus;
    let perf = PerfModel::new(hw);
    let topo = hw.topo();
    let mut comm_bytes_per_step = 0.0;
    let sim_steps = knobs.sim_decode_steps.min(cfg.seq_out).max(1);

    // Routing shape: taken from the strategy when it is `Expert` (the
    // normal path); the defaults keep the lowerer usable standalone.
    let (top_k, capacity_pct) = match cfg.parallelism {
        Parallelism::Expert { top_k, capacity_pct, .. } => (top_k.max(1), capacity_pct.max(100)),
        _ => (2, 125),
    };
    let capacity = capacity_pct as f64 / 100.0;

    // Attention is data-parallel: each rank owns a batch shard.
    let shard = (cfg.batch + g - 1) / g;
    // Expert MLP: each token activates `top_k` experts; the assignments
    // spread over the g expert hosts, so per-rank expert compute is the
    // dense MLP at `tokens × top_k` sharded g ways.
    let expert_tokens = cfg.batch * top_k;

    // All-to-all rendezvous over all g ranks — hierarchical when the mesh
    // spans nodes (local exchange, leader exchange, local redistribution).
    // Returns bytes moved.
    let topo_ref = &topo;
    let a2a_coll = CollKind::AllToAllHier { first: 0, n: g as u32 };
    let alltoall = move |b: &mut S, payload_per_rank: f64, pr: PayloadRule, layer: u16, step: u32| -> f64 {
        if g == 1 {
            // A single rank hosts every expert: no collective at all.
            return 0.0;
        }
        let t = collective::alltoall_hier(topo_ref, 0, g, payload_per_rank);
        let (xfer, wire) = (t.cost.transfer_s, t.wire_w);
        b.rule(OpRule::Collective { coll: a2a_coll, payload: pr });
        b.collective_tiered(0..g, ModuleKind::AllToAll, layer, step, xfer, wire, true, WaitRecord::All);
        t.cost.bytes_moved
    };

    // ---- Prefill (step 0): compute-bound pass over the prompt.
    let sa = BatchArg::CeilDiv(g as u32);
    let et = BatchArg::TimesTopK;
    let prefill_payload =
        (shard * cfg.seq_in * spec.hidden * spec.dtype_bytes) as f64 * top_k as f64 * capacity;
    let pr_prefill = PayloadRule::ExpertActs { batch: sa, times_seq_in: true };
    b.rule(OpRule::Compute(ComputeRule::Embed { batch: sa, times_seq_in: true }));
    b.compute(0..g, perf.embed_decode(spec, shard * cfg.seq_in), ModuleKind::Embedding, 0, 0);
    for layer in 0..spec.layers as u16 {
        b.rule(OpRule::Compute(ComputeRule::NormPrefill { batch: sa }));
        b.compute(0..g, perf.norm_prefill(spec, shard, cfg.seq_in), ModuleKind::Norm, layer, 0);
        b.rule(OpRule::Compute(ComputeRule::AttnPrefill { batch: sa, g: 1 }));
        b.compute(0..g, perf.attn_prefill(spec, shard, cfg.seq_in, 1), ModuleKind::SelfAttention, layer, 0);
        b.rule(OpRule::Compute(ComputeRule::NormPrefill { batch: sa }));
        b.compute(0..g, perf.norm_prefill(spec, shard, cfg.seq_in), ModuleKind::Norm, layer, 0);
        alltoall(&mut *b, prefill_payload, pr_prefill, layer, 0);
        b.rule(OpRule::Compute(ComputeRule::MlpPrefill { batch: et, g: g as u32 }));
        b.compute(0..g, perf.mlp_prefill(spec, expert_tokens, cfg.seq_in, g), ModuleKind::Mlp, layer, 0);
        alltoall(&mut *b, prefill_payload, pr_prefill, layer, 0);
    }

    // ---- Decode: `sim_steps` representative steps spread over seq_out.
    let decode_payload = (shard * spec.hidden * spec.dtype_bytes) as f64 * top_k as f64 * capacity;
    let pr_decode = PayloadRule::ExpertActs { batch: sa, times_seq_in: false };
    for si in 0..sim_steps {
        let step = (si + 1) as u32;
        // Representative KV context for this sampled step.
        let frac = (si as f64 + 0.5) / sim_steps as f64;
        let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;

        b.rule(OpRule::Compute(ComputeRule::Embed { batch: sa, times_seq_in: false }));
        b.compute(0..g, perf.embed_decode(spec, shard), ModuleKind::Embedding, 0, step);
        for layer in 0..spec.layers as u16 {
            b.rule(OpRule::Compute(ComputeRule::NormDecode { batch: sa }));
            b.compute(0..g, perf.norm_decode(spec, shard), ModuleKind::Norm, layer, step);
            b.rule(OpRule::Compute(ComputeRule::AttnDecode { batch: sa, si: si as u32, g: 1 }));
            b.compute(0..g, perf.attn_decode(spec, shard, context, 1), ModuleKind::SelfAttention, layer, step);
            b.rule(OpRule::Compute(ComputeRule::NormDecode { batch: sa }));
            b.compute(0..g, perf.norm_decode(spec, shard), ModuleKind::Norm, layer, step);
            let b1 = alltoall(&mut *b, decode_payload, pr_decode, layer, step);
            b.rule(OpRule::Compute(ComputeRule::MlpDecode { batch: et, g: g as u32 }));
            b.compute(0..g, perf.mlp_decode(spec, expert_tokens, g), ModuleKind::Mlp, layer, step);
            let b2 = alltoall(&mut *b, decode_payload, pr_decode, layer, step);
            if si == 0 {
                b.comm_term(CommTerm {
                    base: CommBase::CollPair { coll: a2a_coll, payload: pr_decode },
                    scale: CommScale::One,
                });
                comm_bytes_per_step += b1 + b2;
            }
        }
        // Logits are data-parallel (full head replica per rank).
        b.rule(OpRule::Compute(ComputeRule::LogitsDecode { batch: sa, g: 1 }));
        b.compute(0..g, perf.logits_decode(spec, shard, 1), ModuleKind::LogitsHead, 0, step);
    }

    // Terminal collation of the per-rank output shards, as in data
    // parallelism (the sequences never leave their home rank).
    if g > 1 {
        let payload = spec.allgather_payload_bytes(shard);
        let t = collective::allgather_ring(&topo, 0, g, g, payload);
        let (xfer, wire) = (t.cost.transfer_s, t.wire_w);
        let ag_coll = CollKind::AllGatherRing { first: 0, n: g as u32, ring: g as u32 };
        let pr_ag = PayloadRule::Ag { batch: sa };
        b.rule(OpRule::Collective { coll: ag_coll, payload: pr_ag });
        b.collective_tiered(0..g, ModuleKind::AllGather, 0, sim_steps as u32, xfer, wire, false, WaitRecord::All);
        b.comm_term(CommTerm {
            base: CommBase::Coll { coll: ag_coll, payload: pr_ag },
            scale: CommScale::OverSteps,
        });
        comm_bytes_per_step += t.cost.bytes_moved / sim_steps as f64;
    }

    LowerMeta {
        sim_steps,
        comm_bytes_per_step,
        draws_sync_jitter: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;
    use crate::parallelism::BuiltRun;
    use crate::simulator::power::PowerModel;
    use crate::simulator::timeline::PhaseKind;
    use crate::util::rng::Rng;

    fn build_run(gpus: usize, seed: u64) -> BuiltRun {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::expert(gpus), gpus, 8).with_seed(seed);
        let power = PowerModel::new(&hw);
        let mut rng = Rng::new(seed);
        crate::parallelism::build(&spec, &hw, &knobs, &cfg, &power, &mut rng)
    }

    #[test]
    fn alltoall_count_matches_structure() {
        let r = build_run(2, 1);
        // 2 all-to-alls per layer per pass (prefill + 4 decode steps).
        let a2a_xfers = r
            .timeline
            .phases
            .iter()
            .filter(|p| p.module == ModuleKind::AllToAll && p.kind == PhaseKind::Transfer)
            .count();
        let expected = 2 * 32 * (1 + 4) * 2; // syncs × ranks
        assert_eq!(a2a_xfers, expected);
    }

    #[test]
    fn plan_is_seed_free_and_structured() {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::expert(2), 2, 8);
        let plan = lower(&spec, &hw, &knobs, &cfg);
        let (compute, coll, send, recv) = plan.op_census();
        assert!(compute > 0);
        // 2 all-to-alls × 32 layers × 5 passes + 1 terminal AllGather.
        assert_eq!(coll, 2 * 32 * 5 + 1);
        assert_eq!((send, recv), (0, 0));
        assert!(plan.draws_sync_jitter);
        assert!(plan.draws_route_bias, "all-to-alls must arm the routing-imbalance draw");
        assert!(plan.comm_bytes_per_step > 0.0);
    }

    #[test]
    fn waits_are_nonnegative_and_some_positive() {
        let r = build_run(4, 2);
        assert!(r.wait_samples.iter().all(|&w| w >= 0.0));
        let positive = r.wait_samples.iter().filter(|&&w| w > 0.0).count();
        // With skew, all but the slowest rank wait at nearly every sync.
        assert!(positive as f64 > 0.5 * r.wait_samples.len() as f64);
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let r = build_run(1, 3);
        assert!(!r
            .timeline
            .phases
            .iter()
            .any(|p| p.kind == PhaseKind::Transfer));
        assert!(r.wait_samples.iter().all(|&w| w == 0.0));
    }

    #[test]
    fn more_gpus_faster_decode() {
        let r2 = build_run(2, 4);
        let r4 = build_run(4, 4);
        let d2 = r2.timeline.makespan() - r2.prefill_end;
        let d4 = r4.timeline.makespan() - r4.prefill_end;
        assert!(d4 < d2, "decode g=4 {d4} vs g=2 {d2}");
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let a = build_run(2, 9);
        let b = build_run(2, 9);
        assert_eq!(a.timeline.makespan(), b.timeline.makespan());
        assert_eq!(a.wait_samples, b.wait_samples);
    }

    #[test]
    fn ranks_synchronized_after_final_collective() {
        let r = build_run(4, 5);
        let clocks: Vec<f64> = (0..4).map(|g| r.timeline.clock(g)).collect();
        for c in &clocks {
            assert!((c - clocks[0]).abs() < 1e-12);
        }
    }
}
