//! Pipeline-parallel lowerer.
//!
//! Layers are split into g contiguous stages; the batch is split into g
//! microbatches that flow through the stages (GPipe-style inference
//! schedule). Communication lowers to hop-local P2P *edges*: stage i's
//! boundary send produces an edge that stage i+1's receive consumes — the
//! engine keeps the receiver busy-waiting (recorded wait phase, matching
//! the paper's timestamping of (end of producing stage, first byte, first
//! op of consuming stage)) until the edge is ready. Pipeline bubbles
//! appear as those waits plus the autoregressive step barrier after every
//! decode pass.

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::plan::affine::{BatchArg, CollKind, CommBase, CommScale, CommTerm, ComputeRule, OpRule, PayloadRule};
use crate::plan::{Plan, PlanBuilder, PlanSink, WaitRecord};
use crate::simulator::collective;
use crate::simulator::perf::PerfModel;
use crate::simulator::timeline::ModuleKind;

use super::LowerMeta;

/// Contiguous layer ranges per stage (remainder to the earliest stages).
pub fn stage_layers(layers: usize, stages: usize) -> Vec<std::ops::Range<usize>> {
    let base = layers / stages;
    let rem = layers % stages;
    let mut out = Vec::with_capacity(stages);
    let mut start = 0;
    for s in 0..stages {
        let len = base + usize::from(s < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// GPipe microbatching of `batch` over `stages`: (microbatch size, count).
/// Shared with `parallelism::structure_key` — the microbatch count is part
/// of a pipeline mesh's structural identity.
pub fn microbatches(batch: usize, stages: usize) -> (usize, usize) {
    let micro = (batch + stages - 1) / stages;
    let num_micro = (batch + micro - 1) / micro;
    (micro, num_micro)
}

/// Reference lowering into the interpreted `Plan` representation.
pub fn lower(spec: &ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &RunConfig) -> Plan {
    let mut b = PlanBuilder::new(cfg.gpus);
    let m = lower_into(spec, hw, knobs, cfg, &mut b);
    b.finish(m.sim_steps, m.comm_bytes_per_step, m.draws_sync_jitter)
}

/// Lowering pass, generic over the sink (reference build, SoA compile, or
/// shape rebind — see `plan::PlanSink`).
pub fn lower_into<S: PlanSink>(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    b: &mut S,
) -> LowerMeta {
    let g = cfg.gpus;
    let perf = PerfModel::new(hw);
    let topo = hw.topo();

    let sim_steps = knobs.sim_decode_steps.min(cfg.seq_out).max(1);
    let ranges = stage_layers(spec.layers, g);
    let (micro, num_micro) = microbatches(cfg.batch, g);

    // One full pass (prefill with seq tokens, or a decode step) pipelined
    // over microbatches. Returns payload bytes transferred per pass.
    let mb_arg = BatchArg::Micro { stages: g as u32 };
    let run_pass = |b: &mut S, step: u32, context: usize, prefill: bool| -> f64 {
        // Boundary edge per microbatch (overwritten stage by stage).
        let mut boundary: Vec<u32> = vec![u32::MAX; num_micro];
        let payload = if prefill {
            spec.p2p_payload_bytes(micro, cfg.seq_in)
        } else {
            spec.p2p_payload_bytes(micro, 1)
        };
        let pr_boundary = PayloadRule::Acts { batch: mb_arg, times_seq_in: prefill };
        for (stage, range) in ranges.iter().enumerate() {
            for mb in 0..num_micro {
                // Consume our input edge: the previous stage's boundary
                // send for this microbatch.
                if stage > 0 {
                    b.recv(stage..stage + 1, range.start as u16, step, boundary[mb]);
                }
                // Stage compute: embed on stage 0, layers, logits on last.
                if stage == 0 {
                    let t = if prefill {
                        perf.embed_decode(spec, micro * cfg.seq_in)
                    } else {
                        perf.embed_decode(spec, micro)
                    };
                    b.rule(OpRule::Compute(ComputeRule::Embed { batch: mb_arg, times_seq_in: prefill }));
                    b.compute(stage..stage + 1, t, ModuleKind::Embedding, 0, step);
                }
                for layer in range.clone() {
                    let (tn, ta, tm) = if prefill {
                        (
                            perf.norm_prefill(spec, micro, cfg.seq_in),
                            perf.attn_prefill(spec, micro, cfg.seq_in, 1),
                            perf.mlp_prefill(spec, micro, cfg.seq_in, 1),
                        )
                    } else {
                        (
                            perf.norm_decode(spec, micro),
                            perf.attn_decode(spec, micro, context, 1),
                            perf.mlp_decode(spec, micro, 1),
                        )
                    };
                    let (rn, ra, rm) = if prefill {
                        (
                            ComputeRule::NormPrefill { batch: mb_arg },
                            ComputeRule::AttnPrefill { batch: mb_arg, g: 1 },
                            ComputeRule::MlpPrefill { batch: mb_arg, g: 1 },
                        )
                    } else {
                        (
                            ComputeRule::NormDecode { batch: mb_arg },
                            ComputeRule::AttnDecode { batch: mb_arg, si: step - 1, g: 1 },
                            ComputeRule::MlpDecode { batch: mb_arg, g: 1 },
                        )
                    };
                    for (t, rule, module) in [
                        (tn, rn, ModuleKind::Norm),
                        (ta, ra, ModuleKind::SelfAttention),
                        (tn, rn, ModuleKind::Norm),
                        (tm, rm, ModuleKind::Mlp),
                    ] {
                        b.rule(OpRule::Compute(rule));
                        b.compute(stage..stage + 1, t, module, layer as u16, step);
                    }
                }
                if stage + 1 == g {
                    b.rule(OpRule::Compute(ComputeRule::LogitsDecode { batch: mb_arg, g: 1 }));
                    b.compute(stage..stage + 1, perf.logits_decode(spec, micro, 1), ModuleKind::LogitsHead, 0, step);
                } else {
                    // Send boundary activations to the next stage — over
                    // the inter-node tier when the boundary crosses nodes.
                    let t = collective::p2p_range(&topo, stage, 1, stage + 1, payload);
                    b.rule(OpRule::Send {
                        coll: CollKind::P2pRange { src: stage as u32, count: 1, dst: stage as u32 + 1 },
                        payload: pr_boundary,
                    });
                    boundary[mb] = b.send_tiered(stage..stage + 1, range.end as u16, step, t.cost.transfer_s, t.wire_w);
                }
            }
        }
        payload * (g - 1) as f64 * num_micro as f64
    };

    // Prefill.
    run_pass(&mut *b, 0, cfg.seq_in, true);

    // Decode steps. Autoregressive serialization: the next step's stage-0
    // embedding needs the token sampled from the last stage's logits, so
    // every stage synchronizes at the step boundary (the defining bubble
    // of pipeline-parallel decode) — a mesh-wide barrier rendezvous.
    let mut decode_bytes = 0.0;
    for si in 0..sim_steps {
        let frac = (si as f64 + 0.5) / sim_steps as f64;
        let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;
        let bytes = run_pass(&mut *b, (si + 1) as u32, context, false);
        if si == 0 {
            b.comm_term(CommTerm {
                base: CommBase::Boundary { stages: g as u32, batch: BatchArg::Full },
                scale: CommScale::One,
            });
            decode_bytes = bytes;
        }
        b.rule(OpRule::Barrier);
        b.collective(0..g, ModuleKind::P2PTransfer, 0, (si + 1) as u32, 0.0, false, WaitRecord::None);
    }

    LowerMeta {
        sim_steps,
        comm_bytes_per_step: decode_bytes,
        draws_sync_jitter: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;
    use crate::models::by_name;
    use crate::parallelism::BuiltRun;
    use crate::simulator::power::PowerModel;
    use crate::simulator::timeline::PhaseKind;
    use crate::util::rng::Rng;

    fn build_run(gpus: usize, seed: u64) -> BuiltRun {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Pipeline, gpus, 8).with_seed(seed);
        let power = PowerModel::new(&hw);
        let mut rng = Rng::new(seed);
        crate::parallelism::build(&spec, &hw, &knobs, &cfg, &power, &mut rng)
    }

    #[test]
    fn stage_layer_split_covers_all() {
        let r = stage_layers(32, 4);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0], 0..8);
        assert_eq!(r[3], 24..32);
        let r = stage_layers(33, 4);
        assert_eq!(r[0].len(), 9);
        assert_eq!(r.iter().map(|x| x.len()).sum::<usize>(), 33);
    }

    #[test]
    fn p2p_transfers_present_between_stages() {
        let r = build_run(2, 1);
        let sends = r
            .timeline
            .phases
            .iter()
            .filter(|p| p.module == ModuleKind::P2PTransfer && p.kind == PhaseKind::Transfer)
            .count();
        // 1 boundary × 2 microbatches × (prefill + 4 steps).
        assert_eq!(sends, 2 * 5);
    }

    #[test]
    fn plan_has_matched_edges_and_no_jitter_draw() {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Pipeline, 4, 8);
        let plan = lower(&spec, &hw, &knobs, &cfg);
        let (_, coll, send, recv) = plan.op_census();
        // 3 boundaries × 4 microbatches × 5 passes, each edge consumed once.
        assert_eq!(send, 3 * 4 * 5);
        assert_eq!(recv, send);
        assert_eq!(plan.num_edges as usize, send);
        // One step barrier per decode step.
        assert_eq!(coll, 4);
        assert!(!plan.draws_sync_jitter);
    }

    #[test]
    fn no_allreduce_under_pp() {
        let r = build_run(4, 2);
        assert!(!r
            .timeline
            .phases
            .iter()
            .any(|p| p.module == ModuleKind::AllReduce));
    }

    #[test]
    fn later_stages_bubble_wait_at_start() {
        let r = build_run(4, 3);
        // Stage 3's startup bubble is a recv busy-wait attributed to the
        // P2P transfer (the paper's timestamped interval).
        let first = r
            .timeline
            .phases
            .iter()
            .find(|p| p.gpu == 3)
            .expect("stage 3 has phases");
        assert_eq!(first.kind, PhaseKind::Wait);
        assert_eq!(first.module, ModuleKind::P2PTransfer);
    }

    #[test]
    fn logits_only_on_last_stage() {
        let r = build_run(4, 4);
        for p in &r.timeline.phases {
            if p.module == ModuleKind::LogitsHead {
                assert_eq!(p.gpu, 3);
            }
            if p.module == ModuleKind::Embedding && p.kind == PhaseKind::Compute {
                assert_eq!(p.gpu, 0);
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = build_run(2, 7);
        let b = build_run(2, 7);
        assert_eq!(a.timeline.makespan(), b.timeline.makespan());
    }
}
