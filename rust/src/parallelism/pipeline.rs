//! Pipeline-parallel planner.
//!
//! Layers are split into g contiguous stages; the batch is split into g
//! microbatches that flow through the stages (GPipe-style inference
//! schedule). Communication is hop-local: stage i sends its boundary
//! activations to stage i+1 (Appendix D). Pipeline bubbles appear as idle
//! phases; transfers are point-to-point `P2PTransfer` phases on the sender
//! with the receiver idling until arrival — matching the paper's
//! timestamping of (end of producing stage, first byte, first op of
//! consuming stage).

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::simulator::collective;
use crate::simulator::perf::PerfModel;
use crate::simulator::power::PowerModel;
use crate::simulator::skew::SkewModel;
use crate::simulator::timeline::{ModuleKind, PhaseKind, Timeline};
use crate::util::rng::Rng;

use super::BuiltRun;

/// Contiguous layer ranges per stage (remainder to the earliest stages).
pub fn stage_layers(layers: usize, stages: usize) -> Vec<std::ops::Range<usize>> {
    let base = layers / stages;
    let rem = layers % stages;
    let mut out = Vec::with_capacity(stages);
    let mut start = 0;
    for s in 0..stages {
        let len = base + usize::from(s < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

pub fn build(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    power: &PowerModel,
    rng: &mut Rng,
) -> BuiltRun {
    let g = cfg.gpus;
    let perf = PerfModel::new(hw);
    let skew = SkewModel::with_complexity(knobs, g, spec.complexity_factor(), rng);
    let mut tl = Timeline::new(g, power.gpu_power(PhaseKind::Idle, 0.0));
    let mut wait_samples = Vec::new();

    let sim_steps = knobs.sim_decode_steps.min(cfg.seq_out).max(1);
    let ranges = stage_layers(spec.layers, g);
    let micro = (cfg.batch + g - 1) / g; // microbatch size
    let num_micro = (cfg.batch + micro - 1) / micro;

    // One full pass (prefill with seq tokens, or a decode step) pipelined
    // over microbatches. Returns payload bytes transferred per microbatch
    // per boundary.
    let run_pass = |tl: &mut Timeline,
                        rng: &mut Rng,
                        wait_samples: &mut Vec<f64>,
                        step: u32,
                        context: usize,
                        prefill: bool|
     -> f64 {
        // end[(stage, mb)] completion times for the dependency recurrence.
        let mut prev_stage_ready = vec![0.0f64; num_micro];
        let payload = if prefill {
            spec.p2p_payload_bytes(micro, cfg.seq_in)
        } else {
            spec.p2p_payload_bytes(micro, 1)
        };
        for (stage, range) in ranges.iter().enumerate() {
            for mb in 0..num_micro {
                // Wait for our input: previous stage's send completed. The
                // paper timestamps exactly this interval — (end of boundary
                // layer in the producing stage) → (first op of the consuming
                // stage) — and attributes it to the Point-to-Point transfer;
                // the NCCL recv busy-waits, so it burns wait power, not idle.
                if stage > 0 {
                    let ready = prev_stage_ready[mb];
                    let waited = tl.wait_until(
                        stage,
                        ready,
                        ModuleKind::P2PTransfer,
                        range.start as u16,
                        step,
                        power.gpu_power(PhaseKind::Wait, 0.0),
                    );
                    if waited > 0.0 {
                        wait_samples.push(waited);
                    }
                }
                // Stage compute: embed on stage 0, layers, logits on last.
                if stage == 0 {
                    let t = if prefill {
                        perf.embed_decode(spec, micro * cfg.seq_in)
                    } else {
                        perf.embed_decode(spec, micro)
                    };
                    let dur = skew.sample(t.dur_s, stage, rng);
                    tl.push(stage, PhaseKind::Compute, ModuleKind::Embedding, 0, step, dur, power.gpu_power(PhaseKind::Compute, t.util));
                }
                for layer in range.clone() {
                    let (tn, ta, tm) = if prefill {
                        (
                            perf.norm_prefill(spec, micro, cfg.seq_in),
                            perf.attn_prefill(spec, micro, cfg.seq_in, 1),
                            perf.mlp_prefill(spec, micro, cfg.seq_in, 1),
                        )
                    } else {
                        (
                            perf.norm_decode(spec, micro),
                            perf.attn_decode(spec, micro, context, 1),
                            perf.mlp_decode(spec, micro, 1),
                        )
                    };
                    for (t, module) in [
                        (tn, ModuleKind::Norm),
                        (ta, ModuleKind::SelfAttention),
                        (tn, ModuleKind::Norm),
                        (tm, ModuleKind::Mlp),
                    ] {
                        let dur = skew.sample_module(t.dur_s, stage, module, rng);
                        tl.push(stage, PhaseKind::Compute, module, layer as u16, step, dur, power.gpu_power(PhaseKind::Compute, t.util));
                    }
                }
                if stage + 1 == g {
                    let t = perf.logits_decode(spec, micro, 1);
                    let dur = skew.sample(t.dur_s, stage, rng);
                    tl.push(stage, PhaseKind::Compute, ModuleKind::LogitsHead, 0, step, dur, power.gpu_power(PhaseKind::Compute, t.util));
                } else {
                    // Send boundary activations to the next stage.
                    let cost = collective::p2p(hw, payload);
                    tl.push(stage, PhaseKind::Transfer, ModuleKind::P2PTransfer, range.end as u16, step, cost.transfer_s, power.gpu_power(PhaseKind::Transfer, 0.0));
                    prev_stage_ready[mb] = tl.clock(stage);
                }
            }
        }
        payload * (g - 1) as f64 * num_micro as f64
    };

    // Prefill.
    run_pass(&mut tl, rng, &mut wait_samples, 0, cfg.seq_in, true);
    let prefill_end = tl.makespan();

    // Decode steps. Autoregressive serialization: the next step's stage-0
    // embedding needs the token sampled from the last stage's logits, so
    // every stage waits for the step boundary (the defining bubble of
    // pipeline-parallel decode) — receiver-side, attributed like any other
    // hop-local recv.
    let mut decode_bytes = 0.0;
    for si in 0..sim_steps {
        let frac = (si as f64 + 0.5) / sim_steps as f64;
        let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;
        let b = run_pass(&mut tl, rng, &mut wait_samples, (si + 1) as u32, context, false);
        if si == 0 {
            decode_bytes = b;
        }
        let token_ready = tl.makespan();
        for stage in 0..g {
            tl.wait_until(
                stage,
                token_ready,
                ModuleKind::P2PTransfer,
                0,
                (si + 1) as u32,
                power.gpu_power(PhaseKind::Wait, 0.0),
            );
        }
    }
    let comm_bytes_per_step = decode_bytes;

    tl.finalize();
    BuiltRun {
        timeline: tl,
        wait_samples,
        prefill_end,
        sim_steps,
        comm_bytes_per_step,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;
    use crate::models::by_name;

    fn build_run(gpus: usize, seed: u64) -> BuiltRun {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Pipeline, gpus, 8).with_seed(seed);
        let power = PowerModel::new(&hw);
        let mut rng = Rng::new(seed);
        build(&spec, &hw, &knobs, &cfg, &power, &mut rng)
    }

    #[test]
    fn stage_layer_split_covers_all() {
        let r = stage_layers(32, 4);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0], 0..8);
        assert_eq!(r[3], 24..32);
        let r = stage_layers(33, 4);
        assert_eq!(r[0].len(), 9);
        assert_eq!(r.iter().map(|x| x.len()).sum::<usize>(), 33);
    }

    #[test]
    fn p2p_transfers_present_between_stages() {
        let r = build_run(2, 1);
        let sends = r
            .timeline
            .phases
            .iter()
            .filter(|p| p.module == ModuleKind::P2PTransfer && p.kind == PhaseKind::Transfer)
            .count();
        // 1 boundary × 2 microbatches × (prefill + 4 steps).
        assert_eq!(sends, 2 * 5);
    }

    #[test]
    fn no_allreduce_under_pp() {
        let r = build_run(4, 2);
        assert!(!r
            .timeline
            .phases
            .iter()
            .any(|p| p.module == ModuleKind::AllReduce));
    }

    #[test]
    fn later_stages_bubble_wait_at_start() {
        let r = build_run(4, 3);
        // Stage 3's startup bubble is a recv busy-wait attributed to the
        // P2P transfer (the paper's timestamped interval).
        let first = r
            .timeline
            .phases
            .iter()
            .find(|p| p.gpu == 3)
            .expect("stage 3 has phases");
        assert_eq!(first.kind, PhaseKind::Wait);
        assert_eq!(first.module, ModuleKind::P2PTransfer);
    }

    #[test]
    fn logits_only_on_last_stage() {
        let r = build_run(4, 4);
        for p in &r.timeline.phases {
            if p.module == ModuleKind::LogitsHead {
                assert_eq!(p.gpu, 3);
            }
            if p.module == ModuleKind::Embedding && p.kind == PhaseKind::Compute {
                assert_eq!(p.gpu, 0);
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = build_run(2, 7);
        let b = build_run(2, 7);
        assert_eq!(a.timeline.makespan(), b.timeline.makespan());
    }
}
