//! Data-parallel lowerer.
//!
//! The full model is replicated on every GPU; the batch is split evenly
//! across replicas which decode independently (no per-layer coupling).
//! Outputs are collated by a single terminal AllGather rendezvous
//! (Appendix E): at execution, faster replicas busy-wait for stragglers,
//! then exchange final logits.

use crate::config::{HwSpec, RunConfig, SimKnobs};
use crate::models::ModelSpec;
use crate::plan::affine::{BatchArg, CollKind, CommBase, CommScale, CommTerm, ComputeRule, OpRule, PayloadRule};
use crate::plan::{Plan, PlanBuilder, PlanSink, WaitRecord};
use crate::simulator::collective;
use crate::simulator::perf::PerfModel;
use crate::simulator::timeline::ModuleKind;

use super::LowerMeta;

/// Reference lowering into the interpreted `Plan` representation.
pub fn lower(spec: &ModelSpec, hw: &HwSpec, knobs: &SimKnobs, cfg: &RunConfig) -> Plan {
    let mut b = PlanBuilder::new(cfg.gpus);
    let m = lower_into(spec, hw, knobs, cfg, &mut b);
    b.finish(m.sim_steps, m.comm_bytes_per_step, m.draws_sync_jitter)
}

/// Lowering pass, generic over the sink (reference build, SoA compile, or
/// shape rebind — see `plan::PlanSink`).
pub fn lower_into<S: PlanSink>(
    spec: &ModelSpec,
    hw: &HwSpec,
    knobs: &SimKnobs,
    cfg: &RunConfig,
    b: &mut S,
) -> LowerMeta {
    let g = cfg.gpus;
    let perf = PerfModel::new(hw);

    let sim_steps = knobs.sim_decode_steps.min(cfg.seq_out).max(1);
    let shard = (cfg.batch + g - 1) / g; // per-replica batch

    // Each replica runs prefill + decode independently.
    let sa = BatchArg::CeilDiv(g as u32);
    for rank in 0..g {
        // Prefill.
        b.rule(OpRule::Compute(ComputeRule::Embed { batch: sa, times_seq_in: true }));
        b.compute(rank..rank + 1, perf.embed_decode(spec, shard * cfg.seq_in), ModuleKind::Embedding, 0, 0);
        for layer in 0..spec.layers as u16 {
            b.rule(OpRule::Compute(ComputeRule::NormPrefill { batch: sa }));
            b.compute(rank..rank + 1, perf.norm_prefill(spec, shard, cfg.seq_in), ModuleKind::Norm, layer, 0);
            let ta = perf.attn_prefill(spec, shard, cfg.seq_in, 1);
            b.rule(OpRule::Compute(ComputeRule::AttnPrefill { batch: sa, g: 1 }));
            b.compute(rank..rank + 1, ta, ModuleKind::SelfAttention, layer, 0);
            b.rule(OpRule::Compute(ComputeRule::NormPrefill { batch: sa }));
            b.compute(rank..rank + 1, perf.norm_prefill(spec, shard, cfg.seq_in), ModuleKind::Norm, layer, 0);
            b.rule(OpRule::Compute(ComputeRule::MlpPrefill { batch: sa, g: 1 }));
            b.compute(rank..rank + 1, perf.mlp_prefill(spec, shard, cfg.seq_in, 1), ModuleKind::Mlp, layer, 0);
        }
        // Decode.
        for si in 0..sim_steps {
            let step = (si + 1) as u32;
            let frac = (si as f64 + 0.5) / sim_steps as f64;
            let context = cfg.seq_in + (frac * cfg.seq_out as f64) as usize;
            b.rule(OpRule::Compute(ComputeRule::Embed { batch: sa, times_seq_in: false }));
            b.compute(rank..rank + 1, perf.embed_decode(spec, shard), ModuleKind::Embedding, 0, step);
            for layer in 0..spec.layers as u16 {
                b.rule(OpRule::Compute(ComputeRule::NormDecode { batch: sa }));
                b.compute(rank..rank + 1, perf.norm_decode(spec, shard), ModuleKind::Norm, layer, step);
                let ta = perf.attn_decode(spec, shard, context, 1);
                b.rule(OpRule::Compute(ComputeRule::AttnDecode { batch: sa, si: si as u32, g: 1 }));
                b.compute(rank..rank + 1, ta, ModuleKind::SelfAttention, layer, step);
                b.rule(OpRule::Compute(ComputeRule::NormDecode { batch: sa }));
                b.compute(rank..rank + 1, perf.norm_decode(spec, shard), ModuleKind::Norm, layer, step);
                b.rule(OpRule::Compute(ComputeRule::MlpDecode { batch: sa, g: 1 }));
                b.compute(rank..rank + 1, perf.mlp_decode(spec, shard, 1), ModuleKind::Mlp, layer, step);
            }
            b.rule(OpRule::Compute(ComputeRule::LogitsDecode { batch: sa, g: 1 }));
            b.compute(rank..rank + 1, perf.logits_decode(spec, shard, 1), ModuleKind::LogitsHead, 0, step);
        }
    }

    // Terminal collation: replicas rendezvous once, then AllGather their
    // final output logits — bottlenecked by the inter-node tier when the
    // replica ring crosses nodes.
    let mut comm_bytes_per_step = 0.0;
    if g > 1 {
        let topo = hw.topo();
        let payload = spec.allgather_payload_bytes(shard);
        let t = collective::allgather_ring(&topo, 0, g, g, payload);
        let (xfer, wire) = (t.cost.transfer_s, t.wire_w);
        let ag_coll = CollKind::AllGatherRing { first: 0, n: g as u32, ring: g as u32 };
        let pr_ag = PayloadRule::Ag { batch: sa };
        b.rule(OpRule::Collective { coll: ag_coll, payload: pr_ag });
        b.collective_tiered(0..g, ModuleKind::AllGather, 0, sim_steps as u32, xfer, wire, false, WaitRecord::All);
        b.comm_term(CommTerm {
            base: CommBase::Coll { coll: ag_coll, payload: pr_ag },
            scale: CommScale::OverSteps,
        });
        comm_bytes_per_step = t.cost.bytes_moved / sim_steps as f64;
    }

    LowerMeta {
        sim_steps,
        comm_bytes_per_step,
        draws_sync_jitter: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism;
    use crate::models::by_name;
    use crate::parallelism::BuiltRun;
    use crate::simulator::power::PowerModel;
    use crate::simulator::timeline::PhaseKind;
    use crate::util::rng::Rng;

    fn build_run(gpus: usize, seed: u64) -> BuiltRun {
        let spec = by_name("Vicuna-7B").unwrap();
        let hw = HwSpec::default();
        let knobs = SimKnobs {
            sim_decode_steps: 4,
            ..SimKnobs::default()
        };
        let cfg = RunConfig::new("Vicuna-7B", Parallelism::Data, gpus, 8).with_seed(seed);
        let power = PowerModel::new(&hw);
        let mut rng = Rng::new(seed);
        crate::parallelism::build(&spec, &hw, &knobs, &cfg, &power, &mut rng)
    }

    #[test]
    fn single_terminal_allgather() {
        let r = build_run(2, 1);
        let gathers = r
            .timeline
            .phases
            .iter()
            .filter(|p| p.module == ModuleKind::AllGather && p.kind == PhaseKind::Transfer)
            .count();
        assert_eq!(gathers, 2); // one per replica
    }

    #[test]
    fn no_per_layer_comm() {
        let r = build_run(4, 2);
        assert!(!r
            .timeline
            .phases
            .iter()
            .any(|p| p.module == ModuleKind::AllReduce || p.module == ModuleKind::P2PTransfer));
    }

    #[test]
    fn replicas_do_full_model_work() {
        let r = build_run(2, 3);
        // Both replicas run logits (unlike PP where only the last stage does).
        for rank in 0..2 {
            assert!(r
                .timeline
                .phases
                .iter()
                .any(|p| p.gpu == rank && p.module == ModuleKind::LogitsHead));
        }
    }

    #[test]
    fn waits_recorded_at_collation() {
        let r = build_run(4, 4);
        assert_eq!(r.wait_samples.len(), 4);
        // Exactly one replica (the slowest) waits zero.
        let zeros = r.wait_samples.iter().filter(|&&w| w == 0.0).count();
        assert_eq!(zeros, 1);
    }

    #[test]
    fn dp_decode_wall_time_less_than_replica_sum() {
        let r = build_run(4, 5);
        let makespan = r.timeline.makespan();
        let busy: f64 = r
            .timeline
            .phases
            .iter()
            .filter(|p| p.kind == PhaseKind::Compute)
            .map(|p| p.dur())
            .sum();
        assert!(makespan < busy, "replicas must run concurrently");
    }
}
