//! Execution tracing + observability layer (DESIGN.md §15).
//!
//! The event engine materializes per-rank, power-annotated phase timelines
//! (`simulator::timeline::Timeline`) and then collapses them into run
//! records and tables. This module keeps the structure observable:
//!
//! * [`Trace`] — the engine-side capture: per materialized phase, the index
//!   of the plan op that produced it. Recorded by
//!   `simulator::engine` when `SimKnobs::trace` is on (zero allocation
//!   when off); joined back against the `ExecPlan` arrays to recover
//!   op-level metadata (rank range, link tier, payload) the timeline
//!   itself does not carry.
//! * [`SpanEvent`] / [`TraceSink`] — the structured event stream derived
//!   from a traced run: one span per phase with rank, step, module, phase
//!   kind, times, energy, and (for communication phases) the estimated
//!   bytes moved and the link tier driven.
//! * [`critpath`] — the critical-path pass over the materialized phases:
//!   which chain of compute/transfer phases determines the makespan, how
//!   much energy is on-path vs. off-path (slack), and which resource
//!   (compute rank, collective, inter-node link) binds the scenario.
//! * [`export`] — Chrome trace-event / Perfetto JSON rendering (one pid
//!   per rank plus an instantaneous total-power counter track) for
//!   `ui.perfetto.dev`.
//!
//! # Example: trace a run and attribute its critical path
//!
//! ```
//! use piep::config::{HwSpec, Parallelism, RunConfig, SimKnobs};
//! use piep::simulator::run::execute_traced;
//! use piep::trace::critpath::critical_path_with;
//!
//! let hw = HwSpec::default();
//! let knobs = SimKnobs { sim_decode_steps: 2, ..SimKnobs::default() };
//! let cfg = RunConfig::new("Vicuna-7B", Parallelism::expert(2), 2, 8);
//! let (plan, built) = execute_traced(&cfg, &hw, &knobs);
//! let trace = built.trace.as_ref().expect("execute_traced captures the trace");
//!
//! let topo = hw.topo();
//! let cp = critical_path_with(&built.timeline, Some((trace, &plan, &topo)));
//! // The chain spans exactly the makespan...
//! assert!((cp.len_s - built.timeline.makespan()).abs() <= 1e-9 * cp.len_s);
//! // ...and the three buckets partition the timeline's GPU-side energy.
//! let total = built.timeline.gpu_energy_j();
//! assert!((cp.on_path_j + cp.off_path_j + cp.idle_j - total).abs() <= 1e-9 * total);
//! ```

pub mod critpath;
pub mod export;

use crate::cluster::{LinkSpec, LinkTier, Topology};
use crate::plan::exec::{ExecPlan, OpKind};
use crate::simulator::timeline::{ModuleKind, PhaseKind, Timeline};

/// Engine-side execution trace: for each phase the engine materialized (in
/// `Timeline::phases` order, *excluding* the idle tail padding appended by
/// `finalize_with`), the index of the plan op that produced it.
///
/// `u32::MAX` marks a phase with no originating op (never produced by the
/// current engine, reserved for synthetic phases).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Op index per materialized phase (aligned with the first
    /// `ops.len()` entries of `Timeline::phases`).
    pub ops: Vec<u32>,
}

impl Trace {
    /// Op index of phase `i`, or `None` for idle-tail padding phases
    /// (which have no originating op).
    #[inline]
    pub fn op_of(&self, phase_idx: usize) -> Option<u32> {
        match self.ops.get(phase_idx) {
            Some(&op) if op != u32::MAX => Some(op),
            _ => None,
        }
    }
}

/// One structured trace event: a phase joined with its op-level metadata.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub rank: u16,
    pub step: u32,
    pub layer: u16,
    pub module: ModuleKind,
    pub kind: PhaseKind,
    pub t0: f64,
    pub t1: f64,
    /// Board power during the span, W.
    pub power_w: f64,
    /// Exact phase energy, J.
    pub energy_j: f64,
    /// Estimated payload bytes moved during a communication transfer span
    /// (transfer seconds × link bandwidth); 0 for compute/wait/idle.
    pub bytes: f64,
    /// Link tier driven by a communication span (`"nvlink"`, `"pcie"`,
    /// `"infiniband"`, or `"flat"` for the legacy single-tier link);
    /// `"-"` for non-communication spans.
    pub link_tier: &'static str,
    /// Plan op index that produced the span (`None` for idle padding).
    pub op: Option<u32>,
}

/// Consumer of a structured span stream. The exporters and the critpath
/// CSV writer are sinks; tests use [`VecSink`] to capture events.
pub trait TraceSink {
    fn span(&mut self, ev: &SpanEvent);
}

/// A sink that collects every span into a `Vec`.
#[derive(Debug, Default)]
pub struct VecSink {
    pub events: Vec<SpanEvent>,
}

impl TraceSink for VecSink {
    fn span(&mut self, ev: &SpanEvent) {
        self.events.push(ev.clone());
    }
}

/// Name a link spec by matching it against the named tiers' constants
/// (`"flat"` for the legacy single-tier link derived from `HwSpec`).
pub fn tier_name(spec: &LinkSpec) -> &'static str {
    for t in LinkTier::ALL {
        if t.spec() == *spec {
            return t.name();
        }
    }
    "flat"
}

/// The link tier a communication op drives: the inter-node tier when the
/// op's rank range crosses a node boundary, the intra-node tier otherwise.
fn op_tier(topo: &Topology, first: usize, count: usize) -> &'static str {
    tier_name(if topo.spans(first, count) {
        &topo.inter
    } else {
        &topo.intra
    })
}

/// Derive the structured span stream of a traced run and feed it to
/// `sink`, in `Timeline::phases` order. With a plan and topology the
/// communication spans carry estimated payload bytes and the link tier;
/// without them those fields are zero / `"-"`.
pub fn emit_spans(
    tl: &Timeline,
    trace: &Trace,
    plan: Option<&ExecPlan>,
    topo: Option<&Topology>,
    sink: &mut dyn TraceSink,
) {
    for (i, p) in tl.phases.iter().enumerate() {
        let op = trace.op_of(i);
        let mut bytes = 0.0;
        let mut link_tier = "-";
        if p.kind == PhaseKind::Transfer {
            if let (Some(op), Some(ep)) = (op, plan) {
                let o = op as usize;
                let s = &ep.structure;
                if matches!(s.kind[o], OpKind::Collective | OpKind::Send) {
                    let r = s.ranks[o];
                    let (first, count) = (r.first as usize, r.count as usize);
                    if let Some(topo) = topo {
                        let link = if topo.spans(first, count) { &topo.inter } else { &topo.intra };
                        bytes = ep.scalars.dur_s[o] * link.bw;
                        link_tier = op_tier(topo, first, count);
                    }
                }
            }
        }
        sink.span(&SpanEvent {
            rank: p.gpu,
            step: p.step,
            layer: p.layer,
            module: p.module,
            kind: p.kind,
            t0: p.t0,
            t1: p.t1,
            power_w: p.power_w,
            energy_j: p.energy_j(),
            bytes,
            link_tier,
            op,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::timeline::{ModuleKind, PhaseKind, Timeline};

    #[test]
    fn trace_op_lookup_handles_padding() {
        let t = Trace { ops: vec![3, 7, u32::MAX] };
        assert_eq!(t.op_of(0), Some(3));
        assert_eq!(t.op_of(1), Some(7));
        assert_eq!(t.op_of(2), None, "sentinel is not an op");
        assert_eq!(t.op_of(9), None, "idle tails beyond the capture");
    }

    #[test]
    fn tier_names_resolve_and_flat_falls_through() {
        for t in LinkTier::ALL {
            assert_eq!(tier_name(&t.spec()), t.name());
        }
        let flat = crate::config::HwSpec::default().flat_link();
        assert_eq!(tier_name(&flat), "flat");
    }

    #[test]
    fn emit_spans_covers_every_phase_in_order() {
        let mut tl = Timeline::new(2, 20.0);
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 1.0, 200.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 0.5, 200.0);
        tl.wait_until(1, 1.0, ModuleKind::AllReduce, 0, 0, 95.0);
        tl.finalize();
        let trace = Trace { ops: vec![0, 0, 1] };
        let mut sink = VecSink::default();
        emit_spans(&tl, &trace, None, None, &mut sink);
        assert_eq!(sink.events.len(), tl.phases.len());
        assert_eq!(sink.events[0].op, Some(0));
        assert_eq!(sink.events[2].kind, PhaseKind::Wait);
        assert!((sink.events[0].energy_j - 200.0).abs() < 1e-12);
        assert_eq!(sink.events[0].link_tier, "-");
    }
}
