//! Chrome trace-event / Perfetto JSON export of a traced run
//! (DESIGN.md §15).
//!
//! The output is the JSON-object flavor of the trace-event format that
//! `ui.perfetto.dev` and `chrome://tracing` both load: a `traceEvents`
//! array of complete (`"X"`) duration events — one per materialized phase,
//! one process (`pid`) per rank — plus metadata (`"M"`) events naming each
//! process and a counter (`"C"`) track on a dedicated pid carrying the
//! cluster's instantaneous total board power from `Timeline::power_at`.
//!
//! Rendering is deterministic: events are emitted in ascending-timestamp
//! order, objects render with sorted keys (`util::json`), and no
//! wall-clock or RNG state is consulted — the same run renders the same
//! bytes.

use crate::cluster::Topology;
use crate::plan::exec::ExecPlan;
use crate::simulator::timeline::{PhaseKind, Timeline};
use crate::trace::{emit_spans, SpanEvent, Trace, TraceSink, VecSink};
use crate::util::json::{arr, num, obj, s, Json};

/// Microseconds per second — trace-event timestamps are in µs.
const US: f64 = 1e6;

fn phase_cat(kind: PhaseKind) -> &'static str {
    match kind {
        PhaseKind::Compute => "compute",
        PhaseKind::Transfer => "transfer",
        PhaseKind::Wait => "wait",
        PhaseKind::Idle => "idle",
    }
}

fn span_event(ev: &SpanEvent) -> Json {
    let mut args = vec![
        ("energy_j", num(ev.energy_j)),
        ("power_w", num(ev.power_w)),
        ("step", num(ev.step as f64)),
        ("layer", num(ev.layer as f64)),
    ];
    if let Some(op) = ev.op {
        args.push(("op", num(op as f64)));
    }
    if ev.bytes > 0.0 {
        args.push(("bytes", num(ev.bytes)));
        args.push(("link", s(ev.link_tier)));
    }
    obj(vec![
        ("ph", s("X")),
        ("name", s(ev.module.name())),
        ("cat", s(phase_cat(ev.kind))),
        ("pid", num(ev.rank as f64)),
        ("tid", num(0.0)),
        ("ts", num(ev.t0 * US)),
        ("dur", num((ev.t1 - ev.t0) * US)),
        ("args", obj(args)),
    ])
}

/// Render a traced run as trace-event JSON (the object form with a
/// `traceEvents` array), loadable in `ui.perfetto.dev`. One pid per rank;
/// pid `num_gpus` carries the total-power counter track.
pub fn perfetto_json(tl: &Timeline, trace: &Trace, plan: Option<&ExecPlan>, topo: Option<&Topology>) -> String {
    let mut sink = VecSink::default();
    emit_spans(tl, trace, plan, topo, &mut sink);

    let mut events: Vec<(f64, Json)> = Vec::with_capacity(sink.events.len() + 2 * tl.num_gpus + 8);
    for rank in 0..tl.num_gpus {
        events.push((
            -1.0,
            obj(vec![
                ("ph", s("M")),
                ("name", s("process_name")),
                ("pid", num(rank as f64)),
                ("tid", num(0.0)),
                ("args", obj(vec![("name", s(&format!("rank {rank}")))])),
            ]),
        ));
        events.push((
            -1.0,
            obj(vec![
                ("ph", s("M")),
                ("name", s("process_sort_index")),
                ("pid", num(rank as f64)),
                ("tid", num(0.0)),
                ("args", obj(vec![("sort_index", num(rank as f64))])),
            ]),
        ));
    }
    let power_pid = tl.num_gpus;
    events.push((
        -1.0,
        obj(vec![
            ("ph", s("M")),
            ("name", s("process_name")),
            ("pid", num(power_pid as f64)),
            ("tid", num(0.0)),
            ("args", obj(vec![("name", s("cluster power"))])),
        ]),
    ));

    for ev in &sink.events {
        events.push((ev.t0 * US, span_event(ev)));
    }

    // Counter track: total board power sampled just after every phase
    // boundary (phase powers are piecewise-constant, so boundaries are the
    // only change points; the epsilon keeps the sample inside the new
    // segment). Boundaries are deduplicated on their rendered µs value so
    // the track is strictly monotone.
    let mut cuts: Vec<f64> = tl.phases.iter().flat_map(|p| [p.t0, p.t1]).collect();
    cuts.push(0.0);
    cuts.sort_by(f64::total_cmp);
    cuts.dedup();
    let eps = tl.makespan().max(1e-9) * 1e-12;
    let mut last_us = f64::NEG_INFINITY;
    for &t in &cuts {
        if t >= tl.makespan() {
            continue;
        }
        let ts = t * US;
        if ts <= last_us {
            continue;
        }
        last_us = ts;
        events.push((
            ts,
            obj(vec![
                ("ph", s("C")),
                ("name", s("total_power_w")),
                ("pid", num(power_pid as f64)),
                ("tid", num(0.0)),
                ("ts", num(ts)),
                ("args", obj(vec![("power_w", num(tl.power_at(t + eps)))])),
            ]),
        ));
    }

    // Stable order: metadata first (ts -1 sorts ahead), then ascending ts;
    // ties keep insertion order (rank spans before counter samples).
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    let list = events.into_iter().map(|(_, e)| e).collect();
    obj(vec![("traceEvents", arr(list)), ("displayTimeUnit", s("ms"))]).render()
}

/// Compact per-phase CSV of a traced run: one row per span, in timeline
/// order, with an `on_path` flag from a critical-path pass.
pub fn spans_csv(tl: &Timeline, trace: &Trace, plan: Option<&ExecPlan>, topo: Option<&Topology>, on_path: &[bool]) -> String {
    struct Csv<'a> {
        out: String,
        on_path: &'a [bool],
        i: usize,
    }
    impl TraceSink for Csv<'_> {
        fn span(&mut self, ev: &SpanEvent) {
            let on = self.on_path.get(self.i).copied().unwrap_or(false);
            self.i += 1;
            self.out.push_str(&format!(
                "{},{},{},{},{},{:.9},{:.9},{:.3},{:.6},{:.0},{},{}\n",
                ev.rank,
                ev.step,
                ev.layer,
                ev.module.name(),
                phase_cat(ev.kind),
                ev.t0,
                ev.t1,
                ev.power_w,
                ev.energy_j,
                ev.bytes,
                ev.link_tier,
                u8::from(on),
            ));
        }
    }
    let mut sink = Csv {
        out: String::from("rank,step,layer,module,kind,t0_s,t1_s,power_w,energy_j,bytes,link,on_path\n"),
        on_path,
        i: 0,
    };
    emit_spans(tl, trace, plan, topo, &mut sink);
    sink.out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::timeline::{ModuleKind, Timeline};
    use crate::util::json::Json;

    fn traced_timeline() -> (Timeline, Trace) {
        let mut tl = Timeline::new(2, 20.0);
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 1.0, 200.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 0.5, 200.0);
        tl.wait_until(1, 1.0, ModuleKind::AllReduce, 0, 0, 95.0);
        tl.push(0, PhaseKind::Transfer, ModuleKind::AllReduce, 0, 0, 0.25, 120.0);
        tl.push(1, PhaseKind::Transfer, ModuleKind::AllReduce, 0, 0, 0.25, 120.0);
        tl.finalize();
        let n = tl.phases.len();
        let trace = Trace { ops: (0..n as u32).collect() };
        (tl, trace)
    }

    #[test]
    fn perfetto_events_are_schema_shaped_and_monotone() {
        let (tl, trace) = traced_timeline();
        let rendered = perfetto_json(&tl, &trace, None, None);
        let doc = Json::parse(&rendered).expect("render is valid json");
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        assert!(!events.is_empty());
        let mut last_ts = f64::NEG_INFINITY;
        let mut pids = std::collections::BTreeSet::new();
        for ev in events {
            let ph = ev.get("ph").and_then(|p| p.as_str()).expect("ph");
            assert!(matches!(ph, "X" | "M" | "C"), "unexpected ph {ph}");
            pids.insert(ev.get("pid").and_then(|p| p.as_usize()).expect("pid"));
            if ph == "M" {
                continue;
            }
            let ts = ev.get("ts").and_then(|t| t.as_f64()).expect("ts");
            assert!(ts >= last_ts, "timestamps must be monotone");
            last_ts = ts;
            if ph == "X" {
                assert!(ev.get("dur").and_then(|d| d.as_f64()).expect("dur") > 0.0);
                assert!(ev.get("name").is_some() && ev.get("cat").is_some());
            }
        }
        // One pid per rank plus the power-counter pid.
        assert!(pids.contains(&0) && pids.contains(&1) && pids.contains(&2));
    }

    #[test]
    fn perfetto_render_is_deterministic() {
        let (tl, trace) = traced_timeline();
        let a = perfetto_json(&tl, &trace, None, None);
        let b = perfetto_json(&tl, &trace, None, None);
        assert_eq!(a, b);
    }

    #[test]
    fn csv_rows_align_with_phases() {
        let (tl, trace) = traced_timeline();
        let on_path = vec![true; tl.phases.len()];
        let csv = spans_csv(&tl, &trace, None, None, &on_path);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), tl.phases.len() + 1, "header + one row per phase");
        assert!(lines[0].starts_with("rank,step,"));
        assert!(lines[1].ends_with(",1"), "on_path flag rendered");
    }
}
