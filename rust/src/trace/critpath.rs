//! Critical-path extraction over materialized phase timelines, and the
//! energy lower bound the tune search uses to prune candidates
//! (DESIGN.md §15).
//!
//! The engine's timeline is a program-activity graph in disguise: per-rank
//! phases are chained by clock continuity, and cross-rank edges exist
//! exactly where a synchronization wait ends — a collective's rendezvous
//! is set by its straggler's arrival, a P2P receive by its sender's
//! completion. The backward walk here recovers the makespan-defining chain
//! from those timestamps alone, with no replay of the plan:
//!
//! 1. Start at the makespan on the latest-ending *productive* phase
//!    (compute or transfer — waits and idles never bound a run).
//! 2. From the current phase's start time `t`, find the productive phase
//!    that *ends* at `t` (bitwise — resolved clocks are copied, not
//!    recomputed, so the producer's end time is exactly the consumer's
//!    start). Prefer the same rank (clock continuity), else the lowest
//!    rank; if no phase ends exactly at `t` (a jittered rendezvous
//!    arrives after every rank), fall back to the latest-ending phase
//!    before `t` — the jitter gap rides on the chain.
//! 3. Repeat until `t` reaches 0.
//!
//! Every phase lands in exactly one of three buckets — on-path, off-path
//! (slack), idle — so energy conservation against the timeline total is
//! exact, and the chain covers `[0, makespan]` by construction.

use std::collections::BTreeMap;

use crate::cluster::Topology;
use crate::plan::exec::{ExecPlan, OpKind};
use crate::simulator::power::PowerModel;
use crate::simulator::skew::SkewModel;
use crate::simulator::timeline::{ModuleKind, PhaseKind, Timeline};
use crate::trace::Trace;

/// The resource class that binds a scenario (dominates its critical path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum BoundBy {
    /// Compute phases on some rank dominate the path.
    Compute,
    /// Intra-node (or flat-link) collective transfers dominate.
    Collective,
    /// Transfers whose rank range crosses a node boundary dominate —
    /// the inter-node link is the binding resource.
    InterLink,
    /// Intra-node point-to-point stage transfers dominate.
    P2P,
}

impl BoundBy {
    pub const ALL: [BoundBy; 4] = [BoundBy::Compute, BoundBy::Collective, BoundBy::InterLink, BoundBy::P2P];

    #[inline]
    pub fn idx(&self) -> usize {
        match self {
            BoundBy::Compute => 0,
            BoundBy::Collective => 1,
            BoundBy::InterLink => 2,
            BoundBy::P2P => 3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BoundBy::Compute => "compute",
            BoundBy::Collective => "collective",
            BoundBy::InterLink => "inter-link",
            BoundBy::P2P => "p2p",
        }
    }

    pub fn parse(s: &str) -> Option<BoundBy> {
        BoundBy::ALL.into_iter().find(|b| b.name() == s)
    }
}

/// Per-decode-step slice of the critical path.
#[derive(Debug, Clone)]
pub struct StepCrit {
    pub step: u32,
    /// On-path time contributed by this step's phases, s.
    pub on_s: f64,
    /// On-path energy contributed by this step's phases, J.
    pub on_j: f64,
    /// Binding resource of this step's on-path time.
    pub bound_by: BoundBy,
}

/// Result of the critical-path pass over one timeline.
#[derive(Debug, Clone)]
pub struct CritPath {
    pub makespan_s: f64,
    /// Time covered by the backward walk — equal to the makespan whenever
    /// the walk reaches t = 0 (always, for engine-produced timelines).
    pub len_s: f64,
    /// Phase membership flags, aligned with `Timeline::phases`.
    pub on_path: Vec<bool>,
    /// Energy of on-path phases, J.
    pub on_path_j: f64,
    /// Energy of off-path productive phases and all sync waits (slack), J.
    pub off_path_j: f64,
    /// Energy of idle phases, J.
    pub idle_j: f64,
    /// On-path time per binding class, indexed by `BoundBy::idx`, s.
    pub time_by: [f64; 4],
    /// On-path energy per module, J.
    pub energy_by_module: BTreeMap<ModuleKind, f64>,
    /// On-path time per rank, s.
    pub rank_time: Vec<f64>,
    /// Per-step slices, ascending step order.
    pub steps: Vec<StepCrit>,
}

impl CritPath {
    /// The dominant binding resource (largest on-path time; ties resolve
    /// to the earlier `BoundBy::ALL` entry).
    pub fn bound_by(&self) -> BoundBy {
        let mut best = BoundBy::Compute;
        for b in BoundBy::ALL {
            if self.time_by[b.idx()] > self.time_by[best.idx()] {
                best = b;
            }
        }
        best
    }

    /// On-path share of non-idle energy, in [0, 1].
    pub fn on_path_share(&self) -> f64 {
        let active = self.on_path_j + self.off_path_j;
        if active <= 0.0 {
            0.0
        } else {
            self.on_path_j / active
        }
    }
}

/// Extract the critical path of a timeline (no op-level refinement: all
/// transfers classify by module kind alone).
pub fn critical_path(tl: &Timeline) -> CritPath {
    critical_path_with(tl, None)
}

/// Extract the critical path, refining transfer classification through the
/// execution trace: a transfer whose originating op's rank range crosses a
/// node boundary is bound by the inter-node link, not the collective.
pub fn critical_path_with(tl: &Timeline, ctx: Option<(&Trace, &ExecPlan, &Topology)>) -> CritPath {
    let phases = &tl.phases;
    let classify = |i: usize| -> BoundBy {
        let p = &phases[i];
        if p.kind == PhaseKind::Compute {
            return BoundBy::Compute;
        }
        if let Some((trace, ep, topo)) = ctx {
            if let Some(op) = trace.op_of(i) {
                let o = op as usize;
                if matches!(ep.structure.kind[o], OpKind::Collective | OpKind::Send) {
                    let r = ep.structure.ranks[o];
                    if topo.spans(r.first as usize, r.count as usize) {
                        return BoundBy::InterLink;
                    }
                }
            }
        }
        if p.module == ModuleKind::P2PTransfer {
            BoundBy::P2P
        } else {
            BoundBy::Collective
        }
    };

    // Productive phases sorted by (end time, rank, index): the walk's
    // exact-match and latest-before queries are binary searches over this.
    let mut prod: Vec<u32> = (0..phases.len() as u32)
        .filter(|&i| matches!(phases[i as usize].kind, PhaseKind::Compute | PhaseKind::Transfer))
        .collect();
    prod.sort_unstable_by(|&a, &b| {
        let (pa, pb) = (&phases[a as usize], &phases[b as usize]);
        pa.t1.total_cmp(&pb.t1).then(pa.gpu.cmp(&pb.gpu)).then(a.cmp(&b))
    });

    let makespan = tl.makespan();
    let mut on = vec![false; phases.len()];
    let mut t = makespan;
    let mut cur_rank = u16::MAX;
    while t > 0.0 {
        // Candidates ending exactly at t: [lo, hi).
        let lo = prod.partition_point(|&i| phases[i as usize].t1 < t);
        let hi = prod.partition_point(|&i| phases[i as usize].t1 <= t);
        let pick = if lo < hi {
            prod[lo..hi]
                .iter()
                .copied()
                .find(|&i| phases[i as usize].gpu == cur_rank)
                .unwrap_or(prod[lo])
        } else if lo > 0 {
            // Jittered rendezvous: nothing ends bitwise at t — chain to
            // the latest producer before t (same tie-break as above).
            let t1 = phases[prod[lo - 1] as usize].t1;
            let lo2 = prod[..lo].partition_point(|&i| phases[i as usize].t1 < t1);
            prod[lo2..lo]
                .iter()
                .copied()
                .find(|&i| phases[i as usize].gpu == cur_rank)
                .unwrap_or(prod[lo2])
        } else {
            break; // nothing productive before t: the head is idle/wait
        };
        let p = &phases[pick as usize];
        on[pick as usize] = true;
        cur_rank = p.gpu;
        t = p.t0;
    }
    let len_s = makespan - t.max(0.0);

    let (mut on_j, mut off_j, mut idle_j) = (0.0f64, 0.0f64, 0.0f64);
    let mut time_by = [0.0f64; 4];
    let mut energy_by_module: BTreeMap<ModuleKind, f64> = BTreeMap::new();
    let mut rank_time = vec![0.0f64; tl.num_gpus];
    let mut per_step: BTreeMap<u32, (f64, f64, [f64; 4])> = BTreeMap::new();
    for (i, p) in phases.iter().enumerate() {
        if p.kind == PhaseKind::Idle {
            idle_j += p.energy_j();
        } else if on[i] {
            let e = p.energy_j();
            on_j += e;
            let class = classify(i);
            time_by[class.idx()] += p.dur();
            *energy_by_module.entry(p.module).or_insert(0.0) += e;
            rank_time[p.gpu as usize] += p.dur();
            let s = per_step.entry(p.step).or_insert((0.0, 0.0, [0.0; 4]));
            s.0 += p.dur();
            s.1 += e;
            s.2[class.idx()] += p.dur();
        } else {
            off_j += p.energy_j();
        }
    }
    let steps = per_step
        .into_iter()
        .map(|(step, (on_s, on_j, by))| {
            let mut bound_by = BoundBy::Compute;
            for b in BoundBy::ALL {
                if by[b.idx()] > by[bound_by.idx()] {
                    bound_by = b;
                }
            }
            StepCrit {
                step,
                on_s,
                on_j,
                bound_by,
            }
        })
        .collect();

    CritPath {
        makespan_s: makespan,
        len_s,
        on_path: on,
        on_path_j: on_j,
        off_path_j: off_j,
        idle_j,
        time_by,
        energy_by_module,
        rank_time,
        steps,
    }
}

/// Deterministic lower bound on one run's wall time and GPU-side energy,
/// resolved from the compiled plan under the run's *actual* drawn
/// conditions (skew state, power model) with every remaining stochastic
/// term replaced by its floor:
///
/// * per-op transient compute factor — the unit-mean lognormal's
///   9σ lower quantile `exp(−σ²/2 − 9σ)` (a per-draw violation
///   probability of ~1e-19; stragglers only slow ranks further);
/// * launch-desync jitter, rendezvous waits, interference, background
///   draw — all ≥ 0, dropped;
/// * transfer durations — exact (deterministic scalars).
///
/// The clock recursion is monotone in op durations (max/+ structure), so
/// the resolved makespan, prefill end, and per-phase energies are sound
/// floors of the engine's. `decode_scale` extrapolates decode-step
/// (step > 0) op energies exactly as `finish_record` does.
#[derive(Debug, Clone, Copy)]
pub struct FloorBound {
    /// Lower bound on the simulated-window makespan, s.
    pub makespan_s: f64,
    /// Lower bound on the prefill-end clock, s.
    pub prefill_end_s: f64,
    /// Lower bound on GPU-side compute + transfer energy with decode
    /// extrapolation applied, J.
    pub gpu_j: f64,
}

/// Resolve the floor bound of a compiled plan (see [`FloorBound`]).
pub fn floor_resolve(ep: &ExecPlan, power: &PowerModel, skew: &SkewModel, decode_scale: f64) -> FloorBound {
    let s = &*ep.structure;
    let sc = &*ep.scalars;
    let sigma = (1.0 + skew.compute_cv * skew.compute_cv).ln().sqrt();
    let gamma = (-sigma * sigma / 2.0 - 9.0 * sigma).exp();
    let mut clocks = vec![0.0f64; s.num_ranks];
    let mut edges = vec![0.0f64; s.num_edges as usize];
    let mut gpu_j = 0.0f64;
    let mut prefill_end = 0.0f64;
    for i in 0..s.len() {
        let ranks = s.ranks[i];
        let scale = if s.step[i] == 0 { 1.0 } else { decode_scale };
        match s.kind[i] {
            OpKind::Compute => {
                let floor_mult = skew.module_mult(s.module[i]) * gamma;
                for rank in ranks.iter() {
                    let d = sc.dur_s[i] * floor_mult * skew.rank_bias(rank);
                    clocks[rank] += d;
                    gpu_j += d * power.gpu_power_rank(PhaseKind::Compute, sc.aux[i], rank) * scale;
                }
            }
            OpKind::Collective => {
                let mut arrive = 0.0f64;
                for rank in ranks.iter() {
                    arrive = arrive.max(clocks[rank]);
                }
                let transfer_s = sc.dur_s[i];
                for rank in ranks.iter() {
                    clocks[rank] = arrive + transfer_s;
                    gpu_j += transfer_s * power.gpu_power_rank(PhaseKind::Transfer, 0.0, rank) * scale;
                }
            }
            OpKind::Send => {
                let transfer_s = sc.dur_s[i];
                let mut done = 0.0f64;
                for rank in ranks.iter() {
                    clocks[rank] += transfer_s;
                    done = done.max(clocks[rank]);
                    gpu_j += transfer_s * power.gpu_power_rank(PhaseKind::Transfer, 0.0, rank) * scale;
                }
                edges[s.edge[i] as usize] = done;
            }
            OpKind::Recv => {
                let ready = edges[s.edge[i] as usize];
                for rank in ranks.iter() {
                    clocks[rank] = clocks[rank].max(ready);
                }
            }
        }
        if s.step[i] == 0 {
            for rank in ranks.iter() {
                prefill_end = prefill_end.max(clocks[rank]);
            }
        }
    }
    let makespan_s = clocks.iter().copied().fold(0.0, f64::max);
    FloorBound {
        makespan_s,
        prefill_end_s: prefill_end.min(makespan_s),
        gpu_j,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::timeline::{ModuleKind, PhaseKind, Timeline};

    /// A hand-built two-rank run: rank 0 computes 2s, rank 1 computes 1s
    /// then waits 1s, both transfer 0.5s, then rank 1 computes 1s while
    /// rank 0 idles.
    fn two_rank_timeline() -> Timeline {
        let mut tl = Timeline::new(2, 20.0);
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 2.0, 200.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 1.0, 200.0);
        tl.wait_until(1, 2.0, ModuleKind::AllReduce, 0, 0, 95.0);
        tl.push(0, PhaseKind::Transfer, ModuleKind::AllReduce, 0, 0, 0.5, 120.0);
        tl.push(1, PhaseKind::Transfer, ModuleKind::AllReduce, 0, 0, 0.5, 120.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::LogitsHead, 0, 1, 1.0, 250.0);
        tl.finalize();
        tl
    }

    #[test]
    fn walk_recovers_the_straggler_chain() {
        let tl = two_rank_timeline();
        let cp = critical_path(&tl);
        assert!((cp.makespan_s - 3.5).abs() < 1e-12);
        assert!((cp.len_s - cp.makespan_s).abs() < 1e-12, "walk reaches t = 0");
        // Path: rank1 logits [2.5,3.5] <- a transfer ending at 2.5 (same
        // rank preferred) <- rank0 compute [0,2] (the straggler).
        // Rank 1's 1s compute and wait are slack.
        let marked: Vec<usize> = cp.on_path.iter().enumerate().filter(|(_, &m)| m).map(|(i, _)| i).collect();
        assert_eq!(marked.len(), 3);
        let kinds: Vec<PhaseKind> = marked.iter().map(|&i| tl.phases[i].kind).collect();
        assert_eq!(kinds, vec![PhaseKind::Compute, PhaseKind::Transfer, PhaseKind::Compute]);
        // On-path: 200*2 + 120*0.5 + 250*1 = 710. Slack: rank1 compute 200
        // + wait 95 + rank1 transfer 60. Idle: rank0 tail 1.0s * 20.
        assert!((cp.on_path_j - 710.0).abs() < 1e-9);
        assert!((cp.off_path_j - 355.0).abs() < 1e-9);
        assert!((cp.idle_j - 20.0).abs() < 1e-9);
        let total = tl.gpu_energy_j();
        assert!((cp.on_path_j + cp.off_path_j + cp.idle_j - total).abs() < 1e-9 * total);
        assert_eq!(cp.bound_by(), BoundBy::Compute);
        assert!(cp.on_path_share() > 0.5);
        // Per-step slices: step 0 carries 2.5s, step 1 carries 1.0s.
        assert_eq!(cp.steps.len(), 2);
        assert!((cp.steps[0].on_s - 2.5).abs() < 1e-12);
        assert!((cp.steps[1].on_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn transfer_dominated_path_binds_on_the_collective() {
        let mut tl = Timeline::new(2, 20.0);
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 0.2, 200.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 0.2, 200.0);
        tl.push(0, PhaseKind::Transfer, ModuleKind::AllReduce, 0, 0, 3.0, 120.0);
        tl.push(1, PhaseKind::Transfer, ModuleKind::AllReduce, 0, 0, 3.0, 120.0);
        tl.finalize();
        let cp = critical_path(&tl);
        assert_eq!(cp.bound_by(), BoundBy::Collective);
        assert!((cp.len_s - 3.2).abs() < 1e-12);
    }

    #[test]
    fn bound_by_round_trips_names() {
        for b in BoundBy::ALL {
            assert_eq!(BoundBy::parse(b.name()), Some(b));
        }
        assert_eq!(BoundBy::parse("tpu"), None);
    }

    #[test]
    fn empty_timeline_is_degenerate_but_finite() {
        let tl = Timeline::new(2, 20.0);
        let cp = critical_path(&tl);
        assert_eq!(cp.makespan_s, 0.0);
        assert_eq!(cp.len_s, 0.0);
        assert_eq!(cp.on_path_j, 0.0);
        assert_eq!(cp.on_path_share(), 0.0);
    }
}
