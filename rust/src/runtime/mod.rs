//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! This is the request-path bridge of the three-layer architecture: the
//! Python side (`make artifacts`) lowered the JAX module forwards (which
//! call the Pallas kernels) to HLO *text*; here we parse the text with the
//! `xla` crate, compile once per module on the PJRT CPU client, and execute
//! with concrete buffers. Python never runs after artifacts exist.
//!
//! Two consumers:
//! * the functional-forward path (`execute`): the end-to-end example runs
//!   real transformer-module forwards whose tensors correspond to the
//!   modules the profiler measures;
//! * the prediction hot path (`predict_batch`): PIE-P's fitted leaf
//!   regressors are flattened to a weight vector and evaluated for 256
//!   module instances per PJRT call via the `ridge_predict` executable.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Shape/ABI info for one AOT module.
#[derive(Debug, Clone)]
pub struct ModuleInfo {
    pub name: String,
    pub inputs: Vec<Vec<usize>>,
    pub output: Vec<usize>,
    pub hlo_path: String,
}

/// A compiled module executable.
pub struct Compiled {
    pub info: ModuleInfo,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: client + all compiled module executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub modules: BTreeMap<String, Compiled>,
    pub feature_dim: usize,
    pub predict_batch: usize,
}

fn parse_manifest(dir: &Path) -> Result<(Vec<ModuleInfo>, usize, usize)> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let feature_dim = j
        .get("feature_dim")
        .and_then(Json::as_usize)
        .context("feature_dim")?;
    let predict_batch = j
        .get("predict_batch")
        .and_then(Json::as_usize)
        .context("predict_batch")?;
    let modules = j.get("modules").and_then(Json::as_obj).context("modules")?;
    let mut out = Vec::new();
    for (name, m) in modules {
        let inputs = m
            .get("inputs")
            .and_then(Json::as_arr)
            .context("inputs")?
            .iter()
            .map(|shape| {
                shape
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect()
            })
            .collect();
        let output = m
            .get("output")
            .and_then(Json::as_arr)
            .context("output")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let hlo = m.get("hlo").and_then(Json::as_str).context("hlo")?;
        out.push(ModuleInfo {
            name: name.clone(),
            inputs,
            output,
            hlo_path: dir.join(hlo).to_string_lossy().into_owned(),
        });
    }
    Ok((out, feature_dim, predict_batch))
}

impl Runtime {
    /// Load every artifact in `dir` and compile it on the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let (infos, feature_dim, predict_batch) = parse_manifest(dir)?;
        if feature_dim != crate::features::FEATURE_DIM {
            bail!(
                "artifact ABI mismatch: manifest feature_dim {feature_dim} != crate {}",
                crate::features::FEATURE_DIM
            );
        }
        let client = xla::PjRtClient::cpu()?;
        let mut modules = BTreeMap::new();
        for info in infos {
            let proto = xla::HloModuleProto::from_text_file(&info.hlo_path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            modules.insert(info.name.clone(), Compiled { info, exe });
        }
        Ok(Runtime {
            client,
            modules,
            feature_dim,
            predict_batch,
        })
    }

    pub fn module(&self, name: &str) -> Result<&Compiled> {
        self.modules
            .get(name)
            .ok_or_else(|| anyhow!("no AOT module named {name}"))
    }

    /// Execute a module with f32 input buffers (row-major, shapes per the
    /// manifest). Returns the flattened f32 output.
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let c = self.module(name)?;
        if inputs.len() != c.info.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                c.info.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&c.info.inputs) {
            let n: usize = shape.iter().product();
            if buf.len() != n {
                bail!("{name}: input length {} != shape {:?}", buf.len(), shape);
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = c.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Random (seeded) f32 inputs matching a module's signature — used by
    /// the examples/benches to exercise the functional path.
    pub fn random_inputs(&self, name: &str, seed: u64, scale: f32) -> Result<Vec<Vec<f32>>> {
        let c = self.module(name)?;
        let mut rng = Rng::new(seed);
        Ok(c.info
            .inputs
            .iter()
            .map(|shape| rng.f32_vec(shape.iter().product(), scale))
            .collect())
    }

    /// Batched ridge prediction on the PJRT path: evaluates `w·x + b` for
    /// up to `predict_batch` feature rows per call (rows padded with
    /// zeros). Returns one raw prediction per input row.
    pub fn predict_batch(&self, features: &[Vec<f64>], w: &[f64], b: f64) -> Result<Vec<f64>> {
        if w.len() != self.feature_dim {
            bail!("weight length {} != feature_dim {}", w.len(), self.feature_dim);
        }
        let mut out = Vec::with_capacity(features.len());
        let wf: Vec<f32> = w.iter().map(|&x| x as f32).collect();
        for chunk in features.chunks(self.predict_batch) {
            let mut x = vec![0.0f32; self.predict_batch * self.feature_dim];
            for (i, row) in chunk.iter().enumerate() {
                if row.len() != self.feature_dim {
                    bail!("feature row length {} != {}", row.len(), self.feature_dim);
                }
                for (j, &v) in row.iter().enumerate() {
                    x[i * self.feature_dim + j] = v as f32;
                }
            }
            let y = self.execute("ridge_predict", &[x, wf.clone(), vec![b as f32]])?;
            out.extend(y[..chunk.len()].iter().map(|&v| v as f64));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (infos, fd, pb) = parse_manifest(&dir).unwrap();
        assert_eq!(fd, crate::features::FEATURE_DIM);
        assert_eq!(pb, 256);
        let names: Vec<&str> = infos.iter().map(|i| i.name.as_str()).collect();
        for want in ["self_attention", "mlp", "rmsnorm", "logits_head", "block", "ridge_predict"] {
            assert!(names.contains(&want), "{want}");
        }
    }

    #[test]
    fn runtime_loads_and_executes_all_modules() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        for name in ["rmsnorm", "mlp", "self_attention", "block", "logits_head"] {
            let inputs = rt.random_inputs(name, 7, 0.05).unwrap();
            let out = rt.execute(name, &inputs).unwrap();
            let expect: usize = rt.module(name).unwrap().info.output.iter().product();
            assert_eq!(out.len(), expect, "{name}");
            assert!(out.iter().all(|v| v.is_finite()), "{name} finite");
        }
    }

    #[test]
    fn rmsnorm_numerics_match_reference() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        let info = rt.module("rmsnorm").unwrap().info.clone();
        let (b, s, d) = (info.inputs[0][0], info.inputs[0][1], info.inputs[0][2]);
        let mut rng = Rng::new(3);
        let x = rng.f32_vec(b * s * d, 1.0);
        let gain = vec![1.0f32; d];
        let out = rt.execute("rmsnorm", &[x.clone(), gain]).unwrap();
        // Row-wise RMS of the output must be ≈ 1 for unit gain.
        for row in 0..b * s {
            let xs = &out[row * d..(row + 1) * d];
            let rms = (xs.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / d as f64).sqrt();
            assert!((rms - 1.0).abs() < 1e-2, "row {row}: rms={rms}");
        }
    }

    #[test]
    fn predict_batch_matches_cpu_math() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> = (0..300)
            .map(|_| (0..rt.feature_dim).map(|_| rng.range(-1.0, 1.0)).collect())
            .collect();
        let w: Vec<f64> = (0..rt.feature_dim).map(|_| rng.range(-0.5, 0.5)).collect();
        let b = 0.25;
        let got = rt.predict_batch(&rows, &w, b).unwrap();
        assert_eq!(got.len(), 300);
        for (row, &g) in rows.iter().zip(&got) {
            let want: f64 = b + row.iter().zip(&w).map(|(x, wi)| x * wi).sum::<f64>();
            assert!((g - want).abs() < 1e-4, "{g} vs {want}");
        }
    }
}
