//! AOT-artifact runtime: manifest loading, ABI validation, and the
//! prediction hot path.
//!
//! The three-layer architecture lowers the JAX module forwards (which call
//! the Pallas kernels) to HLO text via `make artifacts`; this module is the
//! Rust-side consumer. The offline image carries neither the `xla` crate
//! nor a PJRT plugin, so the runtime is split into two tiers:
//!
//! * **Always available** — parse `artifacts/manifest.json`, validate the
//!   feature-dimension ABI against `features::FEATURE_DIM`, check the HLO
//!   files exist, validate input shapes, and serve `predict_batch` (the
//!   PIE-P leaf-regressor hot path, `y = w·x + b` over padded row chunks)
//!   with a native implementation that is bit-compatible with the lowered
//!   `ridge_predict` executable (both accumulate in f32).
//! * **PJRT-gated** — `execute` (functional transformer-module forwards)
//!   needs a real PJRT client; without one it returns a structured
//!   `RtError` after shape validation, keeping the API seam so a
//!   PJRT-enabled build only has to swap the backend.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Runtime error (the offline stand-in for `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct RtError(pub String);

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RtError {}

pub type Result<T> = std::result::Result<T, RtError>;

fn err(msg: impl Into<String>) -> RtError {
    RtError(msg.into())
}

/// Shape/ABI info for one AOT module.
#[derive(Debug, Clone)]
pub struct ModuleInfo {
    pub name: String,
    pub inputs: Vec<Vec<usize>>,
    pub output: Vec<usize>,
    pub hlo_path: String,
}

/// A validated module (plus, in a PJRT-enabled build, its executable).
#[derive(Debug, Clone)]
pub struct Compiled {
    pub info: ModuleInfo,
}

/// The artifact runtime: validated module table + ABI constants.
#[derive(Debug, Clone)]
pub struct Runtime {
    pub modules: BTreeMap<String, Compiled>,
    pub feature_dim: usize,
    pub predict_batch: usize,
}

fn parse_manifest(dir: &Path) -> Result<(Vec<ModuleInfo>, usize, usize)> {
    let manifest = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest)
        .map_err(|e| err(format!("reading {} (run `make artifacts`): {e}", manifest.display())))?;
    let j = Json::parse(&text).map_err(|e| err(format!("manifest parse: {e}")))?;
    let feature_dim = j
        .get("feature_dim")
        .and_then(Json::as_usize)
        .ok_or_else(|| err("manifest missing feature_dim"))?;
    let predict_batch = j
        .get("predict_batch")
        .and_then(Json::as_usize)
        .ok_or_else(|| err("manifest missing predict_batch"))?;
    let modules = j
        .get("modules")
        .and_then(Json::as_obj)
        .ok_or_else(|| err("manifest missing modules"))?;
    let mut out = Vec::new();
    for (name, m) in modules {
        let inputs = m
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| err(format!("{name}: missing inputs")))?
            .iter()
            .map(|shape| {
                shape
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect()
            })
            .collect();
        let output = m
            .get("output")
            .and_then(Json::as_arr)
            .ok_or_else(|| err(format!("{name}: missing output")))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let hlo = m
            .get("hlo")
            .and_then(Json::as_str)
            .ok_or_else(|| err(format!("{name}: missing hlo")))?;
        out.push(ModuleInfo {
            name: name.clone(),
            inputs,
            output,
            hlo_path: dir.join(hlo).to_string_lossy().into_owned(),
        });
    }
    Ok((out, feature_dim, predict_batch))
}

impl Runtime {
    /// Load and validate every artifact in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let (infos, feature_dim, predict_batch) = parse_manifest(dir)?;
        if feature_dim != crate::features::FEATURE_DIM {
            return Err(err(format!(
                "artifact ABI mismatch: manifest feature_dim {feature_dim} != crate {}",
                crate::features::FEATURE_DIM
            )));
        }
        let mut modules = BTreeMap::new();
        for info in infos {
            if !Path::new(&info.hlo_path).exists() {
                return Err(err(format!("{}: missing HLO file {}", info.name, info.hlo_path)));
            }
            modules.insert(info.name.clone(), Compiled { info });
        }
        Ok(Runtime {
            modules,
            feature_dim,
            predict_batch,
        })
    }

    /// An artifact-free runtime: no AOT modules, just the native prediction
    /// hot path with the given ABI constants. This is the constructor for
    /// environments without `make artifacts` (CI, examples) — `execute`
    /// reports the missing module, `predict_batch` works.
    pub fn offline(feature_dim: usize, predict_batch: usize) -> Runtime {
        Runtime {
            modules: BTreeMap::new(),
            feature_dim,
            predict_batch,
        }
    }

    /// Backend description (mirrors the PJRT client's platform name).
    pub fn platform_name(&self) -> &'static str {
        "cpu-native (PJRT backend unavailable in this build)"
    }

    pub fn module(&self, name: &str) -> Result<&Compiled> {
        self.modules
            .get(name)
            .ok_or_else(|| err(format!("no AOT module named {name}")))
    }

    /// Functional module forward. Validates the input signature against the
    /// manifest, then requires a PJRT backend — absent one, returns a
    /// structured error (the offline build cannot interpret HLO text).
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let c = self.module(name)?;
        if inputs.len() != c.info.inputs.len() {
            return Err(err(format!(
                "{name}: expected {} inputs, got {}",
                c.info.inputs.len(),
                inputs.len()
            )));
        }
        for (buf, shape) in inputs.iter().zip(&c.info.inputs) {
            let n: usize = shape.iter().product();
            if buf.len() != n {
                return Err(err(format!(
                    "{name}: input length {} != shape {:?}",
                    buf.len(),
                    shape
                )));
            }
        }
        Err(err(format!(
            "{name}: functional forwards need a PJRT backend (xla crate), which the offline build omits"
        )))
    }

    /// Random (seeded) f32 inputs matching a module's signature — used by
    /// the examples/benches to exercise the functional path.
    pub fn random_inputs(&self, name: &str, seed: u64, scale: f32) -> Result<Vec<Vec<f32>>> {
        let c = self.module(name)?;
        let mut rng = Rng::new(seed);
        Ok(c.info
            .inputs
            .iter()
            .map(|shape| rng.f32_vec(shape.iter().product(), scale))
            .collect())
    }

    /// Batched ridge prediction: evaluates `w·x + b` for feature rows in
    /// `predict_batch`-sized chunks (rows padded with zeros), exactly the
    /// shape the lowered `ridge_predict` executable computes. Accumulates
    /// in f32 to stay bit-compatible with the AOT path.
    pub fn predict_batch(&self, features: &[Vec<f64>], w: &[f64], b: f64) -> Result<Vec<f64>> {
        if w.len() != self.feature_dim {
            return Err(err(format!(
                "weight length {} != feature_dim {}",
                w.len(),
                self.feature_dim
            )));
        }
        let wf: Vec<f32> = w.iter().map(|&x| x as f32).collect();
        let mut out = Vec::with_capacity(features.len());
        for chunk in features.chunks(self.predict_batch.max(1)) {
            for row in chunk {
                if row.len() != self.feature_dim {
                    return Err(err(format!(
                        "feature row length {} != {}",
                        row.len(),
                        self.feature_dim
                    )));
                }
                let mut acc = b as f32;
                for (&x, &wi) in row.iter().zip(&wf) {
                    acc += x as f32 * wi;
                }
                out.push(acc as f64);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    /// A runtime with no artifacts on disk — the ABI constants alone drive
    /// the native prediction hot path.
    fn bare_runtime() -> Runtime {
        let mut modules = BTreeMap::new();
        modules.insert(
            "rmsnorm".to_string(),
            Compiled {
                info: ModuleInfo {
                    name: "rmsnorm".into(),
                    inputs: vec![vec![2, 4, 8], vec![8]],
                    output: vec![2, 4, 8],
                    hlo_path: "unused".into(),
                },
            },
        );
        Runtime {
            modules,
            feature_dim: crate::features::FEATURE_DIM,
            predict_batch: 256,
        }
    }

    #[test]
    fn manifest_parses_when_artifacts_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (infos, fd, pb) = parse_manifest(&dir).unwrap();
        assert_eq!(fd, crate::features::FEATURE_DIM);
        assert_eq!(pb, 256);
        let names: Vec<&str> = infos.iter().map(|i| i.name.as_str()).collect();
        for want in ["self_attention", "mlp", "rmsnorm", "logits_head", "block", "ridge_predict"] {
            assert!(names.contains(&want), "{want}");
        }
    }

    #[test]
    fn load_errors_cleanly_without_artifacts() {
        let e = Runtime::load("definitely/not/a/dir").unwrap_err();
        assert!(e.0.contains("manifest"), "{e}");
    }

    #[test]
    fn predict_batch_matches_f64_math_closely() {
        let rt = bare_runtime();
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> = (0..300)
            .map(|_| (0..rt.feature_dim).map(|_| rng.range(-1.0, 1.0)).collect())
            .collect();
        let w: Vec<f64> = (0..rt.feature_dim).map(|_| rng.range(-0.5, 0.5)).collect();
        let b = 0.25;
        let got = rt.predict_batch(&rows, &w, b).unwrap();
        assert_eq!(got.len(), 300);
        for (row, &g) in rows.iter().zip(&got) {
            let want: f64 = b + row.iter().zip(&w).map(|(x, wi)| x * wi).sum::<f64>();
            assert!((g - want).abs() < 1e-4, "{g} vs {want}");
        }
    }

    #[test]
    fn predict_batch_validates_shapes() {
        let rt = bare_runtime();
        assert!(rt.predict_batch(&[], &[0.0; 3], 0.0).is_err());
        let bad_row = vec![vec![0.0; 3]];
        assert!(rt
            .predict_batch(&bad_row, &vec![0.0; rt.feature_dim], 0.0)
            .is_err());
    }

    #[test]
    fn execute_validates_then_reports_missing_backend() {
        let rt = bare_runtime();
        // Unknown module.
        assert!(rt.execute("nonexistent", &[]).is_err());
        // Wrong input count.
        assert!(rt.execute("rmsnorm", &[vec![0.0; 64]]).is_err());
        // Wrong input length.
        let e = rt.execute("rmsnorm", &[vec![0.0; 3], vec![0.0; 8]]).unwrap_err();
        assert!(e.0.contains("input length"), "{e}");
        // Valid shapes: structured missing-backend error, not a panic.
        let inputs = rt.random_inputs("rmsnorm", 1, 0.1).unwrap();
        let e = rt.execute("rmsnorm", &inputs).unwrap_err();
        assert!(e.0.contains("PJRT"), "{e}");
    }

    #[test]
    fn random_inputs_match_signature() {
        let rt = bare_runtime();
        let inputs = rt.random_inputs("rmsnorm", 3, 0.05).unwrap();
        assert_eq!(inputs.len(), 2);
        assert_eq!(inputs[0].len(), 2 * 4 * 8);
        assert_eq!(inputs[1].len(), 8);
        assert!(inputs[0].iter().all(|v| v.abs() <= 0.05));
    }
}
