//! Deterministic per-module performance model (roofline style).
//!
//! Decode is memory-bandwidth-bound (weights + KV cache streamed per
//! token); prefill is compute-bound. Time per module = max(bytes/BW,
//! FLOPs/peak) + kernel-launch overhead; `util` is the arithmetic
//! utilization used by the power model. Shards are TP degree `g`
//! (g = 1 for pipeline stages and data-parallel replicas).

use crate::config::HwSpec;
use crate::models::{MlpKind, ModelSpec};

/// Per-kernel launch/dispatch overhead, s.
pub const KERNEL_OVERHEAD_S: f64 = 8.0e-6;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModuleTiming {
    pub dur_s: f64,
    /// Arithmetic utilization in [0,1] for the power model.
    pub util: f64,
}

fn timing(mem_s: f64, flop_s: f64) -> ModuleTiming {
    let dur = mem_s.max(flop_s) + KERNEL_OVERHEAD_S;
    // Memory-bound kernels sit near half power; compute-bound near TDP.
    let balance = if mem_s > 0.0 {
        (flop_s / mem_s).min(1.0)
    } else {
        1.0
    };
    ModuleTiming {
        dur_s: dur,
        util: 0.50 + 0.42 * balance,
    }
}

#[derive(Debug, Clone)]
pub struct PerfModel {
    pub hw: HwSpec,
}

impl PerfModel {
    pub fn new(hw: &HwSpec) -> Self {
        PerfModel { hw: hw.clone() }
    }

    fn bw(&self) -> f64 {
        self.hw.gpu_mem_bw * self.hw.gpu_mem_eff
    }

    fn peak(&self) -> f64 {
        self.hw.gpu_peak_flops * self.hw.gpu_mfu
    }

    /// Self-attention decode step: stream this rank's attention weights +
    /// KV cache, batch tokens of compute at the given context length.
    pub fn attn_decode(
        &self,
        spec: &ModelSpec,
        batch: usize,
        context: usize,
        g: usize,
    ) -> ModuleTiming {
        let h = spec.hidden as f64;
        let dh = spec.head_dim() as f64;
        let w_bytes = (h * (spec.heads as f64 * dh)
            + 2.0 * h * (spec.kv_heads as f64 * dh)
            + (spec.heads as f64 * dh) * h)
            * spec.dtype_bytes as f64
            / g as f64;
        let kv_bytes = batch as f64
            * context as f64
            * 2.0
            * (spec.kv_heads as f64 / g as f64).max(1.0)
            * dh
            * spec.dtype_bytes as f64;
        let flops = batch as f64
            * crate::models::ModuleFlops::per_token(spec, context).attention
            / g as f64;
        timing((w_bytes + kv_bytes) / self.bw(), flops / self.peak())
    }

    /// MLP decode step.
    pub fn mlp_decode(&self, spec: &ModelSpec, batch: usize, g: usize) -> ModuleTiming {
        let h = spec.hidden as f64;
        let mats = match spec.mlp {
            MlpKind::Gelu => 2.0,
            MlpKind::SwiGlu => 3.0,
        };
        let w_bytes = mats * h * spec.ffn as f64 * spec.dtype_bytes as f64 / g as f64;
        let flops = batch as f64 * 2.0 * mats * h * spec.ffn as f64 / g as f64;
        timing(w_bytes / self.bw(), flops / self.peak())
    }

    /// RMSNorm/LayerNorm decode step (activation-bound, tiny).
    pub fn norm_decode(&self, spec: &ModelSpec, batch: usize) -> ModuleTiming {
        let bytes = 3.0 * batch as f64 * spec.hidden as f64 * spec.dtype_bytes as f64;
        let flops = 4.0 * batch as f64 * spec.hidden as f64;
        timing(bytes / self.bw(), flops / self.peak())
    }

    /// Embedding lookup per decode step.
    pub fn embed_decode(&self, spec: &ModelSpec, batch: usize) -> ModuleTiming {
        let bytes = 2.0 * batch as f64 * spec.hidden as f64 * spec.dtype_bytes as f64;
        timing(bytes / self.bw(), 0.0)
    }

    /// Logits head per decode step (vocab projection, sharded by g).
    pub fn logits_decode(&self, spec: &ModelSpec, batch: usize, g: usize) -> ModuleTiming {
        let w_bytes = spec.hidden as f64 * spec.vocab as f64 * spec.dtype_bytes as f64 / g as f64;
        let flops = batch as f64 * 2.0 * spec.hidden as f64 * spec.vocab as f64 / g as f64;
        timing(w_bytes / self.bw(), flops / self.peak())
    }

    /// Self-attention prefill over `seq_in` prompt tokens (compute-bound).
    pub fn attn_prefill(
        &self,
        spec: &ModelSpec,
        batch: usize,
        seq_in: usize,
        g: usize,
    ) -> ModuleTiming {
        let tokens = (batch * seq_in) as f64;
        let flops =
            tokens * crate::models::ModuleFlops::per_token(spec, seq_in / 2).attention / g as f64;
        let h = spec.hidden as f64;
        let dh = spec.head_dim() as f64;
        let w_bytes = (2.0 * h * (spec.heads as f64 * dh)
            + 2.0 * h * (spec.kv_heads as f64 * dh))
            * spec.dtype_bytes as f64
            / g as f64;
        let act_bytes = 4.0 * tokens * h * spec.dtype_bytes as f64;
        timing((w_bytes + act_bytes) / self.bw(), flops / self.peak())
    }

    /// MLP prefill.
    pub fn mlp_prefill(
        &self,
        spec: &ModelSpec,
        batch: usize,
        seq_in: usize,
        g: usize,
    ) -> ModuleTiming {
        let tokens = (batch * seq_in) as f64;
        let mats = match spec.mlp {
            MlpKind::Gelu => 2.0,
            MlpKind::SwiGlu => 3.0,
        };
        let h = spec.hidden as f64;
        let flops = tokens * 2.0 * mats * h * spec.ffn as f64 / g as f64;
        let w_bytes = mats * h * spec.ffn as f64 * spec.dtype_bytes as f64 / g as f64;
        let act_bytes = 2.0 * tokens * h * spec.dtype_bytes as f64;
        timing((w_bytes + act_bytes) / self.bw(), flops / self.peak())
    }

    /// Norm prefill.
    pub fn norm_prefill(&self, spec: &ModelSpec, batch: usize, seq_in: usize) -> ModuleTiming {
        self.norm_decode(spec, batch * seq_in)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    fn pm() -> PerfModel {
        PerfModel::new(&HwSpec::default())
    }

    #[test]
    fn decode_is_memory_bound_low_util() {
        let m = by_name("Vicuna-7B").unwrap();
        let t = pm().attn_decode(&m, 8, 512, 1);
        assert!(t.util < 0.75, "decode util {}", t.util);
        // Streaming 67M fp16 attn params at ~576 GB/s ≈ 0.23 ms.
        assert!((1.0e-4..2.0e-3).contains(&t.dur_s), "{}", t.dur_s);
    }

    #[test]
    fn prefill_is_compute_bound_high_util() {
        let m = by_name("Vicuna-7B").unwrap();
        let t = pm().attn_prefill(&m, 8, 512, 1);
        assert!(t.util > 0.85, "prefill util {}", t.util);
    }

    #[test]
    fn tp_sharding_speeds_up_modules() {
        let m = by_name("Llama-70B").unwrap();
        let p = pm();
        let t1 = p.mlp_decode(&m, 8, 1).dur_s;
        let t4 = p.mlp_decode(&m, 8, 4).dur_s;
        assert!(t4 < t1 / 2.0, "t1={t1} t4={t4}");
    }

    #[test]
    fn larger_batch_increases_compute_not_weight_stream() {
        let m = by_name("Mistral-8B").unwrap();
        let p = pm();
        let t8 = p.mlp_decode(&m, 8, 1);
        let t64 = p.mlp_decode(&m, 64, 1);
        // Weight streaming dominates; time nearly flat, util rises.
        assert!(t64.dur_s < 1.5 * t8.dur_s);
        assert!(t64.util > t8.util);
    }

    #[test]
    fn kv_cache_grows_attention_time_with_context() {
        let m = by_name("Vicuna-13B").unwrap();
        let p = pm();
        let short = p.attn_decode(&m, 32, 128, 1).dur_s;
        let long = p.attn_decode(&m, 32, 1024, 1).dur_s;
        assert!(long > short);
    }

    #[test]
    fn norm_and_embed_are_fast() {
        let m = by_name("Qwen-8B").unwrap();
        let p = pm();
        assert!(p.norm_decode(&m, 64).dur_s < 1e-4);
        assert!(p.embed_decode(&m, 64).dur_s < 1e-4);
    }

    #[test]
    fn decode_step_time_order_of_magnitude() {
        // Vicuna-7B @ g=2: whole-step module sum should land near the
        // ~10 ms/step regime (≈100 tok/s/seq decode on A6000s).
        let m = by_name("Vicuna-7B").unwrap();
        let p = pm();
        let per_layer =
            p.attn_decode(&m, 8, 512, 2).dur_s + p.mlp_decode(&m, 8, 2).dur_s
                + 2.0 * p.norm_decode(&m, 8).dur_s;
        let step = per_layer * m.layers as f64 + p.logits_decode(&m, 8, 2).dur_s;
        assert!((3e-3..4e-2).contains(&step), "step={step}");
    }
}
