//! Inter-GPU communication time models (Appendix B, D, E of the paper).
//!
//! Ring AllReduce: 2(n−1) steps (ReduceScatter then AllGather), each moving
//! payload/n bytes per rank over the slowest link, plus per-step launch/DMA
//! latency and a per-call base latency. AllGather: (n−1) steps. P2P: single
//! hop. These are the standard α–β cost models (Xiong et al., 2024), with
//! the constants in `HwSpec`.

use crate::config::HwSpec;

/// Decomposition of one collective call on one rank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveCost {
    /// Time driving the interconnect, s.
    pub transfer_s: f64,
    /// Number of ring steps (for telemetry/features).
    pub steps: usize,
    /// Bytes this rank moves in total.
    pub bytes_moved: f64,
}

/// Ring AllReduce of `payload` bytes across `n` ranks.
pub fn allreduce(hw: &HwSpec, n: usize, payload: f64) -> CollectiveCost {
    assert!(n >= 1);
    if n == 1 {
        return CollectiveCost {
            transfer_s: 0.0,
            steps: 0,
            bytes_moved: 0.0,
        };
    }
    let steps = 2 * (n - 1);
    let chunk = payload / n as f64;
    let bytes_moved = chunk * steps as f64;
    let transfer_s = hw.coll_base_latency
        + steps as f64 * (hw.link_step_latency + chunk / hw.link_bw);
    CollectiveCost {
        transfer_s,
        steps,
        bytes_moved,
    }
}

/// Ring AllGather: each rank contributes `payload` bytes; n−1 steps.
pub fn allgather(hw: &HwSpec, n: usize, payload_per_rank: f64) -> CollectiveCost {
    assert!(n >= 1);
    if n == 1 {
        return CollectiveCost {
            transfer_s: 0.0,
            steps: 0,
            bytes_moved: 0.0,
        };
    }
    let steps = n - 1;
    let bytes_moved = payload_per_rank * steps as f64;
    let transfer_s = hw.coll_base_latency
        + steps as f64 * (hw.link_step_latency + payload_per_rank / hw.link_bw);
    CollectiveCost {
        transfer_s,
        steps,
        bytes_moved,
    }
}

/// Interleaved bidirectional ring AllReduce (IBing-style, Zong et al. 2025,
/// cited by the paper): the payload is split across both ring directions,
/// halving the per-step chunk at the cost of a slightly higher per-step
/// latency. Used by the collective-algorithm ablation (`piep ablate`).
pub fn allreduce_bidirectional(hw: &HwSpec, n: usize, payload: f64) -> CollectiveCost {
    assert!(n >= 1);
    if n == 1 {
        return CollectiveCost {
            transfer_s: 0.0,
            steps: 0,
            bytes_moved: 0.0,
        };
    }
    let steps = 2 * (n - 1);
    // Each direction carries payload/2; chunks move concurrently.
    let chunk = payload / (2.0 * n as f64);
    let bytes_moved = 2.0 * chunk * steps as f64;
    let transfer_s = hw.coll_base_latency
        + steps as f64 * (1.25 * hw.link_step_latency + chunk / hw.link_bw);
    CollectiveCost {
        transfer_s,
        steps,
        bytes_moved,
    }
}

/// Point-to-point transfer of `payload` bytes between adjacent stages.
pub fn p2p(hw: &HwSpec, payload: f64) -> CollectiveCost {
    CollectiveCost {
        transfer_s: hw.coll_base_latency + hw.link_step_latency + payload / hw.link_bw,
        steps: 1,
        bytes_moved: payload,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwSpec {
        HwSpec::default()
    }

    #[test]
    fn single_rank_is_free() {
        let c = allreduce(&hw(), 1, 1e6);
        assert_eq!(c.transfer_s, 0.0);
        assert_eq!(allgather(&hw(), 1, 1e6).transfer_s, 0.0);
    }

    #[test]
    fn allreduce_steps_2n_minus_2() {
        assert_eq!(allreduce(&hw(), 2, 1e6).steps, 2);
        assert_eq!(allreduce(&hw(), 4, 1e6).steps, 6);
    }

    #[test]
    fn allreduce_bandwidth_term_matches_2nm1_over_n() {
        // For large payloads the time tends to 2(n-1)/n * payload / bw.
        let h = hw();
        let payload = 1e9;
        let c = allreduce(&h, 4, payload);
        let ideal = 2.0 * 3.0 / 4.0 * payload / h.link_bw;
        assert!((c.transfer_s - ideal).abs() / ideal < 0.01, "{}", c.transfer_s);
    }

    #[test]
    fn latency_dominates_small_payloads() {
        // The paper's key TP effect: per-call latency makes many small
        // AllReduces expensive even when payloads are tiny.
        let h = hw();
        let small = allreduce(&h, 4, 64.0 * 1024.0);
        let latency_floor = h.coll_base_latency + 6.0 * h.link_step_latency;
        assert!(small.transfer_s > latency_floor);
        assert!(small.transfer_s < 2.0 * latency_floor + 1e-3);
    }

    #[test]
    fn more_ranks_more_time_at_fixed_payload() {
        let h = hw();
        let t2 = allreduce(&h, 2, 1e6).transfer_s;
        let t4 = allreduce(&h, 4, 1e6).transfer_s;
        assert!(t4 > t2);
    }

    #[test]
    fn p2p_single_hop() {
        let h = hw();
        let c = p2p(&h, 1e6);
        assert_eq!(c.steps, 1);
        assert!(c.transfer_s > 1e6 / h.link_bw);
    }

    #[test]
    fn allgather_cheaper_than_allreduce() {
        let h = hw();
        assert!(allgather(&h, 4, 1e6).transfer_s < allreduce(&h, 4, 4e6).transfer_s);
    }

    #[test]
    fn bidirectional_wins_large_payloads_loses_small() {
        let h = hw();
        // Large payload: bandwidth-bound, halved chunks win.
        let big = 64e6;
        assert!(
            allreduce_bidirectional(&h, 4, big).transfer_s < allreduce(&h, 4, big).transfer_s
        );
        // Tiny payload: latency-bound, the extra per-step cost loses.
        let small = 8.0 * 1024.0;
        assert!(
            allreduce_bidirectional(&h, 4, small).transfer_s
                > allreduce(&h, 4, small).transfer_s
        );
    }

    #[test]
    fn bidirectional_preserves_total_bytes() {
        let h = hw();
        let a = allreduce(&h, 4, 1e6);
        let b = allreduce_bidirectional(&h, 4, 1e6);
        assert!((a.bytes_moved - b.bytes_moved).abs() < 1e-6);
    }
}
