//! Inter-GPU communication time models (Appendix B, D, E of the paper).
//!
//! Ring AllReduce: 2(n−1) steps (ReduceScatter then AllGather), each moving
//! payload/n bytes per rank over the slowest link, plus per-step launch/DMA
//! latency and a per-call base latency. AllGather: (n−1) steps. P2P: single
//! hop. These are the standard α–β cost models (Xiong et al., 2024),
//! parameterized by a `cluster::LinkSpec` per interconnect tier; the legacy
//! `HwSpec`-based entry points delegate to the flat link derived from the
//! `link_*` fields and are bit-identical to the historical formulas.
//!
//! The `*_hier` variants consult a `cluster::Topology`: rank ranges inside
//! one node pay the intra-node tier with the flat formula; ranges crossing
//! a node boundary decompose hierarchically (intra-node reduce, inter-node
//! exchange among node leaders, intra-node broadcast) or — for ring
//! AllGathers, where every step saturates the boundary link simultaneously
//! — run the whole ring at the slower tier. Each tiered cost also carries
//! the tier's wire power (`LinkSpec::energy_per_byte × rate`), which the
//! engine adds to the transfer-phase board power; the legacy flat link has
//! zero wire energy, preserving bit-identity.

use crate::cluster::{LinkSpec, Topology};
use crate::config::HwSpec;

/// Decomposition of one collective call on one rank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveCost {
    /// Time driving the interconnect, s.
    pub transfer_s: f64,
    /// Number of ring steps (for telemetry/features).
    pub steps: usize,
    /// Bytes this rank moves in total.
    pub bytes_moved: f64,
}

impl CollectiveCost {
    const ZERO: CollectiveCost = CollectiveCost {
        transfer_s: 0.0,
        steps: 0,
        bytes_moved: 0.0,
    };
}

/// A topology-aware collective cost: the α–β decomposition plus the extra
/// board power drawn while driving the tier's wire (0 on the legacy flat
/// link, whose wire draw lives in `HwSpec::gpu_comm_w`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TieredCost {
    pub cost: CollectiveCost,
    /// Extra transfer-phase board power, W.
    pub wire_w: f64,
}

impl TieredCost {
    const ZERO: TieredCost = TieredCost {
        cost: CollectiveCost::ZERO,
        wire_w: 0.0,
    };

    fn of(cost: CollectiveCost, link: &LinkSpec) -> TieredCost {
        TieredCost {
            cost,
            // Wire power while the transfer is in flight: energy per byte ×
            // achieved byte rate over the phase.
            wire_w: if cost.transfer_s > 0.0 {
                link.energy_per_byte * cost.bytes_moved / cost.transfer_s
            } else {
                0.0
            },
        }
    }
}

/// Ring AllReduce of `payload` bytes across `n` ranks over one link tier.
pub fn allreduce_link(link: &LinkSpec, n: usize, payload: f64) -> CollectiveCost {
    assert!(n >= 1);
    if n == 1 {
        return CollectiveCost::ZERO;
    }
    let steps = 2 * (n - 1);
    let chunk = payload / n as f64;
    let bytes_moved = chunk * steps as f64;
    let transfer_s = link.base_latency + steps as f64 * (link.step_latency + chunk / link.bw);
    CollectiveCost {
        transfer_s,
        steps,
        bytes_moved,
    }
}

/// Ring AllReduce over the legacy flat link (`HwSpec` constants).
pub fn allreduce(hw: &HwSpec, n: usize, payload: f64) -> CollectiveCost {
    allreduce_link(&hw.flat_link(), n, payload)
}

/// Ring AllGather over one link tier: each rank contributes `payload`
/// bytes; n−1 steps.
pub fn allgather_link(link: &LinkSpec, n: usize, payload_per_rank: f64) -> CollectiveCost {
    assert!(n >= 1);
    if n == 1 {
        return CollectiveCost::ZERO;
    }
    let steps = n - 1;
    let bytes_moved = payload_per_rank * steps as f64;
    let transfer_s = link.base_latency + steps as f64 * (link.step_latency + payload_per_rank / link.bw);
    CollectiveCost {
        transfer_s,
        steps,
        bytes_moved,
    }
}

/// Ring AllGather over the legacy flat link (`HwSpec` constants).
pub fn allgather(hw: &HwSpec, n: usize, payload_per_rank: f64) -> CollectiveCost {
    allgather_link(&hw.flat_link(), n, payload_per_rank)
}

/// All-to-all exchange of `payload` bytes per rank across `n` ranks over
/// one link tier: each rank scatters payload/n-byte chunks to the n−1
/// peers (keeping its own shard local), pairwise-exchanged over n−1 steps.
/// The per-step chunk matches the AllGather formula's shape, but the total
/// bytes moved stay constant in n for a fixed per-rank payload — the MoE
/// dispatch cost is latency-dominated at high degree.
pub fn alltoall_link(link: &LinkSpec, n: usize, payload_per_rank: f64) -> CollectiveCost {
    assert!(n >= 1);
    if n == 1 {
        return CollectiveCost::ZERO;
    }
    let steps = n - 1;
    let chunk = payload_per_rank / n as f64;
    let bytes_moved = chunk * steps as f64;
    let transfer_s = link.base_latency + steps as f64 * (link.step_latency + chunk / link.bw);
    CollectiveCost {
        transfer_s,
        steps,
        bytes_moved,
    }
}

/// All-to-all over the legacy flat link (`HwSpec` constants).
pub fn alltoall(hw: &HwSpec, n: usize, payload_per_rank: f64) -> CollectiveCost {
    alltoall_link(&hw.flat_link(), n, payload_per_rank)
}

/// Hierarchical all-to-all over ranks `[first, first + count)` of the
/// topology. Single-node ranges pay the intra-node tier with the flat
/// formula (bit-identical to `alltoall_link`); multi-node ranges decompose
/// as an intra-node all-to-all (local shard exchange) followed by an
/// inter-node all-to-all among one leader per node carrying the full
/// boundary-crossing fraction of the payload, then an intra-node
/// redistribution hop — mirroring `allreduce_hier`'s leader-averaging of
/// bytes and wire energy over the range.
pub fn alltoall_hier(topo: &Topology, first: usize, count: usize, payload_per_rank: f64) -> TieredCost {
    if count <= 1 {
        return TieredCost::ZERO;
    }
    let nodes = topo.nodes_spanned(first, count);
    if nodes <= 1 {
        return TieredCost::of(alltoall_link(&topo.intra, count, payload_per_rank), &topo.intra);
    }
    let local = topo.max_local(first, count);
    // Intra-node shard exchange among local peers.
    let intra = if local > 1 {
        alltoall_link(&topo.intra, local, payload_per_rank)
    } else {
        CollectiveCost::ZERO
    };
    // Node leaders exchange the boundary-crossing fraction of every local
    // rank's payload: (nodes−1)/nodes of local×payload bytes leave the node.
    let cross_frac = (nodes - 1) as f64 / nodes as f64;
    let inter_payload = payload_per_rank * local as f64 * cross_frac;
    let inter = alltoall_link(&topo.inter, nodes, inter_payload);
    // Leaders redistribute the received remote shards to local peers.
    let redist = if local > 1 {
        p2p_link(&topo.intra, payload_per_rank * cross_frac)
    } else {
        CollectiveCost::ZERO
    };
    let transfer_s = intra.transfer_s + inter.transfer_s + redist.transfer_s;
    // Only one leader per node drives the inter ring and the
    // redistribution; average their bytes/wire energy over the range as
    // `allreduce_hier` does.
    let leaders_frac = nodes as f64 / count as f64;
    let per_rank_inter_bytes = inter.bytes_moved * leaders_frac;
    let per_rank_redist_bytes = redist.bytes_moved * leaders_frac;
    let wire_j = (intra.bytes_moved + per_rank_redist_bytes) * topo.intra.energy_per_byte
        + per_rank_inter_bytes * topo.inter.energy_per_byte;
    TieredCost {
        cost: CollectiveCost {
            transfer_s,
            steps: intra.steps + inter.steps + redist.steps,
            bytes_moved: intra.bytes_moved + per_rank_redist_bytes + per_rank_inter_bytes,
        },
        wire_w: if transfer_s > 0.0 { wire_j / transfer_s } else { 0.0 },
    }
}

/// Point-to-point transfer over one link tier.
pub fn p2p_link(link: &LinkSpec, payload: f64) -> CollectiveCost {
    CollectiveCost {
        transfer_s: link.base_latency + link.step_latency + payload / link.bw,
        steps: 1,
        bytes_moved: payload,
    }
}

/// Hierarchical ring AllReduce over ranks `[first, first + count)` of the
/// topology. Single-node ranges reduce to `allreduce_link` on the
/// intra-node tier (bit-identical to the flat path); multi-node ranges
/// decompose as intra-node reduce → inter-node AllReduce among one leader
/// per node → intra-node broadcast, each phase priced on its own tier.
pub fn allreduce_hier(topo: &Topology, first: usize, count: usize, payload: f64) -> TieredCost {
    if count <= 1 {
        return TieredCost::ZERO;
    }
    let nodes = topo.nodes_spanned(first, count);
    if nodes <= 1 {
        return TieredCost::of(allreduce_link(&topo.intra, count, payload), &topo.intra);
    }
    let local = topo.max_local(first, count);
    let intra_reduce = if local > 1 {
        allreduce_link(&topo.intra, local, payload)
    } else {
        CollectiveCost::ZERO
    };
    let inter = allreduce_link(&topo.inter, nodes, payload);
    // Pipelined intra-node broadcast of the reduced result.
    let bcast = if local > 1 {
        p2p_link(&topo.intra, payload)
    } else {
        CollectiveCost::ZERO
    };
    let transfer_s = intra_reduce.transfer_s + inter.transfer_s + bcast.transfer_s;
    // The engine applies this cost to *every* participating rank, but only
    // one leader per node drives the inter-node ring (and the broadcast),
    // so those phases' bytes and wire energy are averaged over the range —
    // leaders_frac × count ranks reconstructs the leaders' total exactly.
    let leaders_frac = nodes as f64 / count as f64;
    let per_rank_inter_bytes = inter.bytes_moved * leaders_frac;
    let per_rank_bcast_bytes = bcast.bytes_moved * leaders_frac;
    let wire_j = (intra_reduce.bytes_moved + per_rank_bcast_bytes) * topo.intra.energy_per_byte
        + per_rank_inter_bytes * topo.inter.energy_per_byte;
    TieredCost {
        cost: CollectiveCost {
            transfer_s,
            steps: intra_reduce.steps + inter.steps + bcast.steps,
            bytes_moved: intra_reduce.bytes_moved + per_rank_bcast_bytes + per_rank_inter_bytes,
        },
        wire_w: if transfer_s > 0.0 { wire_j / transfer_s } else { 0.0 },
    }
}

/// Tiered ring AllGather: a ring of `ring_n` participants hosted on ranks
/// `[first, first + count)`. Every ring step moves data on all links
/// simultaneously, so a ring that crosses a node boundary is bottlenecked
/// by the inter-node tier on every step; single-node rings pay the
/// intra-node tier with the flat formula.
pub fn allgather_ring(topo: &Topology, first: usize, count: usize, ring_n: usize, payload_per_rank: f64) -> TieredCost {
    if ring_n <= 1 {
        return TieredCost::ZERO;
    }
    let link = topo.link_for(first, count);
    TieredCost::of(allgather_link(link, ring_n, payload_per_rank), link)
}

/// Tiered P2P edge between two rank ranges (`src` ranks feed `dst` ranks
/// pairwise): if any pair crosses a node boundary the whole edge pays the
/// inter-node tier (the lockstep sends are bottlenecked by the slowest
/// pair).
pub fn p2p_range(topo: &Topology, src_first: usize, count: usize, dst_first: usize, payload: f64) -> TieredCost {
    let crosses = (0..count.max(1))
        .any(|i| topo.node_of(src_first + i) != topo.node_of(dst_first + i));
    let link = if crosses { &topo.inter } else { &topo.intra };
    TieredCost::of(p2p_link(link, payload), link)
}

/// Interleaved bidirectional ring AllReduce (IBing-style, Zong et al. 2025,
/// cited by the paper): the payload is split across both ring directions,
/// halving the per-step chunk at the cost of a slightly higher per-step
/// latency. Used by the collective-algorithm ablation (`piep ablate`).
pub fn allreduce_bidirectional(hw: &HwSpec, n: usize, payload: f64) -> CollectiveCost {
    assert!(n >= 1);
    if n == 1 {
        return CollectiveCost {
            transfer_s: 0.0,
            steps: 0,
            bytes_moved: 0.0,
        };
    }
    let steps = 2 * (n - 1);
    // Each direction carries payload/2; chunks move concurrently.
    let chunk = payload / (2.0 * n as f64);
    let bytes_moved = 2.0 * chunk * steps as f64;
    let transfer_s = hw.coll_base_latency
        + steps as f64 * (1.25 * hw.link_step_latency + chunk / hw.link_bw);
    CollectiveCost {
        transfer_s,
        steps,
        bytes_moved,
    }
}

/// Point-to-point transfer of `payload` bytes between adjacent stages over
/// the legacy flat link.
pub fn p2p(hw: &HwSpec, payload: f64) -> CollectiveCost {
    p2p_link(&hw.flat_link(), payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwSpec {
        HwSpec::default()
    }

    #[test]
    fn single_rank_is_free() {
        let c = allreduce(&hw(), 1, 1e6);
        assert_eq!(c.transfer_s, 0.0);
        assert_eq!(allgather(&hw(), 1, 1e6).transfer_s, 0.0);
    }

    #[test]
    fn allreduce_steps_2n_minus_2() {
        assert_eq!(allreduce(&hw(), 2, 1e6).steps, 2);
        assert_eq!(allreduce(&hw(), 4, 1e6).steps, 6);
    }

    #[test]
    fn allreduce_bandwidth_term_matches_2nm1_over_n() {
        // For large payloads the time tends to 2(n-1)/n * payload / bw.
        let h = hw();
        let payload = 1e9;
        let c = allreduce(&h, 4, payload);
        let ideal = 2.0 * 3.0 / 4.0 * payload / h.link_bw;
        assert!((c.transfer_s - ideal).abs() / ideal < 0.01, "{}", c.transfer_s);
    }

    #[test]
    fn latency_dominates_small_payloads() {
        // The paper's key TP effect: per-call latency makes many small
        // AllReduces expensive even when payloads are tiny.
        let h = hw();
        let small = allreduce(&h, 4, 64.0 * 1024.0);
        let latency_floor = h.coll_base_latency + 6.0 * h.link_step_latency;
        assert!(small.transfer_s > latency_floor);
        assert!(small.transfer_s < 2.0 * latency_floor + 1e-3);
    }

    #[test]
    fn more_ranks_more_time_at_fixed_payload() {
        let h = hw();
        let t2 = allreduce(&h, 2, 1e6).transfer_s;
        let t4 = allreduce(&h, 4, 1e6).transfer_s;
        assert!(t4 > t2);
    }

    #[test]
    fn p2p_single_hop() {
        let h = hw();
        let c = p2p(&h, 1e6);
        assert_eq!(c.steps, 1);
        assert!(c.transfer_s > 1e6 / h.link_bw);
    }

    #[test]
    fn allgather_cheaper_than_allreduce() {
        let h = hw();
        assert!(allgather(&h, 4, 1e6).transfer_s < allreduce(&h, 4, 4e6).transfer_s);
    }

    #[test]
    fn bidirectional_wins_large_payloads_loses_small() {
        let h = hw();
        // Large payload: bandwidth-bound, halved chunks win.
        let big = 64e6;
        assert!(
            allreduce_bidirectional(&h, 4, big).transfer_s < allreduce(&h, 4, big).transfer_s
        );
        // Tiny payload: latency-bound, the extra per-step cost loses.
        let small = 8.0 * 1024.0;
        assert!(
            allreduce_bidirectional(&h, 4, small).transfer_s
                > allreduce(&h, 4, small).transfer_s
        );
    }

    #[test]
    fn bidirectional_preserves_total_bytes() {
        let h = hw();
        let a = allreduce(&h, 4, 1e6);
        let b = allreduce_bidirectional(&h, 4, 1e6);
        assert!((a.bytes_moved - b.bytes_moved).abs() < 1e-6);
    }

    #[test]
    fn single_node_hier_is_bit_identical_to_flat() {
        use crate::cluster::Topology;
        let h = hw();
        let topo = Topology::single_node(h.flat_link());
        for n in 1..=8usize {
            for payload in [0.0, 64.0 * 1024.0, 1e6, 64e6] {
                let t = allreduce_hier(&topo, 0, n, payload);
                assert_eq!(t.cost, allreduce(&h, n, payload), "allreduce n={n}");
                assert_eq!(t.wire_w, 0.0, "flat link has no wire term");
                let g = allgather_ring(&topo, 0, n, n, payload);
                assert_eq!(g.cost, allgather(&h, n, payload), "allgather n={n}");
                if n >= 2 {
                    let p = p2p_range(&topo, 0, 1, 1, payload);
                    assert_eq!(p.cost, p2p(&h, payload), "p2p");
                    assert_eq!(p.wire_w, 0.0);
                }
            }
        }
    }

    #[test]
    fn crossing_a_node_boundary_costs_more() {
        use crate::cluster::{LinkTier, Topology};
        let topo = Topology::multi_node(2, LinkTier::NvLink, LinkTier::InfiniBand);
        let intra_only = Topology::single_node(LinkTier::NvLink.spec());
        let payload = 4e6;
        // Hierarchical AllReduce across 2 nodes beats nothing: it pays the
        // inter tier on top of the intra phases.
        let flat = allreduce_hier(&intra_only, 0, 4, payload);
        let hier = allreduce_hier(&topo, 0, 4, payload);
        assert!(hier.cost.transfer_s > flat.cost.transfer_s, "{} vs {}", hier.cost.transfer_s, flat.cost.transfer_s);
        assert!(hier.wire_w > 0.0, "named tiers carry wire power");
        // Ring AllGather bottlenecked by the boundary link on every step.
        let ag_in = allgather_ring(&topo, 0, 2, 2, payload);
        let ag_x = allgather_ring(&topo, 0, 4, 4, payload);
        assert!(ag_x.cost.transfer_s / 3.0 > ag_in.cost.transfer_s / 1.0, "per-step inter > per-step intra");
        // P2P pays the inter tier iff the pair crosses nodes.
        let inside = p2p_range(&topo, 0, 1, 1, payload);
        let across = p2p_range(&topo, 1, 1, 2, payload);
        assert!(across.cost.transfer_s > inside.cost.transfer_s);
        // Shard-wise group edge (2 ranks each side): crossing dominates.
        let group = p2p_range(&topo, 0, 2, 2, payload);
        assert_eq!(group.cost, across.cost);
    }

    #[test]
    fn hier_allreduce_averages_leader_driven_phases_over_the_range() {
        use crate::cluster::{LinkTier, Topology};
        let topo = Topology::multi_node(2, LinkTier::NvLink, LinkTier::InfiniBand);
        let payload = 1e6;
        let t = allreduce_hier(&topo, 0, 4, payload);
        let intra = allreduce_link(&topo.intra, 2, payload);
        let inter = allreduce_link(&topo.inter, 2, payload);
        let bcast = p2p_link(&topo.intra, payload);
        // Per-rank bytes: every rank reduces intra-node; only the 2 node
        // leaders (of 4 ranks) drive the inter ring and the broadcast.
        let want = intra.bytes_moved + 0.5 * (inter.bytes_moved + bcast.bytes_moved);
        assert!((t.cost.bytes_moved - want).abs() < 1e-9 * want, "{} vs {want}", t.cost.bytes_moved);
        // Summed over all 4 ranks, the engine-applied wire energy equals
        // the physical total drawn by the actual drivers of each phase.
        let applied_wire_j = t.wire_w * t.cost.transfer_s * 4.0;
        let physical_wire_j = 4.0 * intra.bytes_moved * topo.intra.energy_per_byte
            + 2.0 * inter.bytes_moved * topo.inter.energy_per_byte
            + 2.0 * bcast.bytes_moved * topo.intra.energy_per_byte;
        assert!(
            (applied_wire_j - physical_wire_j).abs() < 1e-9 * physical_wire_j,
            "{applied_wire_j} vs {physical_wire_j}"
        );
    }

    #[test]
    fn alltoall_steps_and_bytes() {
        let h = hw();
        let c = alltoall(&h, 4, 1e6);
        assert_eq!(c.steps, 3);
        // Each rank keeps its own 1/n shard: moves (n-1)/n of its payload.
        assert!((c.bytes_moved - 0.75e6).abs() < 1e-6);
        assert_eq!(alltoall(&h, 1, 1e6).transfer_s, 0.0);
        // Total bytes moved are bounded by the per-rank payload, so the
        // bandwidth term grows sublinearly in n ((n−1)/n of payload).
        let t2 = alltoall(&h, 2, 64e6).transfer_s;
        let t8 = alltoall(&h, 8, 64e6).transfer_s;
        assert!(t8 > t2, "more peers cost more: {t2} vs {t8}");
        assert!(t8 < 2.0 * t2, "but sublinearly: {t2} vs {t8}");
    }

    #[test]
    fn single_node_alltoall_hier_is_bit_identical_to_flat() {
        use crate::cluster::Topology;
        let h = hw();
        let topo = Topology::single_node(h.flat_link());
        for n in 1..=8usize {
            for payload in [0.0, 64.0 * 1024.0, 1e6, 64e6] {
                let t = alltoall_hier(&topo, 0, n, payload);
                assert_eq!(t.cost, alltoall(&h, n, payload), "alltoall n={n}");
                assert_eq!(t.wire_w, 0.0, "flat link has no wire term");
            }
        }
    }

    #[test]
    fn alltoall_crossing_a_node_boundary_costs_more() {
        use crate::cluster::{LinkTier, Topology};
        let topo = Topology::multi_node(2, LinkTier::NvLink, LinkTier::InfiniBand);
        let intra_only = Topology::single_node(LinkTier::NvLink.spec());
        let payload = 4e6;
        let flat = alltoall_hier(&intra_only, 0, 4, payload);
        let hier = alltoall_hier(&topo, 0, 4, payload);
        assert!(
            hier.cost.transfer_s > flat.cost.transfer_s,
            "{} vs {}",
            hier.cost.transfer_s,
            flat.cost.transfer_s
        );
        assert!(hier.wire_w > 0.0, "named tiers carry wire power");
        // One GPU per node degenerates to the pure inter-node exchange.
        let solo = Topology::multi_node(1, LinkTier::NvLink, LinkTier::InfiniBand);
        let t = alltoall_hier(&solo, 0, 4, payload);
        let inter = alltoall_link(&topo.inter, 4, payload * 0.75);
        assert_eq!(t.cost.transfer_s, inter.transfer_s);
        assert_eq!(t.cost.steps, inter.steps);
    }

    #[test]
    fn hier_allreduce_degenerate_leaders_skip_intra_phases() {
        use crate::cluster::{LinkTier, Topology};
        // One GPU per node: purely inter-node ring, no intra reduce/bcast.
        let topo = Topology::multi_node(1, LinkTier::NvLink, LinkTier::InfiniBand);
        let t = allreduce_hier(&topo, 0, 4, 1e6);
        let inter = allreduce_link(&topo.inter, 4, 1e6);
        assert_eq!(t.cost.transfer_s, inter.transfer_s);
        assert_eq!(t.cost.steps, inter.steps);
    }
}
