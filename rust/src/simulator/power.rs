//! System power model (DESIGN.md §7).
//!
//! Total wall power = PSU(CPU + DRAM + Σ GPU). GPU board power is a state
//! machine parameterized by the phase kind and the module's arithmetic
//! utilization; CPU power follows host-side driver/serving activity.
//! Heterogeneous fleets (`cluster::GpuSpec` per rank) replace the idle/peak
//! endpoints of the state machine per rank and scale the wait/transfer
//! draws by the rank's power-limit ratio; a homogeneous fleet takes the
//! exact legacy expressions.

use crate::cluster::GpuSpec;
use crate::config::HwSpec;
use crate::simulator::timeline::PhaseKind;

#[derive(Debug, Clone)]
pub struct PowerModel {
    pub hw: HwSpec,
    /// Run-level thermal drift multiplier on GPU power (sampled per run).
    pub thermal_mult: f64,
    /// Run-level multiplier on busy-wait power (NCCL spin/yield mix).
    pub wait_mult: f64,
    /// Per-rank GPU classes from the topology (empty ⇒ homogeneous
    /// baseline — the bit-identical legacy path).
    fleet: Vec<GpuSpec>,
}

impl PowerModel {
    pub fn new(hw: &HwSpec) -> Self {
        let fleet = hw.topology.as_ref().map(|t| t.fleet.clone()).unwrap_or_default();
        PowerModel {
            hw: hw.clone(),
            thermal_mult: 1.0,
            wait_mult: 1.0,
            fleet,
        }
    }

    /// GPU board power for a phase. `util` is the module's arithmetic
    /// utilization in [0,1] (compute-bound prefill ≈ 0.9, memory-bound
    /// decode ≈ 0.5).
    pub fn gpu_power(&self, kind: PhaseKind, util: f64) -> f64 {
        let hw = &self.hw;
        let p = match kind {
            PhaseKind::Compute => {
                hw.gpu_idle_w + util.clamp(0.0, 1.0) * (hw.gpu_tdp_w - hw.gpu_idle_w)
            }
            PhaseKind::Wait => hw.gpu_wait_w * self.wait_mult,
            PhaseKind::Transfer => hw.gpu_comm_w,
            PhaseKind::Idle => hw.gpu_idle_w,
        };
        p * self.thermal_mult
    }

    /// GPU board power for a phase on a specific rank: heterogeneous
    /// fleets swap in the rank's idle/peak endpoints and scale wait/
    /// transfer draw by the rank's power-limit ratio; on the homogeneous
    /// baseline this is exactly `gpu_power`.
    pub fn gpu_power_rank(&self, kind: PhaseKind, util: f64, rank: usize) -> f64 {
        let Some(g) = self.fleet.get(rank) else {
            return self.gpu_power(kind, util);
        };
        let hw = &self.hw;
        let limit_ratio = g.peak_w / hw.gpu_tdp_w;
        let p = match kind {
            PhaseKind::Compute => g.idle_w + util.clamp(0.0, 1.0) * (g.peak_w - g.idle_w),
            PhaseKind::Wait => hw.gpu_wait_w * self.wait_mult * limit_ratio,
            PhaseKind::Transfer => hw.gpu_comm_w * limit_ratio,
            PhaseKind::Idle => g.idle_w,
        };
        p * self.thermal_mult
    }

    /// Per-rank compute-throughput scales of the heterogeneous fleet, or
    /// `None` on the homogeneous baseline (so callers skip the rescale
    /// entirely and stay bit-identical).
    pub fn fleet_compute_scales(&self, num_ranks: usize) -> Option<Vec<f64>> {
        if self.fleet.is_empty() {
            return None;
        }
        Some(
            (0..num_ranks)
                .map(|r| self.fleet.get(r).map(|g| g.compute_scale).unwrap_or(1.0))
                .collect(),
        )
    }

    /// CPU package power given a host activity fraction in [0,1].
    pub fn cpu_power(&self, activity: f64) -> f64 {
        self.hw.cpu_idle_w + activity.clamp(0.0, 1.0) * (self.hw.cpu_max_w - self.hw.cpu_idle_w)
    }

    /// DRAM/board power given the same activity fraction.
    pub fn dram_power(&self, activity: f64) -> f64 {
        self.hw.dram_base_w + activity.clamp(0.0, 1.0) * self.hw.dram_active_w
    }

    /// Wall power from a subtotal (adds PSU conversion loss + base).
    pub fn wall_from_subtotal(&self, subtotal_w: f64) -> f64 {
        self.hw.psu_base_w + subtotal_w * (1.0 + self.hw.psu_loss_frac)
    }

    /// Host (non-GPU) wall-side power for a given activity level: CPU +
    /// DRAM + PSU base; the proportional PSU loss on the GPU side is
    /// applied by the caller via `wall_from_subtotal`.
    pub fn host_power(&self, activity: f64) -> f64 {
        self.cpu_power(activity) + self.dram_power(activity)
    }

    /// Host activity fraction for a run: driven by kernel-launch pressure
    /// (decode steps/s × layers × GPUs) and serving-layer work (batch).
    /// Matches the intuition that multi-GPU runs keep the host busier.
    pub fn host_activity(&self, gpus: usize, batch: usize, steps_per_s: f64, layers: usize) -> f64 {
        let launch_rate = steps_per_s * layers as f64 * gpus as f64; // kernels/s
        let launch_load = (launch_rate / 60_000.0).min(1.0); // ~60k launches/s saturates a core pool
        let serving_load = (batch as f64 / 256.0).min(0.3);
        (0.08 + 0.75 * launch_load + serving_load).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pm() -> PowerModel {
        PowerModel::new(&HwSpec::default())
    }

    #[test]
    fn gpu_power_ordering() {
        let p = pm();
        let idle = p.gpu_power(PhaseKind::Idle, 0.0);
        let wait = p.gpu_power(PhaseKind::Wait, 0.0);
        let comm = p.gpu_power(PhaseKind::Transfer, 0.0);
        let decode = p.gpu_power(PhaseKind::Compute, 0.5);
        let prefill = p.gpu_power(PhaseKind::Compute, 0.9);
        assert!(idle < wait && wait <= comm && comm < decode && decode < prefill);
        assert!(prefill <= p.hw.gpu_tdp_w);
    }

    #[test]
    fn util_clamped() {
        let p = pm();
        assert_eq!(
            p.gpu_power(PhaseKind::Compute, 1.5),
            p.gpu_power(PhaseKind::Compute, 1.0)
        );
        assert_eq!(
            p.gpu_power(PhaseKind::Compute, -1.0),
            p.gpu_power(PhaseKind::Idle, 0.0)
        );
    }

    #[test]
    fn thermal_drift_scales_gpu_only() {
        let mut p = pm();
        let base = p.gpu_power(PhaseKind::Compute, 0.5);
        let cpu = p.cpu_power(0.5);
        p.thermal_mult = 1.1;
        assert!((p.gpu_power(PhaseKind::Compute, 0.5) - base * 1.1).abs() < 1e-9);
        assert_eq!(p.cpu_power(0.5), cpu);
    }

    #[test]
    fn rank_power_matches_global_on_homogeneous_fleet() {
        let p = pm();
        for kind in [PhaseKind::Compute, PhaseKind::Wait, PhaseKind::Transfer, PhaseKind::Idle] {
            for rank in 0..4 {
                assert_eq!(p.gpu_power_rank(kind, 0.6, rank), p.gpu_power(kind, 0.6));
            }
        }
        assert!(p.fleet_compute_scales(4).is_none());
    }

    #[test]
    fn heterogeneous_fleet_changes_rank_power() {
        use crate::cluster::{GpuSpec, LinkTier};
        let fleet = [GpuSpec::a6000(), GpuSpec::h100()];
        let hw = HwSpec::cluster_testbed(2, 2, LinkTier::NvLink, LinkTier::InfiniBand, &fleet);
        let p = PowerModel::new(&hw);
        // Rank 0 is the baseline A6000: identical to the global model.
        assert_eq!(p.gpu_power_rank(PhaseKind::Compute, 0.5, 0), p.gpu_power(PhaseKind::Compute, 0.5));
        // Rank 1 is an H100: hotter at idle and at the limit.
        assert!(p.gpu_power_rank(PhaseKind::Idle, 0.0, 1) > p.gpu_power(PhaseKind::Idle, 0.0));
        assert!(p.gpu_power_rank(PhaseKind::Compute, 1.0, 1) > p.gpu_power(PhaseKind::Compute, 1.0));
        assert!(p.gpu_power_rank(PhaseKind::Wait, 0.0, 1) > p.gpu_power(PhaseKind::Wait, 0.0));
        let scales = p.fleet_compute_scales(4).unwrap();
        assert_eq!(scales.len(), 4);
        assert_eq!(scales[0], 1.0);
        assert!(scales[1] > 1.0);
    }

    #[test]
    fn host_activity_monotone_in_gpus() {
        let p = pm();
        let a2 = p.host_activity(2, 8, 60.0, 32);
        let a4 = p.host_activity(4, 8, 60.0, 32);
        assert!(a4 > a2);
        assert!(a2 > 0.0 && a4 <= 1.0);
    }

    #[test]
    fn wall_power_adds_overhead() {
        let p = pm();
        let w = p.wall_from_subtotal(500.0);
        assert!(w > 500.0);
        let expect = p.hw.psu_base_w + 500.0 * (1.0 + p.hw.psu_loss_frac);
        assert!((w - expect).abs() < 1e-9);
    }
}
