//! Multi-GPU LLM inference substrate: a deterministic, seeded simulator of
//! the paper's testbed (DESIGN.md §2, §7, §9).
//!
//! The planners lower a run into the shared Plan IR (`crate::plan`); the
//! per-rank discrete-event engine (`engine`) executes it into a *timeline*
//! of power-annotated phases per GPU (compute / synchronization-wait /
//! transfer / idle), from which the telemetry layer derives everything the
//! paper measures: wall-meter system energy, NVML GPU energy, utilization
//! counters, and the fine-grained module windows PIE-P's profiler
//! timestamps — with sync-wait energy isolated from transfer energy per
//! communication module.

pub mod collective;
pub mod engine;
pub mod perf;
pub mod power;
pub mod run;
pub mod skew;
pub mod timeline;

pub use engine::BuiltRun;
pub use run::{simulate_run, simulate_run_batch, simulate_run_planned, simulate_run_reference, RunRecord};
pub use timeline::{ModuleKind, Phase, PhaseKind, Timeline};
