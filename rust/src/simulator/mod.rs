//! Multi-GPU LLM inference substrate: a deterministic, seeded simulator of
//! the paper's testbed (DESIGN.md §2, §7).
//!
//! The simulator produces, for one inference run, a *timeline* of
//! power-annotated phases per GPU (compute / synchronization-wait /
//! transfer / idle), from which the telemetry layer derives everything the
//! paper measures: wall-meter system energy, NVML GPU energy, utilization
//! counters, and the fine-grained module windows PIE-P's profiler
//! timestamps.

pub mod collective;
pub mod perf;
pub mod power;
pub mod run;
pub mod skew;
pub mod timeline;

pub use run::{simulate_run, RunRecord};
pub use timeline::{ModuleKind, Phase, PhaseKind, Timeline};
