//! Power-annotated execution timelines.
//!
//! A `Timeline` is the ground-truth record of one simulated run: per GPU, a
//! contiguous sequence of phases, each with a start/end time, a board power
//! draw, and a module tag. All energies derive from exact integration over
//! phases; the telemetry layer (meter/NVML) then *samples* the same
//! timeline the way real instruments would.

use std::collections::BTreeMap;

/// Model-tree leaf module kinds, including the communication modules PIE-P
/// adds to IrEne's abstraction (AllReduce / P2PTransfer / AllGather).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModuleKind {
    Embedding,
    Norm,
    SelfAttention,
    Mlp,
    LogitsHead,
    /// Tensor-parallel ring AllReduce (ReduceScatter + AllGather phases).
    AllReduce,
    /// Pipeline-parallel point-to-point stage transfer.
    P2PTransfer,
    /// Data-parallel terminal output collation.
    AllGather,
    /// Expert-parallel (MoE) all-to-all token dispatch/combine.
    AllToAll,
}

impl ModuleKind {
    /// Number of module kinds (dense-array dimension on hot paths).
    pub const COUNT: usize = 9;

    pub const ALL: [ModuleKind; ModuleKind::COUNT] = [
        ModuleKind::Embedding,
        ModuleKind::Norm,
        ModuleKind::SelfAttention,
        ModuleKind::Mlp,
        ModuleKind::LogitsHead,
        ModuleKind::AllReduce,
        ModuleKind::P2PTransfer,
        ModuleKind::AllGather,
        ModuleKind::AllToAll,
    ];

    /// Dense index (0..COUNT) for array-based aggregation on hot paths.
    #[inline]
    pub fn idx(&self) -> usize {
        match self {
            ModuleKind::Embedding => 0,
            ModuleKind::Norm => 1,
            ModuleKind::SelfAttention => 2,
            ModuleKind::Mlp => 3,
            ModuleKind::LogitsHead => 4,
            ModuleKind::AllReduce => 5,
            ModuleKind::P2PTransfer => 6,
            ModuleKind::AllGather => 7,
            ModuleKind::AllToAll => 8,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModuleKind::Embedding => "LLMEmbedding",
            ModuleKind::Norm => "LayerNorm/RMSNorm",
            ModuleKind::SelfAttention => "Self-Attention",
            ModuleKind::Mlp => "MLP",
            ModuleKind::LogitsHead => "LogitsHead",
            ModuleKind::AllReduce => "AllReduce",
            ModuleKind::P2PTransfer => "P2PTransfer",
            ModuleKind::AllGather => "AllGather",
            ModuleKind::AllToAll => "AllToAll",
        }
    }

    /// Is this one of PIE-P's communication modules?
    pub fn is_comm(&self) -> bool {
        matches!(
            self,
            ModuleKind::AllReduce
                | ModuleKind::P2PTransfer
                | ModuleKind::AllGather
                | ModuleKind::AllToAll
        )
    }
}

/// What the GPU is doing during a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseKind {
    Compute,
    /// Blocked at a synchronization point waiting for peers (the paper's
    /// non-deterministic "waiting phase").
    Wait,
    /// Driving the interconnect (ring step / P2P send-recv).
    Transfer,
    Idle,
}

/// One contiguous activity interval on one GPU.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub gpu: u16,
    pub kind: PhaseKind,
    pub module: ModuleKind,
    pub layer: u16,
    pub step: u32,
    pub t0: f64,
    pub t1: f64,
    /// Board power during the phase, W.
    pub power_w: f64,
}

impl Phase {
    pub fn dur(&self) -> f64 {
        self.t1 - self.t0
    }
    pub fn energy_j(&self) -> f64 {
        self.dur() * self.power_w
    }
}

/// Builder + container for a run's phases.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub num_gpus: usize,
    pub phases: Vec<Phase>,
    /// Per-GPU logical clock (s).
    clocks: Vec<f64>,
    /// Per-GPU idle power used to backfill gaps.
    idle_w: f64,
}

impl Timeline {
    pub fn new(num_gpus: usize, idle_w: f64) -> Self {
        Timeline {
            num_gpus,
            phases: Vec::new(),
            clocks: vec![0.0; num_gpus],
            idle_w,
        }
    }

    /// Reassemble a timeline from externally materialized phases (the
    /// event engine's parallel path). `clocks` must equal each GPU's final
    /// phase end time; per-GPU phases must be contiguous and time-ordered,
    /// as `push` would have produced them. Both vectors are taken by value
    /// and owned for the timeline's lifetime — they are exactly the engine
    /// buffers that must *not* be recycled into `EngineScratch`.
    pub(crate) fn from_parts(
        num_gpus: usize,
        idle_w: f64,
        phases: Vec<Phase>,
        clocks: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(clocks.len(), num_gpus);
        Timeline {
            num_gpus,
            phases,
            clocks,
            idle_w,
        }
    }

    pub fn clock(&self, gpu: usize) -> f64 {
        self.clocks[gpu]
    }

    /// Append a phase on `gpu` starting at its current clock.
    pub fn push(
        &mut self,
        gpu: usize,
        kind: PhaseKind,
        module: ModuleKind,
        layer: u16,
        step: u32,
        dur: f64,
        power_w: f64,
    ) {
        debug_assert!(dur >= 0.0, "negative phase duration {dur}");
        let t0 = self.clocks[gpu];
        let t1 = t0 + dur;
        self.clocks[gpu] = t1;
        if dur > 0.0 {
            self.phases.push(Phase {
                gpu: gpu as u16,
                kind,
                module,
                layer,
                step,
                t0,
                t1,
                power_w,
            });
        }
    }

    /// Advance `gpu`'s clock to `t`, recording an idle phase for the gap.
    pub fn idle_until(&mut self, gpu: usize, t: f64, module: ModuleKind, step: u32) {
        let now = self.clocks[gpu];
        if t > now {
            self.push(gpu, PhaseKind::Idle, module, 0, step, t - now, self.idle_w);
        }
    }

    /// Advance `gpu`'s clock to `t`, recording a synchronization *wait*
    /// phase (elevated busy-spin power, attributed to `module`).
    pub fn wait_until(
        &mut self,
        gpu: usize,
        t: f64,
        module: ModuleKind,
        layer: u16,
        step: u32,
        wait_w: f64,
    ) -> f64 {
        let now = self.clocks[gpu];
        let waited = (t - now).max(0.0);
        if waited > 0.0 {
            self.push(gpu, PhaseKind::Wait, module, layer, step, waited, wait_w);
        }
        waited
    }

    /// Wall-clock of the run (max GPU clock).
    pub fn makespan(&self) -> f64 {
        self.clocks.iter().copied().fold(0.0, f64::max)
    }

    /// Pad every GPU with idle time to the makespan so all GPUs cover the
    /// same interval (as on the real machine where the meter sees them all).
    pub fn finalize(&mut self) {
        let end = self.makespan();
        for g in 0..self.num_gpus {
            self.idle_until(g, end, ModuleKind::Embedding, u32::MAX);
        }
    }

    /// `finalize` with an explicit per-GPU idle power — heterogeneous
    /// fleets bill each rank's tail padding at its own board's idle draw.
    /// With every entry equal to the timeline's own idle power this is
    /// exactly `finalize`.
    pub fn finalize_with(&mut self, idle_w_per_gpu: &[f64]) {
        let end = self.makespan();
        for g in 0..self.num_gpus {
            let now = self.clocks[g];
            if end > now {
                let w = idle_w_per_gpu.get(g).copied().unwrap_or(self.idle_w);
                self.push(g, PhaseKind::Idle, ModuleKind::Embedding, 0, u32::MAX, end - now, w);
            }
        }
    }

    /// Exact GPU-side energy (J), all phases.
    pub fn gpu_energy_j(&self) -> f64 {
        self.phases.iter().map(|p| p.energy_j()).sum()
    }

    /// Exact per-GPU energy (J).
    pub fn gpu_energy_per_gpu(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.num_gpus];
        for p in &self.phases {
            out[p.gpu as usize] += p.energy_j();
        }
        out
    }

    /// Exact GPU energy grouped by module tag (J).
    pub fn energy_by_module(&self) -> BTreeMap<ModuleKind, f64> {
        let mut out = BTreeMap::new();
        for p in &self.phases {
            if p.kind == PhaseKind::Idle {
                continue;
            }
            *out.entry(p.module).or_insert(0.0) += p.energy_j();
        }
        out
    }

    /// Busy time grouped by module tag (GPU-seconds, waits included).
    pub fn time_by_module(&self) -> BTreeMap<ModuleKind, f64> {
        let mut out = BTreeMap::new();
        for p in &self.phases {
            if p.kind == PhaseKind::Idle {
                continue;
            }
            *out.entry(p.module).or_insert(0.0) += p.dur();
        }
        out
    }

    /// Energy split of a communication module into (wait, transfer) — the
    /// paper's synchronization-sampling decomposition.
    pub fn comm_split_j(&self, module: ModuleKind) -> (f64, f64) {
        let mut wait = 0.0;
        let mut xfer = 0.0;
        for p in self.phases.iter().filter(|p| p.module == module) {
            match p.kind {
                PhaseKind::Wait => wait += p.energy_j(),
                PhaseKind::Transfer => xfer += p.energy_j(),
                _ => {}
            }
        }
        (wait, xfer)
    }

    /// Per-GPU utilization: fraction of the run spent executing compute or
    /// copy kernels. Synchronization busy-waits are excluded — nvidia-smi's
    /// utilization counter tracks SM occupancy by real kernels, which is
    /// why utilization dips on sync-heavy configurations (a signal the
    /// Table-1 features rely on).
    pub fn busy_fraction(&self) -> Vec<f64> {
        let span = self.makespan().max(1e-12);
        let mut busy = vec![0.0; self.num_gpus];
        for p in &self.phases {
            if matches!(p.kind, PhaseKind::Compute | PhaseKind::Transfer) {
                busy[p.gpu as usize] += p.dur();
            }
        }
        busy.iter().map(|b| (b / span).min(1.0)).collect()
    }

    /// Per-GPU occupancy split: fraction of the run spent (busy, waiting
    /// at synchronization points, idle). The three sum to 1 per GPU —
    /// uncovered head/tail time counts as idle. `busy_fraction` equals the
    /// first component; serving occupancy tables use this split so that
    /// sync-wait time is reported as wait, not busy.
    pub fn occupancy_split(&self) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let span = self.makespan().max(1e-12);
        let mut busy = vec![0.0; self.num_gpus];
        let mut wait = vec![0.0; self.num_gpus];
        for p in &self.phases {
            match p.kind {
                PhaseKind::Compute | PhaseKind::Transfer => busy[p.gpu as usize] += p.dur(),
                PhaseKind::Wait => wait[p.gpu as usize] += p.dur(),
                PhaseKind::Idle => {}
            }
        }
        let busy: Vec<f64> = busy.iter().map(|b| (b / span).min(1.0)).collect();
        let wait: Vec<f64> = wait.iter().map(|w| (w / span).min(1.0)).collect();
        let idle = busy
            .iter()
            .zip(&wait)
            .map(|(b, w)| (1.0 - b - w).max(0.0))
            .collect();
        (busy, wait, idle)
    }

    /// Time-weighted mean and coefficient of variation of the *total* GPU
    /// power signal over the run — used by the sampling telemetry to model
    /// aliasing error without replaying every sample. Sweep over phase
    /// boundaries maintaining the sum of active powers.
    pub fn power_mean_cv(&self) -> (f64, f64) {
        let base = self.idle_w * self.num_gpus as f64;
        if self.phases.is_empty() {
            return (base, 0.0);
        }
        // Per-GPU phase index lists. Phases are pushed in nondecreasing
        // time order *per GPU* by construction, so instead of sorting all
        // 2n boundary events (O(n log n), the former hot spot of
        // simulate_run — see EXPERIMENTS.md §Perf) we k-way merge the g
        // already-sorted streams with simple cursors (g ≤ 4).
        let mut per: Vec<Vec<u32>> = vec![Vec::new(); self.num_gpus];
        for (i, p) in self.phases.iter().enumerate() {
            per[p.gpu as usize].push(i as u32);
        }
        let mut cursor = vec![0usize; self.num_gpus];
        // Current board power per GPU (idle until its first phase).
        let mut gpu_power = vec![self.idle_w; self.num_gpus];
        let mut power: f64 = base;
        let mut last_t = 0.0f64;
        let (mut e1, mut e2, mut total_t) = (0.0f64, 0.0f64, 0.0f64);
        loop {
            // Next boundary: the earliest un-entered phase start across GPUs.
            let mut next_t = f64::INFINITY;
            let mut next_g = usize::MAX;
            for g in 0..self.num_gpus {
                if let Some(&pi) = per[g].get(cursor[g]) {
                    let t0 = self.phases[pi as usize].t0;
                    if t0 < next_t {
                        next_t = t0;
                        next_g = g;
                    }
                }
            }
            if next_g == usize::MAX {
                break;
            }
            let dt = next_t - last_t;
            if dt > 0.0 {
                e1 += power * dt;
                e2 += power * power * dt;
                total_t += dt;
            }
            let ph = &self.phases[per[next_g][cursor[next_g]] as usize];
            power += ph.power_w - gpu_power[next_g];
            gpu_power[next_g] = ph.power_w;
            cursor[next_g] += 1;
            last_t = next_t;
            // Handle a trailing gap after this GPU's last phase: phases per
            // GPU are contiguous, so the next start is also the previous
            // end; only the final makespan tail needs closing below.
        }
        // Close the interval to the makespan with the last powers.
        let end = self.makespan();
        let dt = end - last_t;
        if dt > 0.0 {
            e1 += power * dt;
            e2 += power * power * dt;
            total_t += dt;
        }
        if total_t <= 0.0 {
            return (base, 0.0);
        }
        let mean = e1 / total_t;
        let var = (e2 / total_t - mean * mean).max(0.0);
        (mean, var.sqrt() / mean.max(1e-9))
    }

    /// Instantaneous total GPU power at time `t` (W). Phases per GPU are
    /// contiguous and time-ordered per construction; this scans with a
    /// cursor and is only used by the sampling telemetry.
    pub fn power_at(&self, t: f64) -> f64 {
        let mut total = 0.0;
        let mut seen = vec![false; self.num_gpus];
        for p in &self.phases {
            if p.t0 <= t && t < p.t1 {
                total += p.power_w;
                seen[p.gpu as usize] = true;
            }
        }
        for s in seen {
            if !s {
                total += self.idle_w;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Timeline {
        Timeline::new(2, 20.0)
    }

    #[test]
    fn module_kind_indices_are_dense_and_consistent() {
        assert_eq!(ModuleKind::ALL.len(), ModuleKind::COUNT);
        for (i, m) in ModuleKind::ALL.iter().enumerate() {
            assert_eq!(m.idx(), i, "{m:?}");
        }
        assert!(ModuleKind::AllToAll.is_comm());
        assert_eq!(ModuleKind::AllToAll.name(), "AllToAll");
    }

    #[test]
    fn clocks_advance_and_energy_integrates() {
        let mut tl = mk();
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 2.0, 100.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 1.0, 100.0);
        assert_eq!(tl.clock(0), 2.0);
        assert_eq!(tl.clock(1), 1.0);
        assert_eq!(tl.gpu_energy_j(), 300.0);
    }

    #[test]
    fn wait_until_records_wait_phase() {
        let mut tl = mk();
        tl.push(0, PhaseKind::Compute, ModuleKind::SelfAttention, 0, 0, 2.0, 150.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::SelfAttention, 0, 0, 1.0, 150.0);
        let w = tl.wait_until(1, 2.0, ModuleKind::AllReduce, 0, 0, 95.0);
        assert!((w - 1.0).abs() < 1e-12);
        let (wait_j, xfer_j) = tl.comm_split_j(ModuleKind::AllReduce);
        assert!((wait_j - 95.0).abs() < 1e-12);
        assert_eq!(xfer_j, 0.0);
        // GPU 0 waited zero.
        assert_eq!(tl.wait_until(0, 2.0, ModuleKind::AllReduce, 0, 0, 95.0), 0.0);
    }

    #[test]
    fn finalize_pads_to_makespan() {
        let mut tl = mk();
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 3.0, 100.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 1.0, 100.0);
        tl.finalize();
        assert_eq!(tl.clock(1), 3.0);
        // Idle energy for the 2s gap at 20 W.
        assert!((tl.gpu_energy_j() - (400.0 + 40.0)).abs() < 1e-9);
    }

    #[test]
    fn energy_by_module_excludes_idle() {
        let mut tl = mk();
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 1.0, 100.0);
        tl.finalize();
        let by = tl.energy_by_module();
        assert_eq!(by.len(), 1);
        assert!((by[&ModuleKind::Mlp] - 100.0).abs() < 1e-12);
    }

    #[test]
    fn power_at_sums_active_gpus() {
        let mut tl = mk();
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 2.0, 100.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 1.0, 150.0);
        assert!((tl.power_at(0.5) - 250.0).abs() < 1e-12);
        // After GPU 1 finished: its idle power counts.
        assert!((tl.power_at(1.5) - 120.0).abs() < 1e-12);
    }

    #[test]
    fn power_mean_cv_matches_reference_sweep() {
        // Reference: sort-based boundary sweep (the pre-optimization
        // implementation, kept here as the correctness oracle).
        fn reference(tl: &Timeline) -> (f64, f64) {
            let mut evs: Vec<(f64, f64)> = Vec::new();
            for p in &tl.phases {
                evs.push((p.t0, p.power_w - tl.idle_w));
                evs.push((p.t1, -(p.power_w - tl.idle_w)));
            }
            evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let base = tl.idle_w * tl.num_gpus as f64;
            let mut power = base;
            let mut last_t = evs[0].0;
            let (mut e1, mut e2, mut tt) = (0.0, 0.0, 0.0);
            for (t, dp) in evs {
                let dt = t - last_t;
                if dt > 0.0 {
                    e1 += power * dt;
                    e2 += power * power * dt;
                    tt += dt;
                }
                power += dp;
                last_t = t;
            }
            let mean = e1 / tt;
            ((mean), ((e2 / tt - mean * mean).max(0.0)).sqrt() / mean)
        }
        let mut tl = mk();
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..200 {
            let g = rng.below(2);
            let dur = rng.range(0.001, 0.1);
            let pw = rng.range(20.0, 300.0);
            tl.push(g, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, dur, pw);
        }
        tl.finalize();
        let (m_fast, cv_fast) = tl.power_mean_cv();
        let (m_ref, cv_ref) = reference(&tl);
        assert!((m_fast - m_ref).abs() / m_ref < 1e-9, "{m_fast} vs {m_ref}");
        assert!((cv_fast - cv_ref).abs() < 1e-9, "{cv_fast} vs {cv_ref}");
    }

    #[test]
    fn occupancy_split_partitions_the_run() {
        let mut tl = mk();
        tl.push(0, PhaseKind::Compute, ModuleKind::SelfAttention, 0, 0, 2.0, 150.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::SelfAttention, 0, 0, 1.0, 150.0);
        tl.wait_until(1, 2.0, ModuleKind::AllReduce, 0, 0, 95.0);
        tl.push(0, PhaseKind::Transfer, ModuleKind::AllReduce, 0, 0, 1.0, 120.0);
        tl.finalize();
        let (busy, wait, idle) = tl.occupancy_split();
        assert!((busy[0] - 1.0).abs() < 1e-9);
        assert!((busy[1] - 1.0 / 3.0).abs() < 1e-9);
        assert!((wait[1] - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(wait[0], 0.0);
        for g in 0..2 {
            assert!((busy[g] + wait[g] + idle[g] - 1.0).abs() < 1e-9);
        }
        // The busy component is exactly `busy_fraction` (the nvidia-smi
        // style utilization signal the feature extractor reads).
        assert_eq!(busy, tl.busy_fraction());
    }

    #[test]
    fn busy_fraction_bounds() {
        let mut tl = mk();
        tl.push(0, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 4.0, 100.0);
        tl.push(1, PhaseKind::Compute, ModuleKind::Mlp, 0, 0, 2.0, 100.0);
        tl.finalize();
        let b = tl.busy_fraction();
        assert!((b[0] - 1.0).abs() < 1e-9);
        assert!((b[1] - 0.5).abs() < 1e-9);
    }
}
