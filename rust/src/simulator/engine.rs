//! Per-rank discrete-event engine: executes a lowered `Plan` into a
//! power-annotated `Timeline` (DESIGN.md §9).
//!
//! Execution is two-phase:
//!
//! 1. **Resolve** (serial): walk the topologically ordered op list once,
//!    advancing per-rank clocks. All stochastic draws happen here, in op
//!    order — per-(rank, op) skew samples for compute, per-rank exponential
//!    launch-desync jitter at jittered collectives — so a plan plus a seed
//!    stream fully determines the run. Collectives resolve as *rendezvous
//!    events*: the straggler (latest arrival) sets the start time; P2P
//!    edges become ready when the slowest sender finishes. Per-rank waits
//!    are recorded as synchronization samples — per each collective's
//!    `WaitRecord`, and positive-only at P2P receives.
//! 2. **Materialize** (parallel over ranks via `util::par`): each rank
//!    independently expands its op slice into wait / transfer / compute
//!    phases using the resolved rendezvous times and sampled durations.
//!    The per-rank phase lists are merged back into the exact global order
//!    a serial walk would produce (op index, then wait-before-transfer,
//!    then rank), so the serial (`threads == 1`) and parallel paths are
//!    bit-identical — including downstream floating-point reductions.
//!
//! The explicit *sync-wait* vs *transfer* phases this engine emits are
//! what give the run record its phase-resolved communication/
//! synchronization energy isolation.
//!
//! Buffer churn on the hot paths is absorbed by [`EngineScratch`]: a
//! per-thread pool of the engine's internal vectors (sampled durations,
//! per-op offsets, rendezvous times, edge clocks, the merged keyed phase
//! list) recycled across runs, so sweep / tune / serve / fleet loops do
//! not re-allocate per execution (DESIGN.md §17). Pooling never changes
//! results — buffers are cleared on take and every arithmetic fold order
//! is unchanged (property-tested).

use crate::plan::exec::{ExecBatch, ExecPlan, OpKind};
use crate::plan::{Op, Plan, WaitRecord};
use crate::simulator::power::PowerModel;
use crate::simulator::skew::SkewModel;
use crate::simulator::timeline::{ModuleKind, Phase, PhaseKind, Timeline};
use crate::util::par;
use crate::util::rng::Rng;

/// Output of executing a plan: the timeline plus profiler-visible side
/// channels (formerly produced by each bespoke planner).
#[derive(Debug, Clone)]
pub struct BuiltRun {
    pub timeline: Timeline,
    /// Per-sync per-rank wait durations (s) — the raw material of PIE-P's
    /// synchronization sampling.
    pub wait_samples: Vec<f64>,
    /// Time at which prefill finished (phases with step 0 are prefill).
    pub prefill_end: f64,
    /// Decode steps actually simulated (before extrapolation).
    pub sim_steps: usize,
    /// Total collective/P2P payload bytes moved per simulated decode step.
    pub comm_bytes_per_step: f64,
    /// Execution trace (plan-op index per materialized phase), captured
    /// when `SimKnobs::trace` is on; `None` otherwise — the capture is the
    /// knob's only cost, the resolved run is identical either way.
    pub trace: Option<crate::trace::Trace>,
}

/// Reusable engine buffers, pooled per thread across runs.
///
/// `resolve_compiled` / `resolve_batch` and `materialize` draw their
/// internal vectors (sampled durations, per-op offsets, rendezvous times,
/// edge-ready clocks, the merged keyed phase list) from here instead of
/// allocating, and return them once the run's outputs have been
/// extracted. Buffers that escape into the returned `BuiltRun` — final
/// clocks, wait samples, phases, the trace — are never pooled. Reuse is
/// invisible to results: every buffer is cleared before use and no fold
/// order changes (pinned by
/// `prop_scratch_reuse_leaves_records_byte_identical`).
#[derive(Debug, Default)]
pub struct EngineScratch {
    f64_pool: Vec<Vec<f64>>,
    u32_pool: Vec<Vec<u32>>,
    keyed_pool: Vec<Vec<(u64, Phase)>>,
}

/// Pool-size cap: prevents pathological growth when a wide batch returns
/// more buffers than steady-state execution takes back out.
const SCRATCH_POOL_CAP: usize = 64;

impl EngineScratch {
    pub fn new() -> EngineScratch {
        EngineScratch::default()
    }

    fn take_f64(&mut self) -> Vec<f64> {
        let mut v = self.f64_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    fn take_u32(&mut self) -> Vec<u32> {
        let mut v = self.u32_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    fn take_keyed(&mut self) -> Vec<(u64, Phase)> {
        let mut v = self.keyed_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    fn put_f64(&mut self, v: Vec<f64>) {
        if self.f64_pool.len() < SCRATCH_POOL_CAP {
            self.f64_pool.push(v);
        }
    }

    fn put_u32(&mut self, v: Vec<u32>) {
        if self.u32_pool.len() < SCRATCH_POOL_CAP {
            self.u32_pool.push(v);
        }
    }

    fn put_keyed(&mut self, v: Vec<(u64, Phase)>) {
        if self.keyed_pool.len() < SCRATCH_POOL_CAP {
            self.keyed_pool.push(v);
        }
    }
}

thread_local! {
    /// Per-thread scratch backing the signature-stable entry points
    /// (`execute`, `execute_compiled`, `execute_batch`); the `_scratch`
    /// variants accept an explicit pool for callers that manage their own.
    static SCRATCH: std::cell::RefCell<EngineScratch> =
        std::cell::RefCell::new(EngineScratch::new());
}

/// Resolved stochastic state of one run: everything pass 2 needs to expand
/// phases without touching the RNG.
struct Resolved {
    /// Flat pool of sampled compute durations (per op, per rank in range).
    durs: Vec<f64>,
    /// Per-op offset into `durs` (compute ops only).
    dur_at: Vec<u32>,
    /// Per-op resolved rendezvous / edge-ready time (sync ops only).
    sync_t: Vec<f64>,
    /// Final per-rank clocks.
    clocks: Vec<f64>,
    wait_samples: Vec<f64>,
    prefill_end: f64,
}

/// Pass 1: resolve clocks, rendezvous times, and all stochastic draws.
fn resolve(plan: &Plan, skew: &SkewModel, sync_jitter: f64, rng: &mut Rng) -> Resolved {
    let n_ops = plan.ops.len();
    let mut clocks = vec![0.0f64; plan.num_ranks];
    let mut durs: Vec<f64> = Vec::new();
    let mut dur_at = vec![0u32; n_ops];
    let mut sync_t = vec![0.0f64; n_ops];
    let mut edges = vec![0.0f64; plan.num_edges as usize];
    let mut wait_samples = Vec::new();
    let mut prefill_end = 0.0f64;

    for (i, op) in plan.ops.iter().enumerate() {
        match op {
            Op::Compute {
                ranks,
                module,
                nominal_s,
                ..
            } => {
                dur_at[i] = durs.len() as u32;
                for rank in ranks.iter() {
                    let d = skew.sample_module(*nominal_s, rank, *module, rng);
                    durs.push(d);
                    clocks[rank] += d;
                }
            }
            Op::Collective {
                ranks,
                transfer_s,
                jitter,
                record,
                ..
            } => {
                // Rendezvous: the straggler-determined start time. The fold
                // from 0.0 matches the planners' historical arrival max.
                let mut arrive = 0.0f64;
                if *jitter {
                    for rank in ranks.iter() {
                        arrive = arrive.max(clocks[rank] + rng.exponential(sync_jitter));
                    }
                } else {
                    for rank in ranks.iter() {
                        arrive = arrive.max(clocks[rank]);
                    }
                }
                sync_t[i] = arrive;
                for rank in ranks.iter() {
                    let waited = (arrive - clocks[rank]).max(0.0);
                    match record {
                        WaitRecord::All => wait_samples.push(waited),
                        WaitRecord::None => {}
                    }
                    clocks[rank] = clocks[rank].max(arrive) + transfer_s;
                }
            }
            Op::Send {
                ranks,
                transfer_s,
                edge,
                ..
            } => {
                let mut done = 0.0f64;
                for rank in ranks.iter() {
                    clocks[rank] += transfer_s;
                    done = done.max(clocks[rank]);
                }
                edges[*edge as usize] = done;
            }
            Op::Recv { ranks, edge, .. } => {
                let ready = edges[*edge as usize];
                sync_t[i] = ready;
                for rank in ranks.iter() {
                    let waited = (ready - clocks[rank]).max(0.0);
                    if waited > 0.0 {
                        wait_samples.push(waited);
                    }
                    clocks[rank] = clocks[rank].max(ready);
                }
            }
        }
        if op.step() == 0 {
            for rank in op.ranks().iter() {
                prefill_end = prefill_end.max(clocks[rank]);
            }
        }
    }

    Resolved {
        durs,
        dur_at,
        sync_t,
        clocks,
        wait_samples,
        prefill_end,
    }
}

/// Ordering key reproducing the serial emission order inside one op:
/// all waits (class 0) in rank order, then all transfers (class 1).
#[inline]
fn seq_key(op_idx: usize, class: u8, rank: usize) -> u64 {
    ((op_idx as u64) << 24) | ((class as u64) << 16) | rank as u64
}

/// Pass 2 (per rank): expand this rank's ops into keyed phases.
fn rank_phases(
    plan: &Plan,
    res: &Resolved,
    power: &PowerModel,
    rank: usize,
) -> Vec<(u64, Phase)> {
    let wait_w = power.gpu_power_rank(PhaseKind::Wait, 0.0, rank);
    let comm_w = power.gpu_power_rank(PhaseKind::Transfer, 0.0, rank);
    let mut clock = 0.0f64;
    let mut out = Vec::new();
    let mut push = |key: u64, kind, module, layer, step, t0: f64, t1: f64, power_w| {
        if t1 > t0 {
            out.push((
                key,
                Phase {
                    gpu: rank as u16,
                    kind,
                    module,
                    layer,
                    step,
                    t0,
                    t1,
                    power_w,
                },
            ));
        }
    };
    for (i, op) in plan.ops.iter().enumerate() {
        let ranks = op.ranks();
        if !ranks.contains(rank) {
            continue;
        }
        match op {
            Op::Compute {
                module,
                layer,
                step,
                util,
                ..
            } => {
                let d = res.durs[res.dur_at[i] as usize + (rank - ranks.first as usize)];
                let p = power.gpu_power_rank(PhaseKind::Compute, *util, rank);
                push(seq_key(i, 0, rank), PhaseKind::Compute, *module, *layer, *step, clock, clock + d, p);
                clock += d;
            }
            Op::Collective {
                module,
                layer,
                step,
                transfer_s,
                wire_w,
                ..
            } => {
                let t = res.sync_t[i];
                push(seq_key(i, 0, rank), PhaseKind::Wait, *module, *layer, *step, clock, clock.max(t), wait_w);
                clock = clock.max(t);
                let end = clock + transfer_s;
                // Link-tier wire power rides on top of the board's transfer
                // draw (wire_w is 0 on the legacy flat link).
                let p = comm_w + wire_w * power.thermal_mult;
                push(seq_key(i, 1, rank), PhaseKind::Transfer, *module, *layer, *step, clock, end, p);
                clock += transfer_s;
            }
            Op::Send {
                layer,
                step,
                transfer_s,
                wire_w,
                ..
            } => {
                push(
                    seq_key(i, 0, rank),
                    PhaseKind::Transfer,
                    ModuleKind::P2PTransfer,
                    *layer,
                    *step,
                    clock,
                    clock + transfer_s,
                    comm_w + wire_w * power.thermal_mult,
                );
                clock += transfer_s;
            }
            Op::Recv { layer, step, .. } => {
                let t = res.sync_t[i];
                push(
                    seq_key(i, 0, rank),
                    PhaseKind::Wait,
                    ModuleKind::P2PTransfer,
                    *layer,
                    *step,
                    clock,
                    clock.max(t),
                    wait_w,
                );
                clock = clock.max(t);
            }
        }
    }
    debug_assert!(
        (clock - res.clocks[rank]).abs() < 1e-12,
        "rank {rank} clock drift: {clock} vs {}",
        res.clocks[rank]
    );
    out
}

/// Pass 1 over the compiled SoA arrays: identical walk, clock advance, and
/// RNG draw order to `resolve` — the two paths are bit-identical for the
/// same seed stream (property-tested).
fn resolve_compiled(
    ep: &ExecPlan,
    skew: &SkewModel,
    sync_jitter: f64,
    rng: &mut Rng,
    scratch: &mut EngineScratch,
) -> Resolved {
    let s = &*ep.structure;
    let sc = &*ep.scalars;
    let n_ops = s.len();
    // Clocks and wait samples escape into the `BuiltRun`; the rest come
    // from (and return to) the scratch pool.
    let mut clocks = vec![0.0f64; s.num_ranks];
    let mut durs = scratch.take_f64();
    let mut dur_at = scratch.take_u32();
    dur_at.resize(n_ops, 0);
    let mut sync_t = scratch.take_f64();
    sync_t.resize(n_ops, 0.0);
    let mut edges = scratch.take_f64();
    edges.resize(s.num_edges as usize, 0.0);
    let mut wait_samples = Vec::new();
    let mut prefill_end = 0.0f64;

    for i in 0..n_ops {
        let ranks = s.ranks[i];
        match s.kind[i] {
            OpKind::Compute => {
                dur_at[i] = durs.len() as u32;
                let nominal_s = sc.dur_s[i];
                let module = s.module[i];
                for rank in ranks.iter() {
                    let d = skew.sample_module(nominal_s, rank, module, rng);
                    durs.push(d);
                    clocks[rank] += d;
                }
            }
            OpKind::Collective => {
                let mut arrive = 0.0f64;
                if s.jitter[i] {
                    for rank in ranks.iter() {
                        arrive = arrive.max(clocks[rank] + rng.exponential(sync_jitter));
                    }
                } else {
                    for rank in ranks.iter() {
                        arrive = arrive.max(clocks[rank]);
                    }
                }
                sync_t[i] = arrive;
                let transfer_s = sc.dur_s[i];
                for rank in ranks.iter() {
                    let waited = (arrive - clocks[rank]).max(0.0);
                    match s.record[i] {
                        WaitRecord::All => wait_samples.push(waited),
                        WaitRecord::None => {}
                    }
                    clocks[rank] = clocks[rank].max(arrive) + transfer_s;
                }
            }
            OpKind::Send => {
                let transfer_s = sc.dur_s[i];
                let mut done = 0.0f64;
                for rank in ranks.iter() {
                    clocks[rank] += transfer_s;
                    done = done.max(clocks[rank]);
                }
                edges[s.edge[i] as usize] = done;
            }
            OpKind::Recv => {
                let ready = edges[s.edge[i] as usize];
                sync_t[i] = ready;
                for rank in ranks.iter() {
                    let waited = (ready - clocks[rank]).max(0.0);
                    if waited > 0.0 {
                        wait_samples.push(waited);
                    }
                    clocks[rank] = clocks[rank].max(ready);
                }
            }
        }
        if s.step[i] == 0 {
            for rank in ranks.iter() {
                prefill_end = prefill_end.max(clocks[rank]);
            }
        }
    }
    scratch.put_f64(edges);

    Resolved {
        durs,
        dur_at,
        sync_t,
        clocks,
        wait_samples,
        prefill_end,
    }
}

/// Pass 2 over the compiled arrays (per rank): identical phase emission
/// and key order to `rank_phases`.
fn rank_phases_compiled(ep: &ExecPlan, res: &Resolved, power: &PowerModel, rank: usize) -> Vec<(u64, Phase)> {
    let s = &*ep.structure;
    let sc = &*ep.scalars;
    let wait_w = power.gpu_power_rank(PhaseKind::Wait, 0.0, rank);
    let comm_w = power.gpu_power_rank(PhaseKind::Transfer, 0.0, rank);
    let mut clock = 0.0f64;
    let mut out = Vec::new();
    let mut push = |key: u64, kind, module, layer, step, t0: f64, t1: f64, power_w| {
        if t1 > t0 {
            out.push((
                key,
                Phase {
                    gpu: rank as u16,
                    kind,
                    module,
                    layer,
                    step,
                    t0,
                    t1,
                    power_w,
                },
            ));
        }
    };
    for i in 0..s.len() {
        let ranks = s.ranks[i];
        if !ranks.contains(rank) {
            continue;
        }
        let (module, layer, step) = (s.module[i], s.layer[i], s.step[i]);
        match s.kind[i] {
            OpKind::Compute => {
                let d = res.durs[res.dur_at[i] as usize + (rank - ranks.first as usize)];
                let p = power.gpu_power_rank(PhaseKind::Compute, sc.aux[i], rank);
                push(seq_key(i, 0, rank), PhaseKind::Compute, module, layer, step, clock, clock + d, p);
                clock += d;
            }
            OpKind::Collective => {
                let t = res.sync_t[i];
                push(seq_key(i, 0, rank), PhaseKind::Wait, module, layer, step, clock, clock.max(t), wait_w);
                clock = clock.max(t);
                let transfer_s = sc.dur_s[i];
                let end = clock + transfer_s;
                // Link-tier wire power rides on top of the board's transfer
                // draw (aux is 0 on the legacy flat link).
                let p = comm_w + sc.aux[i] * power.thermal_mult;
                push(seq_key(i, 1, rank), PhaseKind::Transfer, module, layer, step, clock, end, p);
                clock += transfer_s;
            }
            OpKind::Send => {
                let transfer_s = sc.dur_s[i];
                push(
                    seq_key(i, 0, rank),
                    PhaseKind::Transfer,
                    ModuleKind::P2PTransfer,
                    layer,
                    step,
                    clock,
                    clock + transfer_s,
                    comm_w + sc.aux[i] * power.thermal_mult,
                );
                clock += transfer_s;
            }
            OpKind::Recv => {
                let t = res.sync_t[i];
                push(
                    seq_key(i, 0, rank),
                    PhaseKind::Wait,
                    ModuleKind::P2PTransfer,
                    layer,
                    step,
                    clock,
                    clock.max(t),
                    wait_w,
                );
                clock = clock.max(t);
            }
        }
    }
    debug_assert!(
        (clock - res.clocks[rank]).abs() < 1e-12,
        "rank {rank} clock drift: {clock} vs {}",
        res.clocks[rank]
    );
    out
}

/// Shared tail of pass 2: merge the keyed per-rank phase lists back into
/// the exact serial emission order, bill the idle tail per rank, and wrap
/// the run's side channels. Used verbatim by the single-plan and batched
/// execution paths so their timelines cannot drift.
#[allow(clippy::too_many_arguments)]
fn materialize(
    num_ranks: usize,
    power: &PowerModel,
    mut keyed: Vec<(u64, Phase)>,
    res: Resolved,
    sim_steps: usize,
    comm_bytes_per_step: f64,
    trace: bool,
    scratch: &mut EngineScratch,
) -> BuiltRun {
    keyed.sort_unstable_by_key(|(k, _)| *k);
    // The op index is the high bits of the emission key (`seq_key`), so
    // the trace capture is a projection of the sort — no extra bookkeeping
    // in the walk, and strictly zero work when the knob is off.
    let trace = trace.then(|| crate::trace::Trace {
        ops: keyed.iter().map(|(k, _)| (k >> 24) as u32).collect(),
    });
    let phases: Vec<Phase> = keyed.drain(..).map(|(_, p)| p).collect();
    scratch.put_keyed(keyed);
    // Pass-1 working vectors go back to the pool; clocks and wait samples
    // escape into the timeline / run record and stay owned.
    scratch.put_f64(res.durs);
    scratch.put_u32(res.dur_at);
    scratch.put_f64(res.sync_t);

    let mut timeline = Timeline::from_parts(
        num_ranks,
        power.gpu_power(PhaseKind::Idle, 0.0),
        phases,
        res.clocks,
    );
    let idle_w: Vec<f64> = (0..num_ranks)
        .map(|r| power.gpu_power_rank(PhaseKind::Idle, 0.0, r))
        .collect();
    timeline.finalize_with(&idle_w);

    BuiltRun {
        timeline,
        wait_samples: res.wait_samples,
        prefill_end: res.prefill_end,
        sim_steps,
        comm_bytes_per_step,
        trace,
    }
}

/// Execute a compiled `ExecPlan` under the run's stochastic conditions —
/// the hot execution path. Walks the structure-of-arrays form directly
/// (no `Op` enum dispatch or pointer chasing); the serial resolve pass
/// order is unchanged, so seeded results are bit-identical to the
/// interpreted `execute` (which remains as the reference mode behind
/// `SimKnobs::reference_engine`).
pub fn execute_compiled(
    ep: &ExecPlan,
    power: &PowerModel,
    skew: &SkewModel,
    sync_jitter: f64,
    rng: &mut Rng,
    threads: usize,
    trace: bool,
) -> BuiltRun {
    SCRATCH.with(|s| {
        execute_compiled_scratch(ep, power, skew, sync_jitter, rng, threads, trace, &mut s.borrow_mut())
    })
}

/// `execute_compiled` with an explicit scratch pool — the signature-stable
/// wrapper above routes through a per-thread pool; callers that manage
/// their own reuse (and the scratch property test) pass one here.
#[allow(clippy::too_many_arguments)]
pub fn execute_compiled_scratch(
    ep: &ExecPlan,
    power: &PowerModel,
    skew: &SkewModel,
    sync_jitter: f64,
    rng: &mut Rng,
    threads: usize,
    trace: bool,
    scratch: &mut EngineScratch,
) -> BuiltRun {
    let res = resolve_compiled(ep, skew, sync_jitter, rng, scratch);

    let num_ranks = ep.num_ranks();
    let ranks: Vec<usize> = (0..num_ranks).collect();
    let per_rank = par::par_map(&ranks, threads, |&r| rank_phases_compiled(ep, &res, power, r));
    let mut keyed = scratch.take_keyed();
    for mut v in per_rank {
        keyed.append(&mut v);
        scratch.put_keyed(v);
    }
    materialize(num_ranks, power, keyed, res, ep.scalars.sim_steps, ep.scalars.comm_bytes_per_step, trace, scratch)
}

/// Per-lane stochastic state of a batched execution. Each candidate owns
/// its complete run-conditions chain — power model, sampled skew state,
/// launch-desync scale, and seeded RNG — so interleaving the lanes through
/// one op walk preserves every lane's intra-stream draw order, which is
/// what makes the batched path bit-identical per lane to a serial
/// `execute_compiled` of that lane alone (DESIGN.md §14).
pub struct BatchLane {
    pub power: PowerModel,
    pub skew: SkewModel,
    pub sync_jitter: f64,
    pub rng: Rng,
}

/// Batched pass 1: ONE walk over the shared op/edge arrays resolving all
/// K lanes simultaneously. Per op, the inner loop visits the lanes in
/// order, each drawing from its own RNG against its own clocks/edges —
/// the per-lane draw sequence across ops is exactly the sequence
/// `resolve_compiled` would produce for that lane, so results are
/// bit-identical per lane (property-tested).
fn resolve_batch(batch: &ExecBatch, lanes: &mut [BatchLane], scratch: &mut EngineScratch) -> Vec<Resolved> {
    let s = &*batch.structure;
    let k = lanes.len();
    let n_ops = s.len();
    // The dur offsets are a pure function of the structure walk, identical
    // across lanes: computed once, cloned into each lane's `Resolved`.
    let mut dur_at = scratch.take_u32();
    dur_at.resize(n_ops, 0);
    let mut clocks = vec![vec![0.0f64; s.num_ranks]; k];
    let mut durs: Vec<Vec<f64>> = (0..k).map(|_| scratch.take_f64()).collect();
    let mut sync_t: Vec<Vec<f64>> = (0..k)
        .map(|_| {
            let mut v = scratch.take_f64();
            v.resize(n_ops, 0.0);
            v
        })
        .collect();
    let mut edges: Vec<Vec<f64>> = (0..k)
        .map(|_| {
            let mut v = scratch.take_f64();
            v.resize(s.num_edges as usize, 0.0);
            v
        })
        .collect();
    let mut waits: Vec<Vec<f64>> = vec![Vec::new(); k];
    let mut prefill_end = vec![0.0f64; k];

    for i in 0..n_ops {
        let ranks = s.ranks[i];
        match s.kind[i] {
            OpKind::Compute => {
                dur_at[i] = durs[0].len() as u32;
                let module = s.module[i];
                for (l, lane) in lanes.iter_mut().enumerate() {
                    let nominal_s = batch.dur_s[i * k + l];
                    for rank in ranks.iter() {
                        let d = lane.skew.sample_module(nominal_s, rank, module, &mut lane.rng);
                        durs[l].push(d);
                        clocks[l][rank] += d;
                    }
                }
            }
            OpKind::Collective => {
                for (l, lane) in lanes.iter_mut().enumerate() {
                    let mut arrive = 0.0f64;
                    if s.jitter[i] {
                        for rank in ranks.iter() {
                            arrive = arrive.max(clocks[l][rank] + lane.rng.exponential(lane.sync_jitter));
                        }
                    } else {
                        for rank in ranks.iter() {
                            arrive = arrive.max(clocks[l][rank]);
                        }
                    }
                    sync_t[l][i] = arrive;
                    let transfer_s = batch.dur_s[i * k + l];
                    for rank in ranks.iter() {
                        let waited = (arrive - clocks[l][rank]).max(0.0);
                        match s.record[i] {
                            WaitRecord::All => waits[l].push(waited),
                            WaitRecord::None => {}
                        }
                        clocks[l][rank] = clocks[l][rank].max(arrive) + transfer_s;
                    }
                }
            }
            OpKind::Send => {
                for l in 0..k {
                    let transfer_s = batch.dur_s[i * k + l];
                    let mut done = 0.0f64;
                    for rank in ranks.iter() {
                        clocks[l][rank] += transfer_s;
                        done = done.max(clocks[l][rank]);
                    }
                    edges[l][s.edge[i] as usize] = done;
                }
            }
            OpKind::Recv => {
                for l in 0..k {
                    let ready = edges[l][s.edge[i] as usize];
                    sync_t[l][i] = ready;
                    for rank in ranks.iter() {
                        let waited = (ready - clocks[l][rank]).max(0.0);
                        if waited > 0.0 {
                            waits[l].push(waited);
                        }
                        clocks[l][rank] = clocks[l][rank].max(ready);
                    }
                }
            }
        }
        if s.step[i] == 0 {
            for l in 0..k {
                for rank in ranks.iter() {
                    prefill_end[l] = prefill_end[l].max(clocks[l][rank]);
                }
            }
        }
    }

    for e in edges {
        scratch.put_f64(e);
    }
    let out: Vec<Resolved> = durs
        .into_iter()
        .zip(sync_t)
        .zip(clocks)
        .zip(waits)
        .zip(prefill_end)
        .map(|((((durs, sync_t), clocks), wait_samples), prefill_end)| Resolved {
            durs,
            dur_at: dur_at.clone(),
            sync_t,
            clocks,
            wait_samples,
            prefill_end,
        })
        .collect();
    scratch.put_u32(dur_at);
    out
}

/// Execute K shape-bindings of one mesh structure in a single engine
/// pass: one batched resolve walk, then phase materialization over all
/// (lane, rank) pairs through the `util::par` pool. Returns one
/// `BuiltRun` per lane, each bit-identical to what `execute_compiled`
/// would produce for that lane's plan and stochastic state alone.
pub fn execute_batch(batch: &ExecBatch, lanes: &mut [BatchLane], threads: usize, trace: bool) -> Vec<BuiltRun> {
    SCRATCH.with(|s| execute_batch_scratch(batch, lanes, threads, trace, &mut s.borrow_mut()))
}

/// `execute_batch` with an explicit scratch pool (see
/// [`execute_compiled_scratch`]).
pub fn execute_batch_scratch(
    batch: &ExecBatch,
    lanes: &mut [BatchLane],
    threads: usize,
    trace: bool,
    scratch: &mut EngineScratch,
) -> Vec<BuiltRun> {
    assert_eq!(lanes.len(), batch.width(), "one stochastic lane per candidate");
    let reses = resolve_batch(batch, lanes, scratch);
    let lanes: &[BatchLane] = lanes;

    let num_ranks = batch.structure.num_ranks;
    let jobs: Vec<(usize, usize)> = (0..batch.width())
        .flat_map(|l| (0..num_ranks).map(move |r| (l, r)))
        .collect();
    let per_job = par::par_map(&jobs, threads, |&(l, r)| {
        rank_phases_compiled(&batch.lanes[l], &reses[l], &lanes[l].power, r)
    });

    let mut per_job = per_job.into_iter();
    let mut runs = Vec::with_capacity(batch.width());
    for (l, res) in reses.into_iter().enumerate() {
        let mut keyed = scratch.take_keyed();
        for _ in 0..num_ranks {
            let mut v = per_job.next().expect("one materialization job per (lane, rank)");
            keyed.append(&mut v);
            scratch.put_keyed(v);
        }
        let sc = &batch.lanes[l].scalars;
        runs.push(materialize(
            num_ranks,
            &lanes[l].power,
            keyed,
            res,
            sc.sim_steps,
            sc.comm_bytes_per_step,
            trace,
            scratch,
        ));
    }
    runs
}

/// Execute a plan under the run's stochastic conditions. `threads` bounds
/// the `util::par` pool materializing per-rank phases (1 ⇒ serial; the
/// result is bit-identical either way).
pub fn execute(
    plan: &Plan,
    power: &PowerModel,
    skew: &SkewModel,
    sync_jitter: f64,
    rng: &mut Rng,
    threads: usize,
    trace: bool,
) -> BuiltRun {
    let res = resolve(plan, skew, sync_jitter, rng);

    // `threads` follows the `util::par` convention: 0 ⇒ available cores,
    // 1 ⇒ serial map (no spawn). Tail padding is billed at each rank's own
    // idle draw inside `materialize` (heterogeneous fleets); on the
    // homogeneous baseline every entry equals the global idle power, so
    // this is exactly the legacy `finalize`.
    let ranks: Vec<usize> = (0..plan.num_ranks).collect();
    let per_rank = par::par_map(&ranks, threads, |&r| rank_phases(plan, &res, power, r));
    let keyed: Vec<(u64, Phase)> = per_rank.into_iter().flatten().collect();
    SCRATCH.with(|s| {
        materialize(
            plan.num_ranks,
            power,
            keyed,
            res,
            plan.sim_steps,
            plan.comm_bytes_per_step,
            trace,
            &mut s.borrow_mut(),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwSpec, SimKnobs};
    use crate::plan::{PlanBuilder, PlanSink};
    use crate::simulator::perf::ModuleTiming;

    fn setup() -> (PowerModel, SkewModel, Rng) {
        let hw = HwSpec::default();
        let mut rng = Rng::new(7);
        let skew = SkewModel::new(&SimKnobs::default(), 4, &mut rng);
        (PowerModel::new(&hw), skew, rng)
    }

    fn t(dur: f64) -> ModuleTiming {
        ModuleTiming {
            dur_s: dur,
            util: 0.7,
        }
    }

    #[test]
    fn rendezvous_waits_align_ranks() {
        let (power, skew, mut rng) = setup();
        let mut b = PlanBuilder::new(4);
        b.compute(0..4, t(1e-3), ModuleKind::Mlp, 0, 0);
        b.collective(0..4, ModuleKind::AllReduce, 0, 0, 1e-4, false, WaitRecord::All);
        let plan = b.finish(1, 0.0, false);
        let run = execute(&plan, &power, &skew, 0.0, &mut rng, 1, false);
        // All four ranks end at rendezvous + transfer.
        let end = run.timeline.clock(0);
        for r in 1..4 {
            assert!((run.timeline.clock(r) - end).abs() < 1e-15);
        }
        // Exactly one rank (the straggler) waited zero.
        assert_eq!(run.wait_samples.len(), 4);
        assert_eq!(run.wait_samples.iter().filter(|&&w| w == 0.0).count(), 1);
    }

    #[test]
    fn p2p_edge_gates_receiver() {
        let (power, skew, mut rng) = setup();
        let mut b = PlanBuilder::new(2);
        b.compute(0..1, t(2e-3), ModuleKind::Mlp, 0, 0);
        let e = b.send(0..1, 1, 0, 5e-4);
        b.recv(1..2, 1, 0, e);
        b.compute(1..2, t(1e-3), ModuleKind::Mlp, 1, 0);
        let plan = b.finish(1, 0.0, false);
        let run = execute(&plan, &power, &skew, 0.0, &mut rng, 1, false);
        let tl = &run.timeline;
        // Receiver's first phase is the recorded busy-wait on the edge.
        let first = tl.phases.iter().find(|p| p.gpu == 1).unwrap();
        assert_eq!(first.kind, PhaseKind::Wait);
        assert_eq!(first.module, ModuleKind::P2PTransfer);
        assert_eq!(run.wait_samples.len(), 1);
        // Sender transfer ends exactly where the receiver wait ends.
        let send_end = tl
            .phases
            .iter()
            .find(|p| p.gpu == 0 && p.kind == PhaseKind::Transfer)
            .unwrap()
            .t1;
        assert!((first.t1 - send_end).abs() < 1e-15);
    }

    #[test]
    fn barrier_records_no_samples_but_wait_phases() {
        let (power, skew, mut rng) = setup();
        let mut b = PlanBuilder::new(2);
        b.compute(0..2, t(1e-3), ModuleKind::Mlp, 0, 1);
        b.collective(0..2, ModuleKind::P2PTransfer, 0, 1, 0.0, false, WaitRecord::None);
        let plan = b.finish(1, 0.0, false);
        let run = execute(&plan, &power, &skew, 0.0, &mut rng, 1, false);
        assert!(run.wait_samples.is_empty());
        assert!(run
            .timeline
            .phases
            .iter()
            .any(|p| p.kind == PhaseKind::Wait));
        assert!((run.timeline.clock(0) - run.timeline.clock(1)).abs() < 1e-15);
    }

    #[test]
    fn serial_and_parallel_materialization_bit_identical() {
        let hw = HwSpec::default();
        let power = PowerModel::new(&hw);
        let mut b = PlanBuilder::new(4);
        for step in 0..3u32 {
            for layer in 0..8u16 {
                b.compute(0..4, t(1e-3), ModuleKind::SelfAttention, layer, step);
                b.collective(0..4, ModuleKind::AllReduce, layer, step, 1e-4, true, WaitRecord::All);
            }
            let e = b.send(0..2, 0, step, 2e-4);
            b.recv(2..4, 0, step, e);
        }
        let plan = b.finish(2, 1.0, true);
        let exec = |threads: usize| {
            let mut rng = Rng::new(11);
            let skew = SkewModel::new(&SimKnobs::default(), 4, &mut rng);
            execute(&plan, &power, &skew, 40e-6, &mut rng, threads, false)
        };
        let (a, b) = (exec(1), exec(4));
        assert_eq!(a.wait_samples, b.wait_samples);
        assert_eq!(a.prefill_end, b.prefill_end);
        assert_eq!(a.timeline.phases.len(), b.timeline.phases.len());
        for (pa, pb) in a.timeline.phases.iter().zip(&b.timeline.phases) {
            assert_eq!(pa.gpu, pb.gpu);
            assert_eq!(pa.kind, pb.kind);
            assert_eq!(pa.t0, pb.t0);
            assert_eq!(pa.t1, pb.t1);
            assert_eq!(pa.power_w, pb.power_w);
        }
        assert_eq!(a.timeline.gpu_energy_j(), b.timeline.gpu_energy_j());
    }

    #[test]
    fn compiled_execution_is_bit_identical_to_interpreted() {
        // Same seed stream through the SoA walk and the Op-enum walk.
        let hw = HwSpec::default();
        let power = PowerModel::new(&hw);
        let mut b = PlanBuilder::new(4);
        for step in 0..3u32 {
            for layer in 0..6u16 {
                b.compute(0..4, t(1e-3), ModuleKind::SelfAttention, layer, step);
                b.collective(0..4, ModuleKind::AllReduce, layer, step, 1e-4, true, WaitRecord::All);
            }
            let e = b.send(0..2, 0, step, 2e-4);
            b.recv(2..4, 0, step, e);
            b.collective(0..4, ModuleKind::P2PTransfer, 0, step, 0.0, false, WaitRecord::None);
        }
        let plan = b.finish(2, 1.0, true);
        let ep = crate::plan::exec::compile(&plan);
        let run = |compiled: bool| {
            let mut rng = Rng::new(23);
            let skew = SkewModel::new(&SimKnobs::default(), 4, &mut rng);
            if compiled {
                execute_compiled(&ep, &power, &skew, 40e-6, &mut rng, 1, false)
            } else {
                execute(&plan, &power, &skew, 40e-6, &mut rng, 1, false)
            }
        };
        let (a, b) = (run(false), run(true));
        assert_eq!(a.wait_samples, b.wait_samples);
        assert_eq!(a.prefill_end, b.prefill_end);
        assert_eq!(a.sim_steps, b.sim_steps);
        assert_eq!(a.timeline.phases.len(), b.timeline.phases.len());
        for (pa, pb) in a.timeline.phases.iter().zip(&b.timeline.phases) {
            assert_eq!((pa.gpu, pa.kind, pa.module), (pb.gpu, pb.kind, pb.module));
            assert_eq!(pa.t0, pb.t0);
            assert_eq!(pa.t1, pb.t1);
            assert_eq!(pa.power_w, pb.power_w);
        }
        assert_eq!(a.timeline.gpu_energy_j(), b.timeline.gpu_energy_j());
    }

    #[test]
    fn batched_execution_is_bit_identical_per_lane() {
        // K shape-bindings of one structure through ONE resolve walk must
        // reproduce K serial `execute_compiled` runs exactly — phases,
        // waits, clocks — for the same per-lane seed streams.
        use crate::plan::exec::{ExecBatch, ShapeBinding};
        use std::sync::Arc;

        let mut b = PlanBuilder::new(4);
        for step in 0..3u32 {
            for layer in 0..6u16 {
                b.compute(0..4, t(1e-3), ModuleKind::SelfAttention, layer, step);
                b.collective(0..4, ModuleKind::AllReduce, layer, step, 1e-4, true, WaitRecord::All);
            }
            let e = b.send(0..2, 0, step, 2e-4);
            b.recv(2..4, 0, step, e);
        }
        let plan = b.finish(2, 1.0, true);
        let base = crate::plan::exec::compile(&plan);
        // Lane plans: the base shape plus two scalar rebinds of it.
        let mut plans = vec![base.clone()];
        for scale in [1.5f64, 0.25] {
            let mut r = ShapeBinding::new(Arc::clone(&base.structure));
            for step in 0..3u32 {
                for layer in 0..6u16 {
                    r.compute(0..4, t(1e-3 * scale), ModuleKind::SelfAttention, layer, step);
                    r.collective(0..4, ModuleKind::AllReduce, layer, step, 1e-4 * scale, true, WaitRecord::All);
                }
                let e = r.send(0..2, 0, step, 2e-4 * scale);
                r.recv(2..4, 0, step, e);
            }
            plans.push(r.finish(2, 1.0, true));
        }

        let lane_state = |seed: u64| {
            let hw = HwSpec::default();
            let mut rng = Rng::new(seed);
            let skew = SkewModel::new(&SimKnobs::default(), 4, &mut rng);
            (PowerModel::new(&hw), skew, rng)
        };
        let serial: Vec<BuiltRun> = plans
            .iter()
            .enumerate()
            .map(|(l, ep)| {
                let (power, skew, mut rng) = lane_state(100 + l as u64);
                execute_compiled(ep, &power, &skew, 40e-6, &mut rng, 1, false)
            })
            .collect();
        for threads in [1usize, 4] {
            let mut lanes: Vec<BatchLane> = (0..plans.len())
                .map(|l| {
                    let (power, skew, rng) = lane_state(100 + l as u64);
                    BatchLane {
                        power,
                        skew,
                        sync_jitter: 40e-6,
                        rng,
                    }
                })
                .collect();
            let batch = ExecBatch::new(plans.clone());
            let batched = execute_batch(&batch, &mut lanes, threads, false);
            assert_eq!(batched.len(), serial.len());
            for (a, b) in serial.iter().zip(&batched) {
                assert_eq!(a.wait_samples, b.wait_samples);
                assert_eq!(a.prefill_end, b.prefill_end);
                assert_eq!(a.timeline.phases.len(), b.timeline.phases.len());
                for (pa, pb) in a.timeline.phases.iter().zip(&b.timeline.phases) {
                    assert_eq!((pa.gpu, pa.kind, pa.module), (pb.gpu, pb.kind, pb.module));
                    assert_eq!(pa.t0, pb.t0);
                    assert_eq!(pa.t1, pb.t1);
                    assert_eq!(pa.power_w, pb.power_w);
                }
                assert_eq!(a.timeline.gpu_energy_j(), b.timeline.gpu_energy_j());
            }
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_runs() {
        // Two consecutive runs through one scratch pool must equal a run
        // through a fresh pool: buffers are cleared on take and no fold
        // order changes. The second run is the interesting one — it draws
        // warm (previously returned) buffers.
        let hw = HwSpec::default();
        let power = PowerModel::new(&hw);
        let mut b = PlanBuilder::new(4);
        for step in 0..3u32 {
            for layer in 0..6u16 {
                b.compute(0..4, t(1e-3), ModuleKind::SelfAttention, layer, step);
                b.collective(0..4, ModuleKind::AllReduce, layer, step, 1e-4, true, WaitRecord::All);
            }
            let e = b.send(0..2, 0, step, 2e-4);
            b.recv(2..4, 0, step, e);
        }
        let plan = b.finish(2, 1.0, true);
        let ep = crate::plan::exec::compile(&plan);
        let run_with = |scratch: &mut EngineScratch| {
            let mut rng = Rng::new(23);
            let skew = SkewModel::new(&SimKnobs::default(), 4, &mut rng);
            execute_compiled_scratch(&ep, &power, &skew, 40e-6, &mut rng, 1, true, scratch)
        };
        let fresh = run_with(&mut EngineScratch::new());
        let mut pool = EngineScratch::new();
        let first = run_with(&mut pool);
        let second = run_with(&mut pool);
        for r in [&first, &second] {
            assert_eq!(fresh.wait_samples, r.wait_samples);
            assert_eq!(fresh.prefill_end, r.prefill_end);
            assert_eq!(fresh.timeline.phases.len(), r.timeline.phases.len());
            for (pa, pb) in fresh.timeline.phases.iter().zip(&r.timeline.phases) {
                assert_eq!((pa.gpu, pa.kind, pa.module), (pb.gpu, pb.kind, pb.module));
                assert_eq!(pa.t0, pb.t0);
                assert_eq!(pa.t1, pb.t1);
                assert_eq!(pa.power_w, pb.power_w);
            }
            assert_eq!(fresh.trace.as_ref().unwrap().ops, r.trace.as_ref().unwrap().ops);
            assert_eq!(fresh.timeline.gpu_energy_j(), r.timeline.gpu_energy_j());
        }
    }

    #[test]
    fn heterogeneous_fleet_bills_idle_tail_per_rank() {
        use crate::cluster::{GpuSpec, LinkTier};
        let hw = HwSpec::cluster_testbed(1, 2, LinkTier::PciE, LinkTier::PciE, &[GpuSpec::a6000(), GpuSpec::h100()]);
        let power = PowerModel::new(&hw);
        let mut rng = Rng::new(3);
        let skew = SkewModel::new(&SimKnobs::default(), 2, &mut rng);
        // Rank 0 computes long, rank 1 short: rank 1 (an H100) idles a tail.
        let mut b = PlanBuilder::new(2);
        b.compute(0..1, t(5e-3), ModuleKind::Mlp, 0, 0);
        b.compute(1..2, t(1e-3), ModuleKind::Mlp, 0, 0);
        let plan = b.finish(1, 0.0, false);
        let run = execute(&plan, &power, &skew, 0.0, &mut rng, 1, false);
        let idle = run
            .timeline
            .phases
            .iter()
            .find(|p| p.gpu == 1 && p.kind == PhaseKind::Idle)
            .expect("rank 1 has an idle tail");
        // Billed at the H100's idle draw (60 W × thermal), not the A6000's.
        assert_eq!(idle.power_w, power.gpu_power_rank(PhaseKind::Idle, 0.0, 1));
        assert!(idle.power_w > power.gpu_power(PhaseKind::Idle, 0.0));
    }

    #[test]
    fn trace_capture_aligns_ops_with_phases() {
        let (power, skew, mut rng) = setup();
        let mut b = PlanBuilder::new(4);
        b.compute(0..4, t(1e-3), ModuleKind::SelfAttention, 0, 0);
        b.collective(0..4, ModuleKind::AllReduce, 0, 0, 1e-4, false, WaitRecord::All);
        let e = b.send(0..2, 0, 0, 2e-4);
        b.recv(2..4, 0, 0, e);
        let plan = b.finish(1, 0.0, false);
        let ep = crate::plan::exec::compile(&plan);
        let run = execute_compiled(&ep, &power, &skew, 0.0, &mut rng, 1, true);
        let trace = run.trace.as_ref().expect("trace captured when on");
        // One entry per materialized phase, none for the idle tails.
        assert!(trace.ops.len() <= run.timeline.phases.len());
        for (i, p) in run.timeline.phases.iter().enumerate() {
            match trace.op_of(i) {
                Some(op) => {
                    // The op the phase maps to really covers its rank.
                    let r = ep.structure.ranks[op as usize];
                    assert!(r.contains(p.gpu as usize), "phase {i} op {op}");
                    assert_eq!(p.step, ep.structure.step[op as usize]);
                }
                None => assert_eq!(p.kind, PhaseKind::Idle, "only idle tails lack an op"),
            }
        }
        // Op indices are nondecreasing — the emission-key projection.
        assert!(trace.ops.windows(2).all(|w| w[0] <= w[1]));
        // Knob off: identical run, no capture.
        let mut rng2 = Rng::new(7);
        let skew2 = SkewModel::new(&SimKnobs::default(), 4, &mut rng2);
        let off = execute_compiled(&ep, &power, &skew2, 0.0, &mut rng2, 1, false);
        assert!(off.trace.is_none());
        assert_eq!(off.timeline.gpu_energy_j(), run.timeline.gpu_energy_j());
    }

    #[test]
    fn prefill_end_tracks_step_zero_ops_only() {
        let (power, skew, mut rng) = setup();
        let mut b = PlanBuilder::new(2);
        b.compute(0..2, t(1e-3), ModuleKind::Mlp, 0, 0);
        b.compute(0..2, t(5e-3), ModuleKind::Mlp, 0, 1);
        let plan = b.finish(1, 0.0, false);
        let run = execute(&plan, &power, &skew, 0.0, &mut rng, 1, false);
        assert!(run.prefill_end > 0.0);
        assert!(run.prefill_end < run.timeline.makespan());
    }
}
